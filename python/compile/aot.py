"""AOT entry point: lower the L2 graphs to HLO *text* artifacts.

Run once at build time (``make artifacts``).  Emits one ``.hlo.txt`` per
(function, shape) variant plus ``manifest.json`` describing the I/O
signatures, which the Rust runtime (``rust/src/runtime/``) parses to load
and execute the artifacts via PJRT.

HLO text — NOT ``lowered.compile().serialize()`` and NOT a serialized
``HloModuleProto`` — is the interchange format: jax >= 0.5 emits protos
with 64-bit instruction ids that xla_extension 0.5.1 (the version the
published ``xla`` 0.1.6 crate binds) rejects; the text parser reassigns
ids and round-trips cleanly.  See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_variants():
    """(name, lowered, signature) for every artifact we ship.

    Shapes are chosen so interpret-mode Pallas stays fast on CPU while
    covering the tensor sizes the Rust analyzer samples (it tiles larger
    tensors across multiple calls).
    """
    variants = []

    # Empirical sparsity analyzer at three tensor scales.
    for (r, c, br, bc) in [(512, 512, 16, 16), (1024, 1024, 16, 16), (2048, 2048, 32, 32)]:
        name = f"sparsity_stats_{r}x{c}_b{br}"
        lowered = jax.jit(
            model.sparsity_stats, static_argnames=("block_r", "block_c")
        ).lower(_spec((r, c)), block_r=br, block_c=bc)
        sig = {
            "inputs": [{"shape": [r, c], "dtype": "f32"}],
            "outputs": [
                {"shape": [r // br, c // bc], "dtype": "f32"},
                {"shape": [r, 1], "dtype": "f32"},
                {"shape": [c], "dtype": "f32"},
                {"shape": [], "dtype": "f32"},
            ],
            "params": {"rows": r, "cols": c, "block_r": br, "block_c": bc},
        }
        variants.append((name, lowered, sig))

    # Batched format-cost scorer: 256 candidates x 6 levels.
    b, l = 256, 6
    name = f"format_cost_b{b}_l{l}"
    lowered = jax.jit(model.format_cost_batch).lower(
        _spec((b, l), jnp.int32),
        _spec((b, l)),
        _spec((b, l)),
        _spec((b, l + 1)),
        _spec(()),
    )
    sig = {
        "inputs": [
            {"shape": [b, l], "dtype": "i32"},
            {"shape": [b, l], "dtype": "f32"},
            {"shape": [b, l], "dtype": "f32"},
            {"shape": [b, l + 1], "dtype": "f32"},
            {"shape": [], "dtype": "f32"},
        ],
        "outputs": [{"shape": [b], "dtype": "f32"}],
        "params": {"batch": b, "levels": l},
    }
    variants.append((name, lowered, sig))

    # N:M conformance checker (2:4 over 1024x1024).
    name = "nm_conformance_1024x1024_2_4"
    lowered = jax.jit(
        model.nm_conformance, static_argnames=("n", "m", "block_r")
    ).lower(_spec((1024, 1024)), n=2, m=4, block_r=16)
    sig = {
        "inputs": [{"shape": [1024, 1024], "dtype": "f32"}],
        "outputs": [{"shape": [], "dtype": "f32"}],
        "params": {"n": 2, "m": 4},
    }
    variants.append((name, lowered, sig))

    return variants


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    )
    # kept for Makefile compatibility; --out <file> writes the manifest path
    parser.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = parser.parse_args()
    out_dir = os.path.abspath(
        os.path.dirname(args.out) if args.out else args.out_dir
    )
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"artifacts": []}
    for name, lowered, sig in build_variants():
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append({"name": name, "file": f"{name}.hlo.txt", **sig})
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
