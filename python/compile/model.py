"""L2: SnipSnap's empirical Sparsity Analyzer as a JAX compute graph.

Two entry points, both AOT-lowered to HLO text by ``aot.py`` and executed
from the Rust coordinator via PJRT (never imported at runtime):

- ``sparsity_stats``: one pass over a concrete sparse tensor producing the
  base occupancy lattice (per-block nnz via the L1 Pallas kernel) plus
  per-row / per-column nnz and the total count.  The Rust side aggregates
  these into non-empty node counts for *any* hierarchical format level.

- ``format_cost_batch``: batched scoring of compression-format candidates —
  given per-level primitive kinds, fanouts and non-empty node counts, it
  returns the expected total bits (metadata + payload) for every candidate
  in a single XLA call.  This is the vectorized twin of the Rust analytical
  scorer and of ``kernels/ref.py::format_cost_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import occupancy

# Primitive kind encoding, shared with ref.py and rust/src/format/.
KIND_NONE, KIND_B, KIND_CP, KIND_RLE, KIND_UOP = 0, 1, 2, 3, 4


@functools.partial(jax.jit, static_argnames=("block_r", "block_c"))
def sparsity_stats(x: jax.Array, block_r: int, block_c: int):
    """Base occupancy statistics of a 2-D sparse tensor.

    Returns:
      block_counts: (R/block_r, C/block_c) f32 — per-tile nnz (L1 kernel).
      row_counts:   (R, 1) f32 — per-row nnz (L1 kernel).
      col_counts:   (C,) f32 — per-column nnz.
      total:        () f32 — total nnz.
    """
    block_counts = occupancy.block_nnz(x, block_r, block_c)
    row_counts = occupancy.row_nnz(x, block_r)
    col_counts = jnp.sum((x != 0).astype(jnp.float32), axis=0)
    total = jnp.sum(block_counts)
    return block_counts, row_counts, col_counts, total


@jax.jit
def format_cost_batch(
    kinds: jax.Array,     # (B, L) int32
    fanouts: jax.Array,   # (B, L) f32
    widths: jax.Array,    # (B, L) f32 — metadata word width per level
    nonempty: jax.Array,  # (B, L+1) f32
    data_bits: jax.Array,  # () f32
):
    """Expected total bits per format candidate (see ref.format_cost_ref).

    Widths are precomputed by the caller (the Rust costing core derives
    CP/RLE/UOP word widths from the level geometry); the scorer is pure
    arithmetic, so the whole candidate batch fuses into one XLA
    computation.
    """
    fan = jnp.maximum(fanouts, 1.0)
    parents = nonempty[:, :-1]
    children = nonempty[:, 1:]

    bits_b = parents * fan
    bits_cp = children * widths
    bits_rle = (children + parents) * widths
    bits_uop = parents * (fan + 1.0) * widths

    lvl = jnp.where(kinds == KIND_B, bits_b, 0.0)
    lvl = jnp.where(kinds == KIND_CP, bits_cp, lvl)
    lvl = jnp.where(kinds == KIND_RLE, bits_rle, lvl)
    lvl = jnp.where(kinds == KIND_UOP, bits_uop, lvl)

    payload = nonempty[:, -1] * data_bits
    return (jnp.sum(lvl, axis=1) + payload,)


@functools.partial(jax.jit, static_argnames=("n", "m", "block_r"))
def nm_conformance(x: jax.Array, n: int, m: int, block_r: int):
    """Total N:M violations of a tensor (0.0 iff conforming)."""
    from .kernels import nm_check

    return (nm_check.nm_violations(x, n, m, block_r),)
