"""Pure-jnp correctness oracles for the Pallas kernels.

These are the golden references: trivially-correct formulations that the
kernels in ``occupancy.py`` / ``nm_check.py`` must match bit-exactly (the
counts are small integers held in f32, so ``assert_allclose`` with rtol=0
is appropriate).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def block_nnz_ref(x: jax.Array, block_r: int, block_c: int) -> jax.Array:
    """Per-block nnz via reshape/transpose — oracle for occupancy.block_nnz."""
    r, c = x.shape
    rb, cb = r // block_r, c // block_c
    blocks = x.reshape(rb, block_r, cb, block_c)
    nz = (blocks != 0).astype(jnp.float32)
    return nz.sum(axis=(1, 3))


def row_nnz_ref(x: jax.Array) -> jax.Array:
    """Per-row nnz, shape (R, 1) — oracle for occupancy.row_nnz."""
    return (x != 0).astype(jnp.float32).sum(axis=1, keepdims=True)


def nm_violations_ref(x: jax.Array, n: int, m: int) -> jax.Array:
    """Total N:M group violations — oracle for nm_check.nm_violations."""
    r, c = x.shape
    groups = x.reshape(r, c // m, m)
    nnz = (groups != 0).astype(jnp.float32).sum(axis=2)
    return jnp.maximum(nnz - float(n), 0.0).sum()


def sparsity_stats_ref(x: jax.Array, block_r: int, block_c: int):
    """Oracle for model.sparsity_stats: (block counts, row nnz, col nnz, total)."""
    counts = block_nnz_ref(x, block_r, block_c)
    rows = row_nnz_ref(x)[:, 0]
    cols = (x != 0).astype(jnp.float32).sum(axis=0)
    return counts, rows, cols, counts.sum()


# --- format-cost oracle (mirrors model.format_cost_batch) -----------------

KIND_NONE, KIND_B, KIND_CP, KIND_RLE, KIND_UOP = 0, 1, 2, 3, 4


def format_cost_ref(kinds, fanouts, widths, nonempty, data_bits: float):
    """Expected total bits for a batch of format candidates — numpy oracle.

    Args:
      kinds:    (B, L) int32  — primitive kind per level (KIND_*).
      fanouts:  (B, L) f32    — children per node at each level (>=1; 1 for
                                padding levels, which must carry KIND_NONE).
      widths:   (B, L) f32    — metadata word width per level (the caller
                 derives CP/RLE/UOP widths from level geometry).
      nonempty: (B, L+1) f32  — expected non-empty node count per boundary;
                 nonempty[:, 0] == 1 (root), nonempty[:, i+1] = non-empty
                 nodes *below* level i.  For padding levels the count just
                 repeats.
      data_bits: payload bits per non-zero element.

    Returns:
      (B,) f32 total expected bits: metadata at every level + payload
      (= nonempty[:, L] * data_bits, i.e. leaf-level non-empty elements).
    """
    import numpy as np

    kinds = np.asarray(kinds)
    fanouts = np.asarray(fanouts, dtype=np.float64)
    widths = np.asarray(widths, dtype=np.float64)
    nonempty = np.asarray(nonempty, dtype=np.float64)
    b, l = kinds.shape
    total = np.zeros(b, dtype=np.float64)
    for i in range(l):
        parents = nonempty[:, i]
        children = nonempty[:, i + 1]
        f = fanouts[:, i]
        cb = widths[:, i]
        bits_b = parents * f
        bits_cp = children * cb
        bits_rle = (children + parents) * cb
        # UOP: one offset per child slot + 1 terminator per parent.
        bits_uop = (parents * (f + 1.0)) * cb
        k = kinds[:, i]
        lvl = np.where(k == KIND_B, bits_b, 0.0)
        lvl = np.where(k == KIND_CP, bits_cp, lvl)
        lvl = np.where(k == KIND_RLE, bits_rle, lvl)
        lvl = np.where(k == KIND_UOP, bits_uop, lvl)
        total += lvl
    payload = nonempty[:, l] * data_bits
    return (total + payload).astype(np.float32)
