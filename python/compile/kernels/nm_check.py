"""L1 Pallas kernel: N:M structured-sparsity conformance check.

SnipSnap's workload zoo includes N:M-pruned tensors (e.g. the paper's 2:4
case in Fig. 6).  The synthetic-tensor sampler must produce tensors that
actually satisfy the N:M constraint; this kernel verifies conformance at
scale: for every group of ``m`` consecutive elements along the last axis it
counts non-zeros and accumulates ``max(0, nnz_group - n)`` violations.

A conforming tensor yields exactly 0.  Runs under ``interpret=True`` (CPU
PJRT); oracle in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nm_violation_kernel(x_ref, o_ref, *, n: int, m: int):
    tile = x_ref[...]  # (block_r, C)
    br, c = tile.shape
    groups = tile.reshape(br, c // m, m)
    nnz = jnp.sum((groups != 0).astype(jnp.float32), axis=2)
    viol = jnp.maximum(nnz - float(n), 0.0)
    o_ref[0, 0] = jnp.sum(viol)


@functools.partial(jax.jit, static_argnames=("n", "m", "block_r"))
def nm_violations(x: jax.Array, n: int, m: int, block_r: int) -> jax.Array:
    """Total N:M violations, reduced per row-stripe then summed.

    Args:
      x: ``(R, C)`` array with ``C % m == 0`` and ``R % block_r == 0``.
      n, m: at most ``n`` non-zeros allowed per group of ``m``.
      block_r: row-stripe height per grid step.

    Returns:
      scalar float32 — 0.0 iff ``x`` is N:M conforming.
    """
    r, c = x.shape
    if c % m:
        raise ValueError(f"cols {c} not divisible by group {m}")
    if r % block_r:
        raise ValueError(f"rows {r} not divisible by stripe {block_r}")
    grid = (r // block_r,)
    per_stripe = pl.pallas_call(
        functools.partial(_nm_violation_kernel, n=n, m=m),
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r // block_r, 1), jnp.float32),
        interpret=True,
    )(x)
    return jnp.sum(per_stripe)
