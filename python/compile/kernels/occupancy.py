"""L1 Pallas kernel: tiled block-occupancy analysis.

This is the compute hot-spot of SnipSnap's *empirical* Sparsity Analyzer:
given a (possibly huge) sparse matrix, produce the per-block non-zero count
for a lattice of ``(block_r, block_c)`` tiles.  Every hierarchical format
level's expected occupancy is an aggregation of this base lattice, so one
pass over the tensor feeds the whole format-cost evaluation.

TPU mapping (see DESIGN.md §Hardware-Adaptation): each grid step stages one
``block_r x block_c`` tile from HBM into VMEM via ``BlockSpec`` and reduces
it on the VPU to a single count.  There is no MXU work; the kernel is
bandwidth-bound by construction (arithmetic intensity ~1 op/element).  VMEM
footprint per step is ``block_r * block_c * itemsize`` bytes.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute the
Mosaic custom-call a real TPU lowering would emit.  Correctness against the
pure-jnp oracle in ``ref.py`` is enforced by pytest (incl. hypothesis
sweeps over shapes and dtypes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_nnz_kernel(x_ref, o_ref):
    """Reduce one VMEM-resident tile to its non-zero count."""
    tile = x_ref[...]
    # Count in f32: exact for counts < 2^24, far above any tile size we use.
    o_ref[0, 0] = jnp.sum((tile != 0).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_r", "block_c"))
def block_nnz(x: jax.Array, block_r: int, block_c: int) -> jax.Array:
    """Per-block non-zero counts over a 2-D array.

    Args:
      x: ``(R, C)`` array; ``R % block_r == 0`` and ``C % block_c == 0``.
      block_r, block_c: tile shape of the base occupancy lattice.

    Returns:
      ``(R // block_r, C // block_c)`` float32 array of per-tile nnz counts.
    """
    r, c = x.shape
    if r % block_r or c % block_c:
        raise ValueError(
            f"shape {x.shape} not divisible by block ({block_r}, {block_c})"
        )
    grid = (r // block_r, c // block_c)
    return pl.pallas_call(
        _block_nnz_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(grid, jnp.float32),
        interpret=True,
    )(x)


def _row_nnz_kernel(x_ref, o_ref):
    """Per-row non-zero counts of one row-stripe tile."""
    tile = x_ref[...]
    o_ref[...] = jnp.sum((tile != 0).astype(jnp.float32), axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_r",))
def row_nnz(x: jax.Array, block_r: int) -> jax.Array:
    """Per-row nnz counts, tiled over row stripes.

    Returns ``(R, 1)`` float32.  Used for CSR/UOP-style per-fiber occupancy
    (a row is "non-empty" iff its count is > 0; the CP coordinate payload is
    the count itself).
    """
    r, c = x.shape
    if r % block_r:
        raise ValueError(f"rows {r} not divisible by stripe {block_r}")
    grid = (r // block_r,)
    return pl.pallas_call(
        _row_nnz_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.float32),
        interpret=True,
    )(x)
