"""L2 graph correctness: sparsity_stats + format_cost_batch vs oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from tests.test_kernel import sparse_matrix


@pytest.mark.parametrize("r,c,br,bc", [(64, 64, 16, 16), (32, 64, 16, 16)])
@pytest.mark.parametrize("density", [0.0, 0.2, 0.9])
def test_sparsity_stats_matches_ref(r, c, br, bc, density):
    rng = np.random.default_rng(11)
    x = jnp.asarray(sparse_matrix(rng, r, c, density))
    blocks, rows, cols, total = model.sparsity_stats(x, br, bc)
    wb, wr, wc, wt = ref.sparsity_stats_ref(x, br, bc)
    np.testing.assert_allclose(blocks, wb, rtol=0, atol=0)
    np.testing.assert_allclose(rows[:, 0], wr, rtol=0, atol=0)
    np.testing.assert_allclose(cols, wc, rtol=0, atol=0)
    np.testing.assert_allclose(total, wt, rtol=0, atol=0)


def test_sparsity_stats_internal_consistency():
    rng = np.random.default_rng(5)
    x = jnp.asarray(sparse_matrix(rng, 64, 64, 0.37))
    blocks, rows, cols, total = model.sparsity_stats(x, 16, 16)
    np.testing.assert_allclose(float(blocks.sum()), float(total))
    np.testing.assert_allclose(float(rows.sum()), float(total))
    np.testing.assert_allclose(float(cols.sum()), float(total))


def random_candidates(rng, b, l):
    kinds = rng.integers(0, 5, size=(b, l)).astype(np.int32)
    fanouts = 2.0 ** rng.integers(0, 8, size=(b, l)).astype(np.float32)
    fanouts = np.where(kinds == ref.KIND_NONE, 1.0, fanouts).astype(np.float32)
    widths = np.ceil(np.log2(np.maximum(fanouts, 2.0))).astype(np.float32)
    # Monotone non-decreasing non-empty counts down the tree.
    nonempty = np.ones((b, l + 1), dtype=np.float32)
    for i in range(1, l + 1):
        growth = 1.0 + rng.random((b,)) * (fanouts[:, i - 1] - 1.0)
        nonempty[:, i] = nonempty[:, i - 1] * growth
    return kinds, fanouts, widths, nonempty


def test_format_cost_batch_matches_ref():
    rng = np.random.default_rng(19)
    kinds, fanouts, widths, nonempty = random_candidates(rng, 64, 6)
    (got,) = model.format_cost_batch(
        jnp.asarray(kinds), jnp.asarray(fanouts), jnp.asarray(widths),
        jnp.asarray(nonempty), jnp.float32(16.0)
    )
    want = ref.format_cost_ref(kinds, fanouts, widths, nonempty, 16.0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), data_bits=st.sampled_from([8.0, 16.0, 32.0]))
def test_format_cost_batch_hypothesis(seed, data_bits):
    rng = np.random.default_rng(seed)
    kinds, fanouts, widths, nonempty = random_candidates(rng, 32, 6)
    (got,) = model.format_cost_batch(
        jnp.asarray(kinds), jnp.asarray(fanouts), jnp.asarray(widths),
        jnp.asarray(nonempty), jnp.float32(data_bits)
    )
    want = ref.format_cost_ref(kinds, fanouts, widths, nonempty, data_bits)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


def test_format_cost_custom_widths_respected():
    """Doubling widths doubles CP metadata exactly."""
    b, l = 1, 6
    kinds = np.full((b, l), ref.KIND_CP, dtype=np.int32)
    fanouts = np.full((b, l), 4.0, dtype=np.float32)
    nonempty = np.cumprod(np.full((b, l + 1), 2.0, dtype=np.float32), axis=1) / 2.0
    w1 = np.full((b, l), 2.0, dtype=np.float32)
    w2 = np.full((b, l), 4.0, dtype=np.float32)
    (c1,) = model.format_cost_batch(
        jnp.asarray(kinds), jnp.asarray(fanouts), jnp.asarray(w1),
        jnp.asarray(nonempty), jnp.float32(0.0)
    )
    (c2,) = model.format_cost_batch(
        jnp.asarray(kinds), jnp.asarray(fanouts), jnp.asarray(w2),
        jnp.asarray(nonempty), jnp.float32(0.0)
    )
    np.testing.assert_allclose(np.asarray(c2), 2.0 * np.asarray(c1), rtol=1e-6)


def test_format_cost_payload_only_when_all_none():
    """KIND_NONE everywhere -> cost is exactly the payload term."""
    b, l = 4, 6
    kinds = np.zeros((b, l), dtype=np.int32)
    fanouts = np.ones((b, l), dtype=np.float32)
    widths = np.ones((b, l), dtype=np.float32)
    nonempty = np.ones((b, l + 1), dtype=np.float32) * 100.0
    nonempty[:, 0] = 1.0
    (got,) = model.format_cost_batch(
        jnp.asarray(kinds), jnp.asarray(fanouts), jnp.asarray(widths),
        jnp.asarray(nonempty), jnp.float32(16.0)
    )
    np.testing.assert_allclose(np.asarray(got), 100.0 * 16.0, rtol=1e-6)


def test_nm_conformance_entry_point():
    rng = np.random.default_rng(2)
    from tests.test_kernel import nm_prune

    x = jnp.asarray(nm_prune(rng, 1024, 1024, 2, 4))
    (v,) = model.nm_conformance(x, 2, 4, 16)
    assert float(v) == 0.0
