"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every Pallas kernel must match its pure-jnp oracle bit-exactly (counts are
small integers in f32).  Hypothesis sweeps shapes, densities and dtypes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import nm_check, occupancy, ref


def sparse_matrix(rng, r, c, density, dtype=np.float32):
    mask = rng.random((r, c)) < density
    vals = rng.standard_normal((r, c))
    # Make sure sampled non-zeros are never exactly 0.0.
    vals = np.where(vals == 0.0, 1.0, vals)
    return (mask * vals).astype(dtype)


@pytest.mark.parametrize("r,c,br,bc", [(32, 32, 16, 16), (64, 32, 16, 16), (48, 96, 16, 16), (64, 64, 32, 32)])
@pytest.mark.parametrize("density", [0.0, 0.05, 0.5, 1.0])
def test_block_nnz_matches_ref(r, c, br, bc, density):
    rng = np.random.default_rng(42)
    x = jnp.asarray(sparse_matrix(rng, r, c, density))
    got = occupancy.block_nnz(x, br, bc)
    want = ref.block_nnz_ref(x, br, bc)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
    # Sanity: total equals global nnz.
    np.testing.assert_allclose(got.sum(), (x != 0).sum().astype(jnp.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_block_nnz_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = (rng.random((32, 32)) < 0.3).astype(np.float32)
    x = jnp.asarray(x).astype(dtype)
    got = occupancy.block_nnz(x, 16, 16)
    want = ref.block_nnz_ref(x, 16, 16)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_block_nnz_rejects_misaligned():
    x = jnp.zeros((30, 32))
    with pytest.raises(ValueError):
        occupancy.block_nnz(x, 16, 16)


@pytest.mark.parametrize("r,c,br", [(32, 16, 16), (64, 8, 16), (32, 128, 32)])
def test_row_nnz_matches_ref(r, c, br):
    rng = np.random.default_rng(7)
    x = jnp.asarray(sparse_matrix(rng, r, c, 0.3))
    got = occupancy.row_nnz(x, br)
    want = ref.row_nnz_ref(x)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(
    rb=st.integers(1, 4),
    cb=st.integers(1, 4),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_nnz_hypothesis(rb, cb, density, seed):
    """Shape/density sweep: grid dims (rb, cb) of 16x16 blocks."""
    r, c = rb * 16, cb * 16
    rng = np.random.default_rng(seed)
    x = jnp.asarray(sparse_matrix(rng, r, c, density))
    got = occupancy.block_nnz(x, 16, 16)
    want = ref.block_nnz_ref(x, 16, 16)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(1, 3),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
    dtype_idx=st.integers(0, 1),
)
def test_row_nnz_hypothesis(rows, density, seed, dtype_idx):
    dtype = [jnp.float32, jnp.bfloat16][dtype_idx]
    r, c = rows * 16, 48
    rng = np.random.default_rng(seed)
    x = jnp.asarray(sparse_matrix(rng, r, c, density)).astype(dtype)
    got = occupancy.row_nnz(x, 16)
    want = ref.row_nnz_ref(x)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


# --- N:M check kernel ------------------------------------------------------


def nm_prune(rng, r, c, n, m):
    """Random dense matrix pruned to exact N:M along the last axis."""
    x = rng.standard_normal((r, c)).astype(np.float32)
    x = np.where(x == 0.0, 1.0, x)
    groups = x.reshape(r, c // m, m)
    order = np.argsort(-np.abs(groups), axis=2)
    keep = np.zeros_like(groups, dtype=bool)
    np.put_along_axis(keep, order[:, :, :n], True, axis=2)
    return (groups * keep).reshape(r, c)


@pytest.mark.parametrize("n,m", [(2, 4), (1, 4), (4, 8)])
def test_nm_conforming_tensor_has_zero_violations(n, m):
    rng = np.random.default_rng(3)
    x = jnp.asarray(nm_prune(rng, 32, 64, n, m))
    got = nm_check.nm_violations(x, n, m, 16)
    want = ref.nm_violations_ref(x, n, m)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
    assert float(got) == 0.0


def test_nm_dense_tensor_counts_all_violations():
    x = jnp.ones((16, 16))
    got = nm_check.nm_violations(x, 2, 4, 16)
    # Every group of 4 has 4 nonzeros -> 2 violations; 16*4 groups.
    assert float(got) == 2.0 * 16 * 4


@settings(max_examples=15, deadline=None)
@given(density=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
def test_nm_violations_hypothesis(density, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((32, 32)) < density
    x = jnp.asarray(mask.astype(np.float32))
    got = nm_check.nm_violations(x, 2, 4, 16)
    want = ref.nm_violations_ref(x, 2, 4)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
