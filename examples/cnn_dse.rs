//! CNN design-space exploration and the DiMO-Sparse workflow comparison
//! (paper §IV-D): run SnipSnap and the DiMO-like iterative baseline on
//! AlexNet, VGG-16 and ResNet-18, reporting solution quality and
//! exploration speedup.
//!
//! Run with: `cargo run --release --example cnn_dse`

use snipsnap::arch::presets;
use snipsnap::baselines::dimo_like::{dimo_workload, DimoConfig};
use snipsnap::cost::Metric;
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::search::{cosearch_workload, FormatMode, SearchConfig};
use snipsnap::util::table::{fmt_f, fmt_x, Table};
use snipsnap::workload::cnn;

fn main() {
    let arch = presets::arch1(); // Eyeriss-style, the CNN-era baseline
    let mapper = MapperConfig {
            max_candidates: 2_000,
            min_spatial_utilization: 0.0,
            ..Default::default()
        };
    let snip_cfg = SearchConfig {
        metric: Metric::Energy,
        mode: FormatMode::Fixed, // DiMO comparison uses preset formats
        mapper: mapper.clone(),
        ..Default::default()
    };
    let dimo_cfg = DimoConfig::default();

    let mut t = Table::new(vec![
        "network",
        "SnipSnap energy (pJ)",
        "DiMO energy (pJ)",
        "SnipSnap time (s)",
        "DiMO time (s)",
        "speedup",
    ])
    .with_title(format!("CNN DSE on {} (fixed {} format)", arch.name, "RLE"));

    let mut speedups = Vec::new();
    for w in cnn::all_cnns() {
        let snip = cosearch_workload(&arch, &w, &snip_cfg);
        let dimo = dimo_workload(&arch, &w, &dimo_cfg, Metric::Energy);
        let speedup = dimo.elapsed.as_secs_f64() / snip.elapsed.as_secs_f64();
        speedups.push(speedup);
        t.add_row(vec![
            w.name.clone(),
            fmt_f(snip.total_energy_pj()),
            fmt_f(dimo.total_energy_pj()),
            format!("{:.2}", snip.elapsed.as_secs_f64()),
            format!("{:.2}", dimo.elapsed.as_secs_f64()),
            fmt_x(speedup),
        ]);
        // SnipSnap must not lose on quality while being faster.
        assert!(
            snip.total_energy_pj() <= dimo.total_energy_pj() * 1.20,
            "{}: quality regression",
            w.name
        );
    }
    println!("{}", t.render());
    println!(
        "geomean speedup over DiMO-like baseline: {}",
        fmt_x(snipsnap::util::stats::geomean(&speedups))
    );
    println!("cnn_dse OK");
}
