//! End-to-end driver: the full three-layer pipeline on a real small
//! workload (OPT-125M, 256-token prefill + 32-token decode).
//!
//! Exercises every layer of the stack in one run:
//!  1. L3 Rust co-search: adaptive compression engine + progressive
//!     co-search across all four Table II accelerators;
//!  2. L1/L2 XLA artifacts: sample concrete tensors at the workload's
//!     sparsity, run the AOT-compiled Pallas occupancy analyzer through
//!     PJRT, and cross-validate the analytical format costs against the
//!     empirical (measured-tensor) costs;
//!  3. The batched XLA format-cost scorer vs the Rust costing core.
//!
//! Reports the paper's headline metric — memory-energy saving of the
//! searched format vs the best standard baseline — and is recorded in
//! EXPERIMENTS.md.
//!
//! Run with: `python python/compile/aot.py && cargo run --release --features pjrt --example e2e_codesign`
//! (the `pjrt` feature needs the `xla` bindings crate added to Cargo.toml
//! first — see README.md "snipsnap xla"; without it stages 2-3 error out)

use snipsnap::arch::presets;
use snipsnap::engine::ScoredFormat;
use snipsnap::format::named;
use snipsnap::runtime::stats::{analyze_mask, empirical_cost};
use snipsnap::runtime::{InputBuf, Runtime};
use snipsnap::search::{cosearch_workload, evaluate_with_formats, FormatMode, SearchConfig};
use snipsnap::sparsity::analyzer::{analytical_cost, operands_from_ne, expected_ne};
use snipsnap::sparsity::sample::sample_mask;
use snipsnap::sparsity::SparsityPattern;
use snipsnap::util::table::{fmt_f, fmt_pct, Table};
use snipsnap::workload::llm;

fn main() -> anyhow::Result<()> {
    let workload = llm::opt_125m(llm::Phase::new(256, 32));
    println!("== SnipSnap end-to-end co-design: {} ==", workload.name);
    println!("{} ops, {:.3e} total MACs\n", workload.op_count(), workload.total_macs());

    // ---- Stage 1: co-search across all Table II accelerators ----------
    let mut t = Table::new(vec![
        "arch", "mode", "mem energy (pJ)", "cycles", "evals", "time (s)",
    ])
    .with_title("Progressive co-search (L3)");
    let mut headline = Vec::new();
    for arch in presets::all_table2() {
        let fixed = cosearch_workload(
            &arch,
            &workload,
            &SearchConfig { mode: FormatMode::Fixed, ..Default::default() },
        );
        let search = cosearch_workload(
            &arch,
            &workload,
            &SearchConfig { mode: FormatMode::Search, ..Default::default() },
        );
        for (mode, r) in [("fixed", &fixed), ("search", &search)] {
            t.add_row(vec![
                arch.name.clone(),
                mode.to_string(),
                fmt_f(r.memory_energy_pj()),
                fmt_f(r.total_cycles()),
                r.evaluations.to_string(),
                format!("{:.2}", r.elapsed.as_secs_f64()),
            ]);
        }
        headline.push(1.0 - search.memory_energy_pj() / fixed.memory_energy_pj());
    }
    println!("{}", t.render());

    // Headline: saving vs the best standard baseline on Arch 3.
    let arch3 = presets::arch3();
    let cfg = SearchConfig { mode: FormatMode::Search, ..Default::default() };
    let searched = cosearch_workload(&arch3, &workload, &cfg);
    let mut best_baseline = f64::INFINITY;
    let mut best_name = "";
    for (name, _) in named::baselines(4, 4) {
        let r = evaluate_with_formats(
            &arch3,
            &workload,
            |op| {
                let mk = |rows, cols| match name {
                    "Bitmap" => named::bitmap(rows, cols),
                    "RLE" => named::rle(rows, cols),
                    "CSR" => named::csr(rows, cols),
                    _ => named::coo(rows, cols),
                };
                (mk(op.dims.m, op.dims.n), mk(op.dims.n, op.dims.k))
            },
            &cfg,
        );
        if r.memory_energy_pj() < best_baseline {
            best_baseline = r.memory_energy_pj();
            best_name = name;
        }
    }
    let saving = 1.0 - searched.memory_energy_pj() / best_baseline;
    let avg_vs_fixed = headline.iter().sum::<f64>() / headline.len() as f64;
    println!(
        "HEADLINE: memory-energy saving vs best standard baseline ({best_name}) on Arch 3: {}",
        fmt_pct(saving)
    );
    println!(
        "HEADLINE: mean saving vs each arch's native fixed format (Arch 1-4): {}\n",
        fmt_pct(avg_vs_fixed)
    );

    // ---- Stage 2: empirical cross-validation through PJRT -------------
    println!("Empirical Sparsity Analyzer (L1 Pallas kernel via PJRT):");
    let mut rt = Runtime::load_default()?;
    let mut v = Table::new(vec![
        "tensor", "format", "analytical bits", "empirical bits", "gap",
    ]);
    // Sample tensors at the workload's characteristic densities.
    let cases = [
        ("act d=0.70", SparsityPattern::Unstructured { density: 0.70 }),
        ("act d=0.15", SparsityPattern::Unstructured { density: 0.15 }),
        ("wgt d=0.60", SparsityPattern::Unstructured { density: 0.60 }),
        ("wgt 2:4", SparsityPattern::Nm { n: 2, m: 4 }),
    ];
    let mut worst_gap = 0.0f64;
    for (label, pattern) in cases {
        let mask = sample_mask(&pattern, 1024, 1024, 0xE2E);
        let stats = analyze_mask(&mut rt, &mask)?;
        for f in [named::bitmap(1024, 1024), named::csr(1024, 1024), named::csb(1024, 1024, 16, 16)] {
            let ana = analytical_cost(&f, &pattern, 16).total_bits();
            let emp = empirical_cost(&f, &stats, 16).total_bits();
            let gap = (ana - emp).abs() / emp;
            worst_gap = worst_gap.max(gap);
            v.add_row(vec![
                label.to_string(),
                f.to_string(),
                fmt_f(ana),
                fmt_f(emp),
                fmt_pct(gap),
            ]);
        }
    }
    println!("{}", v.render());
    assert!(worst_gap < 0.05, "analytical vs empirical gap {worst_gap}");

    // ---- Stage 3: batched XLA format-cost scorer vs Rust core ---------
    println!("Batched format-cost scorer (L2 XLA graph vs Rust core):");
    let meta = rt
        .manifest
        .get("format_cost_b256_l6")
        .expect("format_cost artifact")
        .clone();
    let (b, l) = (256usize, 6usize);
    let mut kinds = vec![0i32; b * l];
    let mut fanouts = vec![1.0f32; b * l];
    let mut widths = vec![1.0f32; b * l];
    let mut nonempty = vec![1.0f32; b * (l + 1)];
    let mut expected = vec![0.0f64; b];
    let pattern = SparsityPattern::Unstructured { density: 0.3 };
    let formats: Vec<_> = (0..4)
        .map(|i| match i {
            0 => named::bitmap(1024, 1024),
            1 => named::csr(1024, 1024),
            2 => named::coo(1024, 1024),
            _ => named::csb(1024, 1024, 16, 16),
        })
        .collect();
    for (row, f) in formats.iter().enumerate() {
        let ne = expected_ne(f, &pattern);
        let ops = operands_from_ne(f, &ne);
        for (i, lv) in f.levels.iter().enumerate() {
            kinds[row * l + i] = lv.prim.kind_id();
            fanouts[row * l + i] = ops.fanouts[i] as f32;
            widths[row * l + i] = ops.widths[i] as f32;
            nonempty[row * (l + 1) + i] = ops.parents[i] as f32;
            nonempty[row * (l + 1) + i + 1] = ops.children[i] as f32;
        }
        // Pad shallower formats: the payload term reads nonempty[:, L].
        for i in f.levels.len()..l {
            nonempty[row * (l + 1) + i + 1] = ops.leaf_count as f32;
        }
        expected[row] = ScoredFormat::score(f.clone(), &pattern, &Default::default())
            .cost
            .total_bits();
    }
    let _ = meta;
    let outs = rt.exec(
        "format_cost_b256_l6",
        &[
            InputBuf::I32(&kinds),
            InputBuf::F32(&fanouts),
            InputBuf::F32(&widths),
            InputBuf::F32(&nonempty),
            InputBuf::F32(&[16.0f32]),
        ],
    )?;
    let mut s = Table::new(vec!["format", "rust bits", "xla bits", "gap"]);
    for (row, f) in formats.iter().enumerate() {
        // f32 XLA arithmetic vs f64 Rust core: allow rounding headroom.
        let gap = (expected[row] - outs[0][row] as f64).abs() / expected[row];
        assert!(gap < 5e-3, "{f}: rust {} vs xla {}", expected[row], outs[0][row]);
        s.add_row(vec![
            f.to_string(),
            fmt_f(expected[row]),
            fmt_f(outs[0][row] as f64),
            fmt_pct(gap),
        ]);
    }
    println!("{}", s.render());

    println!("e2e co-design complete: all three layers composed.");
    Ok(())
}
