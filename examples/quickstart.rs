//! Quickstart: co-optimize compression format + dataflow for one sparse
//! LLM operator on the paper's primary accelerator (Arch 3, DSTC-based).
//!
//! Run with: `cargo run --release --example quickstart`

use snipsnap::arch::presets;
use snipsnap::dataflow::ProblemDims;
use snipsnap::search::{cosearch_workload, FormatMode, SearchConfig};
use snipsnap::sparsity::SparsitySpec;
use snipsnap::util::table::{fmt_f, fmt_pct, Table};
use snipsnap::workload::{MatMulOp, Workload};

fn main() {
    // The FC2 projection of a sparse OPT-6.7B block: 2048-token prefill,
    // 95%-sparse activations (post-ReLU), 50%-sparse weights.
    let workload = Workload {
        name: "quickstart".to_string(),
        ops: vec![MatMulOp {
            name: "fc2".to_string(),
            dims: ProblemDims::new(2048, 16384, 4096),
            spec: SparsitySpec::unstructured(0.05, 0.50),
            count: 1,
        }],
    };
    let arch = presets::arch3();

    println!("== SnipSnap quickstart ==");
    println!("arch:     {}", arch.name);
    println!("operator: {} (M={}, N={}, K={})", workload.ops[0].name, 2048, 16384, 4096);

    // Fixed mode: the accelerator's native Bitmap format.
    let fixed = cosearch_workload(
        &arch,
        &workload,
        &SearchConfig { mode: FormatMode::Fixed, ..Default::default() },
    );
    // Search mode: the adaptive compression engine explores the format space.
    let search = cosearch_workload(
        &arch,
        &workload,
        &SearchConfig { mode: FormatMode::Search, ..Default::default() },
    );

    let mut t = Table::new(vec!["mode", "I format", "W format", "memory energy (pJ)", "cycles"]);
    for (name, r) in [("Fixed (Bitmap)", &fixed), ("SnipSnap search", &search)] {
        let d = &r.designs[0];
        t.add_row(vec![
            name.to_string(),
            d.input_format.to_string(),
            d.weight_format.to_string(),
            fmt_f(r.memory_energy_pj()),
            fmt_f(r.total_cycles()),
        ]);
    }
    println!("{}", t.render());

    let saving = 1.0 - search.memory_energy_pj() / fixed.memory_energy_pj();
    println!(
        "memory-energy saving from format search: {} ({} evaluations, {:.2}s)",
        fmt_pct(saving),
        search.evaluations,
        search.elapsed.as_secs_f64()
    );
    assert!(
        search.memory_energy_pj() <= fixed.memory_energy_pj() * 1.0001,
        "format search must not lose to the fixed format"
    );
    println!("quickstart OK");
}
