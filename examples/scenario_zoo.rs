//! Scenario-zoo walkthrough: build one workload per scenario family
//! (dense-shaped MHA, GQA, MoE, batched decode, N:M weights) and run a
//! quick fixed-format co-search on each, printing what makes the family
//! distinctive (op structure, sparsity patterns) and what it costs.
//!
//! Run with: `cargo run --release --example scenario_zoo`

use snipsnap::arch::presets;
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::search::{cosearch_workload, FormatMode, SearchConfig};
use snipsnap::util::table::{fmt_f, Table};
use snipsnap::workload::scenario_zoo;

fn main() {
    let arch = presets::arch3();
    let cfg = SearchConfig {
        mode: FormatMode::Fixed,
        mapper: MapperConfig { max_candidates: 300, ..Default::default() },
        ..Default::default()
    };

    let mut t = Table::new(vec!["scenario", "ops", "GMACs", "energy (pJ)", "cycles"]);
    for w in scenario_zoo() {
        // What makes the family distinctive, visible in the op list:
        let marker = w
            .ops
            .iter()
            .map(|o| o.name.as_str())
            .find(|n| n.contains("kv_proj") || n.contains("expert_"))
            .unwrap_or("dense transformer block");
        println!("{}: {} ops (e.g. {marker})", w.name, w.op_count());
        let r = cosearch_workload(&arch, &w, &cfg);
        t.add_row(vec![
            w.name.clone(),
            w.op_count().to_string(),
            format!("{:.2}", w.total_macs() / 1e9),
            fmt_f(r.total_energy_pj()),
            fmt_f(r.total_cycles()),
        ]);
    }
    println!("\n{}", t.render());
    println!(
        "Every scenario is also a CLI preset — try `snipsnap list`, then e.g.\n\
         `snipsnap search --arch arch3 --workload gqa-tiny --nm 2:4 --batch 2`."
    );
}
