//! §Perf probe: micro-timings of the L3 hot paths (cost evaluation,
//! access counting, mapping enumeration, engine format search) used to
//! drive and record the optimization pass in EXPERIMENTS.md §Perf.
//!
//! Appends a record to `results/perf_probe.jsonl` under the unified
//! bench-record schema (`bench`, `git_rev`, `ts_unix`, `wall_time_s`,
//! per-row payload) — history accumulates across runs and `snipsnap
//! report` diffs the latest run against the previous one.

use snipsnap::arch::presets;
use snipsnap::cost::{
    evaluate, CompressionRatios, ContentionParams, CostBackend, CostModel, EvalInputs, Metric,
};
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::dataflow::{access_counts, LoopDim, Mapping, ProblemDims, Spatial, TileLevel};
use snipsnap::engine::{search_formats, EngineConfig};
use snipsnap::search::{cosearch_workload, FormatMode, SearchConfig};
use snipsnap::sparsity::{reduction::ReductionStrategy, SparsityPattern, SparsitySpec};
use snipsnap::util::bench::{time_median, write_record};
use snipsnap::util::json::Json;
use snipsnap::workload::{llm, MatMulOp, Workload};
use std::time::Instant;

fn main() {
    let t_main = Instant::now();
    let arch = presets::arch3();
    let p = ProblemDims::new(2048, 4096, 4096);
    let mapping = Mapping {
        levels: vec![
            TileLevel { factors: [32, 64, 16], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
            TileLevel { factors: [16, 16, 4], order: [LoopDim::N, LoopDim::K, LoopDim::M] },
            TileLevel { factors: [1, 4, 2], order: [LoopDim::K, LoopDim::M, LoopDim::N] },
        ],
        spatial: Spatial {
            dim_rows: LoopDim::M,
            unroll_rows: 4,
            dim_cols: LoopDim::K,
            unroll_cols: 32,
        },
    };
    mapping.validate(&p).unwrap();
    let spec = SparsitySpec::unstructured(0.4, 0.4);

    // 1) access_counts — the innermost analytical kernel.
    let n = 200_000;
    let t_ac = time_median(5, || {
        let mut acc = 0.0;
        for _ in 0..n {
            acc += access_counts(&mapping, &p).fills[0][0];
        }
        acc
    }) / n as f64;
    println!("access_counts:        {:>8.1} ns/call", t_ac * 1e9);

    // 2) evaluate — full cost model.
    let t_ev = time_median(5, || {
        let mut acc = 0.0;
        for _ in 0..n {
            acc += evaluate(
                &arch, &p, &mapping, &spec,
                &ReductionStrategy::NONE, &CompressionRatios::DENSE,
            )
            .total_energy_pj();
        }
        acc
    }) / n as f64;
    println!("evaluate:             {:>8.1} ns/call", t_ev * 1e9);

    // 2b) cost backends head to head on the same mapping: the flat
    //     analytical bits→cycles transform vs the contention roofline
    //     (burst roundup, bandwidth derate, decompression throughput —
    //     docs/COST.md).  Both consume the same AccessCounts, so the
    //     delta is the backend alone; contention must dominate.
    let ac = access_counts(&mapping, &p);
    let ratios = CompressionRatios { input: 0.5, weight: 0.6 };
    let reduction = ReductionStrategy::NONE;
    let inp = EvalInputs {
        arch: &arch,
        p: &p,
        mapping: &mapping,
        spec: &spec,
        reduction: &reduction,
        ratios: &ratios,
    };
    let contention = CostModel::Contention(ContentionParams::default());
    let t_ra = time_median(5, || {
        let mut acc = 0.0;
        for _ in 0..n {
            acc += CostModel::Analytical.report(&inp, &ac).latency_cycles();
        }
        acc
    }) / n as f64;
    let t_rc = time_median(5, || {
        let mut acc = 0.0;
        for _ in 0..n {
            acc += contention.report(&inp, &ac).latency_cycles();
        }
        acc
    }) / n as f64;
    let cyc_a = CostModel::Analytical.report(&inp, &ac).latency_cycles();
    let cyc_c = contention.report(&inp, &ac).latency_cycles();
    assert!(cyc_c >= cyc_a, "contention latency {cyc_c} < analytical {cyc_a}");
    println!("report (analytical):  {:>8.1} ns/call", t_ra * 1e9);
    println!(
        "report (contention):  {:>8.1} ns/call  ({:.3}x latency of analytical)",
        t_rc * 1e9,
        cyc_c / cyc_a
    );

    // 3) engine format search on a 4096x4096 tensor.
    let cfg = EngineConfig::default();
    let pattern = SparsityPattern::Unstructured { density: 0.3 };
    let t_fs = time_median(3, || {
        search_formats(4096, 4096, &pattern, None, &cfg).0.len()
    });
    println!("search_formats 4096²: {:>8.2} ms", t_fs * 1e3);

    // 4) one full co-search op (Fixed / Search).
    let w = Workload {
        name: "probe".into(),
        ops: vec![MatMulOp {
            name: "op".into(),
            dims: ProblemDims::new(2048, 4096, 4096),
            spec,
            count: 1,
        }],
    };
    let mk = |mode| SearchConfig {
        metric: Metric::Energy,
        mode,
        mapper: MapperConfig { max_candidates: 2_000, ..Default::default() },
        ..Default::default()
    };
    let t_fixed = time_median(3, || {
        cosearch_workload(&arch, &w, &mk(FormatMode::Fixed)).evaluations
    });
    let t_search = time_median(3, || {
        cosearch_workload(&arch, &w, &mk(FormatMode::Search)).evaluations
    });
    println!("cosearch op (fixed):  {:>8.2} ms", t_fixed * 1e3);
    println!("cosearch op (search): {:>8.2} ms", t_search * 1e3);

    // 4b) the same op co-searched for latency under each cost backend.
    //     Contention latency dominates analytical exactly per mapping
    //     (asserted above); the whole-search comparison also crosses
    //     the backend-metric-driven tile refinement, hence the slack
    //     (rust/tests/cost_backends.rs documents the distinction).
    let mk_cost = |cost| SearchConfig {
        metric: Metric::Latency,
        mode: FormatMode::Fixed,
        mapper: MapperConfig { max_candidates: 2_000, ..Default::default() },
        cost,
        ..Default::default()
    };
    let lat_a = cosearch_workload(&arch, &w, &mk_cost(CostModel::Analytical));
    let lat_c = cosearch_workload(&arch, &w, &mk_cost(contention));
    assert!(
        lat_c.total_cycles() >= lat_a.total_cycles() * 0.98,
        "contention co-search undercut the analytical optimum: {} < {}",
        lat_c.total_cycles(),
        lat_a.total_cycles(),
    );
    println!(
        "cosearch latency:     {:>8.3e} cyc analytical | {:>8.3e} cyc contention",
        lat_a.total_cycles(),
        lat_c.total_cycles(),
    );

    // 5) parallel co-search + memoized evaluation: the Fig. 10 LLaMA2-7B
    //    activation-sparsity workload, serial vs 4 worker threads.  The
    //    designs are bit-identical by the docs/SEARCH.md contract
    //    (evaluation *counts* are shard-dependent when pruning is on, so
    //    only the scores are asserted here; counts are covered by the
    //    prune-off section below).
    let w10 = llm::activation_sparse_variant(llm::llama2_7b(llm::Phase::prefill_only(2048)));
    let cfg10 = |threads: usize, prune: bool| SearchConfig {
        metric: Metric::MemoryEnergy,
        mode: FormatMode::Search,
        mapper: MapperConfig { max_candidates: 1_200, ..Default::default() },
        threads,
        prune,
        ..Default::default()
    };
    let mut serial = None;
    let t_serial =
        time_median(3, || serial = Some(cosearch_workload(&arch, &w10, &cfg10(1, true))));
    let mut par = None;
    let t_par = time_median(3, || par = Some(cosearch_workload(&arch, &w10, &cfg10(4, true))));
    let (serial, par) = (serial.unwrap(), par.unwrap());
    assert_eq!(
        serial.total_energy_pj().to_bits(),
        par.total_energy_pj().to_bits(),
        "parallel run is not bit-identical to serial"
    );
    assert!(par.cache.hits > 0, "access-counts cache never hit");
    let speedup = t_serial / t_par;
    println!("cosearch fig10 1 thr: {:>8.2} s", t_serial);
    println!("cosearch fig10 4 thr: {:>8.2} s  ({speedup:.2}x speedup)", t_par);
    println!(
        "access-counts cache:  {} hits / {} misses ({:.1}% hit rate)",
        par.cache.hits,
        par.cache.misses,
        100.0 * par.cache.hit_rate()
    );

    // 6) enumeration throughput + branch-and-bound pruning on the same
    //    fig10 workload at 1 thread: legal protos per second through the
    //    arena-backed search, prune rate, and the cache/evaluation
    //    deltas of pruning.  Prune off vs on must agree bit for bit on
    //    the result (also asserted by rust/tests/prune_correctness.rs).
    let mut off = None;
    let t_off = time_median(3, || off = Some(cosearch_workload(&arch, &w10, &cfg10(1, false))));
    let off = off.unwrap();
    let on = serial; // prune-on serial run from section 5
    assert_eq!(
        off.total_energy_pj().to_bits(),
        on.total_energy_pj().to_bits(),
        "pruning changed the search result"
    );
    let t_on = t_serial;
    let protos_per_s = on.protos as f64 / t_on;
    let prune_rate = on.prune_rate();
    let prune_speedup = t_off / t_on;
    println!("enumeration:          {:>8.0} protos/s (1 thr, prune on)", protos_per_s);
    println!(
        "pruning:              {} / {} protos pruned ({:.1}%), {:.2}x vs prune-off ({:.2}s)",
        on.pruned,
        on.protos,
        100.0 * prune_rate,
        prune_speedup,
        t_off,
    );
    println!(
        "evaluations:          {} (prune on) vs {} (off) | cache hit% {:.1} vs {:.1}",
        on.evaluations,
        off.evaluations,
        100.0 * on.cache.hit_rate(),
        100.0 * off.cache.hit_rate(),
    );

    // 7) frontier mode: one arena pass serving all four metrics vs four
    //    independent scalar searches on the probe op.  Serial, pruned,
    //    index-order visits — the configuration under which the per-
    //    metric prune sets provably match the solo searches', so the
    //    eval-count saving is structural (rust/tests/frontier.rs pins
    //    the winners bit for bit; this section records the perf side).
    let mk_frontier = |metric| SearchConfig {
        metric,
        mode: FormatMode::Fixed,
        mapper: MapperConfig { max_candidates: 2_000, ..Default::default() },
        best_first: false,
        ..Default::default()
    };
    let mut four_evals = 0u64;
    let t_four = time_median(3, || {
        four_evals = 0;
        for &m in &Metric::SCALARS {
            four_evals += cosearch_workload(&arch, &w, &mk_frontier(m)).evaluations;
        }
    });
    let mut one = None;
    let t_one =
        time_median(3, || one = Some(cosearch_workload(&arch, &w, &mk_frontier(Metric::Frontier))));
    let one = one.unwrap();
    assert!(
        one.evaluations < four_evals,
        "frontier pass spent {} evaluations vs {} for four scalar passes",
        one.evaluations,
        four_evals
    );
    println!(
        "frontier one pass:    {:>8.2} ms, {} evals | four passes {:.2} ms, {} evals | {} points",
        t_one * 1e3,
        one.evaluations,
        t_four * 1e3,
        four_evals,
        one.frontier_size,
    );

    write_record(
        "perf_probe",
        t_main.elapsed().as_secs_f64(),
        Json::obj(vec![
            ("access_counts_ns", Json::num(t_ac * 1e9)),
            ("evaluate_ns", Json::num(t_ev * 1e9)),
            ("report_analytical_ns", Json::num(t_ra * 1e9)),
            ("report_contention_ns", Json::num(t_rc * 1e9)),
            ("latency_ratio_contention", Json::num(cyc_c / cyc_a)),
            ("cosearch_latency_analytical_cycles", Json::num(lat_a.total_cycles())),
            ("cosearch_latency_contention_cycles", Json::num(lat_c.total_cycles())),
            ("search_formats_ms", Json::num(t_fs * 1e3)),
            ("cosearch_fixed_ms", Json::num(t_fixed * 1e3)),
            ("cosearch_search_ms", Json::num(t_search * 1e3)),
            ("fig10_serial_s", Json::num(t_serial)),
            ("fig10_threads4_s", Json::num(t_par)),
            ("fig10_speedup_4t", Json::num(speedup)),
            ("fig10_prune_off_s", Json::num(t_off)),
            ("fig10_prune_speedup_1t", Json::num(prune_speedup)),
            ("protos_per_s", Json::num(protos_per_s)),
            ("protos", Json::num(on.protos as f64)),
            ("pruned", Json::num(on.pruned as f64)),
            ("prune_rate", Json::num(prune_rate)),
            ("evals_prune_on", Json::num(on.evaluations as f64)),
            ("evals_prune_off", Json::num(off.evaluations as f64)),
            ("cache_hits", Json::num(on.cache.hits as f64)),
            ("cache_misses", Json::num(on.cache.misses as f64)),
            ("cache_hit_rate_prune_on", Json::num(on.cache.hit_rate())),
            ("cache_hit_rate_prune_off", Json::num(off.cache.hit_rate())),
            ("frontier_one_pass_evals", Json::num(one.evaluations as f64)),
            ("frontier_four_pass_evals", Json::num(four_evals as f64)),
            ("frontier_one_pass_s", Json::num(t_one)),
            ("frontier_four_pass_s", Json::num(t_four)),
            ("frontier_points", Json::num(one.frontier_size as f64)),
        ]),
    );
}
