//! Multi-model accelerator co-design (paper Fig. 11 scenarios).
//!
//! Case 1: BERT-Base (NLU, 256 input tokens) + OPT-125M (text generation,
//!         256 in / 32 out) sharing one accelerator.
//! Case 2: speculative decoding — OPT-125M drafts, OPT-6.7B verifies.
//!
//! Importance-based scoring selects ONE shared compression format pattern
//! that minimizes the importance-weighted metric; we sweep the importance
//! split to show how the choice shifts toward the prioritized model.
//!
//! Run with: `cargo run --release --example multi_model`

use snipsnap::engine::scoring::{select_shared_pattern, workload_format_bits, WeightedWorkload};
use snipsnap::engine::EngineConfig;
use snipsnap::format::space::SpaceConfig;
use snipsnap::format::{Axis, CompPat, Prim};
use snipsnap::util::table::{fmt_pct, Table};
use snipsnap::workload::llm;

fn baseline_patterns() -> Vec<(&'static str, CompPat)> {
    vec![
        ("Bitmap", CompPat::new(vec![(Prim::None, Axis::Row), (Prim::B, Axis::Col)])),
        ("RLE", CompPat::new(vec![(Prim::None, Axis::Row), (Prim::Rle, Axis::Col)])),
        ("CSR", CompPat::new(vec![(Prim::Uop, Axis::Row), (Prim::Cp, Axis::Col)])),
        ("COO", CompPat::new(vec![(Prim::Cp, Axis::Row), (Prim::Cp, Axis::Col)])),
    ]
}

fn run_case(case: &str, a: &snipsnap::workload::Workload, b: &snipsnap::workload::Workload) {
    let cfg = EngineConfig {
        space: SpaceConfig { max_depth: 3, ..Default::default() },
        top_k: 3,
        ..Default::default()
    };
    println!("== {case}: {} + {} ==", a.name, b.name);
    let mut t = Table::new(vec![
        "importance (A:B)",
        "selected pattern",
        "weighted bits vs best baseline",
    ]);
    for (wa, wb) in [(99.0, 1.0), (75.0, 25.0), (50.0, 50.0), (25.0, 75.0), (1.0, 99.0)] {
        let ws = [
            WeightedWorkload { workload: a, importance: wa },
            WeightedWorkload { workload: b, importance: wb },
        ];
        let sel = select_shared_pattern(&ws, &cfg);
        // Best single baseline under the same weighting.
        let best_baseline = baseline_patterns()
            .iter()
            .map(|(_, pat)| {
                wa * workload_format_bits(a, pat, &cfg) + wb * workload_format_bits(b, pat, &cfg)
            })
            .fold(f64::INFINITY, f64::min);
        let saving = 1.0 - sel.weighted_bits / best_baseline;
        t.add_row(vec![
            format!("{wa:.0}:{wb:.0}"),
            sel.pattern.to_string(),
            format!("-{}", fmt_pct(saving)),
        ]);
    }
    println!("{}", t.render());
}

fn main() {
    // Case 1: NLU + generation.
    let bert = llm::bert_base(256);
    let opt125 = llm::opt_125m(llm::Phase::new(256, 32));
    run_case("Case 1 (BERT-Base + OPT-125M)", &bert, &opt125);

    // Case 2: speculative decoding (draft + verify).
    let opt67 = llm::opt_6_7b(llm::Phase::new(256, 32));
    run_case("Case 2 (speculative decoding: OPT-125M + OPT-6.7B)", &opt125, &opt67);

    println!("multi-model co-design OK");
}
