//! Fig. 10 — single-LLM compression-format optimization.
//!
//! Memory energy and speedup of five sparse LLMs (LLaMA2-7B/13B,
//! OPT-6.7B/13B/30B; 2048-token prefill + 128-token decode) under the
//! four standard baselines and SnipSnap's searched formats, normalized
//! to Bitmap.  Activation (SA) and weight (SW) sparsity are evaluated
//! separately.  Paper: SnipSnap beats the best baseline (Bitmap) by
//! 14.53% energy / 1.18x speed (SA) and 21.95% / 1.30x (SW); larger
//! models benefit more.

use snipsnap::arch::presets;
use snipsnap::cost::Metric;
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::format::named;
use snipsnap::search::{cosearch_workload, evaluate_with_formats, FormatMode, SearchConfig};
use snipsnap::util::bench::{banner, write_record};
use snipsnap::util::json::Json;
use snipsnap::util::stats::mean;
use snipsnap::util::table::{fmt_pct, fmt_x, Table};
use snipsnap::workload::llm::{self, Phase};
use snipsnap::workload::Workload;
use std::time::Instant;

const FORMATS: [&str; 4] = ["Bitmap", "RLE", "CSR", "COO"];

fn cfg(mode: FormatMode) -> SearchConfig {
    SearchConfig {
        metric: Metric::MemoryEnergy,
        mode,
        mapper: MapperConfig { max_candidates: 1_200, ..Default::default() },
        ..Default::default()
    }
}

fn run_variant(
    label: &str,
    workloads: &[Workload],
    records: &mut Vec<Json>,
    cache_totals: &mut snipsnap::cost::CacheStats,
) -> (Vec<f64>, Vec<f64>) {
    let arch = presets::arch3();
    let mut t = Table::new(vec![
        "model", "Bitmap", "RLE", "CSR", "COO", "SnipSnap", "saving", "speedup",
    ])
    .with_title(format!("{label} — memory energy normalized to Bitmap (Arch 3)"));
    let mut savings = Vec::new();
    let mut speedups = Vec::new();
    for w in workloads {
        let mut energies = Vec::new();
        let mut bitmap_cycles = 0.0;
        for fname in FORMATS {
            let r = evaluate_with_formats(
                &arch,
                w,
                |op| {
                    let mk = |rows, cols| match fname {
                        "Bitmap" => named::bitmap(rows, cols),
                        "RLE" => named::rle(rows, cols),
                        "CSR" => named::csr(rows, cols),
                        _ => named::coo(rows, cols),
                    };
                    (mk(op.dims.m, op.dims.n), mk(op.dims.n, op.dims.k))
                },
                &cfg(FormatMode::Fixed),
            );
            if fname == "Bitmap" {
                bitmap_cycles = r.total_cycles();
            }
            energies.push(r.memory_energy_pj());
        }
        let snip = cosearch_workload(&arch, w, &cfg(FormatMode::Search));
        cache_totals.merge(snip.cache);
        let bitmap_e = energies[0];
        let saving = 1.0 - snip.memory_energy_pj() / bitmap_e;
        let speedup = bitmap_cycles / snip.total_cycles();
        savings.push(saving);
        speedups.push(speedup);
        let mut row = vec![w.name.clone()];
        for e in &energies {
            row.push(format!("{:.3}", e / bitmap_e));
        }
        row.push(format!("{:.3}", snip.memory_energy_pj() / bitmap_e));
        row.push(fmt_pct(saving));
        row.push(fmt_x(speedup));
        t.add_row(row);
        records.push(Json::obj(vec![
            ("variant", Json::str(label)),
            ("model", Json::str(&w.name)),
            ("saving_vs_bitmap", Json::num(saving)),
            ("speedup_vs_bitmap", Json::num(speedup)),
            (
                "baseline_rel",
                Json::arr(energies.iter().map(|e| Json::num(e / bitmap_e)).collect::<Vec<_>>()),
            ),
        ]));
    }
    println!("{}", t.render());
    (savings, speedups)
}

fn main() {
    let t0 = Instant::now();
    banner("Fig. 10", "single-LLM format optimization (SA / SW)");
    let ph = Phase::default_prefill_decode();
    // SA is evaluated on the prefill phase (activation traffic dominates
    // there; decode with dense weights is weight-stream-bound and would
    // dilute the activation-format signal the figure isolates).  SW uses
    // the full prefill+decode pipeline where weight streaming dominates.
    let prefill = Phase::prefill_only(2048);
    let sa: Vec<Workload> = vec![
        llm::llama2_7b(prefill),
        llm::llama2_13b(prefill),
        llm::opt_6_7b(prefill),
        llm::opt_13b(prefill),
        llm::opt_30b(prefill),
    ]
    .into_iter()
    .map(llm::activation_sparse_variant)
    .collect();
    let sw: Vec<Workload> = vec![
        llm::llama2_7b(ph),
        llm::llama2_13b(ph),
        llm::opt_6_7b(ph),
        llm::opt_13b(ph),
        llm::opt_30b(ph),
    ]
    .into_iter()
    .map(|w| llm::weight_sparse_variant(w, 8))
    .collect();

    let mut records = Vec::new();
    let mut cache_totals = snipsnap::cost::CacheStats::default();
    let (sa_savings, sa_speedups) =
        run_variant("Activation sparsity (SA)", &sa, &mut records, &mut cache_totals);
    let (sw_savings, sw_speedups) =
        run_variant("Weight sparsity (SW)", &sw, &mut records, &mut cache_totals);

    println!(
        "SA: mean saving {} (paper 14.53%), mean speedup {} (paper 1.18x)",
        fmt_pct(mean(&sa_savings)),
        fmt_x(mean(&sa_speedups))
    );
    println!(
        "SW: mean saving {} (paper 21.95%), mean speedup {} (paper 1.30x)",
        fmt_pct(mean(&sw_savings)),
        fmt_x(mean(&sw_speedups))
    );
    // Shape assertions: SnipSnap never loses to Bitmap; SW gains exceed SA.
    for s in sa_savings.iter().chain(&sw_savings) {
        assert!(*s > -0.001, "SnipSnap lost to Bitmap: {s}");
    }
    assert!(
        mean(&sw_savings) > mean(&sa_savings) * 0.8,
        "SW should benefit at least comparably to SA"
    );
    println!(
        "access-counts cache (co-searches): {} hits / {} misses ({:.1}% hit rate)",
        cache_totals.hits,
        cache_totals.misses,
        100.0 * cache_totals.hit_rate()
    );
    write_record(
        "fig10_single_llm",
        t0.elapsed().as_secs_f64(),
        Json::obj(vec![
            ("sa_mean_saving", Json::num(mean(&sa_savings))),
            ("sw_mean_saving", Json::num(mean(&sw_savings))),
            ("sa_mean_speedup", Json::num(mean(&sa_speedups))),
            ("sw_mean_speedup", Json::num(mean(&sw_speedups))),
            ("cache_hits", Json::num(cache_totals.hits as f64)),
            ("cache_misses", Json::num(cache_totals.misses as f64)),
            ("rows", Json::arr(records)),
        ]),
    );
    println!("fig10 OK");
}
