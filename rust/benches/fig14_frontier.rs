//! Fig. 14 (extension beyond the paper) — single-pass Pareto-frontier
//! co-search: one `--metric frontier` arena pass vs four independent
//! scalar searches (energy / memory-energy / latency / EDP) on Arch 3
//! over the reduced OPT-125M prefill workload.
//!
//! Claims asserted:
//!   * the frontier pass reproduces every scalar search's winners **bit
//!     for bit** (mapping, metric value, cost report),
//!   * serially, with pruning on and index-order visits (so each
//!     metric's prune decisions match its solo search exactly), the one
//!     pass spends strictly fewer cost-model evaluations than the four
//!     passes summed — the shared trial recorder evaluates each distinct
//!     mapping once per proto instead of once per metric.
//!
//! The JSON record carries both evaluation counts and both wall times so
//! `snipsnap report` can roll up the one-pass saving alongside the other
//! figures.

use snipsnap::arch::presets;
use snipsnap::cost::Metric;
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::search::{cosearch_workload, FormatMode, SearchConfig, WorkloadResult};
use snipsnap::util::bench::{banner, write_record};
use snipsnap::util::json::Json;
use snipsnap::util::table::{fmt_f, Table};
use snipsnap::workload::llm;
use std::time::Instant;

const METRIC_NAMES: [&str; 4] = ["energy", "memory-energy", "latency", "edp"];

/// Serial, pruned, index-order — the configuration under which the
/// per-metric prune sets of the frontier pass and the solo searches are
/// provably identical, making the eval-count comparison structural.
fn cfg(metric: Metric) -> SearchConfig {
    SearchConfig {
        mode: FormatMode::Fixed,
        metric,
        threads: 1,
        prune: true,
        best_first: false,
        mapper: MapperConfig { max_candidates: 600, ..Default::default() },
        ..Default::default()
    }
}

fn assert_winners_identical(frontier: &[snipsnap::search::OpDesign], solo: &WorkloadResult, name: &str) {
    assert_eq!(frontier.len(), solo.designs.len(), "{name}: design count mismatch");
    for (a, b) in frontier.iter().zip(&solo.designs) {
        assert_eq!(a.op_name, b.op_name, "{name}: op order mismatch");
        assert_eq!(a.mapping, b.mapping, "{name} {}: mappings diverged", a.op_name);
        assert_eq!(
            a.metric_value.to_bits(),
            b.metric_value.to_bits(),
            "{name} {}: {} vs {}",
            a.op_name,
            a.metric_value,
            b.metric_value
        );
        assert_eq!(a.report, b.report, "{name} {}: reports diverged", a.op_name);
    }
}

fn main() {
    let t0 = Instant::now();
    banner("Fig. 14", "single-pass Pareto frontier vs four scalar searches");
    let arch = presets::arch3();
    let w = llm::opt_125m(llm::Phase::prefill_only(64));

    // Four independent scalar passes (the historical workflow).
    let mut solos = Vec::new();
    let mut four_pass_evals = 0u64;
    let mut four_pass_s = 0.0f64;
    for &m in &Metric::SCALARS {
        let t = Instant::now();
        let r = cosearch_workload(&arch, &w, &cfg(m));
        four_pass_s += t.elapsed().as_secs_f64();
        four_pass_evals += r.evaluations;
        solos.push(r);
    }

    // One frontier pass over the same arena.
    let t = Instant::now();
    let fr = cosearch_workload(&arch, &w, &cfg(Metric::Frontier));
    let one_pass_s = t.elapsed().as_secs_f64();
    let one_pass_evals = fr.evaluations;
    let f = fr.frontier.as_ref().expect("frontier mode returns a frontier");

    let mut t = Table::new(vec!["metric", "solo evals", "winner objective", "frontier objective"])
        .with_title("per-metric winners: frontier pass vs independent searches");
    let mut rows = Vec::new();
    for (mi, name) in METRIC_NAMES.iter().enumerate() {
        assert_winners_identical(&f.winners[mi], &solos[mi], name);
        t.add_row(vec![
            name.to_string(),
            solos[mi].evaluations.to_string(),
            fmt_f(solos[mi].metric_total(Metric::SCALARS[mi])),
            fmt_f(f.winner_total(mi)),
        ]);
        rows.push(Json::obj(vec![
            ("metric", Json::str(name)),
            ("solo_evals", Json::num(solos[mi].evaluations as f64)),
            ("objective", Json::num(f.winner_total(mi))),
        ]));
    }
    println!("{}", t.render());

    assert!(
        one_pass_evals < four_pass_evals,
        "one-pass frontier spent {one_pass_evals} evaluations vs {four_pass_evals} for four passes"
    );
    println!(
        "evaluations: one pass {} vs four passes {} ({:.1}% saved) | {} Pareto points | walls {:.2}s vs {:.2}s",
        one_pass_evals,
        four_pass_evals,
        100.0 * (1.0 - one_pass_evals as f64 / four_pass_evals as f64),
        f.total_points(),
        one_pass_s,
        four_pass_s
    );

    write_record(
        "fig14_frontier",
        t0.elapsed().as_secs_f64(),
        Json::obj(vec![
            ("frontier_one_pass_evals", Json::num(one_pass_evals as f64)),
            ("frontier_four_pass_evals", Json::num(four_pass_evals as f64)),
            ("frontier_one_pass_s", Json::num(one_pass_s)),
            ("frontier_four_pass_s", Json::num(four_pass_s)),
            ("frontier_points", Json::num(f.total_points() as f64)),
            ("pruned_by_metric", Json::arr(fr.pruned_by_metric.iter().map(|&n| Json::num(n as f64)).collect())),
            ("rows", Json::arr(rows)),
        ]),
    );
    println!("fig14 OK");
}
