//! Fig. 9 — latency-model validation against DSTC.
//!
//! 4096x4096 MatMul on the DSTC configuration across the sparsity levels
//! common in LLaMA2-7B, compared with the published relative-latency
//! series.  The paper reports SnipSnap at 6.26% mean relative error vs
//! Sparseloop's 8.55%; we additionally emulate the stepwise baseline's
//! coarser correction (dense dataflow latency scaled by the skip factor
//! only, no compression-aware memory roofline) to reproduce the gap's
//! *direction*.
//!
//! With `--cost-backend contention` (or `both`, the default) the same
//! study also runs under the contention memory model (burst roundup,
//! bandwidth derate, decompression — docs/COST.md), reported side by
//! side.  The contention series is self-normalized (sparse vs dense
//! under the same backend), so it tracks the same trend; it is asserted
//! finite and monotone, not pinned to the published MRE envelope (the
//! reference numbers were fit against the flat-bandwidth model).

use snipsnap::arch::presets;
use snipsnap::arch::published::DSTC_LATENCY;
use snipsnap::arch::validation::{dstc_latency_validation, dstc_latency_validation_with};
use snipsnap::cost::{ContentionParams, CostModel, Metric};
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::dataflow::ProblemDims;
use snipsnap::search::{cosearch_workload, FormatMode, SearchConfig};
use snipsnap::sparsity::SparsitySpec;
use snipsnap::util::bench::{banner, write_record};
use snipsnap::util::json::Json;
use snipsnap::util::stats::{mean, relative_error};
use snipsnap::util::table::{fmt_f, fmt_pct, Table};
use snipsnap::workload::{MatMulOp, Workload};
use std::time::Instant;

/// Sparseloop-style post-hoc latency correction: dense-optimal mapping's
/// latency scaled by the computation-reduction factor only.
fn stepwise_estimate() -> Vec<f64> {
    let arch = presets::dstc_validation();
    let dims = ProblemDims::new(4096, 4096, 4096);
    let dense = Workload {
        name: "dense".into(),
        ops: vec![MatMulOp {
            name: "op".into(),
            dims,
            spec: SparsitySpec::dense(),
            count: 1,
        }],
    };
    let cfg = SearchConfig {
        metric: Metric::Latency,
        mode: FormatMode::Fixed,
        mapper: MapperConfig { max_candidates: 4_000, ..Default::default() },
        ..Default::default()
    };
    let dense_cycles = cosearch_workload(&arch, &dense, &cfg).total_cycles();
    DSTC_LATENCY
        .iter()
        .map(|p| {
            let spec = SparsitySpec::unstructured(p.act_density, p.wgt_density);
            let frac = arch.reduction.cycle_fraction(&spec);
            // Post-hoc correction can only scale compute; memory-bound
            // effects of compression are invisible to it.
            dense_cycles * frac / dense_cycles
        })
        .collect()
}

/// `--cost-backend analytical|contention|both` (default both).  Unknown
/// flags are ignored (bench harness convention); a bad value exits 2
/// like the CLI's usage error.
fn backend_arg() -> (bool, bool) {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut choice = "both".to_string();
    let mut i = 0;
    while i < argv.len() {
        if argv[i] == "--cost-backend" {
            match argv.get(i + 1) {
                Some(v) => choice = v.clone(),
                None => {
                    eprintln!("error: --cost-backend needs a value (analytical|contention|both)");
                    std::process::exit(2);
                }
            }
            i += 2;
        } else {
            i += 1;
        }
    }
    match choice.as_str() {
        "analytical" => (true, false),
        "contention" => (false, true),
        "both" => (true, true),
        other => {
            eprintln!("error: unknown cost backend '{other}' (analytical|contention|both)");
            std::process::exit(2);
        }
    }
}

fn main() {
    let (run_analytical, run_contention) = backend_arg();
    let t0 = Instant::now();
    banner("Fig. 9", "DSTC latency validation (4096x4096 MatMul)");

    let mut record = Vec::new();

    if run_analytical {
        let (mre, rows) = dstc_latency_validation();
        let stepwise = stepwise_estimate();
        let stepwise_errs: Vec<f64> = stepwise
            .iter()
            .zip(&DSTC_LATENCY)
            .map(|(m, p)| relative_error(*m, p.latency_rel))
            .collect();
        let sl_mre = mean(&stepwise_errs);

        let mut t = Table::new(vec![
            "density", "reported", "SnipSnap", "err", "stepwise est.", "err",
        ])
        .with_title("analytical backend");
        let mut records = Vec::new();
        for (i, r) in rows.iter().enumerate() {
            t.add_row(vec![
                format!("{:.2}", r.density),
                fmt_f(r.reported),
                fmt_f(r.modeled),
                fmt_pct(r.rel_err),
                fmt_f(stepwise[i]),
                fmt_pct(stepwise_errs[i]),
            ]);
            records.push(Json::obj(vec![
                ("density", Json::num(r.density)),
                ("reported", Json::num(r.reported)),
                ("snipsnap", Json::num(r.modeled)),
                ("stepwise", Json::num(stepwise[i])),
            ]));
        }
        println!("{}", t.render());
        println!(
            "mean relative error: SnipSnap {} (paper 6.26%) vs stepwise {} (paper: Sparseloop 8.55%)",
            fmt_pct(mre),
            fmt_pct(sl_mre)
        );
        assert!(mre < 0.10, "SnipSnap MRE {mre}");
        assert!(mre < sl_mre, "SnipSnap must model latency better than the stepwise estimate");
        record.push(("snipsnap_mre", Json::num(mre)));
        record.push(("stepwise_mre", Json::num(sl_mre)));
        record.push(("rows", Json::arr(records)));
    }

    if run_contention {
        let (mre, rows) =
            dstc_latency_validation_with(CostModel::Contention(ContentionParams::default()));
        let mut t = Table::new(vec!["density", "reported", "contention", "err"])
            .with_title("contention backend (burst/derate/decompress)");
        let mut records = Vec::new();
        for r in &rows {
            t.add_row(vec![
                format!("{:.2}", r.density),
                fmt_f(r.reported),
                fmt_f(r.modeled),
                fmt_pct(r.rel_err),
            ]);
            records.push(Json::obj(vec![
                ("density", Json::num(r.density)),
                ("reported", Json::num(r.reported)),
                ("contention", Json::num(r.modeled)),
            ]));
        }
        println!("{}", t.render());
        println!("contention mean relative error: {}", fmt_pct(mre));
        // The contention series is validated structurally, not pinned to
        // the published envelope: finite, positive, density-monotone.
        assert!(mre.is_finite(), "contention MRE {mre}");
        for r in &rows {
            assert!(r.modeled.is_finite() && r.modeled > 0.0, "{r:?}");
        }
        for w in rows.windows(2) {
            assert!(w[1].modeled <= w[0].modeled + 1e-9, "contention series not monotone");
        }
        record.push(("contention_mre", Json::num(mre)));
        record.push(("contention_rows", Json::arr(records)));
    }

    write_record("fig09_dstc_latency", t0.elapsed().as_secs_f64(), Json::obj(record));
    println!("fig09 OK");
}
