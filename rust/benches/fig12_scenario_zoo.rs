//! Fig. 12 (extension beyond the paper) — scenario zoo: co-search on
//! one representative per scenario family (dense-shaped MHA, GQA, MoE,
//! batched decode, N:M weights) at reduced sizes, on Arch 3.
//!
//! Qualitative claims asserted:
//!   * every scenario co-searches end to end (a design per op),
//!   * GQA costs less energy than the same shape as MHA (smaller K/V
//!     projections and KV cache),
//!   * 2:4 N:M weights cost less than the fully dense workload,
//!   * batched decode amortizes weight streaming: batch-4 decode costs
//!     less than 4x batch-1 decode.

use snipsnap::arch::presets;
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::search::{cosearch_workload, SearchConfig};
use snipsnap::util::bench::{banner, write_record};
use snipsnap::util::json::Json;
use snipsnap::util::table::{fmt_f, Table};
use snipsnap::workload::llm::{build_llm, LlmShape, LlmSparsity, Phase};
use snipsnap::workload::{gqa, llm, scenario_zoo, Workload};
use std::time::Instant;

fn cfg() -> SearchConfig {
    SearchConfig {
        mapper: MapperConfig { max_candidates: 300, ..Default::default() },
        ..Default::default()
    }
}

fn search(arch: &snipsnap::arch::Accelerator, w: &Workload) -> snipsnap::search::WorkloadResult {
    let r = cosearch_workload(arch, w, &cfg());
    assert_eq!(r.designs.len(), w.ops.len(), "{}: missing designs", w.name);
    assert!(r.total_energy_pj() > 0.0 && r.total_cycles() > 0.0, "{}", w.name);
    r
}

fn main() {
    let t0 = Instant::now();
    banner("Fig. 12", "scenario zoo: GQA / MoE / batched decode / N:M end-to-end");
    let arch = presets::arch3();

    let mut t = Table::new(vec![
        "scenario", "ops", "GMACs", "energy (pJ)", "cycles", "EDP", "cache hit%",
    ]);
    let mut rows = Vec::new();
    for w in scenario_zoo() {
        let r = search(&arch, &w);
        t.add_row(vec![
            w.name.clone(),
            w.op_count().to_string(),
            format!("{:.2}", w.total_macs() / 1e9),
            fmt_f(r.total_energy_pj()),
            fmt_f(r.total_cycles()),
            fmt_f(r.edp()),
            format!("{:.1}", 100.0 * r.cache.hit_rate()),
        ]);
        rows.push(Json::obj(vec![
            ("scenario", Json::str(&w.name)),
            ("ops", Json::num(w.op_count() as f64)),
            ("gmacs", Json::num(w.total_macs() / 1e9)),
            ("energy_pj", Json::num(r.total_energy_pj())),
            ("cycles", Json::num(r.total_cycles())),
            ("edp", Json::num(r.edp())),
        ]));
    }
    println!("{}", t.render());

    // Claim 1: GQA beats the same shape as MHA (smaller K/V projections).
    let sp = LlmSparsity { act_proj: 0.55, act_fc1: 0.50, act_fc2: 0.20, attn: 0.30, weight: 0.40 };
    let ph = Phase::new(256, 32);
    let gqa_r = search(&arch, &gqa::gqa_tiny(ph));
    let mha_like = build_llm("MHA-ref", LlmShape::mha(256, 512, 2, 8), sp, ph);
    let mha_r = search(&arch, &mha_like);
    let gqa_saving = 1.0 - gqa_r.total_energy_pj() / mha_r.total_energy_pj();
    println!("GQA (8 heads over 2 KV heads) vs MHA energy saving: {:.1}%", 100.0 * gqa_saving);
    assert!(gqa_saving > 0.0, "GQA did not save energy over MHA");

    // Claim 2: 2:4 N:M weights beat the fully dense workload.
    let small = Phase::new(256, 32);
    let dense =
        llm::with_uniform_density(llm::opt_125m(small), 1.0, 1.0).expect("densities in range");
    let dense_r = search(&arch, &dense);
    let nm_r = search(&arch, &llm::weight_nm_variant(llm::opt_125m(small), 2, 4));
    let nm_saving = 1.0 - nm_r.total_energy_pj() / dense_r.total_energy_pj();
    println!("2:4 N:M weights vs dense energy saving: {:.1}%", 100.0 * nm_saving);
    assert!(nm_saving > 0.0, "N:M weights did not save energy over dense");

    // Claim 3: batched decode amortizes weight streaming.
    let shape = LlmShape::mha(256, 512, 2, 4);
    let b1 = search(&arch, &build_llm("decode-b1", shape, sp, Phase::new(0, 16)));
    let b4 =
        search(&arch, &build_llm("decode-b4", shape, sp, Phase::new(0, 16).with_batch(4)));
    let amort = b4.total_energy_pj() / b1.total_energy_pj();
    println!("batch-4 decode energy = {amort:.2}x batch-1 (4 sequences; < 4x means amortization)");
    assert!(amort < 4.0, "batched decode showed no amortization: {amort}x");

    write_record(
        "fig12_scenario_zoo",
        t0.elapsed().as_secs_f64(),
        Json::obj(vec![
            ("gqa_energy_saving", Json::num(gqa_saving)),
            ("nm_energy_saving", Json::num(nm_saving)),
            ("batch4_vs_1x4_ratio", Json::num(amort)),
            ("rows", Json::arr(rows)),
        ]),
    );
    println!("fig12 OK");
}
