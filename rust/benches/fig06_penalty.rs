//! Fig. 6 — complexity-based penalizing ablation.
//!
//! Search a 4096x4096 tensor at 90% sparsity and 2:4 structured sparsity
//! with and without the complexity penalty.  The paper reports: the full
//! space holds >400k candidates; penalizing explores a small subset while
//! staying within 0.31% of the optimal payload, and the selected formats
//! have 2-3 levels.

use snipsnap::engine::penalty::{exhaustive_search, optimality_gap};
use snipsnap::engine::{search_formats, EngineConfig};
use snipsnap::sparsity::SparsityPattern;
use snipsnap::util::bench::{banner, time_once, write_record};
use snipsnap::util::json::Json;
use snipsnap::util::table::{fmt_f, fmt_pct, Table};
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    banner("Fig. 6", "penalized vs exhaustive format search (4096x4096)");
    let cfg = EngineConfig::default();
    let mut t = Table::new(vec![
        "sparsity",
        "full-space candidates",
        "explored (penalized)",
        "best bits (exhaustive)",
        "best bits (penalized)",
        "gap",
        "levels",
        "time exh. (s)",
        "time pen. (s)",
    ]);
    let mut records = Vec::new();
    for (label, pattern) in [
        ("90% (d=0.10)", SparsityPattern::Unstructured { density: 0.10 }),
        ("2:4", SparsityPattern::Nm { n: 2, m: 4 }),
    ] {
        let (ex, t_ex) = time_once(|| exhaustive_search(4096, 4096, &pattern, &cfg));
        let ((top, stats), t_pen) =
            time_once(|| search_formats(4096, 4096, &pattern, None, &cfg));
        let gap = optimality_gap(top[0].cost.total_bits(), ex.best_bits);
        let levels = top[0].format.compressing_depth();
        t.add_row(vec![
            label.to_string(),
            ex.candidates.to_string(),
            stats.evaluated.to_string(),
            fmt_f(ex.best_bits),
            fmt_f(top[0].cost.total_bits()),
            fmt_pct(gap),
            levels.to_string(),
            format!("{t_ex:.2}"),
            format!("{t_pen:.3}"),
        ]);
        records.push(Json::obj(vec![
            ("sparsity", Json::str(label)),
            ("full_space", Json::num(ex.candidates as f64)),
            ("explored", Json::num(stats.evaluated as f64)),
            ("gap", Json::num(gap)),
            ("levels", Json::num(levels as f64)),
        ]));
        // Paper claims: near-optimal payload (their tensor: within 0.31%)
        // at 2-3 levels.  The achievable gap is bounded by the penalty
        // itself: a (d+1)-level format must beat the d-level best by
        // >gamma to be selected, so the selected format can trade up to
        // ~gamma^1..2 - 1 (5-10%) of payload for generality by design.
        assert!(gap < 0.06, "{label}: gap {}", fmt_pct(gap));
        assert!((1..=3).contains(&levels), "{label}: {levels} levels");
        assert!(stats.evaluated < ex.candidates / 50);
    }
    println!("{}", t.render());
    write_record("fig06_penalty", t0.elapsed().as_secs_f64(), Json::arr(records));
    println!("fig06 OK");
}
