//! §IV-E — format feasibility discussion.
//!
//! Shows the formats SnipSnap discovers for the paper's two showcased
//! cases — weight-sparse OPT-6.7B (paper: `B(M)-B(N)-B(N)`, the Fig. 5
//! family) and BERT-Base (paper: `UOP(M)-B(N)`, CSR with the CP replaced
//! by a cheaper bitmap) — and summarizes the level counts and codec-area
//! budgets that make them deployable (existing accelerators report
//! 1.56%-15.45% compression/decompression area overhead).

use snipsnap::arch::presets;
use snipsnap::engine::{search_formats, EngineConfig};
use snipsnap::format::space::SpaceConfig;
use snipsnap::sparsity::SparsityPattern;
use snipsnap::util::bench::{banner, write_record};
use snipsnap::util::json::Json;
use snipsnap::util::table::{fmt_pct, Table};
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    banner("§IV-E", "discovered formats and deployment feasibility");
    let cfg = EngineConfig {
        space: SpaceConfig { max_depth: 3, ..Default::default() },
        top_k: 3,
        ..Default::default()
    };

    let mut t = Table::new(vec![
        "tensor case", "paper's showcased pick", "our top formats", "levels", "ratio",
    ]);
    let mut records = Vec::new();
    let cases: Vec<(&str, &str, u64, u64, SparsityPattern)> = vec![
        (
            "OPT-6.7B weights (clustered 30% dense)",
            "B(M)-B(N)-B(N)",
            4096,
            16384,
            SparsityPattern::Block { br: 8, bc: 8, block_density: 0.30 },
        ),
        (
            "BERT-Base FC weights (25% dense)",
            "UOP(M)-B(N)",
            768,
            3072,
            SparsityPattern::Unstructured { density: 0.25 },
        ),
        (
            "FC2 activations (5% dense)",
            "(highly sparse regime)",
            2048,
            16384,
            SparsityPattern::Unstructured { density: 0.05 },
        ),
    ];
    for (case, paper_pick, rows, cols, pattern) in cases {
        let (top, _) = search_formats(rows, cols, &pattern, None, &cfg);
        let names: Vec<String> = top.iter().map(|s| s.format.to_string()).collect();
        let levels = top[0].format.compressing_depth();
        t.add_row(vec![
            case.to_string(),
            paper_pick.to_string(),
            names.join(" ; "),
            levels.to_string(),
            fmt_pct(top[0].cost.ratio()),
        ]);
        records.push(Json::obj(vec![
            ("case", Json::str(case)),
            ("top_format", Json::str(&names[0])),
            ("levels", Json::num(levels as f64)),
            ("ratio", Json::num(top[0].cost.ratio())),
        ]));
        // Feasibility claim: 2-3 compressing levels, like CSR/CSB.
        assert!(levels <= 3, "{case}: {levels} levels");
    }
    println!("{}", t.render());

    let mut a = Table::new(vec!["accelerator", "codec area budget"])
        .with_title("Compression/decompression area overheads (reported range 1.56%-15.45%)");
    for arch in presets::all_table2().iter().chain([presets::scnn()].iter()) {
        a.add_row(vec![arch.name.clone(), fmt_pct(arch.codec_area_overhead)]);
        assert!(arch.codec_area_overhead < 0.1545 + 1e-9);
    }
    println!("{}", a.render());
    write_record("feasibility", t0.elapsed().as_secs_f64(), Json::arr(records));
    println!("feasibility OK");
}
