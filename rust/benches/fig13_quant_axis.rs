//! Fig. 13 (extension beyond the paper) — quantization as a co-search
//! axis: payload bitwidths searched jointly with compression format and
//! dataflow, on Arch 3 over small scenario workloads.
//!
//! Qualitative claims asserted:
//!   * the multi-width search picks widths from the configured spaces
//!     only (activations pinned at 8, weights/KV searched over 4/8/16),
//!   * per op, the searched design's objective is <= the design of
//!     every fixed-width run over the same set (the set search
//!     dominates each of its members),
//!   * consequently the per-op objective sum of the search run is <=
//!     that of the best fixed-width run, for energy and for EDP.
//!
//! The dominance comparison uses the per-op objective sum
//! `sum(metric_value * count)` — the quantity the co-search actually
//! minimizes per op.  Workload EDP is `(sum E) * (sum C)`, not a per-op
//! sum, so a workload-level EDP comparison would not be a theorem; the
//! per-op sum is (see docs/SEARCH.md).

use snipsnap::arch::presets;
use snipsnap::config::typed::workload_by_name;
use snipsnap::cost::Metric;
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::format::quant::{BitwidthSpace, QuantConfig};
use snipsnap::search::{cosearch_workload, SearchConfig, WorkloadResult};
use snipsnap::util::bench::{banner, write_record};
use snipsnap::util::json::Json;
use snipsnap::util::table::{fmt_f, Table};
use std::time::Instant;

const WIDTHS: [u32; 3] = [4, 8, 16];
const SCENARIOS: [&str; 3] = ["gqa-tiny", "decode-tiny", "moe-tiny"];

fn cfg(metric: Metric, quant: QuantConfig) -> SearchConfig {
    SearchConfig {
        metric,
        mapper: MapperConfig { max_candidates: 300, ..Default::default() },
        quant,
        ..Default::default()
    }
}

/// Weights and KV searched over 4/8/16; activations pinned at 8.
fn set_quant() -> QuantConfig {
    let wide = BitwidthSpace::new(WIDTHS.to_vec()).expect("static set");
    QuantConfig {
        w_bits: Some(wide.clone()),
        a_bits: Some(BitwidthSpace::fixed(8)),
        kv_bits: Some(wide),
    }
}

/// One member of the searched set: weights and KV pinned at `b`.
fn fixed_quant(b: u32) -> QuantConfig {
    QuantConfig {
        w_bits: Some(BitwidthSpace::fixed(b)),
        a_bits: Some(BitwidthSpace::fixed(8)),
        kv_bits: Some(BitwidthSpace::fixed(b)),
    }
}

/// The per-op objective the co-search minimizes, summed over instances.
fn per_op_sum(r: &WorkloadResult) -> f64 {
    r.designs.iter().map(|d| d.metric_value * d.count as f64).sum()
}

/// Per-op dominance: the searched design must be no worse than the
/// fixed-width design on every op (same workload, same op order).
fn assert_dominates(searched: &WorkloadResult, fixed: &WorkloadResult, label: &str) {
    for (s, f) in searched.designs.iter().zip(&fixed.designs) {
        assert_eq!(s.op_name, f.op_name, "{label}: op order mismatch");
        assert!(
            s.metric_value <= f.metric_value,
            "{label} {}: searched {} > fixed {}",
            s.op_name,
            s.metric_value,
            f.metric_value
        );
    }
}

fn main() {
    let t0 = Instant::now();
    banner("Fig. 13", "quantization co-search axis: set search vs fixed widths");
    let arch = presets::arch3();

    let mut t = Table::new(vec![
        "scenario", "search (pJ)", "W4 (pJ)", "W8 (pJ)", "W16 (pJ)", "vs best fixed",
    ]);
    let mut rows = Vec::new();
    for name in SCENARIOS {
        let w = workload_by_name(name).expect("scenario preset");
        let searched = cosearch_workload(&arch, &w, &cfg(Metric::Energy, set_quant()));
        assert_eq!(searched.designs.len(), w.ops.len(), "{name}: missing designs");
        for d in &searched.designs {
            assert_eq!(d.input_bits, 8, "{name} {}: activations pinned at 8", d.op_name);
            assert!(
                WIDTHS.contains(&d.weight_bits),
                "{name} {}: searched width {} outside the configured set",
                d.op_name,
                d.weight_bits
            );
        }

        let mut fixed = Vec::new();
        for b in WIDTHS {
            let r = cosearch_workload(&arch, &w, &cfg(Metric::Energy, fixed_quant(b)));
            assert_dominates(&searched, &r, &format!("{name} energy W{b}"));
            fixed.push(r);
        }
        let best_fixed = fixed
            .iter()
            .map(per_op_sum)
            .fold(f64::INFINITY, f64::min);
        let s_sum = per_op_sum(&searched);
        assert!(
            s_sum <= best_fixed,
            "{name}: search sum {s_sum} > best fixed sum {best_fixed}"
        );

        t.add_row(vec![
            w.name.clone(),
            fmt_f(searched.total_energy_pj()),
            fmt_f(fixed[0].total_energy_pj()),
            fmt_f(fixed[1].total_energy_pj()),
            fmt_f(fixed[2].total_energy_pj()),
            format!("{:.1}%", 100.0 * (1.0 - s_sum / best_fixed)),
        ]);
        rows.push(Json::obj(vec![
            ("scenario", Json::str(&w.name)),
            ("search_objective", Json::num(s_sum)),
            ("best_fixed_objective", Json::num(best_fixed)),
            ("search_energy_pj", Json::num(searched.total_energy_pj())),
            (
                "fixed_energy_pj",
                Json::arr(fixed.iter().map(|r| Json::num(r.total_energy_pj())).collect()),
            ),
        ]));
    }
    println!("{}", t.render());

    // Same dominance under EDP, on one scenario (per-op objective sums;
    // see the module comment for why not workload EDP).
    let w = workload_by_name("gqa-tiny").expect("scenario preset");
    let searched = cosearch_workload(&arch, &w, &cfg(Metric::Edp, set_quant()));
    let mut best_fixed = f64::INFINITY;
    for b in WIDTHS {
        let r = cosearch_workload(&arch, &w, &cfg(Metric::Edp, fixed_quant(b)));
        assert_dominates(&searched, &r, &format!("gqa-tiny edp W{b}"));
        best_fixed = best_fixed.min(per_op_sum(&r));
    }
    let edp_sum = per_op_sum(&searched);
    assert!(edp_sum <= best_fixed, "EDP search sum {edp_sum} > best fixed {best_fixed}");
    println!(
        "EDP per-op objective: search {} vs best fixed {} ({:.1}% better)",
        fmt_f(edp_sum),
        fmt_f(best_fixed),
        100.0 * (1.0 - edp_sum / best_fixed)
    );

    write_record(
        "fig13_quant_axis",
        t0.elapsed().as_secs_f64(),
        Json::obj(vec![
            ("edp_search_objective", Json::num(edp_sum)),
            ("edp_best_fixed_objective", Json::num(best_fixed)),
            ("rows", Json::arr(rows)),
        ]),
    );
    println!("fig13 OK");
}
