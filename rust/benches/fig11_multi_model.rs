//! Fig. 11 — multi-model shared-format selection with importance scoring.
//!
//! Case 1: BERT-Base + OPT-125M; Case 2: speculative decoding with
//! OPT-125M + OPT-6.7B.  One shared format pattern is selected by
//! importance-weighted scoring and evaluated with the full cost model;
//! results are normalized to the best single baseline format.  Paper:
//! 14.23% average energy saving, selection biased toward the
//! higher-importance / higher-cost model.

use snipsnap::arch::presets;
use snipsnap::cost::Metric;
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::engine::allocate::choose_allocation;
use snipsnap::engine::scoring::{select_shared_pattern, WeightedWorkload};
use snipsnap::engine::EngineConfig;
use snipsnap::format::space::SpaceConfig;
use snipsnap::format::{named, Axis, CompPat, Prim};
use snipsnap::search::{evaluate_with_formats, FormatMode, SearchConfig};
use snipsnap::util::bench::{banner, write_record};
use snipsnap::util::json::Json;
use snipsnap::util::stats::mean;
use snipsnap::util::table::{fmt_pct, Table};
use snipsnap::workload::{llm, Workload};
use std::time::Instant;

fn search_cfg() -> SearchConfig {
    SearchConfig {
        metric: Metric::MemoryEnergy,
        mode: FormatMode::Fixed,
        mapper: MapperConfig { max_candidates: 800, ..Default::default() },
        ..Default::default()
    }
}

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        space: SpaceConfig { max_depth: 3, ..Default::default() },
        top_k: 3,
        ..Default::default()
    }
}

/// Energy of a workload with every tensor using `pat` (per-tensor
/// allocation chosen by the engine; dense fallback when unallocatable).
fn energy_with_pattern(w: &Workload, pat: &CompPat) -> f64 {
    let arch = presets::arch3();
    let ecfg = engine_cfg();
    evaluate_with_formats(
        &arch,
        w,
        |op| {
            let mk = |rows: u64, cols: u64, pattern: &snipsnap::sparsity::SparsityPattern| {
                choose_allocation(pat, rows, cols, pattern, None, &ecfg)
                    .unwrap_or_else(|| named::dense(rows, cols))
            };
            (
                mk(op.dims.m, op.dims.n, &op.spec.input),
                mk(op.dims.n, op.dims.k, &op.spec.weight),
            )
        },
        &search_cfg(),
    )
    .memory_energy_pj()
}

fn baseline_patterns() -> Vec<(&'static str, CompPat)> {
    vec![
        ("Bitmap", CompPat::new(vec![(Prim::None, Axis::Row), (Prim::B, Axis::Col)])),
        ("RLE", CompPat::new(vec![(Prim::None, Axis::Row), (Prim::Rle, Axis::Col)])),
        ("CSR", CompPat::new(vec![(Prim::Uop, Axis::Row), (Prim::Cp, Axis::Col)])),
        ("COO", CompPat::new(vec![(Prim::Cp, Axis::Row), (Prim::Cp, Axis::Col)])),
    ]
}

fn run_case(
    case: &str,
    a: &Workload,
    b: &Workload,
    importances: &[(f64, f64)],
    records: &mut Vec<Json>,
) -> Vec<f64> {
    println!("-- {case}: A={} B={} --", a.name, b.name);
    let ecfg = engine_cfg();
    // Baseline energies are importance-independent; compute once.
    let base_energy: Vec<(&str, f64, f64)> = baseline_patterns()
        .iter()
        .map(|(n, p)| (*n, energy_with_pattern(a, p), energy_with_pattern(b, p)))
        .collect();
    let mut t = Table::new(vec![
        "importance A:B",
        "selected pattern",
        "weighted energy (norm. to best baseline)",
        "saving",
    ]);
    let mut savings = Vec::new();
    for &(wa, wb) in importances {
        let ws = [
            WeightedWorkload { workload: a, importance: wa },
            WeightedWorkload { workload: b, importance: wb },
        ];
        let sel = select_shared_pattern(&ws, &ecfg);
        let e = wa * energy_with_pattern(a, &sel.pattern)
            + wb * energy_with_pattern(b, &sel.pattern);
        let best_base = base_energy
            .iter()
            .map(|(_, ea, eb)| wa * ea + wb * eb)
            .fold(f64::INFINITY, f64::min);
        let saving = 1.0 - e / best_base;
        savings.push(saving);
        t.add_row(vec![
            format!("{wa:.0}:{wb:.0}"),
            sel.pattern.to_string(),
            format!("{:.3}", e / best_base),
            fmt_pct(saving),
        ]);
        records.push(Json::obj(vec![
            ("case", Json::str(case)),
            ("importance_a", Json::num(wa)),
            ("importance_b", Json::num(wb)),
            ("pattern", Json::str(&sel.pattern.to_string())),
            ("saving", Json::num(saving)),
        ]));
    }
    println!("{}", t.render());
    savings
}

fn main() {
    let t0 = Instant::now();
    banner("Fig. 11", "multi-model shared format with importance scoring");
    let bert = llm::bert_base(256);
    let opt125 = llm::opt_125m(llm::Phase::new(256, 32));
    let opt67 = llm::opt_6_7b(llm::Phase::new(256, 32));
    let sweeps = [(99.0, 1.0), (75.0, 25.0), (50.0, 50.0), (25.0, 75.0), (1.0, 99.0)];

    let mut records = Vec::new();
    let s1 = run_case("Case 1 (BERT-Base + OPT-125M)", &bert, &opt125, &sweeps, &mut records);
    let s2 = run_case(
        "Case 2 (speculative decoding OPT-125M + OPT-6.7B)",
        &opt125,
        &opt67,
        &sweeps,
        &mut records,
    );

    let avg = mean(&[s1.clone(), s2.clone()].concat());
    println!("average saving vs best baseline: {} (paper: 14.23%)", fmt_pct(avg));
    // Shape: the shared selection never loses to the best single baseline.
    for s in s1.iter().chain(&s2) {
        assert!(*s > -0.02, "shared format lost badly to a baseline: {s}");
    }
    write_record(
        "fig11_multi_model",
        t0.elapsed().as_secs_f64(),
        Json::obj(vec![("avg_saving", Json::num(avg)), ("rows", Json::arr(records))]),
    );
    println!("fig11 OK");
}
