//! §IV-D — DiMO-Sparse workflow comparison on CNNs.
//!
//! SnipSnap (preset formats, matching DiMO's constraint) vs the DiMO-like
//! iterative optimizer on AlexNet, VGG-16 and ResNet-18.  Paper: 19.4x,
//! 19.7x and 23.8x speedups; we reproduce the shape (order-of-magnitude
//! faster at comparable quality).

use snipsnap::arch::presets;
use snipsnap::baselines::dimo_like::{dimo_workload, DimoConfig};
use snipsnap::cost::Metric;
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::search::{cosearch_workload, FormatMode, SearchConfig};
use snipsnap::util::bench::{banner, write_record};
use snipsnap::util::json::Json;
use snipsnap::util::stats::geomean;
use snipsnap::util::table::{fmt_f, fmt_x, Table};
use snipsnap::workload::cnn;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    banner("§IV-D", "exploration speed vs DiMO-like iterative baseline (CNNs)");
    let arch = presets::arch1();
    // CNN im2col dims are divisor-rich; give the one-shot search enough
    // protos that truncation doesn't concede quality to DiMO's restarts.
    let snip_cfg = SearchConfig {
        metric: Metric::Energy,
        mode: FormatMode::Fixed,
        mapper: MapperConfig {
            max_candidates: 2_000,
            min_spatial_utilization: 0.0,
            ..Default::default()
        },
        ..Default::default()
    };
    let dimo_cfg = DimoConfig::default();

    let mut t = Table::new(vec![
        "network", "SnipSnap evals", "DiMO evals", "speedup (evals)",
        "SnipSnap (s)", "DiMO (s)", "SnipSnap energy", "DiMO energy",
    ]);
    let mut speedups = Vec::new();
    let mut records = Vec::new();
    for w in cnn::all_cnns() {
        let snip = cosearch_workload(&arch, &w, &snip_cfg);
        let dimo = dimo_workload(&arch, &w, &dimo_cfg, Metric::Energy);
        // Both workflows run on OUR fast evaluator, so wall-clock no longer
        // reflects the methodology gap the paper measured against the real
        // DiMO tool; cost-model evaluations are the deterministic
        // workflow-effort proxy (DiMO re-evaluates 6^L order combos per
        // candidate move across restarts).
        let sp = dimo.evaluations as f64 / snip.evaluations as f64;
        speedups.push(sp);
        t.add_row(vec![
            w.name.clone(),
            snip.evaluations.to_string(),
            dimo.evaluations.to_string(),
            fmt_x(sp),
            format!("{:.2}", snip.elapsed.as_secs_f64()),
            format!("{:.2}", dimo.elapsed.as_secs_f64()),
            fmt_f(snip.total_energy_pj()),
            fmt_f(dimo.total_energy_pj()),
        ]);
        records.push(Json::obj(vec![
            ("network", Json::str(&w.name)),
            ("speedup", Json::num(sp)),
            ("snip_energy", Json::num(snip.total_energy_pj())),
            ("dimo_energy", Json::num(dimo.total_energy_pj())),
        ]));
        assert!(
            snip.total_energy_pj() <= dimo.total_energy_pj() * 1.20,
            "{}: quality regression ({} vs {})",
            w.name,
            snip.total_energy_pj(),
            dimo.total_energy_pj()
        );
    }
    println!("{}", t.render());
    let g = geomean(&speedups);
    println!(
        "geomean workflow-effort speedup: {} (paper wall-clock vs real DiMO: 19.4x / 19.7x / 23.8x)",
        fmt_x(g)
    );
    assert!(g > 1.0, "speedup too small: {g}");
    write_record(
        "dimo_cnn_speed",
        t0.elapsed().as_secs_f64(),
        Json::obj(vec![("geomean_speedup", Json::num(g)), ("rows", Json::arr(records))]),
    );
    println!("dimo_cnn OK");
}
