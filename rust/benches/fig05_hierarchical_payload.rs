//! Fig. 5 — hierarchical vs flat bitmap payload.
//!
//! The paper's worked example: a matrix compressed with the traditional
//! one-level B format vs a three-level `B(M)-B(N1)-B(N2)` enabled by the
//! hierarchical encoding, reporting the metadata/payload reduction
//! (paper: 16.7% on the 3x6 example).  We reproduce the 3x6 example
//! exactly and sweep block-sparse 4096-class matrices to show where the
//! multi-level format pays off.

use snipsnap::format::{named, Axis, Format, Level, Prim};
use snipsnap::sparsity::analyzer::analytical_cost;
use snipsnap::sparsity::exact::{exact_cost, DenseMask};
use snipsnap::sparsity::SparsityPattern;
use snipsnap::util::bench::{banner, write_record};
use snipsnap::util::json::Json;
use snipsnap::util::table::{fmt_f, fmt_pct, Table};
use std::time::Instant;

fn three_level_b(rows: u64, n1: u64, n2: u64) -> Format {
    Format::new(
        vec![
            Level { prim: Prim::B, axis: Axis::Row, size: rows },
            Level { prim: Prim::B, axis: Axis::Col, size: n1 },
            Level { prim: Prim::B, axis: Axis::Col, size: n2 },
        ],
        rows,
        n1 * n2,
    )
    .expect("three-level B")
}

fn main() {
    let t0 = Instant::now();
    banner("Fig. 5", "hierarchical three-level B vs one-level B payload");

    // --- The paper's 3x6 example -----------------------------------------
    // Non-zeros confined to the first column group: a whole group bit
    // replaces six element bits.
    let mask = DenseMask::from_fn(3, 6, |r, c| r < 2 && c < 2 && (r + c) % 2 == 0);
    let flat = exact_cost(&named::bitmap(3, 6), &mask, 8);
    let hier = exact_cost(&three_level_b(3, 3, 2), &mask, 8);
    let total_red = 1.0 - hier.total_bits() / flat.total_bits();
    let meta_red = 1.0 - hier.metadata_bits / flat.metadata_bits;

    let mut t = Table::new(vec!["format", "metadata bits", "payload bits", "total"])
        .with_title("3x6 worked example (8-bit data)");
    t.add_row(vec![
        "B (one level)".to_string(),
        fmt_f(flat.metadata_bits),
        fmt_f(flat.payload_bits),
        fmt_f(flat.total_bits()),
    ]);
    t.add_row(vec![
        "B(M)-B(N1)-B(N2)".to_string(),
        fmt_f(hier.metadata_bits),
        fmt_f(hier.payload_bits),
        fmt_f(hier.total_bits()),
    ]);
    println!("{}", t.render());
    println!(
        "metadata reduction {} | total reduction {} (paper example: 16.7%)",
        fmt_pct(meta_red),
        fmt_pct(total_red)
    );
    assert!(hier.total_bits() < flat.total_bits());

    // --- Sweep: block-sparse square matrices ------------------------------
    let mut s = Table::new(vec![
        "size", "block", "block density", "flat B bits", "3-level B bits", "reduction",
    ])
    .with_title("Analytical sweep (16-bit data)");
    let mut rows_out = Vec::new();
    for (size, block, bd) in [
        (1024u64, 32u64, 0.10),
        (1024, 32, 0.25),
        (4096, 64, 0.10),
        (4096, 64, 0.25),
        (4096, 128, 0.10),
    ] {
        let pattern = SparsityPattern::Block { br: block, bc: block, block_density: bd };
        let flat = analytical_cost(&named::bitmap(size, size), &pattern, 16);
        let hier = analytical_cost(&three_level_b(size, size / block, block), &pattern, 16);
        let red = 1.0 - hier.total_bits() / flat.total_bits();
        s.add_row(vec![
            format!("{size}"),
            format!("{block}"),
            format!("{bd}"),
            fmt_f(flat.total_bits()),
            fmt_f(hier.total_bits()),
            fmt_pct(red),
        ]);
        rows_out.push(Json::obj(vec![
            ("size", Json::num(size as f64)),
            ("block", Json::num(block as f64)),
            ("block_density", Json::num(bd)),
            ("reduction", Json::num(red)),
        ]));
        assert!(
            hier.total_bits() < flat.total_bits(),
            "hierarchical must win on block sparsity at {size}/{block}/{bd}"
        );
    }
    println!("{}", s.render());

    write_record(
        "fig05_hierarchical_payload",
        t0.elapsed().as_secs_f64(),
        Json::obj(vec![
            ("example_total_reduction", Json::num(total_red)),
            ("example_metadata_reduction", Json::num(meta_red)),
            ("sweep", Json::arr(rows_out)),
        ]),
    );
    println!("fig05 OK");
}
