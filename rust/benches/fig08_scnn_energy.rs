//! Fig. 8 — energy-model validation against SCNN.
//!
//! Models the SCNN architecture and compares relative energy (normalized
//! to the dense run) against the published reference series for sparse
//! activations (SA), sparse weights (SW) and both (SA&SW).  The paper
//! reports a mean relative error of 4.33%.  Reference series are plot
//! reconstructions — see `arch::published` and DESIGN.md §5.

use snipsnap::arch::validation::scnn_energy_validation;
use snipsnap::util::bench::{banner, time_once, write_record};
use snipsnap::util::json::Json;
use snipsnap::util::table::{fmt_f, fmt_pct, Table};
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    banner("Fig. 8", "SCNN energy validation (SA / SW / SA&SW)");
    let ((mre, rows), secs) = time_once(scnn_energy_validation);
    let mut t = Table::new(vec!["layer", "case", "reported", "modeled", "rel err"]);
    let mut records = Vec::new();
    for r in &rows {
        t.add_row(vec![
            r.layer.to_string(),
            r.case.to_string(),
            fmt_f(r.reported),
            fmt_f(r.modeled),
            fmt_pct(r.rel_err),
        ]);
        records.push(Json::obj(vec![
            ("layer", Json::str(r.layer)),
            ("case", Json::str(r.case)),
            ("reported", Json::num(r.reported)),
            ("modeled", Json::num(r.modeled)),
            ("rel_err", Json::num(r.rel_err)),
        ]));
    }
    println!("{}", t.render());
    println!(
        "mean relative error: {} (paper: 4.33%) — modeled in {secs:.1}s",
        fmt_pct(mre)
    );
    assert!(mre < 0.10, "MRE {mre}");
    write_record(
        "fig08_scnn_energy",
        t0.elapsed().as_secs_f64(),
        Json::obj(vec![("mre", Json::num(mre)), ("rows", Json::arr(records))]),
    );
    println!("fig08 OK");
}
