//! Table I — exploration speed: SnipSnap (Fixed / Search) vs the
//! Sparseloop-style stepwise workflow, five LLMs x four architectures,
//! both densities 0.75 (the paper's setup).
//!
//! Absolute speedups differ from the paper (which timed the real
//! Sparseloop artifact under a 20-minute-per-MatMul budget); the claim
//! reproduced here is the *shape*: the progressive workflow explores the
//! same candidate space one to two orders of magnitude faster, and
//! enabling format search costs extra but stays far ahead of stepwise.

use snipsnap::arch::presets;
use snipsnap::baselines::sparseloop_like::stepwise_workload;
use snipsnap::cost::Metric;
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::search::{cosearch_workload, FormatMode, SearchConfig};
use snipsnap::util::bench::{banner, write_record};
use snipsnap::util::json::Json;
use snipsnap::util::stats::geomean;
use snipsnap::util::table::{fmt_x, Table};
use snipsnap::workload::llm;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    banner("Table I", "exploration speed vs Sparseloop-like stepwise workflow");
    // Shared candidate space for a fair workflow comparison.
    let mapper = MapperConfig { max_candidates: 300, ..Default::default() };
    let workloads: Vec<_> = llm::table1_llms()
        .into_iter()
        .map(|w| llm::with_uniform_density(w, 0.75, 0.75).expect("densities in range"))
        .collect();
    let archs = presets::all_table2();

    let mut t = Table::new(vec![
        "arch", "model", "fixed (s)", "speedup", "search (s)", "speedup", "stepwise (s)",
        "cache hit%",
    ]);
    let mut fixed_speedups = Vec::new();
    let mut search_speedups = Vec::new();
    let mut records = Vec::new();
    let mut cache_totals = snipsnap::cost::CacheStats::default();
    for arch in &archs {
        for w in &workloads {
            let fixed = cosearch_workload(
                arch,
                w,
                &SearchConfig {
                    metric: Metric::Energy,
                    mode: FormatMode::Fixed,
                    mapper: mapper.clone(),
                    ..Default::default()
                },
            );
            let search = cosearch_workload(
                arch,
                w,
                &SearchConfig {
                    metric: Metric::Energy,
                    mode: FormatMode::Search,
                    mapper: mapper.clone(),
                    ..Default::default()
                },
            );
            let stepwise = stepwise_workload(arch, w, &mapper, Metric::Energy);
            let t_f = fixed.elapsed.as_secs_f64();
            let t_s = search.elapsed.as_secs_f64();
            let t_sl = stepwise.elapsed.as_secs_f64();
            let sp_f = t_sl / t_f;
            let sp_s = t_sl / t_s;
            fixed_speedups.push(sp_f);
            search_speedups.push(sp_s);
            cache_totals.merge(fixed.cache);
            cache_totals.merge(search.cache);
            cache_totals.merge(stepwise.cache);
            t.add_row(vec![
                arch.name.split(' ').take(2).collect::<Vec<_>>().join(" "),
                w.name.clone(),
                format!("{t_f:.2}"),
                fmt_x(sp_f),
                format!("{t_s:.2}"),
                fmt_x(sp_s),
                format!("{t_sl:.2}"),
                format!("{:.1}", 100.0 * search.cache.hit_rate()),
            ]);
            records.push(Json::obj(vec![
                ("arch", Json::str(&arch.name)),
                ("model", Json::str(&w.name)),
                ("fixed_s", Json::num(t_f)),
                ("search_s", Json::num(t_s)),
                ("stepwise_s", Json::num(t_sl)),
                ("fixed_speedup", Json::num(sp_f)),
                ("search_speedup", Json::num(sp_s)),
                ("search_cache_hits", Json::num(search.cache.hits as f64)),
                ("search_cache_misses", Json::num(search.cache.misses as f64)),
            ]));
            // Quality parity on the shared space.
            let q = fixed.total_energy_pj() / stepwise.total_energy_pj();
            assert!(q < 1.25, "{} {}: quality ratio {q}", arch.name, w.name);
        }
    }
    println!("{}", t.render());
    let gf = geomean(&fixed_speedups);
    let gs = geomean(&search_speedups);
    println!(
        "geomean speedup over stepwise: Fixed {} | Search {} (paper vs real Sparseloop: 2248.3x / 231.5x)",
        fmt_x(gf),
        fmt_x(gs)
    );
    assert!(gf > 3.0, "fixed-mode speedup too small: {gf}");
    // Search mode adds the format-engine cost on top; the paper's Search
    // column stays 231x ahead only because the real Sparseloop artifact
    // is itself ~2000x slower than our stepwise reimplementation.  The
    // reproducible claim is: Search costs a bounded multiple of Fixed
    // while exploring a strictly larger (format x dataflow) space.
    assert!(gs > 0.05, "search mode unreasonably slow vs stepwise: {gs}");
    println!(
        "access-counts cache (all runs): {} hits / {} misses ({:.1}% hit rate)",
        cache_totals.hits,
        cache_totals.misses,
        100.0 * cache_totals.hit_rate()
    );
    write_record(
        "table1_speed",
        t0.elapsed().as_secs_f64(),
        Json::obj(vec![
            ("geomean_fixed_speedup", Json::num(gf)),
            ("geomean_search_speedup", Json::num(gs)),
            ("cache_hits", Json::num(cache_totals.hits as f64)),
            ("cache_misses", Json::num(cache_totals.misses as f64)),
            ("rows", Json::arr(records)),
        ]),
    );
    println!("table1 OK");
}
