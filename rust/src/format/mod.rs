//! Hierarchical compression-format encoding (paper §III-B).
//!
//! A format over an `R x C` tensor is an ordered sequence of *levels*
//! (high → low).  Each level names a compression primitive and a
//! (sub)dimension axis; the *compression pattern* subspace fixes the
//! primitive/axis sequence, the *dimension allocation* subspace assigns a
//! concrete size (fanout) to every level.  Together they reproduce all the
//! classic formats (Bitmap, RLE, CSR, CSC, COO, CSB, …) and open the
//! multi-level space the paper explores (e.g. Fig. 5's `B(M)-B(N)-B(N)`).
//!
//! Semantics used throughout the analyzer (see DESIGN.md §4.1): reshape
//! the tensor into the level axes, outermost first.  A *node* at level
//! boundary `i` is a fixing of the first `i` axes; its *region* is the
//! remaining sub-tensor.  A node is **non-empty** if its region holds any
//! non-zero.  A node is **active** (materialized) if every compressed
//! ancestor level kept it: `None` levels materialize all children,
//! compressing levels only non-empty ones.

pub mod named;
pub mod quant;
pub mod space;

use crate::util::mathx::ceil_log2;
use std::fmt;

/// Tensor axis a level subdivides. The paper writes `M` for rows and `N`
/// (or `K`) for columns of the operand being compressed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Axis {
    Row,
    Col,
}

impl Axis {
    pub fn paper_name(&self) -> &'static str {
        match self {
            Axis::Row => "M",
            Axis::Col => "N",
        }
    }
}

/// Compression primitives (paper Fig. 4a).
#[derive(Clone, Debug, PartialEq)]
pub enum Prim {
    /// Uncompressed / flattened dimension: no metadata, children dense.
    None,
    /// Bitmap: one presence bit per child slot of every active parent.
    B,
    /// Coordinate payload: one coordinate per non-empty child.
    Cp,
    /// Run-length encoding: one run length per non-empty child plus a
    /// terminator per active parent.
    Rle,
    /// Uncompressed offset pairs (CSR-style pointer array): `fanout + 1`
    /// offsets per active parent.
    Uop,
    /// User-defined primitive with a linear metadata cost model:
    /// `bits = parents * bits_per_parent + children * bits_per_child`.
    Custom {
        name: &'static str,
        bits_per_parent: f64,
        bits_per_child: f64,
    },
}

impl Prim {
    /// Does this level prune empty children (i.e. compress)?
    pub fn compresses(&self) -> bool {
        !matches!(self, Prim::None)
    }

    pub fn code(&self) -> &'static str {
        match self {
            Prim::None => "None",
            Prim::B => "B",
            Prim::Cp => "CP",
            Prim::Rle => "RLE",
            Prim::Uop => "UOP",
            Prim::Custom { name, .. } => name,
        }
    }

    /// Kind id shared with the XLA scorer (python/compile/model.py).
    pub fn kind_id(&self) -> i32 {
        match self {
            Prim::None => 0,
            Prim::B => 1,
            Prim::Cp => 2,
            Prim::Rle => 3,
            Prim::Uop => 4,
            Prim::Custom { .. } => 5,
        }
    }
}

/// One level of a *compression pattern* (no size assigned yet).
#[derive(Clone, Debug, PartialEq)]
pub struct PatternLevel {
    pub prim: Prim,
    pub axis: Axis,
}

/// A compression pattern: ordered primitive/axis sequence, high → low
/// (paper Definition 1).
#[derive(Clone, Debug, PartialEq)]
pub struct CompPat {
    pub levels: Vec<PatternLevel>,
}

impl CompPat {
    pub fn new(levels: Vec<(Prim, Axis)>) -> Self {
        CompPat {
            levels: levels
                .into_iter()
                .map(|(prim, axis)| PatternLevel { prim, axis })
                .collect(),
        }
    }

    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Number of levels that actually compress (used by the complexity
    /// penalty γ^level).
    pub fn compressing_depth(&self) -> usize {
        self.levels.iter().filter(|l| l.prim.compresses()).count()
    }
}

impl fmt::Display for CompPat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, l) in self.levels.iter().enumerate() {
            if i > 0 {
                write!(f, "-")?;
            }
            write!(f, "{}({})", l.prim.code(), l.axis.paper_name())?;
        }
        Ok(())
    }
}

/// A fully-allocated level: primitive + axis + fanout.
#[derive(Clone, Debug, PartialEq)]
pub struct Level {
    pub prim: Prim,
    pub axis: Axis,
    /// Children per node (the size of this subdimension).
    pub size: u64,
}

/// A complete compression format: pattern + dimension allocation over a
/// concrete tensor shape (paper Definition 2).
#[derive(Clone, Debug, PartialEq)]
pub struct Format {
    pub levels: Vec<Level>,
    pub rows: u64,
    pub cols: u64,
}

/// Geometry of one level boundary, derived once per format.
#[derive(Clone, Debug, PartialEq)]
pub struct BoundaryGeom {
    /// Total nodes at this boundary (all fixings of the first i axes).
    pub nodes: f64,
    /// Remaining region shape under one node: rows x cols.
    pub region_rows: u64,
    pub region_cols: u64,
}

/// Structural validation errors for [`Format::new`] / [`Format::validate`].
#[derive(Debug, PartialEq)]
pub enum FormatError {
    /// The sizes of the levels on one axis do not multiply to the tensor
    /// extent on that axis.
    AxisMismatch { axis: Axis, got: u64, want: u64 },
    /// A level was given a zero fanout.
    ZeroSize { index: usize },
    /// The format has no levels at all.
    Empty,
}

impl fmt::Display for FormatError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FormatError::AxisMismatch { axis, got, want } => {
                write!(f, "level sizes over {axis:?} multiply to {got}, tensor has {want}")
            }
            FormatError::ZeroSize { index } => write!(f, "level {index} has size 0"),
            FormatError::Empty => write!(f, "format must have at least one level"),
        }
    }
}

impl std::error::Error for FormatError {}

impl Format {
    pub fn new(levels: Vec<Level>, rows: u64, cols: u64) -> Result<Self, FormatError> {
        let f = Format { levels, rows, cols };
        f.validate()?;
        Ok(f)
    }

    pub fn validate(&self) -> Result<(), FormatError> {
        if self.levels.is_empty() {
            return Err(FormatError::Empty);
        }
        for (index, l) in self.levels.iter().enumerate() {
            if l.size == 0 {
                return Err(FormatError::ZeroSize { index });
            }
        }
        for axis in [Axis::Row, Axis::Col] {
            let got: u64 = self
                .levels
                .iter()
                .filter(|l| l.axis == axis)
                .map(|l| l.size)
                .product();
            let want = match axis {
                Axis::Row => self.rows,
                Axis::Col => self.cols,
            };
            if got != want {
                return Err(FormatError::AxisMismatch { axis, got, want });
            }
        }
        Ok(())
    }

    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    pub fn compressing_depth(&self) -> usize {
        self.levels.iter().filter(|l| l.prim.compresses()).count()
    }

    pub fn pattern(&self) -> CompPat {
        CompPat {
            levels: self
                .levels
                .iter()
                .map(|l| PatternLevel { prim: l.prim.clone(), axis: l.axis })
                .collect(),
        }
    }

    /// Boundary geometries: index 0 is the root (whole tensor), index i is
    /// after fixing levels 1..=i.  Length = depth + 1.
    pub fn boundaries(&self) -> Vec<BoundaryGeom> {
        let mut out = Vec::with_capacity(self.levels.len() + 1);
        let mut nodes = 1.0;
        let mut rr = self.rows;
        let mut rc = self.cols;
        out.push(BoundaryGeom { nodes, region_rows: rr, region_cols: rc });
        for l in &self.levels {
            nodes *= l.size as f64;
            match l.axis {
                Axis::Row => rr /= l.size,
                Axis::Col => rc /= l.size,
            }
            out.push(BoundaryGeom { nodes, region_rows: rr, region_cols: rc });
        }
        out
    }

    /// Metadata width in bits for coordinates/runs/offsets at level i.
    pub fn level_width_bits(&self, i: usize) -> u32 {
        let l = &self.levels[i];
        match l.prim {
            // Runs can span the whole fanout, offsets index up to the full
            // region payload under the parent; coordinates index children.
            Prim::Uop => {
                let b = self.boundaries();
                let region = b[i].region_rows as u128 * b[i].region_cols as u128;
                ceil_log2((region as u64).saturating_add(1).max(2))
            }
            Prim::Rle => ceil_log2(l.size + 1),
            _ => ceil_log2(l.size.max(2)),
        }
    }
}

impl fmt::Display for Format {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, l) in self.levels.iter().enumerate() {
            if i > 0 {
                write!(f, "-")?;
            }
            write!(f, "{}({},{})", l.prim.code(), l.axis.paper_name(), l.size)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lv(prim: Prim, axis: Axis, size: u64) -> Level {
        Level { prim, axis, size }
    }

    #[test]
    fn csc_structure_of_fig4() {
        // CSC over M x N (M=3, N=6): UOP(N)-CP(M).
        let f = Format::new(
            vec![lv(Prim::Uop, Axis::Col, 6), lv(Prim::Cp, Axis::Row, 3)],
            3,
            6,
        )
        .unwrap();
        assert_eq!(f.to_string(), "UOP(N,6)-CP(M,3)");
        let b = f.boundaries();
        assert_eq!(b.len(), 3);
        assert_eq!(b[0].nodes, 1.0);
        assert_eq!((b[0].region_rows, b[0].region_cols), (3, 6));
        assert_eq!(b[1].nodes, 6.0);
        assert_eq!((b[1].region_rows, b[1].region_cols), (3, 1));
        assert_eq!(b[2].nodes, 18.0);
        assert_eq!((b[2].region_rows, b[2].region_cols), (1, 1));
    }

    #[test]
    fn validate_rejects_bad_allocation() {
        let err = Format::new(
            vec![lv(Prim::B, Axis::Row, 2), lv(Prim::B, Axis::Col, 6)],
            3,
            6,
        )
        .unwrap_err();
        assert_eq!(err, FormatError::AxisMismatch { axis: Axis::Row, got: 2, want: 3 });
    }

    #[test]
    fn validate_rejects_empty_and_zero() {
        assert_eq!(Format::new(vec![], 2, 2).unwrap_err(), FormatError::Empty);
        let err = Format::new(vec![lv(Prim::B, Axis::Row, 0)], 0, 1);
        assert!(err.is_err());
    }

    #[test]
    fn multi_level_split_allocation() {
        // UOP(N1,3)-CP(M,3)-CP(N2,2) over 3 x 6 — the paper's §III-B example.
        let f = Format::new(
            vec![
                lv(Prim::Uop, Axis::Col, 3),
                lv(Prim::Cp, Axis::Row, 3),
                lv(Prim::Cp, Axis::Col, 2),
            ],
            3,
            6,
        )
        .unwrap();
        assert_eq!(f.depth(), 3);
        let b = f.boundaries();
        assert_eq!((b[1].region_rows, b[1].region_cols), (3, 2));
        assert_eq!((b[3].region_rows, b[3].region_cols), (1, 1));
    }

    #[test]
    fn widths() {
        let f = Format::new(
            vec![lv(Prim::Cp, Axis::Col, 1024), lv(Prim::Rle, Axis::Row, 16)],
            16,
            1024,
        )
        .unwrap();
        assert_eq!(f.level_width_bits(0), 10);
        // RLE run can be 0..=16 -> 17 values -> 5 bits.
        assert_eq!(f.level_width_bits(1), 5);
    }

    #[test]
    fn compressing_depth_ignores_none() {
        let f = Format::new(
            vec![
                lv(Prim::B, Axis::Row, 4),
                lv(Prim::None, Axis::Col, 8),
                lv(Prim::B, Axis::Col, 2),
            ],
            4,
            16,
        )
        .unwrap();
        assert_eq!(f.depth(), 3);
        assert_eq!(f.compressing_depth(), 2);
    }

    #[test]
    fn display_pattern() {
        let p = CompPat::new(vec![(Prim::Uop, Axis::Col), (Prim::Cp, Axis::Row)]);
        assert_eq!(p.to_string(), "UOP(N)-CP(M)");
        assert_eq!(p.compressing_depth(), 2);
    }
}
