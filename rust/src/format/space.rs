//! The compression-format exploration space (paper Definitions 1 & 2):
//! enumeration of compression patterns and of dimension allocations.
//!
//! The full space is huge (the paper reports >400k candidates for a 4096²
//! tensor at depth ≤ 4); the adaptive engine prunes it with the
//! complexity-based penalty, but the raw enumerators here are also used
//! by the Fig. 6 ablation to measure the unpruned space.

use super::{Axis, CompPat, Format, Level, PatternLevel, Prim};
use crate::util::mathx::ordered_factorizations;

/// Which primitives pattern enumeration draws from.
pub const SEARCH_PRIMS: [Prim; 5] = [Prim::None, Prim::B, Prim::Cp, Prim::Rle, Prim::Uop];

/// Configuration of the pattern space.
#[derive(Clone, Debug)]
pub struct SpaceConfig {
    /// Maximum number of levels (paper uses small depths; penalty keeps
    /// selected formats at 2-3).
    pub max_depth: usize,
    /// Maximum number of levels per axis (subdimension splits).
    pub max_splits_per_axis: usize,
    /// Disallow size-1 levels in allocations (degenerate duplicates).
    pub forbid_unit_levels: bool,
}

impl Default for SpaceConfig {
    fn default() -> Self {
        SpaceConfig { max_depth: 4, max_splits_per_axis: 2, forbid_unit_levels: true }
    }
}

/// Is a pattern structurally sensible for a 2-D tensor?
///
/// Rules: both axes must appear (so allocation can cover the tensor);
/// at least one level must compress; `UOP` needs a level *below* it to
/// point at (it is a pointer array into child payloads); two consecutive
/// `None` levels on the same axis are a duplicate of one.
pub fn pattern_is_valid(pat: &CompPat) -> bool {
    let n = pat.levels.len();
    if n == 0 {
        return false;
    }
    let has_row = pat.levels.iter().any(|l| l.axis == Axis::Row);
    let has_col = pat.levels.iter().any(|l| l.axis == Axis::Col);
    if !has_row || !has_col {
        return false;
    }
    if pat.compressing_depth() == 0 {
        return false;
    }
    if matches!(pat.levels[n - 1].prim, Prim::Uop) {
        return false;
    }
    for w in pat.levels.windows(2) {
        if w[0].prim == Prim::None && w[1].prim == Prim::None && w[0].axis == w[1].axis {
            return false;
        }
    }
    true
}

/// Enumerate all valid compression patterns up to the configured depth.
pub fn enumerate_patterns(cfg: &SpaceConfig) -> Vec<CompPat> {
    let mut out = Vec::new();
    let mut stack: Vec<PatternLevel> = Vec::new();
    fn rec(
        cfg: &SpaceConfig,
        stack: &mut Vec<PatternLevel>,
        out: &mut Vec<CompPat>,
    ) {
        if !stack.is_empty() {
            let pat = CompPat { levels: stack.clone() };
            if pattern_is_valid(&pat) {
                out.push(pat);
            }
        }
        if stack.len() == cfg.max_depth {
            return;
        }
        for prim in SEARCH_PRIMS.iter() {
            for axis in [Axis::Row, Axis::Col] {
                let splits = stack.iter().filter(|l| l.axis == axis).count();
                if splits >= cfg.max_splits_per_axis {
                    continue;
                }
                stack.push(PatternLevel { prim: prim.clone(), axis });
                rec(cfg, stack, out);
                stack.pop();
            }
        }
    }
    rec(cfg, &mut stack, &mut out);
    out
}

/// Enumerate every dimension allocation of `pat` over an `rows x cols`
/// tensor (paper Definition 2): all ordered factorizations of each axis
/// extent across that axis's levels.
pub fn enumerate_allocations(
    pat: &CompPat,
    rows: u64,
    cols: u64,
    cfg: &SpaceConfig,
) -> Vec<Format> {
    let row_slots: Vec<usize> = pat
        .levels
        .iter()
        .enumerate()
        .filter(|(_, l)| l.axis == Axis::Row)
        .map(|(i, _)| i)
        .collect();
    let col_slots: Vec<usize> = pat
        .levels
        .iter()
        .enumerate()
        .filter(|(_, l)| l.axis == Axis::Col)
        .map(|(i, _)| i)
        .collect();
    if row_slots.is_empty() || col_slots.is_empty() {
        return Vec::new();
    }
    let row_allocs = ordered_factorizations(rows, row_slots.len());
    let col_allocs = ordered_factorizations(cols, col_slots.len());
    // Degenerate axes (extent 1, e.g. single-token decode activations)
    // can only use unit levels; allow them there.
    let ok = |alloc: &[u64], extent: u64| {
        !cfg.forbid_unit_levels || extent == 1 || alloc.iter().all(|&s| s > 1)
    };

    let mut out = Vec::new();
    for ra in row_allocs.iter().filter(|a| ok(a, rows)) {
        for ca in col_allocs.iter().filter(|a| ok(a, cols)) {
            let mut levels: Vec<Level> = pat
                .levels
                .iter()
                .map(|l| Level { prim: l.prim.clone(), axis: l.axis, size: 0 })
                .collect();
            for (slot, &size) in row_slots.iter().zip(ra) {
                levels[*slot].size = size;
            }
            for (slot, &size) in col_slots.iter().zip(ca) {
                levels[*slot].size = size;
            }
            if let Ok(f) = Format::new(levels, rows, cols) {
                out.push(f);
            }
        }
    }
    out
}

/// Size of the full (pattern x allocation) space without building it —
/// used by the Fig. 6 ablation to report the unpruned candidate count.
pub fn full_space_size(rows: u64, cols: u64, cfg: &SpaceConfig) -> u64 {
    let mut total = 0u64;
    for pat in enumerate_patterns(cfg) {
        let kr = pat.levels.iter().filter(|l| l.axis == Axis::Row).count();
        let kc = pat.levels.iter().filter(|l| l.axis == Axis::Col).count();
        let count = |n: u64, k: usize| -> u64 {
            ordered_factorizations(n, k)
                .iter()
                .filter(|a| !cfg.forbid_unit_levels || a.iter().all(|&s| s > 1))
                .count() as u64
        };
        total += count(rows, kr) * count(cols, kc);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn patterns_all_valid_and_unique() {
        let cfg = SpaceConfig::default();
        let pats = enumerate_patterns(&cfg);
        assert!(!pats.is_empty());
        for p in &pats {
            assert!(pattern_is_valid(p), "{p}");
            assert!(p.depth() <= cfg.max_depth);
        }
        // Uniqueness.
        let mut seen = std::collections::HashSet::new();
        for p in &pats {
            assert!(seen.insert(p.to_string()), "duplicate {p}");
        }
    }

    #[test]
    fn pattern_validity_rules() {
        // Missing Col axis.
        assert!(!pattern_is_valid(&CompPat::new(vec![(Prim::B, Axis::Row)])));
        // All-None.
        assert!(!pattern_is_valid(&CompPat::new(vec![
            (Prim::None, Axis::Row),
            (Prim::None, Axis::Col)
        ])));
        // UOP at leaf.
        assert!(!pattern_is_valid(&CompPat::new(vec![
            (Prim::Cp, Axis::Row),
            (Prim::Uop, Axis::Col)
        ])));
        // CSR shape is valid.
        assert!(pattern_is_valid(&CompPat::new(vec![
            (Prim::Uop, Axis::Row),
            (Prim::Cp, Axis::Col)
        ])));
    }

    #[test]
    fn allocations_cover_tensor() {
        let pat = CompPat::new(vec![
            (Prim::B, Axis::Row),
            (Prim::B, Axis::Col),
            (Prim::B, Axis::Col),
        ]);
        let cfg = SpaceConfig::default();
        let allocs = enumerate_allocations(&pat, 8, 16, &cfg);
        assert!(!allocs.is_empty());
        for f in &allocs {
            f.validate().unwrap();
            assert_eq!(f.depth(), 3);
        }
        // Col split into two >1 factors of 16: (2,8),(4,4),(8,2) = 3; row 1 way.
        assert_eq!(allocs.len(), 3);
    }

    #[test]
    fn unit_levels_filtered() {
        // Two Col levels over cols=4: with unit levels forbidden only the
        // (2,2) split survives; without, (1,4)/(2,2)/(4,1) all appear.
        let pat = CompPat::new(vec![
            (Prim::B, Axis::Row),
            (Prim::B, Axis::Col),
            (Prim::B, Axis::Col),
        ]);
        let cfg = SpaceConfig { forbid_unit_levels: true, ..Default::default() };
        let allocs = enumerate_allocations(&pat, 4, 4, &cfg);
        assert_eq!(allocs.len(), 1);
        let cfg2 = SpaceConfig { forbid_unit_levels: false, ..Default::default() };
        assert_eq!(enumerate_allocations(&pat, 4, 4, &cfg2).len(), 3);
    }

    #[test]
    fn space_is_large_for_4096_squared() {
        // The paper reports >400k raw candidates at depth <= 4 for 4096².
        let cfg = SpaceConfig::default();
        let size = full_space_size(4096, 4096, &cfg);
        assert!(size > 100_000, "space size {size}");
    }
}
