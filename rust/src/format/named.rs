//! The classic named formats as instances of the hierarchical encoding
//! (paper §II-B3, Fig. 4b and the four baselines of §IV-A2).

use super::{Axis, Format, Level, Prim};

fn lv(prim: Prim, axis: Axis, size: u64) -> Level {
    Level { prim, axis, size }
}

/// Flat bitmap over the whole tensor: `B(M)-B-less` — encoded as a single
/// bitmap level over rows then an uncompressed column level is *not* how a
/// bitmap works; the canonical one-level bitmap is a presence bit per
/// element: `None(M)-B(N)` (rows materialized, bit per element).
pub fn bitmap(rows: u64, cols: u64) -> Format {
    Format::new(
        vec![lv(Prim::None, Axis::Row, rows), lv(Prim::B, Axis::Col, cols)],
        rows,
        cols,
    )
    .expect("bitmap")
}

/// Row-major RLE over the flattened element stream (per-row runs):
/// `None(M)-RLE(N)`.
pub fn rle(rows: u64, cols: u64) -> Format {
    Format::new(
        vec![lv(Prim::None, Axis::Row, rows), lv(Prim::Rle, Axis::Col, cols)],
        rows,
        cols,
    )
    .expect("rle")
}

/// CSR: row-pointer array + column coordinates: `UOP(M)-CP(N)`.
pub fn csr(rows: u64, cols: u64) -> Format {
    Format::new(
        vec![lv(Prim::Uop, Axis::Row, rows), lv(Prim::Cp, Axis::Col, cols)],
        rows,
        cols,
    )
    .expect("csr")
}

/// CSC: column-pointer array + row coordinates: `UOP(N)-CP(M)` (Fig. 4b,
/// Flexagon).
pub fn csc(rows: u64, cols: u64) -> Format {
    Format::new(
        vec![lv(Prim::Uop, Axis::Col, cols), lv(Prim::Cp, Axis::Row, rows)],
        rows,
        cols,
    )
    .expect("csc")
}

/// COO: full coordinates per non-zero: `CP(M)-CP(N)`.
pub fn coo(rows: u64, cols: u64) -> Format {
    Format::new(
        vec![lv(Prim::Cp, Axis::Row, rows), lv(Prim::Cp, Axis::Col, cols)],
        rows,
        cols,
    )
    .expect("coo")
}

/// CSB (Compressed Sparse Block, Fig. 4b / Procrustes): coordinates of
/// non-empty `br x bc` blocks, bitmap within each block.
pub fn csb(rows: u64, cols: u64, br: u64, bc: u64) -> Format {
    assert!(rows % br == 0 && cols % bc == 0, "block must divide tensor");
    Format::new(
        vec![
            lv(Prim::Cp, Axis::Row, rows / br),
            lv(Prim::Cp, Axis::Col, cols / bc),
            lv(Prim::None, Axis::Row, br),
            lv(Prim::B, Axis::Col, bc),
        ],
        rows,
        cols,
    )
    .expect("csb")
}

/// The paper's Fig. 5 discovery: three-level bitmap `B(M)-B(N1)-B(N2)`
/// with the column dimension split as `cols = n1 * n2`.
pub fn b3(rows: u64, cols: u64, n1: u64) -> Format {
    assert!(cols % n1 == 0);
    Format::new(
        vec![
            lv(Prim::B, Axis::Row, rows),
            lv(Prim::B, Axis::Col, n1),
            lv(Prim::B, Axis::Col, cols / n1),
        ],
        rows,
        cols,
    )
    .expect("b3")
}

/// The paper's §IV-E BERT pick: `UOP(M)-B(N)` — CSR's CP replaced by a
/// lower-overhead bitmap.
pub fn uop_b(rows: u64, cols: u64) -> Format {
    Format::new(
        vec![lv(Prim::Uop, Axis::Row, rows), lv(Prim::B, Axis::Col, cols)],
        rows,
        cols,
    )
    .expect("uop_b")
}

/// Fully dense (no compression) — the degenerate reference point.
pub fn dense(rows: u64, cols: u64) -> Format {
    Format::new(
        vec![lv(Prim::None, Axis::Row, rows), lv(Prim::None, Axis::Col, cols)],
        rows,
        cols,
    )
    .expect("dense")
}

/// The four widely-used baselines of §IV-A2, by name.
pub fn baselines(rows: u64, cols: u64) -> Vec<(&'static str, Format)> {
    vec![
        ("Bitmap", bitmap(rows, cols)),
        ("RLE", rle(rows, cols)),
        ("CSR", csr(rows, cols)),
        ("COO", coo(rows, cols)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_formats_validate() {
        for (_, f) in baselines(64, 128) {
            f.validate().unwrap();
        }
        csb(64, 128, 8, 16).validate().unwrap();
        b3(64, 126, 7).validate().unwrap();
        uop_b(64, 128).validate().unwrap();
        dense(64, 128).validate().unwrap();
        csc(64, 128).validate().unwrap();
    }

    #[test]
    fn csr_display() {
        assert_eq!(csr(4, 8).to_string(), "UOP(M,4)-CP(N,8)");
        assert_eq!(coo(4, 8).to_string(), "CP(M,4)-CP(N,8)");
        assert_eq!(csc(4, 8).to_string(), "UOP(N,8)-CP(M,4)");
    }

    #[test]
    fn csb_block_geometry() {
        let f = csb(64, 64, 8, 8);
        let b = f.boundaries();
        // After the two CP levels: one 8x8 block region per node.
        assert_eq!((b[2].region_rows, b[2].region_cols), (8, 8));
        assert_eq!(b[2].nodes, 64.0);
    }

    #[test]
    #[should_panic(expected = "block must divide")]
    fn csb_rejects_nondividing_block() {
        csb(64, 64, 7, 8);
    }
}
