//! Payload-bitwidth (quantization) axis of the format space.
//!
//! Two retrieved papers (FPGA co-design for N:M sparse + quantized
//! inference, arxiv 2512.24713; flexible N:M via digital CiM, arxiv
//! 2504.14365) argue that sparsity pattern and precision must be
//! optimized *jointly* — the same "overlooked axis" thesis SnipSnap
//! makes for compression formats.  This module makes the payload
//! bitwidth of each operand a searchable dimension alongside the
//! hierarchical compression patterns: a [`BitwidthSpace`] per operand
//! class (weights, activations, KV-cache) is enumerated by the
//! co-search, and the adaptive engine re-runs format-structure search
//! per candidate bitwidth (quantizing the payload shifts the
//! metadata/payload trade-off, so the best pattern can change with
//! precision).
//!
//! Quantization flows through the existing compression-ratio seam: a
//! format scored at payload bitwidth `b` keeps its *dense* reference at
//! the accelerator word width, so `FormatCost::ratio()` carries both the
//! sparsity compression and the `b / data_bits` precision scaling into
//! tile legality, traffic costing and the branch-and-bound lower bound
//! unchanged.  With every space a singleton at the accelerator's
//! `data_bits` (the default), every f64 operation is literally the
//! pre-quantization one — the bit-identity contract pinned by
//! `rust/tests/quant_axis.rs`.

use std::fmt;

/// Maximum representable payload width (a generous bound; the point is
/// rejecting nonsense like 0 or 1000, not modeling exotic widths).
pub const MAX_BITS: u32 = 64;

/// Errors from [`BitwidthSpace`] construction/parsing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum QuantError {
    /// The set was empty (nothing to search).
    Empty,
    /// A width fell outside `1..=64`.
    OutOfRange(u32),
    /// A comma-separated entry failed to parse as an integer.
    Unparsable(String),
}

impl fmt::Display for QuantError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QuantError::Empty => write!(f, "bitwidth set is empty"),
            QuantError::OutOfRange(b) => {
                write!(f, "bitwidth {b} out of range (want 1..={MAX_BITS})")
            }
            QuantError::Unparsable(s) => write!(f, "cannot parse bitwidth '{s}'"),
        }
    }
}

impl std::error::Error for QuantError {}

/// A non-empty, sorted, deduplicated set of candidate payload bitwidths
/// for one operand class.  A singleton set pins the width; a multi-value
/// set hands the choice to the co-search.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitwidthSpace {
    values: Vec<u32>,
}

impl BitwidthSpace {
    /// Validate, sort and deduplicate a candidate set.
    pub fn new(mut values: Vec<u32>) -> Result<Self, QuantError> {
        if values.is_empty() {
            return Err(QuantError::Empty);
        }
        for &b in &values {
            if b == 0 || b > MAX_BITS {
                return Err(QuantError::OutOfRange(b));
            }
        }
        values.sort_unstable();
        values.dedup();
        Ok(BitwidthSpace { values })
    }

    /// The singleton space `{bits}`.  Panics on an out-of-range width —
    /// only used with widths the caller already validated (e.g. the
    /// accelerator's own `data_bits`).
    pub fn fixed(bits: u32) -> Self {
        BitwidthSpace::new(vec![bits]).expect("fixed bitwidth out of range")
    }

    /// Parse `"4"` or `"4,8,16"` (whitespace around entries tolerated).
    /// Trailing commas, empty entries and non-integers are errors.
    pub fn parse(s: &str) -> Result<Self, QuantError> {
        let mut values = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            let b: u32 = part
                .parse()
                .map_err(|_| QuantError::Unparsable(part.to_string()))?;
            values.push(b);
        }
        BitwidthSpace::new(values)
    }

    /// Candidate widths, ascending.
    pub fn values(&self) -> &[u32] {
        &self.values
    }

    /// True when there is nothing to search (one candidate).
    pub fn is_fixed(&self) -> bool {
        self.values.len() == 1
    }

    pub fn contains(&self, bits: u32) -> bool {
        self.values.contains(&bits)
    }
}

impl fmt::Display for BitwidthSpace {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, b) in self.values.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{b}")?;
        }
        Ok(())
    }
}

/// User-facing quantization configuration: one optional space per
/// operand class.  `None` means "not quantized" — the operand stays at
/// the accelerator's native `data_bits` and the search degenerates to
/// the pre-quantization flow bit for bit.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct QuantConfig {
    /// Weight-operand widths (non-KV weights).  CLI `--w-bits`.
    pub w_bits: Option<BitwidthSpace>,
    /// Activation (input-operand) widths.  CLI `--a-bits`.
    pub a_bits: Option<BitwidthSpace>,
    /// KV-cache widths: the weight-slot tensor of attention `qk`/`av`
    /// ops (K and V respectively).  CLI `--kv-bits`.
    pub kv_bits: Option<BitwidthSpace>,
}

impl QuantConfig {
    /// True when the axis is disabled entirely (the default).
    pub fn is_default(&self) -> bool {
        self.w_bits.is_none() && self.a_bits.is_none() && self.kv_bits.is_none()
    }

    /// Resolve against an accelerator word width: absent spaces become
    /// the singleton `{data_bits}`, so downstream code never branches on
    /// "quant enabled?" — disabled is just the one-point space.
    pub fn resolve(&self, data_bits: u32) -> QuantSpace {
        let or_native = |s: &Option<BitwidthSpace>| {
            s.clone().unwrap_or_else(|| BitwidthSpace::fixed(data_bits))
        };
        QuantSpace {
            act: or_native(&self.a_bits),
            weight: or_native(&self.w_bits),
            kv: or_native(&self.kv_bits),
        }
    }
}

/// A fully-resolved quantization space: every operand class has a
/// concrete non-empty candidate set.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QuantSpace {
    pub act: BitwidthSpace,
    pub weight: BitwidthSpace,
    pub kv: BitwidthSpace,
}

impl QuantSpace {
    /// The space governing an op's weight-slot tensor: KV ops (attention
    /// `qk`/`av`, whose "weights" are the K/V caches) draw from the KV
    /// space, everything else from the weight space.
    pub fn weight_space(&self, weight_is_kv: bool) -> &BitwidthSpace {
        if weight_is_kv {
            &self.kv
        } else {
            &self.weight
        }
    }

    /// Total (act, weight) combinations an op enumerates.
    pub fn combos(&self, weight_is_kv: bool) -> usize {
        self.act.values().len() * self.weight_space(weight_is_kv).values().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_single_and_set() {
        assert_eq!(BitwidthSpace::parse("4").unwrap().values(), &[4]);
        assert_eq!(BitwidthSpace::parse("16,4, 8").unwrap().values(), &[4, 8, 16]);
        assert_eq!(BitwidthSpace::parse("8,8,8").unwrap().values(), &[8]);
    }

    #[test]
    fn parse_rejects_bogus() {
        assert_eq!(BitwidthSpace::parse("0"), Err(QuantError::OutOfRange(0)));
        assert_eq!(
            BitwidthSpace::parse("3,"),
            Err(QuantError::Unparsable(String::new()))
        );
        assert_eq!(
            BitwidthSpace::parse("foo"),
            Err(QuantError::Unparsable("foo".into()))
        );
        assert_eq!(BitwidthSpace::parse("65"), Err(QuantError::OutOfRange(65)));
        assert!(BitwidthSpace::new(vec![]).is_err());
    }

    #[test]
    fn display_round_trips() {
        let s = BitwidthSpace::parse("16,4,8").unwrap();
        assert_eq!(s.to_string(), "4,8,16");
        assert_eq!(BitwidthSpace::parse(&s.to_string()).unwrap(), s);
    }

    #[test]
    fn default_config_resolves_to_native_singletons() {
        let q = QuantConfig::default();
        assert!(q.is_default());
        let sp = q.resolve(16);
        assert_eq!(sp.act.values(), &[16]);
        assert_eq!(sp.weight.values(), &[16]);
        assert_eq!(sp.kv.values(), &[16]);
        assert_eq!(sp.combos(false), 1);
        assert_eq!(sp.combos(true), 1);
    }

    #[test]
    fn kv_ops_draw_from_kv_space() {
        let q = QuantConfig {
            w_bits: Some(BitwidthSpace::parse("4,8").unwrap()),
            a_bits: None,
            kv_bits: Some(BitwidthSpace::fixed(8)),
        };
        assert!(!q.is_default());
        let sp = q.resolve(16);
        assert_eq!(sp.weight_space(false).values(), &[4, 8]);
        assert_eq!(sp.weight_space(true).values(), &[8]);
        assert_eq!(sp.combos(false), 2);
        assert_eq!(sp.combos(true), 1);
    }
}
