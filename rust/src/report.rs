//! `snipsnap report` — roll up the run artifacts under `results/`.
//!
//! The results layer emits four artifact shapes (docs/ARCHITECTURE.md
//! "Run artifacts"):
//! - `<bench>.jsonl` — append-mode bench history, one unified-schema
//!   record per line (`{bench, git_rev, ts_unix, wall_time_s, rows}`,
//!   written by [`crate::util::bench::write_record`]);
//! - `*.config.json` — run-config snapshots emitted by `snipsnap
//!   search`, replayable via `--config` ([`crate::config::snapshot`]);
//!   the scanner runs them through the real snapshot loader, so a
//!   snapshot the config layer could not replay fails the roll-up;
//! - `<sweep>.sweep.jsonl` — a sweep's merged roll-up
//!   ([`crate::driver::sweep`]): one serve-format response line per
//!   config, in plan order.  Rendered as per-config rows (id, totals,
//!   frontier size) plus a sweep summary line;
//! - legacy `*.json` — single-record files from the pre-JSONL harness,
//!   still readable so old results keep counting: a parseable legacy
//!   record is merged into the same bench's history (as the oldest
//!   entry, so trajectory diffs span the migration), while one poisoned
//!   by the old non-finite-rendering bug is quarantined as a warning
//!   rather than failing the roll-up.
//!
//! [`report`] parses everything with [`crate::util::json`], renders a
//! cross-bench summary table plus a per-bench trajectory diff (latest
//! vs previous record, wall-time regressions flagged), and **fails on
//! any parse error in the artifacts this harness emits** (`*.jsonl`,
//! `*.config.json`) — CI runs it after the bench step, so a schema
//! regression in any emitter can never silently rot the artifacts.

use crate::util::json::Json;
use crate::util::table::{fmt_f, Table};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Wall-time growth beyond this fraction flags a bench as regressed in
/// the summary table.
pub const WALL_REGRESSION_THRESHOLD: f64 = 0.10;

/// One bench's accumulated history, oldest record first.
pub struct BenchHistory {
    pub bench: String,
    pub path: PathBuf,
    pub records: Vec<Json>,
}

impl BenchHistory {
    fn latest(&self) -> &Json {
        self.records.last().expect("scan never yields empty histories")
    }

    fn previous(&self) -> Option<&Json> {
        self.records.len().checked_sub(2).map(|i| &self.records[i])
    }
}

/// One sweep's merged roll-up: the response lines of
/// `<name>.sweep.jsonl`, in plan order.
pub struct SweepRollup {
    pub name: String,
    pub path: PathBuf,
    pub responses: Vec<Json>,
}

/// Everything found under a results directory.
pub struct ResultsScan {
    pub benches: Vec<BenchHistory>,
    pub snapshots: Vec<PathBuf>,
    pub sweeps: Vec<SweepRollup>,
    /// Legacy `*.json` files that do not parse — typically history
    /// poisoned by the old non-finite-rendering bug.  Surfaced as
    /// warnings: the current harness can no longer produce them, so
    /// they must not brick the roll-up on machines with old results.
    pub unreadable_legacy: Vec<(PathBuf, String)>,
}

/// Parse every artifact under `dir`.  An unparseable harness-emitted
/// artifact (`*.jsonl`, `*.config.json`) is an error naming the file
/// (and line, for JSONL); unparseable pre-migration `*.json` files are
/// collected into [`ResultsScan::unreadable_legacy`] instead.  Legacy
/// and JSONL records of the same bench merge into one history, legacy
/// first (it always predates the append-mode migration).
pub fn scan_results(dir: &Path) -> Result<ResultsScan> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading results dir '{}'", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    // Sorted, so `<bench>.json` contributes before `<bench>.jsonl`.
    entries.sort();
    let mut by_bench: BTreeMap<String, BenchHistory> = BTreeMap::new();
    let mut snapshots = Vec::new();
    let mut sweeps = Vec::new();
    let mut unreadable_legacy = Vec::new();
    // `legacy` records always predate the append-mode migration, so on a
    // merge they splice in *front* of any JSONL history — even when the
    // legacy file sorts after the JSONL file (a legacy `bench` field can
    // disagree with its filename stem, e.g. `zz.json` carrying bench
    // "aaa") — and they never steal the history's path from the live
    // JSONL file.
    let mut add = |bench: String, path: PathBuf, mut records: Vec<Json>, legacy: bool| {
        match by_bench.entry(bench) {
            std::collections::btree_map::Entry::Occupied(mut o) => {
                let h = o.get_mut();
                if legacy {
                    records.append(&mut h.records);
                    h.records = records;
                } else {
                    h.records.append(&mut records);
                    h.path = path;
                }
            }
            std::collections::btree_map::Entry::Vacant(v) => {
                let bench = v.key().clone();
                v.insert(BenchHistory { bench, path, records });
            }
        }
    };
    for path in entries {
        let fname = path.file_name().and_then(|s| s.to_str()).unwrap_or("").to_string();
        let stem = fname.split('.').next().unwrap_or("").to_string();
        let read = || {
            std::fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))
        };
        if fname.ends_with(".config.json") {
            let src = read()?;
            // Full schema check, not just syntax: a snapshot the config
            // loader cannot replay is already rotten.
            crate::config::snapshot::load_run_config_json(&src)
                .map_err(|e| anyhow!("{}: {e:#}", path.display()))?;
            snapshots.push(path);
        } else if fname.ends_with(".sweep.jsonl") {
            // A sweep's merged roll-up: serve-format response lines in
            // plan order.  Harness-emitted, so parse failures are errors.
            let src = read()?;
            let mut responses = Vec::new();
            for (i, line) in src.lines().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                let v = Json::parse(line)
                    .map_err(|e| anyhow!("{} line {}: {e}", path.display(), i + 1))?;
                if v.get("snipsnap_response").is_none() {
                    bail!(
                        "{} line {}: not a snipsnap response line",
                        path.display(),
                        i + 1
                    );
                }
                responses.push(v);
            }
            if !responses.is_empty() {
                let name = fname.trim_end_matches(".sweep.jsonl").to_string();
                sweeps.push(SweepRollup { name, path, responses });
            }
        } else if fname.ends_with(".jsonl") {
            let src = read()?;
            let mut records = Vec::new();
            for (i, line) in src.lines().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                records.push(
                    Json::parse(line)
                        .map_err(|e| anyhow!("{} line {}: {e}", path.display(), i + 1))?,
                );
            }
            if let Some(bench) = bench_id(&records, &stem) {
                add(bench, path, records, false);
            }
        } else if fname.ends_with(".json") {
            let src = read()?;
            match Json::parse(&src) {
                Ok(rec) => {
                    let records = vec![rec];
                    let bench = bench_id(&records, &stem).unwrap();
                    add(bench, path, records, true);
                }
                Err(e) => unreadable_legacy.push((path, e.to_string())),
            }
        }
        // Anything else (e.g. editor droppings) is ignored.
    }
    Ok(ResultsScan {
        benches: by_bench.into_values().collect(),
        snapshots,
        sweeps,
        unreadable_legacy,
    })
}

fn bench_id(records: &[Json], stem: &str) -> Option<String> {
    let last = records.last()?;
    Some(
        last.get("bench")
            .and_then(Json::as_str)
            .unwrap_or(stem)
            .to_string(),
    )
}

fn wall_s(rec: &Json) -> Option<f64> {
    rec.get("wall_time_s").and_then(Json::as_f64).filter(|w| w.is_finite())
}

/// Numeric scalar fields of a record's payload (`rows` in the unified
/// schema, `data` in the legacy shape), plus the record's own wall time
/// — the fields the trajectory diff compares.
fn numeric_scalars(rec: &Json) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    if let Some(w) = wall_s(rec) {
        out.insert("wall_time_s".to_string(), w);
    }
    let payload = rec.get("rows").or_else(|| rec.get("data"));
    if let Some(Json::Obj(m)) = payload {
        for (k, v) in m {
            if let Json::Num(n) = v {
                out.insert(k.clone(), *n);
            }
        }
    }
    out
}

fn pct_change(prev: f64, latest: f64) -> Option<f64> {
    if prev != 0.0 && prev.is_finite() && latest.is_finite() {
        Some(100.0 * (latest / prev - 1.0))
    } else {
        None
    }
}

/// The cross-bench summary table.
pub fn render_summary(scan: &ResultsScan) -> String {
    let mut t = Table::new(vec![
        "bench", "records", "latest rev", "wall (s)", "wall vs prev", "flags",
    ])
    .with_title("Run-artifact roll-up (latest record per bench)");
    for b in &scan.benches {
        let latest = b.latest();
        let rev = latest.get("git_rev").and_then(Json::as_str).unwrap_or("-").to_string();
        let wall = wall_s(latest);
        let delta = b
            .previous()
            .and_then(wall_s)
            .zip(wall)
            .and_then(|(p, l)| pct_change(p, l));
        let mut flags = String::new();
        if delta.is_some_and(|d| d > 100.0 * WALL_REGRESSION_THRESHOLD) {
            flags.push_str("WALL-REGRESSION");
        }
        t.add_row(vec![
            b.bench.clone(),
            b.records.len().to_string(),
            rev,
            wall.map(|w| format!("{w:.3}")).unwrap_or_else(|| "-".to_string()),
            delta.map(|d| format!("{d:+.1}%")).unwrap_or_else(|| "-".to_string()),
            flags,
        ]);
    }
    t.render()
}

/// The latest-vs-previous field diff for one bench, or `None` with
/// fewer than two records.
pub fn render_trajectory(b: &BenchHistory) -> Option<String> {
    let prev = numeric_scalars(b.previous()?);
    let latest = numeric_scalars(b.latest());
    let mut out = format!("{} (latest vs previous of {} records):\n", b.bench, b.records.len());
    let mut any = false;
    for (k, lv) in &latest {
        match prev.get(k) {
            Some(pv) => {
                let delta = pct_change(*pv, *lv)
                    .map(|d| format!(" ({d:+.1}%)"))
                    .unwrap_or_default();
                out.push_str(&format!("  {k}: {pv} -> {lv}{delta}\n"));
            }
            None => out.push_str(&format!("  {k}: (new) {lv}\n")),
        }
        any = true;
    }
    for k in prev.keys().filter(|k| !latest.contains_key(*k)) {
        out.push_str(&format!("  {k}: dropped from the latest record\n"));
        any = true;
    }
    if !any {
        out.push_str("  (no numeric scalar fields to compare)\n");
    }
    Some(out)
}

/// Render one sweep's roll-up: a per-config table (grouped by the
/// sweep's id prefix, in plan order) plus a summary line surfacing the
/// failure and frontier-run counts.
pub fn render_sweep(s: &SweepRollup) -> String {
    let mut t = Table::new(vec![
        "config", "workload", "ok", "energy (pJ)", "cycles", "EDP", "frontier",
    ])
    .with_title(format!("Sweep '{}' ({} configs)", s.name, s.responses.len()));
    let mut failed = 0usize;
    let mut frontier_runs = 0usize;
    for (i, r) in s.responses.iter().enumerate() {
        let id = r
            .get("id")
            .and_then(Json::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| format!("#{i}"));
        let ok = r.get("ok").and_then(Json::as_bool) == Some(true);
        failed += usize::from(!ok);
        let total = |k: &str| {
            r.get("totals")
                .and_then(|t| t.get(k))
                .and_then(Json::as_f64)
                .map(fmt_f)
                .unwrap_or_else(|| "-".to_string())
        };
        let frontier = r
            .get("frontier")
            .and_then(|f| f.get("points"))
            .and_then(Json::as_f64)
            .map(|p| {
                frontier_runs += 1;
                format!("{p} pts")
            })
            .unwrap_or_else(|| "-".to_string());
        t.add_row(vec![
            id,
            r.get("workload").and_then(Json::as_str).unwrap_or("-").to_string(),
            if ok { "yes".to_string() } else { "NO".to_string() },
            total("energy_pj"),
            total("cycles"),
            total("edp"),
            frontier,
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "sweep {}: {} configs, {} failed, {} frontier run{}\n",
        s.name,
        s.responses.len(),
        failed,
        frontier_runs,
        if frontier_runs == 1 { "" } else { "s" },
    ));
    out
}

/// Render the whole roll-up for a results directory: summary table,
/// sweep roll-ups, per-bench trajectories, snapshot count.  Errors when
/// the directory is missing, empty of artifacts, or any artifact fails
/// to parse.
pub fn report(dir: &Path) -> Result<String> {
    let scan = scan_results(dir)?;
    if scan.benches.is_empty()
        && scan.snapshots.is_empty()
        && scan.sweeps.is_empty()
        && scan.unreadable_legacy.is_empty()
    {
        bail!("no run artifacts under '{}'", dir.display());
    }
    let mut out = render_summary(&scan);
    for (path, err) in &scan.unreadable_legacy {
        out.push_str(&format!(
            "warning: {} predates the non-finite JSON fix and cannot be parsed ({err}); \
             delete it or re-run the bench to start a fresh history\n",
            path.display()
        ));
    }
    for s in &scan.sweeps {
        out.push('\n');
        out.push_str(&render_sweep(s));
    }
    let diffs: Vec<String> = scan.benches.iter().filter_map(render_trajectory).collect();
    if !diffs.is_empty() {
        out.push_str("\nTrajectories:\n");
        for d in diffs {
            out.push_str(&d);
        }
    }
    out.push_str(&format!(
        "\n{} bench histor{} ({} record{}), {} run-config snapshot{}",
        scan.benches.len(),
        if scan.benches.len() == 1 { "y" } else { "ies" },
        scan.benches.iter().map(|b| b.records.len()).sum::<usize>(),
        if scan.benches.iter().map(|b| b.records.len()).sum::<usize>() == 1 { "" } else { "s" },
        scan.snapshots.len(),
        if scan.snapshots.len() == 1 { "" } else { "s" },
    ));
    if !scan.sweeps.is_empty() {
        out.push_str(&format!(
            ", {} sweep roll-up{}",
            scan.sweeps.len(),
            if scan.sweeps.len() == 1 { "" } else { "s" },
        ));
    }
    out.push('\n');
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bench::write_record_at;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("snipsnap_report_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn rolls_up_accumulated_history_and_flags_regressions() {
        let dir = tmpdir("ok");
        assert!(write_record_at(&dir, "demo", 1.0, Json::obj(vec![("metric", Json::num(10.0))])));
        assert!(write_record_at(&dir, "demo", 1.5, Json::obj(vec![("metric", Json::num(12.0))])));
        std::fs::write(dir.join("legacy.json"), "{\"bench\":\"legacy\",\"data\":{\"x\":1}}")
            .unwrap();
        let cfg = crate::config::load_run_config(
            "[run]\narch = \"arch3\"\n[[op]]\nm = 8\nn = 8\nk = 8\n",
        )
        .unwrap();
        let snap = crate::config::snapshot::render(&cfg.arch, &cfg.workload, &cfg.search);
        std::fs::write(dir.join("run-1.config.json"), snap).unwrap();
        let out = report(&dir).unwrap();
        assert!(out.contains("demo"), "{out}");
        assert!(out.contains("legacy"), "{out}");
        assert!(out.contains("WALL-REGRESSION"), "wall 1.0 -> 1.5 must flag:\n{out}");
        assert!(out.contains("+50.0%"), "{out}");
        assert!(out.contains("metric: 10 -> 12"), "{out}");
        assert!(out.contains("1 run-config snapshot"), "{out}");
        let scan = scan_results(&dir).unwrap();
        assert_eq!(scan.benches.len(), 2);
        assert_eq!(scan.benches.iter().find(|b| b.bench == "demo").unwrap().records.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Legacy single-record files merge into the same bench's JSONL
    /// history (oldest first), so trajectory diffs span the migration;
    /// legacy files poisoned by the old NaN-rendering bug are warnings,
    /// not failures.
    #[test]
    fn legacy_records_merge_and_poisoned_legacy_warns() {
        let dir = tmpdir("legacy");
        std::fs::write(
            dir.join("demo.json"),
            "{\"bench\":\"demo\",\"data\":{\"metric\":9.0},\"wall_time_s\":1.0}",
        )
        .unwrap();
        assert!(write_record_at(&dir, "demo", 1.2, Json::obj(vec![("metric", Json::num(10.0))])));
        // The old Display bug wrote literal NaN — invalid JSON.
        std::fs::write(dir.join("poisoned.json"), "{\"bench\":\"old\",\"x\":NaN}").unwrap();
        let scan = scan_results(&dir).unwrap();
        assert_eq!(scan.benches.len(), 1, "legacy + jsonl must merge into one history");
        assert_eq!(scan.benches[0].records.len(), 2);
        assert_eq!(scan.unreadable_legacy.len(), 1);
        let out = report(&dir).unwrap();
        assert!(out.contains("metric: 9 -> 10"), "diff must span the migration:\n{out}");
        assert!(out.contains("warning") && out.contains("poisoned.json"), "{out}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parse_errors_name_the_file_and_fail() {
        let dir = tmpdir("bad");
        assert!(write_record_at(&dir, "demo", 1.0, Json::Null));
        std::fs::write(dir.join("broken.jsonl"), "{\"bench\":\"b\"}\n{oops\n").unwrap();
        let e = report(&dir).unwrap_err().to_string();
        assert!(e.contains("broken.jsonl"), "{e}");
        assert!(e.contains("line 2"), "{e}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_or_missing_dirs_error() {
        let dir = tmpdir("empty");
        assert!(report(&dir).unwrap_err().to_string().contains("no run artifacts"));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(report(&dir).is_err(), "missing dir must not be reported as healthy");
    }

    /// A legacy file whose `bench` field disagrees with its filename
    /// stem buckets by the *field*, and stays the oldest record of the
    /// merged history even when the legacy filename sorts after the
    /// JSONL file (regression: the merge used to append it last and
    /// steal the history's path).
    #[test]
    fn legacy_bench_field_beats_stem_and_stays_oldest() {
        let dir = tmpdir("stem_mismatch");
        assert!(write_record_at(&dir, "aaa", 2.0, Json::obj(vec![("metric", Json::num(10.0))])));
        std::fs::write(
            dir.join("zz.json"),
            "{\"bench\":\"aaa\",\"data\":{\"metric\":9.0},\"wall_time_s\":1.0}",
        )
        .unwrap();
        let scan = scan_results(&dir).unwrap();
        assert_eq!(scan.benches.len(), 1, "must merge into one 'aaa' history, not a 'zz' bench");
        let h = &scan.benches[0];
        assert_eq!(h.bench, "aaa");
        assert_eq!(h.records.len(), 2);
        assert_eq!(
            h.records[0].get("data").and_then(|d| d.get("metric")),
            Some(&Json::num(9.0)),
            "legacy record must stay oldest regardless of filename order"
        );
        assert!(h.path.ends_with("aaa.jsonl"), "path must stay the live JSONL file");
        let out = report(&dir).unwrap();
        assert!(out.contains("metric: 9 -> 10"), "{out}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A `.jsonl` filename with dots truncates its *stem* at the first
    /// dot, but records carrying a `bench` field bucket by the field —
    /// dotted bench names must not split or mis-bucket histories.
    #[test]
    fn dotted_jsonl_names_bucket_by_record_bench() {
        let dir = tmpdir("dotted");
        assert!(write_record_at(&dir, "fig.v2", 1.0, Json::obj(vec![("m", Json::num(1.0))])));
        assert!(write_record_at(&dir, "fig.v2", 2.0, Json::obj(vec![("m", Json::num(2.0))])));
        // A record with no bench field falls back to the first-dot stem.
        std::fs::write(dir.join("x.y.jsonl"), "{\"wall_time_s\":1.0}\n").unwrap();
        let scan = scan_results(&dir).unwrap();
        let names: Vec<&str> = scan.benches.iter().map(|b| b.bench.as_str()).collect();
        assert_eq!(names, vec!["fig.v2", "x"], "got {names:?}");
        assert_eq!(
            scan.benches.iter().find(|b| b.bench == "fig.v2").unwrap().records.len(),
            2,
            "dotted bench name must keep one merged history"
        );
        report(&dir).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Empty (or whitespace-only) `.jsonl` files yield no records: they
    /// must be skipped without panicking and without creating a
    /// zero-record history (`BenchHistory::latest` would panic on one).
    #[test]
    fn empty_jsonl_files_are_skipped() {
        let dir = tmpdir("empty_jsonl");
        std::fs::write(dir.join("hollow.jsonl"), "").unwrap();
        std::fs::write(dir.join("blank.jsonl"), "\n  \n\n").unwrap();
        let scan = scan_results(&dir).unwrap();
        assert!(scan.benches.is_empty(), "empty files must not become histories");
        // With nothing else present the roll-up reports no artifacts.
        assert!(report(&dir).unwrap_err().to_string().contains("no run artifacts"));
        // And alongside a real history they stay invisible.
        assert!(write_record_at(&dir, "real", 1.0, Json::Null));
        let scan = scan_results(&dir).unwrap();
        assert_eq!(scan.benches.len(), 1);
        assert_eq!(scan.benches[0].bench, "real");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Sweep roll-ups render per-config rows (plan order), surface the
    /// frontier point count, and count into the footer; a non-response
    /// line in a `.sweep.jsonl` fails the roll-up like any other
    /// harness-emitted artifact.
    #[test]
    fn sweep_rollups_render_rows_and_summary() {
        let dir = tmpdir("sweep");
        let ok_line = "{\"snipsnap_response\":1,\"id\":\"demo-0\",\"ok\":true,\
                       \"workload\":\"w\",\"designs\":[],\
                       \"totals\":{\"energy_pj\":10.5,\"cycles\":100,\"edp\":1050},\
                       \"frontier\":{\"points\":7}}";
        let err_line =
            "{\"snipsnap_response\":1,\"id\":\"demo-1\",\"ok\":false,\"error\":\"boom\"}";
        std::fs::write(dir.join("demo.sweep.jsonl"), format!("{ok_line}\n{err_line}\n"))
            .unwrap();
        let scan = scan_results(&dir).unwrap();
        assert_eq!(scan.sweeps.len(), 1);
        assert!(scan.benches.is_empty(), "sweep roll-ups are not bench histories");
        let out = report(&dir).unwrap();
        assert!(out.contains("Sweep 'demo' (2 configs)"), "{out}");
        assert!(out.contains("demo-0"), "{out}");
        assert!(out.contains("7 pts"), "{out}");
        assert!(out.contains("sweep demo: 2 configs, 1 failed, 1 frontier run\n"), "{out}");
        assert!(out.contains("1 sweep roll-up\n"), "{out}");

        std::fs::write(dir.join("bad.sweep.jsonl"), "{\"not_a_response\":1}\n").unwrap();
        let e = report(&dir).unwrap_err().to_string();
        assert!(e.contains("bad.sweep.jsonl") && e.contains("line 1"), "{e}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Every record shape the harness can emit — including non-finite
    /// metrics, which serialize as null — must re-parse through the
    /// scanner (the acceptance-level schema guarantee).
    #[test]
    fn harness_emitted_records_always_reparse() {
        let dir = tmpdir("nan");
        assert!(write_record_at(
            &dir,
            "edge",
            f64::NAN,
            Json::obj(vec![
                ("nan", Json::num(f64::NAN)),
                ("inf", Json::num(f64::INFINITY)),
                ("neg", Json::num(f64::NEG_INFINITY)),
                ("fine", Json::num(0.25)),
            ]),
        ));
        assert!(write_record_at(&dir, "edge", 2.0, Json::arr([Json::num(1.0)])));
        let out = report(&dir).unwrap();
        assert!(out.contains("edge"), "{out}");
        let scan = scan_results(&dir).unwrap();
        let hist = &scan.benches[0];
        assert_eq!(hist.records.len(), 2);
        // The NaN wall time became null; the scanner treats it as absent.
        assert_eq!(wall_s(&hist.records[0]), None);
        assert_eq!(hist.records[0].get("rows").unwrap().get("nan"), Some(&Json::Null));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
