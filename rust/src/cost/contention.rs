//! The contention cost backend: bandwidth derating, burst/transaction
//! granularity, and decompression latency on the critical path.
//!
//! Same [`AccessCounts`](crate::dataflow::AccessCounts), same energy
//! model as the analytical backend — only the bits→cycles transform of
//! each memory boundary changes:
//!
//! 1. Per-operand traffic rounds **up** to whole bursts:
//!    `service = max(ceil(bits / burst) * burst, bits)` per operand
//!    (the `max` guards the one f64 edge where `ceil` of a rounded
//!    quotient lands a hair *below* `bits`, which would otherwise let
//!    contention under-cut the analytical time).  Compressed operands
//!    ship fewer bits and therefore fewer transactions, but a format
//!    whose tile shrinks below one burst still pays a full burst.
//! 2. Effective bandwidth is derated: `bw * derate[b]`, `derate ∈ (0,1]`
//!    modeling arbitration/refresh/row-conflict loss at that boundary.
//! 3. At the innermost boundary (delivery into the PEs) compressed
//!    operands pass through a decompressor with throughput
//!    `decompress_bits_per_cycle`; the boundary's service time is the
//!    roofline-style `max(transfer, decompress)`.
//!
//! With default parameters (derate 1.0 everywhere) every service time
//! is ≥ the analytical `bits / bw`, term by term, so the contention
//! latency **dominates** the analytical latency on every mapping — the
//! invariant the differential suite asserts exactly (not approximately)
//! and the reason the branch-and-bound `lower_bound` remains a true
//! lower bound under this backend (`docs/COST.md`).

use crate::arch::Accelerator;
use crate::cost::{CompressionRatios, CostBackend};
use crate::dataflow::{Operand, MAX_LEVELS};

/// Default burst size (bits) for the outermost boundary — a 64-byte
/// DRAM burst, the granularity at which compressed blocks round up.
pub const DEFAULT_BURST_BITS_OUTER: f64 = 512.0;

/// Default burst size (bits) for every on-chip boundary — a 16-byte
/// SRAM line.
pub const DEFAULT_BURST_BITS_INNER: f64 = 128.0;

/// Default decompressor throughput (bits/cycle) at the PE boundary.
/// Wide enough that decompression only surfaces on heavily compressed,
/// bandwidth-light tiles — matching the paper's claim that decoding is
/// off the critical path for well-chosen formats.
pub const DEFAULT_DECOMPRESS_BITS_PER_CYCLE: f64 = 4096.0;

/// Tunable knobs of the contention model, settable per run via the
/// `[cost]` TOML section and captured bit-identically in run-config
/// snapshots.  Arrays are indexed by memory boundary (same order as
/// `Accelerator::levels`, outermost first); boundaries beyond the
/// machine's actual level count are ignored.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ContentionParams {
    /// Fraction of each boundary's peak bandwidth actually achievable,
    /// in `(0, 1]`.  `1.0` = no contention loss.
    pub bandwidth_derate: [f64; MAX_LEVELS],
    /// Burst/transaction granularity (bits) per boundary, ≥ 1.
    pub burst_bits: [f64; MAX_LEVELS],
    /// Decompressor throughput (bits/cycle) at the innermost boundary,
    /// applied to compressed operands only.  `None` disables the
    /// decompression term (serialized as `0` in TOML / `null` in
    /// snapshots).
    pub decompress_bits_per_cycle: Option<f64>,
}

impl Default for ContentionParams {
    fn default() -> Self {
        let mut burst_bits = [DEFAULT_BURST_BITS_INNER; MAX_LEVELS];
        burst_bits[0] = DEFAULT_BURST_BITS_OUTER;
        ContentionParams {
            bandwidth_derate: [1.0; MAX_LEVELS],
            burst_bits,
            decompress_bits_per_cycle: Some(DEFAULT_DECOMPRESS_BITS_PER_CYCLE),
        }
    }
}

impl ContentionParams {
    /// Every knob finite and in range; rejects the configs that would
    /// let NaN/inf leak into `CostReport`.
    pub fn validate(&self) -> Result<(), String> {
        for (b, d) in self.bandwidth_derate.iter().enumerate() {
            if !d.is_finite() || *d <= 0.0 || *d > 1.0 {
                return Err(format!(
                    "cost.bandwidth_derate[{b}] = {d}: must be finite and in (0, 1]"
                ));
            }
        }
        for (b, w) in self.burst_bits.iter().enumerate() {
            if !w.is_finite() || *w < 1.0 {
                return Err(format!("cost.burst_bits[{b}] = {w}: must be finite and >= 1"));
            }
        }
        if let Some(tp) = self.decompress_bits_per_cycle {
            if !tp.is_finite() || tp <= 0.0 {
                return Err(format!(
                    "cost.decompress_bits_per_cycle = {tp}: must be finite and > 0 \
                     (use 0 in TOML to disable)"
                ));
            }
        }
        Ok(())
    }
}

/// Number of whole bursts needed to move `bits` (0 for no traffic).
pub fn transactions(bits: f64, burst_bits: f64) -> f64 {
    if bits <= 0.0 {
        0.0
    } else {
        (bits / burst_bits).ceil()
    }
}

/// The contention backend: [`ContentionParams`] applied on top of the
/// shared access-count funnel.
#[derive(Clone, Copy, Debug)]
pub struct Contention {
    pub params: ContentionParams,
}

impl CostBackend for Contention {
    fn name(&self) -> &'static str {
        "contention"
    }

    fn boundary_cycles(
        &self,
        arch: &Accelerator,
        b: usize,
        op_bits: &[f64; 3],
        _total_bits: f64,
        ratios: &CompressionRatios,
    ) -> f64 {
        let burst = self.params.burst_bits[b];
        let mut service_bits = 0.0;
        for bits in op_bits {
            // `.max(bits)` keeps service ≥ raw bits even in the f64
            // corner where ceil(fl(bits/burst)) * burst < bits.
            service_bits += (transactions(*bits, burst) * burst).max(*bits);
        }
        let bw = arch.levels[b].bandwidth_bits_per_cycle * self.params.bandwidth_derate[b];
        let transfer = service_bits / bw;

        // Decompression sits at the PE boundary only, and only for
        // operands that are actually compressed.
        if b + 1 == arch.levels.len() {
            if let Some(tp) = self.params.decompress_bits_per_cycle {
                let mut decomp = 0.0f64;
                for (oi, op) in Operand::ALL.iter().enumerate() {
                    if ratios.get(*op) < 1.0 {
                        decomp = decomp.max(op_bits[oi] / tp);
                    }
                }
                return transfer.max(decomp);
            }
        }
        transfer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::Analytical;

    #[test]
    fn transactions_rounds_up() {
        assert_eq!(transactions(0.0, 512.0), 0.0);
        assert_eq!(transactions(1.0, 512.0), 1.0);
        assert_eq!(transactions(512.0, 512.0), 1.0);
        assert_eq!(transactions(513.0, 512.0), 2.0);
        assert_eq!(transactions(4096.0, 128.0), 32.0);
    }

    #[test]
    fn default_params_validate() {
        ContentionParams::default().validate().unwrap();
    }

    /// Defaults with one knob twiddled (avoids the
    /// `field_reassign_with_default` pattern clippy rejects).
    fn tweaked(f: impl FnOnce(&mut ContentionParams)) -> ContentionParams {
        let mut p = ContentionParams::default();
        f(&mut p);
        p
    }

    #[test]
    fn bad_params_are_rejected() {
        let p = tweaked(|p| p.bandwidth_derate[2] = 0.0);
        assert!(p.validate().unwrap_err().contains("bandwidth_derate[2]"));
        assert!(tweaked(|p| p.bandwidth_derate[0] = 1.5).validate().is_err());
        let p = tweaked(|p| p.burst_bits[1] = 0.5);
        assert!(p.validate().unwrap_err().contains("burst_bits[1]"));
        assert!(tweaked(|p| p.burst_bits[0] = f64::NAN).validate().is_err());
        assert!(tweaked(|p| p.decompress_bits_per_cycle = Some(0.0)).validate().is_err());
        tweaked(|p| p.decompress_bits_per_cycle = None).validate().unwrap();
    }

    /// The load-bearing invariant, checked term by term at the boundary
    /// level: contention service time ≥ analytical service time for the
    /// same traffic, including awkward non-burst-aligned bit counts.
    #[test]
    fn boundary_cycles_dominate_analytical() {
        let arch = presets::arch3();
        let c = Contention { params: ContentionParams::default() };
        let ratios = CompressionRatios { input: 0.4, weight: 0.7 };
        for op_bits in [
            [0.0, 0.0, 0.0],
            [1.0, 1.0, 1.0],
            [511.0, 513.0, 128.0],
            [1e6 + 0.5, 3.0, 77777.0],
            [1e12, 1e-9, 12345.6],
        ] {
            let total: f64 = op_bits.iter().sum();
            for b in 0..arch.levels.len() {
                let anal = Analytical.boundary_cycles(&arch, b, &op_bits, total, &ratios);
                let cont = c.boundary_cycles(&arch, b, &op_bits, total, &ratios);
                assert!(
                    cont >= anal,
                    "boundary {b}: contention {cont} < analytical {anal} for {op_bits:?}"
                );
                assert!(cont.is_finite());
            }
        }
    }

    /// With derate 1.0 and traffic that is an exact multiple of the
    /// burst, the transfer term equals the analytical time exactly; the
    /// dense case also skips the decompression term.
    #[test]
    fn burst_aligned_dense_traffic_matches_analytical() {
        let arch = presets::arch3();
        let c = Contention { params: ContentionParams::default() };
        let ratios = CompressionRatios::DENSE;
        for b in 0..arch.levels.len() {
            let burst = c.params.burst_bits[b];
            let op_bits = [burst * 4.0, burst * 9.0, burst * 2.0];
            let total: f64 = op_bits.iter().sum();
            let anal = Analytical.boundary_cycles(&arch, b, &op_bits, total, &ratios);
            let cont = c.boundary_cycles(&arch, b, &op_bits, total, &ratios);
            assert_eq!(cont.to_bits(), anal.to_bits(), "boundary {b}");
        }
    }

    /// Decompression applies only at the innermost boundary, only to
    /// compressed operands, and can dominate the transfer time.
    #[test]
    fn decompression_gates_innermost_boundary() {
        let arch = presets::arch3();
        let inner = arch.levels.len() - 1;
        // Pathologically slow decompressor: 1 bit/cycle.
        let c = Contention { params: tweaked(|p| p.decompress_bits_per_cycle = Some(1.0)) };
        let compressed = CompressionRatios { input: 0.5, weight: 1.0 };
        let op_bits = [1024.0, 1024.0, 0.0];
        let total: f64 = op_bits.iter().sum();

        // Innermost + compressed input → decomp term (1024 cycles at
        // 1 bit/cycle) dominates any realistic transfer time.
        let gated = c.boundary_cycles(&arch, inner, &op_bits, total, &compressed);
        assert_eq!(gated, 1024.0);

        // Outer boundary: same traffic, no decompression term.
        let outer = c.boundary_cycles(&arch, 0, &op_bits, total, &compressed);
        assert!(outer < gated);

        // Dense traffic at the innermost boundary: no decompression.
        let dense = c.boundary_cycles(&arch, inner, &op_bits, total, &CompressionRatios::DENSE);
        assert!(dense < gated);

        // Disabled decompressor: pure transfer time.
        let c_off = Contention { params: tweaked(|p| p.decompress_bits_per_cycle = None) };
        let plain = c_off.boundary_cycles(&arch, inner, &op_bits, total, &compressed);
        assert!(plain < gated);
    }

    #[test]
    fn derate_scales_transfer_time() {
        let arch = presets::arch3();
        let c = Contention { params: tweaked(|p| p.bandwidth_derate[0] = 0.5) };
        let base = Contention { params: ContentionParams::default() };
        let ratios = CompressionRatios::DENSE;
        let op_bits = [512.0 * 3.0, 512.0 * 5.0, 512.0];
        let total: f64 = op_bits.iter().sum();
        let slow = c.boundary_cycles(&arch, 0, &op_bits, total, &ratios);
        let fast = base.boundary_cycles(&arch, 0, &op_bits, total, &ratios);
        assert_eq!(slow.to_bits(), (fast * 2.0).to_bits());
    }

    #[test]
    fn name_is_stable() {
        assert_eq!(Contention { params: ContentionParams::default() }.name(), "contention");
    }
}
