//! The Evaluator's Cost Model (paper §III-A): energy, latency and EDP of
//! one MatMul under a mapping, a computation-reduction strategy and
//! per-operand compression ratios.
//!
//! Energy: MAC energy scaled by the reduction strategy's energy fraction,
//! plus per-boundary transfer energy (read at the source level + write at
//! the destination) with I/W traffic scaled by their compressed-size
//! ratios (operands move compressed; decompression happens at the PEs).
//! Latency: max of compute cycles (skipping shrinks the effective MAC
//! count) and each boundary's bandwidth-limited cycles — the perfectly
//! double-buffered roofline.  EDP: product.
//!
//! # Memoized evaluation
//!
//! [`access_counts`] depends only on the mapping and problem dims —
//! never on sparsity, reduction strategy or compression ratios — while
//! the search re-evaluates the same mapping once per candidate
//! format/ratio pair (and the order sweep / tile refinement revisit
//! mappings many times within one pair).  [`EvalContext`] exploits that:
//! it owns a per-(tiling, order) cache of [`access_counts`] results
//! keyed by the packed [`MapKey`] (a `Copy` `u64`-per-level encoding of
//! factors + orders — no `Mapping` clone or `Vec` hash on either lookup
//! or insert), bundles the per-op invariants (arch, dims, metric) that
//! every evaluator entry point used to thread as separate arguments,
//! and reports [`CacheStats`] hit/miss counters surfaced by the CLI and
//! the bench binaries.  The cached path is bit-identical to
//! [`evaluate`]: both funnel into [`evaluate_from_counts`].
//!
//! Two further hot-path services live here:
//!
//! - [`EvalContext::sweep_level`] — the incremental order sweep:
//!   boundary-`b` traffic depends only on orders of levels ≤ `b`, so
//!   re-evaluating a level-`lvl` order change resumes the fill pass from
//!   a prefix snapshot instead of recounting the whole nest.
//! - [`EvalContext::lower_bound`] — an order-independent lower bound on
//!   the metric from tile footprints alone, used by the search's
//!   branch-and-bound pruning (derivation in `docs/SEARCH.md`).
//!
//! # Pluggable cost backends
//!
//! The bits→cycles transform of each memory boundary sits behind the
//! [`CostBackend`] trait (contract in `docs/COST.md`).  Two backends
//! ship today: [`analytical::Analytical`] (the default — exactly the
//! historical counts model, bit-identical through the trait) and
//! [`contention::Contention`] (burst/transaction roundup, bandwidth
//! derating and decompression latency on the same [`AccessCounts`]).
//! The search carries the selection as a [`CostModel`] enum so contexts
//! stay `Copy`-cheap and `Send`; the memoized counts cache is
//! backend-independent (counts are a pure function of mapping + dims),
//! so switching backends never changes cache semantics.

pub mod analytical;
pub mod contention;

pub use analytical::Analytical;
pub use contention::{transactions, Contention, ContentionParams};

use crate::arch::Accelerator;
use crate::dataflow::{
    access_counts, tiles_of, AccessCounts, FillState, LoopDim, Mapping, Operand, ProblemDims,
    Spatial, TileLevel, MAX_LEVELS,
};
use crate::sparsity::{reduction::ReductionStrategy, SparsitySpec};
use crate::util::inline::InlineVec;
use std::collections::HashMap;

/// Compressed/dense traffic ratios per operand (outputs move dense).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompressionRatios {
    pub input: f64,
    pub weight: f64,
}

impl CompressionRatios {
    pub const DENSE: CompressionRatios = CompressionRatios { input: 1.0, weight: 1.0 };

    pub fn get(&self, op: Operand) -> f64 {
        match op {
            Operand::I => self.input,
            Operand::W => self.weight,
            Operand::O => 1.0,
        }
    }
}

/// Partial-sum traffic multiplier for the output operand: each fill is a
/// read-modify-write.
const PSUM_RW: f64 = 2.0;

/// Full cost breakdown of one evaluated design point.
///
/// Per-boundary rows use inline storage ([`MAX_LEVELS`] slots, `Copy`),
/// so producing, moving and keeping a report never heap-allocates — a
/// requirement of the search's per-proto visitor path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostReport {
    /// Energy of all MAC operations (pJ).
    pub mac_energy_pj: f64,
    /// Per-boundary memory transfer energy (pJ), outermost first.
    pub mem_energy_pj: InlineVec<f64, MAX_LEVELS>,
    /// Compute-bound cycles.
    pub compute_cycles: f64,
    /// Per-boundary bandwidth-bound cycles, outermost first.
    pub mem_cycles: InlineVec<f64, MAX_LEVELS>,
}

impl CostReport {
    pub fn memory_energy_pj(&self) -> f64 {
        self.mem_energy_pj.iter().sum()
    }

    pub fn total_energy_pj(&self) -> f64 {
        self.mac_energy_pj + self.memory_energy_pj()
    }

    /// Roofline latency in cycles.
    pub fn latency_cycles(&self) -> f64 {
        self.mem_cycles
            .iter()
            .fold(self.compute_cycles, |a, &b| a.max(b))
    }

    pub fn latency_seconds(&self, clock_ghz: f64) -> f64 {
        self.latency_cycles() / (clock_ghz * 1e9)
    }

    /// Energy-delay product (pJ x cycles).
    pub fn edp(&self) -> f64 {
        self.total_energy_pj() * self.latency_cycles()
    }
}

/// Which metric the search optimizes (paper: "the prioritized performance
/// metric ... energy consumption, latency, and energy-delay-product").
///
/// [`Metric::Frontier`] is the multi-objective mode: one arena pass
/// serves all four scalar metrics at once, maintaining a Pareto set
/// ([`crate::search::frontier::Frontier`]) and extracting per-metric
/// winners bit-identical to four independent scalar searches
/// (`docs/SEARCH.md` § Frontier search).  Wherever a frontier context
/// needs a single scalar projection (ranking, bounds, aggregate
/// totals), it uses the **primary** metric — energy, the paper's
/// headline objective.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    Energy,
    MemoryEnergy,
    Latency,
    Edp,
    /// Multi-objective Pareto-frontier search over all four scalar
    /// metrics in a single arena pass.  Scalar projections (`of`,
    /// `lower_bound`, workload totals) use the primary metric (energy).
    Frontier,
}

impl Metric {
    /// The scalar metrics, in the canonical index order used by
    /// [`EvalContext::lower_bound_vec`], frontier vectors and the
    /// per-metric telemetry arrays.
    pub const SCALARS: [Metric; 4] =
        [Metric::Energy, Metric::MemoryEnergy, Metric::Latency, Metric::Edp];

    /// Index of this metric in [`Metric::SCALARS`]; `Frontier` projects
    /// to its primary metric (energy, index 0).
    pub fn scalar_index(&self) -> usize {
        match self {
            Metric::Energy | Metric::Frontier => 0,
            Metric::MemoryEnergy => 1,
            Metric::Latency => 2,
            Metric::Edp => 3,
        }
    }

    pub fn of(&self, r: &CostReport) -> f64 {
        match self {
            Metric::Energy => r.total_energy_pj(),
            Metric::MemoryEnergy => r.memory_energy_pj(),
            Metric::Latency => r.latency_cycles(),
            Metric::Edp => r.edp(),
            // The frontier's scalar projection is its primary metric.
            Metric::Frontier => r.total_energy_pj(),
        }
    }
}

/// Everything a backend needs to evaluate one design point, minus the
/// [`AccessCounts`] (which arrive separately so the memoized and the
/// uncached paths share one funnel).  Bundling the references keeps the
/// [`CostBackend::report`] signature small and stable as backends grow.
pub struct EvalInputs<'a> {
    pub arch: &'a Accelerator,
    pub p: &'a ProblemDims,
    pub mapping: &'a Mapping,
    pub spec: &'a SparsitySpec,
    pub reduction: &'a ReductionStrategy,
    pub ratios: &'a CompressionRatios,
}

/// A cost backend: how per-boundary compressed traffic turns into
/// service cycles.  Everything else — MAC energy, compute cycles,
/// per-bit transfer energy, the access-count model — is shared by all
/// backends via the provided [`CostBackend::report`] funnel, so a
/// backend only decides the memory-time story (contract and equations
/// in `docs/COST.md`).  Future measured/PJRT backends can override
/// `report` wholesale without touching the search loop.
pub trait CostBackend {
    /// Stable identifier (`"analytical"`, `"contention"`) used by the
    /// CLI, the `[cost]` config section and run-config snapshots.
    fn name(&self) -> &'static str;

    /// Service cycles of memory boundary `b` given the per-operand bit
    /// traffic crossing it (`op_bits` in [`Operand::ALL`] order, the
    /// partial-sum read-modify-write already folded into the O entry)
    /// and its pre-formed index-order sum `total_bits`.
    fn boundary_cycles(
        &self,
        arch: &Accelerator,
        b: usize,
        op_bits: &[f64; 3],
        total_bits: f64,
        ratios: &CompressionRatios,
    ) -> f64;

    /// Full cost report for one design point.  Provided implementation
    /// shared by all backends: only the bits→cycles transform of each
    /// boundary dispatches through [`Self::boundary_cycles`].  The
    /// energy model is deliberately backend-independent, so
    /// energy-metric searches rank identically under every backend.
    fn report(&self, inp: &EvalInputs<'_>, ac: &AccessCounts) -> CostReport {
        let arch = inp.arch;
        let data_bits = arch.data_bits as f64;

        // --- MAC compute ----------------------------------------------
        let peak_macs = inp.p.macs() as f64;
        let mac_energy_pj =
            peak_macs * inp.reduction.energy_fraction(inp.spec) * arch.mac.pj_per_mac;
        let spatial = (inp.mapping.spatial.factor(LoopDim::M)
            * inp.mapping.spatial.factor(LoopDim::N)
            * inp.mapping.spatial.factor(LoopDim::K)) as f64;
        let compute_cycles = peak_macs * inp.reduction.cycle_fraction(inp.spec) / spatial;

        // --- Memory boundaries ----------------------------------------
        // The per-operand products and the index-order sum reproduce the
        // historical accumulation exactly (same f64 operations in the
        // same association), so the analytical backend is bit-identical
        // to the pre-trait model.
        let nb = inp.mapping.levels.len();
        let mut mem_energy_pj: InlineVec<f64, MAX_LEVELS> = InlineVec::new();
        let mut mem_cycles: InlineVec<f64, MAX_LEVELS> = InlineVec::new();
        for b in 0..nb {
            let mut op_bits = [0.0f64; 3];
            for (oi, op) in Operand::ALL.iter().enumerate() {
                let psum = if *op == Operand::O { PSUM_RW } else { 1.0 };
                op_bits[oi] = ac.fills[b][oi] * data_bits * inp.ratios.get(*op) * psum;
            }
            let mut bits = 0.0;
            for x in op_bits {
                bits += x;
            }
            let read_pj = arch.levels[b].read_pj_per_bit;
            let write_pj = if b + 1 < arch.levels.len() {
                arch.levels[b + 1].write_pj_per_bit
            } else {
                0.0 // delivery into the MAC datapath
            };
            mem_energy_pj.push(bits * (read_pj + write_pj));
            mem_cycles.push(self.boundary_cycles(arch, b, &op_bits, bits, inp.ratios));
        }

        CostReport { mac_energy_pj, mem_energy_pj, compute_cycles, mem_cycles }
    }
}

/// Backend selector carried by `SearchConfig` and [`EvalContext`] — a
/// `Copy` enum rather than a trait object so per-worker contexts stay
/// `Send` and cheap to construct, and so run snapshots can capture the
/// full backend configuration by value.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum CostModel {
    #[default]
    Analytical,
    Contention(ContentionParams),
}

impl CostModel {
    /// Resolve a backend by its CLI/config name.  `"contention"` takes
    /// the representative default [`ContentionParams`]; tune per-level
    /// knobs via the `[cost]` TOML section.
    pub fn by_name(name: &str) -> Result<CostModel, String> {
        match name.to_ascii_lowercase().as_str() {
            "analytical" => Ok(CostModel::Analytical),
            "contention" => Ok(CostModel::Contention(ContentionParams::default())),
            other => Err(format!("unknown cost backend '{other}' (analytical|contention)")),
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        match self {
            CostModel::Analytical => Ok(()),
            CostModel::Contention(p) => p.validate(),
        }
    }
}

impl std::fmt::Display for CostModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(CostBackend::name(self))
    }
}

impl CostBackend for CostModel {
    fn name(&self) -> &'static str {
        match self {
            CostModel::Analytical => Analytical.name(),
            CostModel::Contention(p) => Contention { params: *p }.name(),
        }
    }

    fn boundary_cycles(
        &self,
        arch: &Accelerator,
        b: usize,
        op_bits: &[f64; 3],
        total_bits: f64,
        ratios: &CompressionRatios,
    ) -> f64 {
        match self {
            CostModel::Analytical => {
                Analytical.boundary_cycles(arch, b, op_bits, total_bits, ratios)
            }
            CostModel::Contention(p) => {
                Contention { params: *p }.boundary_cycles(arch, b, op_bits, total_bits, ratios)
            }
        }
    }
}

/// The backend named by `SNIPSNAP_COST_BACKEND` (defaults to analytical
/// when unset).  Tests and benches use this to re-run the whole suite
/// under a second backend in CI; the search itself never consults the
/// environment — backend selection flows through `SearchConfig` so
/// golden fixtures and replayed snapshots stay environment-independent.
pub fn backend_from_env() -> CostModel {
    match std::env::var("SNIPSNAP_COST_BACKEND") {
        Ok(v) => CostModel::by_name(&v).unwrap_or_else(|e| panic!("SNIPSNAP_COST_BACKEND: {e}")),
        Err(_) => CostModel::Analytical,
    }
}

/// Compressed footprint (bits) of one tile — shared by the mapping- and
/// tile-based legality checks so both sum in the same operand order
/// (bit-identical results).
fn footprint_bits(tile: [u64; 3], data_bits: u32, ratios: &CompressionRatios) -> f64 {
    let [tm, tn, tk] = tile;
    Operand::ALL
        .iter()
        .map(|op| op.footprint(tm, tn, tk) as f64 * data_bits as f64 * ratios.get(*op))
        .sum()
}

/// Compressed on-chip footprint (bits) of the tile inside mapping level
/// `b` — the §III-D2 compression-aware legality quantity.
pub fn tile_footprint_bits(
    mapping: &Mapping,
    b: usize,
    data_bits: u32,
    ratios: &CompressionRatios,
) -> f64 {
    let (tm, tn, tk) = mapping.tile_at(b);
    footprint_bits([tm, tn, tk], data_bits, ratios)
}

/// Is `mapping` legal on `arch` given compressed operand sizes?  Double
/// buffering reserves half of each on-chip level.
pub fn mapping_is_legal(
    arch: &Accelerator,
    mapping: &Mapping,
    ratios: &CompressionRatios,
) -> bool {
    debug_assert_eq!(mapping.levels.len(), arch.levels.len());
    for b in 0..mapping.levels.len() - 1 {
        // Tile inside level b is buffered at level b+1 (on-chip).
        let cap = arch.levels[b + 1].capacity_bits as f64 / 2.0;
        if tile_footprint_bits(mapping, b, arch.data_bits, ratios) > cap {
            return false;
        }
    }
    // Spatial unrolling must fit the array axes.
    mapping.spatial.unroll_rows <= arch.mac.spatial_rows
        && mapping.spatial.unroll_cols <= arch.mac.spatial_cols
}

/// [`mapping_is_legal`] evaluated directly on a proto arena row
/// (precomputed per-level tiles + spatial) without materializing a
/// `Mapping`: `tiles[b]` must be the mapping's `tile_at(b)` (as the
/// arena stores them), making this decision bit-identical to the
/// mapping-based check.
pub fn tiles_are_legal(
    arch: &Accelerator,
    tiles: &[[u64; 3]],
    spatial: Spatial,
    ratios: &CompressionRatios,
) -> bool {
    debug_assert_eq!(tiles.len(), arch.levels.len());
    // Tile inside level b is buffered at level b+1 (on-chip) — zip the
    // tiles with the levels shifted by one.
    for (tile, level) in tiles.iter().zip(&arch.levels[1..]) {
        let cap = level.capacity_bits as f64 / 2.0;
        if footprint_bits(*tile, arch.data_bits, ratios) > cap {
            return false;
        }
    }
    spatial.unroll_rows <= arch.mac.spatial_rows && spatial.unroll_cols <= arch.mac.spatial_cols
}

/// Evaluate one design point (uncached: recomputes [`access_counts`]).
pub fn evaluate(
    arch: &Accelerator,
    p: &ProblemDims,
    mapping: &Mapping,
    spec: &SparsitySpec,
    reduction: &ReductionStrategy,
    ratios: &CompressionRatios,
) -> CostReport {
    let ac = access_counts(mapping, p);
    evaluate_from_counts(arch, p, mapping, spec, reduction, ratios, &ac)
}

/// Evaluate one design point from precomputed [`access_counts`] — the
/// memoization seam shared by [`evaluate`] and [`EvalContext`].  This is
/// the **analytical** backend routed through the [`CostBackend`] funnel;
/// the per-operand restructuring inside [`CostBackend::report`] performs
/// the identical f64 operation sequence (same products, same addition
/// association) as the historical inline accumulation, so results are
/// bit-identical to the pre-trait model (pinned by
/// `rust/tests/cost_backends.rs`).
pub fn evaluate_from_counts(
    arch: &Accelerator,
    p: &ProblemDims,
    mapping: &Mapping,
    spec: &SparsitySpec,
    reduction: &ReductionStrategy,
    ratios: &CompressionRatios,
    ac: &AccessCounts,
) -> CostReport {
    Analytical.report(&EvalInputs { arch, p, mapping, spec, reduction, ratios }, ac)
}

/// Hit/miss counters of the memoized [`access_counts`] cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Evaluations served from the cache.
    pub hits: u64,
    /// Evaluations that had to recompute (and then cached) the counts.
    pub misses: u64,
}

impl CacheStats {
    pub fn merge(&mut self, other: CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
    }

    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the cache (0.0 when none ran).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// Cached mappings per context before the cache is cleared and rebuilt.
/// At ~280 bytes/entry (72-byte packed key + inline counts) this bounds
/// a context to a few tens of MB; clearing (rather than evicting) keeps
/// the hot recent protos warm on the very next insert and costs one
/// extra miss per retained mapping.
const EVAL_CACHE_CAP: usize = 1 << 17;

/// Bits per tiling factor in a packed [`MapKey`] level word.
const FACTOR_BITS: u32 = 20;
const FACTOR_MAX: u64 = (1 << FACTOR_BITS) - 1;

fn dim_code(d: LoopDim) -> u64 {
    match d {
        LoopDim::M => 0,
        LoopDim::N => 1,
        LoopDim::K => 2,
    }
}

/// One level packed into a `u64`: three 20-bit factors plus the loop
/// order's first two dims (2 bits each — the third is implied).  Factors
/// are ≥ 1, so a real level word is never 0 and unused trailing slots
/// (zero) cannot collide with it.
fn pack_level(l: &TileLevel) -> u64 {
    let [m, n, k] = l.factors;
    assert!(
        (m | n | k) <= FACTOR_MAX,
        "tiling factor exceeds 2^{FACTOR_BITS}; MapKey cannot represent it"
    );
    m | n << FACTOR_BITS
        | k << (2 * FACTOR_BITS)
        | (dim_code(l.order[0]) << 2 | dim_code(l.order[1])) << (3 * FACTOR_BITS)
}

/// Packed, `Copy` cache key of a full [`Mapping`]: one `u64` per level
/// (factors + order) plus one for the spatial unroll.  Replaces keying
/// the memoized-counts cache by a cloned `Mapping` — lookups hash 9
/// machine words instead of a heap `Vec` of structs, and inserts copy
/// the key instead of cloning the mapping.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MapKey {
    levels: [u64; MAX_LEVELS],
    spatial: u64,
}

/// Pack `mapping` into its cache key.  Panics if the mapping has more
/// than [`MAX_LEVELS`] levels or any factor ≥ 2^20 (far beyond any
/// realistic problem dim; [`EvalContext::new`] checks the dims once up
/// front so the hot path never trips this).
pub fn pack_key(mapping: &Mapping) -> MapKey {
    assert!(mapping.levels.len() <= MAX_LEVELS);
    let mut levels = [0u64; MAX_LEVELS];
    for (slot, l) in levels.iter_mut().zip(&mapping.levels) {
        *slot = pack_level(l);
    }
    let sp = &mapping.spatial;
    assert!((sp.unroll_rows | sp.unroll_cols) <= FACTOR_MAX);
    let spatial = sp.unroll_rows
        | sp.unroll_cols << FACTOR_BITS
        | dim_code(sp.dim_rows) << (2 * FACTOR_BITS)
        | dim_code(sp.dim_cols) << (2 * FACTOR_BITS + 2);
    MapKey { levels, spatial }
}

/// Cross-run memoization seam for cached [`access_counts`] — the
/// persistent memo store behind `snipsnap serve` implements it
/// ([`crate::serve::memo::MemoStore`]).  Implementors are shared across
/// worker threads, so both methods take `&self` and the trait requires
/// `Sync`.  The contract that makes the seam bit-identity-safe: `get`
/// must only ever return counts that some `put` stored for the same
/// key, with every `f64` preserved exactly.
pub trait CountsMemo: Sync {
    /// Previously stored counts for `key`, if any.
    fn get(&self, key: u128) -> Option<AccessCounts>;
    /// Record freshly computed counts for `key`.
    fn put(&self, key: u128, counts: &AccessCounts);
}

/// A [`CountsMemo`] bound to the *scope* it may be consulted under: a
/// caller-computed digest of everything outside the packed [`MapKey`]
/// that the stored counts must be invalidated by.  `access_counts` is a
/// pure function of `(mapping, dims)`, so dims are the minimum;
/// `snipsnap serve` conservatively folds in the arch, workload,
/// cost-backend and quantization config digests (the invalidation key
/// documented in docs/ARCHITECTURE.md "Serving").
#[derive(Clone, Copy)]
pub struct SharedCounts<'m> {
    pub store: &'m dyn CountsMemo,
    pub scope: u64,
}

/// The 128-bit cross-run memo key: FNV-1a over the scope digest and the
/// packed [`MapKey`] words.  128 bits make an accidental collision over
/// a memo store's lifetime negligible (a collision would silently serve
/// wrong counts, so the margin is deliberate).
pub fn memo_key(scope: u64, key: &MapKey) -> u128 {
    let mut h = crate::util::hash::Fnv128::new();
    h.write_u64(scope);
    for w in key.levels {
        h.write_u64(w);
    }
    h.write_u64(key.spatial);
    h.finish()
}

/// Per-operator evaluation context: the invariants every cost-model call
/// shares (accelerator, problem dims, optimization metric) plus a
/// memoized [`access_counts`] cache keyed by the packed [`MapKey`]
/// (tiling factors, loop orders and spatial unroll).
///
/// The cache is sound because `access_counts` is a pure function of
/// `(mapping, dims)`: sparsity spec, reduction strategy and compression
/// ratios only scale the counts downstream, in
/// [`evaluate_from_counts`].  A cached evaluation is therefore
/// bit-identical to the uncached [`evaluate`] path, which is what lets
/// the parallel co-search keep one private context per worker without
/// affecting results (see `docs/SEARCH.md`).
pub struct EvalContext<'a> {
    pub arch: &'a Accelerator,
    pub p: ProblemDims,
    pub metric: Metric,
    /// Cost backend every evaluation dispatches through.  The counts
    /// cache is backend-independent, so this only affects the final
    /// bits→cycles transform.
    pub model: CostModel,
    cache: HashMap<MapKey, AccessCounts>,
    stats: CacheStats,
    /// Optional cross-run store consulted on local-cache misses before
    /// recomputing (and published to after).  Because stored counts are
    /// the exact `f64`s a recompute would produce, binding a store
    /// changes *where* counts come from but never their values — memo-on
    /// and memo-off searches are bit-identical (pinned by
    /// `rust/tests/serve_service.rs`), and `evaluations`/cache counters
    /// are untouched.
    memo: Option<SharedCounts<'a>>,
}

impl<'a> EvalContext<'a> {
    /// Context with the default (analytical) backend — exactly the
    /// historical behavior.
    pub fn new(arch: &'a Accelerator, p: ProblemDims, metric: Metric) -> Self {
        Self::with_model(arch, p, metric, CostModel::Analytical)
    }

    /// Context evaluating through an explicit cost backend.
    pub fn with_model(
        arch: &'a Accelerator,
        p: ProblemDims,
        metric: Metric,
        model: CostModel,
    ) -> Self {
        assert!(
            arch.levels.len() <= MAX_LEVELS,
            "{} has {} memory levels; MAX_LEVELS is {MAX_LEVELS}",
            arch.name,
            arch.levels.len()
        );
        assert!(
            (p.m | p.n | p.k) <= FACTOR_MAX,
            "problem dims {p:?} exceed the 2^{FACTOR_BITS} MapKey factor range"
        );
        EvalContext {
            arch,
            p,
            metric,
            model,
            cache: HashMap::new(),
            stats: CacheStats::default(),
            memo: None,
        }
    }

    /// Bind a shared cross-run counts store (builder-style).  Without a
    /// binding the context behaves exactly as before.
    pub fn with_shared_counts(mut self, memo: SharedCounts<'a>) -> Self {
        self.memo = Some(memo);
        self
    }

    /// Consult the bound cross-run store for counts missing from the
    /// local cache.
    fn memo_fetch(&self, key: &MapKey) -> Option<AccessCounts> {
        self.memo.as_ref().and_then(|m| m.store.get(memo_key(m.scope, key)))
    }

    /// Publish freshly computed counts to the bound cross-run store.
    fn memo_publish(&self, key: &MapKey, ac: &AccessCounts) {
        if let Some(m) = &self.memo {
            m.store.put(memo_key(m.scope, key), ac);
        }
    }

    /// Logical cost-model evaluations requested (cache hits included) —
    /// the exploration-effort metric reported as `evaluations`.  Derived
    /// from the cache counters: every evaluation is exactly one hit or
    /// one miss.
    pub fn evals(&self) -> u64 {
        self.stats.lookups()
    }

    /// Evaluate `mapping`, reusing cached access counts when available.
    pub fn evaluate(
        &mut self,
        mapping: &Mapping,
        spec: &SparsitySpec,
        reduction: &ReductionStrategy,
        ratios: &CompressionRatios,
    ) -> CostReport {
        let model = self.model;
        let key = pack_key(mapping);
        if let Some(ac) = self.cache.get(&key) {
            self.stats.hits += 1;
            let inp = EvalInputs { arch: self.arch, p: &self.p, mapping, spec, reduction, ratios };
            return model.report(&inp, ac);
        }
        self.stats.misses += 1;
        if self.cache.len() >= EVAL_CACHE_CAP {
            self.cache.clear();
        }
        let ac = match self.memo_fetch(&key) {
            Some(ac) => ac,
            None => {
                let ac = access_counts(mapping, &self.p);
                self.memo_publish(&key, &ac);
                ac
            }
        };
        let inp = EvalInputs { arch: self.arch, p: &self.p, mapping, spec, reduction, ratios };
        let r = model.report(&inp, &ac);
        self.cache.insert(key, ac);
        r
    }

    /// Evaluate and score with the context's metric in one call.
    pub fn value(
        &mut self,
        mapping: &Mapping,
        spec: &SparsitySpec,
        reduction: &ReductionStrategy,
        ratios: &CompressionRatios,
    ) -> (CostReport, f64) {
        let r = self.evaluate(mapping, spec, reduction, ratios);
        let v = self.metric.of(&r);
        (r, v)
    }

    /// Try all six loop orders for level `lvl` with every other level
    /// fixed, leave the best one (first-wins on ties, matching the
    /// historical sweep) set in `m`, and return its metric value.
    ///
    /// This is the **incremental order sweep**: boundary-`b` traffic
    /// depends only on orders of levels ≤ `b` (see `docs/SEARCH.md`), so
    /// the fill pass for each trial resumes from a [`FillState`]
    /// snapshot taken after level `lvl - 1` instead of recounting the
    /// whole nest.  Every trial still performs exactly one cache lookup
    /// (and populates the cache on a miss), so `evaluations` and cache
    /// semantics are unchanged versus six separate [`Self::value`]
    /// calls, and a resumed count replays the identical f64 operation
    /// sequence — bit-identical results.
    pub fn sweep_level(
        &mut self,
        m: &mut Mapping,
        lvl: usize,
        spec: &SparsitySpec,
        reduction: &ReductionStrategy,
        ratios: &CompressionRatios,
    ) -> f64 {
        let nlevels = m.levels.len();
        let tiles = tiles_of(m);
        // Prefix over levels < lvl: orders there are fixed during this
        // sweep, so state and fill rows are shared by all six trials.
        let mut prefix_state = FillState::default();
        let mut prefix_fills: InlineVec<[f64; 3], MAX_LEVELS> = InlineVec::new();
        for b in 0..lvl {
            prefix_state.advance(&m.levels[b]);
            prefix_fills.push(prefix_state.row(tiles[b]));
        }
        let model = self.model;
        let mut best: Option<([LoopDim; 3], f64)> = None;
        for ord in crate::dataflow::mapper::ALL_ORDERS {
            m.levels[lvl].order = ord;
            let key = pack_key(m);
            let r = if let Some(ac) = self.cache.get(&key) {
                self.stats.hits += 1;
                let inp =
                    EvalInputs { arch: self.arch, p: &self.p, mapping: m, spec, reduction, ratios };
                model.report(&inp, ac)
            } else {
                self.stats.misses += 1;
                if self.cache.len() >= EVAL_CACHE_CAP {
                    self.cache.clear();
                }
                let ac = match self.memo_fetch(&key) {
                    Some(ac) => ac,
                    None => {
                        let mut ac = AccessCounts { fills: prefix_fills };
                        let mut state = prefix_state;
                        for b in lvl..nlevels {
                            state.advance(&m.levels[b]);
                            ac.fills.push(state.row(tiles[b]));
                        }
                        self.memo_publish(&key, &ac);
                        ac
                    }
                };
                let inp =
                    EvalInputs { arch: self.arch, p: &self.p, mapping: m, spec, reduction, ratios };
                let r = model.report(&inp, &ac);
                self.cache.insert(key, ac);
                r
            };
            let v = self.metric.of(&r);
            if best.map(|(_, b)| v < b).unwrap_or(true) {
                best = Some((ord, v));
            }
        }
        let (ord, v) = best.unwrap();
        m.levels[lvl].order = ord;
        v
    }

    /// Order-independent **lower bound** on the context metric over all
    /// loop-order assignments of the tiling proto described by
    /// `(factors, tiles, spatial)` (a proto-arena row; `tiles[b]` =
    /// `tile_at(b)`).
    ///
    /// Derivation: at boundary `b`, an operand's fill multiplier is the
    /// product of all non-unit loop bounds down to its innermost
    /// *relevant* loop in levels `0..=b` — which is at least the product
    /// of the operand-relevant factors of those levels, whatever the
    /// orders.  Everything order-independent in the cost model (MAC
    /// energy, compute cycles, per-bit energies, footprints, ratios) is
    /// applied exactly as in [`evaluate_from_counts`], with the same
    /// operation association, so monotonicity of f64 rounding makes the
    /// bound safe bit-for-bit: no achievable order evaluates below it.
    /// The search may therefore skip the order sweep for any proto whose
    /// bound already reaches the incumbent best without changing the
    /// result (`docs/SEARCH.md` § pruning).
    ///
    /// The per-boundary cycles dispatch through the context's
    /// [`CostModel`], which keeps the bound true for **every** backend:
    /// each [`CostBackend::boundary_cycles`] implementation is monotone
    /// non-decreasing in every entry of `op_bits` (burst roundup, max,
    /// sum and division by a positive constant all are), so applying it
    /// to the lower-bounded traffic still bounds the achievable cycles
    /// from below — branch-and-bound pruning stays enabled under the
    /// contention backend (`docs/COST.md`, verified by
    /// `rust/tests/prune_correctness.rs`).
    pub fn lower_bound(
        &self,
        factors: &[[u64; 3]],
        tiles: &[[u64; 3]],
        spatial: Spatial,
        spec: &SparsitySpec,
        reduction: &ReductionStrategy,
        ratios: &CompressionRatios,
    ) -> f64 {
        let parts = self.bound_parts(factors, tiles, spatial, spec, reduction, ratios);
        match self.metric {
            Metric::Energy => parts.mac_energy + parts.mem_energy,
            Metric::MemoryEnergy => parts.mem_energy,
            Metric::Latency => parts.compute_cycles.max(parts.worst_mem_cycles),
            Metric::Edp => {
                (parts.mac_energy + parts.mem_energy)
                    * parts.compute_cycles.max(parts.worst_mem_cycles)
            }
            // The frontier's scalar bound is its primary-metric (energy)
            // bound — used for best-first ordering, never for pruning a
            // non-primary metric (that goes through `lower_bound_vec`).
            Metric::Frontier => parts.mac_energy + parts.mem_energy,
        }
    }

    /// Per-metric lower bounds, one entry per [`Metric::SCALARS`] slot,
    /// from **one** pass over the same order-independent traffic
    /// products as [`Self::lower_bound`].
    ///
    /// Each entry is combined from the shared bound components with the
    /// exact f64 expression the scalar bound uses for that metric, so
    /// `lower_bound_vec(..)[m] == lower_bound(..)` bit-for-bit when the
    /// context metric is `Metric::SCALARS[m]` — the same floats, not a
    /// re-derivation (pinned by `rust/tests/properties.rs`).  This is
    /// what lets one arena pass prune every metric of the frontier
    /// search at the cost of a single bound computation.
    pub fn lower_bound_vec(
        &self,
        factors: &[[u64; 3]],
        tiles: &[[u64; 3]],
        spatial: Spatial,
        spec: &SparsitySpec,
        reduction: &ReductionStrategy,
        ratios: &CompressionRatios,
    ) -> [f64; 4] {
        let parts = self.bound_parts(factors, tiles, spatial, spec, reduction, ratios);
        [
            parts.mac_energy + parts.mem_energy,
            parts.mem_energy,
            parts.compute_cycles.max(parts.worst_mem_cycles),
            (parts.mac_energy + parts.mem_energy)
                * parts.compute_cycles.max(parts.worst_mem_cycles),
        ]
    }

    /// The order-independent bound components shared by
    /// [`Self::lower_bound`] and [`Self::lower_bound_vec`]: one
    /// traversal of the proto-arena row producing MAC energy, bounded
    /// memory energy, compute cycles and the worst per-boundary memory
    /// cycles.  Metric-independent by construction, so every metric's
    /// bound combines the identical f64 components.
    fn bound_parts(
        &self,
        factors: &[[u64; 3]],
        tiles: &[[u64; 3]],
        spatial: Spatial,
        spec: &SparsitySpec,
        reduction: &ReductionStrategy,
        ratios: &CompressionRatios,
    ) -> BoundParts {
        let arch = self.arch;
        let data_bits = arch.data_bits as f64;
        let peak_macs = self.p.macs() as f64;
        let mac_energy = peak_macs * reduction.energy_fraction(spec) * arch.mac.pj_per_mac;
        let sp = (spatial.factor(LoopDim::M)
            * spatial.factor(LoopDim::N)
            * spatial.factor(LoopDim::K)) as f64;
        let compute_cycles = peak_macs * reduction.cycle_fraction(spec) / sp;

        let mut loads = [1.0f64; 3];
        let mut mem_energy = 0.0f64;
        let mut worst_mem_cycles = 0.0f64;
        for (b, (f, t)) in factors.iter().zip(tiles).enumerate() {
            for (oi, op) in Operand::ALL.iter().enumerate() {
                let mut rel = 1.0f64;
                for (di, d) in LoopDim::ALL.iter().enumerate() {
                    if op.relevant(*d) {
                        rel *= f[di] as f64;
                    }
                }
                loads[oi] *= rel;
            }
            let [tm, tn, tk] = *t;
            let mut op_bits = [0.0f64; 3];
            for (oi, op) in Operand::ALL.iter().enumerate() {
                let psum = if *op == Operand::O { PSUM_RW } else { 1.0 };
                // Same association order as the fills-based path: the
                // (loads × footprint) product is formed first, exactly
                // like an `AccessCounts` fill row.
                let fill = loads[oi] * op.footprint(tm, tn, tk) as f64;
                op_bits[oi] = fill * data_bits * ratios.get(*op) * psum;
            }
            let mut bits = 0.0f64;
            for x in op_bits {
                bits += x;
            }
            let read_pj = arch.levels[b].read_pj_per_bit;
            let write_pj = if b + 1 < arch.levels.len() {
                arch.levels[b + 1].write_pj_per_bit
            } else {
                0.0
            };
            mem_energy += bits * (read_pj + write_pj);
            let cycles = self.model.boundary_cycles(arch, b, &op_bits, bits, ratios);
            worst_mem_cycles = worst_mem_cycles.max(cycles);
        }
        BoundParts { mac_energy, mem_energy, compute_cycles, worst_mem_cycles }
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.stats
    }
}

/// Order-independent lower-bound components produced by one traversal
/// of a proto-arena row (see [`EvalContext::lower_bound`] for the
/// derivation and the backend-monotonicity argument).
#[derive(Clone, Copy, Debug)]
struct BoundParts {
    mac_energy: f64,
    mem_energy: f64,
    compute_cycles: f64,
    worst_mem_cycles: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::dataflow::{Spatial, TileLevel};
    use crate::sparsity::SparsitySpec;

    fn toy_setup() -> (Accelerator, ProblemDims, Mapping) {
        let arch = presets::arch3();
        let p = ProblemDims::new(64, 64, 64);
        let mapping = Mapping {
            levels: vec![
                TileLevel { factors: [4, 4, 4], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel { factors: [4, 4, 4], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel { factors: [1, 4, 1], order: [LoopDim::N, LoopDim::M, LoopDim::K] },
            ],
            spatial: Spatial {
                dim_rows: LoopDim::M,
                unroll_rows: 4,
                dim_cols: LoopDim::K,
                unroll_cols: 4,
            },
        };
        mapping.validate(&p).unwrap();
        (arch, p, mapping)
    }

    #[test]
    fn dense_evaluation_sane() {
        let (arch, p, mapping) = toy_setup();
        let r = evaluate(
            &arch,
            &p,
            &mapping,
            &SparsitySpec::dense(),
            &ReductionStrategy::NONE,
            &CompressionRatios::DENSE,
        );
        assert!(r.total_energy_pj() > 0.0);
        assert!(r.latency_cycles() > 0.0);
        // Compute cycles = macs / spatial.
        assert_eq!(r.compute_cycles, (64u64 * 64 * 64) as f64 / 16.0);
        // MAC energy = macs * pj.
        assert_eq!(r.mac_energy_pj, (64u64 * 64 * 64) as f64 * arch.mac.pj_per_mac);
    }

    #[test]
    fn skipping_reduces_compute_cycles_and_energy() {
        let (arch, p, mapping) = toy_setup();
        let spec = SparsitySpec::unstructured(0.5, 0.5);
        let dense = evaluate(
            &arch, &p, &mapping, &spec,
            &ReductionStrategy::NONE, &CompressionRatios::DENSE,
        );
        let skip = evaluate(
            &arch, &p, &mapping, &spec,
            &arch.reduction, // Arch3: skipping both
            &CompressionRatios::DENSE,
        );
        assert!(skip.compute_cycles < dense.compute_cycles);
        assert!((skip.compute_cycles / dense.compute_cycles - 0.25).abs() < 1e-9);
        assert!(skip.mac_energy_pj < dense.mac_energy_pj);
        // Memory traffic unchanged by reduction alone.
        assert_eq!(skip.mem_energy_pj, dense.mem_energy_pj);
    }

    #[test]
    fn compression_reduces_memory_energy_not_mac() {
        let (arch, p, mapping) = toy_setup();
        let spec = SparsitySpec::unstructured(0.3, 0.3);
        let dense = evaluate(
            &arch, &p, &mapping, &spec,
            &arch.reduction, &CompressionRatios::DENSE,
        );
        let comp = evaluate(
            &arch, &p, &mapping, &spec,
            &arch.reduction,
            &CompressionRatios { input: 0.4, weight: 0.4 },
        );
        assert!(comp.memory_energy_pj() < dense.memory_energy_pj());
        assert_eq!(comp.mac_energy_pj, dense.mac_energy_pj);
    }

    #[test]
    fn legality_rejects_oversized_tiles() {
        let (arch, _, _) = toy_setup();
        // Whole 1024^3 problem resident on chip: far beyond any level.
        let p = ProblemDims::new(1024, 1024, 1024);
        let mapping = Mapping {
            levels: vec![
                TileLevel { factors: [1, 1, 1], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel { factors: [1, 1, 1], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel {
                    factors: [256, 1024, 256],
                    order: [LoopDim::M, LoopDim::N, LoopDim::K],
                },
            ],
            spatial: Spatial {
                dim_rows: LoopDim::M,
                unroll_rows: 4,
                dim_cols: LoopDim::K,
                unroll_cols: 4,
            },
        };
        mapping.validate(&p).unwrap();
        assert!(!mapping_is_legal(&arch, &mapping, &CompressionRatios::DENSE));
        // Even extreme operand compression cannot help: O stays dense.
        let tiny = CompressionRatios { input: 0.001, weight: 0.001 };
        assert!(!mapping_is_legal(&arch, &mapping, &tiny));
    }

    #[test]
    fn metric_ordering() {
        let (arch, p, mapping) = toy_setup();
        let r = evaluate(
            &arch, &p, &mapping,
            &SparsitySpec::dense(),
            &ReductionStrategy::NONE,
            &CompressionRatios::DENSE,
        );
        assert!(Metric::Energy.of(&r) >= Metric::MemoryEnergy.of(&r));
        assert_eq!(Metric::Edp.of(&r), r.total_energy_pj() * r.latency_cycles());
    }

    /// The cross-run memo seam must be value-transparent: with a store
    /// bound, reports are bit-identical to the unbound path, local cache
    /// counters are untouched, and the scope digest partitions entries.
    #[test]
    fn shared_counts_store_is_value_transparent() {
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Mutex;

        #[derive(Default)]
        struct TestStore {
            map: Mutex<std::collections::HashMap<u128, AccessCounts>>,
            hits: AtomicU64,
            puts: AtomicU64,
        }
        impl CountsMemo for TestStore {
            fn get(&self, key: u128) -> Option<AccessCounts> {
                let got = self.map.lock().unwrap().get(&key).copied();
                if got.is_some() {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                }
                got
            }
            fn put(&self, key: u128, counts: &AccessCounts) {
                self.puts.fetch_add(1, Ordering::Relaxed);
                self.map.lock().unwrap().insert(key, *counts);
            }
        }

        let (arch, p, mapping) = toy_setup();
        let spec = SparsitySpec::unstructured(0.4, 0.6);
        let ratios = CompressionRatios { input: 0.5, weight: 0.7 };
        let store = TestStore::default();
        let scope = 0xfeed;

        let mut plain = EvalContext::new(&arch, p, Metric::Edp);
        let want = plain.evaluate(&mapping, &spec, &arch.reduction, &ratios);

        // Cold store: computes, publishes, matches bit for bit.
        let mut cold = EvalContext::new(&arch, p, Metric::Edp)
            .with_shared_counts(SharedCounts { store: &store, scope });
        assert_eq!(cold.evaluate(&mapping, &spec, &arch.reduction, &ratios), want);
        assert_eq!(store.puts.load(Ordering::Relaxed), 1);
        assert_eq!(store.hits.load(Ordering::Relaxed), 0);

        // Fresh context over a warm store: serves from the store, still
        // identical, and the local cache stats are indistinguishable
        // from a memo-off context (a memo hit stays a local miss).
        let mut warm = EvalContext::new(&arch, p, Metric::Edp)
            .with_shared_counts(SharedCounts { store: &store, scope });
        assert_eq!(warm.evaluate(&mapping, &spec, &arch.reduction, &ratios), want);
        assert_eq!(store.hits.load(Ordering::Relaxed), 1);
        assert_eq!(warm.cache_stats(), CacheStats { hits: 0, misses: 1 });
        assert_eq!(warm.evals(), 1);

        // A different scope must not see the entry (stale-config guard).
        let mut other = EvalContext::new(&arch, p, Metric::Edp)
            .with_shared_counts(SharedCounts { store: &store, scope: scope ^ 1 });
        assert_eq!(other.evaluate(&mapping, &spec, &arch.reduction, &ratios), want);
        assert_eq!(store.hits.load(Ordering::Relaxed), 1, "scope must partition the store");
        assert_eq!(store.puts.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn eval_context_matches_uncached_path_exactly() {
        let (arch, p, mapping) = toy_setup();
        let spec = SparsitySpec::unstructured(0.4, 0.6);
        let ratios = CompressionRatios { input: 0.5, weight: 0.7 };
        let mut ctx = EvalContext::new(&arch, p, Metric::Edp);

        let direct = evaluate(&arch, &p, &mapping, &spec, &arch.reduction, &ratios);
        let first = ctx.evaluate(&mapping, &spec, &arch.reduction, &ratios);
        let second = ctx.evaluate(&mapping, &spec, &arch.reduction, &ratios);
        assert_eq!(first, direct, "cold (miss) path diverged from evaluate()");
        assert_eq!(second, direct, "warm (hit) path diverged from evaluate()");
        assert_eq!(ctx.cache_stats(), CacheStats { hits: 1, misses: 1 });
        assert_eq!(ctx.evals(), 2);

        // Different spec/reduction/ratios share the same cached counts
        // and must still match the uncached path bit for bit.
        let dense_direct = evaluate(
            &arch,
            &p,
            &mapping,
            &SparsitySpec::dense(),
            &ReductionStrategy::NONE,
            &CompressionRatios::DENSE,
        );
        let dense_cached = ctx.evaluate(
            &mapping,
            &SparsitySpec::dense(),
            &ReductionStrategy::NONE,
            &CompressionRatios::DENSE,
        );
        assert_eq!(dense_cached, dense_direct);
        assert_eq!(ctx.cache_stats(), CacheStats { hits: 2, misses: 1 });

        // A different mapping (order flip) is a distinct cache key.
        let mut other = mapping.clone();
        other.levels[0].order = [LoopDim::K, LoopDim::N, LoopDim::M];
        let other_direct = evaluate(&arch, &p, &other, &spec, &arch.reduction, &ratios);
        let other_cached = ctx.evaluate(&other, &spec, &arch.reduction, &ratios);
        assert_eq!(other_cached, other_direct);
        assert_eq!(ctx.cache_stats(), CacheStats { hits: 2, misses: 2 });

        // value() reports the context metric of the same report.
        let (r, v) = ctx.value(&mapping, &spec, &arch.reduction, &ratios);
        assert_eq!(v, Metric::Edp.of(&r));
        assert!(ctx.cache_stats().hit_rate() > 0.5);
    }

    #[test]
    fn map_key_distinguishes_mappings() {
        let (_, _, mapping) = toy_setup();
        let base = pack_key(&mapping);
        assert_eq!(base, pack_key(&mapping), "packing is not deterministic");

        let mut factor = mapping.clone();
        factor.levels[1].factors = [8, 2, 4];
        assert_ne!(base, pack_key(&factor));

        let mut order = mapping.clone();
        order.levels[0].order = [LoopDim::K, LoopDim::N, LoopDim::M];
        assert_ne!(base, pack_key(&order));

        let mut spatial = mapping.clone();
        spatial.spatial.unroll_rows = 2;
        assert_ne!(base, pack_key(&spatial));

        // All six orders of one level pack distinctly.
        let keys: std::collections::HashSet<MapKey> = crate::dataflow::mapper::ALL_ORDERS
            .iter()
            .map(|&ord| {
                let mut m = mapping.clone();
                m.levels[0].order = ord;
                pack_key(&m)
            })
            .collect();
        assert_eq!(keys.len(), 6);

        // Fewer levels (factors folded into one) ≠ more levels.
        let shallow = Mapping {
            levels: vec![TileLevel {
                factors: [16, 64, 16],
                order: [LoopDim::M, LoopDim::N, LoopDim::K],
            }],
            spatial: mapping.spatial,
        };
        assert_ne!(pack_key(&shallow), base);
    }

    #[test]
    fn tiles_are_legal_matches_mapping_is_legal() {
        let (arch, p, legal) = toy_setup();
        let huge = Mapping {
            levels: vec![
                TileLevel { factors: [1, 1, 1], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel { factors: [1, 1, 1], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel { factors: [16, 64, 16], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
            ],
            spatial: legal.spatial,
        };
        huge.validate(&p).unwrap();
        for ratios in [
            CompressionRatios::DENSE,
            CompressionRatios { input: 0.3, weight: 0.6 },
        ] {
            for m in [&legal, &huge] {
                let tiles = tiles_of(m);
                assert_eq!(
                    mapping_is_legal(&arch, m, &ratios),
                    tiles_are_legal(&arch, &tiles, m.spatial, &ratios),
                    "tile- and mapping-based legality disagree"
                );
            }
        }
    }

    #[test]
    fn lower_bound_never_exceeds_any_order_assignment() {
        use crate::dataflow::mapper::ALL_ORDERS;
        let (arch, p, mapping) = toy_setup();
        let spec = SparsitySpec::unstructured(0.5, 0.4);
        let ratios = CompressionRatios { input: 0.6, weight: 0.8 };
        let tiles = tiles_of(&mapping);
        let factors: Vec<[u64; 3]> = mapping.levels.iter().map(|l| l.factors).collect();
        for metric in [Metric::Energy, Metric::MemoryEnergy, Metric::Latency, Metric::Edp] {
            let ctx = EvalContext::new(&arch, p, metric);
            let lb = ctx.lower_bound(
                &factors,
                &tiles,
                mapping.spatial,
                &spec,
                &arch.reduction,
                &ratios,
            );
            assert!(lb > 0.0);
            // Exhaustive over all 6^2 order combos of the two non-trivial
            // levels (level 2 has one non-unit loop; include a couple of
            // its orders anyway).
            for o0 in ALL_ORDERS {
                for o1 in ALL_ORDERS {
                    for o2 in [ALL_ORDERS[0], ALL_ORDERS[5]] {
                        let mut m = mapping.clone();
                        m.levels[0].order = o0;
                        m.levels[1].order = o1;
                        m.levels[2].order = o2;
                        let r = evaluate(&arch, &p, &m, &spec, &arch.reduction, &ratios);
                        let v = metric.of(&r);
                        assert!(
                            lb <= v,
                            "{metric:?}: bound {lb} exceeds achievable {v} at {m}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sweep_level_matches_exhaustive_trials() {
        use crate::dataflow::mapper::ALL_ORDERS;
        let (arch, p, mapping) = toy_setup();
        let spec = SparsitySpec::unstructured(0.4, 0.5);
        let ratios = CompressionRatios { input: 0.7, weight: 0.5 };
        for lvl in 0..mapping.levels.len() {
            // Reference: six plain evaluations, first-wins on ties.
            let mut want: Option<([LoopDim; 3], f64)> = None;
            let mut ref_ctx = EvalContext::new(&arch, p, Metric::Edp);
            for ord in ALL_ORDERS {
                let mut m = mapping.clone();
                m.levels[lvl].order = ord;
                let (_, v) = ref_ctx.value(&m, &spec, &arch.reduction, &ratios);
                if want.map(|(_, b)| v < b).unwrap_or(true) {
                    want = Some((ord, v));
                }
            }
            let (want_ord, want_v) = want.unwrap();

            // Incremental sweep (fresh context: all misses) and a second
            // pass (all hits) must both match bit for bit.
            let mut ctx = EvalContext::new(&arch, p, Metric::Edp);
            for _ in 0..2 {
                let mut m = mapping.clone();
                let v = ctx.sweep_level(&mut m, lvl, &spec, &arch.reduction, &ratios);
                assert_eq!(m.levels[lvl].order, want_ord, "level {lvl}");
                assert_eq!(v.to_bits(), want_v.to_bits(), "level {lvl}");
            }
            assert!(ctx.cache_stats().hits >= 6, "second sweep should hit the cache");
        }
    }

    #[test]
    fn analytical_through_trait_is_bit_identical() {
        // The trait-routed default context vs the free `evaluate`
        // function, and `with_model(Analytical)` vs `new` — all four
        // paths must agree bit for bit (field-level PartialEq on the
        // full report).
        let (arch, p, mapping) = toy_setup();
        let spec = SparsitySpec::unstructured(0.45, 0.55);
        let ratios = CompressionRatios { input: 0.5, weight: 0.8 };
        let direct = evaluate(&arch, &p, &mapping, &spec, &arch.reduction, &ratios);
        let via_trait = Analytical.report(
            &EvalInputs {
                arch: &arch,
                p: &p,
                mapping: &mapping,
                spec: &spec,
                reduction: &arch.reduction,
                ratios: &ratios,
            },
            &access_counts(&mapping, &p),
        );
        assert_eq!(direct, via_trait);
        let mut ctx = EvalContext::with_model(&arch, p, Metric::Edp, CostModel::Analytical);
        assert_eq!(ctx.evaluate(&mapping, &spec, &arch.reduction, &ratios), direct);
        assert_eq!(ctx.model, CostModel::Analytical);
    }

    #[test]
    fn contention_report_dominates_analytical_and_shares_energy() {
        let (arch, p, mapping) = toy_setup();
        let spec = SparsitySpec::unstructured(0.4, 0.6);
        let ratios = CompressionRatios { input: 0.5, weight: 0.7 };
        let model = CostModel::Contention(ContentionParams::default());
        let mut anal = EvalContext::new(&arch, p, Metric::Latency);
        let mut cont = EvalContext::with_model(&arch, p, Metric::Latency, model);
        let ra = anal.evaluate(&mapping, &spec, &arch.reduction, &ratios);
        let rc = cont.evaluate(&mapping, &spec, &arch.reduction, &ratios);
        // Energy model is backend-independent — bit-identical.
        assert_eq!(ra.mac_energy_pj.to_bits(), rc.mac_energy_pj.to_bits());
        assert_eq!(ra.mem_energy_pj, rc.mem_energy_pj);
        assert_eq!(ra.compute_cycles.to_bits(), rc.compute_cycles.to_bits());
        // Memory time dominates, per boundary and in the roofline.
        for (a, c) in ra.mem_cycles.iter().zip(rc.mem_cycles.iter()) {
            assert!(c >= a, "contention boundary time {c} < analytical {a}");
        }
        assert!(rc.latency_cycles() >= ra.latency_cycles());
        assert!(rc.latency_cycles().is_finite());
    }

    #[test]
    fn contention_lower_bound_never_exceeds_any_order_assignment() {
        use crate::dataflow::mapper::ALL_ORDERS;
        let (arch, p, mapping) = toy_setup();
        let spec = SparsitySpec::unstructured(0.5, 0.4);
        let ratios = CompressionRatios { input: 0.6, weight: 0.8 };
        let tiles = tiles_of(&mapping);
        let factors: Vec<[u64; 3]> = mapping.levels.iter().map(|l| l.factors).collect();
        let model = CostModel::Contention(ContentionParams::default());
        for metric in [Metric::Energy, Metric::MemoryEnergy, Metric::Latency, Metric::Edp] {
            let ctx = EvalContext::with_model(&arch, p, metric, model);
            let lb = ctx.lower_bound(
                &factors,
                &tiles,
                mapping.spatial,
                &spec,
                &arch.reduction,
                &ratios,
            );
            assert!(lb > 0.0 && lb.is_finite());
            for o0 in ALL_ORDERS {
                for o1 in ALL_ORDERS {
                    let mut m = mapping.clone();
                    m.levels[0].order = o0;
                    m.levels[1].order = o1;
                    let mut c = EvalContext::with_model(&arch, p, metric, model);
                    let (_, v) = c.value(&m, &spec, &arch.reduction, &ratios);
                    assert!(lb <= v, "{metric:?}: contention bound {lb} exceeds achievable {v}");
                }
            }
        }
    }

    #[test]
    fn cost_model_names_round_trip() {
        assert_eq!(CostModel::by_name("analytical").unwrap(), CostModel::Analytical);
        assert_eq!(
            CostModel::by_name("contention").unwrap(),
            CostModel::Contention(ContentionParams::default())
        );
        assert_eq!(CostModel::by_name("Analytical").unwrap(), CostModel::Analytical);
        let e = CostModel::by_name("bogus").unwrap_err();
        assert!(e.contains("bogus") && e.contains("analytical|contention"), "{e}");
        assert_eq!(CostModel::default(), CostModel::Analytical);
        assert_eq!(CostModel::Analytical.to_string(), "analytical");
        assert_eq!(
            CostModel::Contention(ContentionParams::default()).to_string(),
            "contention"
        );
        CostModel::Analytical.validate().unwrap();
        CostModel::Contention(ContentionParams::default()).validate().unwrap();
    }

    #[test]
    fn edp_and_latency_consistent() {
        let (arch, p, mapping) = toy_setup();
        let r = evaluate(
            &arch, &p, &mapping,
            &SparsitySpec::dense(),
            &ReductionStrategy::NONE,
            &CompressionRatios::DENSE,
        );
        let lat = r.latency_cycles();
        assert!(lat >= r.compute_cycles);
        for &c in &r.mem_cycles {
            assert!(lat >= c);
        }
        assert!(r.latency_seconds(1.0) > 0.0);
    }
}
