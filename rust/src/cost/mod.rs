//! The Evaluator's Cost Model (paper §III-A): energy, latency and EDP of
//! one MatMul under a mapping, a computation-reduction strategy and
//! per-operand compression ratios.
//!
//! Energy: MAC energy scaled by the reduction strategy's energy fraction,
//! plus per-boundary transfer energy (read at the source level + write at
//! the destination) with I/W traffic scaled by their compressed-size
//! ratios (operands move compressed; decompression happens at the PEs).
//! Latency: max of compute cycles (skipping shrinks the effective MAC
//! count) and each boundary's bandwidth-limited cycles — the perfectly
//! double-buffered roofline.  EDP: product.

use crate::arch::Accelerator;
use crate::dataflow::{access_counts, LoopDim, Mapping, Operand, ProblemDims};
use crate::sparsity::{reduction::ReductionStrategy, SparsitySpec};

/// Compressed/dense traffic ratios per operand (outputs move dense).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompressionRatios {
    pub input: f64,
    pub weight: f64,
}

impl CompressionRatios {
    pub const DENSE: CompressionRatios = CompressionRatios { input: 1.0, weight: 1.0 };

    pub fn get(&self, op: Operand) -> f64 {
        match op {
            Operand::I => self.input,
            Operand::W => self.weight,
            Operand::O => 1.0,
        }
    }
}

/// Partial-sum traffic multiplier for the output operand: each fill is a
/// read-modify-write.
const PSUM_RW: f64 = 2.0;

/// Full cost breakdown of one evaluated design point.
#[derive(Clone, Debug, PartialEq)]
pub struct CostReport {
    /// Energy of all MAC operations (pJ).
    pub mac_energy_pj: f64,
    /// Per-boundary memory transfer energy (pJ), outermost first.
    pub mem_energy_pj: Vec<f64>,
    /// Compute-bound cycles.
    pub compute_cycles: f64,
    /// Per-boundary bandwidth-bound cycles, outermost first.
    pub mem_cycles: Vec<f64>,
}

impl CostReport {
    pub fn memory_energy_pj(&self) -> f64 {
        self.mem_energy_pj.iter().sum()
    }

    pub fn total_energy_pj(&self) -> f64 {
        self.mac_energy_pj + self.memory_energy_pj()
    }

    /// Roofline latency in cycles.
    pub fn latency_cycles(&self) -> f64 {
        self.mem_cycles
            .iter()
            .fold(self.compute_cycles, |a, &b| a.max(b))
    }

    pub fn latency_seconds(&self, clock_ghz: f64) -> f64 {
        self.latency_cycles() / (clock_ghz * 1e9)
    }

    /// Energy-delay product (pJ x cycles).
    pub fn edp(&self) -> f64 {
        self.total_energy_pj() * self.latency_cycles()
    }
}

/// Which metric the search optimizes (paper: "the prioritized performance
/// metric ... energy consumption, latency, and energy-delay-product").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    Energy,
    MemoryEnergy,
    Latency,
    Edp,
}

impl Metric {
    pub fn of(&self, r: &CostReport) -> f64 {
        match self {
            Metric::Energy => r.total_energy_pj(),
            Metric::MemoryEnergy => r.memory_energy_pj(),
            Metric::Latency => r.latency_cycles(),
            Metric::Edp => r.edp(),
        }
    }
}

/// Compressed on-chip footprint (bits) of the tile inside mapping level
/// `b` — the §III-D2 compression-aware legality quantity.
pub fn tile_footprint_bits(
    mapping: &Mapping,
    b: usize,
    data_bits: u32,
    ratios: &CompressionRatios,
) -> f64 {
    let (tm, tn, tk) = mapping.tile_at(b);
    Operand::ALL
        .iter()
        .map(|op| op.footprint(tm, tn, tk) as f64 * data_bits as f64 * ratios.get(*op))
        .sum()
}

/// Is `mapping` legal on `arch` given compressed operand sizes?  Double
/// buffering reserves half of each on-chip level.
pub fn mapping_is_legal(
    arch: &Accelerator,
    mapping: &Mapping,
    ratios: &CompressionRatios,
) -> bool {
    debug_assert_eq!(mapping.levels.len(), arch.levels.len());
    for b in 0..mapping.levels.len() - 1 {
        // Tile inside level b is buffered at level b+1 (on-chip).
        let cap = arch.levels[b + 1].capacity_bits as f64 / 2.0;
        if tile_footprint_bits(mapping, b, arch.data_bits, ratios) > cap {
            return false;
        }
    }
    // Spatial unrolling must fit the array axes.
    mapping.spatial.unroll_rows <= arch.mac.spatial_rows
        && mapping.spatial.unroll_cols <= arch.mac.spatial_cols
}

/// Evaluate one design point.
pub fn evaluate(
    arch: &Accelerator,
    p: &ProblemDims,
    mapping: &Mapping,
    spec: &SparsitySpec,
    reduction: &ReductionStrategy,
    ratios: &CompressionRatios,
) -> CostReport {
    let ac = access_counts(mapping, p);
    let data_bits = arch.data_bits as f64;

    // --- MAC compute --------------------------------------------------
    let peak_macs = p.macs() as f64;
    let mac_energy_pj = peak_macs * reduction.energy_fraction(spec) * arch.mac.pj_per_mac;
    let spatial = (mapping.spatial.factor(LoopDim::M)
        * mapping.spatial.factor(LoopDim::N)
        * mapping.spatial.factor(LoopDim::K)) as f64;
    let compute_cycles = peak_macs * reduction.cycle_fraction(spec) / spatial;

    // --- Memory boundaries ---------------------------------------------
    let nb = mapping.levels.len();
    let mut mem_energy_pj = Vec::with_capacity(nb);
    let mut mem_cycles = Vec::with_capacity(nb);
    for b in 0..nb {
        let mut bits = 0.0;
        for (oi, op) in Operand::ALL.iter().enumerate() {
            let psum = if *op == Operand::O { PSUM_RW } else { 1.0 };
            bits += ac.fills[b][oi] * data_bits * ratios.get(*op) * psum;
        }
        let read_pj = arch.levels[b].read_pj_per_bit;
        let write_pj = if b + 1 < arch.levels.len() {
            arch.levels[b + 1].write_pj_per_bit
        } else {
            0.0 // delivery into the MAC datapath
        };
        mem_energy_pj.push(bits * (read_pj + write_pj));
        mem_cycles.push(bits / arch.levels[b].bandwidth_bits_per_cycle);
    }

    CostReport { mac_energy_pj, mem_energy_pj, compute_cycles, mem_cycles }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::dataflow::{Spatial, TileLevel};
    use crate::sparsity::SparsitySpec;

    fn toy_setup() -> (Accelerator, ProblemDims, Mapping) {
        let arch = presets::arch3();
        let p = ProblemDims::new(64, 64, 64);
        let mapping = Mapping {
            levels: vec![
                TileLevel { factors: [4, 4, 4], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel { factors: [4, 4, 4], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel { factors: [1, 4, 1], order: [LoopDim::N, LoopDim::M, LoopDim::K] },
            ],
            spatial: Spatial {
                dim_rows: LoopDim::M,
                unroll_rows: 4,
                dim_cols: LoopDim::K,
                unroll_cols: 4,
            },
        };
        mapping.validate(&p).unwrap();
        (arch, p, mapping)
    }

    #[test]
    fn dense_evaluation_sane() {
        let (arch, p, mapping) = toy_setup();
        let r = evaluate(
            &arch,
            &p,
            &mapping,
            &SparsitySpec::dense(),
            &ReductionStrategy::NONE,
            &CompressionRatios::DENSE,
        );
        assert!(r.total_energy_pj() > 0.0);
        assert!(r.latency_cycles() > 0.0);
        // Compute cycles = macs / spatial.
        assert_eq!(r.compute_cycles, (64u64 * 64 * 64) as f64 / 16.0);
        // MAC energy = macs * pj.
        assert_eq!(r.mac_energy_pj, (64u64 * 64 * 64) as f64 * arch.mac.pj_per_mac);
    }

    #[test]
    fn skipping_reduces_compute_cycles_and_energy() {
        let (arch, p, mapping) = toy_setup();
        let spec = SparsitySpec::unstructured(0.5, 0.5);
        let dense = evaluate(
            &arch, &p, &mapping, &spec,
            &ReductionStrategy::NONE, &CompressionRatios::DENSE,
        );
        let skip = evaluate(
            &arch, &p, &mapping, &spec,
            &arch.reduction, // Arch3: skipping both
            &CompressionRatios::DENSE,
        );
        assert!(skip.compute_cycles < dense.compute_cycles);
        assert!((skip.compute_cycles / dense.compute_cycles - 0.25).abs() < 1e-9);
        assert!(skip.mac_energy_pj < dense.mac_energy_pj);
        // Memory traffic unchanged by reduction alone.
        assert_eq!(skip.mem_energy_pj, dense.mem_energy_pj);
    }

    #[test]
    fn compression_reduces_memory_energy_not_mac() {
        let (arch, p, mapping) = toy_setup();
        let spec = SparsitySpec::unstructured(0.3, 0.3);
        let dense = evaluate(
            &arch, &p, &mapping, &spec,
            &arch.reduction, &CompressionRatios::DENSE,
        );
        let comp = evaluate(
            &arch, &p, &mapping, &spec,
            &arch.reduction,
            &CompressionRatios { input: 0.4, weight: 0.4 },
        );
        assert!(comp.memory_energy_pj() < dense.memory_energy_pj());
        assert_eq!(comp.mac_energy_pj, dense.mac_energy_pj);
    }

    #[test]
    fn legality_rejects_oversized_tiles() {
        let (arch, _, _) = toy_setup();
        // Whole 1024^3 problem resident on chip: far beyond any level.
        let p = ProblemDims::new(1024, 1024, 1024);
        let mapping = Mapping {
            levels: vec![
                TileLevel { factors: [1, 1, 1], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel { factors: [1, 1, 1], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel { factors: [256, 1024, 256], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
            ],
            spatial: Spatial {
                dim_rows: LoopDim::M,
                unroll_rows: 4,
                dim_cols: LoopDim::K,
                unroll_cols: 4,
            },
        };
        mapping.validate(&p).unwrap();
        assert!(!mapping_is_legal(&arch, &mapping, &CompressionRatios::DENSE));
        // Even extreme operand compression cannot help: O stays dense.
        let tiny = CompressionRatios { input: 0.001, weight: 0.001 };
        assert!(!mapping_is_legal(&arch, &mapping, &tiny));
    }

    #[test]
    fn metric_ordering() {
        let (arch, p, mapping) = toy_setup();
        let r = evaluate(
            &arch, &p, &mapping,
            &SparsitySpec::dense(),
            &ReductionStrategy::NONE,
            &CompressionRatios::DENSE,
        );
        assert!(Metric::Energy.of(&r) >= Metric::MemoryEnergy.of(&r));
        assert_eq!(Metric::Edp.of(&r), r.total_energy_pj() * r.latency_cycles());
    }

    #[test]
    fn edp_and_latency_consistent() {
        let (arch, p, mapping) = toy_setup();
        let r = evaluate(
            &arch, &p, &mapping,
            &SparsitySpec::dense(),
            &ReductionStrategy::NONE,
            &CompressionRatios::DENSE,
        );
        let lat = r.latency_cycles();
        assert!(lat >= r.compute_cycles);
        for &c in &r.mem_cycles {
            assert!(lat >= c);
        }
        assert!(r.latency_seconds(1.0) > 0.0);
    }
}
