//! The Evaluator's Cost Model (paper §III-A): energy, latency and EDP of
//! one MatMul under a mapping, a computation-reduction strategy and
//! per-operand compression ratios.
//!
//! Energy: MAC energy scaled by the reduction strategy's energy fraction,
//! plus per-boundary transfer energy (read at the source level + write at
//! the destination) with I/W traffic scaled by their compressed-size
//! ratios (operands move compressed; decompression happens at the PEs).
//! Latency: max of compute cycles (skipping shrinks the effective MAC
//! count) and each boundary's bandwidth-limited cycles — the perfectly
//! double-buffered roofline.  EDP: product.
//!
//! # Memoized evaluation
//!
//! [`access_counts`] depends only on the mapping and problem dims —
//! never on sparsity, reduction strategy or compression ratios — while
//! the search re-evaluates the same mapping once per candidate
//! format/ratio pair (and the order sweep / tile refinement revisit
//! mappings many times within one pair).  [`EvalContext`] exploits that:
//! it owns a per-(tiling, order) cache of [`access_counts`] results
//! keyed by the full [`Mapping`], bundles the per-op invariants (arch,
//! dims, metric) that every evaluator entry point used to thread as
//! separate arguments, and reports [`CacheStats`] hit/miss counters
//! surfaced by the CLI and the bench binaries.  The cached path is
//! bit-identical to [`evaluate`]: both funnel into
//! [`evaluate_from_counts`].

use crate::arch::Accelerator;
use crate::dataflow::{access_counts, AccessCounts, LoopDim, Mapping, Operand, ProblemDims};
use crate::sparsity::{reduction::ReductionStrategy, SparsitySpec};
use std::collections::HashMap;

/// Compressed/dense traffic ratios per operand (outputs move dense).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompressionRatios {
    pub input: f64,
    pub weight: f64,
}

impl CompressionRatios {
    pub const DENSE: CompressionRatios = CompressionRatios { input: 1.0, weight: 1.0 };

    pub fn get(&self, op: Operand) -> f64 {
        match op {
            Operand::I => self.input,
            Operand::W => self.weight,
            Operand::O => 1.0,
        }
    }
}

/// Partial-sum traffic multiplier for the output operand: each fill is a
/// read-modify-write.
const PSUM_RW: f64 = 2.0;

/// Full cost breakdown of one evaluated design point.
#[derive(Clone, Debug, PartialEq)]
pub struct CostReport {
    /// Energy of all MAC operations (pJ).
    pub mac_energy_pj: f64,
    /// Per-boundary memory transfer energy (pJ), outermost first.
    pub mem_energy_pj: Vec<f64>,
    /// Compute-bound cycles.
    pub compute_cycles: f64,
    /// Per-boundary bandwidth-bound cycles, outermost first.
    pub mem_cycles: Vec<f64>,
}

impl CostReport {
    pub fn memory_energy_pj(&self) -> f64 {
        self.mem_energy_pj.iter().sum()
    }

    pub fn total_energy_pj(&self) -> f64 {
        self.mac_energy_pj + self.memory_energy_pj()
    }

    /// Roofline latency in cycles.
    pub fn latency_cycles(&self) -> f64 {
        self.mem_cycles
            .iter()
            .fold(self.compute_cycles, |a, &b| a.max(b))
    }

    pub fn latency_seconds(&self, clock_ghz: f64) -> f64 {
        self.latency_cycles() / (clock_ghz * 1e9)
    }

    /// Energy-delay product (pJ x cycles).
    pub fn edp(&self) -> f64 {
        self.total_energy_pj() * self.latency_cycles()
    }
}

/// Which metric the search optimizes (paper: "the prioritized performance
/// metric ... energy consumption, latency, and energy-delay-product").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    Energy,
    MemoryEnergy,
    Latency,
    Edp,
}

impl Metric {
    pub fn of(&self, r: &CostReport) -> f64 {
        match self {
            Metric::Energy => r.total_energy_pj(),
            Metric::MemoryEnergy => r.memory_energy_pj(),
            Metric::Latency => r.latency_cycles(),
            Metric::Edp => r.edp(),
        }
    }
}

/// Compressed on-chip footprint (bits) of the tile inside mapping level
/// `b` — the §III-D2 compression-aware legality quantity.
pub fn tile_footprint_bits(
    mapping: &Mapping,
    b: usize,
    data_bits: u32,
    ratios: &CompressionRatios,
) -> f64 {
    let (tm, tn, tk) = mapping.tile_at(b);
    Operand::ALL
        .iter()
        .map(|op| op.footprint(tm, tn, tk) as f64 * data_bits as f64 * ratios.get(*op))
        .sum()
}

/// Is `mapping` legal on `arch` given compressed operand sizes?  Double
/// buffering reserves half of each on-chip level.
pub fn mapping_is_legal(
    arch: &Accelerator,
    mapping: &Mapping,
    ratios: &CompressionRatios,
) -> bool {
    debug_assert_eq!(mapping.levels.len(), arch.levels.len());
    for b in 0..mapping.levels.len() - 1 {
        // Tile inside level b is buffered at level b+1 (on-chip).
        let cap = arch.levels[b + 1].capacity_bits as f64 / 2.0;
        if tile_footprint_bits(mapping, b, arch.data_bits, ratios) > cap {
            return false;
        }
    }
    // Spatial unrolling must fit the array axes.
    mapping.spatial.unroll_rows <= arch.mac.spatial_rows
        && mapping.spatial.unroll_cols <= arch.mac.spatial_cols
}

/// Evaluate one design point (uncached: recomputes [`access_counts`]).
pub fn evaluate(
    arch: &Accelerator,
    p: &ProblemDims,
    mapping: &Mapping,
    spec: &SparsitySpec,
    reduction: &ReductionStrategy,
    ratios: &CompressionRatios,
) -> CostReport {
    let ac = access_counts(mapping, p);
    evaluate_from_counts(arch, p, mapping, spec, reduction, ratios, &ac)
}

/// Evaluate one design point from precomputed [`access_counts`] — the
/// memoization seam shared by [`evaluate`] and [`EvalContext`].
pub fn evaluate_from_counts(
    arch: &Accelerator,
    p: &ProblemDims,
    mapping: &Mapping,
    spec: &SparsitySpec,
    reduction: &ReductionStrategy,
    ratios: &CompressionRatios,
    ac: &AccessCounts,
) -> CostReport {
    let data_bits = arch.data_bits as f64;

    // --- MAC compute --------------------------------------------------
    let peak_macs = p.macs() as f64;
    let mac_energy_pj = peak_macs * reduction.energy_fraction(spec) * arch.mac.pj_per_mac;
    let spatial = (mapping.spatial.factor(LoopDim::M)
        * mapping.spatial.factor(LoopDim::N)
        * mapping.spatial.factor(LoopDim::K)) as f64;
    let compute_cycles = peak_macs * reduction.cycle_fraction(spec) / spatial;

    // --- Memory boundaries ---------------------------------------------
    let nb = mapping.levels.len();
    let mut mem_energy_pj = Vec::with_capacity(nb);
    let mut mem_cycles = Vec::with_capacity(nb);
    for b in 0..nb {
        let mut bits = 0.0;
        for (oi, op) in Operand::ALL.iter().enumerate() {
            let psum = if *op == Operand::O { PSUM_RW } else { 1.0 };
            bits += ac.fills[b][oi] * data_bits * ratios.get(*op) * psum;
        }
        let read_pj = arch.levels[b].read_pj_per_bit;
        let write_pj = if b + 1 < arch.levels.len() {
            arch.levels[b + 1].write_pj_per_bit
        } else {
            0.0 // delivery into the MAC datapath
        };
        mem_energy_pj.push(bits * (read_pj + write_pj));
        mem_cycles.push(bits / arch.levels[b].bandwidth_bits_per_cycle);
    }

    CostReport { mac_energy_pj, mem_energy_pj, compute_cycles, mem_cycles }
}

/// Hit/miss counters of the memoized [`access_counts`] cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Evaluations served from the cache.
    pub hits: u64,
    /// Evaluations that had to recompute (and then cached) the counts.
    pub misses: u64,
}

impl CacheStats {
    pub fn merge(&mut self, other: CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
    }

    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the cache (0.0 when none ran).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// Cached mappings per context before the cache is cleared and rebuilt.
/// At roughly 250 bytes/entry this bounds a context to a few tens of MB;
/// clearing (rather than evicting) keeps the hot recent protos warm on
/// the very next insert and costs one extra miss per retained mapping.
const EVAL_CACHE_CAP: usize = 1 << 17;

/// Per-operator evaluation context: the invariants every cost-model call
/// shares (accelerator, problem dims, optimization metric) plus a
/// memoized [`access_counts`] cache keyed by the full [`Mapping`]
/// (tiling factors, loop orders and spatial unroll).
///
/// The cache is sound because `access_counts` is a pure function of
/// `(mapping, dims)`: sparsity spec, reduction strategy and compression
/// ratios only scale the counts downstream, in
/// [`evaluate_from_counts`].  A cached evaluation is therefore
/// bit-identical to the uncached [`evaluate`] path, which is what lets
/// the parallel co-search keep one private context per worker without
/// affecting results (see `docs/SEARCH.md`).
pub struct EvalContext<'a> {
    pub arch: &'a Accelerator,
    pub p: ProblemDims,
    pub metric: Metric,
    cache: HashMap<Mapping, AccessCounts>,
    stats: CacheStats,
}

impl<'a> EvalContext<'a> {
    pub fn new(arch: &'a Accelerator, p: ProblemDims, metric: Metric) -> Self {
        EvalContext {
            arch,
            p,
            metric,
            cache: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    /// Logical cost-model evaluations requested (cache hits included) —
    /// the exploration-effort metric reported as `evaluations`.  Derived
    /// from the cache counters: every evaluation is exactly one hit or
    /// one miss.
    pub fn evals(&self) -> u64 {
        self.stats.lookups()
    }

    /// Evaluate `mapping`, reusing cached access counts when available.
    pub fn evaluate(
        &mut self,
        mapping: &Mapping,
        spec: &SparsitySpec,
        reduction: &ReductionStrategy,
        ratios: &CompressionRatios,
    ) -> CostReport {
        if let Some(ac) = self.cache.get(mapping) {
            self.stats.hits += 1;
            return evaluate_from_counts(self.arch, &self.p, mapping, spec, reduction, ratios, ac);
        }
        self.stats.misses += 1;
        if self.cache.len() >= EVAL_CACHE_CAP {
            self.cache.clear();
        }
        let ac = access_counts(mapping, &self.p);
        let r = evaluate_from_counts(self.arch, &self.p, mapping, spec, reduction, ratios, &ac);
        self.cache.insert(mapping.clone(), ac);
        r
    }

    /// Evaluate and score with the context's metric in one call.
    pub fn value(
        &mut self,
        mapping: &Mapping,
        spec: &SparsitySpec,
        reduction: &ReductionStrategy,
        ratios: &CompressionRatios,
    ) -> (CostReport, f64) {
        let r = self.evaluate(mapping, spec, reduction, ratios);
        let v = self.metric.of(&r);
        (r, v)
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::dataflow::{Spatial, TileLevel};
    use crate::sparsity::SparsitySpec;

    fn toy_setup() -> (Accelerator, ProblemDims, Mapping) {
        let arch = presets::arch3();
        let p = ProblemDims::new(64, 64, 64);
        let mapping = Mapping {
            levels: vec![
                TileLevel { factors: [4, 4, 4], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel { factors: [4, 4, 4], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel { factors: [1, 4, 1], order: [LoopDim::N, LoopDim::M, LoopDim::K] },
            ],
            spatial: Spatial {
                dim_rows: LoopDim::M,
                unroll_rows: 4,
                dim_cols: LoopDim::K,
                unroll_cols: 4,
            },
        };
        mapping.validate(&p).unwrap();
        (arch, p, mapping)
    }

    #[test]
    fn dense_evaluation_sane() {
        let (arch, p, mapping) = toy_setup();
        let r = evaluate(
            &arch,
            &p,
            &mapping,
            &SparsitySpec::dense(),
            &ReductionStrategy::NONE,
            &CompressionRatios::DENSE,
        );
        assert!(r.total_energy_pj() > 0.0);
        assert!(r.latency_cycles() > 0.0);
        // Compute cycles = macs / spatial.
        assert_eq!(r.compute_cycles, (64u64 * 64 * 64) as f64 / 16.0);
        // MAC energy = macs * pj.
        assert_eq!(r.mac_energy_pj, (64u64 * 64 * 64) as f64 * arch.mac.pj_per_mac);
    }

    #[test]
    fn skipping_reduces_compute_cycles_and_energy() {
        let (arch, p, mapping) = toy_setup();
        let spec = SparsitySpec::unstructured(0.5, 0.5);
        let dense = evaluate(
            &arch, &p, &mapping, &spec,
            &ReductionStrategy::NONE, &CompressionRatios::DENSE,
        );
        let skip = evaluate(
            &arch, &p, &mapping, &spec,
            &arch.reduction, // Arch3: skipping both
            &CompressionRatios::DENSE,
        );
        assert!(skip.compute_cycles < dense.compute_cycles);
        assert!((skip.compute_cycles / dense.compute_cycles - 0.25).abs() < 1e-9);
        assert!(skip.mac_energy_pj < dense.mac_energy_pj);
        // Memory traffic unchanged by reduction alone.
        assert_eq!(skip.mem_energy_pj, dense.mem_energy_pj);
    }

    #[test]
    fn compression_reduces_memory_energy_not_mac() {
        let (arch, p, mapping) = toy_setup();
        let spec = SparsitySpec::unstructured(0.3, 0.3);
        let dense = evaluate(
            &arch, &p, &mapping, &spec,
            &arch.reduction, &CompressionRatios::DENSE,
        );
        let comp = evaluate(
            &arch, &p, &mapping, &spec,
            &arch.reduction,
            &CompressionRatios { input: 0.4, weight: 0.4 },
        );
        assert!(comp.memory_energy_pj() < dense.memory_energy_pj());
        assert_eq!(comp.mac_energy_pj, dense.mac_energy_pj);
    }

    #[test]
    fn legality_rejects_oversized_tiles() {
        let (arch, _, _) = toy_setup();
        // Whole 1024^3 problem resident on chip: far beyond any level.
        let p = ProblemDims::new(1024, 1024, 1024);
        let mapping = Mapping {
            levels: vec![
                TileLevel { factors: [1, 1, 1], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel { factors: [1, 1, 1], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel {
                    factors: [256, 1024, 256],
                    order: [LoopDim::M, LoopDim::N, LoopDim::K],
                },
            ],
            spatial: Spatial {
                dim_rows: LoopDim::M,
                unroll_rows: 4,
                dim_cols: LoopDim::K,
                unroll_cols: 4,
            },
        };
        mapping.validate(&p).unwrap();
        assert!(!mapping_is_legal(&arch, &mapping, &CompressionRatios::DENSE));
        // Even extreme operand compression cannot help: O stays dense.
        let tiny = CompressionRatios { input: 0.001, weight: 0.001 };
        assert!(!mapping_is_legal(&arch, &mapping, &tiny));
    }

    #[test]
    fn metric_ordering() {
        let (arch, p, mapping) = toy_setup();
        let r = evaluate(
            &arch, &p, &mapping,
            &SparsitySpec::dense(),
            &ReductionStrategy::NONE,
            &CompressionRatios::DENSE,
        );
        assert!(Metric::Energy.of(&r) >= Metric::MemoryEnergy.of(&r));
        assert_eq!(Metric::Edp.of(&r), r.total_energy_pj() * r.latency_cycles());
    }

    #[test]
    fn eval_context_matches_uncached_path_exactly() {
        let (arch, p, mapping) = toy_setup();
        let spec = SparsitySpec::unstructured(0.4, 0.6);
        let ratios = CompressionRatios { input: 0.5, weight: 0.7 };
        let mut ctx = EvalContext::new(&arch, p, Metric::Edp);

        let direct = evaluate(&arch, &p, &mapping, &spec, &arch.reduction, &ratios);
        let first = ctx.evaluate(&mapping, &spec, &arch.reduction, &ratios);
        let second = ctx.evaluate(&mapping, &spec, &arch.reduction, &ratios);
        assert_eq!(first, direct, "cold (miss) path diverged from evaluate()");
        assert_eq!(second, direct, "warm (hit) path diverged from evaluate()");
        assert_eq!(ctx.cache_stats(), CacheStats { hits: 1, misses: 1 });
        assert_eq!(ctx.evals(), 2);

        // Different spec/reduction/ratios share the same cached counts
        // and must still match the uncached path bit for bit.
        let dense_direct = evaluate(
            &arch,
            &p,
            &mapping,
            &SparsitySpec::dense(),
            &ReductionStrategy::NONE,
            &CompressionRatios::DENSE,
        );
        let dense_cached = ctx.evaluate(
            &mapping,
            &SparsitySpec::dense(),
            &ReductionStrategy::NONE,
            &CompressionRatios::DENSE,
        );
        assert_eq!(dense_cached, dense_direct);
        assert_eq!(ctx.cache_stats(), CacheStats { hits: 2, misses: 1 });

        // A different mapping (order flip) is a distinct cache key.
        let mut other = mapping.clone();
        other.levels[0].order = [LoopDim::K, LoopDim::N, LoopDim::M];
        let other_direct = evaluate(&arch, &p, &other, &spec, &arch.reduction, &ratios);
        let other_cached = ctx.evaluate(&other, &spec, &arch.reduction, &ratios);
        assert_eq!(other_cached, other_direct);
        assert_eq!(ctx.cache_stats(), CacheStats { hits: 2, misses: 2 });

        // value() reports the context metric of the same report.
        let (r, v) = ctx.value(&mapping, &spec, &arch.reduction, &ratios);
        assert_eq!(v, Metric::Edp.of(&r));
        assert!(ctx.cache_stats().hit_rate() > 0.5);
    }

    #[test]
    fn edp_and_latency_consistent() {
        let (arch, p, mapping) = toy_setup();
        let r = evaluate(
            &arch, &p, &mapping,
            &SparsitySpec::dense(),
            &ReductionStrategy::NONE,
            &CompressionRatios::DENSE,
        );
        let lat = r.latency_cycles();
        assert!(lat >= r.compute_cycles);
        for &c in &r.mem_cycles {
            assert!(lat >= c);
        }
        assert!(r.latency_seconds(1.0) > 0.0);
    }
}
