//! The analytical cost backend: the original SnipSnap counts model.
//!
//! Memory time at each boundary is simply `bits / bandwidth` — no
//! transaction rounding, no contention derating, no decompression
//! latency.  This is the default backend and the reference the
//! differential suite (`rust/tests/cost_backends.rs`) pins: routed
//! through the [`CostBackend`] trait it must remain **bit-identical**
//! to the pre-trait evaluation path (same designs, same scores, same
//! evaluation counts through the memo cache).

use crate::arch::Accelerator;
use crate::cost::{CompressionRatios, CostBackend};

/// Zero-sized marker: the flat `bits / bandwidth` memory-time model.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Analytical;

impl CostBackend for Analytical {
    fn name(&self) -> &'static str {
        "analytical"
    }

    /// Exactly the historical transform: one division by the boundary's
    /// peak bandwidth.  `total_bits` is the same index-order operand sum
    /// the pre-trait code accumulated, so this is the identical f64
    /// operation sequence.
    fn boundary_cycles(
        &self,
        arch: &Accelerator,
        b: usize,
        _op_bits: &[f64; 3],
        total_bits: f64,
        _ratios: &CompressionRatios,
    ) -> f64 {
        total_bits / arch.levels[b].bandwidth_bits_per_cycle
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn boundary_cycles_is_bits_over_bandwidth() {
        let arch = presets::arch3();
        let ratios = CompressionRatios::DENSE;
        let op_bits = [1024.0, 2048.0, 512.0];
        let total = 1024.0 + 2048.0 + 512.0;
        for b in 0..arch.levels.len() {
            let got = Analytical.boundary_cycles(&arch, b, &op_bits, total, &ratios);
            let want = total / arch.levels[b].bandwidth_bits_per_cycle;
            assert_eq!(got.to_bits(), want.to_bits(), "boundary {b}");
        }
    }

    #[test]
    fn name_is_stable() {
        assert_eq!(Analytical.name(), "analytical");
    }
}
