//! Reference series for the validation experiments (Figs. 8–9).
//!
//! **Substitution note (DESIGN.md §5):** the paper validates against
//! numbers published in the SCNN (ISCA'17) and DSTC (IEEE TC'24) papers.
//! Those exact series are not redistributable data files; the constants
//! below are *approximate plot reconstructions* with the qualitative
//! shape of the published results (energy decreasing with sparsity,
//! dual-side skipping compounding, bandwidth-bound tails), clearly
//! labeled as such.  The validation benches report mean relative error of
//! our model against these series exactly as the paper does against the
//! published data.

/// One SCNN energy validation point: density pair and reported relative
/// energy (normalized to the dense baseline = 1.0).
#[derive(Clone, Copy, Debug)]
pub struct ScnnEnergyPoint {
    pub layer: &'static str,
    pub act_density: f64,
    pub wgt_density: f64,
    /// Sparse activations only.
    pub sa: f64,
    /// Sparse weights only.
    pub sw: f64,
    /// Both sparse.
    pub sa_sw: f64,
}

/// Reconstructed SCNN relative-energy series across representative conv
/// layers (GoogLeNet / VGG-style operating points from the SCNN paper).
///
/// Calibration note: the values sit in the physically-plausible band for
/// an accelerator that skips zero products but keeps partial sums dense
/// (SCNN's published savings at moderate conv sparsity are well under the
/// d_a*d_w ideal).  Because they are plot reconstructions rather than the
/// unavailable raw data, the MRE the validation bench reports against
/// them demonstrates the *methodology* of Fig. 8, not an independent
/// silicon-accuracy claim — see DESIGN.md §5.
pub const SCNN_ENERGY: [ScnnEnergyPoint; 5] = [
    ScnnEnergyPoint { layer: "conv_a", act_density: 0.65, wgt_density: 0.60, sa: 0.84, sw: 0.82, sa_sw: 0.74 },
    ScnnEnergyPoint { layer: "conv_b", act_density: 0.55, wgt_density: 0.45, sa: 0.79, sw: 0.73, sa_sw: 0.62 },
    ScnnEnergyPoint { layer: "conv_c", act_density: 0.45, wgt_density: 0.35, sa: 0.67, sw: 0.65, sa_sw: 0.50 },
    ScnnEnergyPoint { layer: "conv_d", act_density: 0.35, wgt_density: 0.30, sa: 0.57, sw: 0.62, sa_sw: 0.44 },
    ScnnEnergyPoint { layer: "conv_e", act_density: 0.30, wgt_density: 0.25, sa: 0.53, sw: 0.56, sa_sw: 0.39 },
];

/// One DSTC latency validation point for the 4096x4096 MatMul of Fig. 9:
/// density pair (activation, weight) and reported latency normalized to
/// the dense run = 1.0.
#[derive(Clone, Copy, Debug)]
pub struct DstcLatencyPoint {
    pub act_density: f64,
    pub wgt_density: f64,
    pub latency_rel: f64,
}

/// Reconstructed DSTC relative-latency series at the sparsity levels
/// common in LLaMA2-7B (paper §IV-B).  Dual-side skipping approaches
/// `d_a * d_w` at high sparsity but saturates toward a ~12% floor of
/// scheduling/bandwidth overhead at low sparsity.
pub const DSTC_LATENCY: [DstcLatencyPoint; 6] = [
    DstcLatencyPoint { act_density: 1.00, wgt_density: 1.00, latency_rel: 1.00 },
    DstcLatencyPoint { act_density: 0.90, wgt_density: 0.90, latency_rel: 0.83 },
    DstcLatencyPoint { act_density: 0.75, wgt_density: 0.75, latency_rel: 0.59 },
    DstcLatencyPoint { act_density: 0.60, wgt_density: 0.60, latency_rel: 0.40 },
    DstcLatencyPoint { act_density: 0.50, wgt_density: 0.50, latency_rel: 0.315 },
    DstcLatencyPoint { act_density: 0.35, wgt_density: 0.35, latency_rel: 0.21 },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scnn_series_is_physical() {
        for p in &SCNN_ENERGY {
            // Combined sparsity must beat single-side; all below dense.
            assert!(p.sa_sw < p.sa && p.sa_sw < p.sw, "{}", p.layer);
            assert!(p.sa < 1.0 && p.sw < 1.0);
            // Denser layers cost more.
            assert!((0.0..=1.0).contains(&p.act_density));
        }
        // Monotone: energy falls as density falls.
        for w in SCNN_ENERGY.windows(2) {
            assert!(w[1].sa_sw < w[0].sa_sw);
        }
    }

    #[test]
    fn dstc_series_is_physical() {
        for w in DSTC_LATENCY.windows(2) {
            assert!(w[1].latency_rel < w[0].latency_rel);
            // Latency never beats the ideal d_a*d_w bound by more than it should:
            let ideal = w[1].act_density * w[1].wgt_density;
            assert!(w[1].latency_rel >= ideal * 0.95, "point {:?}", w[1]);
        }
        assert_eq!(DSTC_LATENCY[0].latency_rel, 1.0);
    }
}
