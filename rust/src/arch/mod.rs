//! Hardware configuration system (paper §IV-A1, Table II).
//!
//! An [`Accelerator`] is a MAC array plus a memory hierarchy (outermost
//! level first), a computation-reduction strategy and an optional fixed
//! native compression format.  Presets reproduce Table II's Arch 1–4
//! (Eyeriss- and DSTC-based, scaled 16x MACs / 4x on-chip memory for LLM
//! inference) plus the SCNN and DSTC configs used for validation.

pub mod presets;
pub mod published;
pub mod validation;

use crate::sparsity::reduction::ReductionStrategy;

/// One level of the memory hierarchy.
#[derive(Clone, Debug)]
pub struct MemLevel {
    pub name: String,
    /// Usable capacity in bits; `u64::MAX` for off-chip DRAM.
    pub capacity_bits: u64,
    /// Energy per bit read from this level (pJ).
    pub read_pj_per_bit: f64,
    /// Energy per bit written to this level (pJ).
    pub write_pj_per_bit: f64,
    /// Sustained bandwidth toward the level below, bits per cycle.
    pub bandwidth_bits_per_cycle: f64,
}

impl MemLevel {
    pub fn dram(name: &str, read_pj: f64, write_pj: f64, bw: f64) -> Self {
        MemLevel {
            name: name.to_string(),
            capacity_bits: u64::MAX,
            read_pj_per_bit: read_pj,
            write_pj_per_bit: write_pj,
            bandwidth_bits_per_cycle: bw,
        }
    }

    pub fn is_unbounded(&self) -> bool {
        self.capacity_bits == u64::MAX
    }
}

/// The MAC array.
#[derive(Clone, Debug)]
pub struct MacArray {
    pub total_macs: u64,
    /// Maximum spatial unrolling along the two array axes.
    pub spatial_rows: u64,
    pub spatial_cols: u64,
    /// Energy per MAC operation at the native precision (pJ).
    pub pj_per_mac: f64,
}

/// A complete accelerator configuration.
#[derive(Clone, Debug)]
pub struct Accelerator {
    pub name: String,
    pub mac: MacArray,
    /// Memory hierarchy, outermost (DRAM) first, innermost (regs) last.
    pub levels: Vec<MemLevel>,
    pub reduction: ReductionStrategy,
    /// Operand word width in bits.
    pub data_bits: u32,
    pub clock_ghz: f64,
    /// Fixed native format name, if the hardware supports only one
    /// (most do — paper Challenge 2); `None` lets the engine choose.
    pub native_format: Option<String>,
    /// Area overhead fraction budgeted for (de)compression units, used by
    /// the §IV-E feasibility discussion.
    pub codec_area_overhead: f64,
}

impl Accelerator {
    /// Validate structural invariants.
    pub fn validate(&self) -> Result<(), String> {
        if self.levels.len() < 2 {
            return Err(format!("{}: need at least DRAM + one on-chip level", self.name));
        }
        if !self.levels[0].is_unbounded() {
            return Err(format!("{}: outermost level must be unbounded DRAM", self.name));
        }
        if self.levels[1..].iter().any(|l| l.is_unbounded()) {
            return Err(format!("{}: only the outermost level may be unbounded", self.name));
        }
        if self.mac.spatial_rows * self.mac.spatial_cols > self.mac.total_macs {
            return Err(format!(
                "{}: spatial {}x{} exceeds {} MACs",
                self.name, self.mac.spatial_rows, self.mac.spatial_cols, self.mac.total_macs
            ));
        }
        // Energy must increase monotonically outward (physics sanity).
        for w in self.levels.windows(2) {
            if w[0].read_pj_per_bit < w[1].read_pj_per_bit {
                return Err(format!(
                    "{}: outer level {} cheaper than inner {}",
                    self.name, w[0].name, w[1].name
                ));
            }
        }
        Ok(())
    }

    /// Number of on-chip (bounded) levels.
    pub fn on_chip_levels(&self) -> usize {
        self.levels.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::presets;
    use super::*;
    use crate::sparsity::reduction::{Direction, ReductionStrategy};

    #[test]
    fn presets_validate() {
        for a in presets::all_table2() {
            a.validate().unwrap_or_else(|e| panic!("{e}"));
        }
        presets::scnn().validate().unwrap();
        presets::dstc_validation().validate().unwrap();
    }

    #[test]
    fn table2_matches_paper() {
        let archs = presets::all_table2();
        assert_eq!(archs.len(), 4);
        // MAC counts from Table II (scaled 16x).
        assert_eq!(archs[0].mac.total_macs, 2688);
        assert_eq!(archs[1].mac.total_macs, 2688);
        assert_eq!(archs[2].mac.total_macs, 2048);
        assert_eq!(archs[3].mac.total_macs, 2048);
        // Native formats.
        assert_eq!(archs[0].native_format.as_deref(), Some("RLE"));
        assert_eq!(archs[2].native_format.as_deref(), Some("Bitmap"));
        // Reduction strategies.
        assert_eq!(
            archs[0].reduction,
            ReductionStrategy::gating(Direction::InputOnly)
        );
        assert_eq!(
            archs[2].reduction,
            ReductionStrategy::skipping(Direction::Both)
        );
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut a = presets::arch1();
        a.levels[1].read_pj_per_bit = 1e9; // inner more expensive than DRAM
        assert!(a.validate().is_err());

        let mut b = presets::arch1();
        b.mac.spatial_rows = 10_000;
        assert!(b.validate().is_err());

        let mut c = presets::arch1();
        c.levels.truncate(1);
        assert!(c.validate().is_err());
    }
}
