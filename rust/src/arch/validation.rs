//! Model-validation studies (paper §IV-B, Figs. 8–9): run our cost model
//! on the SCNN and DSTC configurations and compare against the published
//! reference series in [`super::published`], reporting per-point relative
//! error and the mean relative error exactly as the paper does.

use super::published::{DSTC_LATENCY, SCNN_ENERGY};
use super::{presets, Accelerator};
use crate::cost::{CostModel, Metric};
use crate::dataflow::ProblemDims;
use crate::search::{cosearch_workload, FormatMode, SearchConfig};
use crate::sparsity::SparsitySpec;
use crate::util::stats::relative_error;
use crate::workload::{MatMulOp, Workload};

/// One validation row for reporting.
#[derive(Clone, Debug)]
pub struct ValidationRow {
    pub layer: &'static str,
    pub case: &'static str,
    pub density: f64,
    pub reported: f64,
    pub modeled: f64,
    pub rel_err: f64,
}

fn quick_cfg(metric: Metric, cost: CostModel) -> SearchConfig {
    SearchConfig {
        metric,
        mode: FormatMode::Fixed,
        mapper: crate::dataflow::mapper::MapperConfig {
            // The budget is split across spatial configurations; keep it
            // generous enough that each gets full tiling coverage.
            max_candidates: 24_000,
            ..Default::default()
        },
        cost,
        ..Default::default()
    }
}

fn run_energy(arch: &Accelerator, spec: SparsitySpec, dims: ProblemDims) -> f64 {
    let w = Workload {
        name: "validation".into(),
        ops: vec![MatMulOp { name: "op".into(), dims, spec, count: 1 }],
    };
    cosearch_workload(arch, &w, &quick_cfg(Metric::Energy, CostModel::Analytical))
        .total_energy_pj()
}

fn run_latency(arch: &Accelerator, spec: SparsitySpec, dims: ProblemDims, cost: CostModel) -> f64 {
    let w = Workload {
        name: "validation".into(),
        ops: vec![MatMulOp { name: "op".into(), dims, spec, count: 1 }],
    };
    cosearch_workload(arch, &w, &quick_cfg(Metric::Latency, cost)).total_cycles()
}

/// Fig. 8: SCNN energy validation.  Returns (mean relative error, rows).
pub fn scnn_energy_validation() -> (f64, Vec<ValidationRow>) {
    let arch = presets::scnn();
    // Representative conv layer lowered to im2col (a mid-network VGG/
    // GoogLeNet-scale shape, the operating regime of the SCNN paper).
    let dims = ProblemDims::new(28 * 28, 256 * 9, 512);
    let dense = run_energy(&arch, SparsitySpec::dense(), dims);
    let mut rows = Vec::new();
    for p in &SCNN_ENERGY {
        for (case, spec, reported) in [
            ("SA", SparsitySpec::unstructured(p.act_density, 1.0), p.sa),
            ("SW", SparsitySpec::unstructured(1.0, p.wgt_density), p.sw),
            (
                "SA&SW",
                SparsitySpec::unstructured(p.act_density, p.wgt_density),
                p.sa_sw,
            ),
        ] {
            let modeled = run_energy(&arch, spec, dims) / dense;
            rows.push(ValidationRow {
                layer: p.layer,
                case,
                density: p.act_density,
                reported,
                modeled,
                rel_err: relative_error(modeled, reported),
            });
        }
    }
    let mre = crate::util::stats::mean(
        &rows.iter().map(|r| r.rel_err).collect::<Vec<_>>(),
    );
    (mre, rows)
}

/// Fig. 9: DSTC latency validation on the 4096x4096 MatMul, with the
/// default (analytical) cost backend — the paper-comparison series.
pub fn dstc_latency_validation() -> (f64, Vec<ValidationRow>) {
    dstc_latency_validation_with(CostModel::Analytical)
}

/// [`dstc_latency_validation`] under an explicit cost backend.  Each
/// point is still normalized against a dense baseline searched under the
/// **same** backend, so burst and derate constants largely divide out;
/// only the accuracy assertions in the test/bench layers differ (the
/// contention series is validated for finiteness and monotone trend,
/// not pinned to the published MRE envelope — see `docs/COST.md`).
pub fn dstc_latency_validation_with(cost: CostModel) -> (f64, Vec<ValidationRow>) {
    let arch = presets::dstc_validation();
    let dims = ProblemDims::new(4096, 4096, 4096);
    let dense = run_latency(&arch, SparsitySpec::dense(), dims, cost);
    let mut rows = Vec::new();
    for p in &DSTC_LATENCY {
        let spec = SparsitySpec::unstructured(p.act_density, p.wgt_density);
        let modeled = run_latency(&arch, spec, dims, cost) / dense;
        rows.push(ValidationRow {
            layer: "4096x4096",
            case: "latency",
            density: p.act_density,
            reported: p.latency_rel,
            modeled,
            rel_err: relative_error(modeled, p.latency_rel),
        });
    }
    let mre = crate::util::stats::mean(
        &rows.iter().map(|r| r.rel_err).collect::<Vec<_>>(),
    );
    (mre, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scnn_validation_is_reasonably_accurate() {
        let (mre, rows) = scnn_energy_validation();
        assert_eq!(rows.len(), SCNN_ENERGY.len() * 3);
        // The paper reports 4.33%; our independent model must land in the
        // same regime (well under 25%) and the trend must be monotone.
        assert!(mre < 0.25, "SCNN MRE {mre}");
        for r in &rows {
            assert!(r.modeled > 0.0 && r.modeled <= 1.05, "{r:?}");
        }
    }

    #[test]
    fn dstc_validation_is_reasonably_accurate() {
        let (mre, rows) = dstc_latency_validation();
        assert_eq!(rows.len(), DSTC_LATENCY.len());
        assert!(mre < 0.25, "DSTC MRE {mre}");
        // Latency must fall monotonically with density.
        for w in rows.windows(2) {
            assert!(
                w[1].modeled <= w[0].modeled + 1e-9,
                "not monotone: {rows:?}"
            );
        }
    }

    #[test]
    fn dstc_validation_under_contention_is_finite_and_monotone() {
        // The contention series is not pinned to the published MRE (the
        // reference numbers were fit against a flat-bandwidth model);
        // it must stay finite, positive, and keep the density trend.
        let (mre, rows) =
            dstc_latency_validation_with(CostModel::Contention(Default::default()));
        assert_eq!(rows.len(), DSTC_LATENCY.len());
        assert!(mre.is_finite(), "contention MRE {mre}");
        for w in rows.windows(2) {
            assert!(
                w[1].modeled <= w[0].modeled + 1e-9,
                "not monotone: {rows:?}"
            );
        }
        for r in &rows {
            assert!(r.modeled.is_finite() && r.modeled > 0.0, "{r:?}");
        }
    }
}
