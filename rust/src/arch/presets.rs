//! Preset accelerator configurations.
//!
//! Table II of the paper: Arch 1/2 use the Eyeriss memory hierarchy with
//! 2688 MACs (168 PEs x 16 scale-up), Arch 3/4 the DSTC hierarchy with
//! 2048 MACs; all scaled 16x MACs and 4x on-chip memory for LLM inference.
//! Energy-per-access constants follow the widely-used 45 nm numbers from
//! the Eyeriss papers (DRAM ~200 pJ / 16-bit word, global buffer ~6 pJ,
//! local scratchpad ~1 pJ, MAC ~1 pJ), expressed per bit.

use super::{Accelerator, MacArray, MemLevel};
use crate::sparsity::reduction::{Direction, ReductionStrategy};

const WORD: f64 = 16.0;

fn level(name: &str, kib: u64, read_pj_word: f64, write_pj_word: f64, bw_bits: f64) -> MemLevel {
    MemLevel {
        name: name.to_string(),
        capacity_bits: kib * 1024 * 8,
        read_pj_per_bit: read_pj_word / WORD,
        write_pj_per_bit: write_pj_word / WORD,
        bandwidth_bits_per_cycle: bw_bits,
    }
}

/// Eyeriss-style hierarchy scaled 4x on-chip: DRAM -> 432 KiB GLB ->
/// per-PE scratchpads (aggregated).
fn eyeriss_hierarchy() -> Vec<MemLevel> {
    vec![
        MemLevel::dram("DRAM", 200.0 / WORD, 200.0 / WORD, 64.0),
        level("GLB", 432, 6.0, 6.0, 512.0),
        level("SPad", 4 * 168, 1.0, 1.0, 2688.0 * 16.0 * 3.0),
    ]
}

/// DSTC-style hierarchy scaled 4x on-chip: DRAM -> 512 KiB L2 ->
/// 128 KiB operand buffers.
fn dstc_hierarchy() -> Vec<MemLevel> {
    vec![
        MemLevel::dram("DRAM", 200.0 / WORD, 200.0 / WORD, 128.0),
        level("L2", 512, 8.0, 8.0, 1024.0),
        level("OpBuf", 128, 1.5, 1.5, 2048.0 * 16.0 * 3.0),
    ]
}

/// Table II Arch 1: Eyeriss, Gating I->W, RLE.
pub fn arch1() -> Accelerator {
    Accelerator {
        name: "Arch 1 (Eyeriss, Gating I->W, RLE)".to_string(),
        mac: MacArray { total_macs: 2688, spatial_rows: 56, spatial_cols: 48, pj_per_mac: 1.0 },
        levels: eyeriss_hierarchy(),
        reduction: ReductionStrategy::gating(Direction::InputOnly),
        data_bits: 16,
        clock_ghz: 1.0,
        native_format: Some("RLE".to_string()),
        codec_area_overhead: 0.05,
    }
}

/// Table II Arch 2: Eyeriss, Skipping I->W, RLE.
pub fn arch2() -> Accelerator {
    Accelerator {
        name: "Arch 2 (Eyeriss, Skipping I->W, RLE)".to_string(),
        reduction: ReductionStrategy::skipping(Direction::InputOnly),
        ..arch1()
    }
}

/// Table II Arch 3: DSTC, Skipping I<->W, Bitmap — the paper's primary
/// SotA accelerator for the §IV-C format studies.
pub fn arch3() -> Accelerator {
    Accelerator {
        name: "Arch 3 (DSTC, Skipping I<->W, Bitmap)".to_string(),
        mac: MacArray { total_macs: 2048, spatial_rows: 64, spatial_cols: 32, pj_per_mac: 0.8 },
        levels: dstc_hierarchy(),
        reduction: ReductionStrategy::skipping(Direction::Both),
        data_bits: 16,
        clock_ghz: 1.2,
        native_format: Some("Bitmap".to_string()),
        codec_area_overhead: 0.08,
    }
}

/// Table II Arch 4: DSTC, Gating I<->W, Bitmap.
pub fn arch4() -> Accelerator {
    Accelerator {
        name: "Arch 4 (DSTC, Gating I<->W, Bitmap)".to_string(),
        reduction: ReductionStrategy::gating(Direction::Both),
        ..arch3()
    }
}

/// All four Table II architectures, in order.
pub fn all_table2() -> Vec<Accelerator> {
    vec![arch1(), arch2(), arch3(), arch4()]
}

/// SCNN (ISCA'17) as modeled for the Fig. 8 energy validation: 64 PEs x
/// 16 MACs, per-PE buffers, skipping on both operands (SCNN computes only
/// non-zero products via the cartesian-product dataflow).
pub fn scnn() -> Accelerator {
    Accelerator {
        name: "SCNN".to_string(),
        mac: MacArray { total_macs: 1024, spatial_rows: 32, spatial_cols: 32, pj_per_mac: 1.0 },
        levels: vec![
            MemLevel::dram("DRAM", 200.0 / WORD, 200.0 / WORD, 64.0),
            level("GLB", 1024, 6.0, 6.0, 512.0),
            level("PEBuf", 10 * 64, 1.0, 1.0, 1024.0 * 16.0 * 3.0),
        ],
        reduction: ReductionStrategy::skipping(Direction::Both),
        data_bits: 16,
        clock_ghz: 1.0,
        native_format: Some("RLE".to_string()),
        codec_area_overhead: 0.0765, // SCNN reports ~7.65% for sparse logic
    }
}

/// DSTC at its published scale (not the Table II 16x LLM scale-up), used
/// for the Fig. 9 latency validation.
pub fn dstc_validation() -> Accelerator {
    Accelerator {
        name: "DSTC (validation)".to_string(),
        mac: MacArray { total_macs: 512, spatial_rows: 32, spatial_cols: 16, pj_per_mac: 0.8 },
        levels: vec![
            // GPU-class HBM feeding a 512-MAC tensor-core slice: the
            // compute/memory crossover lands near d ~ 0.55, matching the
            // published latency curve's knee.
            MemLevel::dram("DRAM", 200.0 / WORD, 200.0 / WORD, 256.0),
            level("L2", 128, 8.0, 8.0, 2048.0),
            level("OpBuf", 32, 1.5, 1.5, 512.0 * 16.0 * 3.0),
        ],
        reduction: ReductionStrategy::skipping(Direction::Both),
        data_bits: 16,
        clock_ghz: 1.2,
        native_format: Some("Bitmap".to_string()),
        codec_area_overhead: 0.08,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hierarchy_shapes() {
        assert_eq!(arch1().levels.len(), 3);
        assert_eq!(arch3().levels.len(), 3);
        assert_eq!(arch1().on_chip_levels(), 2);
    }

    #[test]
    fn arch2_differs_from_arch1_only_in_reduction() {
        let (a1, a2) = (arch1(), arch2());
        assert_eq!(a1.mac.total_macs, a2.mac.total_macs);
        assert_ne!(a1.reduction, a2.reduction);
    }

    #[test]
    fn dram_is_most_expensive() {
        for a in all_table2() {
            let dram = &a.levels[0];
            for l in &a.levels[1..] {
                assert!(dram.read_pj_per_bit > l.read_pj_per_bit);
            }
        }
    }

    #[test]
    fn spatial_fits_array() {
        for a in all_table2() {
            assert!(a.mac.spatial_rows * a.mac.spatial_cols <= a.mac.total_macs);
        }
    }
}
