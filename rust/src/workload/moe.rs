//! Mixture-of-experts (MoE) workloads: Mixtral-style routed FFNs.
//!
//! The attention path is identical to a dense transformer (built by
//! [`super::llm::attention_ops`], including GQA grouping); the FFN is
//! replaced by per-expert FC1/FC2 operators whose token counts follow
//! top-k routing.  With uniform routing each expert processes
//! `tokens x top_k / experts` tokens per layer in prefill; decode steps
//! route each of the `batch` tokens to `top_k` experts, so the expert
//! MatMuls stay M = batch with their count scaled by `top_k`.  Total
//! expert MACs therefore scale linearly with `top_k` — the invariant
//! the property suite pins.

use super::llm::{attention_ops, push_op, LlmShape, LlmSparsity, Phase};
use super::Workload;

/// MoE transformer shape: the attention backbone plus routing.
#[derive(Clone, Copy, Debug)]
pub struct MoeShape {
    /// Backbone shape; `base.intermediate` is the *per-expert* FFN width.
    pub base: LlmShape,
    /// Routed expert count per layer.
    pub experts: u64,
    /// Experts activated per token.
    pub top_k: u64,
}

/// Build the operator list for one MoE model.
pub fn build_moe(name: &str, shape: MoeShape, sp: LlmSparsity, phase: Phase) -> Workload {
    assert!(
        shape.experts >= 1 && shape.top_k >= 1 && shape.top_k <= shape.experts,
        "need 1 <= top_k {} <= experts {}",
        shape.top_k,
        shape.experts
    );
    let h = shape.base.hidden;
    let f = shape.base.intermediate;
    let l = shape.base.layers;
    let b = phase.batch;
    let mut ops = attention_ops(name, &shape.base, &sp, &phase);

    // --- Prefill: each expert sees tokens x top_k / experts tokens
    // (uniform routing; rounded up when the split is uneven) -----------
    let s = phase.prefill_tokens;
    if s > 0 {
        let routed = b * s * shape.top_k;
        let pe = (routed + shape.experts - 1) / shape.experts;
        let count = l * shape.experts;
        push_op(&mut ops, name, "prefill/expert_fc1", pe, h, f, sp.act_fc1, sp.weight, count);
        push_op(&mut ops, name, "prefill/expert_fc2", pe, f, h, sp.act_fc2, sp.weight, count);
    }

    // --- Decode: batch tokens per step, each routed to top_k experts ---
    let d = phase.decode_tokens;
    if d > 0 {
        let count = l * d * shape.top_k;
        push_op(&mut ops, name, "decode/expert_fc1", b, h, f, sp.act_fc1, sp.weight, count);
        push_op(&mut ops, name, "decode/expert_fc2", b, f, h, sp.act_fc2, sp.weight, count);
    }
    Workload { name: name.to_string(), ops }
}

/// Mixtral-8x7B: LLaMA-style GQA backbone, 8 routed experts, top-2.
pub fn mixtral_8x7b(phase: Phase) -> Workload {
    build_moe(
        "Mixtral-8x7B",
        MoeShape {
            base: LlmShape {
                hidden: 4096,
                intermediate: 14336,
                layers: 32,
                heads: 32,
                kv_heads: 8,
            },
            experts: 8,
            top_k: 2,
        },
        LlmSparsity { act_proj: 0.50, act_fc1: 0.45, act_fc2: 0.18, attn: 0.28, weight: 0.32 },
        phase,
    )
}

/// A reduced MoE shape for tests and the golden suite: 4 experts, top-2,
/// MHA backbone, dims small enough for a sub-second co-search.
pub fn moe_tiny(phase: Phase) -> Workload {
    build_moe(
        "MoE-Tiny",
        MoeShape { base: LlmShape::mha(128, 256, 2, 4), experts: 4, top_k: 2 },
        LlmSparsity { act_proj: 0.55, act_fc1: 0.50, act_fc2: 0.20, attn: 0.30, weight: 0.40 },
        phase,
    )
}

/// The MoE members of the scenario zoo.
pub fn all_moe() -> Vec<Workload> {
    vec![mixtral_8x7b(Phase::default_prefill_decode()), moe_tiny(Phase::new(256, 32))]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moe_zoo_is_populated() {
        for w in all_moe() {
            assert!(!w.ops.is_empty(), "{} has no ops", w.name);
            assert!(w.total_macs() > 0.0);
            assert!(
                w.ops.iter().any(|o| o.name.contains("expert_fc1")),
                "{} has no expert ops",
                w.name
            );
            assert!(
                w.ops.iter().all(|o| !o.name.ends_with("/fc1")),
                "{} still has a dense FFN",
                w.name
            );
        }
    }

    #[test]
    fn expert_tokens_follow_topk_routing() {
        // 256 tokens x top-2 over 4 experts -> 128 tokens per expert.
        let w = moe_tiny(Phase::prefill_only(256));
        let fc1 = w.ops.iter().find(|o| o.name.contains("expert_fc1")).unwrap();
        assert_eq!(fc1.dims.m, 128);
        assert_eq!(fc1.count, 2 * 4); // layers x experts
    }

    #[test]
    fn decode_expert_count_scales_with_topk() {
        let w = moe_tiny(Phase::new(0, 8).with_batch(2));
        let fc1 = w.ops.iter().find(|o| o.name.contains("decode/expert_fc1")).unwrap();
        assert_eq!(fc1.dims.m, 2); // batch
        assert_eq!(fc1.count, 2 * 8 * 2); // layers x steps x top_k
    }

    #[test]
    #[should_panic(expected = "top_k")]
    fn topk_beyond_experts_is_rejected() {
        build_moe(
            "bad",
            MoeShape { base: LlmShape::mha(64, 128, 1, 2), experts: 2, top_k: 3 },
            LlmSparsity { act_proj: 0.5, act_fc1: 0.5, act_fc2: 0.2, attn: 0.3, weight: 0.4 },
            Phase::prefill_only(16),
        );
    }
}
