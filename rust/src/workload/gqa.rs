//! Grouped-query-attention (GQA) workloads: LLaMA3/Mistral-style models
//! where `kv_heads < heads`, so the K/V projections shrink by
//! `heads / kv_heads` and the KV cache read by decode steps is smaller.
//!
//! The score/context MatMuls (QK^T, A x V) keep the full query-head
//! count — GQA shares K/V *across* query heads, it does not remove
//! query work — so only the `kv_proj` ops (and the decode KV traffic)
//! differ from the MHA zoo in [`super::llm`].

use super::llm::{build_llm, LlmShape, LlmSparsity, Phase};
use super::Workload;

/// LLaMA3-8B: 32 query heads over 8 KV heads.
pub fn llama3_8b(phase: Phase) -> Workload {
    build_llm(
        "LLaMA3-8B",
        LlmShape { hidden: 4096, intermediate: 14336, layers: 32, heads: 32, kv_heads: 8 },
        LlmSparsity { act_proj: 0.55, act_fc1: 0.50, act_fc2: 0.22, attn: 0.30, weight: 0.35 },
        phase,
    )
}

/// LLaMA3-70B: 64 query heads over 8 KV heads.
pub fn llama3_70b(phase: Phase) -> Workload {
    build_llm(
        "LLaMA3-70B",
        LlmShape { hidden: 8192, intermediate: 28672, layers: 80, heads: 64, kv_heads: 8 },
        LlmSparsity { act_proj: 0.45, act_fc1: 0.40, act_fc2: 0.12, attn: 0.25, weight: 0.30 },
        phase,
    )
}

/// Mistral-7B: 32 query heads over 8 KV heads.
pub fn mistral_7b(phase: Phase) -> Workload {
    build_llm(
        "Mistral-7B",
        LlmShape { hidden: 4096, intermediate: 14336, layers: 32, heads: 32, kv_heads: 8 },
        LlmSparsity { act_proj: 0.50, act_fc1: 0.45, act_fc2: 0.18, attn: 0.28, weight: 0.32 },
        phase,
    )
}

/// A reduced GQA shape for tests and the golden suite: real 4:1
/// query-to-KV grouping, dims small enough for a sub-second co-search.
pub fn gqa_tiny(phase: Phase) -> Workload {
    build_llm(
        "GQA-Tiny",
        LlmShape { hidden: 256, intermediate: 512, layers: 2, heads: 8, kv_heads: 2 },
        LlmSparsity { act_proj: 0.55, act_fc1: 0.50, act_fc2: 0.20, attn: 0.30, weight: 0.40 },
        phase,
    )
}

/// The GQA members of the scenario zoo.
pub fn all_gqa() -> Vec<Workload> {
    let ph = Phase::default_prefill_decode();
    vec![llama3_8b(ph), llama3_70b(ph), mistral_7b(ph), gqa_tiny(Phase::new(256, 32))]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gqa_zoo_is_populated() {
        for w in all_gqa() {
            assert!(!w.ops.is_empty(), "{} has no ops", w.name);
            assert!(w.total_macs() > 0.0);
            assert!(
                w.ops.iter().any(|o| o.name.contains("kv_proj")),
                "{} has no split K/V projection",
                w.name
            );
        }
    }

    #[test]
    fn llama3_kv_projection_is_quarter_of_q() {
        let w = llama3_8b(Phase::prefill_only(128));
        let q = w.ops.iter().find(|o| o.name.contains("prefill/q_proj")).unwrap();
        let kv = w.ops.iter().find(|o| o.name.contains("prefill/kv_proj")).unwrap();
        // 8/32 grouping: K/V output columns = 2 x (kv_heads/heads) x H
        // = H/2, i.e. half the Q projection's H columns.
        assert_eq!(kv.dims.k * 2, q.dims.k);
    }

    #[test]
    fn gqa_never_exceeds_mha_macs() {
        // Same shape with kv_heads == heads must dominate the GQA MACs.
        let ph = Phase::new(64, 8);
        let gqa = gqa_tiny(ph).total_macs();
        let mha = build_llm(
            "mha-ref",
            LlmShape::mha(256, 512, 2, 8),
            LlmSparsity { act_proj: 0.55, act_fc1: 0.50, act_fc2: 0.20, attn: 0.30, weight: 0.40 },
            ph,
        )
        .total_macs();
        assert!(gqa < mha, "gqa {gqa} vs mha {mha}");
    }
}
