//! Workload zoo (paper §IV-A2): sparse LLMs (LLaMA2, OPT, BERT) and the
//! CNNs used in the DiMO-Sparse comparison (AlexNet, VGG-16, ResNet-18),
//! expressed as lists of MatMul operators with per-operator sparsity.
//!
//! Every operator follows the paper's MatMul convention
//! `O[M][K] = Σ_N I[M][N] × W[N][K]` — N is the reduction dim, `I` holds
//! activations (M×N), `W` holds weights (N×K).

pub mod cnn;
pub mod llm;

use crate::dataflow::ProblemDims;
use crate::sparsity::SparsitySpec;

/// One MatMul operator instance of a workload.
#[derive(Clone, Debug)]
pub struct MatMulOp {
    pub name: String,
    pub dims: ProblemDims,
    pub spec: SparsitySpec,
    /// Times this op executes per end-to-end inference (layers x steps x
    /// heads collapsed into one multiplier).
    pub count: u64,
}

impl MatMulOp {
    pub fn total_macs(&self) -> f64 {
        self.dims.macs() as f64 * self.count as f64
    }
}

/// A complete workload: a named list of operators.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub ops: Vec<MatMulOp>,
}

impl Workload {
    pub fn total_macs(&self) -> f64 {
        self.ops.iter().map(|o| o.total_macs()).sum()
    }

    /// Unique weight-tensor shapes (used by the format engine: formats are
    /// chosen per weight/activation tensor family, not per op instance).
    pub fn op_count(&self) -> usize {
        self.ops.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_is_populated() {
        let all = llm::all_llms();
        assert!(all.len() >= 7);
        for w in &all {
            assert!(!w.ops.is_empty(), "{} has no ops", w.name);
            assert!(w.total_macs() > 0.0);
        }
        let cnns = cnn::all_cnns();
        assert_eq!(cnns.len(), 3);
    }

    #[test]
    fn bigger_models_have_more_macs() {
        let m125 = llm::opt_125m(llm::Phase::default_prefill_decode()).total_macs();
        let m67 = llm::opt_6_7b(llm::Phase::default_prefill_decode()).total_macs();
        let m30 = llm::opt_30b(llm::Phase::default_prefill_decode()).total_macs();
        assert!(m125 < m67 && m67 < m30);
    }
}
