//! Workload zoo: the paper's sparse LLMs (LLaMA2, OPT, BERT — §IV-A2)
//! and DiMO-comparison CNNs (AlexNet, VGG-16, ResNet-18), plus the
//! scenario families beyond the paper's evaluation — grouped-query
//! attention ([`gqa`]), routed-expert FFNs ([`moe`]), batched decode
//! with a KV-cache density knob ([`llm::Phase`]) and N:M structured
//! weight sparsity ([`llm::weight_nm_variant`]) — all expressed as
//! lists of MatMul operators with per-operator sparsity.
//!
//! Every operator follows the paper's MatMul convention
//! `O[M][K] = Σ_N I[M][N] × W[N][K]` — N is the reduction dim, `I` holds
//! activations (M×N), `W` holds weights (N×K).

pub mod cnn;
pub mod gqa;
pub mod llm;
pub mod moe;

use crate::dataflow::ProblemDims;
use crate::sparsity::SparsitySpec;

/// One MatMul operator instance of a workload.
#[derive(Clone, Debug)]
pub struct MatMulOp {
    pub name: String,
    pub dims: ProblemDims,
    pub spec: SparsitySpec,
    /// Times this op executes per end-to-end inference (layers x steps x
    /// heads collapsed into one multiplier).
    pub count: u64,
}

impl MatMulOp {
    pub fn total_macs(&self) -> f64 {
        self.dims.macs() as f64 * self.count as f64
    }
}

/// A complete workload: a named list of operators.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub ops: Vec<MatMulOp>,
}

impl Workload {
    pub fn total_macs(&self) -> f64 {
        self.ops.iter().map(|o| o.total_macs()).sum()
    }

    /// Unique weight-tensor shapes (used by the format engine: formats are
    /// chosen per weight/activation tensor family, not per op instance).
    pub fn op_count(&self) -> usize {
        self.ops.len()
    }
}

/// One representative per scenario family, at reduced sizes — the set
/// the `fig12_scenario_zoo` bench and the golden regression suite run:
/// dense-shaped MHA, GQA, MoE, batched decode, and N:M weight sparsity.
pub fn scenario_zoo() -> Vec<Workload> {
    let small = llm::Phase::new(256, 32);
    vec![
        llm::opt_125m(small),
        gqa::gqa_tiny(small),
        moe::moe_tiny(small),
        llm::decode_tiny(),
        llm::weight_nm_variant(llm::opt_125m(small), 2, 4),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_is_populated() {
        let all = llm::all_llms();
        assert!(all.len() >= 7);
        for w in all.iter().chain(gqa::all_gqa().iter()).chain(moe::all_moe().iter()) {
            assert!(!w.ops.is_empty(), "{} has no ops", w.name);
            assert!(w.total_macs() > 0.0);
        }
        let cnns = cnn::all_cnns();
        assert_eq!(cnns.len(), 3);
    }

    #[test]
    fn scenario_zoo_covers_every_family() {
        let zoo = scenario_zoo();
        assert_eq!(zoo.len(), 5);
        let names: Vec<&str> = zoo.iter().map(|w| w.name.as_str()).collect();
        assert!(names.iter().any(|n| n.contains("OPT-125M") && !n.contains("W2:4")));
        assert!(names.iter().any(|n| n.contains("GQA-Tiny")));
        assert!(names.iter().any(|n| n.contains("MoE-Tiny")));
        assert!(names.iter().any(|n| n.contains("Decode-Tiny")));
        assert!(names.iter().any(|n| n.contains("W2:4")));
        for w in &zoo {
            assert!(w.total_macs() > 0.0, "{}", w.name);
        }
    }

    #[test]
    fn bigger_models_have_more_macs() {
        let m125 = llm::opt_125m(llm::Phase::default_prefill_decode()).total_macs();
        let m67 = llm::opt_6_7b(llm::Phase::default_prefill_decode()).total_macs();
        let m30 = llm::opt_30b(llm::Phase::default_prefill_decode()).total_macs();
        assert!(m125 < m67 && m67 < m30);
    }
}
