//! LLM workloads: transformer-block MatMuls with per-module sparsity.
//!
//! Model shapes follow the public configs (hidden size, FFN intermediate,
//! layers, heads).  Per-module density pairs are synthetic specifications
//! in the ranges the paper cites from [4], [5] (§II-A: FC2 activation
//! sparsity up to 97%, FC1 35–70%; larger models sparser) — see DESIGN.md
//! §5 Substitutions.

use super::{MatMulOp, Workload};
use crate::dataflow::ProblemDims;
use crate::sparsity::{SparsityPattern, SparsitySpec};

/// Inference phase parameters (paper §IV-C: 2048-token prefill +
/// 128-token decoding, following LLMCompass).
#[derive(Clone, Copy, Debug)]
pub struct Phase {
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
}

impl Phase {
    pub fn default_prefill_decode() -> Self {
        Phase { prefill_tokens: 2048, decode_tokens: 128 }
    }

    pub fn prefill_only(tokens: u64) -> Self {
        Phase { prefill_tokens: tokens, decode_tokens: 0 }
    }
}

/// Transformer architecture shape.
#[derive(Clone, Copy, Debug)]
pub struct LlmShape {
    pub hidden: u64,
    pub intermediate: u64,
    pub layers: u64,
    pub heads: u64,
}

/// Per-module sparsity levels (densities).
#[derive(Clone, Copy, Debug)]
pub struct LlmSparsity {
    /// Activation density into Q/K/V/O projections.
    pub act_proj: f64,
    /// Activation density into FC1 (post-attention).
    pub act_fc1: f64,
    /// Activation density into FC2 (post-ReLU/GeLU — the heavy one).
    pub act_fc2: f64,
    /// Density of post-softmax attention probabilities fed to the A x V
    /// MatMul (weak-attention sparsity, cf. DOTA [30]).
    pub attn: f64,
    /// Weight density across all projection/FFN weights.
    pub weight: f64,
}

fn unstr(d: f64) -> SparsityPattern {
    SparsityPattern::Unstructured { density: d }
}

/// Build the operator list for one transformer model.
pub fn build_llm(name: &str, shape: LlmShape, sp: LlmSparsity, phase: Phase) -> Workload {
    let h = shape.hidden;
    let f = shape.intermediate;
    let l = shape.layers;
    let heads = shape.heads;
    let dh = h / heads;
    let mut ops = Vec::new();

    let mut push = |nm: &str, m: u64, n: u64, k: u64, act: f64, wgt: f64, count: u64| {
        if m == 0 || count == 0 {
            return;
        }
        ops.push(MatMulOp {
            name: format!("{name}/{nm}"),
            dims: ProblemDims::new(m, n, k),
            spec: SparsitySpec { input: unstr(act), weight: unstr(wgt) },
            count,
        });
    };

    // --- Prefill phase (batch of S tokens) -----------------------------
    let s = phase.prefill_tokens;
    if s > 0 {
        // QKV fused: X(SxH) x Wqkv(Hx3H); O-proj separate.
        push("prefill/qkv", s, h, 3 * h, sp.act_proj, sp.weight, l);
        // Attention scores and context (per head, dense operands).
        push("prefill/qk", s, dh, s, sp.act_proj, 1.0, l * heads);
        push("prefill/av", s, s, dh, sp.attn, 1.0, l * heads);
        push("prefill/o_proj", s, h, h, sp.act_proj, sp.weight, l);
        push("prefill/fc1", s, h, f, sp.act_fc1, sp.weight, l);
        push("prefill/fc2", s, f, h, sp.act_fc2, sp.weight, l);
    }

    // --- Decode phase: one token per step, weights re-streamed every
    // step (the weight-bound regime; KV length = mean over steps) -------
    let d = phase.decode_tokens;
    if d > 0 {
        let kv = s + d / 2;
        push("decode/qkv", 1, h, 3 * h, sp.act_proj, sp.weight, l * d);
        push("decode/qk", 1, dh, kv, sp.act_proj, 1.0, l * heads * d);
        push("decode/av", 1, kv, dh, sp.attn, 1.0, l * heads * d);
        push("decode/o_proj", 1, h, h, sp.act_proj, sp.weight, l * d);
        push("decode/fc1", 1, h, f, sp.act_fc1, sp.weight, l * d);
        push("decode/fc2", 1, f, h, sp.act_fc2, sp.weight, l * d);
    }

    Workload { name: name.to_string(), ops }
}

// --- The paper's model zoo (§IV-A2) ------------------------------------

pub fn llama2_7b(phase: Phase) -> Workload {
    build_llm(
        "LLaMA2-7B",
        LlmShape { hidden: 4096, intermediate: 11008, layers: 32, heads: 32 },
        LlmSparsity { act_proj: 0.55, act_fc1: 0.50, act_fc2: 0.25, attn: 0.30, weight: 0.35 },
        phase,
    )
}

pub fn llama2_13b(phase: Phase) -> Workload {
    build_llm(
        "LLaMA2-13B",
        LlmShape { hidden: 5120, intermediate: 13824, layers: 40, heads: 40 },
        LlmSparsity { act_proj: 0.50, act_fc1: 0.45, act_fc2: 0.20, attn: 0.28, weight: 0.30 },
        phase,
    )
}

pub fn opt_125m(phase: Phase) -> Workload {
    build_llm(
        "OPT-125M",
        LlmShape { hidden: 768, intermediate: 3072, layers: 12, heads: 12 },
        LlmSparsity { act_proj: 0.60, act_fc1: 0.55, act_fc2: 0.12, attn: 0.35, weight: 0.45 },
        phase,
    )
}

pub fn opt_6_7b(phase: Phase) -> Workload {
    build_llm(
        "OPT-6.7B",
        LlmShape { hidden: 4096, intermediate: 16384, layers: 32, heads: 32 },
        LlmSparsity { act_proj: 0.40, act_fc1: 0.35, act_fc2: 0.05, attn: 0.25, weight: 0.30 },
        phase,
    )
}

pub fn opt_13b(phase: Phase) -> Workload {
    build_llm(
        "OPT-13B",
        LlmShape { hidden: 5120, intermediate: 20480, layers: 40, heads: 40 },
        LlmSparsity { act_proj: 0.35, act_fc1: 0.33, act_fc2: 0.04, attn: 0.22, weight: 0.28 },
        phase,
    )
}

pub fn opt_30b(phase: Phase) -> Workload {
    build_llm(
        "OPT-30B",
        LlmShape { hidden: 7168, intermediate: 28672, layers: 48, heads: 56 },
        LlmSparsity { act_proj: 0.30, act_fc1: 0.30, act_fc2: 0.03, attn: 0.20, weight: 0.25 },
        phase,
    )
}

pub fn bert_base(tokens: u64) -> Workload {
    build_llm(
        "BERT-Base",
        LlmShape { hidden: 768, intermediate: 3072, layers: 12, heads: 12 },
        LlmSparsity { act_proj: 0.30, act_fc1: 0.28, act_fc2: 0.08, attn: 0.22, weight: 0.25 },
        Phase::prefill_only(tokens),
    )
}

/// The five LLMs of Table I / Fig. 10 plus the small models of Fig. 11.
pub fn all_llms() -> Vec<Workload> {
    let ph = Phase::default_prefill_decode();
    vec![
        llama2_7b(ph),
        llama2_13b(ph),
        opt_6_7b(ph),
        opt_13b(ph),
        opt_30b(ph),
        opt_125m(Phase { prefill_tokens: 256, decode_tokens: 32 }),
        bert_base(256),
    ]
}

/// The five large LLMs used in Table I (density overridden to 0.75/0.75
/// by the bench per the paper's setup).
pub fn table1_llms() -> Vec<Workload> {
    let ph = Phase::default_prefill_decode();
    vec![llama2_7b(ph), llama2_13b(ph), opt_6_7b(ph), opt_13b(ph), opt_30b(ph)]
}

/// Override every op's sparsity to a fixed unstructured density pair
/// (Table I sets both densities to 0.75).
pub fn with_uniform_density(mut w: Workload, act: f64, wgt: f64) -> Workload {
    for op in &mut w.ops {
        op.spec = SparsitySpec::unstructured(act, wgt);
    }
    w
}

/// Activation-sparsity variant (paper §IV-C evaluates activation and
/// weight sparsity separately): weights dense, activations keep the
/// model's per-module densities.
pub fn activation_sparse_variant(mut w: Workload) -> Workload {
    w.name = format!("{} (SA)", w.name);
    for op in &mut w.ops {
        op.spec.weight = SparsityPattern::Dense;
    }
    w
}

/// Weight-sparsity variant: activations dense; weights pruned with the
/// model's density as *clustered* block sparsity (global magnitude
/// pruning of LLMs produces correlated zero regions — see [5] and
/// DESIGN.md §5), which is what makes hierarchical formats like the
/// paper's `B(M)-B(N)-B(N)` (§IV-E) pay off.
pub fn weight_sparse_variant(mut w: Workload, block: u64) -> Workload {
    w.name = format!("{} (SW)", w.name);
    for op in &mut w.ops {
        let d = op.spec.weight.density();
        op.spec.input = SparsityPattern::Dense;
        op.spec.weight = if d < 1.0 {
            SparsityPattern::Block { br: block, bc: block, block_density: d }
        } else {
            SparsityPattern::Dense
        };
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama7b_structure() {
        let w = llama2_7b(Phase::default_prefill_decode());
        // 6 prefill + 6 decode op groups.
        assert_eq!(w.ops.len(), 12);
        let qkv = &w.ops[0];
        assert_eq!(qkv.dims, ProblemDims::new(2048, 4096, 3 * 4096));
        assert_eq!(qkv.count, 32);
        // Attention ops occur per layer per head.
        let qk = &w.ops[1];
        assert_eq!(qk.count, 32 * 32);
        assert_eq!(qk.dims.n, 128); // head dim
    }

    #[test]
    fn prefill_only_has_no_decode_ops() {
        let w = bert_base(256);
        assert_eq!(w.ops.len(), 6);
        assert!(w.ops.iter().all(|o| o.name.contains("prefill")));
    }

    #[test]
    fn fc2_is_sparsest_activation() {
        let w = opt_6_7b(Phase::default_prefill_decode());
        let fc2 = w.ops.iter().find(|o| o.name.contains("prefill/fc2")).unwrap();
        let fc1 = w.ops.iter().find(|o| o.name.contains("prefill/fc1")).unwrap();
        assert!(fc2.spec.input.density() < fc1.spec.input.density());
    }

    #[test]
    fn uniform_density_override() {
        let w = with_uniform_density(llama2_7b(Phase::default_prefill_decode()), 0.75, 0.75);
        for op in &w.ops {
            assert_eq!(op.spec.input.density(), 0.75);
            assert_eq!(op.spec.weight.density(), 0.75);
        }
    }

    #[test]
    fn macs_scale_of_7b_prefill_is_plausible() {
        // ~2 * params * tokens for the projection/FFN MACs; 7B params,
        // 2048 tokens -> ~1.4e13 MACs. Attention adds more.
        let w = llama2_7b(Phase::prefill_only(2048));
        let macs = w.total_macs();
        assert!(macs > 5e12 && macs < 5e13, "macs = {macs:.3e}");
    }
}
