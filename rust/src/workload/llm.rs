//! LLM workloads: transformer-block MatMuls with per-module sparsity.
//!
//! Model shapes follow the public configs (hidden size, FFN intermediate,
//! layers, heads, KV heads).  Per-module density pairs are synthetic
//! specifications in the ranges the paper cites from [4], [5] (§II-A:
//! FC2 activation sparsity up to 97%, FC1 35–70%; larger models sparser)
//! — see DESIGN.md §5 Substitutions.
//!
//! The builders here cover the dense-shaped MHA zoo of the paper
//! (§IV-A2) plus the scenario knobs the co-search exercises beyond it:
//! grouped-query attention ([`LlmShape::kv_heads`], presets in
//! [`super::gqa`]), routed-expert FFNs ([`super::moe`]), batched decode
//! and KV-cache density ([`Phase::batch`], [`Phase::kv_density`]), and
//! N:M structured weight sparsity ([`weight_nm_variant`]).

use super::{MatMulOp, Workload};
use crate::dataflow::ProblemDims;
use crate::sparsity::{validate_density, SparsityPattern, SparsitySpec};
use anyhow::{anyhow, Result};

/// Inference phase parameters (paper §IV-C: 2048-token prefill +
/// 128-token decoding, following LLMCompass), extended with the batch
/// size and KV-cache density scenario knobs.
#[derive(Clone, Copy, Debug)]
pub struct Phase {
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    /// Concurrent sequences.  Prefill token batches flatten into the M
    /// dim (`M = batch x prefill_tokens`); decode projections become
    /// M = batch MatMuls per step instead of degenerate M = 1 GEMVs.
    pub batch: u64,
    /// Density of the V operand of the A x V MatMul, modeling a
    /// quantized/pruned KV cache (1.0 = full-precision cache).
    pub kv_density: f64,
}

impl Phase {
    /// A phase with the given token counts, batch 1 and a dense KV cache.
    pub fn new(prefill_tokens: u64, decode_tokens: u64) -> Self {
        Phase { prefill_tokens, decode_tokens, batch: 1, kv_density: 1.0 }
    }

    pub fn default_prefill_decode() -> Self {
        Phase::new(2048, 128)
    }

    pub fn prefill_only(tokens: u64) -> Self {
        Phase::new(tokens, 0)
    }

    /// Set the number of concurrent sequences (must be >= 1).
    pub fn with_batch(mut self, batch: u64) -> Self {
        self.batch = batch;
        self
    }

    /// Set the KV-cache density knob (must lie in `(0, 1]`).
    pub fn with_kv_density(mut self, kv_density: f64) -> Self {
        self.kv_density = kv_density;
        self
    }
}

/// Transformer architecture shape.
#[derive(Clone, Copy, Debug)]
pub struct LlmShape {
    pub hidden: u64,
    pub intermediate: u64,
    pub layers: u64,
    /// Query heads.
    pub heads: u64,
    /// K/V heads; `kv_heads == heads` is classic MHA, `kv_heads < heads`
    /// is grouped-query attention — the K/V projections shrink by
    /// `heads / kv_heads` while the score/context MatMuls are unchanged
    /// (every query head still attends over its group's K/V).
    pub kv_heads: u64,
}

impl LlmShape {
    /// Classic multi-head attention shape (`kv_heads == heads`).
    pub fn mha(hidden: u64, intermediate: u64, layers: u64, heads: u64) -> Self {
        LlmShape { hidden, intermediate, layers, heads, kv_heads: heads }
    }
}

/// Per-module sparsity levels (densities).
#[derive(Clone, Copy, Debug)]
pub struct LlmSparsity {
    /// Activation density into Q/K/V/O projections.
    pub act_proj: f64,
    /// Activation density into FC1 (post-attention).
    pub act_fc1: f64,
    /// Activation density into FC2 (post-ReLU/GeLU — the heavy one).
    pub act_fc2: f64,
    /// Density of post-softmax attention probabilities fed to the A x V
    /// MatMul (weak-attention sparsity, cf. DOTA [30]).
    pub attn: f64,
    /// Weight density across all projection/FFN weights.
    pub weight: f64,
}

fn unstr(d: f64) -> SparsityPattern {
    SparsityPattern::Unstructured { density: d }
}

// The argument list mirrors the op-table row (dims + densities + count);
// a params struct would just rename the same nine fields.
#[allow(clippy::too_many_arguments)]
pub(crate) fn push_op(
    ops: &mut Vec<MatMulOp>,
    model: &str,
    nm: &str,
    m: u64,
    n: u64,
    k: u64,
    act: f64,
    wgt: f64,
    count: u64,
) {
    if m == 0 || count == 0 {
        return;
    }
    ops.push(MatMulOp {
        name: format!("{model}/{nm}"),
        dims: ProblemDims::new(m, n, k),
        spec: SparsitySpec { input: unstr(act), weight: unstr(wgt) },
        count,
    });
}

fn check_shape_and_phase(shape: &LlmShape, phase: &Phase) {
    assert!(
        shape.kv_heads >= 1 && shape.kv_heads <= shape.heads && shape.heads % shape.kv_heads == 0,
        "kv_heads {} must divide heads {}",
        shape.kv_heads,
        shape.heads
    );
    assert!(shape.heads >= 1 && shape.hidden % shape.heads == 0, "heads must divide hidden");
    assert!(phase.batch >= 1, "batch must be >= 1");
    assert!(
        phase.kv_density > 0.0 && phase.kv_density <= 1.0,
        "kv_density {} out of range (0, 1]",
        phase.kv_density
    );
}

/// Attention-path operators (Q/K/V projections, QK^T scores, A x V
/// context, O projection) for both phases.  With `kv_heads == heads`
/// the Q/K/V projections fuse into one `H x 3H` MatMul; under GQA they
/// split into a Q projection and a smaller K/V projection of
/// `2 x kv_heads x head_dim` output columns.
pub fn attention_ops(
    model: &str,
    shape: &LlmShape,
    sp: &LlmSparsity,
    phase: &Phase,
) -> Vec<MatMulOp> {
    check_shape_and_phase(shape, phase);
    let h = shape.hidden;
    let l = shape.layers;
    let heads = shape.heads;
    let kvh = shape.kv_heads;
    let dh = h / heads;
    // GQA K/V projection output columns: K and V for each KV head.
    let kvc = 2 * kvh * dh;
    let b = phase.batch;
    let mut ops = Vec::new();

    // --- Prefill phase (batch of B x S tokens) -------------------------
    let s = phase.prefill_tokens;
    if s > 0 {
        let m = b * s;
        if kvh == heads {
            // QKV fused: X(MxH) x Wqkv(Hx3H); O-proj separate.
            push_op(&mut ops, model, "prefill/qkv", m, h, 3 * h, sp.act_proj, sp.weight, l);
        } else {
            push_op(&mut ops, model, "prefill/q_proj", m, h, h, sp.act_proj, sp.weight, l);
            push_op(&mut ops, model, "prefill/kv_proj", m, h, kvc, sp.act_proj, sp.weight, l);
        }
        // Attention scores and context (per head, per sequence).
        push_op(&mut ops, model, "prefill/qk", s, dh, s, sp.act_proj, 1.0, l * heads * b);
        push_op(&mut ops, model, "prefill/av", s, s, dh, sp.attn, phase.kv_density, l * heads * b);
        push_op(&mut ops, model, "prefill/o_proj", m, h, h, sp.act_proj, sp.weight, l);
    }

    // --- Decode phase: `batch` tokens per step, weights re-streamed
    // every step (the weight-bound regime; KV length = mean over steps) -
    let d = phase.decode_tokens;
    if d > 0 {
        let kv = (s + d / 2).max(1);
        if kvh == heads {
            push_op(&mut ops, model, "decode/qkv", b, h, 3 * h, sp.act_proj, sp.weight, l * d);
        } else {
            push_op(&mut ops, model, "decode/q_proj", b, h, h, sp.act_proj, sp.weight, l * d);
            push_op(&mut ops, model, "decode/kv_proj", b, h, kvc, sp.act_proj, sp.weight, l * d);
        }
        push_op(&mut ops, model, "decode/qk", 1, dh, kv, sp.act_proj, 1.0, l * heads * d * b);
        let kv_d = phase.kv_density;
        push_op(&mut ops, model, "decode/av", 1, kv, dh, sp.attn, kv_d, l * heads * d * b);
        push_op(&mut ops, model, "decode/o_proj", b, h, h, sp.act_proj, sp.weight, l * d);
    }
    ops
}

/// Dense-FFN operators (FC1/FC2) for both phases.  MoE models replace
/// these with routed per-expert ops — see [`super::moe`].
pub fn ffn_ops(model: &str, shape: &LlmShape, sp: &LlmSparsity, phase: &Phase) -> Vec<MatMulOp> {
    check_shape_and_phase(shape, phase);
    let h = shape.hidden;
    let f = shape.intermediate;
    let l = shape.layers;
    let b = phase.batch;
    let mut ops = Vec::new();
    let s = phase.prefill_tokens;
    if s > 0 {
        push_op(&mut ops, model, "prefill/fc1", b * s, h, f, sp.act_fc1, sp.weight, l);
        push_op(&mut ops, model, "prefill/fc2", b * s, f, h, sp.act_fc2, sp.weight, l);
    }
    let d = phase.decode_tokens;
    if d > 0 {
        push_op(&mut ops, model, "decode/fc1", b, h, f, sp.act_fc1, sp.weight, l * d);
        push_op(&mut ops, model, "decode/fc2", b, f, h, sp.act_fc2, sp.weight, l * d);
    }
    ops
}

/// Build the operator list for one dense-FFN transformer model
/// (attention ops first, then the FFN ops).
pub fn build_llm(name: &str, shape: LlmShape, sp: LlmSparsity, phase: Phase) -> Workload {
    let mut ops = attention_ops(name, &shape, &sp, &phase);
    ops.extend(ffn_ops(name, &shape, &sp, &phase));
    Workload { name: name.to_string(), ops }
}

// --- The paper's model zoo (§IV-A2) ------------------------------------

pub fn llama2_7b(phase: Phase) -> Workload {
    build_llm(
        "LLaMA2-7B",
        LlmShape::mha(4096, 11008, 32, 32),
        LlmSparsity { act_proj: 0.55, act_fc1: 0.50, act_fc2: 0.25, attn: 0.30, weight: 0.35 },
        phase,
    )
}

pub fn llama2_13b(phase: Phase) -> Workload {
    build_llm(
        "LLaMA2-13B",
        LlmShape::mha(5120, 13824, 40, 40),
        LlmSparsity { act_proj: 0.50, act_fc1: 0.45, act_fc2: 0.20, attn: 0.28, weight: 0.30 },
        phase,
    )
}

pub fn opt_125m(phase: Phase) -> Workload {
    build_llm(
        "OPT-125M",
        LlmShape::mha(768, 3072, 12, 12),
        LlmSparsity { act_proj: 0.60, act_fc1: 0.55, act_fc2: 0.12, attn: 0.35, weight: 0.45 },
        phase,
    )
}

pub fn opt_6_7b(phase: Phase) -> Workload {
    build_llm(
        "OPT-6.7B",
        LlmShape::mha(4096, 16384, 32, 32),
        LlmSparsity { act_proj: 0.40, act_fc1: 0.35, act_fc2: 0.05, attn: 0.25, weight: 0.30 },
        phase,
    )
}

pub fn opt_13b(phase: Phase) -> Workload {
    build_llm(
        "OPT-13B",
        LlmShape::mha(5120, 20480, 40, 40),
        LlmSparsity { act_proj: 0.35, act_fc1: 0.33, act_fc2: 0.04, attn: 0.22, weight: 0.28 },
        phase,
    )
}

pub fn opt_30b(phase: Phase) -> Workload {
    build_llm(
        "OPT-30B",
        LlmShape::mha(7168, 28672, 48, 56),
        LlmSparsity { act_proj: 0.30, act_fc1: 0.30, act_fc2: 0.03, attn: 0.20, weight: 0.25 },
        phase,
    )
}

/// BERT-Base over an arbitrary phase (encoder models normally run
/// prefill-only — see [`bert_base`]).
pub fn bert_base_phase(phase: Phase) -> Workload {
    build_llm(
        "BERT-Base",
        LlmShape::mha(768, 3072, 12, 12),
        LlmSparsity { act_proj: 0.30, act_fc1: 0.28, act_fc2: 0.08, attn: 0.22, weight: 0.25 },
        phase,
    )
}

pub fn bert_base(tokens: u64) -> Workload {
    bert_base_phase(Phase::prefill_only(tokens))
}

/// The Decode-Tiny shape/sparsity over an arbitrary phase (used by the
/// config layer when the preset's phase knobs are overridden).
pub fn decode_tiny_phase(name: &str, phase: Phase) -> Workload {
    build_llm(
        name,
        LlmShape::mha(256, 512, 2, 4),
        LlmSparsity { act_proj: 0.60, act_fc1: 0.55, act_fc2: 0.20, attn: 0.35, weight: 0.45 },
        phase,
    )
}

/// A small decode-only batched scenario: 4 concurrent sequences, a
/// quantized (0.5-density) KV cache, tiny shape — quick enough for tests
/// and the golden suite while exercising the batch > 1 decode path.
pub fn decode_tiny() -> Workload {
    decode_tiny_phase(
        "Decode-Tiny (b=4, KV 0.5)",
        Phase::new(0, 16).with_batch(4).with_kv_density(0.5),
    )
}

/// The five LLMs of Table I / Fig. 10 plus the small models of Fig. 11.
pub fn all_llms() -> Vec<Workload> {
    let ph = Phase::default_prefill_decode();
    vec![
        llama2_7b(ph),
        llama2_13b(ph),
        opt_6_7b(ph),
        opt_13b(ph),
        opt_30b(ph),
        opt_125m(Phase::new(256, 32)),
        bert_base(256),
    ]
}

/// The five large LLMs used in Table I (density overridden to 0.75/0.75
/// by the bench per the paper's setup).
pub fn table1_llms() -> Vec<Workload> {
    let ph = Phase::default_prefill_decode();
    vec![llama2_7b(ph), llama2_13b(ph), opt_6_7b(ph), opt_13b(ph), opt_30b(ph)]
}

/// Override every op's sparsity to a fixed unstructured density pair
/// (Table I sets both densities to 0.75).  Densities outside `(0, 1]`
/// are rejected — a zero or negative density would silently zero the
/// compute-reduction model, and a density above 1 inflates costs.
pub fn with_uniform_density(mut w: Workload, act: f64, wgt: f64) -> Result<Workload> {
    validate_density(act).map_err(|e| anyhow!("activation {e}"))?;
    validate_density(wgt).map_err(|e| anyhow!("weight {e}"))?;
    for op in &mut w.ops {
        op.spec = SparsitySpec::unstructured(act, wgt);
    }
    Ok(w)
}

/// Activation-sparsity variant (paper §IV-C evaluates activation and
/// weight sparsity separately): weights dense, activations keep the
/// model's per-module densities.
pub fn activation_sparse_variant(mut w: Workload) -> Workload {
    w.name = format!("{} (SA)", w.name);
    for op in &mut w.ops {
        op.spec.weight = SparsityPattern::Dense;
    }
    w
}

/// The attention score/context MatMuls carry K/V tensors — activations
/// from the KV cache — in their weight-operand slot, so weight-pruning
/// variants must leave them alone (in particular, a [`Phase::kv_density`]
/// knob must survive the variant transforms).  The quantization axis
/// (`format::quant`) uses the same classification: these ops draw their
/// weight-slot bitwidths from the KV space (`--kv-bits`), not the
/// weight space.
pub fn weight_is_kv_tensor(op_name: &str) -> bool {
    op_name.ends_with("/qk") || op_name.ends_with("/av")
}

/// Weight-sparsity variant: activations dense; weights pruned with the
/// model's density as *clustered* block sparsity (global magnitude
/// pruning of LLMs produces correlated zero regions — see [5] and
/// DESIGN.md §5), which is what makes hierarchical formats like the
/// paper's `B(M)-B(N)-B(N)` (§IV-E) pay off.  The K/V operands of the
/// attention MatMuls are not weights and keep their pattern.
pub fn weight_sparse_variant(mut w: Workload, block: u64) -> Workload {
    w.name = format!("{} (SW)", w.name);
    for op in &mut w.ops {
        op.spec.input = SparsityPattern::Dense;
        if weight_is_kv_tensor(&op.name) {
            continue;
        }
        let d = op.spec.weight.density();
        op.spec.weight = if d < 1.0 {
            SparsityPattern::Block { br: block, bc: block, block_density: d }
        } else {
            SparsityPattern::Dense
        };
    }
    w
}

/// N:M structured weight-sparsity variant (the pattern deployed on real
/// accelerators, e.g. 2:4 sparse tensor cores): activations dense;
/// every pruned weight tensor carries exactly `n` non-zeros per aligned
/// group of `m` along the reduction axis.  The K/V operands of the
/// attention MatMuls are not weights and keep their pattern (so a
/// KV-cache density knob composes with this variant).
pub fn weight_nm_variant(mut w: Workload, n: u32, m: u32) -> Workload {
    assert!(n >= 1 && n <= m, "N:M sparsity needs 1 <= N <= M, got {n}:{m}");
    w.name = format!("{} (W{n}:{m})", w.name);
    for op in &mut w.ops {
        op.spec.input = SparsityPattern::Dense;
        if weight_is_kv_tensor(&op.name) {
            continue;
        }
        let d = op.spec.weight.density();
        op.spec.weight = if d < 1.0 {
            SparsityPattern::Nm { n, m }
        } else {
            SparsityPattern::Dense
        };
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama7b_structure() {
        let w = llama2_7b(Phase::default_prefill_decode());
        // 8 attention + 4 FFN op groups (prefill + decode).
        assert_eq!(w.ops.len(), 12);
        let qkv = &w.ops[0];
        assert_eq!(qkv.dims, ProblemDims::new(2048, 4096, 3 * 4096));
        assert_eq!(qkv.count, 32);
        // Attention ops occur per layer per head.
        let qk = &w.ops[1];
        assert_eq!(qk.count, 32 * 32);
        assert_eq!(qk.dims.n, 128); // head dim
    }

    #[test]
    fn prefill_only_has_no_decode_ops() {
        let w = bert_base(256);
        assert_eq!(w.ops.len(), 6);
        assert!(w.ops.iter().all(|o| o.name.contains("prefill")));
    }

    #[test]
    fn fc2_is_sparsest_activation() {
        let w = opt_6_7b(Phase::default_prefill_decode());
        let fc2 = w.ops.iter().find(|o| o.name.contains("prefill/fc2")).unwrap();
        let fc1 = w.ops.iter().find(|o| o.name.contains("prefill/fc1")).unwrap();
        assert!(fc2.spec.input.density() < fc1.spec.input.density());
    }

    #[test]
    fn uniform_density_override() {
        let w =
            with_uniform_density(llama2_7b(Phase::default_prefill_decode()), 0.75, 0.75).unwrap();
        for op in &w.ops {
            assert_eq!(op.spec.input.density(), 0.75);
            assert_eq!(op.spec.weight.density(), 0.75);
        }
    }

    #[test]
    fn uniform_density_rejects_out_of_range() {
        let w = || llama2_7b(Phase::prefill_only(64));
        assert!(with_uniform_density(w(), 0.0, 0.5).is_err());
        assert!(with_uniform_density(w(), -0.1, 0.5).is_err());
        assert!(with_uniform_density(w(), 0.5, 1.2).is_err());
        assert!(with_uniform_density(w(), f64::NAN, 0.5).is_err());
        assert!(with_uniform_density(w(), 0.5, 0.5).is_ok());
        assert!(with_uniform_density(w(), 1.0, 1.0).is_ok());
    }

    #[test]
    fn macs_scale_of_7b_prefill_is_plausible() {
        // ~2 * params * tokens for the projection/FFN MACs; 7B params,
        // 2048 tokens -> ~1.4e13 MACs. Attention adds more.
        let w = llama2_7b(Phase::prefill_only(2048));
        let macs = w.total_macs();
        assert!(macs > 5e12 && macs < 5e13, "macs = {macs:.3e}");
    }

    #[test]
    fn batch_scales_prefill_rows_and_attention_counts() {
        let b1 = llama2_7b(Phase::prefill_only(64));
        let b4 = llama2_7b(Phase::prefill_only(64).with_batch(4));
        let qkv1 = &b1.ops[0];
        let qkv4 = &b4.ops[0];
        assert_eq!(qkv4.dims.m, 4 * qkv1.dims.m);
        let qk1 = b1.ops.iter().find(|o| o.name.contains("prefill/qk")).unwrap();
        let qk4 = b4.ops.iter().find(|o| o.name.contains("prefill/qk")).unwrap();
        assert_eq!(qk4.count, 4 * qk1.count);
        assert_eq!(qk4.dims, qk1.dims);
        assert!((b4.total_macs() - 4.0 * b1.total_macs()).abs() < 1e-6 * b1.total_macs());
    }

    #[test]
    fn batched_decode_widens_projection_rows() {
        let w = decode_tiny();
        assert!(w.ops.iter().all(|o| o.name.contains("decode")));
        let qkv = w.ops.iter().find(|o| o.name.contains("decode/qkv")).unwrap();
        assert_eq!(qkv.dims.m, 4);
        let av = w.ops.iter().find(|o| o.name.contains("decode/av")).unwrap();
        assert_eq!(av.spec.weight.density(), 0.5); // the KV-cache knob
        assert_eq!(av.count, 2 * 4 * 16 * 4); // layers x heads x steps x batch
    }

    #[test]
    fn gqa_splits_and_shrinks_kv_projection() {
        let sp =
            LlmSparsity { act_proj: 0.5, act_fc1: 0.5, act_fc2: 0.2, attn: 0.3, weight: 0.4 };
        let shape = LlmShape { hidden: 256, intermediate: 512, layers: 2, heads: 8, kv_heads: 2 };
        let w = build_llm("gqa", shape, sp, Phase::prefill_only(64));
        let q = w.ops.iter().find(|o| o.name.contains("q_proj")).unwrap();
        let kv = w.ops.iter().find(|o| o.name.contains("kv_proj")).unwrap();
        assert_eq!(q.dims.k, 256);
        // 2 kv_heads x head_dim 32 x (K and V) = 128 output columns.
        assert_eq!(kv.dims.k, 128);
        assert!(w.ops.iter().all(|o| !o.name.contains("/qkv")));
    }

    #[test]
    fn nm_variant_marks_pruned_weights_only() {
        let base = opt_6_7b(Phase::prefill_only(128));
        let w = weight_nm_variant(base.clone(), 2, 4);
        assert!(w.name.contains("W2:4"));
        for (op, base_op) in w.ops.iter().zip(&base.ops) {
            assert_eq!(op.spec.input.density(), 1.0, "{}", op.name);
            if op.name.ends_with("/qk") || op.name.ends_with("/av") {
                // K/V operands are activations, not weights: untouched.
                assert_eq!(op.spec.weight, base_op.spec.weight, "{}", op.name);
            } else if base_op.spec.weight.density() < 1.0 {
                assert_eq!(op.spec.weight, SparsityPattern::Nm { n: 2, m: 4 }, "{}", op.name);
            } else {
                assert_eq!(op.spec.weight, SparsityPattern::Dense, "{}", op.name);
            }
        }
    }

    #[test]
    fn nm_variant_preserves_kv_cache_density() {
        // The README's flag combination: --kv-density + --nm must compose.
        let base = decode_tiny_phase("t", Phase::new(0, 8).with_batch(2).with_kv_density(0.9));
        let w = weight_nm_variant(base, 2, 4);
        let av = w.ops.iter().find(|o| o.name.ends_with("/av")).unwrap();
        assert_eq!(av.spec.weight, SparsityPattern::Unstructured { density: 0.9 });
        let qkv = w.ops.iter().find(|o| o.name.contains("/qkv")).unwrap();
        assert_eq!(qkv.spec.weight, SparsityPattern::Nm { n: 2, m: 4 });
    }
}
