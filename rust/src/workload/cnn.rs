//! CNN workloads for the DiMO-Sparse comparison (§IV-D): AlexNet, VGG-16
//! and ResNet-18 convolutions lowered to im2col MatMuls
//! (M = output pixels, N = C_in·k·k reduction, K = C_out).
//!
//! Layer shapes are the standard ImageNet configurations; sparsity uses
//! typical magnitude-pruned CNN densities (weights ~30-50% dense, ReLU
//! activations ~50% dense).

use super::{MatMulOp, Workload};
use crate::dataflow::ProblemDims;
use crate::sparsity::SparsitySpec;

fn conv(name: &str, out_hw: u64, cin: u64, k: u64, cout: u64, act_d: f64, wgt_d: f64) -> MatMulOp {
    MatMulOp {
        name: name.to_string(),
        dims: ProblemDims::new(out_hw * out_hw, cin * k * k, cout),
        spec: SparsitySpec::unstructured(act_d, wgt_d),
        count: 1,
    }
}

pub fn alexnet() -> Workload {
    Workload {
        name: "AlexNet".to_string(),
        ops: vec![
            conv("alexnet/conv1", 55, 3, 11, 96, 1.0, 0.85),
            conv("alexnet/conv2", 27, 96, 5, 256, 0.55, 0.40),
            conv("alexnet/conv3", 13, 256, 3, 384, 0.50, 0.35),
            conv("alexnet/conv4", 13, 384, 3, 384, 0.50, 0.35),
            conv("alexnet/conv5", 13, 384, 3, 256, 0.50, 0.35),
            // FC layers as 1xNxK MatMuls.
            MatMulOp {
                name: "alexnet/fc6".into(),
                dims: ProblemDims::new(1, 9216, 4096),
                spec: SparsitySpec::unstructured(0.5, 0.09),
                count: 1,
            },
            MatMulOp {
                name: "alexnet/fc7".into(),
                dims: ProblemDims::new(1, 4096, 4096),
                spec: SparsitySpec::unstructured(0.5, 0.09),
                count: 1,
            },
            MatMulOp {
                name: "alexnet/fc8".into(),
                dims: ProblemDims::new(1, 4096, 1000),
                spec: SparsitySpec::unstructured(0.5, 0.25),
                count: 1,
            },
        ],
    }
}

pub fn vgg16() -> Workload {
    let cfg: &[(&str, u64, u64, u64)] = &[
        ("conv1_1", 224, 3, 64),
        ("conv1_2", 224, 64, 64),
        ("conv2_1", 112, 64, 128),
        ("conv2_2", 112, 128, 128),
        ("conv3_1", 56, 128, 256),
        ("conv3_2", 56, 256, 256),
        ("conv3_3", 56, 256, 256),
        ("conv4_1", 28, 256, 512),
        ("conv4_2", 28, 512, 512),
        ("conv4_3", 28, 512, 512),
        ("conv5_1", 14, 512, 512),
        ("conv5_2", 14, 512, 512),
        ("conv5_3", 14, 512, 512),
    ];
    Workload {
        name: "VGG-16".to_string(),
        ops: cfg
            .iter()
            .map(|&(n, hw, cin, cout)| {
                let act_d = if cin == 3 { 1.0 } else { 0.5 };
                conv(&format!("vgg16/{n}"), hw, cin, 3, cout, act_d, 0.35)
            })
            .collect(),
    }
}

pub fn resnet18() -> Workload {
    let cfg: &[(&str, u64, u64, u64, u64)] = &[
        ("conv1", 112, 3, 7, 64),
        ("layer1_0a", 56, 64, 3, 64),
        ("layer1_0b", 56, 64, 3, 64),
        ("layer1_1a", 56, 64, 3, 64),
        ("layer1_1b", 56, 64, 3, 64),
        ("layer2_0a", 28, 64, 3, 128),
        ("layer2_0b", 28, 128, 3, 128),
        ("layer2_1a", 28, 128, 3, 128),
        ("layer2_1b", 28, 128, 3, 128),
        ("layer3_0a", 14, 128, 3, 256),
        ("layer3_0b", 14, 256, 3, 256),
        ("layer3_1a", 14, 256, 3, 256),
        ("layer3_1b", 14, 256, 3, 256),
        ("layer4_0a", 7, 256, 3, 512),
        ("layer4_0b", 7, 512, 3, 512),
        ("layer4_1a", 7, 512, 3, 512),
        ("layer4_1b", 7, 512, 3, 512),
    ];
    Workload {
        name: "ResNet-18".to_string(),
        ops: cfg
            .iter()
            .map(|&(n, hw, cin, k, cout)| {
                let act_d = if cin == 3 { 1.0 } else { 0.55 };
                conv(&format!("resnet18/{n}"), hw, cin, k, cout, act_d, 0.40)
            })
            .collect(),
    }
}

/// The three CNNs of the §IV-D DiMO-Sparse comparison.
pub fn all_cnns() -> Vec<Workload> {
    vec![alexnet(), vgg16(), resnet18()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_are_im2col_consistent() {
        let a = alexnet();
        let c2 = &a.ops[1];
        assert_eq!(c2.dims.m, 27 * 27);
        assert_eq!(c2.dims.n, 96 * 25);
        assert_eq!(c2.dims.k, 256);
    }

    #[test]
    fn vgg_has_13_convs() {
        assert_eq!(vgg16().ops.len(), 13);
        assert_eq!(resnet18().ops.len(), 17);
    }

    #[test]
    fn first_layers_have_dense_activations() {
        for w in all_cnns() {
            let first = &w.ops[0];
            assert_eq!(first.spec.input.density(), 1.0, "{}", first.name);
        }
    }

    #[test]
    fn vgg_macs_larger_than_alexnet() {
        assert!(vgg16().total_macs() > alexnet().total_macs());
    }
}
