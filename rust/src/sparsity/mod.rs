//! Sparsity modeling: statistical patterns, the Sparsity Analyzer's
//! analytical expectations, exact counting on concrete masks, synthetic
//! tensor sampling and the computation-reduction model.
//!
//! One shared costing core ([`analyzer::cost_from_ne`]) consumes a vector
//! of non-empty node counts per format boundary; three providers feed it:
//! the analytical expectation (this module), exact counts from a dense
//! mask ([`exact`]) and empirical counts aggregated from the XLA block
//! lattice (`crate::runtime::stats`).

pub mod analyzer;
pub mod exact;
pub mod reduction;
pub mod sample;

use crate::util::mathx::{ln_choose, p_nonempty_iid};

/// Statistical sparsity pattern of one tensor operand.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SparsityPattern {
    /// iid Bernoulli zeros with the given non-zero density.
    Unstructured { density: f64 },
    /// N:M structured sparsity along the column axis: exactly `n` non-zeros
    /// per aligned group of `m` (e.g. 2:4).
    Nm { n: u32, m: u32 },
    /// Block sparsity: the tensor is tiled into `br x bc` blocks; each
    /// block is entirely non-zero with probability `block_density`.
    Block { br: u64, bc: u64, block_density: f64 },
    /// Fully dense.
    Dense,
}

impl SparsityPattern {
    /// Expected fraction of non-zero elements.
    pub fn density(&self) -> f64 {
        match *self {
            SparsityPattern::Unstructured { density } => density,
            SparsityPattern::Nm { n, m } => n as f64 / m as f64,
            SparsityPattern::Block { block_density, .. } => block_density,
            SparsityPattern::Dense => 1.0,
        }
    }

    /// Probability that an axis-aligned `gr x gc` region (a format-tree
    /// node's remaining extent) contains at least one non-zero.
    ///
    /// Regions produced by nested contiguous dimension splits are assumed
    /// aligned with the pattern's structure (group/block boundaries),
    /// which holds for power-of-two splits over power-of-two groups — the
    /// common case in both the paper and our workloads.
    pub fn p_region_nonempty(&self, gr: u64, gc: u64) -> f64 {
        if gr == 0 || gc == 0 {
            return 0.0;
        }
        match *self {
            SparsityPattern::Dense => 1.0,
            SparsityPattern::Unstructured { density } => {
                p_nonempty_iid(density, (gr as f64) * (gc as f64))
            }
            SparsityPattern::Nm { n, m } => {
                if n == 0 {
                    return 0.0;
                }
                let (n, m) = (n as u64, m as u64);
                if gc >= m {
                    // Covers at least one full group per row; every group
                    // holds exactly n >= 1 non-zeros.
                    return 1.0;
                }
                // Aligned sub-group of size gc inside one m-group:
                // P(empty) = C(m-gc, n) / C(m, n), independent across rows.
                let p_row_empty = if m - gc < n {
                    0.0
                } else {
                    (ln_choose(m - gc, n) - ln_choose(m, n)).exp()
                };
                1.0 - p_row_empty.powf(gr as f64)
            }
            SparsityPattern::Block { br, bc, block_density } => {
                // Blocks covered by the region (fractional coverage for
                // sub-block regions clamps to one block).
                let nb_r = (gr as f64 / br as f64).max(1.0);
                let nb_c = (gc as f64 / bc as f64).max(1.0);
                let nb = if gr >= br || gc >= bc { (nb_r * nb_c).round() } else { 1.0 };
                p_nonempty_iid(block_density, nb)
            }
        }
    }
}

/// Check a density knob lies in the valid range `(0, 1]`.  Zero (or
/// negative) densities silently zero the computation-reduction model and
/// densities above 1 inflate every cost, so config and CLI boundaries
/// reject them up front.  NaN fails the comparison and is rejected too.
pub fn validate_density(d: f64) -> Result<(), String> {
    if d > 0.0 && d <= 1.0 {
        Ok(())
    } else {
        Err(format!("density {d} out of range (0, 1]"))
    }
}

/// Sparsity specification for one MatMul operator: input-activation and
/// weight patterns (outputs are produced dense).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SparsitySpec {
    pub input: SparsityPattern,
    pub weight: SparsityPattern,
}

impl SparsitySpec {
    pub fn dense() -> Self {
        SparsitySpec { input: SparsityPattern::Dense, weight: SparsityPattern::Dense }
    }

    pub fn unstructured(input_density: f64, weight_density: f64) -> Self {
        SparsitySpec {
            input: SparsityPattern::Unstructured { density: input_density },
            weight: SparsityPattern::Unstructured { density: weight_density },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn densities() {
        assert_eq!(SparsityPattern::Dense.density(), 1.0);
        assert_eq!(SparsityPattern::Nm { n: 2, m: 4 }.density(), 0.5);
        assert_eq!(
            SparsityPattern::Block { br: 2, bc: 2, block_density: 0.3 }.density(),
            0.3
        );
    }

    #[test]
    fn unstructured_region_probability() {
        let p = SparsityPattern::Unstructured { density: 0.5 };
        assert!((p.p_region_nonempty(1, 1) - 0.5).abs() < 1e-12);
        assert!((p.p_region_nonempty(1, 2) - 0.75).abs() < 1e-12);
        assert_eq!(p.p_region_nonempty(0, 5), 0.0);
    }

    #[test]
    fn nm_region_probability() {
        let p = SparsityPattern::Nm { n: 2, m: 4 };
        // Full group always non-empty.
        assert_eq!(p.p_region_nonempty(1, 4), 1.0);
        assert_eq!(p.p_region_nonempty(3, 8), 1.0);
        // Single element: P = density = 1/2.
        assert!((p.p_region_nonempty(1, 1) - 0.5).abs() < 1e-12);
        // Two of four slots: P(empty) = C(2,2)/C(4,2) = 1/6.
        assert!((p.p_region_nonempty(1, 2) - (1.0 - 1.0 / 6.0)).abs() < 1e-12);
        // 1:4 single element: P = 1/4.
        let p14 = SparsityPattern::Nm { n: 1, m: 4 };
        assert!((p14.p_region_nonempty(1, 1) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn block_region_probability() {
        let p = SparsityPattern::Block { br: 4, bc: 4, block_density: 0.3 };
        // Sub-block region: probability the enclosing block is live.
        assert!((p.p_region_nonempty(2, 2) - 0.3).abs() < 1e-12);
        // Exactly one block.
        assert!((p.p_region_nonempty(4, 4) - 0.3).abs() < 1e-12);
        // Four blocks: 1 - 0.7^4.
        assert!((p.p_region_nonempty(8, 8) - (1.0 - 0.7f64.powi(4))).abs() < 1e-12);
    }

    #[test]
    fn density_validation_bounds() {
        assert!(validate_density(0.5).is_ok());
        assert!(validate_density(1.0).is_ok());
        assert!(validate_density(1e-9).is_ok());
        assert!(validate_density(0.0).is_err());
        assert!(validate_density(-0.2).is_err());
        assert!(validate_density(1.0001).is_err());
        assert!(validate_density(f64::NAN).is_err());
    }

    #[test]
    fn nm_monotone_in_region_size() {
        let p = SparsityPattern::Nm { n: 2, m: 8 };
        let mut last = 0.0;
        for gc in 1..=8 {
            let v = p.p_region_nonempty(1, gc);
            assert!(v >= last - 1e-12, "gc={gc} v={v} last={last}");
            last = v;
        }
        assert_eq!(last, 1.0);
    }
}
