//! Computation-reduction strategies (paper §II-B2, Fig. 2, Table II).
//!
//! *Gating* idles MAC units on zero operands — saves compute **energy**
//! but not cycles.  *Skipping* bypasses the operation entirely — saves
//! both.  Either can check a single operand (unidirectional, e.g.
//! `Skipping I→W`: execute only if the input is non-zero) or both
//! (bidirectional `I↔W`).

use super::SparsitySpec;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReductionKind {
    /// No sparsity mechanism: all MACs execute and burn energy.
    None,
    Gating,
    Skipping,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Direction {
    /// Check the input/activation operand only (paper `I→W`).
    InputOnly,
    /// Check the weight operand only (`W→I`).
    WeightOnly,
    /// Check both operands (`I↔W`).
    Both,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ReductionStrategy {
    pub kind: ReductionKind,
    pub direction: Direction,
}

impl ReductionStrategy {
    pub const NONE: ReductionStrategy =
        ReductionStrategy { kind: ReductionKind::None, direction: Direction::Both };

    pub fn gating(direction: Direction) -> Self {
        ReductionStrategy { kind: ReductionKind::Gating, direction }
    }

    pub fn skipping(direction: Direction) -> Self {
        ReductionStrategy { kind: ReductionKind::Skipping, direction }
    }

    /// Fraction of MAC operations whose *checked operands* are all
    /// non-zero (operand zeros assumed independent).
    fn effective_fraction(&self, spec: &SparsitySpec) -> f64 {
        let di = spec.input.density();
        let dw = spec.weight.density();
        match self.direction {
            Direction::InputOnly => di,
            Direction::WeightOnly => dw,
            Direction::Both => di * dw,
        }
    }

    /// Fraction of peak MAC **cycles** actually spent (paper §III-D1's
    /// upfront estimate shrinks temporal loop bounds by this factor).
    pub fn cycle_fraction(&self, spec: &SparsitySpec) -> f64 {
        match self.kind {
            ReductionKind::Skipping => self.effective_fraction(spec),
            // Gating and None still issue every cycle.
            ReductionKind::Gating | ReductionKind::None => 1.0,
        }
    }

    /// Fraction of peak MAC **energy** actually consumed.
    pub fn energy_fraction(&self, spec: &SparsitySpec) -> f64 {
        match self.kind {
            ReductionKind::Skipping | ReductionKind::Gating => self.effective_fraction(spec),
            ReductionKind::None => 1.0,
        }
    }

    pub fn name(&self) -> String {
        let dir = match self.direction {
            Direction::InputOnly => "I->W",
            Direction::WeightOnly => "W->I",
            Direction::Both => "I<->W",
        };
        match self.kind {
            ReductionKind::None => "None".to_string(),
            ReductionKind::Gating => format!("Gating {dir}"),
            ReductionKind::Skipping => format!("Skipping {dir}"),
        }
    }
}

/// The five practical strategies of §II-B2 ("with only five strategies and
/// skipping typically performing best, this dimension requires little
/// exploration") — exposed for completeness and the ablation bench.
pub fn all_strategies() -> Vec<ReductionStrategy> {
    vec![
        ReductionStrategy::NONE,
        ReductionStrategy::gating(Direction::InputOnly),
        ReductionStrategy::gating(Direction::Both),
        ReductionStrategy::skipping(Direction::InputOnly),
        ReductionStrategy::skipping(Direction::Both),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::SparsitySpec;

    #[test]
    fn skipping_saves_cycles_gating_does_not() {
        let spec = SparsitySpec::unstructured(0.5, 0.4);
        let skip = ReductionStrategy::skipping(Direction::Both);
        let gate = ReductionStrategy::gating(Direction::Both);
        assert!((skip.cycle_fraction(&spec) - 0.2).abs() < 1e-12);
        assert_eq!(gate.cycle_fraction(&spec), 1.0);
        assert!((gate.energy_fraction(&spec) - 0.2).abs() < 1e-12);
        assert!((skip.energy_fraction(&spec) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn unidirectional_checks_one_operand() {
        let spec = SparsitySpec::unstructured(0.5, 0.4);
        let skip_i = ReductionStrategy::skipping(Direction::InputOnly);
        let skip_w = ReductionStrategy::skipping(Direction::WeightOnly);
        assert!((skip_i.cycle_fraction(&spec) - 0.5).abs() < 1e-12);
        assert!((skip_w.cycle_fraction(&spec) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn none_is_identity() {
        let spec = SparsitySpec::unstructured(0.1, 0.1);
        assert_eq!(ReductionStrategy::NONE.cycle_fraction(&spec), 1.0);
        assert_eq!(ReductionStrategy::NONE.energy_fraction(&spec), 1.0);
    }

    #[test]
    fn dense_spec_yields_no_reduction() {
        let spec = SparsitySpec::dense();
        for s in all_strategies() {
            assert_eq!(s.cycle_fraction(&spec), 1.0);
            assert_eq!(s.energy_fraction(&spec), 1.0);
        }
    }

    #[test]
    fn names() {
        assert_eq!(ReductionStrategy::skipping(Direction::Both).name(), "Skipping I<->W");
        assert_eq!(ReductionStrategy::gating(Direction::InputOnly).name(), "Gating I->W");
    }
}
