//! The Sparsity Analyzer's costing core (paper §III-A Evaluator).
//!
//! [`cost_from_ne`] turns a format plus a vector of non-empty node counts
//! (one per level boundary) into metadata/payload bit counts.  The
//! analytical provider [`expected_ne`] computes those counts from a
//! statistical [`SparsityPattern`]; exact and empirical providers live in
//! [`super::exact`] and `crate::runtime::stats`.

use super::SparsityPattern;
use crate::format::{Format, Prim};

/// Bit cost of a compression format applied to one tensor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FormatCost {
    pub metadata_bits: f64,
    pub payload_bits: f64,
    /// Dense storage footprint of the same tensor, for the ratio.
    pub dense_bits: f64,
}

impl FormatCost {
    pub fn total_bits(&self) -> f64 {
        self.metadata_bits + self.payload_bits
    }

    /// Compressed / dense size ratio (< 1.0 means compression wins).
    pub fn ratio(&self) -> f64 {
        self.total_bits() / self.dense_bits
    }
}

/// Expected non-empty node counts per boundary (length = depth + 1) for a
/// statistical sparsity pattern.  `ne[0]` is the root (1 if the tensor is
/// non-empty at all), `ne[i]` the expected count after fixing levels 1..=i.
pub fn expected_ne(format: &Format, pattern: &SparsityPattern) -> Vec<f64> {
    format
        .boundaries()
        .iter()
        .map(|b| b.nodes * pattern.p_region_nonempty(b.region_rows, b.region_cols))
        .collect()
}

/// Per-level operand arrays for the shared costing formulas — also the
/// exact payload of one XLA `format_cost_batch` candidate row.
#[derive(Clone, Debug)]
pub struct CostOperands {
    /// Active (materialized) parent count per level.
    pub parents: Vec<f64>,
    /// Non-empty child count per level.
    pub children: Vec<f64>,
    /// Fanout per level.
    pub fanouts: Vec<f64>,
    /// Metadata word width per level (bits).
    pub widths: Vec<f64>,
    /// Primitive kind id per level (shared with python/compile/model.py).
    pub kinds: Vec<i32>,
    /// Active leaves (payload element count).
    pub leaf_count: f64,
}

/// Derive the costing operands from a non-empty-count vector.
///
/// Active counts follow the recurrence: `A_0 = 1`; a compressing level
/// keeps only non-empty children (`A_i = NE_i` — a non-empty node's
/// ancestors are all non-empty, hence kept everywhere above); a `None`
/// level materializes all children (`A_i = A_{i-1} * size_i`).
pub fn operands_from_ne(format: &Format, ne: &[f64]) -> CostOperands {
    let depth = format.depth();
    assert_eq!(ne.len(), depth + 1, "ne must have depth+1 entries");
    let mut parents = Vec::with_capacity(depth);
    let mut children = Vec::with_capacity(depth);
    let mut fanouts = Vec::with_capacity(depth);
    let mut widths = Vec::with_capacity(depth);
    let mut kinds = Vec::with_capacity(depth);
    let mut active = 1.0f64;
    for (i, l) in format.levels.iter().enumerate() {
        parents.push(active);
        fanouts.push(l.size as f64);
        widths.push(format.level_width_bits(i) as f64);
        // The XLA scorer has no delimiter flag; an undelimited CP level
        // shares RLE's (children + parents) * width formula, so pack it
        // with the RLE kind id.
        let kind = if matches!(l.prim, Prim::Cp) && !level_is_delimited(format, i) {
            Prim::Rle.kind_id()
        } else {
            l.prim.kind_id()
        };
        kinds.push(kind);
        if l.prim.compresses() {
            // NE can only shrink relative to the active frontier.
            active = ne[i + 1].min(active * l.size as f64);
        } else {
            active *= l.size as f64;
        }
        children.push(active);
    }
    CostOperands { parents, children, fanouts, widths, kinds, leaf_count: active }
}

/// Metadata bits of one level given its operands — the single source of
/// truth for primitive cost formulas (mirrored by the XLA scorer).
///
/// `delimited` reflects whether the enclosing level already delimits this
/// level's per-parent entry lists.  `CP` is the only primitive whose
/// encoding is a *variable-length* coordinate list: unless a `UOP` level
/// sits directly above (its offset array gives each parent's list
/// extent), every active parent needs a child-count field — without it
/// the stream is undecodable.  `B` (fixed bitmap), `UOP` (fixed-size
/// offset array) and `RLE` (terminator included in its formula) are
/// self-delimiting.
pub fn level_metadata_bits(
    prim: &Prim,
    parents: f64,
    children: f64,
    fanout: f64,
    width: f64,
    delimited: bool,
) -> f64 {
    match prim {
        Prim::None => 0.0,
        Prim::B => parents * fanout,
        Prim::Cp => {
            let count_field = if delimited { 0.0 } else { parents * width };
            children * width + count_field
        }
        Prim::Rle => (children + parents) * width,
        Prim::Uop => parents * (fanout + 1.0) * width,
        Prim::Custom { bits_per_parent, bits_per_child, .. } => {
            parents * bits_per_parent + children * bits_per_child
        }
    }
}

/// Is level `i` of `format` delimited by its enclosing level?
pub fn level_is_delimited(format: &Format, i: usize) -> bool {
    i > 0 && matches!(format.levels[i - 1].prim, Prim::Uop)
}

/// Full format cost from a non-empty-count vector, with the payload
/// quantized to `payload_bits` while the *dense* reference stays at the
/// accelerator word width `dense_bits` (the quantization axis,
/// `format::quant`).  `ratio()` therefore carries both the sparsity
/// compression and the `payload_bits / dense_bits` precision scaling;
/// metadata widths are payload-independent.  With
/// `payload_bits == dense_bits` this is exactly [`cost_from_ne`].
pub fn cost_from_ne_quant(
    format: &Format,
    ne: &[f64],
    dense_bits: u32,
    payload_bits: u32,
) -> FormatCost {
    let ops = operands_from_ne(format, ne);
    let mut metadata = 0.0;
    for (i, l) in format.levels.iter().enumerate() {
        metadata += level_metadata_bits(
            &l.prim,
            ops.parents[i],
            ops.children[i],
            ops.fanouts[i],
            ops.widths[i],
            level_is_delimited(format, i),
        );
    }
    FormatCost {
        metadata_bits: metadata,
        payload_bits: ops.leaf_count * payload_bits as f64,
        dense_bits: (format.rows * format.cols) as f64 * dense_bits as f64,
    }
}

/// Full format cost from a non-empty-count vector.
pub fn cost_from_ne(format: &Format, ne: &[f64], data_bits: u32) -> FormatCost {
    cost_from_ne_quant(format, ne, data_bits, data_bits)
}

/// Analytical format cost with a quantized payload — the quant-axis DSE
/// hot path (`dense_bits` = accelerator word width, `payload_bits` =
/// candidate operand precision).
pub fn analytical_cost_quant(
    format: &Format,
    pattern: &SparsityPattern,
    dense_bits: u32,
    payload_bits: u32,
) -> FormatCost {
    cost_from_ne_quant(format, &expected_ne(format, pattern), dense_bits, payload_bits)
}

/// Analytical format cost for a statistical pattern — the DSE hot path.
pub fn analytical_cost(
    format: &Format,
    pattern: &SparsityPattern,
    data_bits: u32,
) -> FormatCost {
    analytical_cost_quant(format, pattern, data_bits, data_bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::named;
    use crate::sparsity::SparsityPattern;

    const BITS: u32 = 16;

    #[test]
    fn dense_pattern_bitmap_cost_is_exact() {
        // 8x8 dense tensor under a bitmap: every bit set, payload full.
        let f = named::bitmap(8, 8);
        let c = analytical_cost(&f, &SparsityPattern::Dense, BITS);
        // None(M,8): no metadata; B(N,8): 8 rows active x 8 bits.
        assert_eq!(c.metadata_bits, 64.0);
        assert_eq!(c.payload_bits, 64.0 * BITS as f64);
        assert!(c.ratio() > 1.0); // bitmap on dense data costs extra
    }

    #[test]
    fn bitmap_payload_tracks_density() {
        let f = named::bitmap(64, 64);
        let d = SparsityPattern::Unstructured { density: 0.25 };
        let c = analytical_cost(&f, &d, BITS);
        // Metadata fixed: 64 rows x 64 bits.
        assert_eq!(c.metadata_bits, 64.0 * 64.0);
        // Payload ~ expected nnz x 16.
        let expect = 64.0 * 64.0 * 0.25 * BITS as f64;
        assert!((c.payload_bits - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn csr_cheaper_than_bitmap_at_high_sparsity() {
        let (r, c) = (256, 256);
        let sparse = SparsityPattern::Unstructured { density: 0.02 };
        let bm = analytical_cost(&named::bitmap(r, c), &sparse, BITS);
        let csr = analytical_cost(&named::csr(r, c), &sparse, BITS);
        assert!(
            csr.total_bits() < bm.total_bits(),
            "csr {} vs bitmap {}",
            csr.total_bits(),
            bm.total_bits()
        );
    }

    #[test]
    fn bitmap_beats_coo_at_moderate_sparsity() {
        let (r, c) = (256, 256);
        let moderate = SparsityPattern::Unstructured { density: 0.5 };
        let bm = analytical_cost(&named::bitmap(r, c), &moderate, BITS);
        let coo = analytical_cost(&named::coo(r, c), &moderate, BITS);
        assert!(bm.total_bits() < coo.total_bits());
    }

    #[test]
    fn empty_tensor_costs_only_fixed_metadata() {
        let f = named::csr(64, 64);
        let c = analytical_cost(&f, &SparsityPattern::Unstructured { density: 0.0 }, BITS);
        assert_eq!(c.payload_bits, 0.0);
        // UOP pointer array survives (static structure), CP entries vanish.
        assert!(c.metadata_bits > 0.0);
    }

    #[test]
    fn hierarchical_bitmap_wins_on_block_sparsity() {
        // The Fig. 5 phenomenon: with whole blocks empty, a coarse bitmap
        // level prunes fine-level bitmap storage.
        let (r, c) = (64, 64);
        let pat = SparsityPattern::Block { br: 8, bc: 8, block_density: 0.2 };
        let flat = analytical_cost(&named::bitmap(r, c), &pat, BITS);
        let hier = analytical_cost(&named::csb(r, c, 8, 8), &pat, BITS);
        assert!(
            hier.total_bits() < flat.total_bits(),
            "hier {} vs flat {}",
            hier.total_bits(),
            flat.total_bits()
        );
    }

    #[test]
    fn operands_respect_none_levels() {
        // B(M,4)-None(N,8): the None level materializes all 8 children of
        // every non-empty row.
        let f = crate::format::Format::new(
            vec![
                crate::format::Level { prim: Prim::B, axis: crate::format::Axis::Row, size: 4 },
                crate::format::Level { prim: Prim::None, axis: crate::format::Axis::Col, size: 8 },
            ],
            4,
            8,
        )
        .unwrap();
        let ne = expected_ne(&f, &SparsityPattern::Unstructured { density: 0.1 });
        let ops = operands_from_ne(&f, &ne);
        // Leaves = non-empty rows x 8 (dense within row).
        assert!((ops.leaf_count - ne[1] * 8.0).abs() < 1e-9);
    }

    #[test]
    fn quantized_payload_scales_with_bits() {
        let f = named::bitmap(64, 64);
        let d = SparsityPattern::Unstructured { density: 0.25 };
        let c16 = analytical_cost_quant(&f, &d, BITS, 16);
        let c8 = analytical_cost_quant(&f, &d, BITS, 8);
        let c4 = analytical_cost_quant(&f, &d, BITS, 4);
        // payload_bits == dense_bits is the unquantized cost, bit for bit.
        assert_eq!(c16, analytical_cost(&f, &d, BITS));
        // Metadata and the dense reference are precision-independent.
        assert_eq!(c8.metadata_bits, c16.metadata_bits);
        assert_eq!(c8.dense_bits, c16.dense_bits);
        // Total bits (and hence the ratio) strictly monotone in precision.
        assert!(c4.total_bits() < c8.total_bits());
        assert!(c8.total_bits() < c16.total_bits());
        assert!(c4.ratio() < c8.ratio() && c8.ratio() < c16.ratio());
    }

    #[test]
    fn ne_is_monotone_down_the_tree() {
        let f = named::csb(64, 64, 8, 8);
        let ne = expected_ne(&f, &SparsityPattern::Unstructured { density: 0.3 });
        for w in ne.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "ne not monotone: {ne:?}");
        }
    }
}
