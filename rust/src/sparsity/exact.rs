//! Exact occupancy counting on concrete dense masks.
//!
//! Used by validation tests (analytical expectation vs ground truth), the
//! Fig. 5 worked example and as the golden reference for the XLA lattice
//! aggregation path.

use crate::format::{Axis, Format};

/// A dense boolean occupancy mask of an `rows x cols` tensor.
#[derive(Clone, Debug)]
pub struct DenseMask {
    pub rows: u64,
    pub cols: u64,
    bits: Vec<bool>,
}

impl DenseMask {
    pub fn new(rows: u64, cols: u64) -> Self {
        DenseMask { rows, cols, bits: vec![false; (rows * cols) as usize] }
    }

    pub fn from_fn<F: FnMut(u64, u64) -> bool>(rows: u64, cols: u64, mut f: F) -> Self {
        let mut m = DenseMask::new(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                if f(r, c) {
                    m.set(r, c, true);
                }
            }
        }
        m
    }

    #[inline]
    pub fn get(&self, r: u64, c: u64) -> bool {
        self.bits[(r * self.cols + c) as usize]
    }

    #[inline]
    pub fn set(&mut self, r: u64, c: u64, v: bool) {
        self.bits[(r * self.cols + c) as usize] = v;
    }

    pub fn nnz(&self) -> u64 {
        self.bits.iter().filter(|&&b| b).count() as u64
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Export as f32 values (1.0 at non-zeros) for the XLA analyzer input.
    pub fn to_f32(&self) -> Vec<f32> {
        self.bits.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect()
    }
}

/// Per-level mixed-radix strides for mapping element coordinates to format
/// tree node indices.
fn axis_strides(format: &Format, axis: Axis) -> Vec<(usize, u64)> {
    // For levels on `axis`, outermost first, the stride of level i is the
    // product of the sizes of *deeper* levels on the same axis.
    let sizes: Vec<(usize, u64)> = format
        .levels
        .iter()
        .enumerate()
        .filter(|(_, l)| l.axis == axis)
        .map(|(i, l)| (i, l.size))
        .collect();
    let mut strides = Vec::with_capacity(sizes.len());
    for k in 0..sizes.len() {
        let stride: u64 = sizes[k + 1..].iter().map(|(_, s)| *s).product();
        strides.push((sizes[k].0, stride));
    }
    strides
}

/// Exact non-empty node counts per boundary (length depth+1) for a mask.
pub fn exact_ne(format: &Format, mask: &DenseMask) -> Vec<f64> {
    assert_eq!((format.rows, format.cols), (mask.rows, mask.cols));
    let depth = format.depth();
    let row_strides = axis_strides(format, Axis::Row);
    let col_strides = axis_strides(format, Axis::Col);
    // Per-boundary sets of non-empty node indices, stored as sorted Vec of
    // u64 mixed-radix codes (HashSet is fine at these test scales but the
    // bench path also uses this, so keep it compact).
    let mut seen: Vec<std::collections::HashSet<u64>> = vec![Default::default(); depth + 1];

    // Per-element level coordinates: level i's coordinate is derived from
    // r (Row levels) or c (Col levels) via its stride.
    for r in 0..mask.rows {
        for c in 0..mask.cols {
            if !mask.get(r, c) {
                continue;
            }
            let mut code: u64 = 0;
            seen[0].insert(0);
            for (i, l) in format.levels.iter().enumerate() {
                let coord = match l.axis {
                    Axis::Row => {
                        let (_, stride) = row_strides.iter().find(|(li, _)| *li == i).unwrap();
                        (r / stride) % l.size
                    }
                    Axis::Col => {
                        let (_, stride) = col_strides.iter().find(|(li, _)| *li == i).unwrap();
                        (c / stride) % l.size
                    }
                };
                code = code * l.size + coord;
                seen[i + 1].insert(code);
            }
        }
    }
    seen.iter().map(|s| s.len() as f64).collect()
}

/// Exact format cost for a concrete mask (ground truth).
pub fn exact_cost(
    format: &Format,
    mask: &DenseMask,
    data_bits: u32,
) -> super::analyzer::FormatCost {
    super::analyzer::cost_from_ne(format, &exact_ne(format, mask), data_bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::{named, Format, Level, Prim};
    use crate::sparsity::analyzer::cost_from_ne;

    #[test]
    fn ne_of_identity_matrix_under_csr() {
        // 4x4 identity: every row non-empty, 4 nonzeros.
        let m = DenseMask::from_fn(4, 4, |r, c| r == c);
        let f = named::csr(4, 4);
        let ne = exact_ne(&f, &m);
        assert_eq!(ne, vec![1.0, 4.0, 4.0]);
    }

    #[test]
    fn ne_of_empty_and_full() {
        let f = named::csr(4, 4);
        let empty = DenseMask::new(4, 4);
        assert_eq!(exact_ne(&f, &empty), vec![0.0, 0.0, 0.0]);
        let full = DenseMask::from_fn(4, 4, |_, _| true);
        assert_eq!(exact_ne(&f, &full), vec![1.0, 4.0, 16.0]);
    }

    #[test]
    fn block_structured_mask_under_csb() {
        // 8x8 mask with only the top-left 4x4 block occupied.
        let m = DenseMask::from_fn(8, 8, |r, c| r < 4 && c < 4);
        let f = named::csb(8, 8, 4, 4);
        let ne = exact_ne(&f, &m);
        // Boundaries: root; 2 row-blocks -> 1 non-empty; 2x2 blocks -> 1;
        // rows within block -> 4; elements -> 16.
        assert_eq!(ne, vec![1.0, 1.0, 1.0, 4.0, 16.0]);
    }

    #[test]
    fn fig5_style_three_level_bitmap_payload_reduction() {
        // Reproduce the Fig. 5 phenomenon exactly: a 3x6 matrix whose
        // non-zeros all fall in the first half of the columns.  The
        // three-level format B(M)-B(N1)-B(N2) (N = 3x2) stores fewer
        // metadata bits than the flat per-element bitmap whenever whole
        // column groups are empty.
        let m = DenseMask::from_fn(3, 6, |r, c| r < 2 && c < 2 && (r + c) % 2 == 0);
        let flat = named::bitmap(3, 6);
        let flat_cost = exact_cost(&flat, &m, 8);
        let hier = Format::new(
            vec![
                Level { prim: Prim::B, axis: crate::format::Axis::Row, size: 3 },
                Level { prim: Prim::B, axis: crate::format::Axis::Col, size: 3 },
                Level { prim: Prim::B, axis: crate::format::Axis::Col, size: 2 },
            ],
            3,
            6,
        )
        .unwrap();
        let hier_cost = exact_cost(&hier, &m, 8);
        assert!(
            hier_cost.metadata_bits < flat_cost.metadata_bits,
            "hier {} vs flat {}",
            hier_cost.metadata_bits,
            flat_cost.metadata_bits
        );
    }

    #[test]
    fn exact_matches_analytical_at_extremes() {
        use crate::sparsity::SparsityPattern;
        for f in [named::csr(8, 8), named::bitmap(8, 8), named::coo(8, 8)] {
            let full = DenseMask::from_fn(8, 8, |_, _| true);
            let exact = exact_cost(&f, &full, 16);
            let analytic = crate::sparsity::analyzer::analytical_cost(
                &f,
                &SparsityPattern::Dense,
                16,
            );
            assert!(
                (exact.total_bits() - analytic.total_bits()).abs() < 1e-6,
                "{f}: exact {} vs analytic {}",
                exact.total_bits(),
                analytic.total_bits()
            );
        }
    }

    #[test]
    fn cost_from_exact_ne_is_consistent() {
        let m = DenseMask::from_fn(16, 16, |r, c| (r * 7 + c * 3) % 5 == 0);
        let f = named::csr(16, 16);
        let ne = exact_ne(&f, &m);
        let c1 = exact_cost(&f, &m, 16);
        let c2 = cost_from_ne(&f, &ne, 16);
        assert_eq!(c1, c2);
        // Payload = nnz x bits when the leaf level compresses.
        assert_eq!(c1.payload_bits, m.nnz() as f64 * 16.0);
    }
}
