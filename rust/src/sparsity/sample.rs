//! Synthetic sparse-tensor sampler.
//!
//! The paper's experiments consume sparse LLM tensors from [4], [5]; the
//! framework itself only needs their occupancy structure.  This sampler
//! draws masks matching a [`SparsityPattern`] exactly (N:M) or in
//! distribution (unstructured, block), which exercises the identical
//! analyzer code paths (see DESIGN.md §5 Substitutions).

use super::{exact::DenseMask, SparsityPattern};
use crate::util::prng::Pcg32;

/// Sample a concrete mask following `pattern`.
pub fn sample_mask(pattern: &SparsityPattern, rows: u64, cols: u64, seed: u64) -> DenseMask {
    let mut rng = Pcg32::new(seed);
    match *pattern {
        SparsityPattern::Dense => DenseMask::from_fn(rows, cols, |_, _| true),
        SparsityPattern::Unstructured { density } => {
            let mut m = DenseMask::new(rows, cols);
            for r in 0..rows {
                for c in 0..cols {
                    if rng.bernoulli(density) {
                        m.set(r, c, true);
                    }
                }
            }
            m
        }
        SparsityPattern::Nm { n, m } => {
            assert!(cols % m as u64 == 0, "cols {cols} not divisible by m {m}");
            let mut mask = DenseMask::new(rows, cols);
            let mut slots: Vec<u32> = (0..m).collect();
            for r in 0..rows {
                for g in 0..cols / m as u64 {
                    rng.shuffle(&mut slots);
                    for &s in slots.iter().take(n as usize) {
                        mask.set(r, g * m as u64 + s as u64, true);
                    }
                }
            }
            mask
        }
        SparsityPattern::Block { br, bc, block_density } => {
            assert!(rows % br == 0 && cols % bc == 0, "block must divide tensor");
            let mut mask = DenseMask::new(rows, cols);
            for rb in 0..rows / br {
                for cb in 0..cols / bc {
                    if rng.bernoulli(block_density) {
                        for r in 0..br {
                            for c in 0..bc {
                                mask.set(rb * br + r, cb * bc + c, true);
                            }
                        }
                    }
                }
            }
            mask
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unstructured_density_is_close() {
        let p = SparsityPattern::Unstructured { density: 0.3 };
        let m = sample_mask(&p, 128, 128, 7);
        let d = m.density();
        assert!((d - 0.3).abs() < 0.02, "density {d}");
    }

    #[test]
    fn nm_is_exact() {
        let p = SparsityPattern::Nm { n: 2, m: 4 };
        let mask = sample_mask(&p, 64, 64, 9);
        assert_eq!(mask.nnz(), 64 * 64 / 2);
        // Every aligned group of 4 holds exactly 2.
        for r in 0..64 {
            for g in 0..16 {
                let cnt = (0..4).filter(|&i| mask.get(r, g * 4 + i)).count();
                assert_eq!(cnt, 2, "row {r} group {g}");
            }
        }
    }

    #[test]
    fn block_sampling_produces_full_blocks() {
        let p = SparsityPattern::Block { br: 8, bc: 8, block_density: 0.4 };
        let m = sample_mask(&p, 64, 64, 3);
        for rb in 0..8 {
            for cb in 0..8 {
                let cnt = (0..8)
                    .flat_map(|r| (0..8).map(move |c| (r, c)))
                    .filter(|&(r, c)| m.get(rb * 8 + r, cb * 8 + c))
                    .count();
                assert!(cnt == 0 || cnt == 64, "partial block at ({rb},{cb}): {cnt}");
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let p = SparsityPattern::Unstructured { density: 0.5 };
        let a = sample_mask(&p, 32, 32, 42);
        let b = sample_mask(&p, 32, 32, 42);
        assert_eq!(a.to_f32(), b.to_f32());
        let c = sample_mask(&p, 32, 32, 43);
        assert_ne!(a.to_f32(), c.to_f32());
    }

    #[test]
    fn dense_is_full() {
        let m = sample_mask(&SparsityPattern::Dense, 16, 16, 0);
        assert_eq!(m.nnz(), 256);
    }
}
