//! The Adaptive Compression Engine (paper §III-C): generates candidate
//! compression formats for tensors with diverse sparsity via three
//! techniques — complexity-based penalizing ([`penalty`]),
//! efficiency-oriented dimension allocation ([`allocate`]) and
//! importance-based multi-model scoring ([`scoring`]).

pub mod allocate;
pub mod penalty;
pub mod scoring;

use crate::format::space::SpaceConfig;
use crate::format::Format;
use crate::sparsity::analyzer::{analytical_cost, analytical_cost_quant, FormatCost};
use crate::sparsity::SparsityPattern;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub space: SpaceConfig,
    /// Complexity penalty base: `EqData = gamma^compressing_levels × bits`
    /// (paper default 1.05, configurable).
    pub gamma: f64,
    /// Payload word width in bits.
    pub data_bits: u32,
    /// Number of top formats returned to the co-search.
    pub top_k: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { space: SpaceConfig::default(), gamma: 1.05, data_bits: 16, top_k: 4 }
    }
}

/// A format candidate with its evaluated cost.
#[derive(Clone, Debug)]
pub struct ScoredFormat {
    pub format: Format,
    pub cost: FormatCost,
    /// Penalized equivalent data size (bits).
    pub eq_bits: f64,
}

impl ScoredFormat {
    pub fn score(format: Format, pattern: &SparsityPattern, cfg: &EngineConfig) -> Self {
        let cost = analytical_cost(&format, pattern, cfg.data_bits);
        let eq_bits = cfg.gamma.powi(format.compressing_depth() as i32) * cost.total_bits();
        ScoredFormat { format, cost, eq_bits }
    }

    /// Score with the payload quantized to `payload_bits` (the dense
    /// reference stays at `cfg.data_bits` — see `format::quant`).  With
    /// `payload_bits == cfg.data_bits` this is [`ScoredFormat::score`]
    /// bit for bit.
    pub fn score_quant(
        format: Format,
        pattern: &SparsityPattern,
        cfg: &EngineConfig,
        payload_bits: u32,
    ) -> Self {
        let cost = analytical_cost_quant(&format, pattern, cfg.data_bits, payload_bits);
        let eq_bits = cfg.gamma.powi(format.compressing_depth() as i32) * cost.total_bits();
        ScoredFormat { format, cost, eq_bits }
    }
}

/// Search statistics, reported by the Fig. 6 ablation.
#[derive(Clone, Copy, Debug, Default)]
pub struct SearchStats {
    /// Format candidates in the unpruned (pattern x allocation) space.
    pub full_space: u64,
    /// Candidates actually evaluated after penalty pruning.
    pub evaluated: u64,
    /// Candidates surviving as top-k output.
    pub kept: u64,
}

/// Search the format space for one tensor: returns the top-k formats by
/// penalized size, plus statistics.  This is the engine's main entry
/// point; `tile_hints` (per-axis dataflow tile factors, outermost first)
/// steer dimension allocation (§III-C2).
pub fn search_formats(
    rows: u64,
    cols: u64,
    pattern: &SparsityPattern,
    tile_hints: Option<&allocate::TileHints>,
    cfg: &EngineConfig,
) -> (Vec<ScoredFormat>, SearchStats) {
    search_formats_quant(rows, cols, pattern, tile_hints, cfg, cfg.data_bits)
}

/// [`search_formats`] with the payload quantized to `payload_bits`: the
/// whole structure search — allocation choice, penalty pruning, top-k
/// ranking — reruns under the quantized payload cost, because shrinking
/// the payload shifts the metadata/payload trade-off and can change
/// which pattern wins.  With `payload_bits == cfg.data_bits` this is
/// [`search_formats`] bit for bit (the quant-axis disabled contract).
pub fn search_formats_quant(
    rows: u64,
    cols: u64,
    pattern: &SparsityPattern,
    tile_hints: Option<&allocate::TileHints>,
    cfg: &EngineConfig,
    payload_bits: u32,
) -> (Vec<ScoredFormat>, SearchStats) {
    // NOTE: `full_space` is only filled when the caller asks (the Fig. 6
    // ablation) — counting the unpruned space costs more than the search.
    let mut stats = SearchStats::default();
    let patterns = crate::format::space::enumerate_patterns(&cfg.space);
    let mut kept: Vec<ScoredFormat> = Vec::new();
    // Best penalized size seen at each compressing depth, for the
    // complexity-based pruning rule: a deeper format must beat every
    // simpler one on penalized size to survive.
    let mut best_eq_by_depth: Vec<f64> = vec![f64::INFINITY; cfg.space.max_depth + 1];

    // Visit patterns shallow-first so simpler formats set the bar.
    let mut ordered = patterns;
    ordered.sort_by_key(|p| p.compressing_depth());

    for pat in &ordered {
        let depth = pat.compressing_depth();
        let Some(format) = allocate::choose_allocation_quant(
            pat,
            rows,
            cols,
            pattern,
            tile_hints,
            cfg,
            payload_bits,
        ) else {
            continue;
        };
        stats.evaluated += 1;
        let scored = ScoredFormat::score_quant(format, pattern, cfg, payload_bits);
        let simpler_best = best_eq_by_depth[..depth]
            .iter()
            .fold(f64::INFINITY, |a, &b| a.min(b));
        if scored.eq_bits >= simpler_best {
            // Dominated by a simpler format: excluded (§III-C1).
            continue;
        }
        if scored.eq_bits < best_eq_by_depth[depth] {
            best_eq_by_depth[depth] = scored.eq_bits;
        }
        kept.push(scored);
    }

    kept.sort_by(|a, b| a.eq_bits.partial_cmp(&b.eq_bits).unwrap());
    kept.truncate(cfg.top_k);
    stats.kept = kept.len() as u64;
    (kept, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_compressive_format_for_sparse_tensor() {
        let cfg = EngineConfig::default();
        let pattern = SparsityPattern::Unstructured { density: 0.1 };
        let (top, stats) = search_formats(256, 256, &pattern, None, &cfg);
        assert!(!top.is_empty());
        assert!(stats.evaluated > 0);
        let full = crate::format::space::full_space_size(256, 256, &cfg.space);
        assert!(full > stats.evaluated);
        // Best format should compress well below dense.
        assert!(top[0].cost.ratio() < 0.5, "ratio {}", top[0].cost.ratio());
    }

    #[test]
    fn beats_or_matches_the_best_standard_baseline() {
        let cfg = EngineConfig::default();
        for density in [0.05, 0.3, 0.5, 0.75] {
            let pattern = SparsityPattern::Unstructured { density };
            let (top, _) = search_formats(256, 256, &pattern, None, &cfg);
            let best_baseline = crate::format::named::baselines(256, 256)
                .into_iter()
                .map(|(_, f)| analytical_cost(&f, &pattern, cfg.data_bits).total_bits())
                .fold(f64::INFINITY, f64::min);
            assert!(
                top[0].cost.total_bits() <= best_baseline * 1.001,
                "density {density}: engine {} vs baseline {best_baseline}",
                top[0].cost.total_bits()
            );
        }
    }

    #[test]
    fn results_have_few_levels() {
        // §IV-E: penalizing keeps selected formats at 2-3 levels.
        let cfg = EngineConfig::default();
        let pattern = SparsityPattern::Unstructured { density: 0.5 };
        let (top, _) = search_formats(1024, 1024, &pattern, None, &cfg);
        assert!(top[0].format.compressing_depth() <= 3, "{}", top[0].format);
    }

    #[test]
    fn block_sparsity_selects_hierarchical_format() {
        let cfg = EngineConfig::default();
        let pattern = SparsityPattern::Block { br: 16, bc: 16, block_density: 0.15 };
        let (top, _) = search_formats(256, 256, &pattern, None, &cfg);
        // A hierarchical (multi-level) format must win over the flat
        // baselines here — e.g. block coordinates + dense-inside payload
        // (one compressing level over a block axis) or nested bitmaps.
        assert!(top[0].format.depth() >= 2, "picked {}", top[0].format);
        let flat = analytical_cost(
            &crate::format::named::bitmap(256, 256),
            &pattern,
            cfg.data_bits,
        );
        assert!(top[0].cost.total_bits() < flat.total_bits());
    }

    #[test]
    fn quant_search_at_native_bits_is_the_plain_search() {
        let cfg = EngineConfig::default();
        let pattern = SparsityPattern::Unstructured { density: 0.3 };
        let (plain, s1) = search_formats(128, 128, &pattern, None, &cfg);
        let (quant, s2) =
            search_formats_quant(128, 128, &pattern, None, &cfg, cfg.data_bits);
        assert_eq!(plain.len(), quant.len());
        assert_eq!(s1.evaluated, s2.evaluated);
        for (a, b) in plain.iter().zip(quant.iter()) {
            assert_eq!(a.format, b.format);
            assert_eq!(a.eq_bits.to_bits(), b.eq_bits.to_bits());
            assert_eq!(a.cost.total_bits().to_bits(), b.cost.total_bits().to_bits());
        }
    }

    #[test]
    fn quantized_payload_shrinks_the_winning_cost() {
        let cfg = EngineConfig::default();
        let pattern = SparsityPattern::Unstructured { density: 0.4 };
        let (w16, _) = search_formats_quant(256, 256, &pattern, None, &cfg, 16);
        let (w4, _) = search_formats_quant(256, 256, &pattern, None, &cfg, 4);
        // The 4-bit search minimizes over (at least) the 16-bit winner's
        // pattern, whose best allocation scored at 4 bits is strictly
        // cheaper than at 16 — so the penalized winner must improve.
        assert!(w4[0].eq_bits < w16[0].eq_bits);
        let rescored =
            analytical_cost_quant(&w16[0].format, &pattern, cfg.data_bits, 4);
        assert!(rescored.total_bits() < w16[0].cost.total_bits());
    }

    #[test]
    fn top_k_is_sorted_and_bounded() {
        let cfg = EngineConfig { top_k: 3, ..Default::default() };
        let pattern = SparsityPattern::Unstructured { density: 0.2 };
        let (top, _) = search_formats(128, 128, &pattern, None, &cfg);
        assert!(top.len() <= 3);
        for w in top.windows(2) {
            assert!(w[0].eq_bits <= w[1].eq_bits);
        }
    }
}
