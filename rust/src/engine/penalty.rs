//! Complexity-based penalizing ablation support (paper §III-C1, Fig. 6).
//!
//! The penalty itself lives in the engine's search loop (`EqData =
//! γ^levels × bits`, deeper formats must beat all simpler ones).  This
//! module provides the *unpenalized* exhaustive search used as the Fig. 6
//! reference point: it walks the full (pattern × allocation) space and
//! tracks the true optimum payload, so the bench can report how close the
//! penalized search gets (paper: within 0.31%) and how many candidates
//! each explores (paper: >400k → a small subset).

use super::EngineConfig;
use crate::format::space::{enumerate_allocations, enumerate_patterns};
use crate::format::Format;
use crate::sparsity::analyzer::analytical_cost;
use crate::sparsity::SparsityPattern;

/// Result of an exhaustive (unpenalized) sweep.
#[derive(Clone, Debug)]
pub struct ExhaustiveResult {
    pub best: Format,
    pub best_bits: f64,
    pub candidates: u64,
    /// Best found per compressing depth (depth -> bits).
    pub best_by_depth: Vec<(usize, f64)>,
}

/// Walk the entire format space without penalty; track the optimum.
pub fn exhaustive_search(
    rows: u64,
    cols: u64,
    pattern: &SparsityPattern,
    cfg: &EngineConfig,
) -> ExhaustiveResult {
    let mut best: Option<(f64, Format)> = None;
    let mut candidates = 0u64;
    let mut by_depth: std::collections::BTreeMap<usize, f64> = Default::default();
    for pat in enumerate_patterns(&cfg.space) {
        for f in enumerate_allocations(&pat, rows, cols, &cfg.space) {
            candidates += 1;
            let bits = analytical_cost(&f, pattern, cfg.data_bits).total_bits();
            let d = f.compressing_depth();
            let e = by_depth.entry(d).or_insert(f64::INFINITY);
            if bits < *e {
                *e = bits;
            }
            if best.as_ref().map(|(b, _)| bits < *b).unwrap_or(true) {
                best = Some((bits, f));
            }
        }
    }
    let (best_bits, best) = best.expect("non-empty space");
    ExhaustiveResult {
        best,
        best_bits,
        candidates,
        best_by_depth: by_depth.into_iter().collect(),
    }
}

/// Gap between the penalized search result and the true optimum,
/// as a fraction (paper reports <= 0.31%).
pub fn optimality_gap(penalized_bits: f64, true_best_bits: f64) -> f64 {
    (penalized_bits - true_best_bits).max(0.0) / true_best_bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::search_formats;
    use crate::format::space::SpaceConfig;

    fn small_cfg() -> EngineConfig {
        EngineConfig {
            space: SpaceConfig { max_depth: 3, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn penalized_search_is_near_optimal() {
        let cfg = small_cfg();
        for density in [0.05, 0.25, 0.6] {
            let pattern = SparsityPattern::Unstructured { density };
            let ex = exhaustive_search(64, 64, &pattern, &cfg);
            let (top, stats) = search_formats(64, 64, &pattern, None, &cfg);
            let gap = optimality_gap(top[0].cost.total_bits(), ex.best_bits);
            // The paper reports <= 0.31%; allow a little slack at toy sizes.
            assert!(gap < 0.05, "density {density}: gap {:.2}%", gap * 100.0);
            // And the penalized search must explore far fewer candidates
            // (one allocation per pattern vs every allocation; at 64x64
            // the allocation fan-out is small — large tensors in the
            // Fig. 6 bench show the paper's >100x reduction).
            assert!(
                stats.evaluated < ex.candidates / 4,
                "evaluated {} of {}",
                stats.evaluated,
                ex.candidates
            );
        }
    }

    #[test]
    fn exhaustive_tracks_depth_profile() {
        let cfg = small_cfg();
        let pattern = SparsityPattern::Unstructured { density: 0.3 };
        let ex = exhaustive_search(32, 32, &pattern, &cfg);
        assert!(!ex.best_by_depth.is_empty());
        let global = ex
            .best_by_depth
            .iter()
            .map(|&(_, b)| b)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(global, ex.best_bits);
    }

    #[test]
    fn gap_is_zero_when_equal() {
        assert_eq!(optimality_gap(100.0, 100.0), 0.0);
        assert!((optimality_gap(100.31, 100.0) - 0.0031).abs() < 1e-9);
        // Penalized can't be better than true best; clamp at 0.
        assert_eq!(optimality_gap(99.0, 100.0), 0.0);
    }
}
