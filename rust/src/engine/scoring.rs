//! Importance-based scoring (paper §III-C3): select one shared
//! compression format for an accelerator serving multiple LLMs with
//! different structures, sparsity and usage frequencies.
//!
//! Hardware supports one format *pattern*; per-tensor dimension
//! allocation still adapts (a pattern like `UOP(M)-B(N)` instantiates on
//! any shape).  Selection minimizes `Σ_i ImpScore(LLM_i) ×
//! OptMetric(LLM_i)` over candidate patterns, where the per-workload
//! metric is the traffic-weighted compressed size of all operand tensors.

use super::{allocate, search_formats, EngineConfig};
use crate::format::{CompPat, Prim};
use crate::sparsity::analyzer::analytical_cost;
use crate::sparsity::SparsityPattern;
use crate::workload::Workload;

/// A workload with its importance score (usage frequency / priority).
pub struct WeightedWorkload<'a> {
    pub workload: &'a Workload,
    pub importance: f64,
}

/// Traffic-weighted compressed bits of every operand tensor of `w` under
/// pattern `pat` (per-tensor allocation chosen by the engine).  Falls
/// back to dense bits when the pattern cannot allocate on a shape.
///
/// Identical (shape, sparsity) tensors recur across a transformer's
/// layers and phases, so the per-tensor allocation + costing is memoized
/// within one call — the same idea as the co-search's `access_counts`
/// cache, one layer up.
pub fn workload_format_bits(w: &Workload, pat: &CompPat, cfg: &EngineConfig) -> f64 {
    let mut memo: std::collections::HashMap<(u64, u64, String), f64> =
        std::collections::HashMap::new();
    let mut total = 0.0;
    for op in &w.ops {
        let tensors: [(u64, u64, &SparsityPattern); 2] = [
            (op.dims.m, op.dims.n, &op.spec.input),
            (op.dims.n, op.dims.k, &op.spec.weight),
        ];
        for (rows, cols, pattern) in tensors {
            let key = (rows, cols, format!("{pattern:?}"));
            let bits = *memo.entry(key).or_insert_with(|| {
                match allocate::choose_allocation(pat, rows, cols, pattern, None, cfg) {
                    Some(f) => analytical_cost(&f, pattern, cfg.data_bits).total_bits(),
                    None => (rows * cols) as f64 * cfg.data_bits as f64,
                }
            });
            total += bits * op.count as f64;
        }
    }
    total
}

/// Result of shared-pattern selection.
#[derive(Clone, Debug)]
pub struct SharedSelection {
    pub pattern: CompPat,
    /// Per-workload metric under the chosen pattern, in input order.
    pub per_workload_bits: Vec<f64>,
    /// The weighted objective value.
    pub weighted_bits: f64,
}

/// Candidate patterns: the per-workload optima (engine search on each
/// workload's dominant tensor shapes) plus the four standard baselines.
fn candidate_patterns(ws: &[WeightedWorkload<'_>], cfg: &EngineConfig) -> Vec<CompPat> {
    use crate::format::Axis;
    let mut cands: Vec<CompPat> = vec![
        // Baselines: Bitmap, RLE, CSR, COO (as patterns).
        CompPat::new(vec![(Prim::None, Axis::Row), (Prim::B, Axis::Col)]),
        CompPat::new(vec![(Prim::None, Axis::Row), (Prim::Rle, Axis::Col)]),
        CompPat::new(vec![(Prim::Uop, Axis::Row), (Prim::Cp, Axis::Col)]),
        CompPat::new(vec![(Prim::Cp, Axis::Row), (Prim::Cp, Axis::Col)]),
    ];
    for ww in ws {
        // Dominant tensors: the sparse ops with the most MACs; search
        // formats for both operands of each.
        let mut ops: Vec<_> = ww
            .workload
            .ops
            .iter()
            .filter(|o| o.spec.input.density() < 1.0 || o.spec.weight.density() < 1.0)
            .collect();
        ops.sort_by(|a, b| b.total_macs().partial_cmp(&a.total_macs()).unwrap());
        for op in ops.into_iter().take(3) {
            for (rows, cols, pattern) in [
                (op.dims.m, op.dims.n, op.spec.input),
                (op.dims.n, op.dims.k, op.spec.weight),
            ] {
                let (top, _) = search_formats(rows, cols, &pattern, None, cfg);
                for s in top.into_iter().take(2) {
                    cands.push(s.format.pattern());
                }
            }
        }
    }
    // Dedupe by display form.
    let mut seen = std::collections::HashSet::new();
    cands.retain(|p| seen.insert(p.to_string()));
    cands
}

/// Select the shared pattern minimizing the importance-weighted metric.
pub fn select_shared_pattern(
    ws: &[WeightedWorkload<'_>],
    cfg: &EngineConfig,
) -> SharedSelection {
    assert!(!ws.is_empty());
    let mut best: Option<SharedSelection> = None;
    for pat in candidate_patterns(ws, cfg) {
        let per: Vec<f64> = ws
            .iter()
            .map(|ww| workload_format_bits(ww.workload, &pat, cfg))
            .collect();
        let weighted: f64 = ws
            .iter()
            .zip(&per)
            .map(|(ww, &b)| ww.importance * b)
            .sum();
        if best
            .as_ref()
            .map(|b| weighted < b.weighted_bits)
            .unwrap_or(true)
        {
            best = Some(SharedSelection {
                pattern: pat,
                per_workload_bits: per,
                weighted_bits: weighted,
            });
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::space::SpaceConfig;
    use crate::workload::llm;

    fn fast_cfg() -> EngineConfig {
        EngineConfig {
            space: SpaceConfig { max_depth: 3, ..Default::default() },
            top_k: 2,
            ..Default::default()
        }
    }

    #[test]
    fn selection_minimizes_weighted_objective() {
        let cfg = fast_cfg();
        let a = llm::opt_125m(llm::Phase::new(256, 32));
        let b = llm::bert_base(256);
        let ws = [
            WeightedWorkload { workload: &a, importance: 99.0 },
            WeightedWorkload { workload: &b, importance: 1.0 },
        ];
        let sel = select_shared_pattern(&ws, &cfg);
        // The selected pattern's weighted cost must beat every baseline.
        for pat in [
            crate::format::named::bitmap(4, 4).pattern(),
            crate::format::named::csr(4, 4).pattern(),
        ] {
            let w: f64 = ws
                .iter()
                .map(|ww| ww.importance * workload_format_bits(ww.workload, &pat, &cfg))
                .sum();
            assert!(sel.weighted_bits <= w * 1.0001, "{} beaten by {pat}", sel.pattern);
        }
    }

    #[test]
    fn importance_shifts_the_choice_toward_the_heavy_model() {
        // With all weight on workload A, the shared metric equals A's own;
        // per-workload bits are still reported for both.
        let cfg = fast_cfg();
        let a = llm::opt_125m(llm::Phase::new(256, 32));
        let b = llm::bert_base(256);
        let ws_a = [
            WeightedWorkload { workload: &a, importance: 1.0 },
            WeightedWorkload { workload: &b, importance: 0.0 },
        ];
        let sel_a = select_shared_pattern(&ws_a, &cfg);
        assert_eq!(sel_a.per_workload_bits.len(), 2);
        assert!((sel_a.weighted_bits - sel_a.per_workload_bits[0]).abs() < 1e-6);
    }

    #[test]
    fn shared_selection_spans_gqa_and_moe_scenarios() {
        // Scenario-zoo coverage: one shared pattern must score finite,
        // positive bits on a GQA model and a routed-expert MoE model at
        // once (the multi-model accelerator serving both).
        use crate::workload::{gqa, moe};
        let cfg = fast_cfg();
        let a = gqa::gqa_tiny(llm::Phase::new(64, 8));
        let b = moe::moe_tiny(llm::Phase::new(64, 8));
        let ws = [
            WeightedWorkload { workload: &a, importance: 2.0 },
            WeightedWorkload { workload: &b, importance: 1.0 },
        ];
        let sel = select_shared_pattern(&ws, &cfg);
        assert_eq!(sel.per_workload_bits.len(), 2);
        assert!(sel.weighted_bits.is_finite() && sel.weighted_bits > 0.0);
        for bits in &sel.per_workload_bits {
            assert!(bits.is_finite() && *bits > 0.0);
        }
    }

    #[test]
    fn nm_weight_tensors_score_under_shared_patterns() {
        // N:M weights flow through the importance-based scoring: the
        // bitmap pattern must cost less on 2:8 weights than on the same
        // workload with dense weights (fewer payload words).
        let cfg = fast_cfg();
        let base = llm::opt_125m(llm::Phase::prefill_only(64));
        let nm = llm::weight_nm_variant(base.clone(), 2, 8);
        let pat = crate::format::named::bitmap(4, 4).pattern();
        let dense_w = llm::activation_sparse_variant(base); // dense weights, sparse acts
        let bits_nm = workload_format_bits(&nm, &pat, &cfg);
        let bits_dense = workload_format_bits(&dense_w, &pat, &cfg);
        assert!(bits_nm.is_finite() && bits_nm > 0.0);
        assert!(bits_nm < bits_dense, "nm {bits_nm} vs dense-weight {bits_dense}");
    }

    #[test]
    fn dense_fallback_for_unallocatable_shapes() {
        // A 3-row-level pattern cannot allocate rows=2 with >1 sizes; the
        // metric must still be finite (dense fallback).
        use crate::format::Axis;
        let cfg = fast_cfg();
        let pat = CompPat::new(vec![
            (Prim::B, Axis::Row),
            (Prim::B, Axis::Row),
            (Prim::B, Axis::Col),
        ]);
        let w = Workload {
            name: "tiny".into(),
            ops: vec![crate::workload::MatMulOp {
                name: "t".into(),
                dims: crate::dataflow::ProblemDims::new(2, 8, 8),
                spec: crate::sparsity::SparsitySpec::unstructured(0.5, 0.5),
                count: 1,
            }],
        };
        let bits = workload_format_bits(&w, &pat, &cfg);
        assert!(bits.is_finite() && bits > 0.0);
    }
}
