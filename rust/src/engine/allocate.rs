//! Efficiency-oriented dimension allocation (paper §III-C2).
//!
//! For a fixed compression pattern, many subdimension decompositions
//! exist; each affects (de)compression cost.  The paper's rule: align the
//! allocation with the dataflow's loop-ordering tile sizes — e.g. for
//! `B(M1)-B(M2)` with an outer M-tile of 8 and inner of 32, choose
//! `(M1, M2) = (8, 32)`; other decompositions like `(32, 8)` or `(64, 4)`
//! misalign the compression hierarchy with the access stream and incur
//! runtime overhead [34].
//!
//! We model the misalignment overhead as a fractional surcharge on the
//! tensor's bit cost per misaligned level, and pick the allocation with
//! the lowest surcharged cost.

use super::EngineConfig;
use crate::format::space::enumerate_allocations;
use crate::format::{Axis, CompPat, Format};
use crate::sparsity::analyzer::analytical_cost_quant;
use crate::sparsity::SparsityPattern;

/// Per-axis dataflow tile factors, outermost first (from the chosen loop
/// ordering: the factor by which each memory level splits the axis).
#[derive(Clone, Debug, Default)]
pub struct TileHints {
    pub row: Vec<u64>,
    pub col: Vec<u64>,
}

/// Fractional cost surcharge per misaligned level.
const MISALIGN_SURCHARGE: f64 = 0.02;

/// Count levels whose size does not match the dataflow hint for its axis
/// position (outermost level on an axis should match the outermost hint).
pub fn misaligned_levels(format: &Format, hints: &TileHints) -> usize {
    let mut mis = 0;
    let mut row_pos = 0;
    let mut col_pos = 0;
    for l in &format.levels {
        let (hint, pos) = match l.axis {
            Axis::Row => (&hints.row, &mut row_pos),
            Axis::Col => (&hints.col, &mut col_pos),
        };
        if let Some(&h) = hint.get(*pos) {
            if h != l.size {
                mis += 1;
            }
        }
        *pos += 1;
    }
    mis
}

/// Build the hint-aligned allocation directly: assign each axis level the
/// corresponding dataflow tile factor (outermost first), folding any
/// remainder into the last level.  Returns `None` when the hints don't
/// divide the axis cleanly for this level structure.
pub fn aligned_allocation(
    pat: &CompPat,
    rows: u64,
    cols: u64,
    hints: &TileHints,
) -> Option<Format> {
    use crate::format::Level;
    let mut levels: Vec<Level> = pat
        .levels
        .iter()
        .map(|l| Level { prim: l.prim.clone(), axis: l.axis, size: 0 })
        .collect();
    for (axis, extent, hint) in [(Axis::Row, rows, &hints.row), (Axis::Col, cols, &hints.col)] {
        let slots: Vec<usize> = pat
            .levels
            .iter()
            .enumerate()
            .filter(|(_, l)| l.axis == axis)
            .map(|(i, _)| i)
            .collect();
        if slots.is_empty() {
            if extent != 1 {
                return None;
            }
            continue;
        }
        let mut rem = extent;
        for (j, &slot) in slots.iter().enumerate() {
            if j + 1 == slots.len() {
                levels[slot].size = rem;
                rem = 1;
            } else {
                let h = hint.get(j).copied().unwrap_or(1).max(1);
                if rem % h != 0 {
                    return None;
                }
                levels[slot].size = h;
                rem /= h;
            }
        }
        if rem != 1 {
            return None;
        }
    }
    Format::new(levels, rows, cols).ok()
}

/// Choose the best allocation of `pat` over an `rows x cols` tensor:
/// minimize analytical bit cost plus the misalignment surcharge.
///
/// Fast path (§III-C2): with dataflow tile hints available, the aligned
/// allocation is constructed directly plus a small set of balanced
/// alternatives — the full enumeration is the hint-free fallback.  This
/// is what keeps format search tractable inside the per-op co-search
/// loop (see EXPERIMENTS.md §Perf).
pub fn choose_allocation(
    pat: &CompPat,
    rows: u64,
    cols: u64,
    pattern: &SparsityPattern,
    hints: Option<&TileHints>,
    cfg: &EngineConfig,
) -> Option<Format> {
    choose_allocation_quant(pat, rows, cols, pattern, hints, cfg, cfg.data_bits)
}

/// [`choose_allocation`] with the payload quantized to `payload_bits`
/// (see `format::quant`): the allocation ranking reruns under the
/// quantized bit cost, so a width that shrinks the payload share can
/// shift the best split.  `payload_bits == cfg.data_bits` reproduces
/// [`choose_allocation`] bit for bit.
pub fn choose_allocation_quant(
    pat: &CompPat,
    rows: u64,
    cols: u64,
    pattern: &SparsityPattern,
    hints: Option<&TileHints>,
    cfg: &EngineConfig,
    payload_bits: u32,
) -> Option<Format> {
    let mut candidates: Vec<Format> = Vec::new();
    if let Some(h) = hints {
        if let Some(f) = aligned_allocation(pat, rows, cols, h) {
            candidates.push(f);
        }
        // A few balanced alternatives: split each axis near-evenly.
        let balanced = TileHints {
            row: balanced_split(rows, pat.levels.iter().filter(|l| l.axis == Axis::Row).count()),
            col: balanced_split(cols, pat.levels.iter().filter(|l| l.axis == Axis::Col).count()),
        };
        if let Some(f) = aligned_allocation(pat, rows, cols, &balanced) {
            if !candidates.contains(&f) {
                candidates.push(f);
            }
        }
        // Plus a bounded sample of the raw enumeration: divisor order
        // starts with small factors (2, 4, 8, ...), which covers the
        // block-granularity allocations structured sparsity rewards and
        // the dataflow hints cannot anticipate.
        for f in enumerate_allocations(pat, rows, cols, &cfg.space)
            .into_iter()
            .take(24)
        {
            if !candidates.contains(&f) {
                candidates.push(f);
            }
        }
    }
    if candidates.is_empty() {
        candidates = enumerate_allocations(pat, rows, cols, &cfg.space);
    }
    let mut best: Option<(f64, Format)> = None;
    for f in candidates {
        let bits = analytical_cost_quant(&f, pattern, cfg.data_bits, payload_bits).total_bits();
        let surcharge = match hints {
            Some(h) => 1.0 + MISALIGN_SURCHARGE * misaligned_levels(&f, h) as f64,
            None => 1.0,
        };
        let score = bits * surcharge;
        if best.as_ref().map(|(b, _)| score < *b).unwrap_or(true) {
            best = Some((score, f));
        }
    }
    best.map(|(_, f)| f)
}

/// Split `n` into `k` near-equal divisor factors, outermost first.
fn balanced_split(n: u64, k: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(k);
    let mut rem = n;
    for slot in 0..k {
        let left = k - slot;
        if left == 1 {
            out.push(rem);
            break;
        }
        let target = (rem as f64).powf(1.0 / left as f64).round().max(1.0) as u64;
        let d = crate::util::mathx::divisors(rem)
            .into_iter()
            .filter(|&d| d <= target)
            .next_back()
            .unwrap_or(1);
        out.push(d);
        rem /= d;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::Prim;
    use crate::sparsity::analyzer::analytical_cost;

    fn b2_pattern() -> CompPat {
        CompPat::new(vec![
            (Prim::B, Axis::Row),
            (Prim::B, Axis::Row),
            (Prim::B, Axis::Col),
        ])
    }

    #[test]
    fn hints_steer_the_split() {
        // The paper's example: M = 256 split across two B levels; loop
        // ordering tiles M as 8 (outer) x 32 (inner).
        let cfg = EngineConfig::default();
        let hints = TileHints { row: vec![8, 32], col: vec![64] };
        let pattern = SparsityPattern::Unstructured { density: 0.5 };
        let f = choose_allocation(&b2_pattern(), 256, 64, &pattern, Some(&hints), &cfg)
            .expect("allocation");
        let row_sizes: Vec<u64> = f
            .levels
            .iter()
            .filter(|l| l.axis == Axis::Row)
            .map(|l| l.size)
            .collect();
        assert_eq!(row_sizes, vec![8, 32], "got {f}");
    }

    #[test]
    fn misalignment_counting() {
        let cfg = EngineConfig::default();
        let pattern = SparsityPattern::Unstructured { density: 0.5 };
        let f = choose_allocation(&b2_pattern(), 256, 64, &pattern, None, &cfg).unwrap();
        let aligned = TileHints {
            row: f
                .levels
                .iter()
                .filter(|l| l.axis == Axis::Row)
                .map(|l| l.size)
                .collect(),
            col: vec![64],
        };
        assert_eq!(misaligned_levels(&f, &aligned), 0);
        let anti = TileHints { row: vec![1, 1], col: vec![1] };
        assert_eq!(misaligned_levels(&f, &anti), 3);
    }

    #[test]
    fn without_hints_minimizes_pure_cost() {
        let cfg = EngineConfig::default();
        let pattern = SparsityPattern::Block { br: 8, bc: 8, block_density: 0.1 };
        let f = choose_allocation(&b2_pattern(), 64, 64, &pattern, None, &cfg).unwrap();
        // Every other allocation must cost at least as much.
        let chosen = analytical_cost(&f, &pattern, cfg.data_bits).total_bits();
        for alt in enumerate_allocations(&b2_pattern(), 64, 64, &cfg.space) {
            let c = analytical_cost(&alt, &pattern, cfg.data_bits).total_bits();
            assert!(chosen <= c + 1e-9, "{f} ({chosen}) beaten by {alt} ({c})");
        }
    }

    #[test]
    fn impossible_pattern_returns_none() {
        // Three >1 row splits of a prime extent cannot exist.
        let cfg = EngineConfig::default();
        let pat = CompPat::new(vec![
            (Prim::B, Axis::Row),
            (Prim::B, Axis::Row),
            (Prim::B, Axis::Col),
        ]);
        let pattern = SparsityPattern::Unstructured { density: 0.5 };
        assert!(choose_allocation(&pat, 7, 8, &pattern, None, &cfg).is_none());
    }
}
