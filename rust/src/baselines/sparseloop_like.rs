//! Sparseloop-style *stepwise* workflow (paper §III-D, Fig. 7 left).
//!
//! Sparseloop first searches dataflow for the **dense** workload, then
//! modifies each configuration to account for sparse features
//! (compression, computation reduction) and re-checks legality.  The
//! redundancy is structural:
//!
//! 1. Loop orders are expanded **exhaustively** (no greedy per-boundary
//!    choice — the dense pass cannot know which boundary the sparse
//!    features will make dominant).
//! 2. Every candidate is modeled **twice**: once dense, once with sparse
//!    corrections.
//! 3. Legality uses **uncompressed** footprints during generation, so
//!    compression-enabled mappings (larger tiles that only fit
//!    compressed) are never generated, and the sparse pass must re-check
//!    legality anyway.
//!
//! The SnipSnap progressive workflow (`crate::search`) removes all three.

use crate::arch::Accelerator;
use crate::cost::{mapping_is_legal, tiles_are_legal, CompressionRatios, EvalContext, Metric};
use crate::dataflow::mapper::{all_orders, MapperConfig, ProtoArena};
use crate::dataflow::ProblemDims;
use crate::engine::ScoredFormat;
use crate::search::progressive::native_format;
use crate::search::{OpDesign, ScoredMapping, SearchTelemetry, WorkloadResult};
use crate::sparsity::reduction::ReductionStrategy;
use crate::sparsity::SparsitySpec;
use crate::workload::{MatMulOp, Workload};
use std::time::Instant;

/// Stepwise search for one operator with the accelerator's fixed native
/// format.  Returns the best sparse design; evaluation counts and cache
/// statistics accumulate into `tel`.  The workflow stays single-threaded
/// by construction (it is the Table I comparison target), but it now
/// evaluates through an [`EvalContext`]: the dense pass and the sparse
/// re-modeling of the same mapping share one cached `access_counts`
/// result, so even the baseline's structural double-modeling no longer
/// recounts traffic twice.
pub fn stepwise_op(
    arch: &Accelerator,
    op: &MatMulOp,
    mapper: &MapperConfig,
    metric: Metric,
    tel: &mut SearchTelemetry,
) -> Option<OpDesign> {
    let p = op.dims;
    let dense_spec = SparsitySpec::dense();
    let fi = ScoredFormat::score(
        native_format(arch, p.m, p.n),
        &op.spec.input,
        &crate::engine::EngineConfig::default(),
    );
    let fw = ScoredFormat::score(
        native_format(arch, p.n, p.k),
        &op.spec.weight,
        &crate::engine::EngineConfig::default(),
    );
    let ratios = CompressionRatios {
        input: fi.cost.ratio().min(1.0),
        weight: fw.cost.ratio().min(1.0),
    };

    let orders = all_orders();
    let mut ctx = EvalContext::new(arch, p, metric);
    let mut best: Option<ScoredMapping> = None;

    // Step 1 legality: *dense* footprints (no compression awareness) —
    // evaluated on the packed arena tiles, then every proto's orders are
    // expanded exhaustively over a reused scratch mapping.  Shares the
    // progressive search's op→enumeration wiring so both workflows walk
    // the same proto space (the Table I comparison premise).
    let en = crate::search::progressive::op_enumeration(arch, &p, mapper);
    let mut arena = ProtoArena::new();
    arena.rebuild(&en, mapper, |tiles, spatial| {
        tiles_are_legal(arch, tiles, spatial, &CompressionRatios::DENSE)
    });
    tel.protos += arena.len() as u64;
    let nlevels = arch.levels.len();
    let mut m = en.scratch_mapping();
    let mut order_sets = vec![1usize; nlevels];
    let mut idx = vec![0usize; nlevels];
    for proto_id in 0..arena.len() {
        arena.write_mapping(proto_id, &mut m);
        // Exhaustive order expansion per level (unit levels collapse to
        // one order).
        for (lvl, set) in order_sets.iter_mut().enumerate() {
            let nontrivial = m.levels[lvl].factors.iter().filter(|&&f| f > 1).count();
            *set = if nontrivial <= 1 { 1 } else { orders.len() };
        }
        idx.iter_mut().for_each(|i| *i = 0);
        loop {
            for (i, &oi) in idx.iter().enumerate() {
                m.levels[i].order = orders[oi % orders.len()];
            }
            // Step 1: dense dataflow modeling (its result only ranks;
            // the work is structurally wasted — Fig. 7's green pass).
            let dense_r = ctx.evaluate(
                &m,
                &dense_spec,
                &ReductionStrategy::NONE,
                &CompressionRatios::DENSE,
            );
            let _ = metric.of(&dense_r);

            // Step 2: sparse feature modeling + legality re-check
            // (Fig. 7's blue pass).  Same mapping as step 1, so the
            // access counts come straight from the cache.
            if mapping_is_legal(arch, &m, &ratios) {
                let sparse_r = ctx.evaluate(&m, &op.spec, &arch.reduction, &ratios);
                let v = metric.of(&sparse_r);
                if best.as_ref().map(|(_, _, b)| v < *b).unwrap_or(true) {
                    best = Some((m.clone(), sparse_r, v));
                }
            }

            // Odometer over order combinations.
            let mut i = nlevels;
            let mut done = true;
            while i > 0 {
                i -= 1;
                idx[i] += 1;
                if idx[i] < order_sets[i] {
                    done = false;
                    break;
                }
                idx[i] = 0;
            }
            if done {
                break;
            }
        }
    }

    tel.absorb(&ctx);
    best.map(|(mapping, report, v)| OpDesign {
        op_name: op.name.clone(),
        input_format: fi.format.clone(),
        weight_format: fw.format.clone(),
        // The stepwise baseline predates the quant axis: native width.
        input_bits: arch.data_bits,
        weight_bits: arch.data_bits,
        mapping,
        report,
        metric_value: v,
        count: op.count,
    })
}

/// Stepwise search across a workload (the Table I comparison target).
pub fn stepwise_workload(
    arch: &Accelerator,
    w: &Workload,
    mapper: &MapperConfig,
    metric: Metric,
) -> WorkloadResult {
    let start = Instant::now();
    let mut tel = SearchTelemetry::default();
    let mut designs = Vec::new();
    for op in &w.ops {
        let d = stepwise_op(arch, op, mapper, metric, &mut tel)
            .unwrap_or_else(|| panic!("no legal mapping for {}", op.name));
        designs.push(d);
    }
    WorkloadResult {
        workload: w.name.clone(),
        designs,
        elapsed: start.elapsed(),
        evaluations: tel.evaluations,
        cache: tel.cache,
        protos: tel.protos,
        // The stepwise workflow has no lower-bound pruning by design
        // (and no frontier mode — it optimizes one metric at a time).
        pruned: 0,
        pruned_by_metric: [0; 4],
        bound_tightenings: 0,
        frontier_size: 0,
        frontier: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::search::{cosearch_workload, FormatMode, SearchConfig};

    fn toy() -> Workload {
        Workload {
            name: "toy".into(),
            ops: vec![crate::workload::MatMulOp {
                name: "op".into(),
                dims: ProblemDims::new(64, 64, 64),
                spec: SparsitySpec::unstructured(0.5, 0.5),
                count: 1,
            }],
        }
    }

    fn mapper() -> MapperConfig {
        MapperConfig { max_candidates: 500, ..Default::default() }
    }

    #[test]
    fn stepwise_finds_a_design() {
        let arch = presets::arch3();
        let r = stepwise_workload(&arch, &toy(), &mapper(), Metric::Energy);
        assert_eq!(r.designs.len(), 1);
        assert!(r.total_energy_pj() > 0.0);
    }

    #[test]
    fn stepwise_does_strictly_more_evaluations_than_progressive() {
        let arch = presets::arch3();
        let w = toy();
        let m = mapper();
        let sl = stepwise_workload(&arch, &w, &m, Metric::Energy);
        let cfg = SearchConfig {
            mode: FormatMode::Fixed,
            mapper: m,
            ..Default::default()
        };
        let ss = cosearch_workload(&arch, &w, &cfg);
        // Tile refinement adds evaluations to the progressive side on toy
        // problems; the structural gap (exhaustive ordering + double
        // modeling) still shows.
        assert!(
            sl.evaluations * 2 > 3 * ss.evaluations,
            "stepwise {} vs progressive {}",
            sl.evaluations,
            ss.evaluations
        );
    }

    #[test]
    fn solution_quality_comparable_to_progressive() {
        // The stepwise workflow is slow, not wrong: with the same space it
        // must land within a small factor of the progressive result (it
        // can even be slightly better thanks to exhaustive ordering).
        let arch = presets::arch3();
        let w = toy();
        let m = mapper();
        let sl = stepwise_workload(&arch, &w, &m, Metric::Energy);
        let cfg = SearchConfig { mode: FormatMode::Fixed, mapper: m, ..Default::default() };
        let ss = cosearch_workload(&arch, &w, &cfg);
        let ratio = ss.total_energy_pj() / sl.total_energy_pj();
        assert!(ratio < 1.25 && ratio > 0.8, "quality ratio {ratio}");
    }
}
