//! DiMO-Sparse-style iterative optimizer (DATE'24) for the §IV-D CNN
//! comparison.
//!
//! DiMO-Sparse performs differentiable/iterative optimization of sparse
//! CNN dataflow with *preset* compression formats.  We reproduce the
//! workflow shape: multi-restart coordinate descent over tiling factors
//! with full sparse re-evaluation per move, exhaustive order expansion
//! per accepted point, and no compression-aware pruning.  Like the
//! original it is limited to CNN workloads (single-batch im2col MatMuls)
//! and fixed formats.

use crate::arch::Accelerator;
use crate::cost::{evaluate, mapping_is_legal, CompressionRatios, Metric};
use crate::dataflow::mapper::{all_orders, spatial_candidates};
use crate::dataflow::{LoopDim, Mapping, ProblemDims, TileLevel};
use crate::engine::ScoredFormat;
use crate::search::progressive::native_format;
use crate::search::{OpDesign, ScoredMapping, WorkloadResult};
use crate::util::prng::Pcg32;
use crate::workload::{MatMulOp, Workload};
use std::time::Instant;

/// DiMO-like optimizer parameters.
#[derive(Clone, Debug)]
pub struct DimoConfig {
    pub restarts: usize,
    pub max_sweeps: usize,
    pub seed: u64,
}

impl Default for DimoConfig {
    fn default() -> Self {
        DimoConfig { restarts: 6, max_sweeps: 24, seed: 0xD1_40 }
    }
}

/// Check whether a workload looks like a CNN lowered to im2col MatMuls —
/// DiMO-Sparse does not generalize beyond CNNs (§IV-D).
pub fn is_cnn_workload(w: &Workload) -> bool {
    w.ops.iter().all(|o| o.count == 1)
}

fn random_mapping(
    p: &ProblemDims,
    nlevels: usize,
    arch: &Accelerator,
    rng: &mut Pcg32,
) -> Mapping {
    let spatials =
        spatial_candidates(p, arch.mac.spatial_rows, arch.mac.spatial_cols, 0.0);
    let spatial = *rng.choose(&spatials);
    let mut levels: Vec<TileLevel> = (0..nlevels)
        .map(|_| TileLevel {
            factors: [1, 1, 1],
            order: [LoopDim::M, LoopDim::N, LoopDim::K],
        })
        .collect();
    for (di, d) in LoopDim::ALL.iter().enumerate() {
        let mut rem = p.get(*d) / spatial.factor(*d);
        // Random divisor chain outermost-first.
        for level in levels.iter_mut().take(nlevels - 1) {
            let divs = crate::util::mathx::divisors(rem);
            let pick = *rng.choose(&divs);
            level.factors[di] = pick;
            rem /= pick;
        }
        levels[nlevels - 1].factors[di] = rem;
    }
    Mapping { levels, spatial }
}

/// One coordinate-descent move: shift a factor between two levels.
fn neighbors(m: &Mapping) -> Vec<Mapping> {
    let mut out = Vec::new();
    let n = m.levels.len();
    for di in 0..3 {
        for (a, fa) in m.levels.iter().map(|l| l.factors[di]).enumerate() {
            for b in 0..n {
                if a == b {
                    continue;
                }
                for step in [2u64, 3, 5, 7] {
                    if fa % step == 0 {
                        let mut nm = m.clone();
                        nm.levels[a].factors[di] /= step;
                        nm.levels[b].factors[di] *= step;
                        out.push(nm);
                    }
                }
            }
        }
    }
    out
}

/// Iterative search for one CNN layer with the fixed native format.
pub fn dimo_op(
    arch: &Accelerator,
    op: &MatMulOp,
    cfg: &DimoConfig,
    metric: Metric,
    evals: &mut u64,
) -> Option<OpDesign> {
    let p = op.dims;
    let nlevels = arch.levels.len();
    let fi = ScoredFormat::score(
        native_format(arch, p.m, p.n),
        &op.spec.input,
        &crate::engine::EngineConfig::default(),
    );
    let fw = ScoredFormat::score(
        native_format(arch, p.n, p.k),
        &op.spec.weight,
        &crate::engine::EngineConfig::default(),
    );
    let ratios = CompressionRatios {
        input: fi.cost.ratio().min(1.0),
        weight: fw.cost.ratio().min(1.0),
    };
    let orders = all_orders();
    let mut rng = Pcg32::new(cfg.seed);
    let mut best: Option<ScoredMapping> = None;

    // Full sparse evaluation with exhaustive order expansion — DiMO's
    // inner objective is evaluated on every candidate move.
    let eval_all_orders =
        |m: &Mapping, evals: &mut u64| -> Option<ScoredMapping> {
            if !mapping_is_legal(arch, m, &CompressionRatios::DENSE) {
                return None;
            }
            let mut local: Option<ScoredMapping> = None;
            let mut idx = vec![0usize; nlevels];
            loop {
                let mut cand = m.clone();
                for (i, &oi) in idx.iter().enumerate() {
                    cand.levels[i].order = orders[oi];
                }
                let r = evaluate(arch, &p, &cand, &op.spec, &arch.reduction, &ratios);
                *evals += 1;
                let v = metric.of(&r);
                if local.as_ref().map(|(_, _, b)| v < *b).unwrap_or(true) {
                    local = Some((cand, r, v));
                }
                let mut i = nlevels;
                let mut done = true;
                while i > 0 {
                    i -= 1;
                    idx[i] += 1;
                    if idx[i] < orders.len() {
                        done = false;
                        break;
                    }
                    idx[i] = 0;
                }
                if done {
                    break;
                }
            }
            local
        };

    for _ in 0..cfg.restarts {
        let mut cur = random_mapping(&p, nlevels, arch, &mut rng);
        let mut cur_val = match eval_all_orders(&cur, evals) {
            Some((m, r, v)) => {
                if best.as_ref().map(|(_, _, b)| v < *b).unwrap_or(true) {
                    best = Some((m.clone(), r, v));
                }
                v
            }
            None => f64::INFINITY,
        };
        for _ in 0..cfg.max_sweeps {
            let mut improved = false;
            for nb in neighbors(&cur) {
                if nb.validate(&p).is_err() {
                    continue;
                }
                if let Some((m, r, v)) = eval_all_orders(&nb, evals) {
                    if v < cur_val {
                        cur = nb;
                        cur_val = v;
                        improved = true;
                        if best.as_ref().map(|(_, _, b)| v < *b).unwrap_or(true) {
                            best = Some((m, r, v));
                        }
                    }
                }
            }
            if !improved {
                break;
            }
        }
    }

    best.map(|(mapping, report, v)| OpDesign {
        op_name: op.name.clone(),
        input_format: fi.format.clone(),
        weight_format: fw.format.clone(),
        // DiMO-Sparse has no quantization axis: native width.
        input_bits: arch.data_bits,
        weight_bits: arch.data_bits,
        mapping,
        report,
        metric_value: v,
        count: op.count,
    })
}

/// DiMO-like search across a CNN workload.  Panics on non-CNN workloads
/// (the original tool does not support them — §IV-D).
pub fn dimo_workload(
    arch: &Accelerator,
    w: &Workload,
    cfg: &DimoConfig,
    metric: Metric,
) -> WorkloadResult {
    assert!(is_cnn_workload(w), "DiMO-Sparse is limited to CNNs; got {}", w.name);
    let start = Instant::now();
    let mut evals = 0u64;
    let mut designs = Vec::new();
    for op in &w.ops {
        let d = dimo_op(arch, op, cfg, metric, &mut evals)
            .unwrap_or_else(|| panic!("dimo found no design for {}", op.name));
        designs.push(d);
    }
    WorkloadResult {
        workload: w.name.clone(),
        designs,
        elapsed: start.elapsed(),
        evaluations: evals,
        // DiMO evaluates uncached by design (its evaluation count is the
        // §IV-D comparison metric; a cache would only change wall time),
        // and enumerates no proto table — it random-restarts instead.
        cache: crate::cost::CacheStats::default(),
        protos: 0,
        pruned: 0,
        pruned_by_metric: [0; 4],
        bound_tightenings: 0,
        frontier_size: 0,
        frontier: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::sparsity::SparsitySpec;

    fn tiny_cnn() -> Workload {
        Workload {
            name: "tiny-cnn".into(),
            ops: vec![MatMulOp {
                name: "conv".into(),
                dims: ProblemDims::new(64, 72, 64),
                spec: SparsitySpec::unstructured(0.5, 0.4),
                count: 1,
            }],
        }
    }

    fn quick() -> DimoConfig {
        DimoConfig { restarts: 2, max_sweeps: 4, seed: 7 }
    }

    #[test]
    fn dimo_finds_a_design() {
        let arch = presets::arch1();
        let r = dimo_workload(&arch, &tiny_cnn(), &quick(), Metric::Energy);
        assert_eq!(r.designs.len(), 1);
        assert!(r.total_energy_pj() > 0.0);
        assert!(r.evaluations > 0);
    }

    #[test]
    #[should_panic(expected = "limited to CNNs")]
    fn dimo_rejects_llms() {
        let arch = presets::arch1();
        let w = crate::workload::llm::opt_125m(crate::workload::llm::Phase::prefill_only(16));
        dimo_workload(&arch, &w, &quick(), Metric::Energy);
    }

    #[test]
    fn deterministic_per_seed() {
        let arch = presets::arch1();
        let a = dimo_workload(&arch, &tiny_cnn(), &quick(), Metric::Energy);
        let b = dimo_workload(&arch, &tiny_cnn(), &quick(), Metric::Energy);
        assert_eq!(a.total_energy_pj(), b.total_energy_pj());
    }
}
