//! Baseline DSE workflows re-implemented on the same cost model, for the
//! exploration-efficiency comparisons of §IV-D (Table I and the
//! DiMO-Sparse CNN study).  See DESIGN.md §5: the originals are an
//! external C++ artifact (Sparseloop) and a closed-source tool
//! (DiMO-Sparse); re-implementing their *workflows* against our cost
//! model isolates exactly the variable the paper measures — workflow
//! efficiency — at the price of not reproducing absolute speedup values.

pub mod dimo_like;
pub mod sparseloop_like;
