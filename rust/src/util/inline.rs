//! Fixed-capacity inline vector for the cost-model hot path.
//!
//! [`AccessCounts`](crate::dataflow::AccessCounts) and
//! [`CostReport`](crate::cost::CostReport) carry one row per memory
//! level; memory hierarchies are tiny (≤ [`crate::dataflow::MAX_LEVELS`]
//! levels), yet `Vec` storage made every cost evaluation heap-allocate.
//! [`InlineVec`] keeps the rows on the stack, so the per-proto evaluation
//! path — the hottest loop in the crate — is allocation-free and the
//! memoized counts cache stores `Copy` values.
//!
//! The type derefs to a slice, so indexing, iteration and `len()` read
//! exactly like the `Vec` code it replaced.

use std::fmt;
use std::ops::{Deref, DerefMut};

/// A `Vec`-like container with inline storage for up to `N` elements.
/// Pushing beyond `N` panics — capacity is a structural invariant of the
/// caller (one row per memory level), not a growth limit.
#[derive(Clone, Copy)]
pub struct InlineVec<T, const N: usize> {
    len: usize,
    buf: [T; N],
}

impl<T: Copy + Default, const N: usize> InlineVec<T, N> {
    pub fn new() -> Self {
        InlineVec { len: 0, buf: [T::default(); N] }
    }

    pub fn push(&mut self, v: T) {
        assert!(self.len < N, "InlineVec capacity {N} exceeded");
        self.buf[self.len] = v;
        self.len += 1;
    }

    /// Drop all elements (capacity is static, so this is just `len = 0`).
    pub fn clear(&mut self) {
        self.len = 0;
    }

    pub fn from_slice(s: &[T]) -> Self {
        let mut v = Self::new();
        for &x in s {
            v.push(x);
        }
        v
    }
}

impl<T: Copy + Default, const N: usize> Default for InlineVec<T, N> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T, const N: usize> Deref for InlineVec<T, N> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        &self.buf[..self.len]
    }
}

impl<T, const N: usize> DerefMut for InlineVec<T, N> {
    fn deref_mut(&mut self) -> &mut [T] {
        &mut self.buf[..self.len]
    }
}

impl<T: PartialEq, const N: usize> PartialEq for InlineVec<T, N> {
    fn eq(&self, other: &Self) -> bool {
        self[..] == other[..]
    }
}

impl<T: fmt::Debug, const N: usize> fmt::Debug for InlineVec<T, N> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self[..].fmt(f)
    }
}

impl<'a, T, const N: usize> IntoIterator for &'a InlineVec<T, N> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;

    fn into_iter(self) -> Self::IntoIter {
        self.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_index_iterate() {
        let mut v: InlineVec<f64, 4> = InlineVec::new();
        assert!(v.is_empty());
        v.push(1.0);
        v.push(2.5);
        assert_eq!(v.len(), 2);
        assert_eq!(v[1], 2.5);
        assert_eq!(v.iter().sum::<f64>(), 3.5);
        v[0] = 7.0;
        assert_eq!(v[0], 7.0);
        v.clear();
        assert!(v.is_empty());
    }

    #[test]
    fn equality_ignores_spare_capacity() {
        let a: InlineVec<f64, 8> = InlineVec::from_slice(&[1.0, 2.0]);
        let mut b: InlineVec<f64, 8> = InlineVec::new();
        b.push(1.0);
        b.push(2.0);
        assert_eq!(a, b);
        b.push(3.0);
        assert_ne!(a, b);
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn overflow_panics() {
        let mut v: InlineVec<u32, 2> = InlineVec::new();
        v.push(1);
        v.push(2);
        v.push(3);
    }

    #[test]
    fn debug_formats_as_slice() {
        let v: InlineVec<u32, 4> = InlineVec::from_slice(&[1, 2]);
        assert_eq!(format!("{v:?}"), "[1, 2]");
    }
}
