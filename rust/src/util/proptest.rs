//! Mini property-testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`]; the runner executes it for a
//! configurable number of cases with deterministic per-case seeds and, on
//! failure, reports the seed so the case reproduces exactly:
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath in this image)
//! use snipsnap::util::proptest::{run, Gen};
//! run("addition commutes", 100, |g: &mut Gen| {
//!     let a = g.u64_in(0, 1000);
//!     let b = g.u64_in(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::prng::Pcg32;

/// Per-case value source with convenience generators.
pub struct Gen {
    pub rng: Pcg32,
    pub case: usize,
}

impl Gen {
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + (self.rng.next_u64() % (hi - lo + 1))
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_usize(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// A density in [0,1] biased toward interesting extremes.
    pub fn density(&mut self) -> f64 {
        match self.rng.next_bounded(5) {
            0 => 0.0,
            1 => 1.0,
            2 => self.f64_in(0.0, 0.1),
            3 => self.f64_in(0.9, 1.0),
            _ => self.f64_in(0.0, 1.0),
        }
    }

    /// A "nice" dimension size: a product of small primes, up to `max`.
    pub fn dim(&mut self, max: u64) -> u64 {
        let mut n = 1u64;
        loop {
            let f = *self.rng.choose(&[2u64, 2, 2, 3, 4, 5, 7, 8]);
            if n * f > max {
                return n;
            }
            n *= f;
            if self.rng.bernoulli(0.3) {
                return n;
            }
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
}

/// Run `cases` instances of `prop` with deterministic seeds derived from
/// `name`.  Panics (with the reproducing seed) if any case panics.
pub fn run<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Pcg32::new(seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        run("trivial", 50, |_g| n += 1);
        assert_eq!(n, 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            run("fails", 10, |g: &mut Gen| {
                assert!(g.u64_in(0, 9) < 100, "impossible");
                if g.case == 3 {
                    panic!("boom");
                }
            });
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string>".to_string());
        assert!(msg.contains("case 3"), "{msg}");
        assert!(msg.contains("seed"), "{msg}");
    }

    #[test]
    fn gen_ranges_hold() {
        run("ranges", 200, |g: &mut Gen| {
            let x = g.u64_in(5, 10);
            assert!((5..=10).contains(&x));
            let d = g.density();
            assert!((0.0..=1.0).contains(&d));
            let n = g.dim(4096);
            assert!(n >= 1 && n <= 4096);
        });
    }
}
