//! Scoped worker pool for the parallel co-search.
//!
//! Offline builds cannot take a `rayon` dependency, so this module
//! provides the one primitive the search needs: map a closure over a
//! slice on up to `n` OS threads ([`parallel_map`]), with results
//! returned **in input order** regardless of which worker processed
//! which item.  Workers pull items off a shared atomic cursor (work
//! stealing), so heterogeneous item costs balance automatically;
//! determinism is preserved because the output slot of item `i` is fixed
//! by `i`, never by scheduling.
//!
//! The co-search layers two levels of sharding on top of this primitive
//! (see [`crate::search`]): operators across pool workers, and — when
//! threads outnumber operators — the per-op
//! [`ProtoArena`](crate::dataflow::mapper::ProtoArena) across index
//! shards, merged by a deterministic `(metric value, proto id)` total
//! order.  Uneven thread counts are redistributed as extra shards on
//! the leading operators (`search::progressive::split_threads`) rather
//! than left idle.  The full determinism contract is documented in
//! `docs/SEARCH.md`.

use std::sync::atomic::{AtomicUsize, Ordering};

/// One worker's output: `(input index, result)` pairs in the order the
/// worker pulled them off the cursor.
type IndexedResults<R> = Vec<(usize, R)>;

/// Resolve a configured thread count: `0` means "use all available
/// cores"; any other value is taken literally.
pub fn resolve_threads(configured: usize) -> usize {
    if configured == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        configured
    }
}

/// Map `f` over `items` on up to `threads` scoped OS threads, returning
/// the results in input order.  `f` receives `(index, &item)`.
///
/// With `threads <= 1` (or fewer than two items) everything runs inline
/// on the caller's thread — the serial path spawns nothing, so
/// `threads = 1` is exactly the pre-parallel code path.
///
/// A panic in `f` propagates to the caller once all workers have
/// stopped, **with its original payload** — the join re-raises via
/// [`std::panic::resume_unwind`] instead of wrapping the panic in a
/// generic message, so `catch_unwind` callers (and test output) see the
/// worker's own message.
pub fn parallel_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let parts: Vec<IndexedResults<R>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(i, &items[i])));
                    }
                    local
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|payload| std::panic::resume_unwind(payload)))
            .collect()
    });
    let mut out: Vec<Option<R>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    for (i, r) in parts.into_iter().flatten() {
        debug_assert!(out[i].is_none(), "item {i} produced twice");
        out[i] = Some(r);
    }
    out.into_iter()
        .map(|r| r.expect("pool dropped an item"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<u64> = (0..257).collect();
        let serial = parallel_map(1, &items, |i, &x| x * 2 + i as u64);
        let par = parallel_map(4, &items, |i, &x| x * 2 + i as u64);
        assert_eq!(serial, par);
        assert_eq!(par[10], 30);
    }

    #[test]
    fn more_threads_than_items() {
        let items = [1u32, 2];
        assert_eq!(parallel_map(8, &items, |_, &x| x + 1), vec![2, 3]);
        let empty: [u32; 0] = [];
        assert!(parallel_map(4, &empty, |_, &x| x).is_empty());
    }

    #[test]
    fn work_stealing_covers_every_item_once() {
        // Uneven per-item cost: early items are expensive, so a static
        // block split would leave workers idle; the cursor must still
        // yield each index exactly once.
        let items: Vec<u32> = (0..64).collect();
        let out = parallel_map(3, &items, |i, &x| {
            if i < 4 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            x
        });
        assert_eq!(out, items);
    }

    #[test]
    fn zero_threads_resolves_to_available_cores() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    /// Regression: worker joins used `.expect("pool worker panicked")`,
    /// replacing the original panic message with a generic one.  The
    /// payload must survive the scoped join intact.
    #[test]
    fn worker_panic_payload_survives() {
        let items: Vec<u32> = (0..16).collect();
        let result = std::panic::catch_unwind(|| {
            parallel_map(2, &items, |i, &x| {
                if i == 7 {
                    panic!("original worker payload 1337");
                }
                x
            })
        });
        let payload = result.expect_err("worker panic must propagate");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .expect("payload must stay a string message");
        assert!(
            msg.contains("original worker payload 1337"),
            "payload was rewritten: {msg}"
        );
    }
}
