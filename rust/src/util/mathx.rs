//! Small math helpers used across the analyzer, format and dataflow code:
//! integer factorization, divisor enumeration, ceil-log2, binomial terms.

/// `ceil(log2(x))` for x >= 1; coordinate width in bits for a fanout.
/// By convention a fanout of 1 still needs 1 bit (degenerate coordinate).
pub fn ceil_log2(x: u64) -> u32 {
    debug_assert!(x >= 1);
    if x <= 2 {
        1
    } else {
        64 - (x - 1).leading_zeros()
    }
}

/// All divisors of `n`, ascending.
pub fn divisors(n: u64) -> Vec<u64> {
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            small.push(d);
            if d * d != n {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// Prime factorization as (prime, multiplicity) pairs, ascending primes.
pub fn factorize(mut n: u64) -> Vec<(u64, u32)> {
    let mut out = Vec::new();
    let mut p = 2;
    while p * p <= n {
        if n % p == 0 {
            let mut m = 0;
            while n % p == 0 {
                n /= p;
                m += 1;
            }
            out.push((p, m));
        }
        p += if p == 2 { 1 } else { 2 };
    }
    if n > 1 {
        out.push((n, 1));
    }
    out
}

/// All ordered ways to write `n` as a product of exactly `k` factors >= 1.
/// Used for subdimension decomposition in the dimension-allocation space.
pub fn ordered_factorizations(n: u64, k: usize) -> Vec<Vec<u64>> {
    fn rec(n: u64, k: usize, acc: &mut Vec<u64>, out: &mut Vec<Vec<u64>>) {
        if k == 1 {
            acc.push(n);
            out.push(acc.clone());
            acc.pop();
            return;
        }
        for d in divisors(n) {
            acc.push(d);
            rec(n / d, k - 1, acc, out);
            acc.pop();
        }
    }
    let mut out = Vec::new();
    if k >= 1 {
        rec(n, k, &mut Vec::new(), &mut out);
    }
    out
}

/// ln(n!) — exact summation for small n, Stirling series beyond (relative
/// error < 1e-12 for n > 256).
pub fn ln_factorial(n: u64) -> f64 {
    if n < 2 {
        return 0.0;
    }
    if n <= 256 {
        return (2..=n).map(|i| (i as f64).ln()).sum();
    }
    let x = (n + 1) as f64;
    // Stirling series for ln Gamma(x).
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    (x - 0.5) * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI).ln()
        + inv / 12.0 * (1.0 - inv2 / 30.0 * (1.0 - inv2 * 2.0 / 7.0))
}

/// ln C(n, k).
pub fn ln_choose(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    ln_factorial(n) - ln_factorial(k) - ln_factorial(n - k)
}

/// Probability that a group of `g` elements drawn iid Bernoulli(density)
/// contains at least one non-zero: `1 - (1-d)^g`, numerically stable.
pub fn p_nonempty_iid(density: f64, g: f64) -> f64 {
    if density <= 0.0 {
        return 0.0;
    }
    if density >= 1.0 {
        return 1.0;
    }
    -(g * (1.0 - density).ln()).exp_m1()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log2_known_values() {
        assert_eq!(ceil_log2(1), 1);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(1024), 10);
        assert_eq!(ceil_log2(1025), 11);
    }

    #[test]
    fn divisors_of_12() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(7), vec![1, 7]);
        assert_eq!(divisors(36), vec![1, 2, 3, 4, 6, 9, 12, 18, 36]);
    }

    #[test]
    fn factorize_known() {
        assert_eq!(factorize(12), vec![(2, 2), (3, 1)]);
        assert_eq!(factorize(4096), vec![(2, 12)]);
        // 11008 = 2^7 * 86 = 2^8 * 43
        assert_eq!(factorize(11008), vec![(2, 8), (43, 1)]);
    }

    #[test]
    fn ordered_factorizations_product_invariant() {
        for f in ordered_factorizations(24, 3) {
            assert_eq!(f.iter().product::<u64>(), 24);
            assert_eq!(f.len(), 3);
        }
        assert_eq!(ordered_factorizations(6, 2).len(), 4); // (1,6),(2,3),(3,2),(6,1)
    }

    #[test]
    fn ln_factorial_matches_direct() {
        let direct: f64 = (1..=20u64).map(|i| (i as f64).ln()).sum();
        assert!((ln_factorial(20) - direct).abs() < 1e-9);
    }

    #[test]
    fn ln_choose_symmetry() {
        assert!((ln_choose(10, 3) - ln_choose(10, 7)).abs() < 1e-9);
        assert!((ln_choose(10, 3).exp() - 120.0).abs() < 1e-6);
    }

    #[test]
    fn p_nonempty_limits() {
        assert_eq!(p_nonempty_iid(0.0, 100.0), 0.0);
        assert_eq!(p_nonempty_iid(1.0, 100.0), 1.0);
        let p = p_nonempty_iid(0.5, 1.0);
        assert!((p - 0.5).abs() < 1e-12);
        let p = p_nonempty_iid(0.1, 2.0);
        assert!((p - 0.19).abs() < 1e-12);
    }
}
