//! Deterministic PRNG: PCG-XSH-RR 64/32 plus a SplitMix64 seeder.
//!
//! Used by the synthetic tensor sampler, the Monte-Carlo validation tests
//! and the property-test harness.
//!
//! # Determinism guarantees
//!
//! - **Seed-determined**: every output of [`Pcg32`] is a pure function of
//!   the `new(seed)` argument; no global state, time, thread identity or
//!   OS entropy is ever consulted.
//! - **Platform-independent**: the generators use only fixed-width
//!   wrapping integer arithmetic, so the same seed yields the same
//!   sequence on every architecture, OS and (stable) compiler version.
//!   Floating-point helpers derive from integer draws by exact power-of-
//!   two scaling, which is also bit-reproducible.
//! - **Stable across releases**: the PCG-XSH-RR 64/32 and SplitMix64
//!   algorithms and their constants are part of this module's contract.
//!   Changing them would silently alter every sampled mask and
//!   property-test case, so any such change must be treated as breaking
//!   (bench baselines and recorded seeds would no longer reproduce).
//! - **Stream-independent**: [`Pcg32::new_stream`] decorrelates nearby
//!   seed/stream pairs through SplitMix64, so per-case seeds derived by
//!   hashing (see [`crate::util::proptest`]) behave as independent
//!   generators.
//!
//! A reported failing seed (e.g. from the property harness) therefore
//! reproduces the exact same case on any machine.

/// PCG-XSH-RR 64/32 (O'Neill 2014). 64-bit state, 32-bit output.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with SplitMix64 so nearby seeds decorrelate.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = sm.next_u64();
        let inc = sm.next_u64() | 1;
        let mut rng = Pcg32 { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Independent stream `stream` of the same seed.
    pub fn new_stream(seed: u64, stream: u64) -> Self {
        Self::new(seed ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Unbiased uniform integer in [0, bound) (Lemire rejection).
    #[inline]
    pub fn next_bounded(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(bound as u64);
            let l = m as u32;
            if l >= bound || l >= (u32::MAX - bound + 1) % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.next_bounded((hi - lo + 1) as u32) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_bounded((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.next_bounded(xs.len() as u32) as usize]
    }
}

/// SplitMix64 — seeding helper and a fast standalone generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::new(123);
        let mut b = Pcg32::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bounded_is_in_range_and_roughly_uniform() {
        let mut r = Pcg32::new(99);
        let mut hist = [0u32; 10];
        for _ in 0..100_000 {
            hist[r.next_bounded(10) as usize] += 1;
        }
        for &h in &hist {
            assert!((8_000..12_000).contains(&h), "hist {hist:?}");
        }
    }

    #[test]
    fn bernoulli_mean_close_to_p() {
        let mut r = Pcg32::new(5);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        let mean = hits as f64 / 100_000.0;
        assert!((mean - 0.3).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Pcg32::new(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
