//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Each bench target is a `harness = false` binary that prints the
//! corresponding paper table/figure as an ASCII table and appends a
//! machine-readable record to `results/<bench>.json`.

use crate::util::json::Json;
use std::time::Instant;

/// Measure wall-clock seconds of one closure run.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Median-of-n timing for fast operations.
pub fn time_median<T>(n: usize, mut f: impl FnMut() -> T) -> f64 {
    assert!(n >= 1);
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    crate::util::stats::median(&samples)
}

/// Write a bench result record to `results/<name>.json`.
pub fn write_result(name: &str, payload: Json) {
    let dir = std::path::Path::new("results");
    if std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let path = dir.join(format!("{name}.json"));
    let record = Json::obj(vec![("bench", Json::str(name)), ("data", payload)]);
    let _ = std::fs::write(path, record.to_string());
}

/// Current git revision (short), or `"unknown"` outside a work tree /
/// without git on PATH.  Used to stamp bench records so result files are
/// attributable after the fact.
pub fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Write a bench record under the unified schema (ROADMAP "bench JSON
/// emission"): `{bench, git_rev, wall_time_s, rows}` — bench id, the
/// git revision the numbers came from, total wall time of the run, and
/// the per-row payload (an array or object of measurements).  New bench
/// targets should prefer this over the legacy [`write_result`] shape.
pub fn write_record(name: &str, wall_time_s: f64, rows: Json) {
    let dir = std::path::Path::new("results");
    if std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let path = dir.join(format!("{name}.json"));
    let record = Json::obj(vec![
        ("bench", Json::str(name)),
        ("git_rev", Json::str(&git_rev())),
        ("wall_time_s", Json::num(wall_time_s)),
        ("rows", rows),
    ]);
    let _ = std::fs::write(path, record.to_string());
}

/// Standard bench banner.
pub fn banner(id: &str, what: &str) {
    println!("\n================================================================");
    println!("{id} — {what}");
    println!("================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn git_rev_never_panics_and_is_nonempty() {
        let rev = git_rev();
        assert!(!rev.is_empty());
        assert!(!rev.contains('\n'));
    }

    #[test]
    fn timing_is_positive() {
        let (v, t) = time_once(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499500);
        assert!(t >= 0.0);
        let m = time_median(3, || (0..100).product::<u128>());
        assert!(m >= 0.0);
    }
}
