//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Each bench target is a `harness = false` binary that prints the
//! corresponding paper table/figure as an ASCII table and **appends** a
//! machine-readable record to `results/<bench>.jsonl` — one JSON object
//! per line, so the performance trajectory accumulates across runs
//! instead of the last run clobbering the history.  `snipsnap report`
//! rolls the accumulated records up into a cross-bench summary (see
//! [`crate::report`] and docs/ARCHITECTURE.md "Run artifacts").

use crate::util::json::Json;
use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// Measure wall-clock seconds of one closure run.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Median-of-n timing for fast operations.
pub fn time_median<T>(n: usize, mut f: impl FnMut() -> T) -> f64 {
    assert!(n >= 1);
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    crate::util::stats::median(&samples)
}

/// Current git revision (short), or `"unknown"` outside a work tree /
/// without git on PATH.  Used to stamp bench records and run-config
/// snapshots so result files are attributable after the fact.  Memoized:
/// the subprocess runs at most once per process.
pub fn git_rev() -> String {
    static REV: std::sync::OnceLock<String> = std::sync::OnceLock::new();
    REV.get_or_init(|| {
        std::process::Command::new("git")
            .args(["rev-parse", "--short", "HEAD"])
            .output()
            .ok()
            .filter(|o| o.status.success())
            .and_then(|o| String::from_utf8(o.stdout).ok())
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .unwrap_or_else(|| "unknown".to_string())
    })
    .clone()
}

/// Seconds since the Unix epoch, sub-second precision, **strictly
/// increasing within a process** — back-to-back records (or a clock
/// that only ticks per second / steps backwards) must still carry
/// unambiguous time-ordering, so when the wall clock has not advanced
/// past the previously issued stamp the value is bumped by at least one
/// ulp.  Falls back to bumping from 0.0 when the clock is unavailable.
fn unix_ts() -> f64 {
    static LAST: std::sync::Mutex<f64> = std::sync::Mutex::new(0.0);
    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    let mut last = LAST.lock().unwrap();
    // max(|last|, 1) * EPSILON >= ulp(last), so the sum is a strictly
    // larger float (a few hundred ns at 2026-era epoch seconds).
    let ts = if now > *last {
        now
    } else {
        *last + last.abs().max(1.0) * f64::EPSILON
    };
    *last = ts;
    ts
}

/// Build one bench record under the unified schema: `{bench, git_rev,
/// ts_unix, wall_time_s, rows}` — bench id, the git revision the numbers
/// came from, the record's wall-clock position, total wall time of the
/// run, and the per-row payload (an array or object of measurements).
pub fn record_json(name: &str, wall_time_s: f64, rows: Json) -> Json {
    Json::obj(vec![
        ("bench", Json::str(name)),
        ("git_rev", Json::str(&git_rev())),
        ("ts_unix", Json::num(unix_ts())),
        ("wall_time_s", Json::num(wall_time_s)),
        ("rows", rows),
    ])
}

/// Append one unified-schema record line to `<dir>/<name>.jsonl`.
/// Returns `false` when the filesystem refused (benches treat results
/// emission as best-effort; tests assert on the return).
pub fn write_record_at(dir: &Path, name: &str, wall_time_s: f64, rows: Json) -> bool {
    if std::fs::create_dir_all(dir).is_err() {
        return false;
    }
    let path = dir.join(format!("{name}.jsonl"));
    let line = format!("{}\n", record_json(name, wall_time_s, rows));
    std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .and_then(|mut f| f.write_all(line.as_bytes()))
        .is_ok()
}

/// Append a bench record to `results/<name>.jsonl` under the unified
/// schema.  Records accumulate across runs — nothing is truncated — so
/// `snipsnap report` can diff the latest run against the previous one.
pub fn write_record(name: &str, wall_time_s: f64, rows: Json) {
    let _ = write_record_at(Path::new("results"), name, wall_time_s, rows);
}

/// Standard bench banner.
pub fn banner(id: &str, what: &str) {
    println!("\n================================================================");
    println!("{id} — {what}");
    println!("================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn git_rev_never_panics_and_is_nonempty() {
        let rev = git_rev();
        assert!(!rev.is_empty());
        assert!(!rev.contains('\n'));
    }

    #[test]
    fn timing_is_positive() {
        let (v, t) = time_once(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499500);
        assert!(t >= 0.0);
        let m = time_median(3, || (0..100).product::<u128>());
        assert!(m >= 0.0);
    }

    /// Regression: `write_record` used `fs::write` (truncate), so every
    /// bench run destroyed the accumulated history.  Two consecutive
    /// calls must yield two parseable records.
    #[test]
    fn write_record_appends_history() {
        let dir = std::env::temp_dir()
            .join(format!("snipsnap_bench_append_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(write_record_at(&dir, "t", 0.5, Json::obj(vec![("x", Json::num(1.0))])));
        assert!(write_record_at(&dir, "t", 0.7, Json::num(f64::NAN)));
        let text = std::fs::read_to_string(dir.join("t.jsonl")).unwrap();
        let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
        assert_eq!(lines.len(), 2, "append must accumulate history:\n{text}");
        for l in &lines {
            let rec = Json::parse(l).unwrap_or_else(|e| panic!("{l}: {e}"));
            assert_eq!(rec.get("bench").unwrap().as_str(), Some("t"));
            assert!(rec.get("git_rev").unwrap().as_str().is_some());
            assert!(rec.get("ts_unix").unwrap().as_f64().is_some());
            assert!(rec.get("wall_time_s").unwrap().as_f64().is_some());
        }
        // A NaN payload must still be valid JSON (non-finite -> null).
        assert_eq!(Json::parse(lines[1]).unwrap().get("rows"), Some(&Json::Null));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Regression: `unix_ts` truncated to whole seconds (`as_secs`), so
    /// two records written in the same second carried identical
    /// `ts_unix` and service/bench record ordering was ambiguous.  The
    /// stamp is now sub-second *and* strictly increasing per process.
    #[test]
    fn record_timestamps_strictly_increase() {
        let a = record_json("ts", 0.0, Json::Null);
        let b = record_json("ts", 0.0, Json::Null);
        let ta = a.get("ts_unix").unwrap().as_f64().unwrap();
        let tb = b.get("ts_unix").unwrap().as_f64().unwrap();
        assert!(
            tb > ta,
            "back-to-back records must have strictly increasing ts_unix, got {ta} then {tb}"
        );
        // Sub-second resolution: many stamps within one wall-clock
        // second must all be distinct and ordered.
        let mut prev = tb;
        for _ in 0..100 {
            let t = record_json("ts", 0.0, Json::Null)
                .get("ts_unix")
                .unwrap()
                .as_f64()
                .unwrap();
            assert!(t > prev);
            prev = t;
        }
    }
}
