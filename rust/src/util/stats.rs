//! Summary statistics and error metrics used by the validation benches
//! (mean relative error à la Figs. 8–9) and the perf harness.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean; panics on non-positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|&x| {
        assert!(x > 0.0, "geomean needs positive values, got {x}");
        x.ln()
    }).sum();
    (s / xs.len() as f64).exp()
}

/// Sample standard deviation (n-1 denominator); 0.0 if n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Median (averages the middle pair for even n); 0.0 for empty input.
///
/// NaN samples never panic: ordering is IEEE-754 `total_cmp`, which
/// places NaNs after `+inf`, so a partially NaN-poisoned series keeps a
/// finite median until the NaN tail reaches the middle.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// |est - ref| / |ref| — the paper's per-point relative error.
pub fn relative_error(estimate: f64, reference: f64) -> f64 {
    if reference == 0.0 {
        if estimate == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (estimate - reference).abs() / reference.abs()
    }
}

/// Mean relative error across paired series (Figs. 8–9 headline metric).
pub fn mean_relative_error(estimates: &[f64], references: &[f64]) -> f64 {
    assert_eq!(estimates.len(), references.len());
    mean(
        &estimates
            .iter()
            .zip(references)
            .map(|(&e, &r)| relative_error(e, r))
            .collect::<Vec<_>>(),
    )
}

/// Percentile via linear interpolation; panics unless `p` is in
/// [0, 100] (a NaN `p` fails the range check too).  NaN *samples* are
/// ordered by `total_cmp` (after `+inf`) instead of panicking — see
/// [`median`].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!(
        (0.0..=100.0).contains(&p),
        "percentile p must be in [0, 100], got {p}"
    );
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_stddev() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        let sd = stddev(&xs);
        assert!((sd - 1.2909944487358056).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn relative_error_basic() {
        assert!((relative_error(110.0, 100.0) - 0.1).abs() < 1e-12);
        assert_eq!(relative_error(0.0, 0.0), 0.0);
        assert!(relative_error(1.0, 0.0).is_infinite());
    }

    #[test]
    fn mre_matches_hand_computation() {
        let e = [90.0, 110.0];
        let r = [100.0, 100.0];
        assert!((mean_relative_error(&e, &r) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 10.0).abs() < 1e-12);
    }

    /// Regression: `median`/`percentile` sorted with
    /// `partial_cmp(..).unwrap()`, so a single NaN sample (e.g. a 0/0
    /// rate from an empty bench record) panicked the whole report path.
    #[test]
    fn nan_samples_do_not_panic() {
        let xs = [2.0, f64::NAN, 1.0, 3.0];
        // total_cmp sorts the NaN after +inf: [1, 2, 3, NaN].
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!(percentile(&xs, 100.0).is_nan());
        assert!(median(&[f64::NAN]).is_nan());
    }

    /// Regression: `percentile(xs, 150.0)` indexed out of bounds and
    /// `percentile(xs, -10.0)` silently returned the minimum; both (and
    /// a NaN p) must now fail the range assertion instead.
    #[test]
    fn percentile_rejects_out_of_range_p() {
        let xs = [1.0, 2.0, 3.0];
        for bad in [150.0, -10.0, f64::NAN] {
            let r = std::panic::catch_unwind(|| percentile(&xs, bad));
            assert!(r.is_err(), "p = {bad} must be rejected");
        }
    }
}
