//! FNV-1a hashing (64- and 128-bit) for memo keys and config digests.
//!
//! Offline builds cannot take a hashing crate, and the cross-run memo
//! store (`serve::memo`) needs a *stable* digest — `std`'s `DefaultHasher`
//! is explicitly allowed to change between releases, so keys written by
//! one build must not be hashed differently by the next.  FNV-1a is
//! trivially stable, fast on the short inputs used here (packed map
//! keys, canonical config JSON), and the 128-bit variant makes an
//! accidental collision across a memo store's lifetime negligible.

/// FNV-1a 64-bit offset basis — the initial fold state.
pub const FNV64_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV64_PRIME: u64 = 0x0000_0100_0000_01b3;

const FNV128_OFFSET: u128 = 0x6c62_272e_07bb_0142_62b8_2175_6295_c58d;
const FNV128_PRIME: u128 = 0x0000_0000_0100_0000_0000_0000_0000_013b;

/// Fold `bytes` into an existing 64-bit FNV-1a state.  Folding is how
/// multi-part digests compose: `fnv1a64_fold(fnv1a64(a), b)` equals
/// `fnv1a64` of the concatenation.
pub fn fnv1a64_fold(state: u64, bytes: &[u8]) -> u64 {
    let mut h = state;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV64_PRIME);
    }
    h
}

/// FNV-1a 64-bit digest of `bytes`.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_fold(FNV64_OFFSET, bytes)
}

/// Incremental 128-bit FNV-1a hasher.
#[derive(Clone, Copy, Debug)]
pub struct Fnv128 {
    state: u128,
}

impl Fnv128 {
    pub fn new() -> Self {
        Fnv128 { state: FNV128_OFFSET }
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u128;
            self.state = self.state.wrapping_mul(FNV128_PRIME);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn finish(&self) -> u128 {
        self.state
    }
}

impl Default for Fnv128 {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference vectors from the FNV specification.
    #[test]
    fn fnv64_matches_reference_vectors() {
        assert_eq!(fnv1a64(b""), FNV64_OFFSET);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn folding_equals_concatenation() {
        assert_eq!(fnv1a64_fold(fnv1a64(b"foo"), b"bar"), fnv1a64(b"foobar"));
        let mut whole = Fnv128::new();
        whole.write(b"foobar");
        let mut parts = Fnv128::new();
        parts.write(b"foo");
        parts.write(b"bar");
        assert_eq!(whole.finish(), parts.finish());
        assert_ne!(whole.finish(), Fnv128::new().finish());
    }

    #[test]
    fn write_u64_is_little_endian_bytes() {
        let mut a = Fnv128::new();
        a.write_u64(0x0102_0304_0506_0708);
        let mut b = Fnv128::new();
        b.write(&[0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01]);
        assert_eq!(a.finish(), b.finish());
    }
}
