//! Utility substrates hand-rolled for offline builds (no serde / rand /
//! criterion / rayon / proptest available): PRNG, math helpers,
//! statistics, ASCII tables, a minimal JSON reader/writer, a
//! property-testing harness and the scoped worker [`pool`] driving the
//! parallel co-search.

pub mod bench;
pub mod hash;
pub mod inline;
pub mod json;
pub mod mathx;
pub mod pool;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod table;
