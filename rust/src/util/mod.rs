//! Utility substrates hand-rolled for offline builds (no serde / rand /
//! criterion / proptest available): PRNG, math helpers, statistics, ASCII
//! tables, a minimal JSON reader/writer and a property-testing harness.

pub mod bench;
pub mod json;
pub mod mathx;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod table;
