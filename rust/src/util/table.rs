//! ASCII table renderer for bench/report output (the paper's tables and
//! figure series are printed as aligned text tables by the bench harness).

/// A simple column-aligned table with a header row.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Table {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title<S: Into<String>>(mut self, title: S) -> Self {
        self.title = Some(title.into());
        self
    }

    pub fn add_row<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.headers.len(),
            "row width {} != header width {}",
            row.len(),
            self.headers.len()
        );
        self.rows.push(row);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let pad = widths[i] - cells[i].chars().count();
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

/// Format a float with engineering-friendly precision.
pub fn fmt_f(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1e6 || x.abs() < 1e-3 {
        format!("{x:.3e}")
    } else if x.abs() >= 100.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

/// Format a ratio as "N.NNx".
pub fn fmt_x(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.0}x")
    } else {
        format!("{x:.2}x")
    }
}

/// Format a fraction as a percentage "N.NN%".
pub fn fmt_pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["a", "bb"]).with_title("T");
        t.add_row(vec!["1", "222"]);
        t.add_row(vec!["33", "4"]);
        let s = t.render();
        assert!(s.starts_with("T\n"));
        let lines: Vec<&str> = s.lines().collect();
        // Title + 3 separators + header + 2 rows = 7 lines.
        assert_eq!(lines.len(), 7);
        let widths: Vec<usize> = lines[1..].iter().map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(vec!["a"]);
        t.add_row(vec!["1", "2"]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_pct(0.1824), "18.24%");
        assert_eq!(fmt_x(2248.3), "2248x");
        assert_eq!(fmt_x(1.18), "1.18x");
        assert_eq!(fmt_f(0.0), "0");
    }
}
