//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! Reader: recursive-descent parser covering the full JSON grammar minus
//! exotic number forms; enough for `artifacts/manifest.json` and result
//! files.  Writer: escape-correct serialization used by the bench harness
//! and the run-artifact layer to dump machine-readable results.
//!
//! Non-finite numbers: JSON has no `NaN`/`Infinity` literals, so
//! [`Json::Num`] values that are not finite serialize as `null`.  Every
//! rendered document therefore re-parses with [`Json::parse`], even when
//! a bench metric degenerates to `NaN` or `inf`.  Finite numbers render
//! with Rust's shortest-round-trip float formatting, so
//! parse → render → parse is the identity on them.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { s: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integral non-negative value as `usize`; `None` for negative,
    /// non-finite, fractional or out-of-range numbers (a saturating
    /// cast would silently corrupt such inputs).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().filter(|n| *n <= usize::MAX as u64).map(|n| n as usize)
    }

    /// Integral non-negative value as `u64`, with the same hardening as
    /// [`Json::as_usize`].
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64()
            .filter(|n| n.is_finite() && *n >= 0.0 && n.fract() == 0.0 && *n < u64::MAX as f64)
            .map(|n| n as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Builder helpers for the writer side.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len() && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.s.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.s[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    let len = utf8_len(self.s[start]);
                    let end = (start + len).min(self.s.len());
                    let chunk = std::str::from_utf8(&self.s[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.s[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // No NaN/inf literals in JSON (see module docs).
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"x","vals":[1,2.5,true,null],"nested":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    /// Regression: non-finite numbers used to render verbatim (`NaN`,
    /// `inf`) — invalid JSON that poisoned every downstream reader.
    #[test]
    fn non_finite_numbers_render_as_null() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let doc = Json::obj(vec![("metric", Json::num(bad)), ("ok", Json::num(1.5))]);
            let rendered = doc.to_string();
            let re = Json::parse(&rendered).unwrap_or_else(|e| panic!("{rendered}: {e}"));
            assert_eq!(re.get("metric"), Some(&Json::Null), "{rendered}");
            assert_eq!(re.get("ok").unwrap().as_f64(), Some(1.5));
        }
        assert_eq!(Json::arr([Json::num(f64::NAN)]).to_string(), "[null]");
    }

    #[test]
    fn as_usize_rejects_negative_and_non_finite() {
        assert_eq!(Json::num(4.0).as_usize(), Some(4));
        assert_eq!(Json::num(-1.0).as_usize(), None);
        assert_eq!(Json::num(2.5).as_usize(), None);
        assert_eq!(Json::num(f64::NAN).as_usize(), None);
        assert_eq!(Json::num(f64::INFINITY).as_usize(), None);
        assert_eq!(Json::num(1e19).as_usize(), None, "beyond u64 range");
        assert_eq!(Json::Str("4".into()).as_usize(), None);
        assert_eq!(Json::num(9.0e15).as_u64(), Some(9_000_000_000_000_000));
        assert_eq!(Json::Bool(true).as_bool(), Some(true));
        assert_eq!(Json::num(1.0).as_bool(), None);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"artifacts":[{"name":"m","file":"m.hlo.txt",
            "inputs":[{"shape":[4,4],"dtype":"f32"}],
            "outputs":[{"shape":[],"dtype":"f32"}],
            "params":{"rows":4}}]}"#;
        let v = Json::parse(src).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("name").unwrap().as_str(), Some("m"));
        assert_eq!(
            a.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape").unwrap().as_arr().unwrap()
                .iter().map(|j| j.as_usize().unwrap()).collect::<Vec<_>>(),
            vec![4, 4]
        );
    }
}
