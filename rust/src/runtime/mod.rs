//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the Rust hot path.
//!
//! Interchange is HLO **text** — `HloModuleProto::from_text_file` +
//! `XlaComputation::from_proto` — because jax >= 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects.  One
//! compiled executable per model variant, compiled lazily and cached.
//!
//! The executor depends on the external `xla` bindings crate and is
//! gated behind the **`pjrt`** cargo feature (off by default, since the
//! bindings and a local xla_extension install are not vendored with this
//! repository).  Without the feature, manifest parsing, [`IoSpec`] /
//! [`InputBuf`] and the pure-Rust analyzer in [`stats`] all work
//! normally; [`Runtime::exec`] returns an error explaining how to enable
//! execution.

pub mod stats;

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one artifact I/O slot.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Manifest entry for one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub params: HashMap<String, f64>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

fn parse_iospec(j: &Json) -> Result<IoSpec> {
    let shape = j
        .get("shape")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow!("missing shape"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = j
        .get("dtype")
        .and_then(|d| d.as_str())
        .ok_or_else(|| anyhow!("missing dtype"))?
        .to_string();
    Ok(IoSpec { shape, dtype })
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest.json")?;
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut artifacts = Vec::new();
        for a in arts {
            let name = a
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(|n| n.as_str())
                .ok_or_else(|| anyhow!("artifact missing file"))?
                .to_string();
            let inputs = a
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| anyhow!("artifact missing inputs"))?
                .iter()
                .map(parse_iospec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| anyhow!("artifact missing outputs"))?
                .iter()
                .map(parse_iospec)
                .collect::<Result<Vec<_>>>()?;
            let mut params = HashMap::new();
            if let Some(Json::Obj(m)) = a.get("params") {
                for (k, v) in m {
                    if let Some(x) = v.as_f64() {
                        params.insert(k.clone(), x);
                    }
                }
            }
            artifacts.push(ArtifactMeta { name, file, inputs, outputs, params });
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// The PJRT runtime: parsed manifest plus (with the `pjrt` feature) a
/// CPU client and lazily-compiled executables.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    #[cfg(feature = "pjrt")]
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Default artifacts directory: `$SNIPSNAP_ARTIFACTS` or `artifacts/`
    /// relative to the workspace root.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("SNIPSNAP_ARTIFACTS") {
            return PathBuf::from(d);
        }
        // Tests run from the workspace root; binaries may not.
        let candidates = [
            PathBuf::from("artifacts"),
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        ];
        for c in &candidates {
            if c.join("manifest.json").exists() {
                return c.clone();
            }
        }
        PathBuf::from("artifacts")
    }

    /// Load the manifest and (with the `pjrt` feature) create the PJRT
    /// CPU client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let mtext = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run python/compile/aot.py)", dir.display()))?;
        let manifest = Manifest::parse(&mtext)?;
        #[cfg(feature = "pjrt")]
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            #[cfg(feature = "pjrt")]
            client,
            dir: dir.to_path_buf(),
            manifest,
            #[cfg(feature = "pjrt")]
            cache: HashMap::new(),
        })
    }

    pub fn load_default() -> Result<Runtime> {
        Self::load(&Self::default_dir())
    }

    /// The artifacts directory this runtime was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Compile (or fetch cached) an artifact by name.
    #[cfg(feature = "pjrt")]
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let meta = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact with f32/i32 input buffers (shapes validated
    /// against the manifest).  Returns the flattened f32 outputs.
    #[cfg(feature = "pjrt")]
    pub fn exec(&mut self, name: &str, inputs: &[InputBuf<'_>]) -> Result<Vec<Vec<f32>>> {
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        if inputs.len() != meta.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (buf, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if buf.len() != spec.elements() {
                bail!(
                    "{name} input {i}: expected {} elements, got {}",
                    spec.elements(),
                    buf.len()
                );
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match (buf, spec.dtype.as_str()) {
                (InputBuf::F32(v), "f32") => {
                    let l = xla::Literal::vec1(v);
                    if dims.is_empty() {
                        l.reshape(&[]).map_err(|e| anyhow!("{e:?}"))?
                    } else {
                        l.reshape(&dims).map_err(|e| anyhow!("{e:?}"))?
                    }
                }
                (InputBuf::I32(v), "i32") => {
                    let l = xla::Literal::vec1(v);
                    if dims.is_empty() {
                        l.reshape(&[]).map_err(|e| anyhow!("{e:?}"))?
                    } else {
                        l.reshape(&dims).map_err(|e| anyhow!("{e:?}"))?
                    }
                }
                (_, dt) => bail!("{name} input {i}: dtype mismatch (manifest says {dt})"),
            };
            literals.push(lit);
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the tuple.
        let parts = result.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "{name}: expected {} outputs, got {}",
                meta.outputs.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?);
        }
        Ok(out)
    }

    /// Stub executor for builds without the `pjrt` feature: always an
    /// error.  Keeps the call sites (CLI `xla` subcommand, the e2e
    /// example, the runtime tests) compiling against the same API.
    #[cfg(not(feature = "pjrt"))]
    pub fn exec(&mut self, name: &str, inputs: &[InputBuf<'_>]) -> Result<Vec<Vec<f32>>> {
        let _ = inputs;
        bail!(
            "cannot execute artifact '{name}': snipsnap was built without the `pjrt` \
             feature. Enabling it requires first adding the `xla` bindings crate to \
             Cargo.toml (it is not vendored) plus a local xla_extension install, \
             then rebuilding with `--features pjrt`"
        )
    }
}

/// Typed input view for [`Runtime::exec`].
pub enum InputBuf<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl InputBuf<'_> {
    pub fn len(&self) -> usize {
        match self {
            InputBuf::F32(v) => v.len(),
            InputBuf::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let src = r#"{"artifacts":[{"name":"m","file":"m.hlo.txt",
            "inputs":[{"shape":[4,4],"dtype":"f32"}],
            "outputs":[{"shape":[],"dtype":"f32"}],
            "params":{"rows":4}}]}"#;
        let m = Manifest::parse(src).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("m").unwrap();
        assert_eq!(a.inputs[0].shape, vec![4, 4]);
        assert_eq!(a.inputs[0].elements(), 16);
        assert_eq!(a.outputs[0].elements(), 1); // scalar
        assert_eq!(a.params["rows"], 4.0);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts":[{"name":"x"}]}"#).is_err());
    }
}
