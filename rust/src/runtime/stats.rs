//! Empirical Sparsity Analyzer: XLA-accelerated occupancy statistics.
//!
//! One `sparsity_stats` call per tensor produces the base block lattice
//! (per-16x16-tile nnz, via the L1 Pallas kernel), per-row and per-column
//! counts.  [`empirical_ne`] aggregates those into non-empty node counts
//! for any format whose boundaries align with the lattice (whole-block
//! regions), full rows/columns or single elements — exact in all those
//! cases — and falls back to the analytical iid estimate (at the
//! *measured* density) for sub-block boundaries.

use super::{InputBuf, Runtime};
use crate::format::Format;
use crate::sparsity::analyzer::{cost_from_ne, FormatCost};
use crate::sparsity::exact::DenseMask;
use crate::util::mathx::p_nonempty_iid;
use anyhow::{anyhow, Result};

/// Occupancy statistics of one concrete tensor.
#[derive(Clone, Debug)]
pub struct TensorStats {
    pub rows: u64,
    pub cols: u64,
    /// Lattice tile shape (e.g. 16x16).
    pub block_r: u64,
    pub block_c: u64,
    /// Per-tile nnz, row-major (rows/block_r x cols/block_c).
    pub block_counts: Vec<f32>,
    pub row_counts: Vec<f32>,
    pub col_counts: Vec<f32>,
    pub total_nnz: f64,
}

impl TensorStats {
    pub fn density(&self) -> f64 {
        self.total_nnz / (self.rows * self.cols) as f64
    }

    fn lattice_dims(&self) -> (u64, u64) {
        (self.rows / self.block_r, self.cols / self.block_c)
    }

    /// Count of non-empty `gr x gc` regions (gr, gc multiples of the
    /// block shape): coarsen the lattice.
    fn nonempty_regions(&self, gr: u64, gc: u64) -> f64 {
        let (lr, lc) = self.lattice_dims();
        let sr = gr / self.block_r; // lattice tiles per region row
        let sc = gc / self.block_c;
        debug_assert!(sr >= 1 && sc >= 1);
        let mut count = 0u64;
        for r0 in (0..lr).step_by(sr as usize) {
            'cell: for c0 in (0..lc).step_by(sc as usize) {
                for r in r0..r0 + sr {
                    for c in c0..c0 + sc {
                        if self.block_counts[(r * lc + c) as usize] > 0.0 {
                            count += 1;
                            continue 'cell;
                        }
                    }
                }
            }
        }
        count as f64
    }
}

/// Artifact name for a tensor shape, if one is shipped.
pub fn stats_artifact_for(rows: u64, cols: u64) -> Option<(&'static str, u64)> {
    match (rows, cols) {
        (512, 512) => Some(("sparsity_stats_512x512_b16", 16)),
        (1024, 1024) => Some(("sparsity_stats_1024x1024_b16", 16)),
        (2048, 2048) => Some(("sparsity_stats_2048x2048_b32", 32)),
        _ => None,
    }
}

/// Run the XLA sparsity analyzer on a concrete mask.
pub fn analyze_mask(rt: &mut Runtime, mask: &DenseMask) -> Result<TensorStats> {
    let (name, block) = stats_artifact_for(mask.rows, mask.cols)
        .ok_or_else(|| anyhow!("no sparsity_stats artifact for {}x{}", mask.rows, mask.cols))?;
    let data = mask.to_f32();
    let outs = rt.exec(name, &[InputBuf::F32(&data)])?;
    let [block_counts, row_counts, col_counts, total]: [Vec<f32>; 4] = outs
        .try_into()
        .map_err(|_| anyhow!("unexpected output arity"))?;
    Ok(TensorStats {
        rows: mask.rows,
        cols: mask.cols,
        block_r: block,
        block_c: block,
        block_counts,
        row_counts,
        col_counts,
        total_nnz: total[0] as f64,
    })
}

/// Empirical non-empty counts per boundary of `format`.
///
/// Exactness by boundary region shape (gr x gc):
/// - whole-lattice-block regions (block_r | gr, block_c | gc): exact;
/// - full-row fibers (gr = 1, gc = cols): exact via row counts;
/// - full-col fibers (gr = rows, gc = 1): exact via col counts;
/// - single elements (1 x 1): exact (= total nnz);
/// - otherwise: iid estimate at the measured density.
pub fn empirical_ne(format: &Format, stats: &TensorStats) -> Vec<f64> {
    assert_eq!((format.rows, format.cols), (stats.rows, stats.cols));
    let density = stats.density();
    format
        .boundaries()
        .iter()
        .map(|b| {
            let (gr, gc) = (b.region_rows, b.region_cols);
            if gr == 0 || gc == 0 {
                return 0.0;
            }
            if gr == 1 && gc == 1 {
                return stats.total_nnz;
            }
            if gr == 1 && gc == stats.cols {
                return stats.row_counts.iter().filter(|&&c| c > 0.0).count() as f64;
            }
            if gr == stats.rows && gc == 1 {
                return stats.col_counts.iter().filter(|&&c| c > 0.0).count() as f64;
            }
            if gr % stats.block_r == 0 && gc % stats.block_c == 0 {
                return stats.nonempty_regions(gr, gc);
            }
            // Fallback: iid at measured density.
            b.nodes * p_nonempty_iid(density, (gr * gc) as f64)
        })
        .collect()
}

/// Empirical format cost from XLA statistics.
pub fn empirical_cost(format: &Format, stats: &TensorStats, data_bits: u32) -> FormatCost {
    cost_from_ne(format, &empirical_ne(format, stats), data_bits)
}

/// Pure-Rust fallback analyzer (no XLA): identical statistics computed
/// from the mask directly.  Used for cross-validation and when artifacts
/// are unavailable.
pub fn analyze_mask_native(mask: &DenseMask, block: u64) -> TensorStats {
    let (lr, lc) = (mask.rows / block, mask.cols / block);
    let mut block_counts = vec![0f32; (lr * lc) as usize];
    let mut row_counts = vec![0f32; mask.rows as usize];
    let mut col_counts = vec![0f32; mask.cols as usize];
    let mut total = 0f64;
    for r in 0..mask.rows {
        for c in 0..mask.cols {
            if mask.get(r, c) {
                block_counts[((r / block) * lc + c / block) as usize] += 1.0;
                row_counts[r as usize] += 1.0;
                col_counts[c as usize] += 1.0;
                total += 1.0;
            }
        }
    }
    TensorStats {
        rows: mask.rows,
        cols: mask.cols,
        block_r: block,
        block_c: block,
        block_counts,
        row_counts,
        col_counts,
        total_nnz: total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::named;
    use crate::sparsity::exact::exact_ne;
    use crate::sparsity::sample::sample_mask;
    use crate::sparsity::SparsityPattern;

    #[test]
    fn native_stats_consistency() {
        let mask = sample_mask(
            &SparsityPattern::Unstructured { density: 0.3 },
            64,
            64,
            5,
        );
        let st = analyze_mask_native(&mask, 16);
        assert_eq!(st.total_nnz, mask.nnz() as f64);
        assert_eq!(
            st.block_counts.iter().map(|&c| c as f64).sum::<f64>(),
            st.total_nnz
        );
        assert!((st.density() - mask.density()).abs() < 1e-12);
    }

    #[test]
    fn empirical_ne_exact_for_aligned_formats() {
        let mask = sample_mask(
            &SparsityPattern::Block { br: 16, bc: 16, block_density: 0.3 },
            64,
            64,
            9,
        );
        let st = analyze_mask_native(&mask, 16);
        // CSB with 16x16 blocks: every boundary is lattice-aligned, a full
        // fiber, or an element — all exact.
        let f = named::csb(64, 64, 16, 16);
        let emp = empirical_ne(&f, &st);
        let exact = exact_ne(&f, &mask);
        for (i, (e, x)) in emp.iter().zip(&exact).enumerate() {
            // Boundaries 0..=2 and the element boundary are exact;
            // the within-block row boundary (region 1 x 16) is estimated.
            if i != 3 {
                assert_eq!(e, x, "boundary {i}: {emp:?} vs {exact:?}");
            }
        }
    }

    #[test]
    fn empirical_ne_exact_for_csr_fibers() {
        let mask = sample_mask(
            &SparsityPattern::Unstructured { density: 0.05 },
            64,
            64,
            11,
        );
        let st = analyze_mask_native(&mask, 16);
        let f = named::csr(64, 64);
        let emp = empirical_ne(&f, &st);
        let exact = exact_ne(&f, &mask);
        assert_eq!(emp, exact);
    }

    #[test]
    fn empirical_cost_close_to_exact_generally() {
        let mask = sample_mask(
            &SparsityPattern::Unstructured { density: 0.2 },
            64,
            64,
            13,
        );
        let st = analyze_mask_native(&mask, 16);
        for f in [named::bitmap(64, 64), named::coo(64, 64), named::csb(64, 64, 16, 16)] {
            let emp = empirical_cost(&f, &st, 16).total_bits();
            let exact = crate::sparsity::exact::exact_cost(&f, &mask, 16).total_bits();
            let rel = (emp - exact).abs() / exact;
            assert!(rel < 0.05, "{f}: emp {emp} vs exact {exact}");
        }
    }
}
