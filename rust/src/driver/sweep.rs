//! `snipsnap sweep` — the multi-process sweep coordinator.
//!
//! A sweep plan ([`crate::config::sweep`]) expands to an ordered list of
//! [`RunPlan`](super::RunPlan)s.  This module shards those plans across
//! N worker processes and fan-ins the responses **in plan order**, so
//! the merged artifact is byte-identical at any `--workers` count:
//!
//! - **Workers are `snipsnap serve --once` children** of the current
//!   executable, speaking the existing serve wire format: one rendered
//!   plan line on stdin (a run-config snapshot tagged with the sweep
//!   entry's `id`), one response line on stdout.  No new protocol.
//! - **Determinism.** Each plan is a fully-resolved snapshot — it pins
//!   threads, prune, best-first, the cost backend, the quant spaces —
//!   and the `(value, proto-id)` reduction makes every individual run
//!   bit-identical regardless of scheduling.  Response lines carry only
//!   deterministic fields (the nondeterministic observables go to the
//!   worker's stderr and its own results records).  The fan-in writes
//!   responses in plan order, not completion order.  Composing the
//!   three: the merged file is a pure function of the plan file.
//! - **Workers run memo-off and results-off** (`--memo off --results
//!   off`): the coordinator owns the sweep's artifacts, and a shared
//!   memo file would be a cross-process write race.
//!
//! The merged roll-up lands at `<out>/<name>.sweep.jsonl`, which
//! `snipsnap report` renders as per-config rows plus a sweep summary
//! line (see `crate::report`).

use crate::config::sweep::{load_sweep_plan, SweepPlan};
use crate::serve::{SearchResponse, SearchStats};
use crate::util::json::Json;
use crate::util::{bench, pool};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::PathBuf;
use std::process::{Command, Stdio};

/// Coordinator configuration (resolved from the CLI flags in `main`).
pub struct SweepOpts {
    /// The TOML sweep plan (`[sweep]` + `[[sweep.axis]]`, docs/SWEEP.md).
    pub plan_path: PathBuf,
    /// Worker process count; clamped to `[1, configs]`.  Any value
    /// yields byte-identical merged output.
    pub workers: usize,
    /// Where the merged roll-up and the sweep bench record land.
    pub out_dir: PathBuf,
}

/// What the coordinator did, for the exit banner and the caller.
pub struct SweepSummary {
    /// The sweep name (`[sweep] name`), also the roll-up file stem.
    pub name: String,
    pub configs: u64,
    /// Configs whose worker failed or whose response was `ok:false`.
    pub failed: u64,
    /// The merged `<name>.sweep.jsonl` roll-up.
    pub merged_path: PathBuf,
}

/// Run one config through a `snipsnap serve --once` worker child and
/// return its response line.  The request is the rendered plan
/// (newline-terminated already); memo and results are off — the
/// coordinator owns the sweep's artifacts.
fn run_worker(request: &str) -> Result<String> {
    let exe = std::env::current_exe().context("locating the snipsnap executable")?;
    let mut child = Command::new(exe)
        .args(["serve", "--once", "--memo", "off", "--results", "off"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .context("spawning worker")?;
    {
        let mut stdin = child.stdin.take().context("worker stdin")?;
        stdin.write_all(request.as_bytes()).context("writing request to worker")?;
        // Dropping stdin closes it; `serve --once` reads the one line
        // and exits.
    }
    let out = child.wait_with_output().context("waiting for worker")?;
    if !out.status.success() {
        bail!(
            "worker exited with {}: {}",
            out.status,
            String::from_utf8_lossy(&out.stderr).trim(),
        );
    }
    let line = String::from_utf8(out.stdout).context("worker response was not UTF-8")?;
    let line = line.trim_end_matches('\n').to_string();
    if line.is_empty() {
        bail!("worker produced no response");
    }
    Ok(line)
}

/// Whether a response line reports `"ok":true`.
fn response_ok(line: &str) -> bool {
    Json::parse(line)
        .map(|v| v.get("ok").and_then(Json::as_bool) == Some(true))
        .unwrap_or(false)
}

/// Run a sweep: load and expand the plan, shard the configs across
/// worker processes, and merge the responses **in plan order** into
/// `<out>/<name>.sweep.jsonl`.  Worker crashes become synthesized
/// `ok:false` response lines (counted in `failed`), so one bad config
/// never loses the rest of the sweep.
pub fn run_sweep(opts: &SweepOpts, log: &mut dyn Write) -> Result<SweepSummary> {
    let start = std::time::Instant::now();
    let src = std::fs::read_to_string(&opts.plan_path)
        .with_context(|| opts.plan_path.display().to_string())?;
    let SweepPlan { name, entries } = load_sweep_plan(&src)?;
    let requests: Vec<(String, String)> = entries
        .into_iter()
        .map(|e| {
            let plan = super::RunPlan { id: Some(e.id.clone()), run: e.run };
            (e.id, plan.render())
        })
        .collect();
    let workers = opts.workers.min(requests.len()).max(1);
    writeln!(
        log,
        "snipsnap sweep '{}': {} configs across {} worker{}",
        name,
        requests.len(),
        workers,
        if workers == 1 { "" } else { "s" },
    )?;

    // Shard: each config runs in its own `serve --once` child; the pool
    // caps concurrency at `workers` and returns results in item order.
    let results = pool::parallel_map(workers, &requests, |_, (_, request)| {
        run_worker(request).map_err(|e| format!("{e:#}"))
    });

    // Fan-in, strictly in plan order.  Completion order never touches
    // the merged artifact.
    let mut merged = String::new();
    let mut failed = 0u64;
    for ((id, _), result) in requests.iter().zip(results) {
        let line = match result {
            Ok(line) => line,
            Err(msg) => SearchResponse {
                id: Some(id.clone()),
                result: Err(format!("worker: {msg}")),
                stats: SearchStats::default(),
            }
            .render()
            .trim_end_matches('\n')
            .to_string(),
        };
        let ok = response_ok(&line);
        failed += u64::from(!ok);
        writeln!(log, "sweep: config {id} {}", if ok { "ok" } else { "FAILED" })?;
        merged.push_str(&line);
        merged.push('\n');
    }

    std::fs::create_dir_all(&opts.out_dir)
        .with_context(|| opts.out_dir.display().to_string())?;
    let merged_path = opts.out_dir.join(format!("{name}.sweep.jsonl"));
    std::fs::write(&merged_path, &merged)
        .with_context(|| merged_path.display().to_string())?;

    // One bench record for the sweep itself.  Wall time (the one
    // nondeterministic observable) lives here, never in the roll-up.
    let configs = requests.len() as u64;
    bench::write_record_at(
        &opts.out_dir,
        "sweep",
        start.elapsed().as_secs_f64(),
        Json::obj(vec![
            ("sweep", Json::str(&name)),
            ("configs", Json::num(configs as f64)),
            ("failed", Json::num(failed as f64)),
            ("workers", Json::num(workers as f64)),
        ]),
    );
    writeln!(
        log,
        "snipsnap sweep: {} configs merged to {} ({} failed)",
        configs,
        merged_path.display(),
        failed,
    )?;
    Ok(SweepSummary { name, configs, failed, merged_path })
}
