//! The reusable run driver — the pipeline behind every co-search run.
//!
//! Historically the whole run pipeline (resolve config → dispatch the
//! co-search → emit the snapshot artifact → print the human report)
//! lived inside the `snipsnap search` subcommand, unreachable from the
//! library, from `serve`, or from any coordinator.  This module is that
//! pipeline as a library layer:
//!
//! - [`RunPlan`] — one fully-resolved run.  Its canonical serialized
//!   form **is** the run-config snapshot ([`crate::config::snapshot`]),
//!   optionally tagged with an `id` the snapshot loader ignores — which
//!   makes every plan simultaneously a replayable `--config` artifact
//!   and a valid `snipsnap serve` request line.
//! - [`execute`] — the bare co-search dispatch (scalar and frontier)
//!   with [`SearchHooks`] for memo/budget wiring.  `snipsnap serve`
//!   routes every request through this entry point.
//! - [`run`] — the full pipeline: snapshot emission, stderr banners,
//!   the human report on stdout, frontier tables.  `snipsnap search` is
//!   flag parsing plus one call to this; its output is byte-identical
//!   to the pre-extraction subcommand (pinned by
//!   `rust/tests/driver_differential.rs`).
//!
//! The [`sweep`] submodule builds multi-process orchestration on top:
//! a coordinator shards an ordered list of `RunPlan`s across
//! `snipsnap serve --once` worker processes and fan-ins the responses
//! in plan order (docs/SWEEP.md).

pub mod sweep;

use crate::config::snapshot;
use crate::config::RunConfig;
use crate::search::{try_cosearch_workload, SearchHooks, WorkloadResult};
use crate::util::json::Json;
use crate::util::table::{fmt_f, Table};
use anyhow::{Context, Result};
use std::io::Write;
use std::path::PathBuf;

/// One fully-resolved run: the complete [`RunConfig`] plus an optional
/// caller-chosen id (sweep entries and serve requests carry one; plain
/// CLI runs do not).
pub struct RunPlan {
    /// Correlation id echoed into response lines and report rows.
    pub id: Option<String>,
    pub run: RunConfig,
}

impl RunPlan {
    /// A plan with no id — what `snipsnap search` builds from its flags.
    pub fn new(run: RunConfig) -> RunPlan {
        RunPlan { id: None, run }
    }

    /// Parse a plan from its canonical serialized form: a run-config
    /// snapshot line, optionally carrying an `id` string.  Exactly the
    /// shape [`render`](RunPlan::render) emits and `snipsnap serve`
    /// accepts as a request.
    pub fn parse(line: &str) -> Result<RunPlan> {
        let v = Json::parse(line).map_err(|e| anyhow::anyhow!("run plan: {e}"))?;
        let run = snapshot::run_config_from_value(&v)?;
        let id = match v.get("id") {
            None | Some(Json::Null) => None,
            Some(other) => {
                Some(other.as_str().context("plan 'id' must be a string")?.to_string())
            }
        };
        Ok(RunPlan { id, run })
    }

    /// The canonical wire/artifact form: the run-config snapshot JSON
    /// (one line, trailing newline) with the plan id injected as an
    /// `"id"` key.  The snapshot loader ignores unknown keys, so the
    /// rendered line replays through `snipsnap search --config` and
    /// serves as a `snipsnap serve` request verbatim; [`Json::Obj`] is a
    /// `BTreeMap`, so key order (and therefore the byte sequence) stays
    /// deterministic with the id present.
    pub fn render(&self) -> String {
        let mut doc =
            snapshot::snapshot_json(&self.run.arch, &self.run.workload, &self.run.search);
        if let (Some(id), Json::Obj(m)) = (&self.id, &mut doc) {
            m.insert("id".to_string(), Json::str(id));
        }
        format!("{doc}\n")
    }
}

/// Dispatch the co-search for a resolved run config — scalar or frontier
/// according to `run.search.metric` — through the [`SearchHooks`] seam.
/// This is the single funnel every execution path shares: `snipsnap
/// search` (via [`run`]), `snipsnap serve` requests, and sweep workers.
pub fn execute(run: &RunConfig, hooks: SearchHooks<'_>) -> Result<WorkloadResult> {
    try_cosearch_workload(&run.arch, &run.workload, &run.search, hooks)
}

/// Where the run-config snapshot artifact goes.
pub enum SnapshotSink {
    /// `results/run-<ts>-<pid>.config.json` (the CLI default).
    Default,
    /// No snapshot (`--snapshot off`).
    Off,
    /// An explicit destination (`--snapshot PATH`).
    Path(PathBuf),
}

/// Output wiring for [`run`]: the snapshot destination plus the two
/// report streams.  The CLI passes stdout/stderr; tests and embedders
/// pass buffers.
pub struct RunSinks<'a> {
    pub snapshot: SnapshotSink,
    /// The human report (design table, totals, frontier tables).
    pub out: &'a mut dyn Write,
    /// Banners, the snapshot notice, warnings.
    pub log: &'a mut dyn Write,
}

/// Emit the JSON run-config snapshot for a resolved run (written before
/// the search so a crashed run still leaves its artifact).
/// Best-effort: an unwritable destination warns on `log` instead of
/// failing the run.
fn emit_snapshot(plan: &RunPlan, sink: &SnapshotSink, log: &mut dyn Write) -> Result<()> {
    let path = match sink {
        SnapshotSink::Off => return Ok(()),
        SnapshotSink::Path(p) => p.clone(),
        SnapshotSink::Default => {
            let ts = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0);
            PathBuf::from("results")
                .join(format!("run-{ts}-{}.config.json", std::process::id()))
        }
    };
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        let _ = std::fs::create_dir_all(dir);
    }
    let run = &plan.run;
    match std::fs::write(&path, snapshot::render(&run.arch, &run.workload, &run.search)) {
        Ok(()) => writeln!(log, "run-config snapshot: {}", path.display())?,
        Err(e) => writeln!(log, "warning: could not write snapshot {}: {e}", path.display())?,
    }
    Ok(())
}

/// The full run pipeline: snapshot emission, stderr banners, co-search
/// dispatch through [`execute`], and the human report — byte-identical
/// to what the pre-extraction `snipsnap search` printed (pinned by
/// `rust/tests/driver_differential.rs`).  Returns the search result so
/// embedders can post-process beyond the rendered report.
pub fn run(
    plan: &RunPlan,
    hooks: SearchHooks<'_>,
    sinks: &mut RunSinks<'_>,
) -> Result<WorkloadResult> {
    let RunConfig { arch, workload, search: cfg } = &plan.run;
    emit_snapshot(plan, &sinks.snapshot, sinks.log)?;

    writeln!(sinks.log, "arch: {}", arch.name)?;
    writeln!(sinks.log, "workload: {} ({} ops)", workload.name, workload.op_count())?;
    writeln!(sinks.log, "cost backend: {}", cfg.cost)?;
    if !cfg.quant.is_default() {
        let qs = cfg.quant.resolve(arch.data_bits);
        writeln!(
            sinks.log,
            "quant axis: W{{{}}} A{{{}}} KV{{{}}} (payload bits; dense ref {})",
            qs.weight, qs.act, qs.kv, arch.data_bits
        )?;
    }
    let r = execute(&plan.run, hooks)?;

    let mut t = Table::new(vec![
        "op", "I format", "W format", "bits (A/W)", "energy (pJ)", "cycles",
    ])
    .with_title(format!(
        "SnipSnap co-search: {} on {} [{:?}, {:?}]",
        workload.name, arch.name, cfg.metric, cfg.mode
    ));
    for d in &r.designs {
        t.add_row(vec![
            d.op_name.clone(),
            d.input_format.to_string(),
            d.weight_format.to_string(),
            format!("{}/{}", d.input_bits, d.weight_bits),
            fmt_f(d.report.total_energy_pj()),
            fmt_f(d.report.latency_cycles()),
        ]);
    }
    writeln!(sinks.out, "{}", t.render())?;
    writeln!(
        sinks.out,
        "totals: energy {} pJ | memory energy {} pJ | cycles {} | EDP {}",
        fmt_f(r.total_energy_pj()),
        fmt_f(r.memory_energy_pj()),
        fmt_f(r.total_cycles()),
        fmt_f(r.edp()),
    )?;
    writeln!(
        sinks.out,
        "search: {} cost-model evaluations in {:.2}s ({} threads)",
        r.evaluations,
        r.elapsed.as_secs_f64(),
        crate::util::pool::resolve_threads(cfg.threads),
    )?;
    writeln!(
        sinks.out,
        "cache: access-counts {} hits / {} misses ({:.1}% hit rate)",
        r.cache.hits,
        r.cache.misses,
        100.0 * r.cache.hit_rate(),
    )?;
    writeln!(
        sinks.out,
        "enumeration: {} legal protos, {} pruned by lower bound ({:.1}%)",
        r.protos,
        r.pruned,
        100.0 * r.prune_rate(),
    )?;
    if let Some(f) = &r.frontier {
        let metric_names = ["energy", "memory-energy", "latency", "edp"];
        let mut ft = Table::new(vec!["metric", "energy (pJ)", "cycles", "metric total"])
            .with_title("Pareto frontier: per-metric winners (single arena pass)");
        for (mi, name) in metric_names.iter().enumerate() {
            let ds = &f.winners[mi];
            let energy: f64 =
                ds.iter().map(|d| d.report.total_energy_pj() * d.count as f64).sum();
            let cycles: f64 =
                ds.iter().map(|d| d.report.latency_cycles() * d.count as f64).sum();
            ft.add_row(vec![
                name.to_string(),
                fmt_f(energy),
                fmt_f(cycles),
                fmt_f(f.winner_total(mi)),
            ]);
        }
        writeln!(sinks.out, "{}", ft.render())?;
        writeln!(
            sinks.out,
            "frontier: {} Pareto points across {} ops | pruned per metric {:?} | \
             {} shared-bound prunes",
            f.total_points(),
            f.op_points.len(),
            r.pruned_by_metric,
            r.bound_tightenings,
        )?;
    }
    Ok(r)
}
