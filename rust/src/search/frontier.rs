//! In-pass Pareto frontier and cross-shard incumbent sharing for the
//! single-pass multi-metric co-search (`--metric frontier`).
//!
//! One arena pass evaluates each surviving proto once per distinct
//! trial mapping and feeds every result into two structures:
//!
//! * a [`Frontier`] — a small Pareto set over the four scalar metrics
//!   ([`Metric::SCALARS`] order) with deterministic `(values, id)`
//!   tie-breaking, so the set's contents are a pure function of the
//!   points inserted and the (deterministic) insertion sequence;
//! * a [`SharedBounds`] cell bank — one relaxed `AtomicU64` per scalar
//!   metric holding the f64 bit pattern of the best value *achieved* so
//!   far by any shard (monotone min).  Shards read it to tighten their
//!   branch-and-bound prune threshold, never to select a winner, so
//!   results stay bit-identical to serial whatever the interleaving
//!   (`docs/SEARCH.md` § Frontier search).
//!
//! The dominance rule: point `a` dominates `b` iff `a.values[i] <=
//! b.values[i]` on every metric and `<` on at least one; two points
//! with equal vectors keep the smaller `id` (the deterministic
//! composite key built by [`point_id`]).

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of scalar metrics a frontier point carries
/// (`Metric::SCALARS.len()`).
pub const NUM_METRICS: usize = 4;

/// Maximum points a [`Frontier`] retains.  Beyond the cap the point
/// with the largest `(primary value, id)` key is evicted — a
/// deterministic rule, so capped contents stay reproducible.
pub const FRONTIER_CAP: usize = 64;

/// One evaluated design projected onto the four scalar metrics.
///
/// `values` is in [`crate::cost::Metric::SCALARS`] order; `id` is the
/// deterministic composite ordering key from [`point_id`] used for
/// tie-breaking and eviction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FrontierPoint {
    pub values: [f64; NUM_METRICS],
    pub id: u64,
}

impl FrontierPoint {
    /// Pareto dominance with deterministic duplicate resolution: `self`
    /// dominates `other` when it is no worse on every metric and
    /// strictly better on at least one, or when the vectors are equal
    /// and `self` has the smaller id.
    pub fn dominates(&self, other: &FrontierPoint) -> bool {
        let mut strictly = false;
        for i in 0..NUM_METRICS {
            if self.values[i] > other.values[i] {
                return false;
            }
            if self.values[i] < other.values[i] {
                strictly = true;
            }
        }
        strictly || self.id < other.id
    }

    /// Canonical total order: lexicographic over the value vector, then
    /// the id.  Metric values are finite (the search would have
    /// panicked on NaN long before a point is built).
    fn key_cmp(&self, other: &FrontierPoint) -> std::cmp::Ordering {
        for i in 0..NUM_METRICS {
            match self.values[i].partial_cmp(&other.values[i]) {
                Some(std::cmp::Ordering::Equal) | None => {}
                Some(ord) => return ord,
            }
        }
        self.id.cmp(&other.id)
    }
}

/// Deterministic composite id for a frontier point: which format pair,
/// which arena proto, and which slot produced it.  Slots 0–3 are the
/// in-pass per-metric descents; slots 8–11 are the post-reduction
/// refined winners (`8 + metric index`).  The packing keeps ids
/// strictly ordered by `(pair, proto, slot)`, giving the `(values,
/// id)` tie-break a stable meaning across runs.
pub fn point_id(pair: u64, proto: u64, slot: usize) -> u64 {
    debug_assert!(slot < 16);
    debug_assert!(proto < 1 << 40);
    (pair << 44) | (proto << 4) | slot as u64
}

/// A small Pareto set over [`FrontierPoint`]s, kept in canonical
/// `(values, id)` order.
///
/// Inserts filter dominated points in both directions; when the set
/// exceeds [`FRONTIER_CAP`] the worst `(primary value, id)` point is
/// evicted.  Without the cap the retained set is exactly the maximal
/// elements of everything inserted — insertion-order independent; with
/// the cap, contents depend on the insertion sequence, which the
/// search keeps deterministic (shards merge in shard order).
#[derive(Clone, Debug, Default)]
pub struct Frontier {
    points: Vec<FrontierPoint>,
}

impl Frontier {
    /// Insert `p`, returning whether it survived (was not dominated).
    pub fn insert(&mut self, p: FrontierPoint) -> bool {
        if self.points.iter().any(|q| q.dominates(&p)) {
            return false;
        }
        self.points.retain(|q| !p.dominates(q));
        let pos = self
            .points
            .partition_point(|q| q.key_cmp(&p) == std::cmp::Ordering::Less);
        self.points.insert(pos, p);
        if self.points.len() > FRONTIER_CAP {
            // Evict the worst (primary value, id) — deterministic.
            let worst = self
                .points
                .iter()
                .enumerate()
                .max_by(|(_, a), (_, b)| {
                    a.values[0]
                        .partial_cmp(&b.values[0])
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.id.cmp(&b.id))
                })
                .map(|(i, _)| i)
                .expect("frontier over cap cannot be empty");
            self.points.remove(worst);
        }
        true
    }

    /// Merge `other` into `self` (point-by-point insert, in `other`'s
    /// canonical order — deterministic for deterministic inputs).
    pub fn merge(&mut self, other: &Frontier) {
        for p in &other.points {
            self.insert(*p);
        }
    }

    /// The retained points in canonical `(values, id)` order.
    pub fn points(&self) -> &[FrontierPoint] {
        &self.points
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// Cross-shard incumbent cells: one relaxed `AtomicU64` per scalar
/// metric holding the f64 **bit pattern** of the best value achieved so
/// far across every shard of the current pair search.
///
/// For non-negative finite f64s the IEEE-754 bit pattern is monotone in
/// the value, so `fetch_min` on the bits is `fetch_min` on the floats —
/// no CAS loop needed.  Metric values are strictly positive (energies,
/// cycles, their product), and the cells start at `+inf`.
///
/// Determinism argument (`docs/SEARCH.md` § Frontier search): the cell
/// only ever decreases toward the true global minimum, every published
/// value is *achieved* by some proto, and readers prune a proto only
/// when its lower bound is **strictly** above the cell — such a proto's
/// achievable value is strictly above an achieved value and can never
/// win the `(value, proto-id)` reduction, ties included.  A stale read
/// merely prunes less.  The shared cell is never consulted when
/// *selecting* a winner, so the reduced result is bit-identical to the
/// serial search at any thread count and under any interleaving.
#[derive(Debug)]
pub struct SharedBounds {
    cells: [AtomicU64; NUM_METRICS],
}

impl Default for SharedBounds {
    fn default() -> Self {
        Self::new()
    }
}

impl SharedBounds {
    pub fn new() -> Self {
        let inf = f64::INFINITY.to_bits();
        SharedBounds {
            cells: [
                AtomicU64::new(inf),
                AtomicU64::new(inf),
                AtomicU64::new(inf),
                AtomicU64::new(inf),
            ],
        }
    }

    /// Publish an achieved value for scalar metric `m` (monotone min).
    pub fn publish(&self, m: usize, v: f64) {
        debug_assert!(v >= 0.0, "metric values are non-negative");
        self.cells[m].fetch_min(v.to_bits(), Ordering::Relaxed);
    }

    /// Best value achieved so far for scalar metric `m` across all
    /// shards (`+inf` until something is published).
    pub fn get(&self, m: usize) -> f64 {
        f64::from_bits(self.cells[m].load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(values: [f64; 4], id: u64) -> FrontierPoint {
        FrontierPoint { values, id }
    }

    #[test]
    fn dominance_requires_strict_improvement_or_smaller_id() {
        let a = pt([1.0, 2.0, 3.0, 4.0], 0);
        let b = pt([1.0, 2.0, 3.0, 5.0], 1);
        assert!(a.dominates(&b));
        assert!(!b.dominates(&a));
        // Equal vectors: smaller id wins.
        let c = pt([1.0, 2.0, 3.0, 4.0], 7);
        assert!(a.dominates(&c));
        assert!(!c.dominates(&a));
        // Incomparable: neither dominates.
        let d = pt([0.5, 9.0, 3.0, 4.0], 2);
        assert!(!a.dominates(&d));
        assert!(!d.dominates(&a));
    }

    #[test]
    fn frontier_keeps_only_maximal_points_in_canonical_order() {
        let mut f = Frontier::default();
        assert!(f.insert(pt([2.0, 2.0, 2.0, 2.0], 3)));
        assert!(f.insert(pt([1.0, 3.0, 2.0, 2.0], 1)));
        // Dominated by the first point.
        assert!(!f.insert(pt([2.0, 2.0, 2.0, 3.0], 9)));
        // Dominates the first point — replaces it.
        assert!(f.insert(pt([2.0, 2.0, 1.0, 2.0], 5)));
        let ids: Vec<u64> = f.points().iter().map(|p| p.id).collect();
        assert_eq!(ids, vec![1, 5]);
        // Canonical order: sorted by (values, id).
        for w in f.points().windows(2) {
            assert_eq!(w[0].key_cmp(&w[1]), std::cmp::Ordering::Less);
        }
    }

    #[test]
    fn frontier_merge_is_insertion_of_all_points() {
        let mut a = Frontier::default();
        a.insert(pt([1.0, 4.0, 4.0, 4.0], 0));
        let mut b = Frontier::default();
        b.insert(pt([4.0, 1.0, 4.0, 4.0], 1));
        b.insert(pt([1.0, 4.0, 4.0, 4.0], 2)); // duplicate vector, larger id
        a.merge(&b);
        let ids: Vec<u64> = a.points().iter().map(|p| p.id).collect();
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn frontier_cap_evicts_worst_primary_value() {
        let mut f = Frontier::default();
        // Mutually incomparable points: descending primary, ascending
        // secondary.
        for i in 0..(FRONTIER_CAP + 8) {
            let v = i as f64;
            let n = (FRONTIER_CAP + 8) as f64;
            f.insert(pt([n - v, v, 1.0, 1.0], i as u64));
        }
        assert_eq!(f.len(), FRONTIER_CAP);
        // The evicted points are the largest primary values — the
        // earliest inserted ids here.
        assert!(f.points().iter().all(|p| p.id >= 8));
    }

    #[test]
    fn shared_bounds_monotone_min_over_positive_values() {
        let s = SharedBounds::new();
        assert_eq!(s.get(2), f64::INFINITY);
        s.publish(2, 5.0);
        s.publish(2, 7.0); // larger value never raises the cell
        assert_eq!(s.get(2), 5.0);
        s.publish(2, 4.875);
        assert_eq!(s.get(2), 4.875);
        // Other cells untouched.
        assert_eq!(s.get(0), f64::INFINITY);
    }

    #[test]
    fn point_id_orders_by_pair_then_proto_then_slot() {
        let a = point_id(0, 5, 3);
        let b = point_id(0, 6, 0);
        let c = point_id(1, 0, 0);
        assert!(a < b && b < c);
    }
}
