//! Core of the progressive co-search (see module docs in [`super`]).
//!
//! The hot path is parallel, memoized, arena-backed and pruned:
//! operators shard across a scoped worker pool; per (op, format pair)
//! the legal protos are built once into a flat [`ProtoArena`] (from the
//! per-op hoisted [`OpEnumeration`]) which the proto-level shards then
//! iterate by index with a deterministic `(metric value, proto id)`
//! reduction; every worker evaluates through a private [`EvalContext`]
//! that caches `access_counts` per (tiling, order) proto across
//! candidate format pairs; and with [`SearchConfig::prune`] on, protos
//! whose order-independent lower bound cannot beat the incumbent skip
//! the order sweep.  The per-proto visitor path performs no heap
//! allocation and no `Mapping` clone (shards reuse a scratch mapping;
//! new bests `clone_from` into place).  `docs/SEARCH.md` walks the
//! whole pipeline and states the determinism contract.

use super::frontier::{point_id, Frontier, FrontierPoint, SharedBounds};
use super::{
    FormatMode, FrontierResult, OpDesign, ScoredMapping, SearchConfig, SearchHooks, SearchLimiter,
    SearchTelemetry, WorkloadResult,
};
use crate::arch::Accelerator;
use crate::cost::{
    mapping_is_legal, pack_key, tiles_are_legal, CompressionRatios, CostReport, EvalContext,
    MapKey, Metric, SharedCounts,
};
use crate::dataflow::mapper::{MapperConfig, OpEnumeration, ProtoArena};
use crate::dataflow::{tiles_of, Mapping, ProblemDims, MAX_LEVELS};
use std::collections::HashMap;
use crate::engine::allocate::TileHints;
use crate::engine::{search_formats_quant, ScoredFormat};
use crate::format::{named, Format};
use crate::sparsity::SparsitySpec;
use crate::util::hash::fnv1a64_fold;
use crate::util::inline::InlineVec;
use crate::util::pool;
use crate::workload::llm::weight_is_kv_tensor;
use crate::workload::{MatMulOp, Workload};
use anyhow::{bail, Result};
use std::time::Instant;

/// Quick dense probe: an even split of each dim across levels, used only
/// to derive tile hints for efficiency-oriented dimension allocation.
pub fn probe_tile_hints(p: &ProblemDims, nlevels: usize) -> (TileHints, TileHints) {
    // Split each dim into nlevels roughly-equal divisor factors,
    // outermost first.
    fn split(mut n: u64, nlevels: usize) -> Vec<u64> {
        let mut out = vec![1u64; nlevels];
        for slot in (0..nlevels).rev() {
            if slot == 0 {
                out[0] = n;
                break;
            }
            // Take the largest divisor <= n^(1/(slot+1)).
            let target = (n as f64).powf(1.0 / (slot + 1) as f64).round() as u64;
            let d = crate::util::mathx::divisors(n)
                .into_iter()
                .filter(|&d| d <= target.max(1))
                .next_back()
                .unwrap_or(1);
            out[slot] = d;
            n /= d;
        }
        out
    }
    let m = split(p.m, nlevels);
    let n = split(p.n, nlevels);
    let k = split(p.k, nlevels);
    // I is M x N, W is N x K.
    (
        TileHints { row: m.clone(), col: n.clone() },
        TileHints { row: n, col: k },
    )
}

/// Resolve the accelerator's native fixed format for a tensor shape.
pub fn native_format(arch: &Accelerator, rows: u64, cols: u64) -> Format {
    match arch.native_format.as_deref() {
        Some("Bitmap") => named::bitmap(rows, cols),
        Some("RLE") => named::rle(rows, cols),
        Some("CSR") => named::csr(rows, cols),
        Some("COO") => named::coo(rows, cols),
        Some(other) => panic!("unknown native format {other}"),
        None => named::bitmap(rows, cols),
    }
}

/// One candidate operand configuration the co-search maps: an (input,
/// weight) format pair plus the payload bitwidths each was scored at.
/// With the quantization axis disabled both widths are the engine's
/// `data_bits` and this is the classic format pair.
pub(crate) struct FormatChoice {
    pub input: ScoredFormat,
    pub weight: ScoredFormat,
    pub input_bits: u32,
    pub weight_bits: u32,
}

/// Candidate format choices for one op: per (activation, weight)
/// bitwidth combination, the format pairs best-first by combined
/// penalized bits — truncated to `pairs_to_map` *per combination*, then
/// concatenated in combination order.
///
/// The per-combination truncation is what makes a multi-width search
/// dominate every fixed-width search of the same set: the combo's
/// sub-list is exactly what a fixed search at those widths would map
/// (same engine calls, same truncation), so the union's minimum is ≤
/// each fixed search's minimum — exactly, per op (pinned by the
/// property tests in `rust/tests/quant_axis.rs`).  A single globally
/// truncated list would not have this property, because `eq_bits` ranks
/// low-width pairs first while being only a proxy for the mapped
/// metric.
fn format_pairs(arch: &Accelerator, op: &MatMulOp, cfg: &SearchConfig) -> Vec<FormatChoice> {
    let (m, n, k) = (op.dims.m, op.dims.n, op.dims.k);
    let qs = cfg.quant.resolve(cfg.engine.data_bits);
    let wspace = qs.weight_space(weight_is_kv_tensor(&op.name)).clone();
    let mut out: Vec<FormatChoice> = Vec::new();
    match cfg.mode {
        FormatMode::Fixed => {
            for &ab in qs.act.values() {
                for &wb in wspace.values() {
                    let fi = ScoredFormat::score_quant(
                        native_format(arch, m, n),
                        &op.spec.input,
                        &cfg.engine,
                        ab,
                    );
                    let fw = ScoredFormat::score_quant(
                        native_format(arch, n, k),
                        &op.spec.weight,
                        &cfg.engine,
                        wb,
                    );
                    out.push(FormatChoice { input: fi, weight: fw, input_bits: ab, weight_bits: wb });
                }
            }
        }
        FormatMode::Search => {
            let (hint_i, hint_w) = probe_tile_hints(&op.dims, arch.levels.len());
            // The weight-side structure search depends only on the
            // weight width; hoist it out of the activation loop.
            let tops_w: Vec<(u32, Vec<ScoredFormat>)> = wspace
                .values()
                .iter()
                .map(|&wb| {
                    let (top, _) = search_formats_quant(
                        n,
                        k,
                        &op.spec.weight,
                        Some(&hint_w),
                        &cfg.engine,
                        wb,
                    );
                    (wb, top)
                })
                .collect();
            for &ab in qs.act.values() {
                let (top_i, _) =
                    search_formats_quant(m, n, &op.spec.input, Some(&hint_i), &cfg.engine, ab);
                for (wb, top_w) in &tops_w {
                    let mut pairs = Vec::new();
                    for fi in top_i.iter() {
                        for fw in top_w.iter() {
                            pairs.push((fi.clone(), fw.clone()));
                        }
                    }
                    pairs.sort_by(|a, b| {
                        let ca = a.0.eq_bits + a.1.eq_bits;
                        let cb = b.0.eq_bits + b.1.eq_bits;
                        ca.partial_cmp(&cb).unwrap()
                    });
                    pairs.truncate(cfg.pairs_to_map.max(1));
                    out.extend(pairs.into_iter().map(|(fi, fw)| FormatChoice {
                        input: fi,
                        weight: fw,
                        input_bits: ab,
                        weight_bits: *wb,
                    }));
                }
            }
        }
    }
    out
}

/// Hoisted enumeration tables for one op's dims on `arch` — the single
/// definition of the op→enumeration wiring, shared by the progressive
/// search, the fixed-format evaluator and the stepwise baseline so all
/// three walk the same proto space.
pub(crate) fn op_enumeration(
    arch: &Accelerator,
    dims: &ProblemDims,
    mapper: &MapperConfig,
) -> OpEnumeration {
    OpEnumeration::new(
        dims,
        arch.levels.len(),
        arch.mac.spatial_rows,
        arch.mac.spatial_cols,
        mapper,
    )
}

/// Compression ratios of a format choice.  Each operand's ratio is
/// capped at its *quantized-dense* ratio `bits / data_bits` — the
/// accelerator can always fall back to storing the quantized tensor
/// dense, so an inflating format never costs more than that.  With the
/// quant axis disabled the cap is exactly `1.0` (the classic dense
/// cap), keeping the disabled flow bit-identical.
fn pair_ratios(choice: &FormatChoice, data_bits: u32) -> CompressionRatios {
    let cap = |bits: u32| bits as f64 / data_bits as f64;
    CompressionRatios {
        input: choice.input.cost.ratio().min(cap(choice.input_bits)),
        weight: choice.weight.cost.ratio().min(cap(choice.weight_bits)),
    }
}

/// Per-level loop ordering via coordinate descent **in place**: sweep
/// the levels (outermost first), picking for each the order minimizing
/// the metric with the others fixed; repeat until a sweep brings no
/// improvement (≤3 sweeps in practice).  Boundary-b traffic depends only
/// on orders of levels ≤ b, so the first sweep is already locally exact
/// per boundary; later sweeps catch cross-boundary interactions that a
/// single greedy pass misses — at ~2x the evaluations of one pass, still
/// an order of magnitude below exhaustive 6^L expansion.  The per-level
/// trials run through [`EvalContext::sweep_level`], which resumes the
/// fill pass from the untouched level prefix and absorbs re-trials in
/// the `access_counts` cache.  `m` is left holding the chosen orders.
fn choose_orders_greedy(
    m: &mut Mapping,
    ctx: &mut EvalContext<'_>,
    spec: &SparsitySpec,
    ratios: &CompressionRatios,
) -> CostReport {
    let arch = ctx.arch;
    // Levels with <= 1 non-unit loop need no sweep (order irrelevant);
    // the set depends only on the factors, which the sweep never moves.
    let mut sweep_lvls: InlineVec<usize, MAX_LEVELS> = InlineVec::new();
    for (lvl, level) in m.levels.iter().enumerate() {
        if level.factors.iter().filter(|&&f| f > 1).count() > 1 {
            sweep_lvls.push(lvl);
        }
    }
    let mut current = f64::INFINITY;
    for _sweep in 0..3 {
        let mut improved = false;
        for &lvl in &sweep_lvls {
            let v = ctx.sweep_level(m, lvl, spec, &arch.reduction, ratios);
            if v < current - 1e-12 {
                current = v;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    ctx.evaluate(m, spec, &arch.reduction, ratios)
}

/// Tile refinement: bounded hill climbing from the enumeration's best
/// proto, moving prime-ish factors {2,3,5,7} between memory levels per
/// dim.  Catches optima the capped divisor enumeration truncates away on
/// divisor-rich (CNN im2col) problem dims; each accepted move re-runs the
/// order sweep.  Runs serially after the sharded enumeration has been
/// reduced, so it never affects the determinism contract; with `prune`
/// on, moves whose lower bound cannot strictly beat the incumbent skip
/// their sweep — refinement accepts strict improvements only, so the
/// outcome is unchanged.
fn refine_tiles(
    best: ScoredMapping,
    ctx: &mut EvalContext<'_>,
    spec: &SparsitySpec,
    ratios: &CompressionRatios,
    prune: bool,
) -> ScoredMapping {
    let arch = ctx.arch;
    let (mut mapping, mut report, mut value) = best;
    for _iter in 0..40 {
        let mut improved = false;
        let n = mapping.levels.len();
        'moves: for di in 0..3 {
            // Snapshot this dim's factors: `mapping` is only reassigned
            // on acceptance, which immediately moves to the next dim.
            let mut fdi: InlineVec<u64, MAX_LEVELS> = InlineVec::new();
            for l in &mapping.levels {
                fdi.push(l.factors[di]);
            }
            for (a, &fa) in fdi.iter().enumerate() {
                for b in 0..n {
                    if a == b {
                        continue;
                    }
                    for step in [2u64, 3, 5, 7] {
                        if fa % step != 0 {
                            continue;
                        }
                        let mut cand = mapping.clone();
                        cand.levels[a].factors[di] /= step;
                        cand.levels[b].factors[di] *= step;
                        if !mapping_is_legal(arch, &cand, ratios) {
                            continue;
                        }
                        if prune {
                            let tiles = tiles_of(&cand);
                            let mut factors: InlineVec<[u64; 3], MAX_LEVELS> = InlineVec::new();
                            for l in &cand.levels {
                                factors.push(l.factors);
                            }
                            let lb = ctx.lower_bound(
                                &factors,
                                &tiles,
                                cand.spatial,
                                spec,
                                &arch.reduction,
                                ratios,
                            );
                            if lb >= value {
                                continue;
                            }
                        }
                        let r2 = choose_orders_greedy(&mut cand, ctx, spec, ratios);
                        let v2 = ctx.metric.of(&r2);
                        if v2 < value {
                            mapping = cand;
                            report = r2;
                            value = v2;
                            improved = true;
                            continue 'moves;
                        }
                    }
                }
            }
        }
        if !improved {
            break;
        }
    }
    (mapping, report, value)
}

/// One shard's best over the proto arena: the metric value, the proto's
/// arena id (the deterministic enumeration order), and the ordered
/// mapping with its report.
struct PairBest {
    value: f64,
    proto_id: u64,
    mapping: Mapping,
    report: CostReport,
}

impl PairBest {
    /// `(value, proto id)` total-order comparison: does a candidate with
    /// `(v, id)` beat this incumbent?  The same rule the cross-shard
    /// reduction uses, applied in-shard too so the shard best is the
    /// total-order minimum of its evaluated protos **whatever order the
    /// shard visited them in** — the property that makes the best-first
    /// permutation result-neutral.  Under ascending-id visits the id
    /// clause never fires (the incumbent is always earlier), so this is
    /// exactly the historical "first strictly better wins" rule.
    fn beaten_by(&self, v: f64, id: u64) -> bool {
        match v.partial_cmp(&self.value).expect("metric value was NaN") {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Equal => id < self.proto_id,
            std::cmp::Ordering::Greater => false,
        }
    }

    /// Can a proto whose lower bound is `lb` still beat this incumbent
    /// under the `(value, id)` order?  `lb` bounds every value the proto
    /// can achieve, so `lb > value` rules it out; at `lb == value` only
    /// an *earlier* id could still win the tie-break.  Under ascending-id
    /// visits the candidate id is always later, so the condition reduces
    /// to the historical `lb >= value` prune.
    fn prunes(&self, lb: f64, id: u64) -> bool {
        lb > self.value || (lb == self.value && id > self.proto_id)
    }
}

/// One shard's outcome: the partial best plus the enumeration counters
/// feeding [`SearchTelemetry`].
struct ShardOutcome {
    best: Option<PairBest>,
    protos: u64,
    pruned: u64,
    /// Prunes that only fired thanks to the shared cross-shard incumbent
    /// ([`SharedBounds`]) being tighter than the local one.
    bound_tightenings: u64,
}

/// The immutable inputs one (op, ratios) mapping search shares across
/// its shards — bundled so the shard entry point stays at a sane arity.
#[derive(Clone, Copy)]
struct PairSearch<'s> {
    arena: &'s ProtoArena,
    op: &'s MatMulOp,
    cfg: &'s SearchConfig,
    ratios: &'s CompressionRatios,
    limiter: Option<&'s SearchLimiter>,
    /// Best-first visit permutation over arena ids (ascending
    /// primary-metric lower bound; `None` = ascending id).
    perm: Option<&'s [u32]>,
    /// Primary-metric lower bounds per arena id, precomputed alongside
    /// `perm` so scalar shards don't re-derive them per visit.
    bounds: Option<&'s [f64]>,
    /// Index of the format pair in this op's candidate list — the pair
    /// component of deterministic frontier point ids.
    pair_idx: u64,
}

/// Precompute the best-first machinery for one (op, ratios) arena: the
/// primary-metric lower bound of every proto and the permutation
/// visiting them in ascending bound order.  Only worth building when
/// pruning is on (without pruning every proto is swept regardless of
/// order); `None` leaves the classic ascending-id iteration.
fn build_best_first(
    arena: &ProtoArena,
    ctx: &EvalContext<'_>,
    op: &MatMulOp,
    ratios: &CompressionRatios,
    cfg: &SearchConfig,
) -> Option<(Vec<f64>, Vec<u32>)> {
    if !(cfg.best_first && cfg.prune) || arena.is_empty() {
        return None;
    }
    let arch = ctx.arch;
    let bounds: Vec<f64> = (0..arena.len())
        .map(|i| {
            ctx.lower_bound(
                arena.factors(i),
                arena.tiles(i),
                arena.spatial(i),
                &op.spec,
                &arch.reduction,
                ratios,
            )
        })
        .collect();
    let perm = arena.order_by(|i| bounds[i]);
    Some((bounds, perm))
}

/// Per-proto trial memo for the frontier descent: mapping key → report.
///
/// In frontier mode the four per-metric greedy descents of one proto all
/// start from the identical canonical-order mapping and mostly walk the
/// same trial mappings.  Routing every trial through this recorder —
/// sitting *above* the [`EvalContext`] — turns each repeat into zero
/// context lookups (so zero `evaluations`), while a miss costs exactly
/// one counted [`EvalContext::evaluate`].  Reports are pure functions of
/// the mapping (given the pair's fixed spec/reduction/ratios), so the
/// recorded report is bit-identical to what a fresh evaluation — or a
/// scalar search's [`EvalContext::sweep_level`] resume — would produce;
/// per-metric winners therefore match the four independent searches
/// exactly while the one-pass evaluation count is strictly lower
/// (`rust/tests/frontier.rs`, `fig14_frontier`).
struct TrialRecorder {
    map: HashMap<MapKey, CostReport>,
}

impl TrialRecorder {
    fn new() -> TrialRecorder {
        TrialRecorder { map: HashMap::new() }
    }

    fn clear(&mut self) {
        self.map.clear();
    }

    fn eval(
        &mut self,
        ctx: &mut EvalContext<'_>,
        m: &Mapping,
        spec: &SparsitySpec,
        ratios: &CompressionRatios,
    ) -> CostReport {
        let key = pack_key(m);
        if let Some(r) = self.map.get(&key) {
            return *r;
        }
        let arch = ctx.arch;
        let r = ctx.evaluate(m, spec, &arch.reduction, ratios);
        self.map.insert(key, r);
        r
    }
}

/// [`choose_orders_greedy`] for an explicit `metric`, with every trial
/// routed through the [`TrialRecorder`]: the same level-sweep schedule,
/// the same six-order trials with first-wins tie-breaking, the same
/// `1e-12` improvement exit and the same final re-evaluation — so the
/// chosen orders and the returned report are bit-identical to the
/// scalar path's, only the evaluation accounting differs (recorded
/// repeats cost nothing).
fn choose_orders_greedy_recorded(
    m: &mut Mapping,
    ctx: &mut EvalContext<'_>,
    rec: &mut TrialRecorder,
    metric: Metric,
    spec: &SparsitySpec,
    ratios: &CompressionRatios,
) -> CostReport {
    let mut sweep_lvls: InlineVec<usize, MAX_LEVELS> = InlineVec::new();
    for (lvl, level) in m.levels.iter().enumerate() {
        if level.factors.iter().filter(|&&f| f > 1).count() > 1 {
            sweep_lvls.push(lvl);
        }
    }
    let mut current = f64::INFINITY;
    for _sweep in 0..3 {
        let mut improved = false;
        for &lvl in &sweep_lvls {
            let mut best: Option<([crate::dataflow::LoopDim; 3], f64)> = None;
            for ord in crate::dataflow::mapper::ALL_ORDERS {
                m.levels[lvl].order = ord;
                let r = rec.eval(ctx, m, spec, ratios);
                let trial = metric.of(&r);
                if best.map(|(_, b)| trial < b).unwrap_or(true) {
                    best = Some((ord, trial));
                }
            }
            let (ord, v) = best.unwrap();
            m.levels[lvl].order = ord;
            if v < current - 1e-12 {
                current = v;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    rec.eval(ctx, m, spec, ratios)
}

/// Run the mapping search over one shard's slice of the prebuilt proto
/// arena: visit positions congruent to `shard` mod `nshards` (a
/// balanced interleave) of either the ascending id sequence or the
/// best-first permutation; ids stay arena-global, so the reduction is
/// partition- and visit-order-independent.  The per-proto loop is
/// allocation-free: the shard owns one scratch mapping the arena writes
/// into, the order sweep mutates it in place, and a new best
/// `clone_from`s it (reusing the incumbent's storage).  The incumbent
/// update and the prune test both use the `(value, proto id)` total
/// order ([`PairBest::beaten_by`] / [`PairBest::prunes`]), so the shard
/// best is the total-order minimum of its slice whatever order it was
/// visited in — under ascending-id visits both rules collapse to the
/// historical first-wins / `lb >= value` forms.
///
/// With `cfg.prune` on, a proto is also skipped when its lower bound is
/// **strictly** above the shared cross-shard incumbent ([`SharedBounds`]
/// — strict because no proto id is attached to the shared value, so a
/// tie might still win the id tie-break).  Every shared value was
/// achieved by some proto, so such a proto can never win the reduction:
/// pruning changes the counters, never the result.
fn search_pair_shard(
    shard: usize,
    nshards: usize,
    ctx: &mut EvalContext<'_>,
    ps: &PairSearch<'_>,
    shared: &SharedBounds,
) -> ShardOutcome {
    let PairSearch { arena, op, cfg, ratios, limiter, perm, bounds, .. } = *ps;
    let mut out = ShardOutcome { best: None, protos: 0, pruned: 0, bound_tightenings: 0 };
    if arena.is_empty() || shard >= arena.len() {
        return out;
    }
    let arch = ctx.arch;
    let mi = ctx.metric.scalar_index();
    let mut scratch = arena.scratch_mapping();
    for pos in (shard..arena.len()).step_by(nshards.max(1)) {
        let id = match perm {
            Some(p) => p[pos] as usize,
            None => pos,
        };
        // Budget gate (serve requests): once a cap fires, every shard
        // stops opening protos.
        if let Some(l) = limiter {
            if !l.admit_proto() {
                break;
            }
        }
        out.protos += 1;
        if cfg.prune {
            let lb = match bounds {
                Some(bs) => bs[id],
                None => ctx.lower_bound(
                    arena.factors(id),
                    arena.tiles(id),
                    arena.spatial(id),
                    &op.spec,
                    &arch.reduction,
                    ratios,
                ),
            };
            if out.best.as_ref().is_some_and(|b| b.prunes(lb, id as u64)) {
                out.pruned += 1;
                continue;
            }
            if lb > shared.get(mi) {
                out.pruned += 1;
                out.bound_tightenings += 1;
                continue;
            }
        }
        arena.write_mapping(id, &mut scratch);
        let r = choose_orders_greedy(&mut scratch, ctx, &op.spec, ratios);
        let v = ctx.metric.of(&r);
        shared.publish(mi, v);
        match &mut out.best {
            Some(b) if b.beaten_by(v, id as u64) => {
                b.value = v;
                b.proto_id = id as u64;
                b.mapping.clone_from(&scratch);
                b.report = r;
            }
            None => {
                out.best = Some(PairBest {
                    value: v,
                    proto_id: id as u64,
                    mapping: scratch.clone(),
                    report: r,
                });
            }
            _ => {}
        }
    }
    out
}

/// Deterministic reduction of shard outcomes: fold counters into `tel`
/// (prunes attributed to scalar-metric slot `mi`) and minimize
/// `(value, proto id)`.  The id tie-break reproduces the serial rule
/// "first strictly better wins" exactly, independent of shard count,
/// scheduling and visit order.
fn reduce_outcomes(
    outcomes: Vec<ShardOutcome>,
    mi: usize,
    tel: &mut SearchTelemetry,
) -> Option<PairBest> {
    let mut best: Option<PairBest> = None;
    for o in outcomes {
        tel.protos += o.protos;
        tel.pruned += o.pruned;
        tel.pruned_by_metric[mi] += o.pruned;
        tel.bound_tightenings += o.bound_tightenings;
        let Some(pb) = o.best else { continue };
        let wins = match &best {
            Some(b) => b.beaten_by(pb.value, pb.proto_id),
            None => true,
        };
        if wins {
            best = Some(pb);
        }
    }
    best
}

/// Sharded mapping search for one (op, ratios) pair: fan the arena out
/// over the contexts' threads, merge the partial bests by the total
/// order on `(value, proto id)` — bit-identical to the serial pass for
/// any shard count — then refine tiles serially from the winner.
/// Enumeration counters accumulate into `tel`.
fn map_search(
    ctxs: &mut [EvalContext<'_>],
    ps: &PairSearch<'_>,
    tel: &mut SearchTelemetry,
) -> Option<ScoredMapping> {
    let nshards = ctxs.len();
    let shared = SharedBounds::new();
    let outcomes: Vec<ShardOutcome> = if nshards <= 1 {
        vec![search_pair_shard(0, 1, &mut ctxs[0], ps, &shared)]
    } else {
        std::thread::scope(|s| {
            let handles: Vec<_> = ctxs
                .iter_mut()
                .enumerate()
                .map(|(i, ctx)| {
                    let shared = &shared;
                    s.spawn(move || search_pair_shard(i, nshards, ctx, ps, shared))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("proto-search worker panicked"))
                .collect()
        })
    };
    let mi = ctxs[0].metric.scalar_index();
    let pb = reduce_outcomes(outcomes, mi, tel)?;
    // Tile refinement is bounded and runs on the already-reduced winner,
    // so it stays outside the budget gate: a fired limiter stops new
    // arena work but never truncates refinement of a found design.
    Some(refine_tiles(
        (pb.mapping, pb.report, pb.value),
        &mut ctxs[0],
        &ps.op.spec,
        ps.ratios,
        ps.cfg.prune,
    ))
}

/// One frontier shard's outcome: a partial best per scalar metric, the
/// shard's local Pareto points, and the prune counters.
struct FrontierShardOutcome {
    best: [Option<PairBest>; 4],
    points: Frontier,
    protos: u64,
    /// Protos where *every* metric's descent was skipped.
    pruned: u64,
    pruned_by_metric: [u64; 4],
    bound_tightenings: u64,
}

/// Frontier-mode shard: one pass over the shard's slice serving all
/// four scalar metrics.  Per proto, the vector lower bound
/// ([`EvalContext::lower_bound_vec`]) decides independently per metric
/// whether that metric's greedy descent can still beat its incumbent
/// (the same `(value, id)` total-order rules as the scalar shard, plus
/// the strict shared-bound test); the surviving descents run through a
/// per-proto [`TrialRecorder`], so mappings shared between metrics —
/// always including the canonical starting point and the first swept
/// level's six trials — are evaluated once instead of four times.
/// Every descent result feeds the shard's Pareto [`Frontier`] with its
/// full four-metric vector.
fn search_pair_shard_frontier(
    shard: usize,
    nshards: usize,
    ctx: &mut EvalContext<'_>,
    ps: &PairSearch<'_>,
    shared: &SharedBounds,
) -> FrontierShardOutcome {
    let PairSearch { arena, op, cfg, ratios, limiter, perm, pair_idx, .. } = *ps;
    let mut out = FrontierShardOutcome {
        best: [None, None, None, None],
        points: Frontier::default(),
        protos: 0,
        pruned: 0,
        pruned_by_metric: [0; 4],
        bound_tightenings: 0,
    };
    if arena.is_empty() || shard >= arena.len() {
        return out;
    }
    let arch = ctx.arch;
    let mut scratch = arena.scratch_mapping();
    let mut work = arena.scratch_mapping();
    let mut rec = TrialRecorder::new();
    for pos in (shard..arena.len()).step_by(nshards.max(1)) {
        let id = match perm {
            Some(p) => p[pos] as usize,
            None => pos,
        };
        if let Some(l) = limiter {
            if !l.admit_proto() {
                break;
            }
        }
        out.protos += 1;
        let mut skip = [false; 4];
        if cfg.prune {
            let lbs = ctx.lower_bound_vec(
                arena.factors(id),
                arena.tiles(id),
                arena.spatial(id),
                &op.spec,
                &arch.reduction,
                ratios,
            );
            for (mi, lb) in lbs.into_iter().enumerate() {
                if out.best[mi].as_ref().is_some_and(|b| b.prunes(lb, id as u64)) {
                    skip[mi] = true;
                    out.pruned_by_metric[mi] += 1;
                } else if lb > shared.get(mi) {
                    skip[mi] = true;
                    out.pruned_by_metric[mi] += 1;
                    out.bound_tightenings += 1;
                }
            }
            if skip.iter().all(|&s| s) {
                out.pruned += 1;
                continue;
            }
        }
        arena.write_mapping(id, &mut scratch);
        rec.clear();
        for (mi, metric) in Metric::SCALARS.into_iter().enumerate() {
            if skip[mi] {
                continue;
            }
            // Each metric's descent replays its solo search exactly:
            // same canonical start, same trial sequence, same
            // selections — only the evaluations are shared.
            work.clone_from(&scratch);
            let r = choose_orders_greedy_recorded(&mut work, ctx, &mut rec, metric, &op.spec, ratios);
            let v = metric.of(&r);
            shared.publish(mi, v);
            out.points.insert(FrontierPoint {
                values: [
                    Metric::SCALARS[0].of(&r),
                    Metric::SCALARS[1].of(&r),
                    Metric::SCALARS[2].of(&r),
                    Metric::SCALARS[3].of(&r),
                ],
                id: point_id(pair_idx, id as u64, mi),
            });
            match &mut out.best[mi] {
                Some(b) if b.beaten_by(v, id as u64) => {
                    b.value = v;
                    b.proto_id = id as u64;
                    b.mapping.clone_from(&work);
                    b.report = r;
                }
                None => {
                    out.best[mi] = Some(PairBest {
                        value: v,
                        proto_id: id as u64,
                        mapping: work.clone(),
                        report: r,
                    });
                }
                _ => {}
            }
        }
    }
    out
}

/// Per-metric winners and Pareto points of one (op, format pair)
/// frontier search.
struct FrontierPairOutcome {
    winners: [Option<ScoredMapping>; 4],
    points: Frontier,
}

/// Frontier-mode counterpart of [`map_search`]: one sharded arena pass
/// serving all four scalar metrics, a per-metric `(value, proto id)`
/// reduction, then per-metric tile refinement (serial, with the
/// context temporarily projected onto that metric) whose results are
/// bit-identical to four independent scalar searches
/// (`rust/tests/frontier.rs`).
fn map_search_frontier(
    ctxs: &mut [EvalContext<'_>],
    ps: &PairSearch<'_>,
    tel: &mut SearchTelemetry,
) -> Option<FrontierPairOutcome> {
    let nshards = ctxs.len();
    let shared = SharedBounds::new();
    let outcomes: Vec<FrontierShardOutcome> = if nshards <= 1 {
        vec![search_pair_shard_frontier(0, 1, &mut ctxs[0], ps, &shared)]
    } else {
        std::thread::scope(|s| {
            let handles: Vec<_> = ctxs
                .iter_mut()
                .enumerate()
                .map(|(i, ctx)| {
                    let shared = &shared;
                    s.spawn(move || search_pair_shard_frontier(i, nshards, ctx, ps, shared))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("frontier-search worker panicked"))
                .collect()
        })
    };
    let mut best: [Option<PairBest>; 4] = [None, None, None, None];
    let mut points = Frontier::default();
    for o in outcomes {
        tel.protos += o.protos;
        tel.pruned += o.pruned;
        for (a, b) in tel.pruned_by_metric.iter_mut().zip(o.pruned_by_metric) {
            *a += b;
        }
        tel.bound_tightenings += o.bound_tightenings;
        points.merge(&o.points);
        for (mi, pb) in o.best.into_iter().enumerate() {
            let Some(pb) = pb else { continue };
            let wins = match &best[mi] {
                Some(b) => b.beaten_by(pb.value, pb.proto_id),
                None => true,
            };
            if wins {
                best[mi] = Some(pb);
            }
        }
    }
    if best.iter().all(|b| b.is_none()) {
        return None;
    }
    // Per-metric refinement, serial on ctxs[0] with the context
    // projected onto the metric — refinement is a pure function of the
    // winner and the metric, so each result matches the scalar path's.
    let mut winners: [Option<ScoredMapping>; 4] = [None, None, None, None];
    let outer_metric = ctxs[0].metric;
    for (mi, pb) in best.into_iter().enumerate() {
        let Some(pb) = pb else { continue };
        ctxs[0].metric = Metric::SCALARS[mi];
        let (mapping, report, value) = refine_tiles(
            (pb.mapping, pb.report, pb.value),
            &mut ctxs[0],
            &ps.op.spec,
            ps.ratios,
            ps.cfg.prune,
        );
        points.insert(FrontierPoint {
            values: [
                Metric::SCALARS[0].of(&report),
                Metric::SCALARS[1].of(&report),
                Metric::SCALARS[2].of(&report),
                Metric::SCALARS[3].of(&report),
            ],
            // Slot 8+mi marks refined winners; pb.proto_id keys the
            // proto the refinement started from.
            id: point_id(ps.pair_idx, pb.proto_id, 8 + mi),
        });
        winners[mi] = Some((mapping, report, value));
    }
    ctxs[0].metric = outer_metric;
    Some(FrontierPairOutcome { winners, points })
}

/// Refine a request-level memo scope to one op by folding in its
/// problem dims.  `access_counts` depends on `(mapping, dims)` only, so
/// ops with identical dims deliberately share memo entries — repeated
/// transformer layers (and same-shape q/k/v projections) hit the store
/// even within a single request.
fn op_memo<'m>(memo: Option<SharedCounts<'m>>, dims: &ProblemDims) -> Option<SharedCounts<'m>> {
    memo.map(|m| {
        let mut scope = m.scope;
        for d in [dims.m, dims.n, dims.k] {
            scope = fnv1a64_fold(scope, &d.to_le_bytes());
        }
        SharedCounts { scope, ..m }
    })
}

/// Progressive co-search for one operator over `shards` proto-level
/// threads.  The ratio-independent enumeration tables are hoisted once
/// per op ([`OpEnumeration`]); per format pair the legal-proto arena is
/// rebuilt in place (§III-D2 legality on packed tiles, before any
/// ordering) and the shards iterate it by index.  The per-shard
/// evaluation contexts persist across format pairs, so the
/// `access_counts` cache pays off a second time when the same proto
/// recurs under a different candidate ratio pair.  `hooks` optionally
/// binds a cross-run counts memo and a search budget; default hooks
/// reproduce the classic search exactly.
fn cosearch_op_sharded(
    arch: &Accelerator,
    op: &MatMulOp,
    cfg: &SearchConfig,
    shards: usize,
    tel: &mut SearchTelemetry,
    hooks: SearchHooks<'_>,
) -> (Option<OpDesign>, Option<OpFrontier>) {
    let memo = op_memo(hooks.memo, &op.dims);
    let mut ctxs: Vec<EvalContext<'_>> = (0..shards.max(1))
        .map(|_| {
            let ctx = EvalContext::with_model(arch, op.dims, cfg.metric, cfg.cost);
            match memo {
                Some(m) => ctx.with_shared_counts(m),
                None => ctx,
            }
        })
        .collect();
    let en = op_enumeration(arch, &op.dims, &cfg.mapper);
    let mut arena = ProtoArena::new();
    let frontier_mode = cfg.metric == Metric::Frontier;
    let mut best: Option<OpDesign> = None;
    let mut fbest: [Option<OpDesign>; 4] = [None, None, None, None];
    let mut fpoints = Frontier::default();
    for (pair_idx, choice) in format_pairs(arch, op, cfg).into_iter().enumerate() {
        if hooks.limiter.is_some_and(|l| l.exhausted()) {
            break;
        }
        let ratios = pair_ratios(&choice, cfg.engine.data_bits);
        arena.rebuild(&en, &cfg.mapper, |tiles, spatial| {
            tiles_are_legal(arch, tiles, spatial, &ratios)
        });
        let bf = build_best_first(&arena, &ctxs[0], op, &ratios, cfg);
        let ps = PairSearch {
            arena: &arena,
            op,
            cfg,
            ratios: &ratios,
            limiter: hooks.limiter,
            perm: bf.as_ref().map(|(_, p)| p.as_slice()),
            bounds: bf.as_ref().map(|(b, _)| b.as_slice()),
            pair_idx: pair_idx as u64,
        };
        if frontier_mode {
            if let Some(fo) = map_search_frontier(&mut ctxs, &ps, tel) {
                for (mi, w) in fo.winners.into_iter().enumerate() {
                    let Some((mapping, report, v)) = w else { continue };
                    // First-pair-wins on exact ties — the scalar rule.
                    if fbest[mi].as_ref().map(|b| v < b.metric_value).unwrap_or(true) {
                        fbest[mi] = Some(OpDesign {
                            op_name: op.name.clone(),
                            input_format: choice.input.format.clone(),
                            weight_format: choice.weight.format.clone(),
                            input_bits: choice.input_bits,
                            weight_bits: choice.weight_bits,
                            mapping,
                            report,
                            metric_value: v,
                            count: op.count,
                        });
                    }
                }
                fpoints.merge(&fo.points);
            }
        } else {
            let found = map_search(&mut ctxs, &ps, tel);
            if let Some((mapping, report, v)) = found {
                if best.as_ref().map(|b| v < b.metric_value).unwrap_or(true) {
                    best = Some(OpDesign {
                        op_name: op.name.clone(),
                        input_format: choice.input.format.clone(),
                        weight_format: choice.weight.format.clone(),
                        input_bits: choice.input_bits,
                        weight_bits: choice.weight_bits,
                        mapping,
                        report,
                        metric_value: v,
                        count: op.count,
                    });
                }
            }
        }
    }
    for ctx in &ctxs {
        tel.absorb(ctx);
    }
    if frontier_mode {
        tel.frontier_size += fpoints.len() as u64;
        // The workload-level design list carries the primary-metric
        // (energy) winner; the full per-metric set travels alongside.
        let primary = fbest[0].clone();
        (primary, Some(OpFrontier { winners: fbest, points: fpoints }))
    } else {
        (best, None)
    }
}

/// Frontier-mode payload of one op's co-search: per-scalar-metric
/// winners (each bit-identical to an independent scalar search of that
/// metric) plus the op's retained Pareto points.
pub(crate) struct OpFrontier {
    winners: [Option<OpDesign>; 4],
    points: Frontier,
}

/// Progressive co-search for one operator.  Returns `None` only if no
/// legal mapping exists for any candidate format pair.  Uses
/// `cfg.threads` proto-level shards; evaluation counts and cache
/// statistics accumulate into `tel`.
pub fn cosearch_op(
    arch: &Accelerator,
    op: &MatMulOp,
    cfg: &SearchConfig,
    tel: &mut SearchTelemetry,
) -> Option<OpDesign> {
    cosearch_op_sharded(
        arch,
        op,
        cfg,
        pool::resolve_threads(cfg.threads),
        tel,
        SearchHooks::default(),
    )
    .0
}

/// Split `threads` between op-level workers and a per-op proto-shard
/// plan: operators first (coarser tasks, no redundant arena builds),
/// leftover parallelism goes inside the ops.  When the count divides
/// unevenly (e.g. 6 threads over 4 ops), the remainder becomes one
/// extra shard for each of the first `threads % workers` ops instead of
/// idling, so the total shard budget equals the requested thread count
/// whenever ops bound the workers.  The plan is deterministic and
/// per-op; shard counts never change designs (see docs/SEARCH.md), so
/// redistribution is purely a wall-clock improvement.
fn split_threads(threads: usize, nops: usize) -> (usize, Vec<usize>) {
    let threads = threads.max(1);
    let workers = threads.clamp(1, nops.max(1));
    let base = threads / workers;
    let extra = threads % workers;
    (workers, (0..nops).map(|i| base + usize::from(i < extra)).collect())
}

/// Fold per-op `(design, telemetry)` results — already in workload op
/// order — into a [`WorkloadResult`].  An op with no design is an error
/// naming the op: an exhausted budget when a limiter fired before the
/// op completed, otherwise no legal mapping exists (tiny on-chip
/// memory; a dense worst-case fallback with trivially legal minimal
/// tiles is a possible future softening).
fn collect_workload(
    arch: &Accelerator,
    w: &Workload,
    start: Instant,
    per_op: Vec<(Option<OpDesign>, Option<OpFrontier>, SearchTelemetry)>,
    limiter: Option<&SearchLimiter>,
) -> Result<WorkloadResult> {
    let mut tel = SearchTelemetry::default();
    let mut designs = Vec::with_capacity(w.ops.len());
    let mut fres: Option<FrontierResult> = None;
    for (i, (d, f, t)) in per_op.into_iter().enumerate() {
        tel.merge(t);
        match d {
            Some(d) => designs.push(d),
            None => match limiter.filter(|l| l.exhausted()) {
                Some(l) => bail!(
                    "search budget exhausted ({} protos admitted) before op {} found a design",
                    l.admitted(),
                    w.ops[i].name
                ),
                None => bail!("no legal mapping for op {} on {}", w.ops[i].name, arch.name),
            },
        }
        if let Some(f) = f {
            let fr = fres.get_or_insert_with(FrontierResult::default);
            for (mi, wd) in f.winners.into_iter().enumerate() {
                match wd {
                    Some(wd) => fr.winners[mi].push(wd),
                    // Unreachable when the primary design above exists
                    // (the first descended proto serves all metrics),
                    // but fail loudly rather than silently dropping a
                    // metric column.
                    None => bail!(
                        "frontier search lost the {:?} winner for op {}",
                        Metric::SCALARS[mi],
                        w.ops[i].name
                    ),
                }
            }
            fr.op_points.push((w.ops[i].name.clone(), f.points.points().to_vec()));
        }
    }
    Ok(WorkloadResult {
        workload: w.name.clone(),
        designs,
        elapsed: start.elapsed(),
        evaluations: tel.evaluations,
        cache: tel.cache,
        protos: tel.protos,
        pruned: tel.pruned,
        pruned_by_metric: tel.pruned_by_metric,
        bound_tightenings: tel.bound_tightenings,
        frontier_size: tel.frontier_size,
        frontier: fres,
    })
}

/// Progressive co-search across a whole workload with explicit
/// [`SearchHooks`] — the fallible entry point behind
/// `driver::execute`, and through it the single funnel for `snipsnap
/// search`, `snipsnap serve` and `snipsnap sweep` workers.  With
/// default hooks this is byte-for-byte [`cosearch_workload`]; with a
/// limiter bound, an exhausted budget surfaces as an `Err` naming the
/// first op left without a design instead of a panic.
pub fn try_cosearch_workload(
    arch: &Accelerator,
    w: &Workload,
    cfg: &SearchConfig,
    hooks: SearchHooks<'_>,
) -> Result<WorkloadResult> {
    let start = Instant::now();
    let (workers, shard_plan) = split_threads(pool::resolve_threads(cfg.threads), w.ops.len());
    let per_op = pool::parallel_map(workers, &w.ops, |i, op| {
        let mut tel = SearchTelemetry::default();
        let (d, f) = cosearch_op_sharded(arch, op, cfg, shard_plan[i], &mut tel, hooks);
        (d, f, tel)
    });
    collect_workload(arch, w, start, per_op, hooks.limiter)
}

/// Progressive co-search across a whole workload, parallelized over
/// `cfg.threads` worker threads (serial when 1).  Designs and scores
/// are bit-identical for any thread count and with pruning on or off;
/// the telemetry counters (`evaluations`, cache, prune stats) are
/// additionally thread-invariant when pruning is off.  See
/// `docs/SEARCH.md`.  Panics when an op has no legal mapping; the
/// hook-carrying [`try_cosearch_workload`] is the fallible variant.
pub fn cosearch_workload(
    arch: &Accelerator,
    w: &Workload,
    cfg: &SearchConfig,
) -> WorkloadResult {
    try_cosearch_workload(arch, w, cfg, SearchHooks::default()).unwrap_or_else(|e| panic!("{e}"))
}

/// Evaluate a workload with FIXED formats and a FIXED per-op mapping
/// chosen by the co-search once — utility for format-comparison benches
/// (Fig. 10): same dataflow search, only the format differs.  Shares the
/// workload/op sharding of [`cosearch_workload`], so `make_formats` must
/// be callable from worker threads (`Sync`).
pub fn evaluate_with_formats(
    arch: &Accelerator,
    w: &Workload,
    make_formats: impl Fn(&MatMulOp) -> (Format, Format) + Sync,
    cfg: &SearchConfig,
) -> WorkloadResult {
    let start = Instant::now();
    let (workers, shard_plan) = split_threads(pool::resolve_threads(cfg.threads), w.ops.len());
    let per_op = pool::parallel_map(workers, &w.ops, |i, op| {
        let (f_i, f_w) = make_formats(op);
        let native = cfg.engine.data_bits;
        let choice = FormatChoice {
            input: ScoredFormat::score(f_i, &op.spec.input, &cfg.engine),
            weight: ScoredFormat::score(f_w, &op.spec.weight, &cfg.engine),
            input_bits: native,
            weight_bits: native,
        };
        let ratios = pair_ratios(&choice, native);
        let mut ctxs: Vec<EvalContext<'_>> = (0..shard_plan[i])
            .map(|_| EvalContext::with_model(arch, op.dims, cfg.metric, cfg.cost))
            .collect();
        let en = op_enumeration(arch, &op.dims, &cfg.mapper);
        let mut arena = ProtoArena::new();
        arena.rebuild(&en, &cfg.mapper, |tiles, spatial| {
            tiles_are_legal(arch, tiles, spatial, &ratios)
        });
        let mut tel = SearchTelemetry::default();
        let bf = build_best_first(&arena, &ctxs[0], op, &ratios, cfg);
        let ps = PairSearch {
            arena: &arena,
            op,
            cfg,
            ratios: &ratios,
            limiter: None,
            perm: bf.as_ref().map(|(_, p)| p.as_slice()),
            bounds: bf.as_ref().map(|(b, _)| b.as_slice()),
            pair_idx: 0,
        };
        let found = map_search(&mut ctxs, &ps, &mut tel);
        for ctx in &ctxs {
            tel.absorb(ctx);
        }
        let design = found.map(|(mapping, report, v)| OpDesign {
            op_name: op.name.clone(),
            input_format: choice.input.format,
            weight_format: choice.weight.format,
            input_bits: choice.input_bits,
            weight_bits: choice.weight_bits,
            mapping,
            report,
            metric_value: v,
            count: op.count,
        });
        (design, None::<OpFrontier>, tel)
    });
    collect_workload(arch, w, start, per_op, None).unwrap_or_else(|e| panic!("{e}"))
}

/// Check the compressed tensors of a design still satisfy the analytical
/// model's invariant: compressed bits never exceed dense bits by more
/// than the metadata of a dense tensor (sanity used in tests).
pub fn design_is_sane(d: &OpDesign) -> bool {
    d.report.total_energy_pj() > 0.0 && d.report.latency_cycles() > 0.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::Metric;
    use crate::format::quant::{BitwidthSpace, QuantConfig};
    use crate::sparsity::{SparsityPattern, SparsitySpec};

    fn small_op(name: &str, m: u64, n: u64, k: u64, di: f64, dw: f64) -> MatMulOp {
        MatMulOp {
            name: name.to_string(),
            dims: ProblemDims::new(m, n, k),
            spec: SparsitySpec::unstructured(di, dw),
            count: 1,
        }
    }

    fn fast_cfg(mode: FormatMode) -> SearchConfig {
        SearchConfig {
            mode,
            mapper: crate::dataflow::mapper::MapperConfig {
                max_candidates: 3000,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn fixed_mode_finds_a_design() {
        let arch = presets::arch3();
        let op = small_op("t", 64, 64, 64, 0.5, 0.5);
        let mut tel = SearchTelemetry::default();
        let d = cosearch_op(&arch, &op, &fast_cfg(FormatMode::Fixed), &mut tel).unwrap();
        assert!(design_is_sane(&d));
        assert!(tel.evaluations > 0);
        // The order sweep's final re-evaluation alone guarantees hits.
        assert!(tel.cache.hits > 0, "memoization never fired: {:?}", tel.cache);
        d.mapping.validate(&op.dims).unwrap();
        // Fixed mode uses the native bitmap.
        assert!(d.input_format.to_string().contains("B(N"), "{}", d.input_format);
    }

    #[test]
    fn search_mode_not_worse_than_fixed() {
        let arch = presets::arch3();
        let op = small_op("t", 64, 128, 64, 0.15, 0.3);
        let mut t1 = SearchTelemetry::default();
        let mut t2 = SearchTelemetry::default();
        let fixed = cosearch_op(&arch, &op, &fast_cfg(FormatMode::Fixed), &mut t1).unwrap();
        let search = cosearch_op(&arch, &op, &fast_cfg(FormatMode::Search), &mut t2).unwrap();
        assert!(
            search.metric_value <= fixed.metric_value * 1.0001,
            "search {} vs fixed {}",
            search.metric_value,
            fixed.metric_value
        );
    }

    #[test]
    fn nm_weight_op_cosearches_end_to_end() {
        // ROADMAP item: N:M weight sparsity driven through the co-search.
        // A 2:4 op must find a sane design in Search mode, and must never
        // cost more than the identical op with dense weights (skipping
        // reduction + compressed footprints only help).
        let arch = presets::arch3();
        let nm_op = MatMulOp {
            name: "nm".to_string(),
            dims: ProblemDims::new(64, 64, 64),
            spec: SparsitySpec {
                input: SparsityPattern::Dense,
                weight: SparsityPattern::Nm { n: 2, m: 4 },
            },
            count: 1,
        };
        let dense_op = MatMulOp {
            name: "dense".to_string(),
            spec: SparsitySpec::dense(),
            ..nm_op.clone()
        };
        let mut tel = SearchTelemetry::default();
        let cfg = fast_cfg(FormatMode::Search);
        let nm = cosearch_op(&arch, &nm_op, &cfg, &mut tel).unwrap();
        let dense = cosearch_op(&arch, &dense_op, &cfg, &mut tel).unwrap();
        assert!(design_is_sane(&nm));
        nm.mapping.validate(&nm_op.dims).unwrap();
        assert!(
            nm.metric_value <= dense.metric_value * 1.0001,
            "2:4 {} vs dense {}",
            nm.metric_value,
            dense.metric_value
        );
    }

    #[test]
    fn pruning_does_not_change_op_results() {
        let arch = presets::arch3();
        let op = small_op("t", 64, 128, 64, 0.3, 0.5);
        for mode in [FormatMode::Fixed, FormatMode::Search] {
            let mut t_on = SearchTelemetry::default();
            let mut t_off = SearchTelemetry::default();
            let on = cosearch_op(&arch, &op, &fast_cfg(mode), &mut t_on).unwrap();
            let off_cfg = SearchConfig { prune: false, ..fast_cfg(mode) };
            let off = cosearch_op(&arch, &op, &off_cfg, &mut t_off).unwrap();
            assert_eq!(on.mapping, off.mapping, "{mode:?}");
            assert_eq!(on.metric_value.to_bits(), off.metric_value.to_bits(), "{mode:?}");
            assert_eq!(on.report, off.report, "{mode:?}");
            assert_eq!(t_off.pruned, 0, "prune=false must never prune");
            assert_eq!(t_on.protos, t_off.protos, "same legal proto space");
            assert!(t_on.pruned <= t_on.protos);
            assert!(
                t_on.evaluations <= t_off.evaluations,
                "pruning added evaluations: {} vs {}",
                t_on.evaluations,
                t_off.evaluations
            );
        }
    }

    #[test]
    fn quant_explicit_native_singletons_match_disabled_axis() {
        // Disabled quant and an explicit all-{data_bits} config walk the
        // identical code path: same combos, same engine calls, same caps.
        let arch = presets::arch3();
        let op = small_op("t", 64, 128, 64, 0.3, 0.5);
        for mode in [FormatMode::Fixed, FormatMode::Search] {
            let mut ta = SearchTelemetry::default();
            let mut tb = SearchTelemetry::default();
            let off = cosearch_op(&arch, &op, &fast_cfg(mode), &mut ta).unwrap();
            let native = fast_cfg(mode).engine.data_bits;
            let explicit_cfg = SearchConfig {
                quant: QuantConfig {
                    w_bits: Some(BitwidthSpace::fixed(native)),
                    a_bits: Some(BitwidthSpace::fixed(native)),
                    kv_bits: Some(BitwidthSpace::fixed(native)),
                },
                ..fast_cfg(mode)
            };
            let on = cosearch_op(&arch, &op, &explicit_cfg, &mut tb).unwrap();
            assert_eq!(off.mapping, on.mapping, "{mode:?}");
            assert_eq!(off.metric_value.to_bits(), on.metric_value.to_bits(), "{mode:?}");
            assert_eq!(off.report, on.report, "{mode:?}");
            assert_eq!((off.input_bits, off.weight_bits), (native, native));
            assert_eq!((on.input_bits, on.weight_bits), (native, native));
            assert_eq!(ta.evaluations, tb.evaluations, "{mode:?}");
        }
    }

    #[test]
    fn quant_set_search_dominates_every_fixed_width() {
        let arch = presets::arch3();
        let op = small_op("t", 64, 64, 64, 0.4, 0.4);
        let widths = [4u32, 8, 16];
        let set_cfg = SearchConfig {
            quant: QuantConfig {
                w_bits: Some(BitwidthSpace::new(widths.to_vec()).unwrap()),
                ..QuantConfig::default()
            },
            ..fast_cfg(FormatMode::Search)
        };
        let mut tel = SearchTelemetry::default();
        let searched = cosearch_op(&arch, &op, &set_cfg, &mut tel).unwrap();
        assert!(widths.contains(&searched.weight_bits));
        assert_eq!(searched.input_bits, set_cfg.engine.data_bits);
        for b in widths {
            let fixed_cfg = SearchConfig {
                quant: QuantConfig {
                    w_bits: Some(BitwidthSpace::fixed(b)),
                    ..QuantConfig::default()
                },
                ..fast_cfg(FormatMode::Search)
            };
            let fixed = cosearch_op(&arch, &op, &fixed_cfg, &mut tel).unwrap();
            assert!(
                searched.metric_value <= fixed.metric_value,
                "set search {} beaten by fixed {b}-bit {}",
                searched.metric_value,
                fixed.metric_value
            );
        }
    }

    #[test]
    fn kv_ops_draw_weight_bits_from_the_kv_space() {
        let arch = presets::arch3();
        let mut op = small_op("blk/qk", 64, 64, 64, 0.5, 0.5);
        let cfg = SearchConfig {
            quant: QuantConfig {
                w_bits: Some(BitwidthSpace::fixed(4)),
                a_bits: None,
                kv_bits: Some(BitwidthSpace::fixed(8)),
            },
            ..fast_cfg(FormatMode::Search)
        };
        let mut tel = SearchTelemetry::default();
        let kv = cosearch_op(&arch, &op, &cfg, &mut tel).unwrap();
        assert_eq!(kv.weight_bits, 8, "qk weight slot is the K cache");
        op.name = "blk/fc1".into();
        let plain = cosearch_op(&arch, &op, &cfg, &mut tel).unwrap();
        assert_eq!(plain.weight_bits, 4, "non-KV weights use --w-bits");
    }

    #[test]
    fn pruning_is_sound_under_quant_search() {
        // The acceptance criterion's prune on/off bit-identity, extended
        // to a multi-width search.
        let arch = presets::arch3();
        let op = small_op("t", 64, 128, 64, 0.3, 0.5);
        let base = SearchConfig {
            quant: QuantConfig {
                w_bits: Some(BitwidthSpace::new(vec![4, 16]).unwrap()),
                a_bits: Some(BitwidthSpace::new(vec![8, 16]).unwrap()),
                ..QuantConfig::default()
            },
            ..fast_cfg(FormatMode::Search)
        };
        let mut t_on = SearchTelemetry::default();
        let mut t_off = SearchTelemetry::default();
        let on = cosearch_op(&arch, &op, &base, &mut t_on).unwrap();
        let off_cfg = SearchConfig { prune: false, ..base };
        let off = cosearch_op(&arch, &op, &off_cfg, &mut t_off).unwrap();
        assert_eq!(on.mapping, off.mapping);
        assert_eq!(on.metric_value.to_bits(), off.metric_value.to_bits());
        assert_eq!(on.report, off.report);
        assert_eq!(
            (on.input_bits, on.weight_bits),
            (off.input_bits, off.weight_bits)
        );
        assert_eq!(t_off.pruned, 0);
        assert_eq!(t_on.protos, t_off.protos);
    }

    #[test]
    fn workload_result_aggregates() {
        let arch = presets::arch3();
        let w = Workload {
            name: "toy".into(),
            ops: vec![
                small_op("a", 32, 64, 32, 0.5, 0.5),
                small_op("b", 64, 32, 64, 0.3, 0.4),
            ],
        };
        let r = cosearch_workload(&arch, &w, &fast_cfg(FormatMode::Fixed));
        assert_eq!(r.designs.len(), 2);
        assert!(r.total_energy_pj() > 0.0);
        assert!(r.memory_energy_pj() < r.total_energy_pj());
        assert!(r.total_cycles() > 0.0);
        assert!(r.evaluations > 0);
        assert_eq!(r.cache.lookups(), r.evaluations);
        assert_eq!(
            r.metric_total(Metric::Edp),
            r.total_energy_pj() * r.total_cycles()
        );
    }

    #[test]
    fn op_count_scales_totals() {
        let arch = presets::arch3();
        let mut op = small_op("a", 32, 64, 32, 0.5, 0.5);
        let w1 = Workload { name: "x1".into(), ops: vec![op.clone()] };
        op.count = 3;
        let w3 = Workload { name: "x3".into(), ops: vec![op] };
        let cfg = fast_cfg(FormatMode::Fixed);
        let r1 = cosearch_workload(&arch, &w1, &cfg);
        let r3 = cosearch_workload(&arch, &w3, &cfg);
        assert!((r3.total_energy_pj() / r1.total_energy_pj() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn probe_hints_cover_dims() {
        let (hi, hw) = probe_tile_hints(&ProblemDims::new(64, 128, 256), 3);
        assert_eq!(hi.row.iter().product::<u64>(), 64);
        assert_eq!(hi.col.iter().product::<u64>(), 128);
        assert_eq!(hw.row.iter().product::<u64>(), 128);
        assert_eq!(hw.col.iter().product::<u64>(), 256);
    }

    #[test]
    fn split_threads_prefers_op_workers() {
        assert_eq!(split_threads(1, 6), (1, vec![1; 6]));
        assert_eq!(split_threads(4, 6), (4, vec![1; 6]));
        assert_eq!(split_threads(4, 1), (1, vec![4]));
        assert_eq!(split_threads(8, 2), (2, vec![4, 4]));
        assert_eq!(split_threads(3, 0), (1, vec![]));
    }

    #[test]
    fn split_threads_redistributes_uneven_remainders() {
        // 6 threads over 4 ops used to idle 2 threads (4 workers × 1
        // shard); the remainder now lands as extra shards on the first
        // ops.
        assert_eq!(split_threads(6, 4), (4, vec![2, 2, 1, 1]));
        assert_eq!(split_threads(7, 3), (3, vec![3, 2, 2]));
        assert_eq!(split_threads(5, 2), (2, vec![3, 2]));
        assert_eq!(split_threads(0, 2), (1, vec![1, 1]));
        // Whenever ops bound the workers, the plan spends exactly the
        // requested thread budget and never hands an op zero shards.
        for (t, n) in [(6usize, 4usize), (7, 3), (9, 5), (13, 6)] {
            let (w, plan) = split_threads(t, n);
            assert_eq!(w, n);
            assert_eq!(plan.iter().sum::<usize>(), t);
            assert!(plan.iter().all(|&s| s >= 1));
        }
    }

    #[test]
    fn evaluate_with_formats_matches_fixed_flow() {
        let arch = presets::arch3();
        let op = small_op("a", 64, 64, 64, 0.4, 0.4);
        let w = Workload { name: "t".into(), ops: vec![op] };
        let cfg = fast_cfg(FormatMode::Fixed);
        let via_fixed = cosearch_workload(&arch, &w, &cfg);
        let via_explicit = evaluate_with_formats(
            &arch,
            &w,
            |op| {
                (
                    native_format(&arch, op.dims.m, op.dims.n),
                    native_format(&arch, op.dims.n, op.dims.k),
                )
            },
            &cfg,
        );
        assert!(
            (via_fixed.total_energy_pj() - via_explicit.total_energy_pj()).abs()
                / via_fixed.total_energy_pj()
                < 1e-9
        );
    }
}
