//! Core of the progressive co-search (see module docs in [`super`]).

use super::{FormatMode, OpDesign, SearchConfig, WorkloadResult};
use crate::arch::Accelerator;
use crate::cost::{evaluate, mapping_is_legal, CompressionRatios, CostReport};
use crate::dataflow::mapper::{all_orders, for_each_proto};
use crate::dataflow::{LoopDim, Mapping, ProblemDims};
use crate::engine::allocate::TileHints;
use crate::engine::{search_formats, ScoredFormat};
use crate::format::{named, Format};
use crate::sparsity::{SparsityPattern, SparsitySpec};
use crate::workload::{MatMulOp, Workload};
use std::time::Instant;

/// Quick dense probe: an even split of each dim across levels, used only
/// to derive tile hints for efficiency-oriented dimension allocation.
pub fn probe_tile_hints(p: &ProblemDims, nlevels: usize) -> (TileHints, TileHints) {
    // Split each dim into nlevels roughly-equal divisor factors,
    // outermost first.
    fn split(mut n: u64, nlevels: usize) -> Vec<u64> {
        let mut out = vec![1u64; nlevels];
        for slot in (0..nlevels).rev() {
            if slot == 0 {
                out[0] = n;
                break;
            }
            // Take the largest divisor <= n^(1/(slot+1)).
            let target = (n as f64).powf(1.0 / (slot + 1) as f64).round() as u64;
            let d = crate::util::mathx::divisors(n)
                .into_iter()
                .filter(|&d| d <= target.max(1))
                .next_back()
                .unwrap_or(1);
            out[slot] = d;
            n /= d;
        }
        out
    }
    let m = split(p.m, nlevels);
    let n = split(p.n, nlevels);
    let k = split(p.k, nlevels);
    // I is M x N, W is N x K.
    (
        TileHints { row: m.clone(), col: n.clone() },
        TileHints { row: n, col: k },
    )
}

/// Resolve the accelerator's native fixed format for a tensor shape.
pub fn native_format(arch: &Accelerator, rows: u64, cols: u64) -> Format {
    match arch.native_format.as_deref() {
        Some("Bitmap") => named::bitmap(rows, cols),
        Some("RLE") => named::rle(rows, cols),
        Some("CSR") => named::csr(rows, cols),
        Some("COO") => named::coo(rows, cols),
        Some(other) => panic!("unknown native format {other}"),
        None => named::bitmap(rows, cols),
    }
}

/// Candidate format pairs for one op, best-first by combined bits.
fn format_pairs(
    arch: &Accelerator,
    op: &MatMulOp,
    cfg: &SearchConfig,
) -> Vec<(ScoredFormat, ScoredFormat)> {
    let (m, n, k) = (op.dims.m, op.dims.n, op.dims.k);
    let score = |f: Format, pat: &SparsityPattern| {
        crate::engine::ScoredFormat::score(f, pat, &cfg.engine)
    };
    match cfg.mode {
        FormatMode::Fixed => {
            let fi = score(native_format(arch, m, n), &op.spec.input);
            let fw = score(native_format(arch, n, k), &op.spec.weight);
            vec![(fi, fw)]
        }
        FormatMode::Search => {
            let (hint_i, hint_w) = probe_tile_hints(&op.dims, arch.levels.len());
            let (top_i, _) = search_formats(m, n, &op.spec.input, Some(&hint_i), &cfg.engine);
            let (top_w, _) = search_formats(n, k, &op.spec.weight, Some(&hint_w), &cfg.engine);
            let mut pairs = Vec::new();
            for fi in top_i.iter() {
                for fw in top_w.iter() {
                    pairs.push((fi.clone(), fw.clone()));
                }
            }
            pairs.sort_by(|a, b| {
                let ca = a.0.eq_bits + a.1.eq_bits;
                let cb = b.0.eq_bits + b.1.eq_bits;
                ca.partial_cmp(&cb).unwrap()
            });
            pairs.truncate(cfg.pairs_to_map.max(1));
            pairs
        }
    }
}

/// Compression ratios of a format pair for an op.
fn pair_ratios(
    fi: &ScoredFormat,
    fw: &ScoredFormat,
    _spec: &SparsitySpec,
) -> CompressionRatios {
    CompressionRatios { input: fi.cost.ratio().min(1.0), weight: fw.cost.ratio().min(1.0) }
}

/// Per-level loop ordering via coordinate descent: sweep the levels
/// (outermost first), picking for each the order minimizing the metric
/// with the others fixed; repeat until a sweep brings no improvement
/// (≤3 sweeps in practice).  Boundary-b traffic depends only on orders of
/// levels ≤ b, so the first sweep is already locally exact per boundary;
/// later sweeps catch cross-boundary interactions that a single greedy
/// pass misses — at ~2x the evaluations of one pass, still an order of
/// magnitude below exhaustive 6^L expansion.
fn choose_orders_greedy(
    proto: &Mapping,
    arch: &Accelerator,
    p: &ProblemDims,
    spec: &SparsitySpec,
    ratios: &CompressionRatios,
    metric: crate::cost::Metric,
    evals: &mut u64,
) -> (Mapping, CostReport) {
    let mut m = proto.clone();
    let orders = all_orders();
    let mut current = f64::INFINITY;
    for _sweep in 0..3 {
        let mut improved = false;
        for lvl in 0..m.levels.len() {
            // Skip levels with <= 1 non-unit loop: order irrelevant.
            let nontrivial = m.levels[lvl].factors.iter().filter(|&&f| f > 1).count();
            if nontrivial <= 1 {
                continue;
            }
            let mut best: Option<([LoopDim; 3], f64)> = None;
            for &ord in &orders {
                m.levels[lvl].order = ord;
                let r = evaluate(arch, p, &m, spec, &arch.reduction, ratios);
                *evals += 1;
                let v = metric.of(&r);
                if best.map(|(_, b)| v < b).unwrap_or(true) {
                    best = Some((ord, v));
                }
            }
            let (ord, v) = best.unwrap();
            m.levels[lvl].order = ord;
            if v < current - 1e-12 {
                current = v;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    let r = evaluate(arch, p, &m, spec, &arch.reduction, ratios);
    *evals += 1;
    (m, r)
}

/// Tile refinement: bounded hill climbing from the enumeration's best
/// proto, moving prime-ish factors {2,3,5,7} between memory levels per
/// dim.  Catches optima the capped divisor enumeration truncates away on
/// divisor-rich (CNN im2col) problem dims; each accepted move re-runs the
/// order sweep.
fn refine_tiles(
    best: (Mapping, CostReport, f64),
    arch: &Accelerator,
    p: &ProblemDims,
    spec: &SparsitySpec,
    ratios: &CompressionRatios,
    metric: crate::cost::Metric,
    evals: &mut u64,
) -> (Mapping, CostReport, f64) {
    let (mut mapping, mut report, mut value) = best;
    for _iter in 0..40 {
        let mut improved = false;
        let n = mapping.levels.len();
        'moves: for di in 0..3 {
            for a in 0..n {
                for b in 0..n {
                    if a == b {
                        continue;
                    }
                    for step in [2u64, 3, 5, 7] {
                        if mapping.levels[a].factors[di] % step != 0 {
                            continue;
                        }
                        let mut cand = mapping.clone();
                        cand.levels[a].factors[di] /= step;
                        cand.levels[b].factors[di] *= step;
                        if !mapping_is_legal(arch, &cand, ratios) {
                            continue;
                        }
                        let (m2, r2) = choose_orders_greedy(
                            &cand, arch, p, spec, ratios, metric, evals,
                        );
                        let v2 = metric.of(&r2);
                        if v2 < value {
                            mapping = m2;
                            report = r2;
                            value = v2;
                            improved = true;
                            continue 'moves;
                        }
                    }
                }
            }
        }
        if !improved {
            break;
        }
    }
    (mapping, report, value)
}

/// Progressive co-search for one operator.  Returns `None` only if no
/// legal mapping exists for any candidate format pair.
pub fn cosearch_op(
    arch: &Accelerator,
    op: &MatMulOp,
    cfg: &SearchConfig,
    evals: &mut u64,
) -> Option<OpDesign> {
    let nlevels = arch.levels.len();
    let mut best: Option<OpDesign> = None;
    for (fi, fw) in format_pairs(arch, op, cfg) {
        let ratios = pair_ratios(&fi, &fw, &op.spec);
        let mut pair_best: Option<(Mapping, CostReport, f64)> = None;
        for_each_proto(
            &op.dims,
            nlevels,
            arch.mac.spatial_rows,
            arch.mac.spatial_cols,
            &cfg.mapper,
            // §III-D2: compressed-footprint legality BEFORE ordering.
            |proto| mapping_is_legal(arch, proto, &ratios),
            |proto| {
                let (m, r) = choose_orders_greedy(
                    proto, arch, &op.dims, &op.spec, &ratios, cfg.metric, evals,
                );
                let v = cfg.metric.of(&r);
                if pair_best.as_ref().map(|(_, _, b)| v < *b).unwrap_or(true) {
                    pair_best = Some((m, r, v));
                }
            },
        );
        if let Some(pb) = pair_best {
            let (mapping, report, v) =
                refine_tiles(pb, arch, &op.dims, &op.spec, &ratios, cfg.metric, evals);
            if best.as_ref().map(|b| v < b.metric_value).unwrap_or(true) {
                best = Some(OpDesign {
                    op_name: op.name.clone(),
                    input_format: fi.format.clone(),
                    weight_format: fw.format.clone(),
                    mapping,
                    report,
                    metric_value: v,
                    count: op.count,
                });
            }
        }
    }
    best
}

/// Progressive co-search across a whole workload.
pub fn cosearch_workload(
    arch: &Accelerator,
    w: &Workload,
    cfg: &SearchConfig,
) -> WorkloadResult {
    let start = Instant::now();
    let mut evals = 0u64;
    let mut designs = Vec::with_capacity(w.ops.len());
    for op in &w.ops {
        if let Some(d) = cosearch_op(arch, op, cfg, &mut evals) {
            designs.push(d);
        } else {
            // No legal mapping (tiny on-chip memory): fall back to a dense
            // worst-case evaluation with trivially legal minimal tiles.
            panic!("no legal mapping for op {} on {}", op.name, arch.name);
        }
    }
    WorkloadResult {
        workload: w.name.clone(),
        designs,
        elapsed: start.elapsed(),
        evaluations: evals,
    }
}

/// Evaluate a workload with FIXED formats and a FIXED per-op mapping
/// chosen by the co-search once — utility for format-comparison benches
/// (Fig. 10): same dataflow search, only the format differs.
pub fn evaluate_with_formats(
    arch: &Accelerator,
    w: &Workload,
    make_formats: impl Fn(&MatMulOp) -> (Format, Format),
    cfg: &SearchConfig,
) -> WorkloadResult {
    let start = Instant::now();
    let mut evals = 0u64;
    let mut designs = Vec::with_capacity(w.ops.len());
    for op in &w.ops {
        let (f_i, f_w) = make_formats(op);
        let fi = ScoredFormat::score(f_i, &op.spec.input, &cfg.engine);
        let fw = ScoredFormat::score(f_w, &op.spec.weight, &cfg.engine);
        let ratios = pair_ratios(&fi, &fw, &op.spec);
        let mut best: Option<(Mapping, CostReport, f64)> = None;
        for_each_proto(
            &op.dims,
            arch.levels.len(),
            arch.mac.spatial_rows,
            arch.mac.spatial_cols,
            &cfg.mapper,
            |proto| mapping_is_legal(arch, proto, &ratios),
            |proto| {
                let (m, r) = choose_orders_greedy(
                    proto, arch, &op.dims, &op.spec, &ratios, cfg.metric, &mut evals,
                );
                let v = cfg.metric.of(&r);
                if best.as_ref().map(|(_, _, b)| v < *b).unwrap_or(true) {
                    best = Some((m, r, v));
                }
            },
        );
        let best = best.unwrap_or_else(|| {
            panic!("no legal mapping for {} on {}", op.name, arch.name)
        });
        let (mapping, report, v) =
            refine_tiles(best, arch, &op.dims, &op.spec, &ratios, cfg.metric, &mut evals);
        designs.push(OpDesign {
            op_name: op.name.clone(),
            input_format: fi.format,
            weight_format: fw.format,
            mapping,
            report,
            metric_value: v,
            count: op.count,
        });
    }
    WorkloadResult {
        workload: w.name.clone(),
        designs,
        elapsed: start.elapsed(),
        evaluations: evals,
    }
}

/// Check the compressed tensors of a design still satisfy the analytical
/// model's invariant: compressed bits never exceed dense bits by more
/// than the metadata of a dense tensor (sanity used in tests).
pub fn design_is_sane(d: &OpDesign) -> bool {
    d.report.total_energy_pj() > 0.0 && d.report.latency_cycles() > 0.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::Metric;
    use crate::sparsity::SparsitySpec;

    fn small_op(name: &str, m: u64, n: u64, k: u64, di: f64, dw: f64) -> MatMulOp {
        MatMulOp {
            name: name.to_string(),
            dims: ProblemDims::new(m, n, k),
            spec: SparsitySpec::unstructured(di, dw),
            count: 1,
        }
    }

    fn fast_cfg(mode: FormatMode) -> SearchConfig {
        SearchConfig {
            mode,
            mapper: crate::dataflow::mapper::MapperConfig {
                max_candidates: 3000,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn fixed_mode_finds_a_design() {
        let arch = presets::arch3();
        let op = small_op("t", 64, 64, 64, 0.5, 0.5);
        let mut evals = 0;
        let d = cosearch_op(&arch, &op, &fast_cfg(FormatMode::Fixed), &mut evals).unwrap();
        assert!(design_is_sane(&d));
        assert!(evals > 0);
        d.mapping.validate(&op.dims).unwrap();
        // Fixed mode uses the native bitmap.
        assert!(d.input_format.to_string().contains("B(N"), "{}", d.input_format);
    }

    #[test]
    fn search_mode_not_worse_than_fixed() {
        let arch = presets::arch3();
        let op = small_op("t", 64, 128, 64, 0.15, 0.3);
        let mut e1 = 0;
        let mut e2 = 0;
        let fixed = cosearch_op(&arch, &op, &fast_cfg(FormatMode::Fixed), &mut e1).unwrap();
        let search = cosearch_op(&arch, &op, &fast_cfg(FormatMode::Search), &mut e2).unwrap();
        assert!(
            search.metric_value <= fixed.metric_value * 1.0001,
            "search {} vs fixed {}",
            search.metric_value,
            fixed.metric_value
        );
    }

    #[test]
    fn workload_result_aggregates() {
        let arch = presets::arch3();
        let w = Workload {
            name: "toy".into(),
            ops: vec![
                small_op("a", 32, 64, 32, 0.5, 0.5),
                small_op("b", 64, 32, 64, 0.3, 0.4),
            ],
        };
        let r = cosearch_workload(&arch, &w, &fast_cfg(FormatMode::Fixed));
        assert_eq!(r.designs.len(), 2);
        assert!(r.total_energy_pj() > 0.0);
        assert!(r.memory_energy_pj() < r.total_energy_pj());
        assert!(r.total_cycles() > 0.0);
        assert!(r.evaluations > 0);
        assert_eq!(
            r.metric_total(Metric::Edp),
            r.total_energy_pj() * r.total_cycles()
        );
    }

    #[test]
    fn op_count_scales_totals() {
        let arch = presets::arch3();
        let mut op = small_op("a", 32, 64, 32, 0.5, 0.5);
        let w1 = Workload { name: "x1".into(), ops: vec![op.clone()] };
        op.count = 3;
        let w3 = Workload { name: "x3".into(), ops: vec![op] };
        let cfg = fast_cfg(FormatMode::Fixed);
        let r1 = cosearch_workload(&arch, &w1, &cfg);
        let r3 = cosearch_workload(&arch, &w3, &cfg);
        assert!((r3.total_energy_pj() / r1.total_energy_pj() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn probe_hints_cover_dims() {
        let (hi, hw) = probe_tile_hints(&ProblemDims::new(64, 128, 256), 3);
        assert_eq!(hi.row.iter().product::<u64>(), 64);
        assert_eq!(hi.col.iter().product::<u64>(), 128);
        assert_eq!(hw.row.iter().product::<u64>(), 128);
        assert_eq!(hw.col.iter().product::<u64>(), 256);
    }

    #[test]
    fn evaluate_with_formats_matches_fixed_flow() {
        let arch = presets::arch3();
        let op = small_op("a", 64, 64, 64, 0.4, 0.4);
        let w = Workload { name: "t".into(), ops: vec![op] };
        let cfg = fast_cfg(FormatMode::Fixed);
        let via_fixed = cosearch_workload(&arch, &w, &cfg);
        let via_explicit = evaluate_with_formats(
            &arch,
            &w,
            |op| {
                (
                    native_format(&arch, op.dims.m, op.dims.n),
                    native_format(&arch, op.dims.n, op.dims.k),
                )
            },
            &cfg,
        );
        assert!(
            (via_fixed.total_energy_pj() - via_explicit.total_energy_pj()).abs()
                / via_fixed.total_energy_pj()
                < 1e-9
        );
    }
}
