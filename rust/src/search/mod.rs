//! The Progressive Co-Search Workflow (paper §III-D, Fig. 7).
//!
//! Per operator, the workflow interleaves dataflow and format search:
//!
//! 1. **Upfront estimation of computation reduction** (§III-D1): the
//!    reduction strategy's cycle/energy fractions are modeled *before*
//!    dataflow generation (inside every evaluation — never as a post-hoc
//!    correction pass).
//! 2. **Format generation**: the adaptive compression engine proposes
//!    top-k format pairs for (I, W), steered by tile hints from a quick
//!    dense probe mapping (efficiency-oriented allocation, §III-C2).
//! 3. **Compression-aware loop allocation** (§III-D2): tiling protos are
//!    legality-filtered against the *compressed* operand footprints
//!    before loop-order assignment — illegal dataflows are never
//!    generated, so no repair iterations are needed.
//! 4. **Greedy loop ordering**: per memory level (outermost first), pick
//!    the order minimizing the optimization metric given outer choices —
//!    boundary-`b` traffic is independent of deeper levels' orders, so
//!    the greedy pass is locally exact per boundary.
//!
//! # Parallel execution, memoized evaluation and pruning
//!
//! Per (op, format pair), the legal protos are built **once** into a
//! flat [`ProtoArena`](crate::dataflow::mapper::ProtoArena) (packed
//! factor triples + precomputed tiles; the ratio-independent
//! enumeration tables are hoisted per op into an
//! [`OpEnumeration`](crate::dataflow::mapper::OpEnumeration)).  The
//! per-op searches are independent, so [`cosearch_workload`] shards
//! operators across a scoped worker pool ([`crate::util::pool`]); when
//! [`SearchConfig::threads`] exceeds the operator count, the arena is
//! sharded by index range *within* an op too.  Partial bests are merged
//! by a total order on `(metric value, proto id)`, which makes designs
//! and scores **bit-identical** to the serial path for any thread count
//! — the contract, and why it holds, is documented in `docs/SEARCH.md`.
//! Every worker owns a private [`EvalContext`](crate::cost::EvalContext)
//! that memoizes `access_counts` per (tiling, order) proto across
//! candidate format/ratio pairs; aggregated
//! [`CacheStats`](crate::cost::CacheStats) land in
//! [`WorkloadResult::cache`].  With [`SearchConfig::prune`] on
//! (default), protos whose order-independent metric lower bound already
//! reaches the incumbent shard best skip the order sweep entirely —
//! provably-worse candidates only, so results are unchanged.
//!
//! Contrast with the Sparseloop-style stepwise workflow in
//! [`crate::baselines::sparseloop_like`].

pub mod frontier;
pub mod progressive;

use crate::arch::Accelerator;
use crate::cost::{CacheStats, CostModel, CostReport, EvalContext, Metric, SharedCounts};
use crate::dataflow::Mapping;
use crate::engine::EngineConfig;
use crate::format::quant::QuantConfig;
use crate::format::Format;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant};

pub use progressive::{
    cosearch_op, cosearch_workload, evaluate_with_formats, probe_tile_hints,
    try_cosearch_workload,
};

/// A mapping with its cost report and scalar metric value — the unit the
/// mapping search returns and the tile refinement hill-climbs on.
pub type ScoredMapping = (Mapping, CostReport, f64);

/// Per-search telemetry: logical cost-model evaluations plus the
/// hit/miss counters of the memoized `access_counts` cache, and the
/// enumeration-side counters of the branch-and-bound pass.  Hits still
/// count as evaluations (the exploration-effort metric is unchanged by
/// caching); the cache counters measure how much recomputation the
/// memoization removed; `protos`/`pruned` measure how much of the legal
/// proto space the lower bound let the search skip entirely.
#[derive(Clone, Copy, Debug, Default)]
pub struct SearchTelemetry {
    pub evaluations: u64,
    pub cache: CacheStats,
    /// Legal protos considered by the mapping search (arena rows
    /// iterated, across all format pairs).
    pub protos: u64,
    /// Protos whose order sweep was skipped because their metric lower
    /// bound already reached the incumbent best.  In frontier mode a
    /// proto counts here only when **every** scalar metric's descent
    /// was skipped; the per-metric breakdown is `pruned_by_metric`.
    pub pruned: u64,
    /// Per-scalar-metric prune counts ([`Metric::SCALARS`] order): how
    /// many per-metric order sweeps the vector lower bound skipped.
    /// Scalar searches attribute their prunes to their own metric's
    /// slot.
    pub pruned_by_metric: [u64; 4],
    /// Prunes that fired only because a *shared* cross-shard incumbent
    /// (`search::frontier::SharedBounds`) was tighter than the shard's
    /// local incumbent.  Like all prune telemetry this depends on
    /// thread interleaving; designs and scores do not.
    pub bound_tightenings: u64,
    /// Points retained on the Pareto frontier (frontier mode only;
    /// summed across ops).
    pub frontier_size: u64,
}

impl SearchTelemetry {
    /// Fold one worker's evaluation context into this telemetry.
    pub fn absorb(&mut self, ctx: &EvalContext<'_>) {
        self.evaluations += ctx.evals();
        self.cache.merge(ctx.cache_stats());
    }

    pub fn merge(&mut self, other: SearchTelemetry) {
        self.evaluations += other.evaluations;
        self.cache.merge(other.cache);
        self.protos += other.protos;
        self.pruned += other.pruned;
        for (a, b) in self.pruned_by_metric.iter_mut().zip(other.pruned_by_metric) {
            *a += b;
        }
        self.bound_tightenings += other.bound_tightenings;
        self.frontier_size += other.frontier_size;
    }
}

/// Cooperative budget enforcement for one co-search invocation: an
/// optional wall-clock deadline and an optional cap on protos admitted
/// into the mapping search, shared across every shard of the request
/// (the `serve` layer builds one per [`crate::serve::SearchBudget`]).
///
/// Enforcement happens inside the arena loop: each shard asks
/// [`Self::admit_proto`] before opening a proto, and once any cap fires
/// the limiter latches `exhausted` so all shards — and the format-pair
/// loop above them — stop opening new work.  A limiter whose caps never
/// fire is behaviorally invisible: the search result is bit-identical
/// to running without one.  When a cap *does* fire, which protos got
/// admitted depends on thread scheduling, so budget-exhausted results
/// are best-effort; the determinism contract (docs/SEARCH.md) applies
/// to searches whose budget never fires.
pub struct SearchLimiter {
    deadline: Option<Instant>,
    max_protos: Option<u64>,
    admitted: AtomicU64,
    exhausted: AtomicBool,
}

impl SearchLimiter {
    /// A limiter with the given caps; `None` caps never fire (and a
    /// wall time too large to represent as a deadline is unlimited).
    pub fn new(wall_time: Option<Duration>, max_protos: Option<u64>) -> SearchLimiter {
        SearchLimiter {
            deadline: wall_time.and_then(|d| Instant::now().checked_add(d)),
            max_protos,
            admitted: AtomicU64::new(0),
            exhausted: AtomicBool::new(false),
        }
    }

    /// Ask to admit one more proto into the mapping search; `false`
    /// means a cap fired and the caller must stop opening work.
    pub fn admit_proto(&self) -> bool {
        if self.exhausted.load(Ordering::Relaxed) {
            return false;
        }
        let n = self.admitted.fetch_add(1, Ordering::Relaxed);
        if self.max_protos.is_some_and(|cap| n >= cap) {
            self.admitted.fetch_sub(1, Ordering::Relaxed);
            self.exhausted.store(true, Ordering::Relaxed);
            return false;
        }
        // The deadline is sampled every 64th admission only: an Instant
        // read costs far more than the admission bookkeeping.
        if n % 64 == 0 {
            if let Some(dl) = self.deadline {
                if Instant::now() >= dl {
                    self.admitted.fetch_sub(1, Ordering::Relaxed);
                    self.exhausted.store(true, Ordering::Relaxed);
                    return false;
                }
            }
        }
        true
    }

    /// True once any cap has fired (latched).
    pub fn exhausted(&self) -> bool {
        self.exhausted.load(Ordering::Relaxed)
    }

    /// Protos admitted so far.
    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }
}

/// Cross-cutting hooks for one co-search invocation — the seam the
/// serve layer plugs into the search core.  The `Default` value (no
/// memo, no limiter) is exactly the classic search:
/// [`cosearch_workload`] delegates to [`try_cosearch_workload`] with
/// default hooks.
#[derive(Clone, Copy, Default)]
pub struct SearchHooks<'a> {
    /// Cross-run `access_counts` store plus the request-scope digest
    /// ([`SharedCounts`]).  Value-transparent: binding a store never
    /// changes designs, scores or the `evaluations` counter (pinned by
    /// `rust/tests/serve_service.rs`).
    pub memo: Option<SharedCounts<'a>>,
    /// Budget caps checked inside the arena loop (see
    /// [`SearchLimiter`]).
    pub limiter: Option<&'a SearchLimiter>,
}

/// Format selection mode (Table I columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FormatMode {
    /// Use the accelerator's preset native format (Table I "Fixed").
    Fixed,
    /// Run the adaptive compression engine (Table I "Search").
    Search,
}

/// Co-search configuration.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    pub metric: Metric,
    pub mode: FormatMode,
    pub engine: EngineConfig,
    pub mapper: crate::dataflow::mapper::MapperConfig,
    /// Format pairs receiving a full mapping search (the rest are scored
    /// on the winner's mapping).
    pub pairs_to_map: usize,
    /// Worker threads for the parallel co-search: operators shard across
    /// threads, and when threads exceed the operator count the proto
    /// enumeration within an operator is sharded too.  `1` (the default)
    /// runs fully serial; `0` uses all available cores.  Designs and
    /// scores are bit-identical for any value (see docs/SEARCH.md).
    pub threads: usize,
    /// Branch-and-bound pruning of the mapping search: protos whose
    /// order-independent metric lower bound
    /// ([`EvalContext::lower_bound`]) already reaches the incumbent best
    /// skip the order sweep.  Only provably-worse candidates are
    /// skipped, so designs and scores are bit-identical with pruning on
    /// or off (and at any thread count); the telemetry counters
    /// (`evaluations`, cache and prune stats) do depend on this flag and
    /// — when pruning is on — on the shard count.  Default `true`.
    pub prune: bool,
    /// Best-first proto ordering: when pruning is on, shards visit
    /// arena protos in ascending primary-metric lower bound (a
    /// precomputed [`ProtoArena::order_by`](crate::dataflow::mapper::ProtoArena::order_by)
    /// permutation) instead of ascending id, so the incumbent tightens
    /// — and branch-and-bound fires — much earlier.  The shard
    /// reduction is visit-order independent by construction
    /// (`docs/SEARCH.md` § Frontier search), so designs and scores are
    /// bit-identical with this on or off; only the prune/evaluation
    /// telemetry changes (pinned by `rust/tests/frontier.rs`).  Inert
    /// when `prune` is off.  Default `true`.
    pub best_first: bool,
    /// Cost backend every evaluation (and lower bound) dispatches
    /// through; see `docs/COST.md`.  The default analytical backend is
    /// bit-identical to the pre-backend cost model; branch-and-bound
    /// pruning remains sound under every backend, so `prune` composes
    /// freely with this selection.
    pub cost: CostModel,
    /// Quantization axis (`format::quant`): per-operand-class payload
    /// bitwidth spaces the co-search enumerates alongside compression
    /// formats.  The default (all `None`) disables the axis — every
    /// operand stays at the accelerator's `data_bits` and the search is
    /// bit-identical to the pre-quantization flow (pinned by
    /// `rust/tests/quant_axis.rs`).
    pub quant: QuantConfig,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            metric: Metric::Energy,
            mode: FormatMode::Search,
            engine: EngineConfig::default(),
            mapper: crate::dataflow::mapper::MapperConfig {
                max_candidates: 40_000,
                ..Default::default()
            },
            pairs_to_map: 2,
            threads: 1,
            prune: true,
            best_first: true,
            cost: CostModel::Analytical,
            quant: QuantConfig::default(),
        }
    }
}

/// The chosen design for one operator.
#[derive(Clone, Debug)]
pub struct OpDesign {
    pub op_name: String,
    pub input_format: Format,
    pub weight_format: Format,
    /// Payload bitwidth chosen for the input (activation) operand —
    /// the accelerator's `data_bits` when the quant axis is disabled.
    pub input_bits: u32,
    /// Payload bitwidth chosen for the weight-slot operand (the KV
    /// tensor on attention `qk`/`av` ops).
    pub weight_bits: u32,
    pub mapping: Mapping,
    pub report: CostReport,
    pub metric_value: f64,
    pub count: u64,
}

/// Per-metric winners and Pareto points of a frontier-mode search
/// (`Metric::Frontier`).  `winners[m]` holds one design per workload op
/// (op order) for scalar metric `Metric::SCALARS[m]`, each
/// bit-identical to what an independent scalar search of that metric
/// would have chosen (pinned by `rust/tests/frontier.rs`);
/// `op_points` holds each op's retained Pareto set.
#[derive(Clone, Debug, Default)]
pub struct FrontierResult {
    pub winners: [Vec<OpDesign>; 4],
    pub op_points: Vec<(String, Vec<frontier::FrontierPoint>)>,
}

impl FrontierResult {
    /// Total Pareto points retained across all ops.
    pub fn total_points(&self) -> u64 {
        self.op_points.iter().map(|(_, ps)| ps.len() as u64).sum()
    }

    /// Workload total of scalar metric `Metric::SCALARS[mi]` over that
    /// metric's winner designs, combined exactly like
    /// [`WorkloadResult::metric_total`] (EDP is the workload-level
    /// energy × cycles product, not a per-op sum).
    pub fn winner_total(&self, mi: usize) -> f64 {
        let designs = &self.winners[mi];
        let energy: f64 =
            designs.iter().map(|d| d.report.total_energy_pj() * d.count as f64).sum();
        let mem: f64 =
            designs.iter().map(|d| d.report.memory_energy_pj() * d.count as f64).sum();
        let cycles: f64 =
            designs.iter().map(|d| d.report.latency_cycles() * d.count as f64).sum();
        match mi {
            0 => energy,
            1 => mem,
            2 => cycles,
            _ => energy * cycles,
        }
    }
}

/// Aggregated result over a workload.
#[derive(Clone, Debug)]
pub struct WorkloadResult {
    pub workload: String,
    /// One chosen design per op.  In frontier mode these are the
    /// **primary-metric** (energy) winners, so every aggregate below
    /// keeps its meaning; the other metrics' winners are in
    /// [`Self::frontier`].
    pub designs: Vec<OpDesign>,
    pub elapsed: Duration,
    /// Cost-model evaluations performed (the exploration-effort metric;
    /// cache hits included).  With pruning disabled the count is thread-
    /// and cache-invariant; with pruning on it depends on the shard
    /// count (each shard prunes against its own incumbent), while the
    /// designs and scores stay bit-identical either way.
    pub evaluations: u64,
    /// Aggregated `access_counts` cache hit/miss counters.
    pub cache: CacheStats,
    /// Legal protos considered across all ops and format pairs.
    pub protos: u64,
    /// Protos skipped by the branch-and-bound lower bound.
    pub pruned: u64,
    /// Per-scalar-metric prune counts (see
    /// [`SearchTelemetry::pruned_by_metric`]).
    pub pruned_by_metric: [u64; 4],
    /// Prunes enabled only by cross-shard incumbent sharing (see
    /// [`SearchTelemetry::bound_tightenings`]).
    pub bound_tightenings: u64,
    /// Pareto points retained (frontier mode; 0 otherwise).
    pub frontier_size: u64,
    /// Frontier-mode payload: per-metric winners + Pareto points.
    /// `None` for scalar searches.
    pub frontier: Option<FrontierResult>,
}

impl WorkloadResult {
    /// Total energy over all op instances (pJ).
    pub fn total_energy_pj(&self) -> f64 {
        self.designs
            .iter()
            .map(|d| d.report.total_energy_pj() * d.count as f64)
            .sum()
    }

    /// Total memory energy over all op instances (pJ) — the Fig. 10 metric.
    pub fn memory_energy_pj(&self) -> f64 {
        self.designs
            .iter()
            .map(|d| d.report.memory_energy_pj() * d.count as f64)
            .sum()
    }

    /// Total latency in cycles (ops execute sequentially).
    pub fn total_cycles(&self) -> f64 {
        self.designs
            .iter()
            .map(|d| d.report.latency_cycles() * d.count as f64)
            .sum()
    }

    /// Total EDP.
    pub fn edp(&self) -> f64 {
        self.total_energy_pj() * self.total_cycles()
    }

    /// Fraction of considered protos the lower bound pruned (0.0 when
    /// none were enumerated) — the CLI `enumeration:` line and
    /// `perf_probe` report this.
    pub fn prune_rate(&self) -> f64 {
        if self.protos == 0 {
            0.0
        } else {
            self.pruned as f64 / self.protos as f64
        }
    }

    pub fn metric_total(&self, metric: Metric) -> f64 {
        match metric {
            Metric::Energy => self.total_energy_pj(),
            Metric::MemoryEnergy => self.memory_energy_pj(),
            Metric::Latency => self.total_cycles(),
            Metric::Edp => self.edp(),
            // Frontier designs are the primary-metric (energy) winners.
            Metric::Frontier => self.total_energy_pj(),
        }
    }
}

/// Convenience: run the co-search with the accelerator's native format
/// (Fixed mode) — used by benches and the Sparseloop comparison.
pub fn fixed_format_config(arch: &Accelerator) -> SearchConfig {
    let _ = arch;
    SearchConfig { mode: FormatMode::Fixed, ..Default::default() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn limiter_proto_cap_is_exact_and_latches() {
        let l = SearchLimiter::new(None, Some(3));
        assert!(l.admit_proto());
        assert!(l.admit_proto());
        assert!(l.admit_proto());
        assert!(!l.exhausted());
        assert!(!l.admit_proto());
        assert!(l.exhausted());
        assert!(!l.admit_proto(), "exhaustion must latch");
        assert_eq!(l.admitted(), 3);
    }

    #[test]
    fn limiter_zero_wall_time_denies_immediately() {
        let l = SearchLimiter::new(Some(Duration::ZERO), None);
        assert!(!l.admit_proto());
        assert!(l.exhausted());
        assert_eq!(l.admitted(), 0);
    }

    #[test]
    fn unlimited_limiter_never_fires() {
        let l = SearchLimiter::new(None, None);
        for _ in 0..1000 {
            assert!(l.admit_proto());
        }
        assert!(!l.exhausted());
        assert_eq!(l.admitted(), 1000);
    }
}
