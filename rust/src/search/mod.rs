//! The Progressive Co-Search Workflow (paper §III-D, Fig. 7).
//!
//! Per operator, the workflow interleaves dataflow and format search:
//!
//! 1. **Upfront estimation of computation reduction** (§III-D1): the
//!    reduction strategy's cycle/energy fractions are modeled *before*
//!    dataflow generation (inside every evaluation — never as a post-hoc
//!    correction pass).
//! 2. **Format generation**: the adaptive compression engine proposes
//!    top-k format pairs for (I, W), steered by tile hints from a quick
//!    dense probe mapping (efficiency-oriented allocation, §III-C2).
//! 3. **Compression-aware loop allocation** (§III-D2): tiling protos are
//!    legality-filtered against the *compressed* operand footprints
//!    before loop-order assignment — illegal dataflows are never
//!    generated, so no repair iterations are needed.
//! 4. **Greedy loop ordering**: per memory level (outermost first), pick
//!    the order minimizing the optimization metric given outer choices —
//!    boundary-`b` traffic is independent of deeper levels' orders, so
//!    the greedy pass is locally exact per boundary.
//!
//! # Parallel execution and memoized evaluation
//!
//! The per-op searches are independent, so [`cosearch_workload`] shards
//! operators across a scoped worker pool ([`crate::util::pool`]); when
//! [`SearchConfig::threads`] exceeds the operator count, the
//! [`for_each_proto`](crate::dataflow::mapper::for_each_proto)
//! enumeration *within* an op is sharded too.  Partial bests are merged
//! by a total order on `(metric value, proto id)`, which makes results
//! **bit-identical** to the serial path for any thread count — the
//! contract, and why it holds, is documented in `docs/SEARCH.md`.
//! Every worker owns a private [`EvalContext`](crate::cost::EvalContext)
//! that memoizes `access_counts` per (tiling, order) proto across
//! candidate format/ratio pairs; aggregated
//! [`CacheStats`](crate::cost::CacheStats) land in
//! [`WorkloadResult::cache`].
//!
//! Contrast with the Sparseloop-style stepwise workflow in
//! [`crate::baselines::sparseloop_like`].

pub mod progressive;

use crate::arch::Accelerator;
use crate::cost::{CacheStats, CostReport, EvalContext, Metric};
use crate::dataflow::Mapping;
use crate::engine::EngineConfig;
use crate::format::Format;
use std::time::Duration;

pub use progressive::{
    cosearch_op, cosearch_workload, evaluate_with_formats, probe_tile_hints,
};

/// Per-search telemetry: logical cost-model evaluations plus the
/// hit/miss counters of the memoized `access_counts` cache.  Hits still
/// count as evaluations (the exploration-effort metric is unchanged by
/// caching); the cache counters measure how much recomputation the
/// memoization removed.
#[derive(Clone, Copy, Debug, Default)]
pub struct SearchTelemetry {
    pub evaluations: u64,
    pub cache: CacheStats,
}

impl SearchTelemetry {
    /// Fold one worker's evaluation context into this telemetry.
    pub fn absorb(&mut self, ctx: &EvalContext<'_>) {
        self.evaluations += ctx.evals();
        self.cache.merge(ctx.cache_stats());
    }

    pub fn merge(&mut self, other: SearchTelemetry) {
        self.evaluations += other.evaluations;
        self.cache.merge(other.cache);
    }
}

/// Format selection mode (Table I columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FormatMode {
    /// Use the accelerator's preset native format (Table I "Fixed").
    Fixed,
    /// Run the adaptive compression engine (Table I "Search").
    Search,
}

/// Co-search configuration.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    pub metric: Metric,
    pub mode: FormatMode,
    pub engine: EngineConfig,
    pub mapper: crate::dataflow::mapper::MapperConfig,
    /// Format pairs receiving a full mapping search (the rest are scored
    /// on the winner's mapping).
    pub pairs_to_map: usize,
    /// Worker threads for the parallel co-search: operators shard across
    /// threads, and when threads exceed the operator count the proto
    /// enumeration within an operator is sharded too.  `1` (the default)
    /// runs fully serial; `0` uses all available cores.  Results are
    /// bit-identical for any value (see docs/SEARCH.md).
    pub threads: usize,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            metric: Metric::Energy,
            mode: FormatMode::Search,
            engine: EngineConfig::default(),
            mapper: crate::dataflow::mapper::MapperConfig {
                max_candidates: 40_000,
                ..Default::default()
            },
            pairs_to_map: 2,
            threads: 1,
        }
    }
}

/// The chosen design for one operator.
#[derive(Clone, Debug)]
pub struct OpDesign {
    pub op_name: String,
    pub input_format: Format,
    pub weight_format: Format,
    pub mapping: Mapping,
    pub report: CostReport,
    pub metric_value: f64,
    pub count: u64,
}

/// Aggregated result over a workload.
#[derive(Clone, Debug)]
pub struct WorkloadResult {
    pub workload: String,
    pub designs: Vec<OpDesign>,
    pub elapsed: Duration,
    /// Cost-model evaluations performed (the exploration-effort metric;
    /// cache hits included, so the count is thread- and cache-invariant).
    pub evaluations: u64,
    /// Aggregated `access_counts` cache hit/miss counters.
    pub cache: CacheStats,
}

impl WorkloadResult {
    /// Total energy over all op instances (pJ).
    pub fn total_energy_pj(&self) -> f64 {
        self.designs
            .iter()
            .map(|d| d.report.total_energy_pj() * d.count as f64)
            .sum()
    }

    /// Total memory energy over all op instances (pJ) — the Fig. 10 metric.
    pub fn memory_energy_pj(&self) -> f64 {
        self.designs
            .iter()
            .map(|d| d.report.memory_energy_pj() * d.count as f64)
            .sum()
    }

    /// Total latency in cycles (ops execute sequentially).
    pub fn total_cycles(&self) -> f64 {
        self.designs
            .iter()
            .map(|d| d.report.latency_cycles() * d.count as f64)
            .sum()
    }

    /// Total EDP.
    pub fn edp(&self) -> f64 {
        self.total_energy_pj() * self.total_cycles()
    }

    pub fn metric_total(&self, metric: Metric) -> f64 {
        match metric {
            Metric::Energy => self.total_energy_pj(),
            Metric::MemoryEnergy => self.memory_energy_pj(),
            Metric::Latency => self.total_cycles(),
            Metric::Edp => self.edp(),
        }
    }
}

/// Convenience: run the co-search with the accelerator's native format
/// (Fixed mode) — used by benches and the Sparseloop comparison.
pub fn fixed_format_config(arch: &Accelerator) -> SearchConfig {
    let _ = arch;
    SearchConfig { mode: FormatMode::Fixed, ..Default::default() }
}
