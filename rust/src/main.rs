//! `snipsnap` — CLI launcher for the SnipSnap co-optimization framework.
//!
//! Subcommands:
//!   search    co-optimize format + dataflow for a workload on an arch
//!             (emits a replayable JSON run-config snapshot per run)
//!   serve     long-running co-search service: JSONL requests on stdin,
//!             deterministic JSONL responses on stdout, per-request
//!             budgets, persistent cross-run memo store
//!   sweep     expand a [[sweep.axis]] plan and run every config through
//!             serve --once worker processes, merged in plan order
//!   report    roll up the results/ run artifacts into a summary table
//!   formats   show the adaptive engine's top formats for one tensor
//!   validate  run the Fig. 8 / Fig. 9 model-validation studies
//!   xla       self-test the PJRT runtime against the AOT artifacts
//!   list      list available arch / workload presets

use anyhow::{bail, Context, Result};
use snipsnap::config::typed::{
    arch_by_name, metric_by_name, parse_nm, preset_quant, resolve_workload,
    validate_quant_bits, WorkloadOpts,
};
use snipsnap::engine::{search_formats, EngineConfig};
use snipsnap::format::quant::BitwidthSpace;
use snipsnap::search::{FormatMode, SearchConfig};
use snipsnap::sparsity::SparsityPattern;
use snipsnap::util::table::{fmt_f, fmt_pct, Table};

fn usage() -> ! {
    eprintln!(
        "snipsnap — joint compression-format & dataflow co-optimization\n\
         \n\
         USAGE:\n\
           snipsnap search   [--config F.toml|F.config.json] [--arch A] [--workload W]\n\
                             [--metric M] [--mode search|fixed] [--max-mappings N]\n\
                             [--threads N]  (0 = all cores; designs are\n\
                             bit-identical for any thread count)\n\
                             [--prune on|off]  (branch-and-bound pruning;\n\
                             identical results either way, default on)\n\
                             [--best-first on|off]  (visit protos in\n\
                             ascending lower-bound order; identical\n\
                             results either way, default on)\n\
                             --metric frontier searches all four metrics\n\
                             in one arena pass and prints the Pareto\n\
                             frontier plus per-metric winners\n\
                             [--cost-backend analytical|contention]  (memory\n\
                             model, docs/COST.md; default analytical — tune\n\
                             contention knobs via the [cost] config section)\n\
                             [--snapshot PATH|off]  (JSON run-config snapshot;\n\
                             default results/run-<ts>-<pid>.config.json —\n\
                             feed it back via --config to replay the run)\n\
                             [--w-bits B] [--a-bits B] [--kv-bits B]  (payload\n\
                             bitwidths per operand class: a fixed width like\n\
                             '8' or a search set like '4,8,16'; default =\n\
                             arch data_bits, i.e. quantization disabled)\n\
                             workload modifiers (transformer presets only):\n\
                             [--prefill N] [--decode N] [--batch B]\n\
                             [--kv-density D] [--nm N:M]\n\
           snipsnap serve    [--once] [--jobs N] [--memo PATH|off]\n\
                             [--memo-max-entries N] [--results DIR|off]\n\
                             long-running co-search service: one JSON\n\
                             request per stdin line (the run-config\n\
                             snapshot format, plus optional \"id\" and\n\
                             \"budget\" fields), one deterministic JSON\n\
                             response per stdout line, stats on stderr.\n\
                             --once serves a single request then exits;\n\
                             --memo is the persistent cross-run counts\n\
                             store (default results/serve_memo.jsonl);\n\
                             --memo-max-entries caps the store (enforced\n\
                             at flush, deterministic eviction order);\n\
                             --results is where per-request records land\n\
                             for `snipsnap report` (default results)\n\
           snipsnap sweep    --plan F.toml [--workers N] [--out DIR]\n\
                             expand the plan's [[sweep.axis]] cross-\n\
                             product and run every config through\n\
                             `serve --once` worker processes (docs/\n\
                             SWEEP.md).  Responses merge in plan order\n\
                             to <out>/<name>.sweep.jsonl — byte-\n\
                             identical at any --workers count — and\n\
                             roll up via `snipsnap report`\n\
           snipsnap report   [--dir results]  (summarize results/*.json(l);\n\
                             exits non-zero on any unparseable artifact)\n\
           snipsnap formats  --rows R --cols C --density D [--gamma G] [--depth N]\n\
           snipsnap validate [--study scnn|dstc]\n\
           snipsnap xla      [--artifacts DIR]\n\
           snipsnap list\n"
    );
    std::process::exit(2);
}

/// Per-subcommand flag allowlist: the value-taking `--flags` and the
/// bare `switches` a subcommand accepts.  Anything else is a usage
/// error — a typo like `--thread 4` must fail loudly, not silently run
/// single-threaded.
struct FlagSpec {
    flags: &'static [&'static str],
    switches: &'static [&'static str],
}

const SEARCH_SPEC: FlagSpec = FlagSpec {
    flags: &[
        "config",
        "arch",
        "workload",
        "metric",
        "mode",
        "max-mappings",
        "threads",
        "prune",
        "best-first",
        "cost-backend",
        "snapshot",
        "w-bits",
        "a-bits",
        "kv-bits",
        "prefill",
        "decode",
        "batch",
        "kv-density",
        "nm",
    ],
    switches: &[],
};
const SERVE_SPEC: FlagSpec = FlagSpec {
    flags: &["jobs", "memo", "memo-max-entries", "results"],
    switches: &["once"],
};
const SWEEP_SPEC: FlagSpec = FlagSpec { flags: &["plan", "workers", "out"], switches: &[] };
const REPORT_SPEC: FlagSpec = FlagSpec { flags: &["dir"], switches: &[] };
const FORMATS_SPEC: FlagSpec =
    FlagSpec { flags: &["rows", "cols", "density", "gamma", "depth"], switches: &[] };
const VALIDATE_SPEC: FlagSpec = FlagSpec { flags: &["study"], switches: &[] };
const XLA_SPEC: FlagSpec = FlagSpec { flags: &["artifacts"], switches: &[] };
const LIST_SPEC: FlagSpec = FlagSpec { flags: &[], switches: &[] };

/// Tiny argv parser: `--key value` pairs after the subcommand, plus the
/// subcommand's bare switches, both checked against its [`FlagSpec`].
struct Args {
    flags: std::collections::HashMap<String, String>,
    switches: std::collections::HashSet<String>,
}

impl Args {
    fn parse(argv: &[String], cmd: &str, spec: &FlagSpec) -> Result<Args> {
        let mut flags = std::collections::HashMap::new();
        let mut switches = std::collections::HashSet::new();
        let mut i = 0;
        while i < argv.len() {
            let k = &argv[i];
            if !k.starts_with("--") {
                bail!("unexpected argument '{k}'");
            }
            let key = k.trim_start_matches("--").to_string();
            if spec.switches.contains(&key.as_str()) {
                switches.insert(key);
                i += 1;
                continue;
            }
            if !spec.flags.contains(&key.as_str()) {
                bail!("unknown flag '--{key}' for 'snipsnap {cmd}'");
            }
            let val = argv
                .get(i + 1)
                .with_context(|| format!("--{key} needs a value"))?
                .clone();
            flags.insert(key, val);
            i += 2;
        }
        Ok(Args { flags, switches })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.switches.contains(key)
    }

    fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{key}")))
            .transpose()
    }

    fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.get(key)
            .map(|v| v.parse::<u64>().with_context(|| format!("--{key}")))
            .transpose()
    }
}

/// Resolve the `snipsnap search` flags into a full run config — either
/// replaying a `--config` file or composing preset + modifier flags.
/// Pure flag resolution: the run itself is one `driver::run` call in
/// [`cmd_search`].
fn resolve_search_config(args: &Args) -> Result<snipsnap::config::RunConfig> {
    let mut cfg;
    let arch;
    let workload;
    if let Some(path) = args.get("config") {
        for flag in ["prefill", "decode", "batch", "kv-density", "nm"] {
            if args.get(flag).is_some() {
                bail!(
                    "--{flag} cannot be combined with --config; \
                     set it in the config's [workload] section instead"
                );
            }
        }
        let src = std::fs::read_to_string(path).with_context(|| path.to_string())?;
        // TOML subset or a JSON run-config snapshot from a previous run.
        let run = snipsnap::config::load_run_config_any(&src)?;
        arch = run.arch;
        workload = run.workload;
        cfg = run.search;
    } else {
        arch = arch_by_name(args.get("arch").unwrap_or("arch3"))?;
        let opts = WorkloadOpts {
            prefill_tokens: args.get_u64("prefill")?,
            decode_tokens: args.get_u64("decode")?,
            batch: args.get_u64("batch")?,
            kv_density: args.get_f64("kv-density")?,
            nm: args.get("nm").map(parse_nm).transpose()?,
        };
        let preset = args.get("workload").unwrap_or("opt-125m");
        workload = resolve_workload(preset, &opts)?;
        cfg = SearchConfig::default();
        // Quantized presets bundle a quant axis; --*-bits flags below
        // override per operand class.
        if let Some(q) = preset_quant(preset) {
            cfg.quant = q;
        }
    }
    if let Some(m) = args.get("metric") {
        cfg.metric = metric_by_name(m)?;
    }
    if let Some(mode) = args.get("mode") {
        cfg.mode = match mode {
            "search" => FormatMode::Search,
            "fixed" => FormatMode::Fixed,
            other => bail!("unknown mode '{other}'"),
        };
    }
    if let Some(n) = args.get_u64("max-mappings")? {
        cfg.mapper.max_candidates = n as usize;
    }
    if let Some(t) = args.get_u64("threads")? {
        cfg.threads = t as usize;
    }
    if let Some(p) = args.get("prune") {
        cfg.prune = match p {
            "on" | "true" | "1" => true,
            "off" | "false" | "0" => false,
            other => bail!("--prune takes on|off, got '{other}'"),
        };
    }
    if let Some(b) = args.get("best-first") {
        cfg.best_first = match b {
            "on" | "true" | "1" => true,
            "off" | "false" | "0" => false,
            other => bail!("--best-first takes on|off, got '{other}'"),
        };
    }
    if let Some(b) = args.get("cost-backend") {
        use snipsnap::cost::CostModel;
        match CostModel::by_name(b) {
            // Keep a config-supplied contention tuning when the flag
            // merely re-selects the same backend; the flag's job is
            // backend selection, not knob reset.
            Ok(CostModel::Contention(_)) if matches!(cfg.cost, CostModel::Contention(_)) => {}
            Ok(m) => cfg.cost = m,
            Err(e) => {
                eprintln!("error: {e}");
                usage();
            }
        }
    }
    // Quant-axis flags: like --cost-backend they compose with --config
    // (a flag overrides that operand class; other classes keep the
    // config's spaces).  Bogus values are usage errors.
    let parse_bits = |key: &str| -> Option<BitwidthSpace> {
        args.get(key).map(|v| match BitwidthSpace::parse(v) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("error: --{key}: {e}");
                usage();
            }
        })
    };
    if let Some(s) = parse_bits("w-bits") {
        cfg.quant.w_bits = Some(s);
    }
    if let Some(s) = parse_bits("a-bits") {
        cfg.quant.a_bits = Some(s);
    }
    if let Some(s) = parse_bits("kv-bits") {
        cfg.quant.kv_bits = Some(s);
    }
    if let Err(e) = validate_quant_bits(&cfg.quant, arch.data_bits) {
        eprintln!("error: {e}");
        usage();
    }
    Ok(snipsnap::config::RunConfig { arch, workload, search: cfg })
}

/// `snipsnap search` — flag parsing plus one [`driver::run`] call.  The
/// whole pipeline (snapshot emission, banners, the human report) lives
/// in `snipsnap::driver`; `--snapshot off` disables the artifact,
/// `--snapshot PATH` redirects it, the default lands next to the bench
/// results with a timestamped name.
fn cmd_search(args: &Args) -> Result<()> {
    use snipsnap::driver::{self, RunPlan, RunSinks, SnapshotSink};

    let plan = RunPlan::new(resolve_search_config(args)?);
    let snapshot = match args.get("snapshot") {
        Some("off") => SnapshotSink::Off,
        Some(p) => SnapshotSink::Path(std::path::PathBuf::from(p)),
        None => SnapshotSink::Default,
    };
    let mut sinks = RunSinks {
        snapshot,
        out: &mut std::io::stdout(),
        log: &mut std::io::stderr(),
    };
    driver::run(&plan, snipsnap::search::SearchHooks::default(), &mut sinks)?;
    Ok(())
}

/// `snipsnap sweep` — expand a plan's axis cross-product and run every
/// config through `serve --once` worker processes
/// (`snipsnap::driver::sweep`).  Exits non-zero when any config failed.
fn cmd_sweep(args: &Args) -> Result<()> {
    use snipsnap::driver::sweep::{run_sweep, SweepOpts};

    let opts = SweepOpts {
        plan_path: std::path::PathBuf::from(args.get("plan").context("--plan required")?),
        workers: args.get_u64("workers")?.unwrap_or(1).max(1) as usize,
        out_dir: std::path::PathBuf::from(args.get("out").unwrap_or("results")),
    };
    let summary = run_sweep(&opts, &mut std::io::stderr())?;
    if summary.failed > 0 {
        bail!("{} of {} sweep configs failed", summary.failed, summary.configs);
    }
    Ok(())
}

/// `snipsnap serve` — the long-running co-search service
/// (`snipsnap::serve`).  Wires stdin/stdout/stderr into `serve_loop`
/// and resolves the store/results destinations from the flags.
fn cmd_serve(args: &Args) -> Result<()> {
    use snipsnap::serve::{serve_loop, ServeOpts};

    let opts = ServeOpts {
        once: args.has("once"),
        jobs: args.get_u64("jobs")?.unwrap_or(1).max(1) as usize,
        results_dir: match args.get("results") {
            Some("off") => None,
            Some(dir) => Some(std::path::PathBuf::from(dir)),
            None => Some(std::path::PathBuf::from("results")),
        },
    };
    let mut store = match args.get("memo") {
        Some("off") => None,
        Some(path) => Some(snipsnap::serve::memo::MemoStore::open(std::path::Path::new(path))?),
        None => Some(snipsnap::serve::memo::MemoStore::open(std::path::Path::new(
            "results/serve_memo.jsonl",
        ))?),
    };
    if let Some(cap) = args.get_u64("memo-max-entries")? {
        if cap == 0 {
            bail!("--memo-max-entries must be >= 1");
        }
        match &mut store {
            Some(s) => s.set_max_entries(Some(cap as usize)),
            None => bail!("--memo-max-entries requires a memo store (remove --memo off)"),
        }
    }
    eprintln!(
        "snipsnap serve: {} jobs, memo {} ({} entries), {}",
        opts.jobs,
        if store.is_some() { "on" } else { "off" },
        store.as_ref().map(|s| s.len()).unwrap_or(0),
        if opts.once { "single request (--once)" } else { "reading requests from stdin" },
    );
    let stdin = std::io::stdin();
    let summary = serve_loop(
        &opts,
        store.as_ref(),
        stdin.lock(),
        &mut std::io::stdout(),
        &mut std::io::stderr(),
    )?;
    eprintln!("snipsnap serve: {} requests served, {} failed", summary.requests, summary.failed);
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.get("dir").unwrap_or("results"));
    print!("{}", snipsnap::report::report(&dir)?);
    Ok(())
}

fn cmd_formats(args: &Args) -> Result<()> {
    let rows = args.get_u64("rows")?.context("--rows required")?;
    let cols = args.get_u64("cols")?.context("--cols required")?;
    let density = args.get_f64("density")?.context("--density required")?;
    let mut cfg = EngineConfig::default();
    if let Some(g) = args.get_f64("gamma")? {
        cfg.gamma = g;
    }
    if let Some(d) = args.get_u64("depth")? {
        cfg.space.max_depth = d as usize;
    }
    let pattern = SparsityPattern::Unstructured { density };
    let (top, stats) = search_formats(rows, cols, &pattern, None, &cfg);
    let full = snipsnap::format::space::full_space_size(rows, cols, &cfg.space);
    let mut t = Table::new(vec!["format", "total bits", "ratio", "metadata", "payload"])
        .with_title(format!(
            "Top formats for {rows}x{cols} @ density {density} (space {full} -> evaluated {})",
            stats.evaluated
        ));
    for s in &top {
        t.add_row(vec![
            s.format.to_string(),
            fmt_f(s.cost.total_bits()),
            fmt_pct(s.cost.ratio()),
            fmt_f(s.cost.metadata_bits),
            fmt_f(s.cost.payload_bits),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let study = args.get("study").unwrap_or("scnn");
    match study {
        "scnn" => {
            let (mre, rows) = snipsnap::arch::validation::scnn_energy_validation();
            let mut t = Table::new(vec!["layer", "case", "reported", "modeled", "rel err"])
                .with_title("Fig. 8 — SCNN energy validation");
            for r in rows {
                t.add_row(vec![
                    r.layer.to_string(),
                    r.case.to_string(),
                    fmt_f(r.reported),
                    fmt_f(r.modeled),
                    fmt_pct(r.rel_err),
                ]);
            }
            println!("{}", t.render());
            println!("mean relative error: {}", fmt_pct(mre));
        }
        "dstc" => {
            let (mre, rows) = snipsnap::arch::validation::dstc_latency_validation();
            let mut t = Table::new(vec!["density", "reported", "modeled", "rel err"])
                .with_title("Fig. 9 — DSTC latency validation (4096x4096 MatMul)");
            for r in rows {
                t.add_row(vec![
                    format!("{:.2}", r.density),
                    fmt_f(r.reported),
                    fmt_f(r.modeled),
                    fmt_pct(r.rel_err),
                ]);
            }
            println!("{}", t.render());
            println!("mean relative error: {}", fmt_pct(mre));
        }
        other => bail!("unknown study '{other}' (scnn|dstc)"),
    }
    Ok(())
}

fn cmd_xla(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(snipsnap::runtime::Runtime::default_dir);
    let mut rt = snipsnap::runtime::Runtime::load(&dir)?;
    println!("artifacts: {}", rt.dir().display());
    for a in rt.manifest.artifacts.clone() {
        print!("  {} ... ", a.name);
        // Execute with zero inputs of the right shapes.
        let fbufs: Vec<Vec<f32>> = a.inputs.iter().map(|s| vec![0.0; s.elements()]).collect();
        let ibufs: Vec<Vec<i32>> = a.inputs.iter().map(|s| vec![0; s.elements()]).collect();
        let inputs: Vec<snipsnap::runtime::InputBuf> = a
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                if s.dtype == "i32" {
                    snipsnap::runtime::InputBuf::I32(&ibufs[i])
                } else {
                    snipsnap::runtime::InputBuf::F32(&fbufs[i])
                }
            })
            .collect();
        let outs = rt.exec(&a.name, &inputs)?;
        println!("ok ({} outputs)", outs.len());
    }
    println!("xla runtime self-test passed");
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("arch presets:    arch1 arch2 arch3 arch4 scnn dstc");
    println!("workload presets:");
    println!(
        "  MHA transformers:  llama2-7b llama2-13b opt-125m opt-6.7b opt-13b opt-30b bert-base"
    );
    println!("  GQA attention:     llama3-8b llama3-70b mistral-7b gqa-tiny");
    println!("  MoE (routed FFN):  mixtral-8x7b moe-tiny");
    println!("  batched decode:    llama2-7b-batch8 decode-tiny");
    println!("  N:M weights:       llama2-7b-nm24 (or any transformer preset + --nm N:M)");
    println!(
        "  quantized:         llama2-7b-w4a8 llama2-7b-qsearch \
         (or any preset + --w-bits/--a-bits/--kv-bits)"
    );
    println!("  CNN (im2col):      alexnet vgg-16 resnet-18");
    println!(
        "workload modifiers (transformer presets): --prefill N --decode N --batch B \
         --kv-density D --nm N:M"
    );
    println!("metrics:         energy memory-energy latency edp frontier");
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    let spec = match cmd.as_str() {
        "search" => &SEARCH_SPEC,
        "serve" => &SERVE_SPEC,
        "sweep" => &SWEEP_SPEC,
        "report" => &REPORT_SPEC,
        "formats" => &FORMATS_SPEC,
        "validate" => &VALIDATE_SPEC,
        "xla" => &XLA_SPEC,
        "list" => &LIST_SPEC,
        _ => {
            eprintln!("unknown subcommand '{cmd}'");
            usage();
        }
    };
    let args = match Args::parse(&argv[1..], cmd, spec) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            usage();
        }
    };
    let result = match cmd.as_str() {
        "search" => cmd_search(&args),
        "serve" => cmd_serve(&args),
        "sweep" => cmd_sweep(&args),
        "report" => cmd_report(&args),
        "formats" => cmd_formats(&args),
        "validate" => cmd_validate(&args),
        "xla" => cmd_xla(&args),
        "list" => cmd_list(),
        _ => unreachable!("spec resolution rejects unknown subcommands"),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
