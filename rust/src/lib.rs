//! # SnipSnap
//!
//! A joint compression-format and dataflow co-optimization framework for
//! efficient sparse LLM accelerator design — reproduction of Wu, Fang &
//! Wang (ASP-DAC 2026) as a three-layer Rust + JAX + Pallas stack.
//!
//! - [`format`] — hierarchical compression-format encoding (§III-B)
//! - [`sparsity`] — sparsity patterns, the Sparsity Analyzer and the
//!   computation-reduction model (§III-A, §II-B2)
//! - [`dataflow`] — loop tiling / ordering / spatial mapping (§II-B1)
//! - [`cost`] — energy/latency/EDP cost model over memory hierarchies
//! - [`arch`] — hardware configurations (Table II, SCNN, DSTC)
//! - [`workload`] — LLM and CNN workload zoo (§IV-A2)
//! - [`engine`] — the adaptive compression engine (§III-C)
//! - [`search`] — the progressive co-search workflow (§III-D)
//! - [`baselines`] — Sparseloop-like and DiMO-like comparison workflows
//! - [`runtime`] — PJRT loader/executor for the AOT XLA artifacts
//! - [`util`] — offline substrates (PRNG, JSON, tables, property tests)

pub mod arch;
pub mod baselines;
pub mod config;
pub mod cost;
pub mod dataflow;
pub mod engine;
pub mod format;
pub mod runtime;
pub mod search;
pub mod sparsity;
pub mod util;
pub mod workload;
