//! # SnipSnap
//!
//! A joint compression-format and dataflow co-optimization framework for
//! efficient sparse LLM accelerator design — reproduction of Wu, Fang &
//! Wang (ASP-DAC 2026) as a three-layer Rust + JAX + Pallas stack.
//!
//! - [`format`] — hierarchical compression-format encoding (§III-B)
//! - [`sparsity`] — sparsity patterns, the Sparsity Analyzer and the
//!   computation-reduction model (§III-A, §II-B2)
//! - [`dataflow`] — loop tiling / ordering / spatial mapping (§II-B1)
//! - [`cost`] — energy/latency/EDP cost model over memory hierarchies
//! - [`arch`] — hardware configurations (Table II, SCNN, DSTC)
//! - [`workload`] — LLM and CNN workload zoo (§IV-A2)
//! - [`engine`] — the adaptive compression engine (§III-C)
//! - [`search`] — the progressive co-search workflow (§III-D)
//! - [`baselines`] — Sparseloop-like and DiMO-like comparison workflows
//! - [`runtime`] — PJRT loader/executor for the AOT XLA artifacts
//! - [`config`] — TOML-subset run configs, JSON run-config snapshots,
//!   and sweep plans
//! - [`driver`] — the reusable run pipeline behind `snipsnap search`
//!   and `serve`, plus the multi-process sweep coordinator
//! - [`serve`] — the long-running co-search service (JSONL requests,
//!   per-request budgets, persistent cross-run memo store)
//! - [`report`] — roll-up over the `results/` run artifacts
//! - [`util`] — offline substrates (PRNG, JSON, tables, property tests)
//!
//! # Cargo features
//!
//! - `pjrt` (off by default): enables the XLA/PJRT executor in
//!   [`runtime`].  Requires the external `xla` bindings crate and a local
//!   xla_extension install; the default build substitutes a stub
//!   executor so the rest of the crate (including the pure-Rust
//!   analyzers) builds with `anyhow` as the only dependency.

pub mod arch;
pub mod baselines;
pub mod config;
pub mod cost;
pub mod dataflow;
pub mod driver;
pub mod engine;
pub mod format;
pub mod report;
pub mod runtime;
pub mod search;
pub mod serve;
pub mod sparsity;
pub mod util;
pub mod workload;
