//! Dataflow modeling (paper §II-B1): loop tiling, loop ordering, spatial
//! mapping and memory-level allocation for the MatMul
//! `O[M][K] = Σ_N I[M][N] × W[N][K]` (the paper's convention: N is the
//! reduction dimension).
//!
//! A [`Mapping`] assigns per-memory-level tiling factors and loop orders
//! plus a spatial unrolling at the MAC array.  [`access_counts`] computes
//! the per-level, per-operand fill traffic under exact single-tile-buffer
//! reuse semantics: a tile is reloaded whenever a *relevant* outer loop
//! increments, and irrelevant loops cause revisits unless they are
//! strictly inside the innermost relevant loop (the classic
//! trailing-irrelevant reuse rule, validated against a brute-force nest
//! simulator in `rust/tests/properties.rs`).

pub mod mapper;
pub mod nest;

use crate::util::inline::InlineVec;
use std::fmt;

/// Hard cap on mapping/memory levels.  Real hierarchies have 2–4; the
/// cap lets [`AccessCounts`] (and `cost::CostReport`) keep their
/// per-level rows in fixed inline storage, making the per-proto
/// evaluation path allocation-free.
pub const MAX_LEVELS: usize = 8;

/// MatMul problem dims: `O[M][K] = Σ_N I[M][N] × W[N][K]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ProblemDims {
    pub m: u64,
    pub n: u64,
    pub k: u64,
}

impl ProblemDims {
    pub fn new(m: u64, n: u64, k: u64) -> Self {
        ProblemDims { m, n, k }
    }

    pub fn macs(&self) -> u64 {
        self.m * self.n * self.k
    }

    pub fn get(&self, d: LoopDim) -> u64 {
        match d {
            LoopDim::M => self.m,
            LoopDim::N => self.n,
            LoopDim::K => self.k,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LoopDim {
    M,
    N,
    K,
}

impl LoopDim {
    pub const ALL: [LoopDim; 3] = [LoopDim::M, LoopDim::N, LoopDim::K];
}

impl fmt::Display for LoopDim {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LoopDim::M => write!(f, "M"),
            LoopDim::N => write!(f, "N"),
            LoopDim::K => write!(f, "K"),
        }
    }
}

/// The three MatMul operands.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Operand {
    /// Input activations `I[M][N]`.
    I,
    /// Weights `W[N][K]`.
    W,
    /// Outputs / partial sums `O[M][K]`.
    O,
}

impl Operand {
    pub const ALL: [Operand; 3] = [Operand::I, Operand::W, Operand::O];

    /// Dims that index this operand.
    pub fn relevant(&self, d: LoopDim) -> bool {
        match (self, d) {
            (Operand::I, LoopDim::M) | (Operand::I, LoopDim::N) => true,
            (Operand::W, LoopDim::N) | (Operand::W, LoopDim::K) => true,
            (Operand::O, LoopDim::M) | (Operand::O, LoopDim::K) => true,
            _ => false,
        }
    }

    /// Footprint (elements) of this operand for a tile of the given dims.
    pub fn footprint(&self, m: u64, n: u64, k: u64) -> u64 {
        match self {
            Operand::I => m * n,
            Operand::W => n * k,
            Operand::O => m * k,
        }
    }
}

/// Per-memory-level temporal tiling: the factor by which each dim is
/// split at this level, plus the loop order (outermost first).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TileLevel {
    pub factors: [u64; 3], // indexed by LoopDim order M, N, K
    pub order: [LoopDim; 3],
}

impl TileLevel {
    pub fn factor(&self, d: LoopDim) -> u64 {
        match d {
            LoopDim::M => self.factors[0],
            LoopDim::N => self.factors[1],
            LoopDim::K => self.factors[2],
        }
    }
}

/// Spatial unrolling over the MAC array: dims mapped to the two array
/// axes with their unroll factors.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Spatial {
    pub dim_rows: LoopDim,
    pub unroll_rows: u64,
    pub dim_cols: LoopDim,
    pub unroll_cols: u64,
}

impl Spatial {
    pub fn factor(&self, d: LoopDim) -> u64 {
        let mut f = 1;
        if self.dim_rows == d {
            f *= self.unroll_rows;
        }
        if self.dim_cols == d {
            f *= self.unroll_cols;
        }
        f
    }
}

/// A complete mapping: temporal tiling per memory level (outermost DRAM
/// level first, same order as `Accelerator::levels`) plus the spatial
/// unrolling at the array.  The innermost implicit level is a single MAC.
///
/// The memoized `access_counts` cache in [`crate::cost::EvalContext`]
/// does **not** key on this struct (hashing the `Vec` and cloning it on
/// insert was a measurable cost): it packs the same information into a
/// `Copy` [`crate::cost::MapKey`] instead.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Mapping {
    pub levels: Vec<TileLevel>,
    pub spatial: Spatial,
}

impl Mapping {
    /// Check the mapping covers the problem exactly.
    pub fn validate(&self, p: &ProblemDims) -> Result<(), String> {
        for d in LoopDim::ALL {
            let total: u64 = self.levels.iter().map(|l| l.factor(d)).product::<u64>()
                * self.spatial.factor(d);
            if total != p.get(d) {
                return Err(format!(
                    "dim {d}: factors multiply to {total}, problem has {}",
                    p.get(d)
                ));
            }
        }
        Ok(())
    }

    /// Tile dims held *at* memory level `lvl` (everything inside it):
    /// the product of factors of all levels below `lvl` plus spatial.
    pub fn tile_at(&self, lvl: usize) -> (u64, u64, u64) {
        let mut t = [1u64; 3];
        for l in &self.levels[lvl + 1..] {
            for (i, d) in LoopDim::ALL.iter().enumerate() {
                t[i] *= l.factor(*d);
            }
        }
        for (i, d) in LoopDim::ALL.iter().enumerate() {
            t[i] *= self.spatial.factor(*d);
        }
        (t[0], t[1], t[2])
    }

    /// Flatten to a loop nest, outermost first, with the memory boundary
    /// index each loop belongs to (level 0 = DRAM loops).
    pub fn flatten(&self) -> Vec<nest::Loop> {
        let mut out = Vec::new();
        for (lvl, t) in self.levels.iter().enumerate() {
            for d in t.order {
                out.push(nest::Loop { dim: d, bound: t.factor(d), level: lvl });
            }
        }
        out
    }
}

impl fmt::Display for Mapping {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, l) in self.levels.iter().enumerate() {
            if i > 0 {
                write!(f, " | ")?;
            }
            write!(f, "L{i}:")?;
            for d in l.order {
                write!(f, " {d}{}", l.factor(d))?;
            }
        }
        write!(
            f,
            " | spatial {}{} x {}{}",
            self.spatial.dim_rows,
            self.spatial.unroll_rows,
            self.spatial.dim_cols,
            self.spatial.unroll_cols
        )
    }
}

/// Per-operand, per-level fill counts (elements moved INTO each level from
/// the level above, per whole-problem execution).
///
/// Inline storage ([`MAX_LEVELS`] rows, `Copy`): computing, caching and
/// copying access counts never touches the heap.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AccessCounts {
    /// `fills[lvl][operand]` in elements; `lvl` indexes on-chip levels of
    /// the mapping (0 = the outermost *bounded* level receiving from
    /// DRAM... see `cost::evaluate` for how this maps onto an
    /// `Accelerator`). Length = number of mapping levels.
    pub fills: InlineVec<[f64; 3], MAX_LEVELS>,
}

/// Tile dims held inside each mapping level, outermost first:
/// `tiles_of(m)[b] == m.tile_at(b)` for every level, computed in one
/// reverse pass (tile at `b` = tile at `b+1` scaled by level `b+1`'s
/// factors; innermost = spatial tile).  These depend only on the tiling
/// factors — never on loop orders — so the order sweep and the proto
/// arena compute them once per proto.
pub fn tiles_of(mapping: &Mapping) -> InlineVec<[u64; 3], MAX_LEVELS> {
    let nlevels = mapping.levels.len();
    assert!(nlevels >= 1 && nlevels <= MAX_LEVELS, "mapping has {nlevels} levels");
    let mut tiles: InlineVec<[u64; 3], MAX_LEVELS> = InlineVec::new();
    for _ in 0..nlevels {
        tiles.push([1u64; 3]);
    }
    tiles[nlevels - 1] = [
        mapping.spatial.factor(LoopDim::M),
        mapping.spatial.factor(LoopDim::N),
        mapping.spatial.factor(LoopDim::K),
    ];
    for b in (0..nlevels - 1).rev() {
        for (i, d) in LoopDim::ALL.iter().enumerate() {
            tiles[b][i] = tiles[b + 1][i] * mapping.levels[b + 1].factor(*d);
        }
    }
    tiles
}

/// Running state of the outermost→innermost fill-counting pass: `prod`
/// is the product of all non-unit loop bounds seen so far, `loads[op]`
/// the product up to the innermost *relevant non-unit* loop so far (the
/// trailing-irrelevant reuse rule).
///
/// Public (to the crate's cost model) because the state after level
/// `b` depends only on levels `0..=b`: `cost::EvalContext` snapshots it
/// to re-evaluate order changes at level `lvl` without recounting the
/// untouched prefix — the incremental order sweep.
#[derive(Clone, Copy, Debug)]
pub struct FillState {
    pub prod: f64,
    pub loads: [f64; 3],
}

impl Default for FillState {
    fn default() -> Self {
        FillState { prod: 1.0, loads: [1.0; 3] }
    }
}

impl FillState {
    /// Fold one level's loops (in its order) into the running state.
    pub fn advance(&mut self, level: &TileLevel) {
        for d in level.order {
            let bound = level.factor(d) as f64;
            if bound > 1.0 {
                self.prod *= bound;
                for (oi, op) in Operand::ALL.iter().enumerate() {
                    if op.relevant(d) {
                        self.loads[oi] = self.prod;
                    }
                }
            }
        }
    }

    /// Fill row for the boundary whose inner tile is `tile`, given the
    /// state after that boundary's loops.
    pub fn row(&self, tile: [u64; 3]) -> [f64; 3] {
        let [tm, tn, tk] = tile;
        let mut row = [0f64; 3];
        for (oi, op) in Operand::ALL.iter().enumerate() {
            row[oi] = self.loads[oi] * op.footprint(tm, tn, tk) as f64;
        }
        row
    }
}

/// Exact single-tile-buffer fill counting via the trailing-irrelevant
/// reuse rule.
///
/// For memory boundary `b` (tiles held at mapping level `b`), the loops
/// outside the boundary are all loops of levels `0..=b-1`... for the tile
/// AT level b, the loops that iterate it are those of levels `0..=b`
/// excluding none — the convention here: the tile held at level `b+1` (one
/// step inside) is reloaded as the level-`b` loops iterate.  We expose
/// `fills[b]` = elements entering level `b+1`'s buffer from level `b`,
/// for `b` in `0..levels.len()-1`, plus the DRAM read row `fills[0]`
/// being elements entering level 1 from DRAM.  Concretely:
/// `fills[b][op] = loads(tile_at(b+1)) × footprint(tile_at(b+1))` —
/// with `tile_at(levels.len()-1)` being the spatial/MAC tile.
pub fn access_counts(mapping: &Mapping, p: &ProblemDims) -> AccessCounts {
    debug_assert!(mapping.validate(p).is_ok());
    let tiles = tiles_of(mapping);

    // Single outermost→innermost [`FillState`] pass: exact under
    // single-tile buffering — validated against the brute-force nest
    // simulator in `rust/tests/properties.rs`.
    let mut fills: InlineVec<[f64; 3], MAX_LEVELS> = InlineVec::new();
    let mut state = FillState::default();
    for (b, level) in mapping.levels.iter().enumerate() {
        state.advance(level);
        fills.push(state.row(tiles[b]));
    }
    AccessCounts { fills }
}


#[cfg(test)]
mod tests {
    use super::*;

    fn simple_mapping() -> (Mapping, ProblemDims) {
        // Problem 8x8x8, two levels: DRAM loops (2,2,2), inner loops
        // (4,4,4) with spatial 1x1.
        let p = ProblemDims::new(8, 8, 8);
        let m = Mapping {
            levels: vec![
                TileLevel { factors: [2, 2, 2], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
                TileLevel { factors: [4, 4, 4], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
            ],
            spatial: Spatial {
                dim_rows: LoopDim::M,
                unroll_rows: 1,
                dim_cols: LoopDim::K,
                unroll_cols: 1,
            },
        };
        (m, p)
    }

    #[test]
    fn validate_catches_mismatch() {
        let (mut m, p) = simple_mapping();
        m.levels[0].factors[0] = 4;
        assert!(m.validate(&p).is_err());
    }

    #[test]
    fn tiles_multiply_out() {
        let (m, p) = simple_mapping();
        m.validate(&p).unwrap();
        assert_eq!(m.tile_at(0), (4, 4, 4));
        // tile_at(last) = spatial-only tile.
        assert_eq!(m.tile_at(1), (1, 1, 1));
    }

    #[test]
    fn tiles_of_matches_tile_at() {
        let (m, p) = simple_mapping();
        m.validate(&p).unwrap();
        let tiles = tiles_of(&m);
        assert_eq!(tiles.len(), m.levels.len());
        for (b, t) in tiles.iter().enumerate() {
            let (tm, tn, tk) = m.tile_at(b);
            assert_eq!(*t, [tm, tn, tk], "level {b}");
        }
    }

    #[test]
    fn fills_match_hand_computation() {
        let (m, p) = simple_mapping();
        let ac = access_counts(&m, &p);
        // Boundary 0: outer loops M2 N2 K2 (order M,N,K), tile 4x4x4.
        // I (rel M,N): innermost relevant = N at pos 1 -> loads = 2*2 = 4;
        //   footprint = 16 -> 64.
        assert_eq!(ac.fills[0][0], 4.0 * 16.0);
        // W (rel N,K): innermost relevant = K pos 2 -> loads = 8; fp 16 -> 128.
        assert_eq!(ac.fills[0][1], 8.0 * 16.0);
        // O (rel M,K): innermost relevant = K pos 2 -> loads 8; fp 16 -> 128.
        assert_eq!(ac.fills[0][2], 8.0 * 16.0);
    }

    #[test]
    fn trailing_irrelevant_loops_are_reused() {
        // Order K,N,M at a single level; for W (N,K-relevant) the trailing
        // M loop must NOT multiply the loads.
        let p = ProblemDims::new(4, 4, 4);
        let m = Mapping {
            levels: vec![TileLevel {
                factors: [4, 4, 4],
                order: [LoopDim::K, LoopDim::N, LoopDim::M],
            }],
            spatial: Spatial {
                dim_rows: LoopDim::M,
                unroll_rows: 1,
                dim_cols: LoopDim::K,
                unroll_cols: 1,
            },
        };
        let ac = access_counts(&m, &p);
        // W: innermost relevant loop is N (pos 1): loads = 4*4 = 16, tile 1x1x1.
        assert_eq!(ac.fills[0][1], 16.0);
        // I: M innermost (pos 2): loads = 64.
        assert_eq!(ac.fills[0][0], 64.0);
    }

    #[test]
    fn spatial_factors_count() {
        let p = ProblemDims::new(8, 4, 8);
        let m = Mapping {
            levels: vec![TileLevel {
                factors: [2, 4, 2],
                order: [LoopDim::M, LoopDim::N, LoopDim::K],
            }],
            spatial: Spatial {
                dim_rows: LoopDim::M,
                unroll_rows: 4,
                dim_cols: LoopDim::K,
                unroll_cols: 4,
            },
        };
        m.validate(&p).unwrap();
        assert_eq!(m.tile_at(0), (4, 1, 4));
    }

    #[test]
    fn display_is_informative() {
        let (m, _) = simple_mapping();
        let s = m.to_string();
        assert!(s.contains("L0:") && s.contains("spatial"), "{s}");
    }
}
