//! Mapping enumeration: the Dataflow Engine's candidate generator
//! (paper §III-A — "SnipSnap adopts existing methodologies [20], [25]"
//! for dataflow, i.e. a ZigZag/Timeloop-style tiling + ordering search).
//!
//! The enumerator splits each problem dim into per-level divisor factors,
//! assigns loop orders per level, and spatially unrolls two dims over the
//! MAC array.  Caps keep the space tractable; the progressive co-search
//! additionally prunes with compressed-footprint legality *before*
//! ordering (see `crate::search`).

use super::{LoopDim, Mapping, ProblemDims, Spatial, TileLevel};
use crate::util::mathx::divisors;

/// Enumeration limits.
#[derive(Clone, Debug)]
pub struct MapperConfig {
    /// Loop orders tried per level (all 6 permutations by default).
    pub orders: Vec<[LoopDim; 3]>,
    /// Maximum mappings yielded (safety valve).
    pub max_candidates: usize,
    /// Consider only spatial unrollings with utilization at least this.
    pub min_spatial_utilization: f64,
}

impl Default for MapperConfig {
    fn default() -> Self {
        MapperConfig {
            orders: all_orders(),
            max_candidates: 2_000_000,
            min_spatial_utilization: 0.5,
        }
    }
}

/// All 6 permutations of (M, N, K).
pub fn all_orders() -> Vec<[LoopDim; 3]> {
    use LoopDim::*;
    vec![
        [M, N, K],
        [M, K, N],
        [N, M, K],
        [N, K, M],
        [K, M, N],
        [K, N, M],
    ]
}

/// Candidate spatial unrollings for a problem on an array with the given
/// axis capacities.  Maps M to array rows and K to array columns (the
/// output-stationary style all Table II architectures use), with N an
/// optional column co-unroll skipped for simplicity.
pub fn spatial_candidates(
    p: &ProblemDims,
    rows: u64,
    cols: u64,
    min_util: f64,
) -> Vec<Spatial> {
    let mut out = Vec::new();
    for um in divisors(p.m).into_iter().filter(|&d| d <= rows) {
        for uk in divisors(p.k).into_iter().filter(|&d| d <= cols) {
            let util = (um * uk) as f64 / (rows * cols) as f64;
            if util >= min_util || (um == p.m.min(rows) && uk == p.k.min(cols)) {
                out.push(Spatial {
                    dim_rows: LoopDim::M,
                    unroll_rows: um,
                    dim_cols: LoopDim::K,
                    unroll_cols: uk,
                });
            }
        }
    }
    // Always keep at least the trivial mapping.
    if out.is_empty() {
        out.push(Spatial {
            dim_rows: LoopDim::M,
            unroll_rows: 1,
            dim_cols: LoopDim::K,
            unroll_cols: 1,
        });
    }
    // High-utilization candidates first: enumeration budgets are spent on
    // the promising corner of the space when a candidate cap truncates.
    out.sort_by(|a, b| {
        (b.unroll_rows * b.unroll_cols).cmp(&(a.unroll_rows * a.unroll_cols))
    });
    out
}

/// All ways to split `total` into `nlevels` divisor factors (outermost
/// first), **balanced splits first**: when a candidate cap truncates the
/// enumeration, coverage concentrates on near-geometric tilings (where
/// the optima live) instead of the degenerate all-factors-inner corner
/// the raw divisor order starts with.
fn splits(total: u64, nlevels: usize) -> Vec<Vec<u64>> {
    let mut all = crate::util::mathx::ordered_factorizations(total, nlevels);
    if nlevels > 1 {
        let target = (total as f64).ln() / nlevels as f64;
        let score = |s: &[u64]| -> f64 {
            s.iter().map(|&f| ((f.max(1) as f64).ln() - target).abs()).sum()
        };
        all.sort_by(|a, b| score(a).partial_cmp(&score(b)).unwrap());
    }
    all
}

/// Stream every tiling *proto* (canonical loop order) for `p` over
/// `nlevels` memory levels to the visitor, without materializing the
/// space.  Returns the number of protos visited.  The `keep` filter runs
/// before the visitor — with a compressed-footprint legality check this
/// is the §III-D2 compression-aware loop allocation.
pub fn for_each_proto<K, V>(
    p: &ProblemDims,
    nlevels: usize,
    rows: u64,
    cols: u64,
    cfg: &MapperConfig,
    mut keep: K,
    mut visit: V,
) -> u64
where
    K: FnMut(&Mapping) -> bool,
    V: FnMut(&Mapping),
{
    let mut visited = 0u64;
    let spatials = spatial_candidates(p, rows, cols, cfg.min_spatial_utilization);
    // Split the candidate budget across spatial configurations so a cap
    // never starves all but the first one.
    let per_spatial = (cfg.max_candidates / spatials.len()).max(1) as u64;
    for sp in spatials {
        let mut local = 0u64;
        let rm = p.m / sp.factor(LoopDim::M);
        let rn = p.n / sp.factor(LoopDim::N);
        let rk = p.k / sp.factor(LoopDim::K);
        'this_spatial: for ms in splits(rm, nlevels) {
            for ns in splits(rn, nlevels) {
                for ks in splits(rk, nlevels) {
                    let proto = Mapping {
                        levels: (0..nlevels)
                            .map(|i| TileLevel {
                                factors: [ms[i], ns[i], ks[i]],
                                order: [LoopDim::M, LoopDim::N, LoopDim::K],
                            })
                            .collect(),
                        spatial: sp,
                    };
                    if !keep(&proto) {
                        continue;
                    }
                    visit(&proto);
                    visited += 1;
                    local += 1;
                    if local >= per_spatial {
                        break 'this_spatial;
                    }
                }
            }
        }
    }
    visited
}

/// Enumerate mappings for `p` over `nlevels` memory levels.
///
/// `keep` is the legality filter (e.g. compressed tile footprints fit
/// each level's capacity); mappings failing it are discarded *before*
/// loop-order expansion, which is the compression-aware-allocation
/// optimization of §III-D2.
pub fn enumerate_mappings<F>(
    p: &ProblemDims,
    nlevels: usize,
    rows: u64,
    cols: u64,
    cfg: &MapperConfig,
    mut keep: F,
) -> Vec<Mapping>
where
    F: FnMut(&Mapping) -> bool,
{
    let mut out = Vec::new();
    'spatial: for sp in spatial_candidates(p, rows, cols, cfg.min_spatial_utilization) {
        let rm = p.m / sp.factor(LoopDim::M);
        let rn = p.n / sp.factor(LoopDim::N);
        let rk = p.k / sp.factor(LoopDim::K);
        for ms in splits(rm, nlevels) {
            for ns in splits(rn, nlevels) {
                for ks in splits(rk, nlevels) {
                    // Build with a canonical order first; check legality
                    // once (footprints don't depend on order), then expand
                    // orders.
                    let proto = Mapping {
                        levels: (0..nlevels)
                            .map(|i| TileLevel {
                                factors: [ms[i], ns[i], ks[i]],
                                order: [LoopDim::M, LoopDim::N, LoopDim::K],
                            })
                            .collect(),
                        spatial: sp,
                    };
                    if !keep(&proto) {
                        continue;
                    }
                    // Expand loop orders per level, skipping permutations
                    // of unit loops (they are equivalent).
                    let order_sets: Vec<Vec<[LoopDim; 3]>> = (0..nlevels)
                        .map(|i| {
                            let nontrivial =
                                proto.levels[i].factors.iter().filter(|&&f| f > 1).count();
                            if nontrivial <= 1 {
                                vec![cfg.orders[0]]
                            } else {
                                cfg.orders.clone()
                            }
                        })
                        .collect();
                    let mut stack = vec![0usize; nlevels];
                    loop {
                        let mut m = proto.clone();
                        for i in 0..nlevels {
                            m.levels[i].order = order_sets[i][stack[i]];
                        }
                        out.push(m);
                        if out.len() >= cfg.max_candidates {
                            break 'spatial;
                        }
                        // Odometer over order choices.
                        let mut i = nlevels;
                        loop {
                            if i == 0 {
                                break;
                            }
                            i -= 1;
                            stack[i] += 1;
                            if stack[i] < order_sets[i].len() {
                                break;
                            }
                            stack[i] = 0;
                            if i == 0 {
                                // done
                                stack = vec![usize::MAX; nlevels];
                                break;
                            }
                        }
                        if stack[0] == usize::MAX {
                            break;
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumerates_valid_mappings() {
        let p = ProblemDims::new(16, 16, 16);
        let cfg = MapperConfig::default();
        let maps = enumerate_mappings(&p, 2, 4, 4, &cfg, |_| true);
        assert!(!maps.is_empty());
        for m in &maps {
            m.validate(&p).unwrap_or_else(|e| panic!("{m}: {e}"));
        }
    }

    #[test]
    fn legality_filter_prunes() {
        let p = ProblemDims::new(16, 16, 16);
        let cfg = MapperConfig::default();
        let all = enumerate_mappings(&p, 2, 4, 4, &cfg, |_| true).len();
        let some = enumerate_mappings(&p, 2, 4, 4, &cfg, |m| {
            let (tm, tn, tk) = m.tile_at(0);
            tm * tn + tn * tk + tm * tk <= 64
        })
        .len();
        assert!(some < all, "filter had no effect: {some} vs {all}");
        assert!(some > 0);
    }

    #[test]
    fn spatial_candidates_respect_array() {
        let p = ProblemDims::new(64, 64, 64);
        for s in spatial_candidates(&p, 8, 8, 0.5) {
            assert!(s.unroll_rows <= 8 && s.unroll_cols <= 8);
            assert_eq!(p.m % s.unroll_rows, 0);
            assert_eq!(p.k % s.unroll_cols, 0);
        }
    }

    #[test]
    fn max_candidates_cap_respected() {
        let p = ProblemDims::new(64, 64, 64);
        let cfg = MapperConfig { max_candidates: 100, ..Default::default() };
        let maps = enumerate_mappings(&p, 2, 8, 8, &cfg, |_| true);
        assert!(maps.len() <= 100);
    }

    #[test]
    fn unit_loop_orders_not_duplicated() {
        // A problem of 4x1x1 has only one non-trivial dim; per level only
        // one order should be emitted per factor split.
        let p = ProblemDims::new(4, 1, 1);
        let cfg = MapperConfig { min_spatial_utilization: 0.0, ..Default::default() };
        let maps = enumerate_mappings(&p, 1, 1, 1, &cfg, |_| true);
        let unique: std::collections::HashSet<String> =
            maps.iter().map(|m| m.to_string()).collect();
        assert_eq!(unique.len(), maps.len(), "duplicate mappings emitted");
    }
}
