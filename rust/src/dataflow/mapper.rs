//! Mapping enumeration: the Dataflow Engine's candidate generator
//! (paper §III-A — "SnipSnap adopts existing methodologies [20], [25]"
//! for dataflow, i.e. a ZigZag/Timeloop-style tiling + ordering search).
//!
//! The enumerator splits each problem dim into per-level divisor factors,
//! assigns loop orders per level, and spatially unrolls two dims over the
//! MAC array.  Caps keep the space tractable; the progressive co-search
//! additionally prunes with compressed-footprint legality *before*
//! ordering (see `crate::search`).

use super::{LoopDim, Mapping, ProblemDims, Spatial, TileLevel};
use crate::util::mathx::divisors;

/// Enumeration limits.
#[derive(Clone, Debug)]
pub struct MapperConfig {
    /// Loop orders tried per level (all 6 permutations by default).
    pub orders: Vec<[LoopDim; 3]>,
    /// Maximum mappings yielded (safety valve).
    pub max_candidates: usize,
    /// Consider only spatial unrollings with utilization at least this.
    pub min_spatial_utilization: f64,
}

impl Default for MapperConfig {
    fn default() -> Self {
        MapperConfig {
            orders: all_orders(),
            max_candidates: 2_000_000,
            min_spatial_utilization: 0.5,
        }
    }
}

/// The canonical loop order protos are enumerated with; loop ordering is
/// assigned later by the search's order sweep.
pub const CANONICAL_ORDER: [LoopDim; 3] = [LoopDim::M, LoopDim::N, LoopDim::K];

/// All 6 permutations of (M, N, K) as a const table — the order sweep
/// iterates this directly so the per-proto path never allocates.
pub const ALL_ORDERS: [[LoopDim; 3]; 6] = {
    use LoopDim::*;
    [
        [M, N, K],
        [M, K, N],
        [N, M, K],
        [N, K, M],
        [K, M, N],
        [K, N, M],
    ]
};

/// All 6 permutations of (M, N, K).
pub fn all_orders() -> Vec<[LoopDim; 3]> {
    ALL_ORDERS.to_vec()
}

/// Candidate spatial unrollings for a problem on an array with the given
/// axis capacities.  Maps M to array rows and K to array columns (the
/// output-stationary style all Table II architectures use), with N an
/// optional column co-unroll skipped for simplicity.
pub fn spatial_candidates(
    p: &ProblemDims,
    rows: u64,
    cols: u64,
    min_util: f64,
) -> Vec<Spatial> {
    let mut out = Vec::new();
    for um in divisors(p.m).into_iter().filter(|&d| d <= rows) {
        for uk in divisors(p.k).into_iter().filter(|&d| d <= cols) {
            let util = (um * uk) as f64 / (rows * cols) as f64;
            if util >= min_util || (um == p.m.min(rows) && uk == p.k.min(cols)) {
                out.push(Spatial {
                    dim_rows: LoopDim::M,
                    unroll_rows: um,
                    dim_cols: LoopDim::K,
                    unroll_cols: uk,
                });
            }
        }
    }
    // Always keep at least the trivial mapping.
    if out.is_empty() {
        out.push(Spatial {
            dim_rows: LoopDim::M,
            unroll_rows: 1,
            dim_cols: LoopDim::K,
            unroll_cols: 1,
        });
    }
    // High-utilization candidates first: enumeration budgets are spent on
    // the promising corner of the space when a candidate cap truncates.
    out.sort_by(|a, b| {
        (b.unroll_rows * b.unroll_cols).cmp(&(a.unroll_rows * a.unroll_cols))
    });
    out
}

/// All ways to split `total` into `nlevels` divisor factors (outermost
/// first), **balanced splits first**: when a candidate cap truncates the
/// enumeration, coverage concentrates on near-geometric tilings (where
/// the optima live) instead of the degenerate all-factors-inner corner
/// the raw divisor order starts with.
fn splits(total: u64, nlevels: usize) -> Vec<Vec<u64>> {
    let mut all = crate::util::mathx::ordered_factorizations(total, nlevels);
    if nlevels > 1 {
        let target = (total as f64).ln() / nlevels as f64;
        let score = |s: &[u64]| -> f64 {
            s.iter().map(|&f| ((f.max(1) as f64).ln() - target).abs()).sum()
        };
        all.sort_by(|a, b| score(a).partial_cmp(&score(b)).unwrap());
    }
    all
}

/// Factor-split table of one residual dimension: every way to split the
/// residual across the temporal levels (`splits(total, nlevels)`), one
/// `Vec<u64>` of per-level factors per entry.
type SplitTable = Vec<Vec<u64>>;

/// The ratio-independent part of one op's proto enumeration, hoisted so
/// it is computed **once per op**: the spatial candidates plus the
/// per-level factor-split tables of every residual dim.  `for_each_proto`
/// used to recompute `spatial_candidates` and three `splits` calls per
/// spatial × per shard × per format pair; building an `OpEnumeration`
/// up front and streaming from it removes that entirely.
pub struct OpEnumeration {
    pub nlevels: usize,
    spatials: Vec<Spatial>,
    /// Per spatial: indices into `split_tables` for the residual (m, n, k).
    spatial_splits: Vec<[usize; 3]>,
    /// Distinct split tables, deduplicated by residual value (many
    /// spatial candidates share residuals).
    split_tables: Vec<SplitTable>,
}

impl OpEnumeration {
    pub fn new(p: &ProblemDims, nlevels: usize, rows: u64, cols: u64, cfg: &MapperConfig) -> Self {
        let spatials = spatial_candidates(p, rows, cols, cfg.min_spatial_utilization);
        let mut split_tables: Vec<SplitTable> = Vec::new();
        let mut by_total: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
        let mut table_for = |total: u64| -> usize {
            *by_total.entry(total).or_insert_with(|| {
                split_tables.push(splits(total, nlevels));
                split_tables.len() - 1
            })
        };
        let spatial_splits = spatials
            .iter()
            .map(|sp| {
                [
                    table_for(p.m / sp.factor(LoopDim::M)),
                    table_for(p.n / sp.factor(LoopDim::N)),
                    table_for(p.k / sp.factor(LoopDim::K)),
                ]
            })
            .collect();
        OpEnumeration { nlevels, spatials, spatial_splits, split_tables }
    }

    pub fn spatials(&self) -> &[Spatial] {
        &self.spatials
    }

    /// Stream the level-major factor table of every proto in the
    /// deterministic enumeration order (spatials by utilization, splits
    /// balanced-first).  `f` receives `(factors, spatial index)` and
    /// returns whether it kept the proto; only kept protos count against
    /// the per-spatial candidate budget, exactly as in the historical
    /// `for_each_proto` semantics (the budget is split across spatial
    /// configurations so a cap never starves all but the first one).
    fn stream(&self, cfg: &MapperConfig, mut f: impl FnMut(&[[u64; 3]], u32) -> bool) {
        let per_spatial = (cfg.max_candidates / self.spatials.len().max(1)).max(1) as u64;
        let mut fbuf: Vec<[u64; 3]> = vec![[1; 3]; self.nlevels];
        for (si, &[mi, ni, ki]) in self.spatial_splits.iter().enumerate() {
            let mut local = 0u64;
            'this_spatial: for ms in &self.split_tables[mi] {
                for ns in &self.split_tables[ni] {
                    for ks in &self.split_tables[ki] {
                        for (lvl, slot) in fbuf.iter_mut().enumerate() {
                            *slot = [ms[lvl], ns[lvl], ks[lvl]];
                        }
                        if f(&fbuf, si as u32) {
                            local += 1;
                            if local >= per_spatial {
                                break 'this_spatial;
                            }
                        }
                    }
                }
            }
        }
    }

    /// A canonical-order scratch [`Mapping`] sized for this enumeration —
    /// the one allocation a search shard makes before iterating an arena.
    pub fn scratch_mapping(&self) -> Mapping {
        scratch_mapping(self.nlevels, self.spatials[0])
    }
}

/// Shared constructor of the canonical scratch mapping handed to
/// `write_mapping`: unit factors, canonical orders, a placeholder
/// spatial.  One definition so [`OpEnumeration`] and [`ProtoArena`]
/// cannot drift apart.
fn scratch_mapping(nlevels: usize, spatial: Spatial) -> Mapping {
    Mapping {
        levels: vec![TileLevel { factors: [1; 3], order: CANONICAL_ORDER }; nlevels],
        spatial,
    }
}

/// Flat structure-of-arrays table of one op's **legal** protos under one
/// format pair's compression ratios: packed level-major factor triples,
/// the per-level inner-tile dims (computed once, shared by legality, the
/// metric lower bound and the order sweep), and a spatial index.
///
/// Built once per (op, format pair) and then iterated by index range
/// from every search shard — replacing the old scheme where each shard
/// replayed the entire enumeration and modulo-filtered proto ids.  The
/// arena build is the only allocation site of the mapping search's inner
/// loop; `write_mapping` fills a caller-owned scratch in place.
#[derive(Default)]
pub struct ProtoArena {
    nlevels: usize,
    spatials: Vec<Spatial>,
    spatial_idx: Vec<u32>,
    /// `factors[i * nlevels + b]` = level-`b` factor triple of proto `i`.
    factors: Vec<[u64; 3]>,
    /// Same layout: tile dims *inside* level `b` (`Mapping::tile_at(b)`).
    tiles: Vec<[u64; 3]>,
}

impl ProtoArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild from `en`, keeping only protos whose `(tiles, spatial)`
    /// pass `keep` — the §III-D2 compression-aware legality filter runs
    /// here, before loop ordering, and filtered protos do not count
    /// against the candidate budget.  Reuses this arena's allocations.
    pub fn rebuild(
        &mut self,
        en: &OpEnumeration,
        cfg: &MapperConfig,
        mut keep: impl FnMut(&[[u64; 3]], Spatial) -> bool,
    ) {
        let n = en.nlevels;
        self.nlevels = n;
        self.spatials.clear();
        self.spatials.extend_from_slice(&en.spatials);
        self.spatial_idx.clear();
        self.factors.clear();
        self.tiles.clear();
        let mut tbuf: Vec<[u64; 3]> = vec![[1; 3]; n];
        en.stream(cfg, |factors, si| {
            let sp = en.spatials[si as usize];
            tbuf[n - 1] = [
                sp.factor(LoopDim::M),
                sp.factor(LoopDim::N),
                sp.factor(LoopDim::K),
            ];
            // Factor triples share the (M, N, K) component order with
            // tile triples, so the reverse pass is a plain product.
            for b in (0..n - 1).rev() {
                for i in 0..3 {
                    tbuf[b][i] = tbuf[b + 1][i] * factors[b + 1][i];
                }
            }
            if !keep(&tbuf, sp) {
                return false;
            }
            self.factors.extend_from_slice(factors);
            self.tiles.extend_from_slice(&tbuf);
            self.spatial_idx.push(si);
            true
        });
    }

    /// Number of legal protos in the arena; proto ids are `0..len()`.
    pub fn len(&self) -> usize {
        self.spatial_idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spatial_idx.is_empty()
    }

    /// Level-major factor triples of proto `i`.
    pub fn factors(&self, i: usize) -> &[[u64; 3]] {
        &self.factors[i * self.nlevels..(i + 1) * self.nlevels]
    }

    /// Per-level inner-tile dims of proto `i` (`tiles(i)[b]` =
    /// `tile_at(b)` of the materialized mapping).
    pub fn tiles(&self, i: usize) -> &[[u64; 3]] {
        &self.tiles[i * self.nlevels..(i + 1) * self.nlevels]
    }

    pub fn spatial(&self, i: usize) -> Spatial {
        self.spatials[self.spatial_idx[i] as usize]
    }

    /// A canonical-order scratch [`Mapping`] sized for this arena (the
    /// one allocation a search shard makes before iterating it).  The
    /// arena must have been rebuilt from a non-degenerate enumeration.
    pub fn scratch_mapping(&self) -> Mapping {
        scratch_mapping(self.nlevels, self.spatials[0])
    }

    /// Materialize proto `i` into `out` (canonical loop orders), reusing
    /// `out`'s level storage — no allocation when `out` already has the
    /// right level count (see [`OpEnumeration::scratch_mapping`]).
    pub fn write_mapping(&self, i: usize, out: &mut Mapping) {
        if out.levels.len() != self.nlevels {
            out.levels
                .resize(self.nlevels, TileLevel { factors: [1; 3], order: CANONICAL_ORDER });
        }
        for (lvl, level) in out.levels.iter_mut().enumerate() {
            level.factors = self.factors(i)[lvl];
            level.order = CANONICAL_ORDER;
        }
        out.spatial = self.spatial(i);
    }

    /// Best-first index permutation: every arena id, sorted ascending by
    /// `key(id)` with the id itself as tie-break — a deterministic total
    /// order.  The search uses the primary-metric lower bound as the
    /// key so branch-and-bound visits the most promising protos first
    /// and the incumbent tightens early (`docs/SEARCH.md` § Frontier
    /// search); results are unchanged because the shard reduction is
    /// visit-order independent by construction.
    pub fn order_by(&self, mut key: impl FnMut(usize) -> f64) -> Vec<u32> {
        let keys: Vec<f64> = (0..self.len()).map(&mut key).collect();
        let mut idx: Vec<u32> = (0..self.len() as u32).collect();
        idx.sort_unstable_by(|&a, &b| {
            keys[a as usize]
                .partial_cmp(&keys[b as usize])
                .expect("best-first ordering key was NaN")
                .then(a.cmp(&b))
        });
        idx
    }
}

/// Stream every tiling *proto* (canonical loop order) for `p` over
/// `nlevels` memory levels to the visitor, without materializing the
/// space.  Returns the number of protos visited.  The `keep` filter runs
/// before the visitor — with a compressed-footprint legality check this
/// is the §III-D2 compression-aware loop allocation.
///
/// The visitor is handed a reused scratch mapping; clone it to retain a
/// proto beyond the callback.  The search no longer calls this (it
/// builds a [`ProtoArena`] from an [`OpEnumeration`] instead); the
/// streaming form remains for tests and one-shot tools and shares the
/// same enumeration order by construction.
pub fn for_each_proto<K, V>(
    p: &ProblemDims,
    nlevels: usize,
    rows: u64,
    cols: u64,
    cfg: &MapperConfig,
    mut keep: K,
    mut visit: V,
) -> u64
where
    K: FnMut(&Mapping) -> bool,
    V: FnMut(&Mapping),
{
    let en = OpEnumeration::new(p, nlevels, rows, cols, cfg);
    let mut scratch = en.scratch_mapping();
    let mut visited = 0u64;
    en.stream(cfg, |factors, si| {
        for (lvl, level) in scratch.levels.iter_mut().enumerate() {
            level.factors = factors[lvl];
            level.order = CANONICAL_ORDER;
        }
        scratch.spatial = en.spatials[si as usize];
        if !keep(&scratch) {
            return false;
        }
        visit(&scratch);
        visited += 1;
        true
    });
    visited
}

/// Enumerate mappings for `p` over `nlevels` memory levels.
///
/// `keep` is the legality filter (e.g. compressed tile footprints fit
/// each level's capacity); mappings failing it are discarded *before*
/// loop-order expansion, which is the compression-aware-allocation
/// optimization of §III-D2.
pub fn enumerate_mappings<F>(
    p: &ProblemDims,
    nlevels: usize,
    rows: u64,
    cols: u64,
    cfg: &MapperConfig,
    mut keep: F,
) -> Vec<Mapping>
where
    F: FnMut(&Mapping) -> bool,
{
    let mut out = Vec::new();
    'spatial: for sp in spatial_candidates(p, rows, cols, cfg.min_spatial_utilization) {
        let rm = p.m / sp.factor(LoopDim::M);
        let rn = p.n / sp.factor(LoopDim::N);
        let rk = p.k / sp.factor(LoopDim::K);
        for ms in splits(rm, nlevels) {
            for ns in splits(rn, nlevels) {
                for ks in splits(rk, nlevels) {
                    // Build with a canonical order first; check legality
                    // once (footprints don't depend on order), then expand
                    // orders.
                    let proto = Mapping {
                        levels: (0..nlevels)
                            .map(|i| TileLevel {
                                factors: [ms[i], ns[i], ks[i]],
                                order: [LoopDim::M, LoopDim::N, LoopDim::K],
                            })
                            .collect(),
                        spatial: sp,
                    };
                    if !keep(&proto) {
                        continue;
                    }
                    // Expand loop orders per level, skipping permutations
                    // of unit loops (they are equivalent).
                    let order_sets: Vec<Vec<[LoopDim; 3]>> = (0..nlevels)
                        .map(|i| {
                            let nontrivial =
                                proto.levels[i].factors.iter().filter(|&&f| f > 1).count();
                            if nontrivial <= 1 {
                                vec![cfg.orders[0]]
                            } else {
                                cfg.orders.clone()
                            }
                        })
                        .collect();
                    let mut stack = vec![0usize; nlevels];
                    loop {
                        let mut m = proto.clone();
                        for i in 0..nlevels {
                            m.levels[i].order = order_sets[i][stack[i]];
                        }
                        out.push(m);
                        if out.len() >= cfg.max_candidates {
                            break 'spatial;
                        }
                        // Odometer over order choices.
                        let mut i = nlevels;
                        loop {
                            if i == 0 {
                                break;
                            }
                            i -= 1;
                            stack[i] += 1;
                            if stack[i] < order_sets[i].len() {
                                break;
                            }
                            stack[i] = 0;
                            if i == 0 {
                                // done
                                stack = vec![usize::MAX; nlevels];
                                break;
                            }
                        }
                        if stack[0] == usize::MAX {
                            break;
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumerates_valid_mappings() {
        let p = ProblemDims::new(16, 16, 16);
        let cfg = MapperConfig::default();
        let maps = enumerate_mappings(&p, 2, 4, 4, &cfg, |_| true);
        assert!(!maps.is_empty());
        for m in &maps {
            m.validate(&p).unwrap_or_else(|e| panic!("{m}: {e}"));
        }
    }

    #[test]
    fn legality_filter_prunes() {
        let p = ProblemDims::new(16, 16, 16);
        let cfg = MapperConfig::default();
        let all = enumerate_mappings(&p, 2, 4, 4, &cfg, |_| true).len();
        let some = enumerate_mappings(&p, 2, 4, 4, &cfg, |m| {
            let (tm, tn, tk) = m.tile_at(0);
            tm * tn + tn * tk + tm * tk <= 64
        })
        .len();
        assert!(some < all, "filter had no effect: {some} vs {all}");
        assert!(some > 0);
    }

    #[test]
    fn spatial_candidates_respect_array() {
        let p = ProblemDims::new(64, 64, 64);
        for s in spatial_candidates(&p, 8, 8, 0.5) {
            assert!(s.unroll_rows <= 8 && s.unroll_cols <= 8);
            assert_eq!(p.m % s.unroll_rows, 0);
            assert_eq!(p.k % s.unroll_cols, 0);
        }
    }

    #[test]
    fn max_candidates_cap_respected() {
        let p = ProblemDims::new(64, 64, 64);
        let cfg = MapperConfig { max_candidates: 100, ..Default::default() };
        let maps = enumerate_mappings(&p, 2, 8, 8, &cfg, |_| true);
        assert!(maps.len() <= 100);
    }

    #[test]
    fn arena_matches_streaming_enumeration() {
        let p = ProblemDims::new(16, 16, 16);
        let cfg = MapperConfig::default();
        let mut streamed: Vec<Mapping> = Vec::new();
        for_each_proto(&p, 2, 4, 4, &cfg, |_| true, |m| streamed.push(m.clone()));

        let en = OpEnumeration::new(&p, 2, 4, 4, &cfg);
        let mut arena = ProtoArena::new();
        arena.rebuild(&en, &cfg, |_, _| true);
        assert_eq!(arena.len(), streamed.len());
        let mut scratch = en.scratch_mapping();
        for (i, want) in streamed.iter().enumerate() {
            arena.write_mapping(i, &mut scratch);
            assert_eq!(&scratch, want, "proto {i} diverged");
            scratch.validate(&p).unwrap();
        }
    }

    #[test]
    fn arena_tiles_match_tile_at() {
        let p = ProblemDims::new(32, 16, 8);
        let cfg = MapperConfig::default();
        let en = OpEnumeration::new(&p, 3, 4, 4, &cfg);
        let mut arena = ProtoArena::new();
        arena.rebuild(&en, &cfg, |_, _| true);
        assert!(!arena.is_empty());
        let mut scratch = en.scratch_mapping();
        for i in [0, arena.len() / 2, arena.len() - 1] {
            arena.write_mapping(i, &mut scratch);
            for (b, t) in arena.tiles(i).iter().enumerate() {
                let (tm, tn, tk) = scratch.tile_at(b);
                assert_eq!(*t, [tm, tn, tk], "proto {i} level {b}");
            }
        }
    }

    #[test]
    fn arena_budget_and_filter() {
        let p = ProblemDims::new(64, 64, 64);
        let cfg = MapperConfig { max_candidates: 50, ..Default::default() };
        let en = OpEnumeration::new(&p, 2, 8, 8, &cfg);
        let mut arena = ProtoArena::new();
        arena.rebuild(&en, &cfg, |_, _| true);
        let unfiltered = arena.len();
        assert!(unfiltered > 0);
        // The per-spatial budget bounds the total: at most
        // max(cap / nspatials, 1) per spatial configuration.
        let per_spatial = (cfg.max_candidates / en.spatials().len().max(1)).max(1);
        assert!(unfiltered <= per_spatial * en.spatials().len());

        // A legality filter shrinks the table, and rejected protos do
        // not count against the budget (filtered build still finds
        // protos even when the first candidates fail).
        arena.rebuild(&en, &cfg, |tiles, _| {
            let [tm, tn, tk] = tiles[0];
            tm * tn + tn * tk + tm * tk <= 512
        });
        assert!(arena.len() < unfiltered, "filter had no effect");
        assert!(!arena.is_empty());
    }

    #[test]
    fn unit_loop_orders_not_duplicated() {
        // A problem of 4x1x1 has only one non-trivial dim; per level only
        // one order should be emitted per factor split.
        let p = ProblemDims::new(4, 1, 1);
        let cfg = MapperConfig { min_spatial_utilization: 0.0, ..Default::default() };
        let maps = enumerate_mappings(&p, 1, 1, 1, &cfg, |_| true);
        let unique: std::collections::HashSet<String> =
            maps.iter().map(|m| m.to_string()).collect();
        assert_eq!(unique.len(), maps.len(), "duplicate mappings emitted");
    }
}
