//! Loop-nest representation and a brute-force reuse simulator.
//!
//! The simulator executes a flattened loop nest step by step, maintaining
//! a single-tile buffer per operand per memory boundary, and counts actual
//! tile loads.  It is exponentially slower than the closed form in
//! [`super::access_counts`] but exact by construction — the property tests
//! check the closed form against it on small problems.

use super::{LoopDim, Mapping, Operand, ProblemDims};

/// One temporal loop of the flattened nest.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Loop {
    pub dim: LoopDim,
    pub bound: u64,
    /// Memory level the loop belongs to (0 = outermost / DRAM loops).
    pub level: usize,
}

/// Last-seen relevant-coordinate tuple per operand (indexed like
/// [`Operand::ALL`]) at one memory boundary; `None` until first touch.
type LastCoords = [Option<Vec<u64>>; 3];

/// Brute-force fill counting: simulate the nest, tracking for each memory
/// boundary and operand the last-seen relevant-index tuple; count a load
/// whenever it changes.  Returns `fills[boundary][operand]` in elements.
pub fn simulate_fills(mapping: &Mapping, p: &ProblemDims) -> Vec<[f64; 3]> {
    let nest = mapping.flatten();
    let nlevels = mapping.levels.len();
    let total_iters: u64 = nest.iter().map(|l| l.bound).product();
    assert!(total_iters <= 1 << 22, "simulate_fills is for small problems");

    // Per-boundary, per-operand: last relevant coordinate tuple.
    let mut last: Vec<LastCoords> = vec![[None, None, None]; nlevels];
    let mut loads: Vec<[u64; 3]> = vec![[0; 3]; nlevels];

    let mut idx = vec![0u64; nest.len()];
    loop {
        // For each boundary b, the tile inside level b is indexed by the
        // relevant coords among loops with level <= b.
        for b in 0..nlevels {
            for (oi, op) in Operand::ALL.iter().enumerate() {
                let coord: Vec<u64> = nest
                    .iter()
                    .zip(&idx)
                    .filter(|(l, _)| l.level <= b && op.relevant(l.dim))
                    .map(|(_, &i)| i)
                    .collect();
                if last[b][oi].as_ref() != Some(&coord) {
                    loads[b][oi] += 1;
                    last[b][oi] = Some(coord);
                }
            }
        }
        // Odometer increment (innermost fastest).
        let mut pos = nest.len();
        loop {
            if pos == 0 {
                // Done: convert loads to element fills.
                let mut out = Vec::with_capacity(nlevels);
                for (b, lb) in loads.iter().enumerate() {
                    let (tm, tn, tk) = mapping.tile_at(b);
                    let mut row = [0f64; 3];
                    for (oi, op) in Operand::ALL.iter().enumerate() {
                        row[oi] = lb[oi] as f64 * op.footprint(tm, tn, tk) as f64;
                    }
                    out.push(row);
                }
                return out;
            }
            pos -= 1;
            idx[pos] += 1;
            if idx[pos] < nest[pos].bound {
                break;
            }
            idx[pos] = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{access_counts, Spatial, TileLevel};

    fn mapping(levels: Vec<TileLevel>) -> Mapping {
        Mapping {
            levels,
            spatial: Spatial {
                dim_rows: LoopDim::M,
                unroll_rows: 1,
                dim_cols: LoopDim::K,
                unroll_cols: 1,
            },
        }
    }

    #[test]
    fn simulator_matches_closed_form_two_levels() {
        let p = ProblemDims::new(4, 4, 4);
        for order0 in [
            [LoopDim::M, LoopDim::N, LoopDim::K],
            [LoopDim::K, LoopDim::N, LoopDim::M],
            [LoopDim::N, LoopDim::K, LoopDim::M],
        ] {
            let m = mapping(vec![
                TileLevel { factors: [2, 2, 2], order: order0 },
                TileLevel { factors: [2, 2, 2], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
            ]);
            m.validate(&p).unwrap();
            let sim = simulate_fills(&m, &p);
            let closed = access_counts(&m, &p);
            for b in 0..2 {
                for oi in 0..3 {
                    assert_eq!(
                        sim[b][oi], closed.fills[b][oi],
                        "order {order0:?} boundary {b} operand {oi}"
                    );
                }
            }
        }
    }

    #[test]
    fn simulator_counts_single_level_identity() {
        let p = ProblemDims::new(2, 2, 2);
        let m = mapping(vec![TileLevel {
            factors: [2, 2, 2],
            order: [LoopDim::M, LoopDim::N, LoopDim::K],
        }]);
        let sim = simulate_fills(&m, &p);
        // Innermost tiles are 1x1x1; I loaded on every (M,N) change = 4
        // times... with K innermost the I index changes every M,N change
        // but K iterations reuse: loads(I) = 4, elements = 4.
        assert_eq!(sim[0][0], 4.0);
        // W: (N,K) relevant, innermost K -> every iteration changes = 8.
        assert_eq!(sim[0][1], 8.0);
        // O: (M,K) relevant, K innermost -> 8.
        assert_eq!(sim[0][2], 8.0);
    }
}
