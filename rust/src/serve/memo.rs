//! Persistent cross-run `access_counts` memo store.
//!
//! The co-search's dominant cost is recomputing access counts for
//! mappings it has already seen — across requests, across processes.
//! [`MemoStore`] is an append-mode on-disk map from the 128-bit
//! [`memo_key`](crate::cost::memo_key) (scope digest + packed
//! [`MapKey`](crate::cost::MapKey)) to the cached [`AccessCounts`]:
//! loaded once at startup, consulted through the
//! [`CountsMemo`](crate::cost::CountsMemo) seam during searches, and
//! flushed incrementally (new entries only) between requests.
//!
//! # Why this cannot change results
//!
//! `access_counts` is a pure function of `(mapping, dims)` and the store
//! holds the exact `f64`s a recompute would produce (the JSON writer
//! uses shortest-round-trip float formatting, so render → parse is the
//! identity on finite values — and fill counts are always finite).  A
//! memo hit therefore substitutes bit-identical inputs into the cost
//! backend; designs, scores and the `evaluations` counter are unchanged
//! (pinned by `rust/tests/serve_service.rs`).
//!
//! # Scope digest (the invalidation key)
//!
//! Entries are only shared under an identical [`request_scope`]: an
//! FNV-1a digest of the memo schema version plus the canonical snapshot
//! JSON of the accelerator, workload, cost-backend and quantization
//! configs (the op's problem dims are folded in per-op by the search).
//! Dims alone would be sufficient for correctness; the conservative
//! digest means a config change can only ever cause misses, never a
//! wrong hit.
//!
//! # File format
//!
//! JSONL: a header line `{"snipsnap_memo":1}` followed by one entry per
//! line, `{"counts":[[f,f,f],...],"key":"<32 hex digits>"}`.  Appends
//! are line-atomic in practice but a crash mid-write can truncate the
//! final line, so the loader tolerates (drops) a malformed *last* line
//! while rejecting corruption anywhere else.
//!
//! Append-only files accumulate duplicate lines when several processes
//! share a store (each appends entries the others already wrote; the
//! loader keeps the last copy, and all copies are byte-identical
//! because counts for a key are unique and the renderer is
//! deterministic).  [`MemoStore::flush`] therefore auto-compacts: when
//! the dead (duplicate) bytes exceed twice the live bytes it rewrites
//! the file as header + one line per live entry in ascending key order
//! — a canonical form, so compaction is idempotent.  [`MemoStore::compact`]
//! forces the same rewrite unconditionally.
//!
//! # Size cap (`--memo-max-entries`)
//!
//! An optional entry cap ([`MemoStore::set_max_entries`]) is enforced at
//! flush time through the same canonical rewrite: when the store holds
//! more than `cap` entries, the `cap` **smallest keys survive** and the
//! rest are evicted — the same ascending-key order the compacted file
//! uses, so eviction is deterministic (two stores with the same entries
//! and cap evict identically, regardless of insert order).  The store
//! is a pure cache, so eviction can only cost a future recompute, never
//! correctness.

use crate::arch::Accelerator;
use crate::config::snapshot;
use crate::cost::CountsMemo;
use crate::dataflow::{AccessCounts, MAX_LEVELS};
use crate::search::SearchConfig;
use crate::util::hash::{fnv1a64_fold, FNV64_OFFSET};
use crate::util::inline::InlineVec;
use crate::util::json::Json;
use crate::workload::Workload;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Memo schema version, folded into every [`request_scope`] digest: bump
/// it whenever the meaning of stored counts changes and every existing
/// entry silently (and safely) misses.
pub const MEMO_SCHEMA: u64 = 1;

/// The store-level scope digest for one request: FNV-1a over
/// [`MEMO_SCHEMA`] and the canonical snapshot JSON of everything the
/// stored counts must be invalidated by (see module docs).
pub fn request_scope(arch: &Accelerator, w: &Workload, cfg: &SearchConfig) -> u64 {
    let mut scope = fnv1a64_fold(FNV64_OFFSET, &MEMO_SCHEMA.to_le_bytes());
    for doc in [
        snapshot::arch_json(arch),
        snapshot::workload_json(w),
        snapshot::cost_json(&cfg.cost),
        snapshot::quant_json(&cfg.quant),
    ] {
        scope = fnv1a64_fold(scope, doc.to_string().as_bytes());
    }
    scope
}

/// The on-disk map behind `snipsnap serve` (see module docs).  Shared
/// across worker threads: all methods take `&self` and synchronize on an
/// internal mutex (the search only touches it on local-cache misses, so
/// contention is off the hot path).
pub struct MemoStore {
    path: Option<PathBuf>,
    /// Entry cap enforced at flush time (see module docs); `None` means
    /// unbounded.
    max_entries: Option<usize>,
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    map: HashMap<u128, AccessCounts>,
    /// Entries inserted since the last [`MemoStore::flush`], in insert
    /// order — the append-mode write set.
    pending: Vec<(u128, AccessCounts)>,
    /// Entry-line bytes currently in the backing file (header excluded).
    file_bytes: usize,
    /// Entry-line bytes of the live (deduplicated) entries.  Duplicate
    /// lines for a key are byte-identical (counts for a key are unique
    /// and the renderer is deterministic), so the file's dead bytes are
    /// exactly `file_bytes - live_bytes`.
    live_bytes: usize,
}

impl MemoStore {
    /// Open (or create) the store at `path`, loading every entry.  A
    /// missing file becomes an empty store whose first flush writes the
    /// header; an existing file must start with the versioned header.
    pub fn open(path: &Path) -> Result<MemoStore> {
        let mut inner = Inner::default();
        match std::fs::read_to_string(path) {
            Ok(text) => {
                load_entries(&text, &mut inner)
                    .with_context(|| format!("memo store {}", path.display()))?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(anyhow!("memo store {}: {e}", path.display())),
        }
        Ok(MemoStore {
            path: Some(path.to_path_buf()),
            max_entries: None,
            inner: Mutex::new(inner),
        })
    }

    /// A store with no backing file — same semantics, nothing persists.
    pub fn in_memory() -> MemoStore {
        MemoStore { path: None, max_entries: None, inner: Mutex::new(Inner::default()) }
    }

    /// Cap the store at `cap` entries (`None` removes the cap).  The cap
    /// is enforced at [`flush`](MemoStore::flush) time, not per insert:
    /// the flush evicts down to the cap — the `cap` smallest keys
    /// survive, in the same ascending order the canonical compacted file
    /// uses, so eviction is deterministic — and rewrites the backing
    /// file through the [`compact`](MemoStore::compact) path.
    pub fn set_max_entries(&mut self, cap: Option<usize>) {
        self.max_entries = cap;
    }

    /// Entries currently held (flushed or pending).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stored counts for `key`, if any ([`AccessCounts`] is `Copy`).
    pub fn get(&self, key: u128) -> Option<AccessCounts> {
        self.inner.lock().unwrap().map.get(&key).copied()
    }

    /// Record `counts` under `key`; the first insert wins (counts for a
    /// key are unique by construction, so a duplicate is a no-op rather
    /// than a rewrite) and joins the next flush's write set.
    pub fn insert(&self, key: u128, counts: &AccessCounts) {
        let mut inner = self.inner.lock().unwrap();
        if inner.map.insert(key, *counts).is_none() {
            inner.pending.push((key, *counts));
        }
    }

    /// Append all pending entries to the backing file (creating it with
    /// the header if needed) and clear the write set.  Returns how many
    /// entries were written; an in-memory store just drains.
    ///
    /// After appending, auto-compacts: when the file's dead (duplicate)
    /// bytes exceed twice its live bytes — left behind by earlier
    /// appends from other processes sharing the store — the file is
    /// rewritten from the deduplicated in-memory map (see [`compact`]).
    /// A [`set_max_entries`](MemoStore::set_max_entries) cap is enforced
    /// here too: over-cap stores evict down to the cap (smallest keys
    /// survive) and rewrite unconditionally.
    ///
    /// [`compact`]: MemoStore::compact
    pub fn flush(&self) -> Result<usize> {
        let pending: Vec<(u128, AccessCounts)> = {
            let mut inner = self.inner.lock().unwrap();
            std::mem::take(&mut inner.pending)
        };
        let Some(path) = &self.path else {
            if let Some(cap) = self.max_entries {
                evict_to_cap(&mut self.inner.lock().unwrap(), cap);
            }
            return Ok(pending.len());
        };
        let mut appended = 0usize;
        if !pending.is_empty() {
            if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("memo store {}", path.display()))?;
            }
            let mut out = String::new();
            if !path.exists() {
                out.push_str(&format!("{}\n", header_json()));
            }
            let header_len = out.len();
            for (key, ac) in &pending {
                out.push_str(&format!("{}\n", entry_json(*key, ac)));
            }
            appended = out.len() - header_len;
            std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .and_then(|mut f| f.write_all(out.as_bytes()))
                .with_context(|| format!("memo store {}", path.display()))?;
        }
        // Account the new lines (all fresh keys: `insert` only queues a
        // key the map had never seen), then enforce the entry cap and
        // compact if dead bytes dominate.
        let mut inner = self.inner.lock().unwrap();
        inner.file_bytes += appended;
        inner.live_bytes += appended;
        if self.max_entries.is_some_and(|cap| inner.map.len() > cap) {
            evict_to_cap(&mut inner, self.max_entries.unwrap());
            rewrite_file(path, &mut inner)?;
        } else if inner.file_bytes - inner.live_bytes > 2 * inner.live_bytes {
            rewrite_file(path, &mut inner)?;
        }
        Ok(pending.len())
    }

    /// Rewrite the backing file as header + one line per live entry in
    /// ascending key order, dropping every duplicate line earlier
    /// appends (this process's or another's) left behind.  The output
    /// is canonical, so compacting twice is byte-identical — and a
    /// compacted store reloads to exactly the same map.  A no-op for
    /// in-memory stores.
    ///
    /// Entries another process appended after our last load are not in
    /// our map and are dropped from the file; that only costs a future
    /// recompute (the store is a pure cache), never correctness.
    pub fn compact(&self) -> Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        let mut inner = self.inner.lock().unwrap();
        rewrite_file(path, &mut inner)
    }
}

/// Evict entries until at most `cap` remain: the `cap` smallest keys
/// survive, mirroring the canonical file's ascending-key order so the
/// eviction set is a deterministic function of (entries, cap).  Pending
/// entries whose keys were evicted are dropped from the write set too.
fn evict_to_cap(inner: &mut Inner, cap: usize) {
    if inner.map.len() <= cap {
        return;
    }
    let mut keys: Vec<u128> = inner.map.keys().copied().collect();
    keys.sort_unstable();
    for k in keys.drain(cap..) {
        inner.map.remove(&k);
    }
    let Inner { map, pending, .. } = inner;
    pending.retain(|(k, _)| map.contains_key(k));
}

/// The compaction rewrite shared by [`MemoStore::flush`] and
/// [`MemoStore::compact`]: canonical contents, written to a sibling temp
/// file and renamed into place so a crash never tears the store.
/// Pending entries land in the rewrite, so the write set is cleared.
fn rewrite_file(path: &Path, inner: &mut Inner) -> Result<()> {
    let mut keys: Vec<u128> = inner.map.keys().copied().collect();
    keys.sort_unstable();
    let mut out = format!("{}\n", header_json());
    let mut live = 0usize;
    for k in keys {
        let line = format!("{}\n", entry_json(k, &inner.map[&k]));
        live += line.len();
        out.push_str(&line);
    }
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir).with_context(|| format!("memo store {}", path.display()))?;
    }
    let tmp = path.with_extension("compact-tmp");
    std::fs::write(&tmp, out.as_bytes())
        .and_then(|()| std::fs::rename(&tmp, path))
        .with_context(|| format!("memo store {}", path.display()))?;
    inner.pending.clear();
    inner.file_bytes = live;
    inner.live_bytes = live;
    Ok(())
}

impl CountsMemo for MemoStore {
    fn get(&self, key: u128) -> Option<AccessCounts> {
        MemoStore::get(self, key)
    }

    fn put(&self, key: u128, counts: &AccessCounts) {
        self.insert(key, counts);
    }
}

/// Per-request view of a [`MemoStore`] that counts hits and misses —
/// the numbers behind `memo_hits`/`memo_misses` in
/// [`SearchStats`](crate::serve::SearchStats).  The search binds this
/// (not the store directly) so each request reports its own traffic.
pub struct MemoSession<'a> {
    store: &'a MemoStore,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<'a> MemoSession<'a> {
    pub fn new(store: &'a MemoStore) -> MemoSession<'a> {
        MemoSession { store, hits: AtomicU64::new(0), misses: AtomicU64::new(0) }
    }

    /// Store lookups served from the store during this request.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Store lookups that missed (and were then computed + published).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

impl CountsMemo for MemoSession<'_> {
    fn get(&self, key: u128) -> Option<AccessCounts> {
        let r = self.store.get(key);
        match r {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        r
    }

    fn put(&self, key: u128, counts: &AccessCounts) {
        self.store.insert(key, counts);
    }
}

// --- file format ----------------------------------------------------------

fn header_json() -> Json {
    Json::obj(vec![("snipsnap_memo", Json::num(MEMO_SCHEMA as f64))])
}

fn entry_json(key: u128, ac: &AccessCounts) -> Json {
    Json::obj(vec![
        ("key", Json::str(&format!("{key:032x}"))),
        (
            "counts",
            Json::arr(ac.fills.iter().map(|row| Json::arr(row.iter().map(|&f| Json::num(f))))),
        ),
    ])
}

fn entry_from(v: &Json) -> Result<(u128, AccessCounts)> {
    let hex = v.get("key").and_then(Json::as_str).context("entry missing 'key'")?;
    if hex.len() != 32 {
        bail!("entry key '{hex}' is not 32 hex digits");
    }
    let key = u128::from_str_radix(hex, 16).with_context(|| format!("entry key '{hex}'"))?;
    let rows = v.get("counts").and_then(Json::as_arr).context("entry missing 'counts'")?;
    if rows.is_empty() || rows.len() > MAX_LEVELS {
        bail!("entry has {} count rows (need 1..={MAX_LEVELS})", rows.len());
    }
    let mut fills: InlineVec<[f64; 3], MAX_LEVELS> = InlineVec::new();
    for row in rows {
        let row = row.as_arr().context("count row must be an array")?;
        let row: [f64; 3] = row
            .iter()
            .map(|x| x.as_f64().context("count entries must be numbers"))
            .collect::<Result<Vec<_>>>()?
            .try_into()
            .map_err(|_| anyhow!("count rows must have 3 entries"))?;
        fills.push(row);
    }
    Ok((key, AccessCounts { fills }))
}

/// Parse a store file: versioned header first, then entries.  A
/// malformed **final** line (torn append) is dropped; corruption
/// anywhere else is an error — silently skipping mid-file lines would
/// mask real damage.
fn load_entries(text: &str, inner: &mut Inner) -> Result<()> {
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let Some((first, rest)) = lines.split_first() else { return Ok(()) };
    let header = Json::parse(first).map_err(|e| anyhow!("bad header line: {e}"))?;
    let schema = header
        .get("snipsnap_memo")
        .and_then(Json::as_u64)
        .context("not a snipsnap memo store (missing 'snipsnap_memo' header)")?;
    if schema != MEMO_SCHEMA {
        bail!("unsupported memo schema {schema} (this build reads {MEMO_SCHEMA})");
    }
    for (i, line) in rest.iter().enumerate() {
        let last = i + 1 == rest.len();
        let parsed = Json::parse(line)
            .map_err(|e| anyhow!("line {}: {e}", i + 2))
            .and_then(|v| entry_from(&v).map_err(|e| anyhow!("line {}: {e}", i + 2)));
        match parsed {
            Ok((key, ac)) => {
                // Duplicate lines for a key are byte-identical, so only
                // the first occurrence counts toward the live bytes.
                let bytes = line.len() + 1;
                inner.file_bytes += bytes;
                if inner.map.insert(key, ac).is_none() {
                    inner.live_bytes += bytes;
                }
            }
            Err(_) if last => {} // torn final append — drop it
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts(seed: f64) -> AccessCounts {
        let mut fills: InlineVec<[f64; 3], MAX_LEVELS> = InlineVec::new();
        fills.push([seed, seed * 2.0, seed + 0.125]);
        fills.push([1.0, f64::from_bits(0x3ff0_0000_0000_0001), 3.0e16]);
        AccessCounts { fills }
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("snipsnap_memo_{name}_{}", std::process::id()))
    }

    /// Every `f64` must survive the disk round trip exactly — including
    /// non-integral values, a 1-ulp-off-1.0 value and counts beyond the
    /// writer's integer-formatting range.
    #[test]
    fn disk_round_trip_is_exact() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let store = MemoStore::open(&path).unwrap();
        store.insert(7, &counts(0.3));
        store.insert(u128::MAX, &counts(9.0));
        assert_eq!(store.flush().unwrap(), 2);
        assert_eq!(store.flush().unwrap(), 0, "flush drains the write set");

        let re = MemoStore::open(&path).unwrap();
        assert_eq!(re.len(), 2);
        assert_eq!(re.get(7), Some(counts(0.3)));
        assert_eq!(re.get(u128::MAX), Some(counts(9.0)));
        assert_eq!(re.get(8), None);

        // Appends across reopen accumulate instead of clobbering.
        re.insert(8, &counts(1.5));
        re.flush().unwrap();
        let re2 = MemoStore::open(&path).unwrap();
        assert_eq!(re2.len(), 3);
        assert_eq!(re2.get(7), Some(counts(0.3)));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_final_line_is_dropped_mid_file_corruption_is_not() {
        let path = tmp("torn");
        let store = MemoStore::in_memory();
        store.insert(1, &counts(1.0));
        store.insert(2, &counts(2.0));
        let text = format!(
            "{}\n{}\n{}\n",
            header_json(),
            entry_json(1, &counts(1.0)),
            entry_json(2, &counts(2.0)),
        );
        // Truncate mid-way through the final line (a torn append).
        std::fs::write(&path, &text[..text.len() - 10]).unwrap();
        let re = MemoStore::open(&path).unwrap();
        assert_eq!(re.len(), 1, "torn last line dropped, earlier entries kept");
        assert_eq!(re.get(1), Some(counts(1.0)));

        // The same damage mid-file is corruption, not tolerance.
        let torn_first = format!(
            "{}\n{}\n{}\n",
            header_json(),
            &entry_json(1, &counts(1.0)).to_string()[..20],
            entry_json(2, &counts(2.0)),
        );
        std::fs::write(&path, torn_first).unwrap();
        assert!(MemoStore::open(&path).is_err());

        // Wrong / missing header is rejected outright.
        std::fs::write(&path, format!("{}\n", entry_json(1, &counts(1.0)))).unwrap();
        assert!(MemoStore::open(&path).unwrap_err().to_string().contains("snipsnap_memo"));
        std::fs::write(&path, "{\"snipsnap_memo\":99}\n").unwrap();
        assert!(MemoStore::open(&path).unwrap_err().to_string().contains("schema"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compaction_dedupes_sorts_and_round_trips() {
        let path = tmp("compact");
        let _ = std::fs::remove_file(&path);
        // Four copies of the same entries, the way concurrent processes
        // leave a shared file (keys written in descending order to prove
        // the rewrite canonicalizes).  Dead = 3x live > 2x live.
        let entries: String = (0..8u128)
            .rev()
            .map(|k| format!("{}\n", entry_json(k, &counts(k as f64 + 0.5))))
            .collect();
        std::fs::write(&path, format!("{}\n{entries}{entries}{entries}{entries}", header_json()))
            .unwrap();
        let store = MemoStore::open(&path).unwrap();
        assert_eq!(store.len(), 8);
        // flush with nothing pending still auto-compacts past threshold.
        assert_eq!(store.flush().unwrap(), 0);
        let text = std::fs::read_to_string(&path).unwrap();
        let canonical: String = std::iter::once(format!("{}\n", header_json()))
            .chain((0..8u128).map(|k| format!("{}\n", entry_json(k, &counts(k as f64 + 0.5)))))
            .collect();
        assert_eq!(text, canonical, "header + live entries in ascending key order");
        // Round trip: the compacted file reloads to the same map.
        let re = MemoStore::open(&path).unwrap();
        assert_eq!(re.len(), 8);
        for k in 0..8u128 {
            assert_eq!(re.get(k), Some(counts(k as f64 + 0.5)), "{k}");
        }
        // Idempotence: compacting a compacted store is byte-identical.
        re.compact().unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), canonical);
        re.compact().unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), canonical);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compaction_threshold_spares_append_only_files() {
        let path = tmp("compact_threshold");
        let _ = std::fs::remove_file(&path);
        // Three copies: dead == 2x live, NOT over the threshold — the
        // flush must leave the file byte-identical (append-only wins
        // until duplication actually dominates).
        let entries: String =
            (0..4u128).map(|k| format!("{}\n", entry_json(k, &counts(k as f64)))).collect();
        let text = format!("{}\n{entries}{entries}{entries}", header_json());
        std::fs::write(&path, &text).unwrap();
        let store = MemoStore::open(&path).unwrap();
        assert_eq!(store.flush().unwrap(), 0);
        assert_eq!(std::fs::read_to_string(&path).unwrap(), text);
        // New inserts append, then the accounting still holds.
        store.insert(100, &counts(7.0));
        assert_eq!(store.flush().unwrap(), 1);
        let re = MemoStore::open(&path).unwrap();
        assert_eq!(re.len(), 5);
        assert_eq!(re.get(100), Some(counts(7.0)));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn session_counts_hits_and_misses() {
        let store = MemoStore::in_memory();
        store.insert(5, &counts(4.0));
        let session = MemoSession::new(&store);
        assert_eq!(CountsMemo::get(&session, 5), Some(counts(4.0)));
        assert_eq!(CountsMemo::get(&session, 6), None);
        session.put(6, &counts(6.0));
        assert_eq!(CountsMemo::get(&session, 6), Some(counts(6.0)));
        assert_eq!((session.hits(), session.misses()), (2, 1));
        assert_eq!(store.len(), 2);
    }

    /// The scope digest must shift when any component of the
    /// invalidation key changes.
    #[test]
    fn request_scope_tracks_its_inputs() {
        let run = crate::config::load_run_config(
            "[run]\narch = \"arch1\"\n[[op]]\nname = \"x\"\nm = 8\nn = 8\nk = 8\n",
        )
        .unwrap();
        let base = request_scope(&run.arch, &run.workload, &run.search);
        assert_eq!(base, request_scope(&run.arch, &run.workload, &run.search));

        let mut arch2 = run.arch.clone();
        arch2.data_bits += 8;
        assert_ne!(base, request_scope(&arch2, &run.workload, &run.search));

        let mut w2 = run.workload.clone();
        w2.ops[0].count += 1;
        assert_ne!(base, request_scope(&run.arch, &w2, &run.search));

        let mut cfg2 = run.search.clone();
        cfg2.cost = crate::cost::CostModel::Contention(Default::default());
        assert_ne!(base, request_scope(&run.arch, &run.workload, &cfg2));
    }
}
