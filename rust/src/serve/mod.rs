//! `snipsnap serve` — a long-running co-search service.
//!
//! The service reads one JSON request per line on stdin and writes one
//! JSON response per line on stdout (JSONL), with human-readable
//! per-request stats on stderr.  The wire format for a request **is**
//! the run-config snapshot ([`crate::config::snapshot`]): any snapshot
//! a `snipsnap search` run emitted is a valid request body, optionally
//! wrapped with service-level fields the snapshot loader ignores:
//!
//! ```json
//! {"snipsnap_run_config":1, "arch":{...}, "workload":{...}, "search":{...},
//!  "id":"req-42", "budget":{"wall_time_ms":5000,"max_protos":100000}}
//! ```
//!
//! Every request is therefore replayable by construction — feed the
//! same line back (or hand it to `snipsnap search --config`) and the
//! deterministic co-search reproduces the same designs.  Response lines
//! carry only deterministic fields (designs, totals); the
//! nondeterministic observables (wall time, memo traffic) go to stderr
//! and the per-request [`results record`](crate::report) — so two runs
//! of the same request are byte-identical on stdout.
//!
//! Budgets ([`SearchBudget`]) are enforced *inside* the arena loop via
//! [`SearchLimiter`]: a budget that never fires leaves the result
//! bit-identical to an unbudgeted search, and a fired budget surfaces
//! as an `ok:false` response naming the op that ran out of room.
//!
//! Across requests (and across processes) the service shares a
//! persistent `access_counts` memo ([`memo::MemoStore`]) — see the memo
//! module docs for the bit-identity argument and the invalidation key.

pub mod memo;

use crate::config::snapshot::run_config_from_value;
use crate::config::RunConfig;
use crate::cost::{CacheStats, SharedCounts};
use crate::search::{SearchHooks, SearchLimiter, WorkloadResult};
use crate::util::bench;
use crate::util::json::Json;
use crate::util::pool;
use anyhow::{bail, Context, Result};
use memo::{MemoSession, MemoStore};
use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Version stamped into every response line.
pub const RESPONSE_VERSION: u64 = 1;

/// Per-request search budget: caps enforced cooperatively inside the
/// arena loop (see [`SearchLimiter`]).  Both caps default to unlimited;
/// a request whose budget never fires is bit-identical to an
/// unbudgeted one.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SearchBudget {
    /// Wall-clock cap in milliseconds.
    pub wall_time_ms: Option<u64>,
    /// Cap on protos admitted into the mapping search.
    pub max_protos: Option<u64>,
}

impl SearchBudget {
    /// Parse the request's `budget` object.  Unknown keys are rejected
    /// (a typo'd cap name must not silently mean "unlimited"), and caps
    /// must be non-negative integers.
    pub fn from_json(v: &Json) -> Result<SearchBudget> {
        let Json::Obj(m) = v else { bail!("'budget' must be an object") };
        let mut b = SearchBudget::default();
        for (k, val) in m {
            let cap = Some(
                val.as_u64()
                    .with_context(|| format!("budget '{k}' must be a non-negative integer"))?,
            );
            match k.as_str() {
                "wall_time_ms" => b.wall_time_ms = cap,
                "max_protos" => b.max_protos = cap,
                other => bail!("unknown budget cap '{other}' (wall_time_ms|max_protos)"),
            }
        }
        Ok(b)
    }

    /// The enforcing limiter, or `None` when both caps are unlimited
    /// (no limiter at all keeps the classic search path untouched).
    pub fn limiter(&self) -> Option<SearchLimiter> {
        if self.wall_time_ms.is_none() && self.max_protos.is_none() {
            return None;
        }
        Some(SearchLimiter::new(self.wall_time_ms.map(Duration::from_millis), self.max_protos))
    }
}

/// One parsed service request: a fully-resolved run config plus the
/// service-level wrapper fields.
pub struct SearchRequest {
    /// Caller-chosen correlation id, echoed into the response.
    pub id: Option<String>,
    pub run: RunConfig,
    pub budget: SearchBudget,
}

impl SearchRequest {
    /// Parse one request line (see module docs for the shape).
    pub fn parse(line: &str) -> Result<SearchRequest> {
        let v = Json::parse(line).map_err(|e| anyhow::anyhow!("request: {e}"))?;
        let run = run_config_from_value(&v)?;
        let id = match v.get("id") {
            None | Some(Json::Null) => None,
            Some(other) => {
                Some(other.as_str().context("request 'id' must be a string")?.to_string())
            }
        };
        let budget = match v.get("budget") {
            None | Some(Json::Null) => SearchBudget::default(),
            Some(b) => SearchBudget::from_json(b)?,
        };
        Ok(SearchRequest { id, run, budget })
    }
}

/// Observables of one request: the search telemetry plus the service's
/// own counters.  Reported on stderr and in the per-request results
/// record — never on the response line, because wall time and memo
/// traffic are the two things two identical requests legitimately
/// differ in.
#[derive(Clone, Copy, Debug, Default)]
pub struct SearchStats {
    /// Cost-model evaluations (memo-invariant; see docs/SEARCH.md).
    pub evaluations: u64,
    /// Local per-worker `access_counts` cache counters.
    pub cache: CacheStats,
    /// Legal protos considered across all ops and format pairs.
    pub protos: u64,
    /// Protos skipped by the branch-and-bound lower bound.
    pub pruned: u64,
    /// Cross-run memo store lookups served / missed this request.
    pub memo_hits: u64,
    pub memo_misses: u64,
    /// Wall time of the whole request (parse excluded).
    pub wall_time_s: f64,
    /// True when the request's budget fired before the search finished.
    pub budget_exhausted: bool,
}

impl SearchStats {
    /// Fraction of memo lookups served from the store (0 when none).
    pub fn memo_hit_rate(&self) -> f64 {
        let total = self.memo_hits + self.memo_misses;
        if total == 0 {
            0.0
        } else {
            self.memo_hits as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("evaluations", Json::num(self.evaluations as f64)),
            ("cache_hits", Json::num(self.cache.hits as f64)),
            ("cache_misses", Json::num(self.cache.misses as f64)),
            ("protos", Json::num(self.protos as f64)),
            ("pruned", Json::num(self.pruned as f64)),
            ("memo_hits", Json::num(self.memo_hits as f64)),
            ("memo_misses", Json::num(self.memo_misses as f64)),
            ("wall_time_s", Json::num(self.wall_time_s)),
            ("budget_exhausted", Json::Bool(self.budget_exhausted)),
        ])
    }
}

/// The outcome of one request: the co-search result (or the error
/// string for the `ok:false` response) plus this request's stats.
pub struct SearchResponse {
    pub id: Option<String>,
    pub result: Result<WorkloadResult, String>,
    pub stats: SearchStats,
}

impl SearchResponse {
    /// The deterministic response document (see module docs): protocol
    /// version, echoed id, `ok`, and on success the designs and totals.
    /// Object keys render sorted ([`Json::Obj`] is a `BTreeMap`), so
    /// equal results are byte-equal lines.
    pub fn wire_json(&self) -> Json {
        let id = self.id.as_deref().map(Json::str).unwrap_or(Json::Null);
        match &self.result {
            Ok(r) => {
                let mut rows = vec![
                ("snipsnap_response", Json::num(RESPONSE_VERSION as f64)),
                ("id", id),
                ("ok", Json::Bool(true)),
                ("workload", Json::str(&r.workload)),
                (
                    "designs",
                    Json::arr(r.designs.iter().map(|d| {
                        Json::obj(vec![
                            ("op", Json::str(&d.op_name)),
                            ("input_format", Json::str(&d.input_format.to_string())),
                            ("weight_format", Json::str(&d.weight_format.to_string())),
                            ("input_bits", Json::num(d.input_bits as f64)),
                            ("weight_bits", Json::num(d.weight_bits as f64)),
                            ("energy_pj", Json::num(d.report.total_energy_pj())),
                            ("cycles", Json::num(d.report.latency_cycles())),
                            ("metric_value", Json::num(d.metric_value)),
                            ("count", Json::num(d.count as f64)),
                        ])
                    })),
                ),
                (
                    "totals",
                    Json::obj(vec![
                        ("energy_pj", Json::num(r.total_energy_pj())),
                        ("memory_energy_pj", Json::num(r.memory_energy_pj())),
                        ("cycles", Json::num(r.total_cycles())),
                        ("edp", Json::num(r.edp())),
                    ]),
                ),
                ];
                // Frontier runs add the Pareto summary: the point count
                // and each per-metric winner's total.  All deterministic
                // for a fixed request (the request pins threads/prune),
                // so replays stay byte-identical.
                if let Some(f) = &r.frontier {
                    rows.push((
                        "frontier",
                        Json::obj(vec![
                            ("points", Json::num(f.total_points() as f64)),
                            (
                                "winners",
                                Json::obj(vec![
                                    ("energy_pj", Json::num(f.winner_total(0))),
                                    ("memory_energy_pj", Json::num(f.winner_total(1))),
                                    ("cycles", Json::num(f.winner_total(2))),
                                    ("edp", Json::num(f.winner_total(3))),
                                ]),
                            ),
                        ]),
                    ));
                }
                Json::obj(rows)
            }
            Err(msg) => Json::obj(vec![
                ("snipsnap_response", Json::num(RESPONSE_VERSION as f64)),
                ("id", id),
                ("ok", Json::Bool(false)),
                ("error", Json::str(msg)),
            ]),
        }
    }

    /// The response line (newline included).
    pub fn render(&self) -> String {
        format!("{}\n", self.wire_json())
    }
}

/// Run one parsed request: bind the memo session and budget limiter as
/// [`SearchHooks`] and drive the co-search through the shared run
/// driver ([`crate::driver::execute`]).  Search errors (budget
/// exhaustion, no legal mapping) become `ok:false` responses, never a
/// dead service.
pub fn handle_request(req: &SearchRequest, store: Option<&MemoStore>) -> SearchResponse {
    let start = Instant::now();
    let limiter = req.budget.limiter();
    let session = store.map(MemoSession::new);
    let scope = memo::request_scope(&req.run.arch, &req.run.workload, &req.run.search);
    let hooks = SearchHooks {
        memo: session.as_ref().map(|s| SharedCounts { store: s, scope }),
        limiter: limiter.as_ref(),
    };
    let result = crate::driver::execute(&req.run, hooks);
    let mut stats = SearchStats {
        wall_time_s: start.elapsed().as_secs_f64(),
        budget_exhausted: limiter.as_ref().is_some_and(|l| l.exhausted()),
        memo_hits: session.as_ref().map(|s| s.hits()).unwrap_or(0),
        memo_misses: session.as_ref().map(|s| s.misses()).unwrap_or(0),
        ..SearchStats::default()
    };
    if let Ok(r) = &result {
        stats.evaluations = r.evaluations;
        stats.cache = r.cache;
        stats.protos = r.protos;
        stats.pruned = r.pruned;
    }
    SearchResponse {
        id: req.id.clone(),
        result: result.map_err(|e| format!("{e:#}")),
        stats,
    }
}

/// Parse-and-run one request line.  Parse failures become `ok:false`
/// responses with default stats, so a malformed line costs its sender
/// one error response instead of killing the loop.
pub fn handle_line(line: &str, store: Option<&MemoStore>) -> SearchResponse {
    match SearchRequest::parse(line) {
        Ok(req) => handle_request(&req, store),
        Err(e) => SearchResponse {
            id: None,
            result: Err(format!("{e:#}")),
            stats: SearchStats::default(),
        },
    }
}

/// Service configuration (resolved from the CLI flags in `main`).
pub struct ServeOpts {
    /// Handle exactly one request, then exit (errors if stdin is empty).
    pub once: bool,
    /// Worker threads for concurrent requests; request lines are
    /// batched `jobs` at a time through [`pool::parallel_map`], and
    /// responses always come back in request order.
    pub jobs: usize,
    /// Where per-request unified-schema records land (`serve.jsonl`,
    /// rolled up by `snipsnap report`); `None` disables.
    pub results_dir: Option<PathBuf>,
}

/// What the loop served, for the exit banner.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeSummary {
    pub requests: u64,
    pub failed: u64,
}

/// One stderr stats line per request.  `memo_hits=` is the greppable
/// signal CI uses to prove the cross-run store was actually consulted.
fn log_line(n: u64, resp: &SearchResponse) -> String {
    let s = &resp.stats;
    let id = resp.id.clone().unwrap_or_else(|| format!("#{n}"));
    let outcome = match &resp.result {
        Ok(r) => format!("ok workload={}", r.workload),
        Err(e) => format!("error: {e}"),
    };
    format!(
        "serve: request {id} {outcome} evals={} cache={}/{} memo_hits={} memo_misses={} \
         protos={} pruned={} wall={:.3}s budget_exhausted={}",
        s.evaluations,
        s.cache.hits,
        s.cache.misses,
        s.memo_hits,
        s.memo_misses,
        s.protos,
        s.pruned,
        s.wall_time_s,
        s.budget_exhausted,
    )
}

/// The per-request results record (`rows` of the unified bench schema),
/// so `snipsnap report` rolls service traffic up next to the benches.
fn record_rows(resp: &SearchResponse) -> Json {
    let s = &resp.stats;
    let mut rows = vec![
        ("id", resp.id.as_deref().map(Json::str).unwrap_or(Json::Null)),
        ("ok", Json::Bool(resp.result.is_ok())),
        ("stats", s.to_json()),
    ];
    match &resp.result {
        Ok(r) => {
            rows.push(("workload", Json::str(&r.workload)));
            rows.push(("energy_pj", Json::num(r.total_energy_pj())));
            rows.push(("cycles", Json::num(r.total_cycles())));
            rows.push(("edp", Json::num(r.edp())));
        }
        Err(e) => rows.push(("error", Json::str(e))),
    }
    Json::obj(rows)
}

/// The service loop: read request lines, serve them in order, flush the
/// memo store between batches.  Blank lines are skipped.  I/O errors on
/// the streams are fatal (the peer is gone); per-request failures are
/// in-band `ok:false` responses counted in the summary.
pub fn serve_loop(
    opts: &ServeOpts,
    store: Option<&MemoStore>,
    input: impl BufRead,
    out: &mut impl Write,
    log: &mut impl Write,
) -> Result<ServeSummary> {
    let mut summary = ServeSummary::default();
    let mut lines = input.lines();
    let batch_cap = if opts.once { 1 } else { opts.jobs.max(1) };
    loop {
        // Pull the next batch of non-blank request lines.
        let mut batch: Vec<String> = Vec::with_capacity(batch_cap);
        while batch.len() < batch_cap {
            match lines.next() {
                Some(line) => {
                    let line = line.context("reading request")?;
                    if !line.trim().is_empty() {
                        batch.push(line);
                    }
                }
                None => break,
            }
        }
        if batch.is_empty() {
            if opts.once && summary.requests == 0 {
                bail!("--once: no request on stdin");
            }
            break;
        }
        let responses = pool::parallel_map(batch_cap, &batch, |_, line| {
            handle_line(line, store)
        });
        for resp in &responses {
            summary.requests += 1;
            summary.failed += u64::from(resp.result.is_err());
            out.write_all(resp.render().as_bytes()).context("writing response")?;
            writeln!(log, "{}", log_line(summary.requests, resp)).context("writing stats")?;
            if let Some(dir) = &opts.results_dir {
                bench::write_record_at(dir, "serve", resp.stats.wall_time_s, record_rows(resp));
            }
        }
        out.flush().context("writing response")?;
        // Persist what this batch learned before accepting more work, so
        // a later crash loses at most one batch of memo entries.
        if let Some(s) = store {
            s.flush()?;
        }
        if opts.once {
            break;
        }
    }
    Ok(summary)
}
