//! Minimal TOML-subset parser (serde/toml crates unavailable offline).
//!
//! Supported grammar — the subset our config files use:
//! - `[section]` and `[section.sub]` headers
//! - `[[name]]` arrays of tables (ordered; used for multi-op workloads)
//! - `key = "string" | number | true/false | [array of scalars]`
//! - `#` comments, blank lines
//!
//! Unsupported (rejected with an error): multi-line strings, string
//! escape sequences (any backslash inside a string is an error rather
//! than a silent corruption), inline tables, datetimes, and non-finite
//! numbers (`inf`, `nan` and friends — they would poison every cost
//! computed from the config).  Numeric underscores follow TOML proper:
//! they must sit between two digits (`4_096` yes, `_5`/`1__2`/`5_` no).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_u64().filter(|n| *n <= u32::MAX as u64).map(|n| n as u32)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// One table: key -> value.
pub type TomlTable = BTreeMap<String, TomlValue>;

/// A parsed document: plain sections (section path -> table; the
/// implicit root section is "") plus `[[name]]` arrays of tables, whose
/// elements keep file order.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, TomlTable>,
    pub arrays: BTreeMap<String, Vec<TomlTable>>,
}

#[derive(Debug, Clone)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Where `key = value` lines currently land: the active `[section]` or
/// the latest element of the active `[[name]]` array of tables.
enum Cursor {
    Section(String),
    ArrayElem(String),
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut cursor = Cursor::Section(String::new());
        doc.sections.entry(String::new()).or_default();
        for (ln, raw) in src.lines().enumerate() {
            let err = |msg: &str| TomlError { line: ln + 1, msg: msg.to_string() };
            let line = strip_comment(raw).map_err(|m| err(&m))?.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("[[") {
                let name = rest
                    .strip_suffix("]]")
                    .ok_or_else(|| err("unterminated array-of-tables header"))?
                    .trim();
                if name.is_empty() || name.contains('[') || name.contains(']') {
                    return Err(err("bad array-of-tables header"));
                }
                if doc.sections.contains_key(name) {
                    return Err(err(&format!(
                        "'{name}' is already a [section]; it cannot also be [[{name}]]"
                    )));
                }
                doc.arrays.entry(name.to_string()).or_default().push(TomlTable::new());
                cursor = Cursor::ArrayElem(name.to_string());
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated section header"))?
                    .trim();
                if name.is_empty() || name.contains('[') || name.contains(']') {
                    return Err(err("bad section header"));
                }
                if doc.arrays.contains_key(name) {
                    return Err(err(&format!(
                        "'{name}' is already [[an array of tables]]; it cannot also be [{name}]"
                    )));
                }
                doc.sections.entry(name.to_string()).or_default();
                cursor = Cursor::Section(name.to_string());
                continue;
            }
            let eq = line.find('=').ok_or_else(|| err("expected 'key = value'"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let val = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
            let table = match &cursor {
                Cursor::Section(s) => doc.sections.get_mut(s).unwrap(),
                Cursor::ArrayElem(n) => doc.arrays.get_mut(n).unwrap().last_mut().unwrap(),
            };
            table.insert(key.to_string(), val);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn section(&self, name: &str) -> Option<&TomlTable> {
        self.sections.get(name)
    }

    /// Sections whose path starts with `prefix.` (e.g. all `[op.X]`).
    pub fn sections_under(&self, prefix: &str) -> Vec<(&str, &TomlTable)> {
        let pat = format!("{prefix}.");
        self.sections
            .iter()
            .filter(|(k, _)| k.starts_with(&pat))
            .map(|(k, v)| (k.as_str(), v))
            .collect()
    }

    /// Elements of the `[[name]]` array of tables, in file order (empty
    /// when the document has none).
    pub fn array_of_tables(&self, name: &str) -> &[TomlTable] {
        self.arrays.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

/// Strip a trailing `#` comment, tracking string state so `#` inside
/// quotes survives.  Backslashes inside a string are rejected outright:
/// the subset has no escape sequences, and silently treating `\"` as a
/// quote boundary would flip the string state and corrupt the value.
fn strip_comment(line: &str) -> Result<&str, String> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '\\' if in_str => {
                return Err(
                    "backslash escapes are not supported by the TOML subset".to_string()
                )
            }
            '#' if !in_str => return Ok(&line[..i]),
            _ => {}
        }
    }
    Ok(line)
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.find('"').ok_or("unterminated string")?;
        let body = &rest[..end];
        if body.contains('\\') {
            return Err("backslash escapes are not supported by the TOML subset".into());
        }
        if !rest[end + 1..].trim().is_empty() {
            return Err("trailing characters after string".into());
        }
        return Ok(TomlValue::Str(body.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    parse_number(s).map(TomlValue::Num)
}

/// Parse a numeric literal, enforcing TOML's underscore rule (between
/// two digits only) and rejecting the non-finite spellings Rust's
/// `f64::from_str` would otherwise accept (`inf`, `nan`, `-infinity`,
/// …) as well as finite-looking overflows like `1e999`.
fn parse_number(s: &str) -> Result<f64, String> {
    let b = s.as_bytes();
    for (i, &c) in b.iter().enumerate() {
        if c == b'_' {
            let between_digits = i > 0
                && b[i - 1].is_ascii_digit()
                && i + 1 < b.len()
                && b[i + 1].is_ascii_digit();
            if !between_digits {
                return Err(format!(
                    "malformed underscore in number '{s}' (underscores must sit between digits)"
                ));
            }
        }
    }
    let n = s
        .replace('_', "")
        .parse::<f64>()
        .map_err(|_| format!("cannot parse value '{s}'"))?;
    if !n.is_finite() {
        return Err(format!("non-finite number '{s}' is not a valid TOML value"));
    }
    Ok(n)
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_values() {
        let doc = TomlDoc::parse(
            r#"
# top comment
name = "snipsnap"
[search]
metric = "energy"   # trailing comment
top_k = 4
gamma = 1.05
fixed = false
dims = [2048, 4096, 4_096]
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("snipsnap"));
        assert_eq!(doc.get("search", "top_k").unwrap().as_u64(), Some(4));
        assert_eq!(doc.get("search", "gamma").unwrap().as_f64(), Some(1.05));
        assert_eq!(doc.get("search", "fixed").unwrap().as_bool(), Some(false));
        let dims: Vec<u64> = doc
            .get("search", "dims")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_u64().unwrap())
            .collect();
        assert_eq!(dims, vec![2048, 4096, 4096]);
    }

    #[test]
    fn nested_sections() {
        let doc = TomlDoc::parse("[op.fc1]\nm = 2\n[op.fc2]\nm = 3\n").unwrap();
        let subs = doc.sections_under("op");
        assert_eq!(subs.len(), 2);
        assert_eq!(doc.get("op.fc1", "m").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn arrays_of_tables_keep_order() {
        let doc = TomlDoc::parse(
            "[run]\nx = 1\n[[op]]\nname = \"b\"\nm = 2\n[[op]]\nname = \"a\"\nm = 3\n",
        )
        .unwrap();
        let ops = doc.array_of_tables("op");
        assert_eq!(ops.len(), 2);
        assert_eq!(ops[0].get("name").unwrap().as_str(), Some("b"));
        assert_eq!(ops[1].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(ops[1].get("m").unwrap().as_u64(), Some(3));
        assert!(doc.array_of_tables("missing").is_empty());
        // Keys after a [[op]] header land in that element, not in [run].
        assert!(doc.get("run", "name").is_none());
    }

    #[test]
    fn section_and_array_names_cannot_collide() {
        let e = TomlDoc::parse("[op]\nm = 1\n[[op]]\nm = 2\n").unwrap_err();
        assert_eq!(e.line, 3);
        assert!(e.msg.contains("already a [section]"), "{e}");
        let e = TomlDoc::parse("[[op]]\nm = 2\n[op]\nm = 1\n").unwrap_err();
        assert_eq!(e.line, 3);
        assert!(e.msg.contains("array of tables"), "{e}");
        assert!(TomlDoc::parse("[[x]\n").is_err());
        assert!(TomlDoc::parse("[[]]\n").is_err());
    }

    #[test]
    fn error_reporting_with_line_numbers() {
        let e = TomlDoc::parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = TomlDoc::parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
        assert!(TomlDoc::parse("x = [1, 2\n").is_err());
        assert!(TomlDoc::parse("x = \"abc\ndef\"\n").is_err());
    }

    /// Regression: `inf`/`nan`/`-infinity` parsed as numbers via
    /// `f64::from_str`, and any underscore placement was accepted.
    #[test]
    fn rejects_non_finite_and_malformed_underscore_numbers() {
        for bad in [
            "x = inf\n",
            "x = -inf\n",
            "x = nan\n",
            "x = -infinity\n",
            "x = Infinity\n",
            "x = 1e999\n",
            "x = _5\n",
            "x = 5_\n",
            "x = 1__2\n",
            "x = _\n",
            "x = 1._5\n",
            "x = [1, inf]\n",
        ] {
            let e = TomlDoc::parse(bad).unwrap_err();
            assert_eq!(e.line, 1, "{bad}");
        }
        let e = TomlDoc::parse("ok = 1\nx = nan\n").unwrap_err();
        assert_eq!(e.line, 2, "errors must carry the offending line");
        // Well-placed underscores still work.
        let doc = TomlDoc::parse("a = 5_0\nb = 1_000.5\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_u64(), Some(50));
        assert_eq!(doc.get("", "b").unwrap().as_f64(), Some(1000.5));
    }

    /// Regression: a `\"` inside a string used to flip the string state
    /// in `strip_comment` and silently corrupt the value.  The subset
    /// rejects backslashes in strings outright.
    #[test]
    fn rejects_backslash_escapes_in_strings() {
        for bad in [
            "x = \"a\\\"b\"\n",
            "x = \"a\\nb\"\n",
            "x = \"C:\\path\"\n",
            "x = [\"a\\\\b\"]\n",
            "x = \"a\\\" # not a comment\"\n",
        ] {
            let e = TomlDoc::parse(bad).unwrap_err();
            assert!(e.msg.contains("backslash"), "{bad}: {e}");
        }
        // Backslashes in comments are fine (never inside a string).
        let doc = TomlDoc::parse("x = 1 # C:\\temp\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn hash_inside_string_survives() {
        let doc = TomlDoc::parse("x = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn u64_rejects_negative_and_fractional() {
        let doc = TomlDoc::parse("a = -1\nb = 1.5\nc = 3\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_u64(), None);
        assert_eq!(doc.get("", "b").unwrap().as_u64(), None);
        assert_eq!(doc.get("", "c").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn u32_bounds() {
        let doc = TomlDoc::parse("a = 4\nb = 4294967296\nc = -2\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_u32(), Some(4));
        assert_eq!(doc.get("", "b").unwrap().as_u32(), None);
        assert_eq!(doc.get("", "c").unwrap().as_u32(), None);
    }
}
