//! Minimal TOML-subset parser (serde/toml crates unavailable offline).
//!
//! Supported grammar — the subset our config files use:
//! - `[section]` and `[section.sub]` headers
//! - `key = "string" | number | true/false | [array of scalars]`
//! - `#` comments, blank lines
//!
//! Unsupported (rejected with an error): multi-line strings, inline
//! tables, arrays of tables, datetimes.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_u64().filter(|n| *n <= u32::MAX as u64).map(|n| n as u32)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: section path -> key -> value.  The implicit root
/// section is "".
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

#[derive(Debug, Clone)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut current = String::new();
        doc.sections.entry(current.clone()).or_default();
        for (ln, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { line: ln + 1, msg: msg.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated section header"))?
                    .trim();
                if name.is_empty() || name.starts_with('[') {
                    return Err(err("arrays of tables are not supported"));
                }
                current = name.to_string();
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| err("expected 'key = value'"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let val = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
            doc.sections
                .get_mut(&current)
                .unwrap()
                .insert(key.to_string(), val);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn section(&self, name: &str) -> Option<&BTreeMap<String, TomlValue>> {
        self.sections.get(name)
    }

    /// Sections whose path starts with `prefix.` (e.g. all `[op.X]`).
    pub fn sections_under(&self, prefix: &str) -> Vec<(&str, &BTreeMap<String, TomlValue>)> {
        let pat = format!("{prefix}.");
        self.sections
            .iter()
            .filter(|(k, _)| k.starts_with(&pat))
            .map(|(k, v)| (k.as_str(), v))
            .collect()
    }
}

fn strip_comment(line: &str) -> &str {
    // Track string state so '#' inside quotes survives.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.find('"').ok_or("unterminated string")?;
        if !rest[end + 1..].trim().is_empty() {
            return Err("trailing characters after string".into());
        }
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    let cleaned = s.replace('_', "");
    cleaned
        .parse::<f64>()
        .map(TomlValue::Num)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_values() {
        let doc = TomlDoc::parse(
            r#"
# top comment
name = "snipsnap"
[search]
metric = "energy"   # trailing comment
top_k = 4
gamma = 1.05
fixed = false
dims = [2048, 4096, 4_096]
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("snipsnap"));
        assert_eq!(doc.get("search", "top_k").unwrap().as_u64(), Some(4));
        assert_eq!(doc.get("search", "gamma").unwrap().as_f64(), Some(1.05));
        assert_eq!(doc.get("search", "fixed").unwrap().as_bool(), Some(false));
        let dims: Vec<u64> = doc
            .get("search", "dims")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_u64().unwrap())
            .collect();
        assert_eq!(dims, vec![2048, 4096, 4096]);
    }

    #[test]
    fn nested_sections() {
        let doc = TomlDoc::parse("[op.fc1]\nm = 2\n[op.fc2]\nm = 3\n").unwrap();
        let subs = doc.sections_under("op");
        assert_eq!(subs.len(), 2);
        assert_eq!(doc.get("op.fc1", "m").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn error_reporting_with_line_numbers() {
        let e = TomlDoc::parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = TomlDoc::parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
        assert!(TomlDoc::parse("x = [1, 2\n").is_err());
        assert!(TomlDoc::parse("x = \"abc\ndef\"\n").is_err());
    }

    #[test]
    fn hash_inside_string_survives() {
        let doc = TomlDoc::parse("x = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn u64_rejects_negative_and_fractional() {
        let doc = TomlDoc::parse("a = -1\nb = 1.5\nc = 3\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_u64(), None);
        assert_eq!(doc.get("", "b").unwrap().as_u64(), None);
        assert_eq!(doc.get("", "c").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn u32_bounds() {
        let doc = TomlDoc::parse("a = 4\nb = 4294967296\nc = -2\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_u32(), Some(4));
        assert_eq!(doc.get("", "b").unwrap().as_u32(), None);
        assert_eq!(doc.get("", "c").unwrap().as_u32(), None);
    }
}
