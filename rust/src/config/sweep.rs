//! Sweep plans: a TOML cross-product of run-config axes, expanded to an
//! ordered list of fully-resolved run configurations (docs/SWEEP.md).
//!
//! A plan is a normal run config (`[run]`/`[workload]`/`[search]`/
//! `[cost]`/`[quant]` — the shared base every config starts from) plus
//! a `[sweep]` header and `[[sweep.axis]]` tables:
//!
//! ```toml
//! [run]
//! arch = "arch3"
//! mode = "fixed"
//!
//! [sweep]
//! name = "scenarios"          # roll-up file stem; default "sweep"
//!
//! [[sweep.axis]]
//! key = "workload"            # any key in AXIS_KEYS (CLI spellings)
//! values = ["gqa-tiny", "moe-tiny"]
//!
//! [[sweep.axis]]
//! key = "metric"
//! values = ["energy", "frontier"]
//! ```
//!
//! Expansion is the cross-product of the axes in file order, **first
//! axis slowest** (odometer order), so the example yields
//! `gqa-tiny×energy, gqa-tiny×frontier, moe-tiny×energy,
//! moe-tiny×frontier` with ids `scenarios-0..scenarios-3` (zero-padded
//! to a fixed width so ids sort lexicographically in plan order).  Each
//! combination resolves through [`resolve_run_config`] with the axis
//! values as [`RunOverrides`] — exactly the CLI-flag composition rules.
//! The expansion order is a pure function of the plan text, which is
//! half of the sweep-determinism argument (`crate::driver::sweep` has
//! the other half).

use super::toml::{TomlDoc, TomlValue};
use super::typed::{resolve_run_config, RunConfig, RunOverrides};
use crate::format::quant::BitwidthSpace;
use anyhow::{anyhow, bail, Context, Result};

/// The sweepable axes, named by their CLI-flag spellings.
pub const AXIS_KEYS: &[&str] = &[
    "arch",
    "workload",
    "metric",
    "mode",
    "threads",
    "cost-backend",
    "w-bits",
    "a-bits",
    "kv-bits",
];

/// Hard cap on expanded configs — a typo'd axis must not OOM the
/// coordinator building plans.
pub const MAX_CONFIGS: usize = 100_000;

/// One expanded sweep entry: its stable id and resolved config.
pub struct SweepEntry {
    /// `<name>-<index>`, zero-padded; also the per-config response id.
    pub id: String,
    pub run: RunConfig,
}

/// A loaded plan: the sweep name plus the expanded entries in
/// deterministic plan order.
pub struct SweepPlan {
    pub name: String,
    pub entries: Vec<SweepEntry>,
}

/// One parsed `[[sweep.axis]]`: the override key and its values.
struct Axis {
    key: String,
    values: Vec<TomlValue>,
}

/// Parse a bitwidth axis value: a `"4,8,16"` string, an integer, or an
/// array of integers.
fn bits_space(key: &str, v: &TomlValue) -> Result<BitwidthSpace> {
    match v {
        TomlValue::Str(s) => {
            BitwidthSpace::parse(s).map_err(|e| anyhow!("axis '{key}': {e}"))
        }
        TomlValue::Arr(a) => {
            let bits = a
                .iter()
                .enumerate()
                .map(|(i, x)| {
                    x.as_u32()
                        .ok_or_else(|| anyhow!("axis '{key}'[{i}] must be an integer"))
                })
                .collect::<Result<Vec<u32>>>()?;
            BitwidthSpace::new(bits).map_err(|e| anyhow!("axis '{key}': {e}"))
        }
        other => {
            let b = other
                .as_u32()
                .ok_or_else(|| anyhow!("axis '{key}' values must be widths"))?;
            BitwidthSpace::new(vec![b]).map_err(|e| anyhow!("axis '{key}': {e}"))
        }
    }
}

/// Apply one axis value to the overrides under construction.
fn apply_axis_value(ov: &mut RunOverrides, key: &str, v: &TomlValue) -> Result<()> {
    let want_str = || {
        v.as_str()
            .map(str::to_string)
            .ok_or_else(|| anyhow!("axis '{key}' values must be strings"))
    };
    match key {
        "arch" => ov.arch = Some(want_str()?),
        "workload" => ov.workload = Some(want_str()?),
        "metric" => ov.metric = Some(want_str()?),
        "mode" => ov.mode = Some(want_str()?),
        "threads" => {
            ov.threads = Some(
                v.as_u64()
                    .ok_or_else(|| anyhow!("axis 'threads' values must be integers"))?
                    as usize,
            )
        }
        "cost-backend" => ov.backend = Some(want_str()?),
        "w-bits" => ov.w_bits = Some(bits_space(key, v)?),
        "a-bits" => ov.a_bits = Some(bits_space(key, v)?),
        "kv-bits" => ov.kv_bits = Some(bits_space(key, v)?),
        other => bail!("unknown sweep axis '{other}' (one of {})", AXIS_KEYS.join(", ")),
    }
    Ok(())
}

/// Load and expand a sweep plan from TOML text.
pub fn load_sweep_plan(src: &str) -> Result<SweepPlan> {
    let doc = TomlDoc::parse(src).map_err(|e| anyhow!("{e}"))?;
    expand_sweep(&doc)
}

/// Expand a parsed plan document: validate the axes, walk the
/// cross-product in odometer order (first axis slowest), and resolve
/// every combination into a [`SweepEntry`].
pub fn expand_sweep(doc: &TomlDoc) -> Result<SweepPlan> {
    let name = doc
        .section("sweep")
        .and_then(|s| s.get("name"))
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("[sweep] name must be a string"))
        })
        .transpose()?
        .unwrap_or_else(|| "sweep".to_string());
    if name.is_empty()
        || !name.chars().all(|c| c.is_ascii_alphanumeric() || "._-".contains(c))
    {
        bail!(
            "[sweep] name '{name}' must be non-empty and use only \
             letters, digits, '.', '_', '-' (it names the roll-up file)"
        );
    }

    let mut axes: Vec<Axis> = Vec::new();
    for (i, sec) in doc.array_of_tables("sweep.axis").iter().enumerate() {
        let key = sec
            .get("key")
            .and_then(|v| v.as_str())
            .with_context(|| format!("[[sweep.axis]] #{i}: 'key' must be a string"))?;
        if !AXIS_KEYS.contains(&key) {
            bail!(
                "[[sweep.axis]] #{i}: unknown key '{key}' (one of {})",
                AXIS_KEYS.join(", ")
            );
        }
        if axes.iter().any(|a| a.key == key) {
            bail!("[[sweep.axis]] #{i}: duplicate axis '{key}'");
        }
        let values = sec
            .get("values")
            .and_then(|v| v.as_arr())
            .with_context(|| format!("[[sweep.axis]] #{i}: 'values' must be an array"))?;
        if values.is_empty() {
            bail!("[[sweep.axis]] #{i}: axis '{key}' has no values");
        }
        axes.push(Axis { key: key.to_string(), values: values.to_vec() });
    }

    let total: usize = axes.iter().map(|a| a.values.len()).product();
    if total > MAX_CONFIGS {
        bail!("sweep expands to {total} configs, above the {MAX_CONFIGS} cap");
    }
    // Zero-pad ids to the widest index so lexicographic order == plan
    // order (stable filenames, stable report rows).
    let width = (total.max(1) - 1).to_string().len();
    let mut entries = Vec::with_capacity(total.max(1));
    for idx in 0..total.max(1) {
        // Odometer decode: first axis is the slowest-varying digit.
        let mut digits = vec![0usize; axes.len()];
        let mut rem = idx;
        for ai in (0..axes.len()).rev() {
            digits[ai] = rem % axes[ai].values.len();
            rem /= axes[ai].values.len();
        }
        let mut ov = RunOverrides::default();
        for (ai, axis) in axes.iter().enumerate() {
            apply_axis_value(&mut ov, &axis.key, &axis.values[digits[ai]])?;
        }
        let id = format!("{name}-{idx:0width$}");
        let run =
            resolve_run_config(doc, &ov).with_context(|| format!("sweep config {id}"))?;
        entries.push(SweepEntry { id, run });
    }
    Ok(SweepPlan { name, entries })
}
