//! Typed configuration loaders: turn a [`TomlDoc`] into accelerators,
//! workloads and search settings.
//!
//! A run config looks like:
//!
//! ```toml
//! [run]
//! arch = "arch3"            # preset name, or define [arch] inline
//! workload = "llama2-7b"    # preset name, or define [op.*] tables
//! metric = "energy"         # energy | memory-energy | latency | edp | frontier
//! mode = "search"           # search | fixed
//!
//! [search]
//! gamma = 1.05
//! top_k = 4
//! max_depth = 4
//! max_mappings = 40000
//! threads = 4               # co-search worker threads (0 = all cores)
//! prune = true              # branch-and-bound pruning (results are
//!                           # identical either way; default true)
//! best_first = true         # visit protos in ascending lower-bound
//!                           # order (telemetry-only effect; default true)
//!
//! # Optional preset modifiers (scenario knobs):
//! [workload]
//! preset = "llama3-8b"      # overrides [run] workload when present
//! prefill_tokens = 512
//! decode_tokens = 64
//! batch = 4                 # concurrent sequences (batched decode)
//! kv_density = 0.5          # KV-cache density on the A x V op, (0, 1]
//! nm = "2:4"                # N:M weight sparsity (also: nm = [2, 4])
//!
//! # Optional custom workload (named sections):
//! [op.fc1]
//! m = 2048
//! n = 4096
//! k = 16384
//! act_density = 0.4
//! wgt_density = 0.5
//! count = 32
//!
//! # ...or as an ordered TOML array of tables — the natural shape for
//! # multi-op workloads (ops keep file order; `name` is optional and
//! # defaults to `op<index>`):
//! [[op]]
//! name = "qkv"
//! m = 2048
//! n = 4096
//! k = 4096
//! act_density = 0.4
//! wgt_density = 0.5
//! count = 32
//! [[op]]
//! name = "fc1"
//! m = 2048
//! n = 4096
//! k = 16384
//!
//! # Optional cost backend (docs/COST.md; omit for the analytical
//! # default).  Per-level knobs take a scalar (broadcast to every
//! # boundary) or an array overriding a prefix of boundaries,
//! # outermost first; unlisted boundaries keep their defaults.
//! [cost]
//! backend = "contention"    # analytical (default) | contention
//! bandwidth_derate = 0.85   # achievable fraction of peak bw, (0, 1]
//! burst_bits = [512, 128]   # transaction granularity per boundary
//! decompress_bits_per_cycle = 4096   # 0 disables the decode term
//!
//! # Optional quantization axis (docs/SEARCH.md): payload bitwidths per
//! # operand class — a fixed integer pins the width, an array hands the
//! # choice to the co-search.  Absent keys stay at the accelerator's
//! # data_bits (axis disabled = bit-identical to the pre-quant flow).
//! [quant]
//! w_bits = [4, 8, 16]       # weight payload widths to search
//! a_bits = 8                # activation payload width (fixed)
//! kv_bits = 8               # KV-cache width (attention qk/av weight slot)
//!
//! # Optional custom accelerator:
//! [arch]
//! macs = 2048
//! spatial_rows = 64
//! spatial_cols = 32
//! data_bits = 16
//! clock_ghz = 1.2
//! reduction = "skipping-both"
//! native_format = "Bitmap"
//! # levels: name, capacity KiB (0 = unbounded), read pJ/word, write
//! # pJ/word, bandwidth bits/cycle
//! level0 = ["DRAM", 0, 200.0, 200.0, 128]
//! level1 = ["L2", 512, 8.0, 8.0, 1024]
//! level2 = ["OpBuf", 128, 1.5, 1.5, 8192]
//! ```

use super::toml::{TomlDoc, TomlTable, TomlValue};
use crate::arch::{presets, Accelerator, MacArray, MemLevel};
use crate::cost::{CostModel, Metric};
use crate::dataflow::{ProblemDims, MAX_LEVELS};
use crate::format::quant::{BitwidthSpace, QuantConfig};
use crate::search::{FormatMode, SearchConfig};
use crate::sparsity::reduction::{Direction, ReductionStrategy};
use crate::sparsity::{validate_density, SparsitySpec};
use crate::workload::{gqa, llm, moe, MatMulOp, Workload};
use anyhow::{anyhow, bail, Context, Result};

/// A fully-resolved run configuration.
pub struct RunConfig {
    pub arch: Accelerator,
    pub workload: Workload,
    pub search: SearchConfig,
}

/// Resolve an accelerator preset by name.
pub fn arch_by_name(name: &str) -> Result<Accelerator> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "arch1" => presets::arch1(),
        "arch2" => presets::arch2(),
        "arch3" => presets::arch3(),
        "arch4" => presets::arch4(),
        "scnn" => presets::scnn(),
        "dstc" => presets::dstc_validation(),
        other => bail!("unknown arch preset '{other}' (arch1-4, scnn, dstc)"),
    })
}

/// Scenario modifiers applied on top of a workload preset (from CLI
/// flags or the `[workload]` TOML section).  `None` keeps the preset's
/// default for that knob.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WorkloadOpts {
    pub prefill_tokens: Option<u64>,
    pub decode_tokens: Option<u64>,
    /// Concurrent sequences (batched decode; must be >= 1).
    pub batch: Option<u64>,
    /// KV-cache density on the A x V op (must lie in `(0, 1]`).
    pub kv_density: Option<f64>,
    /// N:M structured weight sparsity applied after building.
    pub nm: Option<(u32, u32)>,
}

impl WorkloadOpts {
    fn is_default(&self) -> bool {
        *self == WorkloadOpts::default()
    }

    fn validate(&self) -> Result<()> {
        if self.batch == Some(0) {
            bail!("batch must be >= 1");
        }
        if let Some(d) = self.kv_density {
            validate_density(d).map_err(|e| anyhow!("kv_density: {e}"))?;
        }
        if let Some((n, m)) = self.nm {
            if n == 0 || n > m {
                bail!("N:M sparsity needs 1 <= N <= M, got {n}:{m}");
            }
        }
        Ok(())
    }
}

/// Parse an `N:M` sparsity spec like `"2:4"`.
pub fn parse_nm(s: &str) -> Result<(u32, u32)> {
    let (n, m) = s
        .split_once(':')
        .with_context(|| format!("N:M spec '{s}' must look like '2:4'"))?;
    let n: u32 = n.trim().parse().with_context(|| format!("N in '{s}'"))?;
    let m: u32 = m.trim().parse().with_context(|| format!("M in '{s}'"))?;
    Ok((n, m))
}

/// Resolve a workload preset by name with scenario modifiers applied.
/// The modifier knobs only make sense for the transformer presets; using
/// them with a CNN preset is an error rather than a silent no-op.
pub fn resolve_workload(name: &str, opts: &WorkloadOpts) -> Result<Workload> {
    opts.validate()?;
    let lname = name.to_ascii_lowercase();

    // Per-preset default phase; the small models and the tiny scenario
    // presets default to short sequences.
    let base = match lname.as_str() {
        "opt-125m" | "gqa-tiny" | "moe-tiny" => llm::Phase::new(256, 32),
        "bert-base" => llm::Phase::prefill_only(256),
        "decode-tiny" => llm::Phase::new(0, 16).with_batch(4).with_kv_density(0.5),
        "llama2-7b-batch8" => llm::Phase::default_prefill_decode().with_batch(8),
        _ => llm::Phase::default_prefill_decode(),
    };
    let mut ph = base;
    if let Some(p) = opts.prefill_tokens {
        ph.prefill_tokens = p;
    }
    if let Some(d) = opts.decode_tokens {
        ph.decode_tokens = d;
    }
    if let Some(b) = opts.batch {
        ph.batch = b;
    }
    if let Some(d) = opts.kv_density {
        ph.kv_density = d;
    }
    if ph.prefill_tokens == 0 && ph.decode_tokens == 0 {
        bail!("workload '{name}' would have no tokens (prefill and decode both 0)");
    }

    let cnn_guard = || -> Result<()> {
        if !opts.is_default() {
            bail!(
                "workload modifiers (--prefill/--decode/--batch/--kv-density/--nm) \
                 only apply to transformer presets, not '{name}'"
            );
        }
        Ok(())
    };
    let mut w = match lname.as_str() {
        "llama2-7b" | "llama2-7b-batch8" => llm::llama2_7b(ph),
        "llama2-7b-nm24" => llm::weight_nm_variant(llm::llama2_7b(ph), 2, 4),
        // Quantized variants: same ops; the bundled QuantConfig rides in
        // via [`preset_quant`] (callers apply it to `search.quant`).
        "llama2-7b-w4a8" => {
            let mut w = llm::llama2_7b(ph);
            w.name.push_str(" (W4A8)");
            w
        }
        "llama2-7b-qsearch" => {
            let mut w = llm::llama2_7b(ph);
            w.name.push_str(" (quant search)");
            w
        }
        "llama2-13b" => llm::llama2_13b(ph),
        "opt-125m" => llm::opt_125m(ph),
        "opt-6.7b" => llm::opt_6_7b(ph),
        "opt-13b" => llm::opt_13b(ph),
        "opt-30b" => llm::opt_30b(ph),
        "bert-base" => llm::bert_base_phase(ph),
        "decode-tiny" if opts.is_default() => llm::decode_tiny(),
        // Overridden phase: rebuild the same shape/sparsity around it.
        "decode-tiny" => llm::decode_tiny_phase("Decode-Tiny (custom)", ph),
        "llama3-8b" => gqa::llama3_8b(ph),
        "llama3-70b" => gqa::llama3_70b(ph),
        "mistral-7b" => gqa::mistral_7b(ph),
        "gqa-tiny" => gqa::gqa_tiny(ph),
        "mixtral-8x7b" => moe::mixtral_8x7b(ph),
        "moe-tiny" => moe::moe_tiny(ph),
        "alexnet" => {
            cnn_guard()?;
            crate::workload::cnn::alexnet()
        }
        "vgg-16" | "vgg16" => {
            cnn_guard()?;
            crate::workload::cnn::vgg16()
        }
        "resnet-18" | "resnet18" => {
            cnn_guard()?;
            crate::workload::cnn::resnet18()
        }
        other => bail!("unknown workload preset '{other}'"),
    };
    if let Some((n, m)) = opts.nm {
        w = llm::weight_nm_variant(w, n, m);
    }
    Ok(w)
}

/// Resolve a workload preset by name with its default scenario knobs.
pub fn workload_by_name(name: &str) -> Result<Workload> {
    resolve_workload(name, &WorkloadOpts::default())
}

/// The quantization axis bundled with a workload preset, if any.  Most
/// presets carry none (axis disabled); the quantized variants pin or
/// search payload widths.  Callers resolving a preset by name apply this
/// to `search.quant` before `[quant]` sections / `--*-bits` flags, which
/// override per key.
pub fn preset_quant(name: &str) -> Option<QuantConfig> {
    match name.to_ascii_lowercase().as_str() {
        "llama2-7b-w4a8" => Some(QuantConfig {
            w_bits: Some(BitwidthSpace::fixed(4)),
            a_bits: Some(BitwidthSpace::fixed(8)),
            kv_bits: Some(BitwidthSpace::fixed(8)),
        }),
        "llama2-7b-qsearch" => Some(QuantConfig {
            w_bits: Some(BitwidthSpace::new(vec![4, 8, 16]).expect("static set")),
            a_bits: Some(BitwidthSpace::new(vec![8, 16]).expect("static set")),
            kv_bits: Some(BitwidthSpace::new(vec![8, 16]).expect("static set")),
        }),
        _ => None,
    }
}

pub fn metric_by_name(name: &str) -> Result<Metric> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "energy" => Metric::Energy,
        "memory-energy" | "memory_energy" => Metric::MemoryEnergy,
        "latency" => Metric::Latency,
        "edp" => Metric::Edp,
        "frontier" => Metric::Frontier,
        other => bail!("unknown metric '{other}'"),
    })
}

pub(crate) fn reduction_by_name(name: &str) -> Result<ReductionStrategy> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "none" => ReductionStrategy::NONE,
        "gating-input" => ReductionStrategy::gating(Direction::InputOnly),
        "gating-weight" => ReductionStrategy::gating(Direction::WeightOnly),
        "gating-both" => ReductionStrategy::gating(Direction::Both),
        "skipping-input" => ReductionStrategy::skipping(Direction::InputOnly),
        "skipping-weight" => ReductionStrategy::skipping(Direction::WeightOnly),
        "skipping-both" => ReductionStrategy::skipping(Direction::Both),
        other => bail!("unknown reduction '{other}'"),
    })
}

fn parse_level(v: &TomlValue) -> Result<MemLevel> {
    let a = v.as_arr().ok_or_else(|| anyhow!("level must be an array"))?;
    if a.len() != 5 {
        bail!("level needs [name, KiB, read pJ/word, write pJ/word, bw]");
    }
    let name = a[0].as_str().ok_or_else(|| anyhow!("level name"))?;
    let kib = a[1].as_f64().ok_or_else(|| anyhow!("capacity"))?;
    let read = a[2].as_f64().ok_or_else(|| anyhow!("read pJ"))?;
    let write = a[3].as_f64().ok_or_else(|| anyhow!("write pJ"))?;
    let bw = a[4].as_f64().ok_or_else(|| anyhow!("bandwidth"))?;
    let word = 16.0;
    Ok(MemLevel {
        name: name.to_string(),
        capacity_bits: if kib == 0.0 { u64::MAX } else { (kib * 1024.0 * 8.0) as u64 },
        read_pj_per_bit: read / word,
        write_pj_per_bit: write / word,
        bandwidth_bits_per_cycle: bw,
    })
}

fn parse_inline_arch(doc: &TomlDoc) -> Result<Option<Accelerator>> {
    let Some(sec) = doc.section("arch") else { return Ok(None) };
    if sec.is_empty() {
        return Ok(None);
    }
    let get_u = |k: &str| -> Result<u64> {
        sec.get(k)
            .and_then(|v| v.as_u64())
            .ok_or_else(|| anyhow!("[arch] missing integer '{k}'"))
    };
    let mut levels = Vec::new();
    for i in 0.. {
        match sec.get(&format!("level{i}")) {
            Some(v) => levels.push(parse_level(v)?),
            None => break,
        }
    }
    if levels.is_empty() {
        bail!("[arch] needs level0..levelN");
    }
    let arch = Accelerator {
        name: sec
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("custom")
            .to_string(),
        mac: MacArray {
            total_macs: get_u("macs")?,
            spatial_rows: get_u("spatial_rows")?,
            spatial_cols: get_u("spatial_cols")?,
            pj_per_mac: sec.get("pj_per_mac").and_then(|v| v.as_f64()).unwrap_or(1.0),
        },
        levels,
        reduction: reduction_by_name(
            sec.get("reduction")
                .and_then(|v| v.as_str())
                .unwrap_or("skipping-both"),
        )?,
        data_bits: get_u("data_bits").unwrap_or(16) as u32,
        clock_ghz: sec.get("clock_ghz").and_then(|v| v.as_f64()).unwrap_or(1.0),
        native_format: sec
            .get("native_format")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string()),
        codec_area_overhead: sec
            .get("codec_area_overhead")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.05),
    };
    arch.validate().map_err(|e| anyhow!(e))?;
    Ok(Some(arch))
}

/// Parse one custom MatMul op from a `[op.NAME]` section or a `[[op]]`
/// table element.
fn parse_op(name: &str, sec: &TomlTable) -> Result<MatMulOp> {
    let get_u = |k: &str| -> Result<u64> {
        sec.get(k)
            .and_then(|v| v.as_u64())
            .ok_or_else(|| anyhow!("[{name}] missing integer '{k}'"))
    };
    let get_density = |k: &str| -> Result<f64> {
        let d = sec.get(k).and_then(|v| v.as_f64()).unwrap_or(1.0);
        validate_density(d).map_err(|e| anyhow!("[{name}] {k}: {e}"))?;
        Ok(d)
    };
    Ok(MatMulOp {
        name: name.to_string(),
        dims: ProblemDims::new(get_u("m")?, get_u("n")?, get_u("k")?),
        spec: SparsitySpec::unstructured(
            get_density("act_density")?,
            get_density("wgt_density")?,
        ),
        count: sec.get("count").and_then(|v| v.as_u64()).unwrap_or(1),
    })
}

fn parse_inline_workload(doc: &TomlDoc) -> Result<Option<Workload>> {
    let subs = doc.sections_under("op");
    let tables = doc.array_of_tables("op");
    if !subs.is_empty() && !tables.is_empty() {
        bail!("define the workload with either [op.NAME] sections or [[op]] tables, not both");
    }
    if subs.is_empty() && tables.is_empty() {
        return Ok(None);
    }
    let mut ops = Vec::new();
    for (name, sec) in subs {
        ops.push(parse_op(name.trim_start_matches("op."), sec)?);
    }
    for (i, sec) in tables.iter().enumerate() {
        let name = match sec.get("name") {
            Some(v) => v
                .as_str()
                .ok_or_else(|| anyhow!("[[op]] #{i}: 'name' must be a string"))?
                .to_string(),
            None => format!("op{i}"),
        };
        ops.push(parse_op(&name, sec)?);
    }
    if let Some(dup) = ops
        .iter()
        .enumerate()
        .find(|(i, o)| ops[..*i].iter().any(|p| p.name == o.name))
        .map(|(_, o)| o.name.clone())
    {
        bail!(
            "custom workload has duplicate op name '{dup}' \
             (unnamed [[op]] tables default to op<index>; name every op explicitly to avoid clashes)"
        );
    }
    Ok(Some(Workload { name: "custom".to_string(), ops }))
}

/// Fill a per-boundary knob array from a TOML value: a scalar
/// broadcasts to every boundary; an array overrides a prefix of
/// boundaries (outermost first), leaving the rest at their defaults.
fn fill_levels(sec: &TomlTable, key: &str, out: &mut [f64; MAX_LEVELS]) -> Result<()> {
    let Some(v) = sec.get(key) else { return Ok(()) };
    match v {
        TomlValue::Arr(a) => {
            if a.is_empty() || a.len() > MAX_LEVELS {
                bail!("[cost] {key} must have 1..={MAX_LEVELS} entries");
            }
            for (i, x) in a.iter().enumerate() {
                out[i] = x
                    .as_f64()
                    .ok_or_else(|| anyhow!("[cost] {key}[{i}] must be a number"))?;
            }
        }
        other => {
            let x = other
                .as_f64()
                .ok_or_else(|| anyhow!("[cost] {key} must be a number or an array"))?;
            out.fill(x);
        }
    }
    Ok(())
}

/// Parse the optional `[cost]` section into `search.cost`.  Absent (or
/// empty) section keeps the analytical default; contention knobs on the
/// analytical backend are an error rather than a silent no-op.
fn parse_cost_section(doc: &TomlDoc, search: &mut SearchConfig) -> Result<()> {
    let Some(sec) = doc.section("cost") else { return Ok(()) };
    if sec.is_empty() {
        return Ok(());
    }
    let backend = sec.get("backend").and_then(|v| v.as_str()).unwrap_or("analytical");
    let knobs = ["bandwidth_derate", "burst_bits", "decompress_bits_per_cycle"];
    let mut model = CostModel::by_name(backend).map_err(|e| anyhow!("[cost] {e}"))?;
    match &mut model {
        CostModel::Analytical => {
            if let Some(k) = knobs.iter().find(|&&k| sec.get(k).is_some()) {
                bail!("[cost] {k} requires backend = \"contention\"");
            }
        }
        CostModel::Contention(p) => {
            fill_levels(sec, "bandwidth_derate", &mut p.bandwidth_derate)?;
            fill_levels(sec, "burst_bits", &mut p.burst_bits)?;
            if let Some(v) = sec.get("decompress_bits_per_cycle") {
                let x = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("[cost] decompress_bits_per_cycle must be a number"))?;
                p.decompress_bits_per_cycle = if x == 0.0 { None } else { Some(x) };
            }
        }
    }
    model.validate().map_err(|e| anyhow!("[cost] {e}"))?;
    search.cost = model;
    Ok(())
}

/// Parse one `[quant]` key: a scalar integer pins the width, an array
/// hands the set to the co-search.  Validation (non-empty, 1..=64)
/// funnels through [`BitwidthSpace::new`].
fn parse_quant_value(sec: &TomlTable, key: &str) -> Result<Option<BitwidthSpace>> {
    let Some(v) = sec.get(key) else { return Ok(None) };
    let bits = match v {
        TomlValue::Arr(a) => a
            .iter()
            .enumerate()
            .map(|(i, x)| {
                x.as_u32()
                    .ok_or_else(|| anyhow!("[quant] {key}[{i}] must be an integer"))
            })
            .collect::<Result<Vec<u32>>>()?,
        other => vec![other
            .as_u32()
            .ok_or_else(|| anyhow!("[quant] {key} must be an integer or an array"))?],
    };
    Ok(Some(
        BitwidthSpace::new(bits).map_err(|e| anyhow!("[quant] {key}: {e}"))?,
    ))
}

/// Parse the optional `[quant]` section into `search.quant`.  Keys
/// override any preset-bundled quant config individually; absent keys
/// keep the preset's (or the disabled default's) value.
fn parse_quant_section(doc: &TomlDoc, search: &mut SearchConfig) -> Result<()> {
    let Some(sec) = doc.section("quant") else { return Ok(()) };
    if let Some(s) = parse_quant_value(sec, "w_bits")? {
        search.quant.w_bits = Some(s);
    }
    if let Some(s) = parse_quant_value(sec, "a_bits")? {
        search.quant.a_bits = Some(s);
    }
    if let Some(s) = parse_quant_value(sec, "kv_bits")? {
        search.quant.kv_bits = Some(s);
    }
    Ok(())
}

/// Reject payload widths above the accelerator word width: quantization
/// narrows operands, and a payload wider than `data_bits` would make the
/// "compressed" tile larger than its dense reference, breaking the
/// ratio-cap invariant the tile-legality and lower-bound math rely on.
pub fn validate_quant_bits(q: &QuantConfig, data_bits: u32) -> Result<()> {
    for (key, space) in [
        ("w_bits", &q.w_bits),
        ("a_bits", &q.a_bits),
        ("kv_bits", &q.kv_bits),
    ] {
        if let Some(s) = space {
            if let Some(&b) = s.values().iter().find(|&&b| b > data_bits) {
                bail!(
                    "quant {key} includes {b}, above the accelerator's data_bits {data_bits}"
                );
            }
        }
    }
    Ok(())
}

/// Per-run overrides applied on top of a parsed TOML document — the
/// seam `config::sweep` axes resolve through.  Every field mirrors a
/// `snipsnap search` CLI flag and composes with the document the same
/// way: `None` keeps the document's (or the default's) value.
#[derive(Clone, Debug, Default)]
pub struct RunOverrides {
    /// Arch preset name; wins over an inline `[arch]` section.
    pub arch: Option<String>,
    /// Workload preset name; combining with an inline `[[op]]` /
    /// `[op.*]` workload is an error (a preset cannot "override" custom
    /// ops meaningfully).
    pub workload: Option<String>,
    pub metric: Option<String>,
    pub mode: Option<String>,
    pub threads: Option<usize>,
    /// Cost-backend name; like the `--cost-backend` flag, re-selecting
    /// `contention` keeps a document-supplied contention tuning.
    pub backend: Option<String>,
    pub w_bits: Option<BitwidthSpace>,
    pub a_bits: Option<BitwidthSpace>,
    pub kv_bits: Option<BitwidthSpace>,
}

/// Load a complete run configuration from TOML text.
pub fn load_run_config(src: &str) -> Result<RunConfig> {
    let doc = TomlDoc::parse(src).map_err(|e| anyhow!("{e}"))?;
    resolve_run_config(&doc, &RunOverrides::default())
}

/// Resolve a parsed TOML document into a run configuration with
/// [`RunOverrides`] applied.  With default overrides this is exactly
/// [`load_run_config`]'s resolution; sweeps call it once per axis
/// combination over the same shared document.
pub fn resolve_run_config(doc: &TomlDoc, ov: &RunOverrides) -> Result<RunConfig> {
    let run = doc.section("run").cloned().unwrap_or_default();

    let arch = match &ov.arch {
        Some(name) => arch_by_name(name)?,
        None => match parse_inline_arch(doc)? {
            Some(a) => a,
            None => arch_by_name(
                run.get("arch")
                    .and_then(|v| v.as_str())
                    .context("[run] arch missing (or provide [arch])")?,
            )?,
        },
    };
    let mut preset_name: Option<String> = None;
    let inline_workload = parse_inline_workload(doc)?;
    if inline_workload.is_some() && ov.workload.is_some() {
        bail!("a workload override cannot be applied to an inline [op.*]/[[op]] workload");
    }
    let workload = match inline_workload {
        Some(w) => w,
        None => {
            let wsec = doc.section("workload");
            let preset = ov
                .workload
                .as_deref()
                .or_else(|| wsec.and_then(|s| s.get("preset")).and_then(|v| v.as_str()))
                .or_else(|| run.get("workload").and_then(|v| v.as_str()))
                .context(
                    "[run] workload / [workload] preset missing (or provide [op.*])",
                )?;
            preset_name = Some(preset.to_string());
            let mut opts = WorkloadOpts::default();
            if let Some(sec) = wsec {
                if let Some(v) = sec.get("prefill_tokens") {
                    opts.prefill_tokens =
                        Some(v.as_u64().context("[workload] prefill_tokens must be an integer")?);
                }
                if let Some(v) = sec.get("decode_tokens") {
                    opts.decode_tokens =
                        Some(v.as_u64().context("[workload] decode_tokens must be an integer")?);
                }
                if let Some(v) = sec.get("batch") {
                    opts.batch = Some(v.as_u64().context("[workload] batch must be an integer")?);
                }
                if let Some(v) = sec.get("kv_density") {
                    opts.kv_density =
                        Some(v.as_f64().context("[workload] kv_density must be a number")?);
                }
                if let Some(v) = sec.get("nm") {
                    opts.nm = Some(match v {
                        TomlValue::Str(s) => parse_nm(s)?,
                        TomlValue::Arr(a) if a.len() == 2 => {
                            let n = a[0].as_u32().context("[workload] nm N")?;
                            let m = a[1].as_u32().context("[workload] nm M")?;
                            (n, m)
                        }
                        _ => bail!("[workload] nm must be \"N:M\" or [N, M]"),
                    });
                }
            }
            resolve_workload(preset, &opts)?
        }
    };

    let mut search = SearchConfig::default();
    if let Some(m) = ov.metric.as_deref().or_else(|| run.get("metric").and_then(|v| v.as_str())) {
        search.metric = metric_by_name(m)?;
    }
    if let Some(m) = ov.mode.as_deref().or_else(|| run.get("mode").and_then(|v| v.as_str())) {
        search.mode = match m {
            "search" => FormatMode::Search,
            "fixed" => FormatMode::Fixed,
            other => bail!("unknown mode '{other}'"),
        };
    }
    if let Some(sec) = doc.section("search") {
        if let Some(g) = sec.get("gamma").and_then(|v| v.as_f64()) {
            search.engine.gamma = g;
        }
        if let Some(k) = sec.get("top_k").and_then(|v| v.as_u64()) {
            search.engine.top_k = k as usize;
        }
        if let Some(d) = sec.get("max_depth").and_then(|v| v.as_u64()) {
            search.engine.space.max_depth = d as usize;
        }
        if let Some(m) = sec.get("max_mappings").and_then(|v| v.as_u64()) {
            search.mapper.max_candidates = m as usize;
        }
        if let Some(p) = sec.get("pairs_to_map").and_then(|v| v.as_u64()) {
            search.pairs_to_map = p as usize;
        }
        if let Some(t) = sec.get("threads").and_then(|v| v.as_u64()) {
            search.threads = t as usize;
        }
        if let Some(p) = sec.get("prune").and_then(|v| v.as_bool()) {
            search.prune = p;
        }
        if let Some(b) = sec.get("best_first").and_then(|v| v.as_bool()) {
            search.best_first = b;
        }
    }
    if let Some(t) = ov.threads {
        search.threads = t;
    }
    parse_cost_section(doc, &mut search)?;
    if let Some(b) = &ov.backend {
        match CostModel::by_name(b).map_err(|e| anyhow!(e))? {
            // Like --cost-backend: re-selecting contention keeps a
            // document-supplied tuning; the override's job is backend
            // selection, not knob reset.
            CostModel::Contention(_) if matches!(search.cost, CostModel::Contention(_)) => {}
            m => search.cost = m,
        }
    }
    // Preset-bundled quant seeds the axis; [quant] keys override per
    // key, and per-class overrides win last.
    if let Some(q) = preset_name.as_deref().and_then(preset_quant) {
        search.quant = q;
    }
    parse_quant_section(doc, &mut search)?;
    if let Some(s) = &ov.w_bits {
        search.quant.w_bits = Some(s.clone());
    }
    if let Some(s) = &ov.a_bits {
        search.quant.a_bits = Some(s.clone());
    }
    if let Some(s) = &ov.kv_bits {
        search.quant.kv_bits = Some(s.clone());
    }
    validate_quant_bits(&search.quant, arch.data_bits)?;
    search.engine.data_bits = arch.data_bits;
    Ok(RunConfig { arch, workload, search })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        assert!(arch_by_name("arch3").is_ok());
        assert!(arch_by_name("bogus").is_err());
        assert!(workload_by_name("llama2-7b").is_ok());
        assert!(workload_by_name("resnet-18").is_ok());
        assert!(workload_by_name("gpt-5").is_err());
        assert!(metric_by_name("edp").is_ok());
    }

    #[test]
    fn scenario_presets_resolve() {
        for name in [
            "llama3-8b",
            "llama3-70b",
            "mistral-7b",
            "gqa-tiny",
            "mixtral-8x7b",
            "moe-tiny",
            "decode-tiny",
            "llama2-7b-batch8",
            "llama2-7b-nm24",
        ] {
            let w = workload_by_name(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!w.ops.is_empty(), "{name}");
            assert!(w.total_macs() > 0.0, "{name}");
        }
        let nm = workload_by_name("llama2-7b-nm24").unwrap();
        assert!(nm.name.contains("W2:4"));
        let batched = workload_by_name("llama2-7b-batch8").unwrap();
        let qkv = batched.ops.iter().find(|o| o.name.contains("decode/qkv")).unwrap();
        assert_eq!(qkv.dims.m, 8);
    }

    #[test]
    fn workload_opts_apply_and_validate() {
        let opts = WorkloadOpts {
            prefill_tokens: Some(64),
            decode_tokens: Some(8),
            batch: Some(4),
            kv_density: Some(0.5),
            nm: Some((2, 4)),
        };
        let w = resolve_workload("gqa-tiny", &opts).unwrap();
        assert!(w.name.contains("W2:4"), "{}", w.name);
        let qk = w.ops.iter().find(|o| o.name.contains("prefill/qk")).unwrap();
        // batch scales the per-sequence attention op counts.
        assert_eq!(qk.count, 2 * 8 * 4); // layers x heads x batch

        let bad = |o: WorkloadOpts| resolve_workload("gqa-tiny", &o);
        assert!(bad(WorkloadOpts { batch: Some(0), ..Default::default() }).is_err());
        assert!(bad(WorkloadOpts { kv_density: Some(0.0), ..Default::default() }).is_err());
        assert!(bad(WorkloadOpts { kv_density: Some(1.5), ..Default::default() }).is_err());
        assert!(bad(WorkloadOpts { nm: Some((0, 4)), ..Default::default() }).is_err());
        assert!(bad(WorkloadOpts { nm: Some((5, 4)), ..Default::default() }).is_err());
        assert!(bad(WorkloadOpts {
            prefill_tokens: Some(0),
            decode_tokens: Some(0),
            ..Default::default()
        })
        .is_err());
        // Modifiers are transformer-only.
        assert!(resolve_workload(
            "alexnet",
            &WorkloadOpts { batch: Some(2), ..Default::default() }
        )
        .is_err());
        assert!(resolve_workload("alexnet", &WorkloadOpts::default()).is_ok());
    }

    #[test]
    fn parse_nm_forms() {
        assert_eq!(parse_nm("2:4").unwrap(), (2, 4));
        assert_eq!(parse_nm("1:8").unwrap(), (1, 8));
        assert!(parse_nm("24").is_err());
        assert!(parse_nm("a:4").is_err());
    }

    #[test]
    fn full_preset_config() {
        let cfg = load_run_config(
            r#"
[run]
arch = "arch3"
workload = "opt-125m"
metric = "memory-energy"
mode = "fixed"
[search]
top_k = 2
max_mappings = 1000
threads = 4
prune = false
best_first = false
"#,
        )
        .unwrap();
        assert_eq!(cfg.workload.name, "OPT-125M");
        assert_eq!(cfg.search.metric, Metric::MemoryEnergy);
        assert_eq!(cfg.search.mode, FormatMode::Fixed);
        assert_eq!(cfg.search.mapper.max_candidates, 1000);
        assert_eq!(cfg.search.threads, 4);
        assert!(!cfg.search.prune);
        assert!(!cfg.search.best_first);
    }

    #[test]
    fn frontier_metric_and_best_first_default() {
        let cfg = load_run_config(
            r#"
[run]
arch = "arch3"
workload = "opt-125m"
metric = "frontier"
"#,
        )
        .unwrap();
        assert_eq!(cfg.search.metric, Metric::Frontier);
        assert!(cfg.search.best_first, "best-first ordering defaults on");
    }

    #[test]
    fn threads_defaults_to_serial() {
        let cfg = load_run_config(
            r#"
[run]
arch = "arch3"
workload = "opt-125m"
"#,
        )
        .unwrap();
        assert_eq!(cfg.search.threads, 1);
        assert!(cfg.search.prune, "pruning defaults on");
    }

    #[test]
    fn inline_arch_and_workload() {
        let cfg = load_run_config(
            r#"
[run]
metric = "energy"
[arch]
name = "tiny"
macs = 64
spatial_rows = 8
spatial_cols = 8
reduction = "skipping-both"
native_format = "Bitmap"
level0 = ["DRAM", 0, 200.0, 200.0, 64]
level1 = ["Buf", 32, 2.0, 2.0, 1024]
[op.gemm]
m = 64
n = 64
k = 64
act_density = 0.5
wgt_density = 0.5
count = 2
"#,
        )
        .unwrap();
        assert_eq!(cfg.arch.name, "tiny");
        assert_eq!(cfg.arch.levels.len(), 2);
        assert_eq!(cfg.workload.ops.len(), 1);
        assert_eq!(cfg.workload.ops[0].count, 2);
        assert_eq!(cfg.workload.ops[0].name, "gemm");
    }

    #[test]
    fn array_of_tables_workload() {
        let cfg = load_run_config(
            r#"
[run]
arch = "arch3"
[[op]]
name = "qkv"
m = 64
n = 64
k = 128
act_density = 0.4
wgt_density = 0.5
count = 3
[[op]]
m = 32
n = 64
k = 64
"#,
        )
        .unwrap();
        assert_eq!(cfg.workload.ops.len(), 2);
        assert_eq!(cfg.workload.ops[0].name, "qkv");
        assert_eq!(cfg.workload.ops[0].count, 3);
        assert_eq!(cfg.workload.ops[0].dims.k, 128);
        // Unnamed elements get positional names; defaults apply.
        assert_eq!(cfg.workload.ops[1].name, "op1");
        assert_eq!(cfg.workload.ops[1].count, 1);
        assert_eq!(cfg.workload.ops[1].spec.input.density(), 1.0);
    }

    #[test]
    fn array_of_tables_workload_rejects_bad_shapes() {
        // Mixing [op.NAME] and [[op]] is ambiguous.
        let e = load_run_config(
            "[run]\narch = \"arch3\"\n[op.a]\nm = 4\nn = 4\nk = 4\n[[op]]\nm = 4\nn = 4\nk = 4\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("not both"), "{e}");
        // Duplicate names collide.
        let dup = "[run]\narch = \"arch3\"\n\
                   [[op]]\nname = \"a\"\nm = 4\nn = 4\nk = 4\n\
                   [[op]]\nname = \"a\"\nm = 8\nn = 8\nk = 8\n";
        assert!(load_run_config(dup).unwrap_err().to_string().contains("duplicate"));
        // Missing dims and bad densities surface with the op name.
        let e = load_run_config("[run]\narch = \"arch3\"\n[[op]]\nname = \"x\"\nm = 4\nn = 4\n")
            .unwrap_err();
        assert!(e.to_string().contains("[x]"), "{e}");
        assert!(load_run_config(
            "[run]\narch = \"arch3\"\n[[op]]\nm = 4\nn = 4\nk = 4\nact_density = 0.0\n"
        )
        .is_err());
    }

    #[test]
    fn workload_section_modifies_preset() {
        let cfg = load_run_config(
            r#"
[run]
arch = "arch3"
[workload]
preset = "gqa-tiny"
prefill_tokens = 64
decode_tokens = 8
batch = 2
kv_density = 0.5
nm = "2:4"
"#,
        )
        .unwrap();
        assert!(cfg.workload.name.contains("W2:4"), "{}", cfg.workload.name);
        let av = cfg.workload.ops.iter().find(|o| o.name.contains("prefill/av")).unwrap();
        // The NM variant re-densifies activations but must not touch the
        // V operand — the kv_density knob survives the variant.
        assert_eq!(av.spec.input.density(), 1.0);
        assert_eq!(av.spec.weight.density(), 0.5);

        // Array form of nm, preset via [run].
        let cfg = load_run_config(
            r#"
[run]
arch = "arch3"
workload = "opt-125m"
[workload]
nm = [1, 4]
"#,
        )
        .unwrap();
        assert!(cfg.workload.name.contains("W1:4"), "{}", cfg.workload.name);
    }

    #[test]
    fn out_of_range_densities_are_rejected() {
        let base = |act: &str, wgt: &str| {
            format!(
                "[run]\narch = \"arch3\"\n[op.g]\nm = 4\nn = 4\nk = 4\nact_density = {act}\nwgt_density = {wgt}\n"
            )
        };
        assert!(load_run_config(&base("0.5", "0.5")).is_ok());
        assert!(load_run_config(&base("0.0", "0.5")).is_err());
        assert!(load_run_config(&base("-0.3", "0.5")).is_err());
        assert!(load_run_config(&base("0.5", "1.2")).is_err());
        let kv_bad = r#"
[run]
arch = "arch3"
[workload]
preset = "gqa-tiny"
kv_density = 1.5
"#;
        assert!(load_run_config(kv_bad).is_err());
    }

    #[test]
    fn cost_section_parses_and_defaults() {
        use crate::cost::ContentionParams;
        let base = "[run]\narch = \"arch3\"\nworkload = \"opt-125m\"\n";

        // Absent section: analytical default.
        let cfg = load_run_config(base).unwrap();
        assert_eq!(cfg.search.cost, CostModel::Analytical);

        // Explicit analytical.
        let cfg = load_run_config(&format!("{base}[cost]\nbackend = \"analytical\"\n")).unwrap();
        assert_eq!(cfg.search.cost, CostModel::Analytical);

        // Contention with all defaults.
        let cfg = load_run_config(&format!("{base}[cost]\nbackend = \"contention\"\n")).unwrap();
        assert_eq!(cfg.search.cost, CostModel::Contention(ContentionParams::default()));

        // Scalar broadcast + prefix array + decomp override.
        let cfg = load_run_config(&format!(
            "{base}[cost]\nbackend = \"contention\"\nbandwidth_derate = 0.8\n\
             burst_bits = [1024, 256]\ndecompress_bits_per_cycle = 2048\n"
        ))
        .unwrap();
        let CostModel::Contention(p) = cfg.search.cost else { panic!("not contention") };
        assert!(p.bandwidth_derate.iter().all(|&d| d == 0.8));
        assert_eq!(p.burst_bits[0], 1024.0);
        assert_eq!(p.burst_bits[1], 256.0);
        // Unlisted boundaries keep their defaults.
        assert_eq!(p.burst_bits[2], ContentionParams::default().burst_bits[2]);
        assert_eq!(p.decompress_bits_per_cycle, Some(2048.0));

        // 0 disables the decompression term.
        let cfg = load_run_config(&format!(
            "{base}[cost]\nbackend = \"contention\"\ndecompress_bits_per_cycle = 0\n"
        ))
        .unwrap();
        let CostModel::Contention(p) = cfg.search.cost else { panic!("not contention") };
        assert_eq!(p.decompress_bits_per_cycle, None);
    }

    #[test]
    fn cost_section_rejects_bad_configs() {
        let base = "[run]\narch = \"arch3\"\nworkload = \"opt-125m\"\n";
        let err = |tail: &str| load_run_config(&format!("{base}{tail}")).unwrap_err().to_string();

        let e = err("[cost]\nbackend = \"bogus\"\n");
        assert!(e.contains("bogus"), "{e}");
        // Contention knobs without the contention backend.
        let e = err("[cost]\nbandwidth_derate = 0.8\n");
        assert!(e.contains("backend = \"contention\""), "{e}");
        // Out-of-range values funnel through ContentionParams::validate.
        let e = err("[cost]\nbackend = \"contention\"\nbandwidth_derate = 1.5\n");
        assert!(e.contains("bandwidth_derate"), "{e}");
        assert!(!err("[cost]\nbackend = \"contention\"\nburst_bits = 0.5\n").is_empty());
        let e = err("[cost]\nbackend = \"contention\"\ndecompress_bits_per_cycle = -1\n");
        assert!(e.contains("decompress"), "{e}");
        // Over-long prefix array.
        let many = "[cost]\nbackend = \"contention\"\nburst_bits = [1,1,1,1,1,1,1,1,1]\n";
        assert!(err(many).contains("entries"));
    }

    #[test]
    fn quant_section_parses_scalar_and_array() {
        let base = "[run]\narch = \"arch3\"\nworkload = \"opt-125m\"\n";

        // Absent section: axis disabled.
        let cfg = load_run_config(base).unwrap();
        assert!(cfg.search.quant.is_default());

        let cfg = load_run_config(&format!(
            "{base}[quant]\nw_bits = [16, 4, 8]\na_bits = 8\n"
        ))
        .unwrap();
        let q = &cfg.search.quant;
        assert_eq!(q.w_bits.as_ref().unwrap().values(), &[4, 8, 16]);
        assert_eq!(q.a_bits.as_ref().unwrap().values(), &[8]);
        assert!(q.kv_bits.is_none(), "absent key stays disabled");
    }

    #[test]
    fn quant_presets_seed_and_sections_override() {
        assert!(preset_quant("llama2-7b").is_none());
        let q = preset_quant("llama2-7b-w4a8").unwrap();
        assert_eq!(q.w_bits.as_ref().unwrap().values(), &[4]);
        assert_eq!(q.a_bits.as_ref().unwrap().values(), &[8]);
        let q = preset_quant("llama2-7b-qsearch").unwrap();
        assert_eq!(q.w_bits.as_ref().unwrap().values(), &[4, 8, 16]);

        // The preset names resolve as workloads too (same ops as the base
        // model, distinct display name).
        let w = workload_by_name("llama2-7b-w4a8").unwrap();
        assert!(w.name.contains("W4A8"), "{}", w.name);
        assert_eq!(w.ops.len(), workload_by_name("llama2-7b").unwrap().ops.len());

        // A [quant] key overrides the preset individually; absent keys
        // keep the preset's value.
        let cfg = load_run_config(
            "[run]\narch = \"arch3\"\nworkload = \"llama2-7b-w4a8\"\n[quant]\nw_bits = 8\n",
        )
        .unwrap();
        assert_eq!(cfg.search.quant.w_bits.as_ref().unwrap().values(), &[8]);
        assert_eq!(cfg.search.quant.a_bits.as_ref().unwrap().values(), &[8]);
        assert_eq!(cfg.search.quant.kv_bits.as_ref().unwrap().values(), &[8]);
    }

    #[test]
    fn quant_section_rejects_bad_values() {
        let base = "[run]\narch = \"arch3\"\nworkload = \"opt-125m\"\n";
        let err = |tail: &str| load_run_config(&format!("{base}{tail}")).unwrap_err().to_string();

        let e = err("[quant]\nw_bits = 0\n");
        assert!(e.contains("out of range"), "{e}");
        let e = err("[quant]\na_bits = []\n");
        assert!(e.contains("empty"), "{e}");
        let e = err("[quant]\nkv_bits = \"8\"\n");
        assert!(e.contains("integer"), "{e}");
        // Widths above the accelerator word width are rejected (arch3 is
        // a 16-bit machine).
        let e = err("[quant]\nw_bits = [4, 32]\n");
        assert!(e.contains("data_bits"), "{e}");
    }

    #[test]
    fn inline_arch_validation_errors_surface() {
        let r = load_run_config(
            r#"
[arch]
macs = 64
spatial_rows = 100
spatial_cols = 100
level0 = ["DRAM", 0, 200.0, 200.0, 64]
level1 = ["Buf", 32, 2.0, 2.0, 1024]
[op.g]
m = 4
n = 4
k = 4
"#,
        );
        assert!(r.is_err());
    }
}
