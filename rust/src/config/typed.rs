//! Typed configuration loaders: turn a [`TomlDoc`] into accelerators,
//! workloads and search settings.
//!
//! A run config looks like:
//!
//! ```toml
//! [run]
//! arch = "arch3"            # preset name, or define [arch] inline
//! workload = "llama2-7b"    # preset name, or define [op.*] tables
//! metric = "energy"         # energy | memory-energy | latency | edp
//! mode = "search"           # search | fixed
//!
//! [search]
//! gamma = 1.05
//! top_k = 4
//! max_depth = 4
//! max_mappings = 40000
//! threads = 4               # co-search worker threads (0 = all cores)
//!
//! # Optional custom workload:
//! [op.fc1]
//! m = 2048
//! n = 4096
//! k = 16384
//! act_density = 0.4
//! wgt_density = 0.5
//! count = 32
//!
//! # Optional custom accelerator:
//! [arch]
//! macs = 2048
//! spatial_rows = 64
//! spatial_cols = 32
//! data_bits = 16
//! clock_ghz = 1.2
//! reduction = "skipping-both"
//! native_format = "Bitmap"
//! # levels: name, capacity KiB (0 = unbounded), read pJ/word, write
//! # pJ/word, bandwidth bits/cycle
//! level0 = ["DRAM", 0, 200.0, 200.0, 128]
//! level1 = ["L2", 512, 8.0, 8.0, 1024]
//! level2 = ["OpBuf", 128, 1.5, 1.5, 8192]
//! ```

use super::toml::{TomlDoc, TomlValue};
use crate::arch::{presets, Accelerator, MacArray, MemLevel};
use crate::cost::Metric;
use crate::dataflow::ProblemDims;
use crate::search::{FormatMode, SearchConfig};
use crate::sparsity::reduction::{Direction, ReductionStrategy};
use crate::sparsity::SparsitySpec;
use crate::workload::{llm, MatMulOp, Workload};
use anyhow::{anyhow, bail, Context, Result};

/// A fully-resolved run configuration.
pub struct RunConfig {
    pub arch: Accelerator,
    pub workload: Workload,
    pub search: SearchConfig,
}

/// Resolve an accelerator preset by name.
pub fn arch_by_name(name: &str) -> Result<Accelerator> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "arch1" => presets::arch1(),
        "arch2" => presets::arch2(),
        "arch3" => presets::arch3(),
        "arch4" => presets::arch4(),
        "scnn" => presets::scnn(),
        "dstc" => presets::dstc_validation(),
        other => bail!("unknown arch preset '{other}' (arch1-4, scnn, dstc)"),
    })
}

/// Resolve a workload preset by name.
pub fn workload_by_name(name: &str) -> Result<Workload> {
    let ph = llm::Phase::default_prefill_decode();
    let small = llm::Phase { prefill_tokens: 256, decode_tokens: 32 };
    Ok(match name.to_ascii_lowercase().as_str() {
        "llama2-7b" => llm::llama2_7b(ph),
        "llama2-13b" => llm::llama2_13b(ph),
        "opt-125m" => llm::opt_125m(small),
        "opt-6.7b" => llm::opt_6_7b(ph),
        "opt-13b" => llm::opt_13b(ph),
        "opt-30b" => llm::opt_30b(ph),
        "bert-base" => llm::bert_base(256),
        "alexnet" => crate::workload::cnn::alexnet(),
        "vgg-16" | "vgg16" => crate::workload::cnn::vgg16(),
        "resnet-18" | "resnet18" => crate::workload::cnn::resnet18(),
        other => bail!("unknown workload preset '{other}'"),
    })
}

pub fn metric_by_name(name: &str) -> Result<Metric> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "energy" => Metric::Energy,
        "memory-energy" | "memory_energy" => Metric::MemoryEnergy,
        "latency" => Metric::Latency,
        "edp" => Metric::Edp,
        other => bail!("unknown metric '{other}'"),
    })
}

fn reduction_by_name(name: &str) -> Result<ReductionStrategy> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "none" => ReductionStrategy::NONE,
        "gating-input" => ReductionStrategy::gating(Direction::InputOnly),
        "gating-weight" => ReductionStrategy::gating(Direction::WeightOnly),
        "gating-both" => ReductionStrategy::gating(Direction::Both),
        "skipping-input" => ReductionStrategy::skipping(Direction::InputOnly),
        "skipping-weight" => ReductionStrategy::skipping(Direction::WeightOnly),
        "skipping-both" => ReductionStrategy::skipping(Direction::Both),
        other => bail!("unknown reduction '{other}'"),
    })
}

fn parse_level(v: &TomlValue) -> Result<MemLevel> {
    let a = v.as_arr().ok_or_else(|| anyhow!("level must be an array"))?;
    if a.len() != 5 {
        bail!("level needs [name, KiB, read pJ/word, write pJ/word, bw]");
    }
    let name = a[0].as_str().ok_or_else(|| anyhow!("level name"))?;
    let kib = a[1].as_f64().ok_or_else(|| anyhow!("capacity"))?;
    let read = a[2].as_f64().ok_or_else(|| anyhow!("read pJ"))?;
    let write = a[3].as_f64().ok_or_else(|| anyhow!("write pJ"))?;
    let bw = a[4].as_f64().ok_or_else(|| anyhow!("bandwidth"))?;
    let word = 16.0;
    Ok(MemLevel {
        name: name.to_string(),
        capacity_bits: if kib == 0.0 { u64::MAX } else { (kib * 1024.0 * 8.0) as u64 },
        read_pj_per_bit: read / word,
        write_pj_per_bit: write / word,
        bandwidth_bits_per_cycle: bw,
    })
}

fn parse_inline_arch(doc: &TomlDoc) -> Result<Option<Accelerator>> {
    let Some(sec) = doc.section("arch") else { return Ok(None) };
    if sec.is_empty() {
        return Ok(None);
    }
    let get_u = |k: &str| -> Result<u64> {
        sec.get(k)
            .and_then(|v| v.as_u64())
            .ok_or_else(|| anyhow!("[arch] missing integer '{k}'"))
    };
    let mut levels = Vec::new();
    for i in 0.. {
        match sec.get(&format!("level{i}")) {
            Some(v) => levels.push(parse_level(v)?),
            None => break,
        }
    }
    if levels.is_empty() {
        bail!("[arch] needs level0..levelN");
    }
    let arch = Accelerator {
        name: sec
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("custom")
            .to_string(),
        mac: MacArray {
            total_macs: get_u("macs")?,
            spatial_rows: get_u("spatial_rows")?,
            spatial_cols: get_u("spatial_cols")?,
            pj_per_mac: sec.get("pj_per_mac").and_then(|v| v.as_f64()).unwrap_or(1.0),
        },
        levels,
        reduction: reduction_by_name(
            sec.get("reduction")
                .and_then(|v| v.as_str())
                .unwrap_or("skipping-both"),
        )?,
        data_bits: get_u("data_bits").unwrap_or(16) as u32,
        clock_ghz: sec.get("clock_ghz").and_then(|v| v.as_f64()).unwrap_or(1.0),
        native_format: sec
            .get("native_format")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string()),
        codec_area_overhead: sec
            .get("codec_area_overhead")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.05),
    };
    arch.validate().map_err(|e| anyhow!(e))?;
    Ok(Some(arch))
}

fn parse_inline_workload(doc: &TomlDoc) -> Result<Option<Workload>> {
    let subs = doc.sections_under("op");
    if subs.is_empty() {
        return Ok(None);
    }
    let mut ops = Vec::new();
    for (name, sec) in subs {
        let get_u = |k: &str| -> Result<u64> {
            sec.get(k)
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow!("[{name}] missing integer '{k}'"))
        };
        let get_f = |k: &str, default: f64| -> f64 {
            sec.get(k).and_then(|v| v.as_f64()).unwrap_or(default)
        };
        ops.push(MatMulOp {
            name: name.trim_start_matches("op.").to_string(),
            dims: ProblemDims::new(get_u("m")?, get_u("n")?, get_u("k")?),
            spec: SparsitySpec::unstructured(
                get_f("act_density", 1.0),
                get_f("wgt_density", 1.0),
            ),
            count: sec.get("count").and_then(|v| v.as_u64()).unwrap_or(1),
        });
    }
    Ok(Some(Workload { name: "custom".to_string(), ops }))
}

/// Load a complete run configuration from TOML text.
pub fn load_run_config(src: &str) -> Result<RunConfig> {
    let doc = TomlDoc::parse(src).map_err(|e| anyhow!("{e}"))?;
    let run = doc.section("run").cloned().unwrap_or_default();

    let arch = match parse_inline_arch(&doc)? {
        Some(a) => a,
        None => arch_by_name(
            run.get("arch")
                .and_then(|v| v.as_str())
                .context("[run] arch missing (or provide [arch])")?,
        )?,
    };
    let workload = match parse_inline_workload(&doc)? {
        Some(w) => w,
        None => workload_by_name(
            run.get("workload")
                .and_then(|v| v.as_str())
                .context("[run] workload missing (or provide [op.*])")?,
        )?,
    };

    let mut search = SearchConfig::default();
    if let Some(m) = run.get("metric").and_then(|v| v.as_str()) {
        search.metric = metric_by_name(m)?;
    }
    if let Some(m) = run.get("mode").and_then(|v| v.as_str()) {
        search.mode = match m {
            "search" => FormatMode::Search,
            "fixed" => FormatMode::Fixed,
            other => bail!("unknown mode '{other}'"),
        };
    }
    if let Some(sec) = doc.section("search") {
        if let Some(g) = sec.get("gamma").and_then(|v| v.as_f64()) {
            search.engine.gamma = g;
        }
        if let Some(k) = sec.get("top_k").and_then(|v| v.as_u64()) {
            search.engine.top_k = k as usize;
        }
        if let Some(d) = sec.get("max_depth").and_then(|v| v.as_u64()) {
            search.engine.space.max_depth = d as usize;
        }
        if let Some(m) = sec.get("max_mappings").and_then(|v| v.as_u64()) {
            search.mapper.max_candidates = m as usize;
        }
        if let Some(p) = sec.get("pairs_to_map").and_then(|v| v.as_u64()) {
            search.pairs_to_map = p as usize;
        }
        if let Some(t) = sec.get("threads").and_then(|v| v.as_u64()) {
            search.threads = t as usize;
        }
    }
    search.engine.data_bits = arch.data_bits;
    Ok(RunConfig { arch, workload, search })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        assert!(arch_by_name("arch3").is_ok());
        assert!(arch_by_name("bogus").is_err());
        assert!(workload_by_name("llama2-7b").is_ok());
        assert!(workload_by_name("resnet-18").is_ok());
        assert!(workload_by_name("gpt-5").is_err());
        assert!(metric_by_name("edp").is_ok());
    }

    #[test]
    fn full_preset_config() {
        let cfg = load_run_config(
            r#"
[run]
arch = "arch3"
workload = "opt-125m"
metric = "memory-energy"
mode = "fixed"
[search]
top_k = 2
max_mappings = 1000
threads = 4
"#,
        )
        .unwrap();
        assert_eq!(cfg.workload.name, "OPT-125M");
        assert_eq!(cfg.search.metric, Metric::MemoryEnergy);
        assert_eq!(cfg.search.mode, FormatMode::Fixed);
        assert_eq!(cfg.search.mapper.max_candidates, 1000);
        assert_eq!(cfg.search.threads, 4);
    }

    #[test]
    fn threads_defaults_to_serial() {
        let cfg = load_run_config(
            r#"
[run]
arch = "arch3"
workload = "opt-125m"
"#,
        )
        .unwrap();
        assert_eq!(cfg.search.threads, 1);
    }

    #[test]
    fn inline_arch_and_workload() {
        let cfg = load_run_config(
            r#"
[run]
metric = "energy"
[arch]
name = "tiny"
macs = 64
spatial_rows = 8
spatial_cols = 8
reduction = "skipping-both"
native_format = "Bitmap"
level0 = ["DRAM", 0, 200.0, 200.0, 64]
level1 = ["Buf", 32, 2.0, 2.0, 1024]
[op.gemm]
m = 64
n = 64
k = 64
act_density = 0.5
wgt_density = 0.5
count = 2
"#,
        )
        .unwrap();
        assert_eq!(cfg.arch.name, "tiny");
        assert_eq!(cfg.arch.levels.len(), 2);
        assert_eq!(cfg.workload.ops.len(), 1);
        assert_eq!(cfg.workload.ops[0].count, 2);
        assert_eq!(cfg.workload.ops[0].name, "gemm");
    }

    #[test]
    fn inline_arch_validation_errors_surface() {
        let r = load_run_config(
            r#"
[arch]
macs = 64
spatial_rows = 100
spatial_cols = 100
level0 = ["DRAM", 0, 200.0, 200.0, 64]
level1 = ["Buf", 32, 2.0, 2.0, 1024]
[op.g]
m = 4
n = 4
k = 4
"#,
        );
        assert!(r.is_err());
    }
}
