//! Configuration system: a hand-rolled TOML-subset parser ([`toml`])
//! plus typed loaders turning config files into [`Accelerator`]s,
//! [`Workload`]s and search settings ([`typed`]), the JSON run-config
//! [`snapshot`] layer that makes every CLI run a replayable artifact,
//! and [`sweep`] plans expanding axis cross-products into ordered lists
//! of run configs.
//!
//! [`Accelerator`]: crate::arch::Accelerator
//! [`Workload`]: crate::workload::Workload

pub mod snapshot;
pub mod sweep;
pub mod toml;
pub mod typed;

pub use snapshot::load_run_config_any;
pub use toml::TomlDoc;
pub use typed::{load_run_config, RunConfig};
