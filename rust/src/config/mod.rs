//! Configuration system: a hand-rolled TOML-subset parser ([`toml`])
//! plus typed loaders turning config files into [`Accelerator`]s,
//! [`Workload`]s and search settings ([`typed`]).

pub mod toml;
pub mod typed;

pub use toml::TomlDoc;
pub use typed::{load_run_config, RunConfig};
