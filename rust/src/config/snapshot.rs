//! JSON run-config snapshots — the replayable-run-artifact layer.
//!
//! Every CLI co-search run emits the **fully resolved** configuration
//! (accelerator, workload, search settings) plus the git revision as a
//! single JSON document next to its results.  Feeding that document
//! back through `snipsnap search --config run.config.json` rebuilds the
//! exact same [`RunConfig`] and — because the co-search is deterministic
//! in its inputs (docs/SEARCH.md) — reproduces bit-identical designs
//! and scores.  This mirrors how Timeloop/Sparseloop treat the
//! config+stats pair as the unit of reproducibility.
//!
//! Fidelity notes:
//! - every field that can influence the search result is serialized,
//!   including the mapper's loop-order list and the engine-space knobs;
//! - finite `f64` values round-trip exactly (shortest-round-trip float
//!   formatting on the writer, `f64::from_str` on the reader);
//! - the unbounded-DRAM sentinel (`capacity_bits == u64::MAX`) is
//!   spelled `null`, since `u64::MAX` is not representable in an `f64`
//!   JSON number;
//! - [`render`] is a fixed point: rendering a reloaded snapshot yields
//!   the same bytes (tested here and in `rust/tests/run_artifacts.rs`).

use super::typed::{metric_by_name, reduction_by_name, RunConfig};
use crate::arch::{Accelerator, MacArray, MemLevel};
use crate::cost::{ContentionParams, CostModel, Metric};
use crate::dataflow::MAX_LEVELS;
use crate::dataflow::mapper::MapperConfig;
use crate::dataflow::{LoopDim, ProblemDims};
use crate::engine::EngineConfig;
use crate::format::quant::{BitwidthSpace, QuantConfig};
use crate::format::space::SpaceConfig;
use crate::search::{FormatMode, SearchConfig};
use crate::sparsity::reduction::{Direction, ReductionKind, ReductionStrategy};
use crate::sparsity::{validate_density, SparsityPattern, SparsitySpec};
use crate::util::json::Json;
use crate::workload::{MatMulOp, Workload};
use anyhow::{anyhow, bail, Context, Result};

/// Schema version stamped into (and checked out of) every snapshot.
pub const SNAPSHOT_VERSION: u64 = 1;

/// Heuristic used by the config loaders: run-config snapshots are JSON
/// objects, everything else is treated as the TOML subset.
pub fn looks_like_json(src: &str) -> bool {
    src.trim_start().starts_with('{')
}

/// Load a run config from either on-disk format: a JSON snapshot
/// (emitted by `snipsnap search`) or the TOML subset.
pub fn load_run_config_any(src: &str) -> Result<RunConfig> {
    if looks_like_json(src) {
        load_run_config_json(src)
    } else {
        super::typed::load_run_config(src)
    }
}

/// Render the snapshot document for a resolved run (one line of JSON
/// plus a trailing newline).
pub fn render(arch: &Accelerator, workload: &Workload, search: &SearchConfig) -> String {
    format!("{}\n", snapshot_json(arch, workload, search))
}

/// Build the snapshot for a fully-resolved run configuration.
pub fn snapshot_json(arch: &Accelerator, workload: &Workload, search: &SearchConfig) -> Json {
    Json::obj(vec![
        ("snipsnap_run_config", num_u(SNAPSHOT_VERSION)),
        ("git_rev", Json::str(&crate::util::bench::git_rev())),
        ("arch", arch_json(arch)),
        ("workload", workload_json(workload)),
        ("search", search_json(search)),
    ])
}

/// Parse a snapshot back into a [`RunConfig`].
pub fn load_run_config_json(src: &str) -> Result<RunConfig> {
    let v = Json::parse(src).map_err(|e| anyhow!("run-config snapshot: {e}"))?;
    run_config_from_value(&v)
}

/// Build a [`RunConfig`] from an already-parsed snapshot document.
/// Unknown keys are ignored, which is what lets `snipsnap serve` wrap a
/// snapshot with request-level fields (`id`, `budget`) while keeping the
/// snapshot itself the wire format.
pub fn run_config_from_value(v: &Json) -> Result<RunConfig> {
    let version = v
        .get("snipsnap_run_config")
        .and_then(Json::as_u64)
        .context("not a snipsnap run-config snapshot (missing 'snipsnap_run_config')")?;
    if version != SNAPSHOT_VERSION {
        bail!("unsupported snapshot version {version} (this build reads {SNAPSHOT_VERSION})");
    }
    let arch = arch_from(get(v, "arch")?)?;
    arch.validate().map_err(|e| anyhow!(e))?;
    let workload = workload_from(get(v, "workload")?)?;
    let search = search_from(get(v, "search")?)?;
    Ok(RunConfig { arch, workload, search })
}

// --- field access helpers -------------------------------------------------

/// JSON numbers are f64, so only integers below 2^53 are exact.  Every
/// run-config field lives far below that in practice; larger values are
/// clamped on write so the snapshot never carries a number that would
/// silently change on reload (a >= 2^53 mapping budget or on-chip
/// capacity is effectively unbounded anyway, and unbounded DRAM proper
/// uses the `null` sentinel).
const MAX_EXACT_U64: u64 = (1 << 53) - 1;

fn num_u(n: u64) -> Json {
    Json::num(n.min(MAX_EXACT_U64) as f64)
}

fn get<'a>(v: &'a Json, k: &str) -> Result<&'a Json> {
    v.get(k).with_context(|| format!("snapshot missing '{k}'"))
}

fn get_f(v: &Json, k: &str) -> Result<f64> {
    get(v, k)?.as_f64().with_context(|| format!("snapshot '{k}' must be a number"))
}

fn get_u(v: &Json, k: &str) -> Result<u64> {
    get(v, k)?
        .as_u64()
        .with_context(|| format!("snapshot '{k}' must be a non-negative integer"))
}

fn get_s<'a>(v: &'a Json, k: &str) -> Result<&'a str> {
    get(v, k)?.as_str().with_context(|| format!("snapshot '{k}' must be a string"))
}

fn get_b(v: &Json, k: &str) -> Result<bool> {
    get(v, k)?.as_bool().with_context(|| format!("snapshot '{k}' must be a boolean"))
}

fn get_arr<'a>(v: &'a Json, k: &str) -> Result<&'a [Json]> {
    get(v, k)?.as_arr().with_context(|| format!("snapshot '{k}' must be an array"))
}

fn get_u32(v: &Json, k: &str) -> Result<u32> {
    let n = get_u(v, k)?;
    u32::try_from(n).map_err(|_| anyhow!("snapshot '{k}' value {n} exceeds u32"))
}

fn get_density(v: &Json, k: &str) -> Result<f64> {
    let d = get_f(v, k)?;
    validate_density(d).map_err(|e| anyhow!("snapshot '{k}': {e}"))?;
    Ok(d)
}

// --- accelerator ----------------------------------------------------------

fn reduction_token(r: ReductionStrategy) -> &'static str {
    let dir = |i: &'static str, w: &'static str, b: &'static str| match r.direction {
        Direction::InputOnly => i,
        Direction::WeightOnly => w,
        Direction::Both => b,
    };
    match r.kind {
        ReductionKind::None => "none",
        ReductionKind::Gating => dir("gating-input", "gating-weight", "gating-both"),
        ReductionKind::Skipping => dir("skipping-input", "skipping-weight", "skipping-both"),
    }
}

fn level_json(l: &MemLevel) -> Json {
    Json::obj(vec![
        ("name", Json::str(&l.name)),
        (
            "capacity_bits",
            if l.capacity_bits == u64::MAX { Json::Null } else { num_u(l.capacity_bits) },
        ),
        ("read_pj_per_bit", Json::num(l.read_pj_per_bit)),
        ("write_pj_per_bit", Json::num(l.write_pj_per_bit)),
        ("bandwidth_bits_per_cycle", Json::num(l.bandwidth_bits_per_cycle)),
    ])
}

fn level_from(v: &Json) -> Result<MemLevel> {
    Ok(MemLevel {
        name: get_s(v, "name")?.to_string(),
        capacity_bits: match get(v, "capacity_bits")? {
            Json::Null => u64::MAX,
            other => other.as_u64().context("snapshot 'capacity_bits' must be null or an integer")?,
        },
        read_pj_per_bit: get_f(v, "read_pj_per_bit")?,
        write_pj_per_bit: get_f(v, "write_pj_per_bit")?,
        bandwidth_bits_per_cycle: get_f(v, "bandwidth_bits_per_cycle")?,
    })
}

pub(crate) fn arch_json(a: &Accelerator) -> Json {
    Json::obj(vec![
        ("name", Json::str(&a.name)),
        ("macs", num_u(a.mac.total_macs)),
        ("spatial_rows", num_u(a.mac.spatial_rows)),
        ("spatial_cols", num_u(a.mac.spatial_cols)),
        ("pj_per_mac", Json::num(a.mac.pj_per_mac)),
        ("levels", Json::arr(a.levels.iter().map(level_json))),
        ("reduction", Json::str(reduction_token(a.reduction))),
        ("data_bits", num_u(a.data_bits as u64)),
        ("clock_ghz", Json::num(a.clock_ghz)),
        (
            "native_format",
            a.native_format.as_ref().map(|s| Json::str(s)).unwrap_or(Json::Null),
        ),
        ("codec_area_overhead", Json::num(a.codec_area_overhead)),
    ])
}

fn arch_from(v: &Json) -> Result<Accelerator> {
    Ok(Accelerator {
        name: get_s(v, "name")?.to_string(),
        mac: MacArray {
            total_macs: get_u(v, "macs")?,
            spatial_rows: get_u(v, "spatial_rows")?,
            spatial_cols: get_u(v, "spatial_cols")?,
            pj_per_mac: get_f(v, "pj_per_mac")?,
        },
        levels: get_arr(v, "levels")?.iter().map(level_from).collect::<Result<Vec<_>>>()?,
        reduction: reduction_by_name(get_s(v, "reduction")?)?,
        data_bits: get_u32(v, "data_bits")?,
        clock_ghz: get_f(v, "clock_ghz")?,
        native_format: match get(v, "native_format")? {
            Json::Null => None,
            other => Some(
                other
                    .as_str()
                    .context("snapshot 'native_format' must be null or a string")?
                    .to_string(),
            ),
        },
        codec_area_overhead: get_f(v, "codec_area_overhead")?,
    })
}

// --- workload -------------------------------------------------------------

fn pattern_json(p: &SparsityPattern) -> Json {
    match *p {
        SparsityPattern::Dense => Json::obj(vec![("kind", Json::str("dense"))]),
        SparsityPattern::Unstructured { density } => Json::obj(vec![
            ("kind", Json::str("unstructured")),
            ("density", Json::num(density)),
        ]),
        SparsityPattern::Nm { n, m } => Json::obj(vec![
            ("kind", Json::str("nm")),
            ("n", num_u(n as u64)),
            ("m", num_u(m as u64)),
        ]),
        SparsityPattern::Block { br, bc, block_density } => Json::obj(vec![
            ("kind", Json::str("block")),
            ("br", num_u(br)),
            ("bc", num_u(bc)),
            ("block_density", Json::num(block_density)),
        ]),
    }
}

/// Parse a sparsity pattern with the same semantic validation the TOML
/// path enforces — a hand-edited snapshot must not smuggle in values a
/// config file would reject.
fn pattern_from(v: &Json) -> Result<SparsityPattern> {
    Ok(match get_s(v, "kind")? {
        "dense" => SparsityPattern::Dense,
        "unstructured" => SparsityPattern::Unstructured { density: get_density(v, "density")? },
        "nm" => {
            let (n, m) = (get_u32(v, "n")?, get_u32(v, "m")?);
            if n == 0 || n > m {
                bail!("snapshot nm pattern needs 1 <= N <= M, got {n}:{m}");
            }
            SparsityPattern::Nm { n, m }
        }
        "block" => {
            let (br, bc) = (get_u(v, "br")?, get_u(v, "bc")?);
            if br == 0 || bc == 0 {
                bail!("snapshot block pattern needs non-zero block dims, got {br}x{bc}");
            }
            SparsityPattern::Block { br, bc, block_density: get_density(v, "block_density")? }
        }
        other => bail!("unknown sparsity-pattern kind '{other}'"),
    })
}

fn op_json(op: &MatMulOp) -> Json {
    Json::obj(vec![
        ("name", Json::str(&op.name)),
        ("m", num_u(op.dims.m)),
        ("n", num_u(op.dims.n)),
        ("k", num_u(op.dims.k)),
        ("input", pattern_json(&op.spec.input)),
        ("weight", pattern_json(&op.spec.weight)),
        ("count", num_u(op.count)),
    ])
}

fn op_from(v: &Json) -> Result<MatMulOp> {
    Ok(MatMulOp {
        name: get_s(v, "name")?.to_string(),
        dims: ProblemDims::new(get_u(v, "m")?, get_u(v, "n")?, get_u(v, "k")?),
        spec: SparsitySpec {
            input: pattern_from(get(v, "input")?)?,
            weight: pattern_from(get(v, "weight")?)?,
        },
        count: get_u(v, "count")?,
    })
}

pub(crate) fn workload_json(w: &Workload) -> Json {
    Json::obj(vec![
        ("name", Json::str(&w.name)),
        ("ops", Json::arr(w.ops.iter().map(op_json))),
    ])
}

fn workload_from(v: &Json) -> Result<Workload> {
    let ops = get_arr(v, "ops")?.iter().map(op_from).collect::<Result<Vec<_>>>()?;
    if ops.is_empty() {
        bail!("snapshot workload has no ops");
    }
    Ok(Workload { name: get_s(v, "name")?.to_string(), ops })
}

// --- search settings ------------------------------------------------------

fn metric_token(m: Metric) -> &'static str {
    match m {
        Metric::Energy => "energy",
        Metric::MemoryEnergy => "memory-energy",
        Metric::Latency => "latency",
        Metric::Edp => "edp",
        Metric::Frontier => "frontier",
    }
}

fn order_token(o: &[LoopDim; 3]) -> Json {
    Json::str(&o.iter().map(|d| d.to_string()).collect::<String>())
}

fn order_from(v: &Json) -> Result<[LoopDim; 3]> {
    let s = v.as_str().context("snapshot loop order must be a string like \"MNK\"")?;
    let dims: Vec<LoopDim> = s
        .chars()
        .map(|c| match c {
            'M' => Ok(LoopDim::M),
            'N' => Ok(LoopDim::N),
            'K' => Ok(LoopDim::K),
            other => Err(anyhow!("bad loop dim '{other}' in order '{s}'")),
        })
        .collect::<Result<_>>()?;
    let arr: [LoopDim; 3] =
        dims.try_into().map_err(|_| anyhow!("loop order '{s}' must have 3 dims"))?;
    if arr[0] == arr[1] || arr[0] == arr[2] || arr[1] == arr[2] {
        bail!("loop order '{s}' is not a permutation of M, N, K");
    }
    Ok(arr)
}

fn search_json(s: &SearchConfig) -> Json {
    Json::obj(vec![
        ("metric", Json::str(metric_token(s.metric))),
        (
            "mode",
            Json::str(match s.mode {
                FormatMode::Fixed => "fixed",
                FormatMode::Search => "search",
            }),
        ),
        ("gamma", Json::num(s.engine.gamma)),
        ("engine_data_bits", num_u(s.engine.data_bits as u64)),
        ("top_k", num_u(s.engine.top_k as u64)),
        ("max_depth", num_u(s.engine.space.max_depth as u64)),
        ("max_splits_per_axis", num_u(s.engine.space.max_splits_per_axis as u64)),
        ("forbid_unit_levels", Json::Bool(s.engine.space.forbid_unit_levels)),
        ("orders", Json::arr(s.mapper.orders.iter().map(order_token))),
        ("max_mappings", num_u(s.mapper.max_candidates as u64)),
        ("min_spatial_utilization", Json::num(s.mapper.min_spatial_utilization)),
        ("pairs_to_map", num_u(s.pairs_to_map as u64)),
        ("threads", num_u(s.threads as u64)),
        ("prune", Json::Bool(s.prune)),
        ("best_first", Json::Bool(s.best_first)),
        ("cost", cost_json(&s.cost)),
        ("quant", quant_json(&s.quant)),
    ])
}

/// Serialize the quantization axis: each operand class is either `null`
/// (axis disabled for that class — native width) or the sorted candidate
/// set.  [`BitwidthSpace`] stores sorted + deduplicated values, so the
/// rendering is canonical and the snapshot stays a fixed point.
pub(crate) fn quant_json(q: &QuantConfig) -> Json {
    let space = |s: &Option<BitwidthSpace>| match s {
        Some(s) => Json::arr(s.values().iter().map(|&b| num_u(b as u64))),
        None => Json::Null,
    };
    Json::obj(vec![
        ("w_bits", space(&q.w_bits)),
        ("a_bits", space(&q.a_bits)),
        ("kv_bits", space(&q.kv_bits)),
    ])
}

fn quant_space_from(v: &Json, k: &str) -> Result<Option<BitwidthSpace>> {
    match get(v, k)? {
        Json::Null => Ok(None),
        other => {
            let arr = other
                .as_arr()
                .with_context(|| format!("snapshot '{k}' must be null or an array"))?;
            let mut vals = Vec::with_capacity(arr.len());
            for x in arr {
                let n = x
                    .as_u64()
                    .with_context(|| format!("snapshot '{k}' entries must be integers"))?;
                vals.push(
                    u32::try_from(n)
                        .map_err(|_| anyhow!("snapshot '{k}' value {n} exceeds u32"))?,
                );
            }
            // Same semantic validation as the CLI/TOML paths: a
            // hand-edited snapshot cannot smuggle in a width the flags
            // would reject.
            BitwidthSpace::new(vals)
                .map(Some)
                .map_err(|e| anyhow!("snapshot '{k}': {e}"))
        }
    }
}

fn quant_from(v: &Json) -> Result<QuantConfig> {
    Ok(QuantConfig {
        w_bits: quant_space_from(v, "w_bits")?,
        a_bits: quant_space_from(v, "a_bits")?,
        kv_bits: quant_space_from(v, "kv_bits")?,
    })
}

/// Serialize the cost backend.  Per-level arrays are written in full
/// ([`MAX_LEVELS`] entries) so the snapshot is machine-independent; the
/// disabled-decompressor state uses the `null` sentinel (like
/// `capacity_bits`), since `Infinity` is not valid JSON.
pub(crate) fn cost_json(c: &CostModel) -> Json {
    match c {
        CostModel::Analytical => Json::obj(vec![("backend", Json::str("analytical"))]),
        CostModel::Contention(p) => Json::obj(vec![
            ("backend", Json::str("contention")),
            (
                "bandwidth_derate",
                Json::arr(p.bandwidth_derate.iter().map(|&d| Json::num(d))),
            ),
            ("burst_bits", Json::arr(p.burst_bits.iter().map(|&w| Json::num(w)))),
            (
                "decompress_bits_per_cycle",
                p.decompress_bits_per_cycle.map(Json::num).unwrap_or(Json::Null),
            ),
        ]),
    }
}

fn levels_from(v: &Json, k: &str) -> Result<[f64; MAX_LEVELS]> {
    let a = get_arr(v, k)?;
    if a.len() != MAX_LEVELS {
        bail!("snapshot '{k}' must have exactly {MAX_LEVELS} entries, got {}", a.len());
    }
    let mut out = [0.0f64; MAX_LEVELS];
    for (slot, x) in out.iter_mut().zip(a) {
        *slot = x.as_f64().with_context(|| format!("snapshot '{k}' entries must be numbers"))?;
    }
    Ok(out)
}

fn cost_from(v: &Json) -> Result<CostModel> {
    let model = match get_s(v, "backend")? {
        "analytical" => CostModel::Analytical,
        "contention" => CostModel::Contention(ContentionParams {
            bandwidth_derate: levels_from(v, "bandwidth_derate")?,
            burst_bits: levels_from(v, "burst_bits")?,
            decompress_bits_per_cycle: match get(v, "decompress_bits_per_cycle")? {
                Json::Null => None,
                other => Some(
                    other
                        .as_f64()
                        .context("snapshot 'decompress_bits_per_cycle' must be null or a number")?,
                ),
            },
        }),
        other => bail!("unknown cost backend '{other}' in snapshot"),
    };
    // Same semantic validation as the TOML path: a hand-edited snapshot
    // cannot smuggle in knobs a config file would reject.
    model.validate().map_err(|e| anyhow!("snapshot cost: {e}"))?;
    Ok(model)
}

fn search_from(v: &Json) -> Result<SearchConfig> {
    let orders = get_arr(v, "orders")?.iter().map(order_from).collect::<Result<Vec<_>>>()?;
    if orders.is_empty() {
        bail!("snapshot 'orders' must name at least one loop order");
    }
    Ok(SearchConfig {
        metric: metric_by_name(get_s(v, "metric")?)?,
        mode: match get_s(v, "mode")? {
            "fixed" => FormatMode::Fixed,
            "search" => FormatMode::Search,
            other => bail!("unknown mode '{other}'"),
        },
        engine: EngineConfig {
            space: SpaceConfig {
                max_depth: get_u(v, "max_depth")? as usize,
                max_splits_per_axis: get_u(v, "max_splits_per_axis")? as usize,
                forbid_unit_levels: get_b(v, "forbid_unit_levels")?,
            },
            gamma: get_f(v, "gamma")?,
            data_bits: get_u32(v, "engine_data_bits")?,
            top_k: get_u(v, "top_k")? as usize,
        },
        mapper: MapperConfig {
            orders,
            max_candidates: get_u(v, "max_mappings")? as usize,
            min_spatial_utilization: get_f(v, "min_spatial_utilization")?,
        },
        pairs_to_map: get_u(v, "pairs_to_map")? as usize,
        threads: get_u(v, "threads")? as usize,
        prune: get_b(v, "prune")?,
        // Absent in snapshots written before best-first proto ordering:
        // those runs iterated the arena in index order with the ordering
        // knob conceptually on-but-inert, which the default reproduces.
        best_first: match v.get("best_first") {
            Some(_) => get_b(v, "best_first")?,
            None => true,
        },
        // Absent in snapshots written before the cost-backend seam:
        // those runs evaluated analytically, so the default is exact.
        cost: match v.get("cost") {
            Some(c) => cost_from(c)?,
            None => CostModel::Analytical,
        },
        // Absent in snapshots written before the quantization axis:
        // those runs searched at the native width, which is exactly
        // what the default (disabled) config reproduces.
        quant: match v.get("quant") {
            Some(q) => quant_from(q)?,
            None => QuantConfig::default(),
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::typed::load_run_config;

    const SRC: &str = r#"
[run]
arch = "arch3"
metric = "memory-energy"
mode = "fixed"
[search]
top_k = 2
max_mappings = 500
threads = 3
prune = false
[[op]]
name = "fc1"
m = 64
n = 64
k = 128
act_density = 0.4
wgt_density = 0.5
count = 2
[[op]]
m = 32
n = 64
k = 64
"#;

    #[test]
    fn snapshot_is_a_fixed_point() {
        let cfg = load_run_config(SRC).unwrap();
        let snap = render(&cfg.arch, &cfg.workload, &cfg.search);
        let cfg2 = load_run_config_any(&snap).unwrap();
        let snap2 = render(&cfg2.arch, &cfg2.workload, &cfg2.search);
        assert_eq!(snap, snap2, "render(load(render(cfg))) must be byte-identical");
        // The reloaded config matches field for field.
        assert_eq!(cfg2.arch.name, cfg.arch.name);
        assert_eq!(cfg2.arch.levels.len(), cfg.arch.levels.len());
        assert_eq!(cfg2.arch.levels[0].capacity_bits, u64::MAX, "DRAM sentinel");
        assert_eq!(cfg2.workload.ops.len(), 2);
        assert_eq!(cfg2.workload.ops[0].name, "fc1");
        assert_eq!(cfg2.workload.ops[1].name, "op1");
        assert_eq!(cfg2.search.metric, cfg.search.metric);
        assert_eq!(cfg2.search.mode, FormatMode::Fixed);
        assert_eq!(cfg2.search.mapper.max_candidates, 500);
        assert_eq!(cfg2.search.mapper.orders, cfg.search.mapper.orders);
        assert_eq!(cfg2.search.threads, 3);
        assert!(!cfg2.search.prune);
    }

    #[test]
    fn snapshot_preserves_structured_sparsity() {
        let cfg = load_run_config(
            "[run]\narch = \"arch3\"\nworkload = \"llama2-7b-nm24\"\n",
        )
        .unwrap();
        let snap = render(&cfg.arch, &cfg.workload, &cfg.search);
        let cfg2 = load_run_config_any(&snap).unwrap();
        assert_eq!(cfg2.workload.name, cfg.workload.name);
        assert_eq!(cfg2.workload.ops.len(), cfg.workload.ops.len());
        for (a, b) in cfg.workload.ops.iter().zip(&cfg2.workload.ops) {
            assert_eq!(a.spec.input, b.spec.input, "{}", a.name);
            assert_eq!(a.spec.weight, b.spec.weight, "{}", a.name);
            assert_eq!(a.dims, b.dims, "{}", a.name);
            assert_eq!(a.count, b.count, "{}", a.name);
        }
    }

    #[test]
    fn snapshot_round_trips_cost_backend() {
        // Contention with non-default knobs: TOML → snapshot → reload →
        // identical CostModel, and the snapshot is still a fixed point.
        let src = format!(
            "{SRC}[cost]\nbackend = \"contention\"\nbandwidth_derate = 0.75\n\
             burst_bits = [1024, 256]\ndecompress_bits_per_cycle = 0\n"
        );
        let cfg = load_run_config(&src).unwrap();
        let CostModel::Contention(p) = cfg.search.cost else { panic!("not contention") };
        assert_eq!(p.decompress_bits_per_cycle, None);
        let snap = render(&cfg.arch, &cfg.workload, &cfg.search);
        assert!(snap.contains("\"backend\":\"contention\""), "{snap}");
        let cfg2 = load_run_config_any(&snap).unwrap();
        assert_eq!(cfg2.search.cost, cfg.search.cost);
        let snap2 = render(&cfg2.arch, &cfg2.workload, &cfg2.search);
        assert_eq!(snap, snap2);

        // Analytical serializes compactly and round-trips too.
        let cfg = load_run_config(SRC).unwrap();
        let snap = render(&cfg.arch, &cfg.workload, &cfg.search);
        assert!(snap.contains("\"cost\":{\"backend\":\"analytical\"}"), "{snap}");
        assert_eq!(load_run_config_any(&snap).unwrap().search.cost, CostModel::Analytical);
    }

    #[test]
    fn snapshot_round_trips_quant_axis() {
        // [quant] TOML → snapshot → reload → identical QuantConfig, and
        // the snapshot stays a fixed point.  Unsorted input canonicalizes.
        let src = format!("{SRC}[quant]\nw_bits = [16, 4, 8]\nkv_bits = 8\n");
        let cfg = load_run_config(&src).unwrap();
        let q = &cfg.search.quant;
        assert_eq!(q.w_bits.as_ref().unwrap().values(), &[4, 8, 16]);
        assert_eq!(q.a_bits, None);
        assert_eq!(q.kv_bits.as_ref().unwrap().values(), &[8]);
        let snap = render(&cfg.arch, &cfg.workload, &cfg.search);
        assert!(snap.contains("\"w_bits\":[4,8,16]"), "{snap}");
        assert!(snap.contains("\"a_bits\":null"), "{snap}");
        let cfg2 = load_run_config_any(&snap).unwrap();
        assert_eq!(cfg2.search.quant, cfg.search.quant);
        let snap2 = render(&cfg2.arch, &cfg2.workload, &cfg2.search);
        assert_eq!(snap, snap2);
    }

    #[test]
    fn legacy_snapshot_without_quant_defaults_to_disabled() {
        let cfg = load_run_config(SRC).unwrap();
        let snap = render(&cfg.arch, &cfg.workload, &cfg.search);
        // Strip the quant key the way a pre-quant snapshot looked.
        let legacy = snap
            .replace(",\"quant\":{\"w_bits\":null,\"a_bits\":null,\"kv_bits\":null}", "");
        assert_ne!(legacy, snap, "strip pattern went stale");
        let cfg2 = load_run_config_json(&legacy).unwrap();
        assert!(cfg2.search.quant.is_default());
    }

    #[test]
    fn tampered_quant_snapshots_are_rejected() {
        let src = format!("{SRC}[quant]\nw_bits = [4, 8]\n");
        let cfg = load_run_config(&src).unwrap();
        let snap = render(&cfg.arch, &cfg.workload, &cfg.search);
        let bad = snap.replace("\"w_bits\":[4,8]", "\"w_bits\":[0]");
        assert!(load_run_config_json(&bad).unwrap_err().to_string().contains("w_bits"));
        let bad = snap.replace("\"w_bits\":[4,8]", "\"w_bits\":[]");
        assert!(load_run_config_json(&bad).unwrap_err().to_string().contains("empty"));
        let bad = snap.replace("\"w_bits\":[4,8]", "\"w_bits\":\"4,8\"");
        assert!(load_run_config_json(&bad).unwrap_err().to_string().contains("array"));
    }

    #[test]
    fn legacy_snapshot_without_cost_defaults_to_analytical() {
        let cfg = load_run_config(SRC).unwrap();
        let snap = render(&cfg.arch, &cfg.workload, &cfg.search);
        // Strip the cost key the way a pre-backend snapshot looked.
        let legacy = snap.replace(",\"cost\":{\"backend\":\"analytical\"}", "");
        assert_ne!(legacy, snap, "strip pattern went stale");
        let cfg2 = load_run_config_json(&legacy).unwrap();
        assert_eq!(cfg2.search.cost, CostModel::Analytical);
    }

    #[test]
    fn legacy_snapshot_without_best_first_defaults_to_on() {
        let cfg = load_run_config(SRC).unwrap();
        let snap = render(&cfg.arch, &cfg.workload, &cfg.search);
        // Strip the key the way a pre-best-first snapshot looked.
        let legacy = snap.replace(",\"best_first\":true", "");
        assert_ne!(legacy, snap, "strip pattern went stale");
        let cfg2 = load_run_config_json(&legacy).unwrap();
        assert!(cfg2.search.best_first);
    }

    #[test]
    fn frontier_metric_round_trips() {
        let src = SRC.replace("metric = \"memory-energy\"", "metric = \"frontier\"");
        assert_ne!(src, SRC, "replace pattern went stale");
        let cfg = load_run_config(&src).unwrap();
        assert_eq!(cfg.search.metric, Metric::Frontier);
        let snap = render(&cfg.arch, &cfg.workload, &cfg.search);
        assert!(snap.contains("\"metric\":\"frontier\""), "{snap}");
        let cfg2 = load_run_config_any(&snap).unwrap();
        assert_eq!(cfg2.search.metric, Metric::Frontier);
        let snap2 = render(&cfg2.arch, &cfg2.workload, &cfg2.search);
        assert_eq!(snap, snap2);
    }

    #[test]
    fn tampered_cost_snapshots_are_rejected() {
        let src = format!("{SRC}[cost]\nbackend = \"contention\"\n");
        let cfg = load_run_config(&src).unwrap();
        let snap = render(&cfg.arch, &cfg.workload, &cfg.search);
        let bad = snap.replace("\"backend\":\"contention\"", "\"backend\":\"vibes\"");
        assert!(load_run_config_json(&bad).unwrap_err().to_string().contains("vibes"));
        // Out-of-range knobs funnel through ContentionParams::validate.
        let bad = snap.replace("\"bandwidth_derate\":[1,", "\"bandwidth_derate\":[7,");
        assert!(load_run_config_json(&bad)
            .unwrap_err()
            .to_string()
            .contains("bandwidth_derate"));
        // Truncated per-level arrays are rejected, not zero-filled.
        let bad = snap.replace("\"burst_bits\":[512,", "\"burst_bits\":[");
        assert!(load_run_config_json(&bad).unwrap_err().to_string().contains("entries"));
    }

    #[test]
    fn tampered_snapshots_are_rejected() {
        let cfg = load_run_config(SRC).unwrap();
        let snap = render(&cfg.arch, &cfg.workload, &cfg.search);
        assert!(load_run_config_json("{}").is_err(), "missing version marker");
        let vers = snap.replace("\"snipsnap_run_config\":1", "\"snipsnap_run_config\":99");
        assert!(load_run_config_json(&vers).unwrap_err().to_string().contains("version"));
        let metric = snap.replace("\"metric\":\"memory-energy\"", "\"metric\":\"vibes\"");
        assert!(load_run_config_json(&metric).is_err());
        // Semantic validation matches the TOML path: out-of-range
        // densities and degenerate N:M specs cannot be smuggled in.
        let dens = snap.replace("\"density\":0.4", "\"density\":0");
        assert!(load_run_config_json(&dens).unwrap_err().to_string().contains("density"));
        let neg = snap.replace("\"density\":0.4", "\"density\":-1");
        assert!(load_run_config_json(&neg).is_err());
        assert!(load_run_config_json(&snap.replace("\"orders\":[", "\"orders\":[\"MMK\","))
            .unwrap_err()
            .to_string()
            .contains("permutation"));
        // TOML text through the JSON loader fails cleanly, and vice versa
        // the dispatcher routes each format correctly.
        assert!(load_run_config_json(SRC).is_err());
        assert!(load_run_config_any(SRC).is_ok());
        assert!(load_run_config_any(&snap).is_ok());
    }
}
