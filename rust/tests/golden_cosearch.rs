//! Golden co-search regression suite.
//!
//! One small fixed workload per scenario family — MHA, GQA, MoE,
//! batched decode, N:M weights — is co-searched and the winning design
//! (format pair names, full mapping incl. loop orders, metric value to
//! 6 decimals, evaluation count) is rendered to a canonical text form.
//!
//! Two layers of protection:
//!
//! 1. **Thread determinism** (always on): the design render must be
//!    identical at `threads ∈ {1, 3, 4}` — 3 exercises the non-divisor
//!    sharding split (`threads % workers != 0`) that `cosearch_e2e`
//!    never covers.  Only the designs are compared across thread
//!    counts: with branch-and-bound pruning on (the default), the
//!    `evaluations` counter legitimately depends on the shard count
//!    (each shard prunes against its own incumbent — docs/SEARCH.md).
//! 2. **Golden fixtures**: the serial render (designs + the serial
//!    evaluation count, which *is* deterministic) is compared against
//!    `rust/tests/golden/<scenario>.txt`.  Regenerate intentionally
//!    changed fixtures with
//!    `SNIPSNAP_BLESS=1 cargo test --test golden_cosearch`.  A missing
//!    fixture is a skip (with the bless command) on fresh local
//!    checkouts, but a **hard failure** when `SNIPSNAP_REQUIRE_GOLDEN=1`
//!    — CI sets that after a bless-if-absent step, so there is no
//!    silent escape hatch there: fixtures are either committed or
//!    generated-then-verified (debug bless, release compare) within the
//!    same CI run.

use snipsnap::arch::presets;
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::search::{cosearch_workload, SearchConfig, WorkloadResult};
use snipsnap::workload::llm::{build_llm, LlmShape, LlmSparsity, Phase};
use snipsnap::workload::moe::{build_moe, MoeShape};
use snipsnap::workload::{llm, Workload};
use std::fmt::Write as _;
use std::path::PathBuf;

const SP: LlmSparsity =
    LlmSparsity { act_proj: 0.55, act_fc1: 0.50, act_fc2: 0.20, attn: 0.30, weight: 0.40 };

fn mha_small() -> Workload {
    build_llm("mha-small", LlmShape::mha(64, 128, 1, 4), SP, Phase::new(16, 4))
}

fn gqa_small() -> Workload {
    build_llm(
        "gqa-small",
        LlmShape { hidden: 64, intermediate: 128, layers: 1, heads: 4, kv_heads: 2 },
        SP,
        Phase::new(16, 4),
    )
}

fn moe_small() -> Workload {
    build_moe(
        "moe-small",
        MoeShape { base: LlmShape::mha(64, 128, 1, 4), experts: 4, top_k: 2 },
        SP,
        Phase::new(16, 4),
    )
}

fn batched_decode_small() -> Workload {
    build_llm(
        "batched-small",
        LlmShape::mha(64, 128, 1, 4),
        SP,
        Phase::new(0, 8).with_batch(4).with_kv_density(0.5),
    )
}

fn nm_small() -> Workload {
    llm::weight_nm_variant(mha_small(), 2, 4)
}

/// Canonical text render of the designs: everything the cross-thread
/// contract pins, nothing time-, machine- or shard-dependent.
fn render_designs(r: &WorkloadResult) -> String {
    let mut s = String::new();
    for d in &r.designs {
        writeln!(
            s,
            "{} | I={} | W={} | map={} | value={:.6e}",
            d.op_name, d.input_format, d.weight_format, d.mapping, d.metric_value
        )
        .unwrap();
    }
    s
}

/// Fixture render: the designs plus the serial-run evaluation count
/// (deterministic at `threads = 1`, a useful regression tripwire for
/// enumeration/sweep/pruning changes).
fn render_fixture(serial: &WorkloadResult) -> String {
    let mut s = render_designs(serial);
    writeln!(s, "evaluations={}", serial.evaluations).unwrap();
    s
}

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden")
        .join(format!("{name}.txt"))
}

fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| v == "1").unwrap_or(false)
}

fn check(name: &str, w: &Workload) {
    let arch = presets::arch3();
    let mk = |threads: usize| SearchConfig {
        threads,
        mapper: MapperConfig { max_candidates: 600, ..Default::default() },
        ..Default::default()
    };
    let serial = cosearch_workload(&arch, w, &mk(1));
    let serial_designs = render_designs(&serial);
    for threads in [3usize, 4] {
        let par = render_designs(&cosearch_workload(&arch, w, &mk(threads)));
        assert_eq!(
            serial_designs, par,
            "{name}: threads={threads} designs diverged from serial"
        );
    }

    let fixture = render_fixture(&serial);
    let path = golden_path(name);
    if env_flag("SNIPSNAP_BLESS") {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &fixture).unwrap();
        eprintln!("BLESSED {}", path.display());
        return;
    }
    match std::fs::read_to_string(&path) {
        Ok(want) => assert_eq!(
            fixture, want,
            "{name}: co-search result changed vs {}.\n\
             If intended, regenerate with `SNIPSNAP_BLESS=1 cargo test --test golden_cosearch`.",
            path.display()
        ),
        Err(_) if env_flag("SNIPSNAP_REQUIRE_GOLDEN") => panic!(
            "{name}: golden fixture {} is missing and SNIPSNAP_REQUIRE_GOLDEN=1. \
             Generate it with `SNIPSNAP_BLESS=1 cargo test --test golden_cosearch` \
             and commit the file.",
            path.display()
        ),
        Err(_) => eprintln!(
            "SKIP golden compare for '{name}': {} missing \
             (create with `SNIPSNAP_BLESS=1 cargo test --test golden_cosearch`)",
            path.display()
        ),
    }
}

#[test]
fn golden_mha() {
    check("mha", &mha_small());
}

#[test]
fn golden_gqa() {
    check("gqa", &gqa_small());
}

#[test]
fn golden_moe() {
    check("moe", &moe_small());
}

#[test]
fn golden_batched_decode() {
    check("batched_decode", &batched_decode_small());
}

#[test]
fn golden_nm() {
    check("nm", &nm_small());
}
