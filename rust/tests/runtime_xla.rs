//! PJRT runtime integration: the AOT XLA artifacts vs the native Rust
//! analyzers.  Requires artifacts built by `python/compile/aot.py` and
//! the `pjrt` feature (skipped with a clear message if the artifacts
//! are missing).

use snipsnap::format::named;
use snipsnap::runtime::stats::{
    analyze_mask, analyze_mask_native, empirical_cost, empirical_ne,
};
use snipsnap::runtime::{InputBuf, Runtime};
use snipsnap::sparsity::exact::exact_cost;
use snipsnap::sparsity::sample::sample_mask;
use snipsnap::sparsity::SparsityPattern;

fn runtime_or_skip() -> Option<Runtime> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("SKIP: built without the `pjrt` feature (Runtime::exec is a stub)");
        return None;
    }
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {} (run python/compile/aot.py)", dir.display());
        return None;
    }
    Some(Runtime::load(&dir).expect("runtime"))
}

#[test]
fn xla_stats_match_native_analyzer() {
    let Some(mut rt) = runtime_or_skip() else { return };
    for pattern in [
        SparsityPattern::Unstructured { density: 0.2 },
        SparsityPattern::Nm { n: 2, m: 4 },
        SparsityPattern::Block { br: 32, bc: 32, block_density: 0.3 },
    ] {
        let mask = sample_mask(&pattern, 512, 512, 41);
        let xla = analyze_mask(&mut rt, &mask).expect("xla stats");
        let native = analyze_mask_native(&mask, 16);
        assert_eq!(xla.total_nnz, native.total_nnz, "{pattern:?}");
        assert_eq!(xla.block_counts, native.block_counts);
        assert_eq!(xla.row_counts, native.row_counts);
        assert_eq!(xla.col_counts, native.col_counts);
    }
}

#[test]
fn xla_empirical_cost_matches_exact_for_aligned_formats() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let pattern = SparsityPattern::Unstructured { density: 0.1 };
    let mask = sample_mask(&pattern, 1024, 1024, 97);
    let stats = analyze_mask(&mut rt, &mask).expect("stats");
    // CSR: all boundaries exact (fibers + elements).
    let csr = named::csr(1024, 1024);
    let emp = empirical_cost(&csr, &stats, 16).total_bits();
    let exact = exact_cost(&csr, &mask, 16).total_bits();
    assert!(
        (emp - exact).abs() / exact < 1e-9,
        "csr: empirical {emp} vs exact {exact}"
    );
    // CSB at lattice granularity: exact except the within-block row level.
    let csb = named::csb(1024, 1024, 16, 16);
    let emp = empirical_cost(&csb, &stats, 16).total_bits();
    let exact = exact_cost(&csb, &mask, 16).total_bits();
    assert!(
        (emp - exact).abs() / exact < 0.02,
        "csb: empirical {emp} vs exact {exact}"
    );
}

#[test]
fn xla_nm_conformance_flags_violations() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // Conforming 2:4 tensor -> 0 violations.
    let ok = sample_mask(&SparsityPattern::Nm { n: 2, m: 4 }, 1024, 1024, 7);
    let outs = rt
        .exec("nm_conformance_1024x1024_2_4", &[InputBuf::F32(&ok.to_f32())])
        .expect("exec");
    assert_eq!(outs[0][0], 0.0);
    // Dense tensor -> every group violates by 2.
    let dense = sample_mask(&SparsityPattern::Dense, 1024, 1024, 0);
    let outs = rt
        .exec("nm_conformance_1024x1024_2_4", &[InputBuf::F32(&dense.to_f32())])
        .expect("exec");
    assert_eq!(outs[0][0] as u64, 2 * 1024 * 256);
}

#[test]
fn xla_rejects_wrong_shapes_and_names() {
    let Some(mut rt) = runtime_or_skip() else { return };
    assert!(rt.exec("nonexistent", &[]).is_err());
    let too_small = vec![0f32; 16];
    assert!(rt
        .exec("sparsity_stats_512x512_b16", &[InputBuf::F32(&too_small)])
        .is_err());
}

#[test]
fn empirical_ne_consistency_across_scales() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // Same density, two artifact scales: per-element expected occupancy
    // must agree within sampling noise.
    let pattern = SparsityPattern::Unstructured { density: 0.15 };
    let m512 = sample_mask(&pattern, 512, 512, 21);
    let m1024 = sample_mask(&pattern, 1024, 1024, 22);
    let s512 = analyze_mask(&mut rt, &m512).expect("512");
    let s1024 = analyze_mask(&mut rt, &m1024).expect("1024");
    let f512 = named::bitmap(512, 512);
    let f1024 = named::bitmap(1024, 1024);
    let r512 = empirical_ne(&f512, &s512).last().copied().unwrap() / (512.0 * 512.0);
    let r1024 = empirical_ne(&f1024, &s1024).last().copied().unwrap() / (1024.0 * 1024.0);
    assert!((r512 - r1024).abs() < 0.01, "density est {r512} vs {r1024}");
}
