//! Differential suite for the run driver (`snipsnap::driver`) — the
//! extraction of the `snipsnap search` pipeline into the library.
//!
//! The load-bearing claims, each pinned here:
//!
//! 1. **`driver::execute` IS the co-search.**  Scalar and frontier runs
//!    through the driver are bit-identical to a direct
//!    `try_cosearch_workload` — designs, scores, evaluations, frontier
//!    winner totals.
//! 2. **`driver::run` is replayable.**  The rendered report is
//!    deterministic, and the snapshot it emits parses back into a
//!    `RunPlan` whose re-run produces the same report bytes (stable
//!    lines) — the pre-extraction `--snapshot` contract, now at the
//!    library seam.
//! 3. **`RunPlan` render/parse is a fixed point** and round-trips the
//!    optional id without disturbing the snapshot form.

use snipsnap::config::load_run_config;
use snipsnap::driver::{self, RunPlan, RunSinks, SnapshotSink};
use snipsnap::search::{try_cosearch_workload, SearchHooks, WorkloadResult};

/// Two small ops with distinct problem dims — enough structure for the
/// format/mapping search to make non-trivial picks, small enough to run
/// in milliseconds.
const SRC: &str = r#"
[run]
arch = "arch3"
metric = "energy"
mode = "fixed"
[search]
max_mappings = 300
[[op]]
name = "a"
m = 32
n = 32
k = 64
act_density = 0.5
wgt_density = 0.4
[[op]]
name = "b"
m = 48
n = 32
k = 32
act_density = 0.3
wgt_density = 0.6
"#;

/// Designs equal bit for bit (mapping, formats, widths, metric value).
fn assert_identical(a: &WorkloadResult, b: &WorkloadResult, what: &str) {
    assert_eq!(a.designs.len(), b.designs.len(), "{what}");
    for (da, db) in a.designs.iter().zip(&b.designs) {
        assert_eq!(da.op_name, db.op_name, "{what}");
        assert_eq!(da.mapping, db.mapping, "{what}: {} mappings diverged", da.op_name);
        assert_eq!(da.input_format, db.input_format, "{what}: {}", da.op_name);
        assert_eq!(da.weight_format, db.weight_format, "{what}: {}", da.op_name);
        assert_eq!(
            (da.input_bits, da.weight_bits),
            (db.input_bits, db.weight_bits),
            "{what}: {}",
            da.op_name
        );
        assert_eq!(
            da.metric_value.to_bits(),
            db.metric_value.to_bits(),
            "{what}: {} metric diverged",
            da.op_name
        );
    }
    assert_eq!(a.evaluations, b.evaluations, "{what}: evaluations diverged");
}

/// Drop the wall-time line; everything else in the report is
/// deterministic for a fixed config (same filter as `rust/tests/cli.rs`
/// uses across processes).
fn stable(bytes: &[u8]) -> String {
    String::from_utf8_lossy(bytes)
        .lines()
        .filter(|l| {
            !l.starts_with("search:") && !l.starts_with("cache:")
                && !l.starts_with("enumeration:")
        })
        .collect::<Vec<_>>()
        .join("\n")
}

/// Claim 1 (scalar): the driver's dispatch is the direct co-search.
#[test]
fn execute_matches_direct_cosearch_bitwise() {
    let run = load_run_config(SRC).unwrap();
    let direct =
        try_cosearch_workload(&run.arch, &run.workload, &run.search, SearchHooks::default())
            .unwrap();
    let via = driver::execute(&run, SearchHooks::default()).unwrap();
    assert_identical(&direct, &via, "driver::execute vs direct co-search");
    assert!(via.frontier.is_none(), "a scalar metric must not grow a frontier");
}

/// Claim 1 (frontier): `--metric frontier` dispatches through the same
/// funnel, with bit-identical per-metric winner totals.
#[test]
fn execute_matches_direct_cosearch_for_frontier() {
    let src = SRC.replace("metric = \"energy\"", "metric = \"frontier\"");
    let run = load_run_config(&src).unwrap();
    let direct =
        try_cosearch_workload(&run.arch, &run.workload, &run.search, SearchHooks::default())
            .unwrap();
    let via = driver::execute(&run, SearchHooks::default()).unwrap();
    assert_identical(&direct, &via, "driver::execute vs direct frontier search");
    let fa = direct.frontier.as_ref().expect("frontier metric must produce a frontier");
    let fb = via.frontier.as_ref().expect("frontier metric must produce a frontier");
    assert_eq!(fa.total_points(), fb.total_points(), "frontier sizes diverged");
    for mi in 0..4 {
        assert_eq!(
            fa.winner_total(mi).to_bits(),
            fb.winner_total(mi).to_bits(),
            "winner total for metric {mi} diverged"
        );
    }
}

/// Claim 2: the driver's report is deterministic and the snapshot it
/// emits replays the run — `RunPlan::parse` of the artifact, re-run
/// through `driver::run`, same stable report bytes.
#[test]
fn run_report_is_deterministic_and_snapshot_replays() {
    let dir = std::env::temp_dir()
        .join(format!("snipsnap_driver_snap_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let snap = dir.join("run.config.json");
    let _ = std::fs::remove_file(&snap);

    let plan = RunPlan::new(load_run_config(SRC).unwrap());
    let (mut out1, mut log1) = (Vec::new(), Vec::new());
    let mut sinks = RunSinks {
        snapshot: SnapshotSink::Path(snap.clone()),
        out: &mut out1,
        log: &mut log1,
    };
    driver::run(&plan, SearchHooks::default(), &mut sinks).unwrap();
    let log = String::from_utf8(log1).unwrap();
    assert!(log.contains("run-config snapshot:"), "{log}");
    assert!(log.contains("arch: arch3"), "{log}");
    let report = stable(&out1);
    assert!(report.contains("totals:"), "{report}");

    // The artifact is a valid plan; replaying it reproduces the report.
    let text = std::fs::read_to_string(&snap).expect("snapshot written");
    let replay = RunPlan::parse(text.trim()).expect("snapshot must parse as a plan");
    assert!(replay.id.is_none(), "snapshots carry no id");
    let (mut out2, mut log2) = (Vec::new(), Vec::new());
    let mut sinks2 =
        RunSinks { snapshot: SnapshotSink::Off, out: &mut out2, log: &mut log2 };
    driver::run(&replay, SearchHooks::default(), &mut sinks2).unwrap();
    assert_eq!(report, stable(&out2), "replayed run diverged from the original");
    let _ = std::fs::remove_file(&snap);
}

/// Claim 3: render ∘ parse is a fixed point, ids round-trip, and a
/// plain plan renders exactly the snapshot line (no stray keys).
#[test]
fn run_plan_render_parse_round_trips_ids() {
    let tagged = RunPlan {
        id: Some("cfg-07".to_string()),
        run: load_run_config(SRC).unwrap(),
    };
    let line = tagged.render();
    assert!(line.ends_with('\n'), "plans render as complete lines");
    assert!(line.contains(r#""id":"cfg-07""#), "{line}");
    let re = RunPlan::parse(line.trim()).unwrap();
    assert_eq!(re.id.as_deref(), Some("cfg-07"));
    assert_eq!(re.render(), line, "render must be a fixed point under parse");

    let plain = RunPlan::new(load_run_config(SRC).unwrap());
    let pline = plain.render();
    assert!(!pline.contains(r#""id":"#), "an id-less plan must not emit one:\n{pline}");
    assert_eq!(RunPlan::parse(pline.trim()).unwrap().id, None);

    // A non-string id is a parse error, not a silent drop.
    let bad = format!(r#"{{"id":7,{}"#, &pline.trim()[1..]);
    let err = RunPlan::parse(&bad).unwrap_err();
    assert!(format!("{err:#}").contains("'id' must be a string"), "{err:#}");
}
