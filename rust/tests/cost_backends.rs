//! Differential suite for the pluggable cost backends (docs/COST.md).
//!
//! Three layers:
//!
//! 1. **Analytical-through-the-trait is the old model, bit for bit.**
//!    For every golden co-search family the default `SearchConfig`
//!    (whose `cost` is `CostModel::Analytical`, i.e. the trait-routed
//!    path) must reproduce the committed golden fixture — designs,
//!    metric values and serial evaluation counts — and an explicitly
//!    selected analytical backend must match the default to the bit.
//!    This suite never blesses fixtures; only `golden_cosearch` does.
//! 2. **Backend dominance and ranking invariants on searched designs**:
//!    the contention backend (burst roundup, bandwidth derate ≤ 1,
//!    decompression on the critical path) can only *add* latency, so
//!    its latency-metric optimum never beats the analytical optimum;
//!    and because the energy model is backend-independent by contract,
//!    energy-metric searches rank identically under both backends.
//! 3. **Property tests** (`util::proptest`): latency monotone
//!    non-increasing in per-level bandwidth, monotone non-decreasing
//!    under power-of-two burst coarsening, compressed transaction
//!    counts never exceeding dense, and finite (no NaN/inf) reports
//!    for every valid configuration.

use snipsnap::arch::presets;
use snipsnap::cost::{
    backend_from_env, transactions, CompressionRatios, ContentionParams, CostBackend, CostModel,
    EvalInputs, Metric,
};
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::dataflow::{
    access_counts, LoopDim, Mapping, ProblemDims, Spatial, TileLevel, MAX_LEVELS,
};
use snipsnap::search::{cosearch_workload, SearchConfig, WorkloadResult};
use snipsnap::sparsity::{reduction::ReductionStrategy, SparsitySpec};
use snipsnap::util::proptest::{run, Gen};
use snipsnap::workload::llm::{build_llm, LlmShape, LlmSparsity, Phase};
use snipsnap::workload::moe::{build_moe, MoeShape};
use snipsnap::workload::{llm, Workload};
use std::fmt::Write as _;
use std::path::PathBuf;

// ---------------------------------------------------------------------
// Golden families — must stay in lockstep with rust/tests/golden_cosearch.rs
// (same workloads, same mapper budget, same render) so both suites pin
// the same fixtures.

const SP: LlmSparsity =
    LlmSparsity { act_proj: 0.55, act_fc1: 0.50, act_fc2: 0.20, attn: 0.30, weight: 0.40 };

fn mha_small() -> Workload {
    build_llm("mha-small", LlmShape::mha(64, 128, 1, 4), SP, Phase::new(16, 4))
}

fn families() -> Vec<(&'static str, Workload)> {
    vec![
        ("mha", mha_small()),
        (
            "gqa",
            build_llm(
                "gqa-small",
                LlmShape { hidden: 64, intermediate: 128, layers: 1, heads: 4, kv_heads: 2 },
                SP,
                Phase::new(16, 4),
            ),
        ),
        (
            "moe",
            build_moe(
                "moe-small",
                MoeShape { base: LlmShape::mha(64, 128, 1, 4), experts: 4, top_k: 2 },
                SP,
                Phase::new(16, 4),
            ),
        ),
        (
            "batched_decode",
            build_llm(
                "batched-small",
                LlmShape::mha(64, 128, 1, 4),
                SP,
                Phase::new(0, 8).with_batch(4).with_kv_density(0.5),
            ),
        ),
        ("nm", llm::weight_nm_variant(mha_small(), 2, 4)),
    ]
}

fn render_designs(r: &WorkloadResult) -> String {
    let mut s = String::new();
    for d in &r.designs {
        writeln!(
            s,
            "{} | I={} | W={} | map={} | value={:.6e}",
            d.op_name, d.input_format, d.weight_format, d.mapping, d.metric_value
        )
        .unwrap();
    }
    s
}

fn render_fixture(serial: &WorkloadResult) -> String {
    let mut s = render_designs(serial);
    writeln!(s, "evaluations={}", serial.evaluations).unwrap();
    s
}

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden")
        .join(format!("{name}.txt"))
}

fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| v == "1").unwrap_or(false)
}

fn golden_cfg(cost: CostModel) -> SearchConfig {
    SearchConfig {
        cost,
        mapper: MapperConfig { max_candidates: 600, ..Default::default() },
        ..Default::default()
    }
}

// ---------------------------------------------------------------------
// Layer 1 — differential vs the golden fixtures.

#[test]
fn analytical_through_trait_matches_golden_fixtures() {
    let arch = presets::arch3();
    for (name, w) in families() {
        let default = cosearch_workload(&arch, &w, &golden_cfg(CostModel::default()));
        let explicit = cosearch_workload(&arch, &w, &golden_cfg(CostModel::Analytical));

        // Explicit backend selection is the same code path as the
        // default: designs, scores and evaluation counts to the bit.
        assert_eq!(
            render_fixture(&default),
            render_fixture(&explicit),
            "{name}: explicit analytical backend diverged from the default config"
        );
        assert_eq!(default.designs.len(), explicit.designs.len(), "{name}");
        for (a, b) in default.designs.iter().zip(&explicit.designs) {
            assert_eq!(
                a.metric_value.to_bits(),
                b.metric_value.to_bits(),
                "{name}/{}: score not bit-identical through the trait",
                a.op_name
            );
        }
        assert_eq!(default.evaluations, explicit.evaluations, "{name}: evaluation count");

        // And the trait-routed model still reproduces the committed
        // pre-refactor fixtures.  Blessing runs are golden_cosearch's
        // job; here a blessing pass just skips the compare.
        if env_flag("SNIPSNAP_BLESS") {
            continue;
        }
        let path = golden_path(name);
        match std::fs::read_to_string(&path) {
            Ok(want) => assert_eq!(
                render_fixture(&default),
                want,
                "{name}: trait-routed analytical search diverged from {}",
                path.display()
            ),
            Err(_) if env_flag("SNIPSNAP_REQUIRE_GOLDEN") => panic!(
                "{name}: golden fixture {} is missing and SNIPSNAP_REQUIRE_GOLDEN=1. \
                 Generate it with `SNIPSNAP_BLESS=1 cargo test --test golden_cosearch` \
                 and commit the file.",
                path.display()
            ),
            Err(_) => eprintln!(
                "SKIP golden compare for '{name}': {} missing \
                 (create with `SNIPSNAP_BLESS=1 cargo test --test golden_cosearch`)",
                path.display()
            ),
        }
    }
}

// ---------------------------------------------------------------------
// Layer 2 — cross-backend invariants on full co-searches.

#[test]
fn contention_latency_never_beats_analytical() {
    let arch = presets::arch3();
    let mk = |cost| SearchConfig {
        metric: Metric::Latency,
        cost,
        mapper: MapperConfig { max_candidates: 300, ..Default::default() },
        ..Default::default()
    };
    for (name, w) in families() {
        let a = cosearch_workload(&arch, &w, &mk(CostModel::Analytical));
        let c = cosearch_workload(
            &arch,
            &w,
            &mk(CostModel::Contention(ContentionParams::default())),
        );
        // Contention dominates analytical exactly on every *evaluated
        // mapping* (the report-level theorem, asserted strictly in the
        // property tests below and in cost::tests), and both searches
        // minimize over the same candidate arena.  The whole-search
        // comparison additionally crosses the greedy tile-refinement
        // stage, whose trajectory legitimately depends on the backend's
        // metric — so it gets a small slack instead of exactness; it
        // still catches any wiring error that made contention cheap.
        let slack = 0.98;
        assert!(
            c.total_cycles() >= a.total_cycles() * slack,
            "{name}: contention total {} < analytical {}",
            c.total_cycles(),
            a.total_cycles()
        );
        assert_eq!(a.designs.len(), c.designs.len(), "{name}");
        for (da, dc) in a.designs.iter().zip(&c.designs) {
            assert_eq!(da.op_name, dc.op_name, "{name}: op order diverged");
            assert!(
                dc.metric_value >= da.metric_value * slack,
                "{name}/{}: contention optimum {} undercut analytical {}",
                da.op_name,
                dc.metric_value,
                da.metric_value
            );
            assert!(dc.metric_value.is_finite(), "{name}/{}", da.op_name);
        }
    }
}

#[test]
fn energy_metric_searches_rank_identically_under_both_backends() {
    // The energy model is backend-independent by the CostBackend
    // contract (only bits→cycles dispatches), so an energy-metric
    // search sees identical scores — and therefore identical designs,
    // pruning decisions and evaluation counts — under every backend.
    let arch = presets::arch3();
    let w = mha_small();
    for metric in [Metric::Energy, Metric::MemoryEnergy] {
        let mk = |cost| SearchConfig {
            metric,
            cost,
            mapper: MapperConfig { max_candidates: 300, ..Default::default() },
            ..Default::default()
        };
        let a = cosearch_workload(&arch, &w, &mk(CostModel::Analytical));
        let c = cosearch_workload(
            &arch,
            &w,
            &mk(CostModel::Contention(ContentionParams::default())),
        );
        assert_eq!(
            render_fixture(&a),
            render_fixture(&c),
            "{metric:?}: energy-metric search is not backend-independent"
        );
        assert_eq!(
            a.total_energy_pj().to_bits(),
            c.total_energy_pj().to_bits(),
            "{metric:?}: total energy diverged across backends"
        );
    }
}

#[test]
fn backend_from_env_selects_and_drives_a_search() {
    // All SNIPSNAP_COST_BACKEND handling lives in this one test (env
    // mutation is process-global and tests run concurrently).
    let original = std::env::var("SNIPSNAP_COST_BACKEND").ok();
    std::env::remove_var("SNIPSNAP_COST_BACKEND");
    assert_eq!(backend_from_env(), CostModel::Analytical);
    std::env::set_var("SNIPSNAP_COST_BACKEND", "contention");
    assert_eq!(backend_from_env(), CostModel::Contention(ContentionParams::default()));
    std::env::set_var("SNIPSNAP_COST_BACKEND", "analytical");
    assert_eq!(backend_from_env(), CostModel::Analytical);
    std::env::set_var("SNIPSNAP_COST_BACKEND", "bogus");
    let r = std::panic::catch_unwind(backend_from_env);
    assert!(r.is_err(), "bad SNIPSNAP_COST_BACKEND must panic, not default silently");
    match &original {
        Some(v) => std::env::set_var("SNIPSNAP_COST_BACKEND", v),
        None => std::env::remove_var("SNIPSNAP_COST_BACKEND"),
    }

    // CI runs this binary once per backend via SNIPSNAP_COST_BACKEND;
    // actually search under whatever the environment selected, and pin
    // the invariant both backends share: the env-selected optimum never
    // undercuts the analytical one (equal when the env picks
    // analytical, dominating when it picks contention).
    let cost = backend_from_env();
    let arch = presets::arch3();
    let w = mha_small();
    let mk = |cost| SearchConfig {
        metric: Metric::Latency,
        cost,
        mapper: MapperConfig { max_candidates: 300, ..Default::default() },
        ..Default::default()
    };
    let env_run = cosearch_workload(&arch, &w, &mk(cost));
    let analytical = cosearch_workload(&arch, &w, &mk(CostModel::Analytical));
    assert!(env_run.total_cycles().is_finite() && env_run.total_cycles() > 0.0);
    // Slack for the backend-dependent refinement trajectory, as in
    // contention_latency_never_beats_analytical.
    assert!(
        env_run.total_cycles() >= analytical.total_cycles() * 0.98,
        "{cost}: env-selected backend undercut the analytical optimum"
    );
    if cost == CostModel::Analytical {
        assert_eq!(
            env_run.total_cycles().to_bits(),
            analytical.total_cycles().to_bits()
        );
    }
}

// ---------------------------------------------------------------------
// Layer 3 — property tests over the contention model.

/// Small legal 3-level mapping on arch3's hierarchy, shared by the
/// report-level properties.
fn toy() -> (ProblemDims, Mapping) {
    let p = ProblemDims::new(64, 64, 64);
    let mapping = Mapping {
        levels: vec![
            TileLevel { factors: [4, 4, 4], order: [LoopDim::M, LoopDim::N, LoopDim::K] },
            TileLevel { factors: [4, 4, 4], order: [LoopDim::K, LoopDim::M, LoopDim::N] },
            TileLevel { factors: [1, 4, 1], order: [LoopDim::N, LoopDim::K, LoopDim::M] },
        ],
        spatial: Spatial {
            dim_rows: LoopDim::M,
            unroll_rows: 4,
            dim_cols: LoopDim::K,
            unroll_cols: 4,
        },
    };
    mapping.validate(&p).unwrap();
    (p, mapping)
}

/// A random valid parameter set: derates in (0, 1], power-of-two bursts
/// (so burst-coarsening comparisons are exact in f64), optional
/// decompression throughput.
fn gen_params(g: &mut Gen) -> ContentionParams {
    let mut derate = [1.0f64; MAX_LEVELS];
    let mut burst = [1.0f64; MAX_LEVELS];
    for b in 0..MAX_LEVELS {
        derate[b] = g.f64_in(0.05, 1.0);
        burst[b] = (1u64 << g.usize_in(0, 10)) as f64;
    }
    let decomp = if g.bool() { Some(g.f64_in(1.0, 1e5)) } else { None };
    ContentionParams {
        bandwidth_derate: derate,
        burst_bits: burst,
        decompress_bits_per_cycle: decomp,
    }
}

#[test]
fn prop_latency_monotone_non_increasing_in_bandwidth() {
    let arch = presets::arch3();
    let (p, mapping) = toy();
    let ac = access_counts(&mapping, &p);
    let reduction = ReductionStrategy::NONE;
    run("latency monotone in bandwidth", 200, |g: &mut Gen| {
        let spec = SparsitySpec::unstructured(g.density(), g.density());
        let ratios =
            CompressionRatios { input: g.f64_in(0.05, 1.0), weight: g.f64_in(0.05, 1.0) };
        let lo = gen_params(g);
        let mut hi = lo;
        for b in 0..MAX_LEVELS {
            hi.bandwidth_derate[b] = (lo.bandwidth_derate[b] * g.f64_in(1.0, 4.0)).min(1.0);
        }
        hi.validate().unwrap();
        let inp = EvalInputs {
            arch: &arch,
            p: &p,
            mapping: &mapping,
            spec: &spec,
            reduction: &reduction,
            ratios: &ratios,
        };
        let cy_lo = CostModel::Contention(lo).report(&inp, &ac).latency_cycles();
        let cy_hi = CostModel::Contention(hi).report(&inp, &ac).latency_cycles();
        assert!(
            cy_hi <= cy_lo,
            "raising per-level bandwidth increased latency: {cy_hi} > {cy_lo}"
        );
    });
}

#[test]
fn prop_latency_monotone_non_decreasing_in_burst() {
    // Monotonicity is claimed (and holds) on divisibility chains:
    // rounding up to a coarser multiple of a finer granularity can only
    // grow.  It does NOT hold for arbitrary burst pairs (10 bits at
    // burst 3 → 12 > burst 5 → 10), hence the power-of-two doubling.
    let arch = presets::arch3();
    run("latency monotone in burst", 200, |g: &mut Gen| {
        let b_lvl = g.usize_in(0, arch.levels.len() - 1);
        let op_bits =
            [g.f64_in(0.0, 1e9), g.f64_in(0.0, 1e9), g.f64_in(0.0, 1e9)];
        let total = op_bits[0] + op_bits[1] + op_bits[2];
        let fine = gen_params(g);
        let mut coarse = fine;
        coarse.burst_bits[b_lvl] = fine.burst_bits[b_lvl] * (1u64 << g.usize_in(1, 3)) as f64;
        coarse.validate().unwrap();
        let ratios = CompressionRatios { input: g.f64_in(0.05, 1.0), weight: 1.0 };
        let cy_fine =
            CostModel::Contention(fine).boundary_cycles(&arch, b_lvl, &op_bits, total, &ratios);
        let cy_coarse =
            CostModel::Contention(coarse).boundary_cycles(&arch, b_lvl, &op_bits, total, &ratios);
        assert!(
            cy_coarse >= cy_fine,
            "coarser burst decreased service time: {cy_coarse} < {cy_fine} (boundary {b_lvl})"
        );
    });
}

#[test]
fn prop_compressed_transactions_never_exceed_dense() {
    run("compressed transactions <= dense", 300, |g: &mut Gen| {
        let burst = (1u64 << g.usize_in(0, 12)) as f64;
        let dense_bits = g.f64_in(0.0, 1e9);
        let ratio = g.density();
        let tx_c = transactions(dense_bits * ratio, burst);
        let tx_d = transactions(dense_bits, burst);
        assert!(
            tx_c <= tx_d,
            "compression grew the transaction count: {tx_c} > {tx_d} \
             (bits {dense_bits}, ratio {ratio}, burst {burst})"
        );
        // At density 1.0 the compressed block IS the dense block.
        assert_eq!(transactions(dense_bits * 1.0, burst).to_bits(), tx_d.to_bits());
    });
}

#[test]
fn prop_reports_are_finite_and_contention_dominates() {
    let arch = presets::arch3();
    let (p, mapping) = toy();
    let ac = access_counts(&mapping, &p);
    let reduction = ReductionStrategy::NONE;
    run("reports finite for valid configs", 200, |g: &mut Gen| {
        let params = gen_params(g);
        let model = CostModel::Contention(params);
        model.validate().unwrap();
        let spec = SparsitySpec::unstructured(g.density(), g.density());
        let ratios =
            CompressionRatios { input: g.f64_in(0.05, 1.0), weight: g.f64_in(0.05, 1.0) };
        let inp = EvalInputs {
            arch: &arch,
            p: &p,
            mapping: &mapping,
            spec: &spec,
            reduction: &reduction,
            ratios: &ratios,
        };
        let ra = CostModel::Analytical.report(&inp, &ac);
        let rc = model.report(&inp, &ac);
        for (tag, r) in [("analytical", &ra), ("contention", &rc)] {
            assert!(r.mac_energy_pj.is_finite(), "{tag}: mac energy");
            assert!(r.compute_cycles.is_finite(), "{tag}: compute cycles");
            assert!(r.latency_cycles().is_finite(), "{tag}: latency");
            assert!(r.total_energy_pj().is_finite(), "{tag}: energy");
            assert!(r.edp().is_finite(), "{tag}: edp");
            for c in r.mem_cycles.iter() {
                assert!(c.is_finite() && *c >= 0.0, "{tag}: mem cycles {c}");
            }
            for e in r.mem_energy_pj.iter() {
                assert!(e.is_finite() && *e >= 0.0, "{tag}: mem energy {e}");
            }
        }
        assert!(
            rc.latency_cycles() >= ra.latency_cycles(),
            "contention {} < analytical {}",
            rc.latency_cycles(),
            ra.latency_cycles()
        );
        // Energy is backend-independent, bit for bit.
        assert_eq!(ra.total_energy_pj().to_bits(), rc.total_energy_pj().to_bits());
    });
}
