//! Differential + property suite for the quantization co-search axis
//! (`format::quant`, docs/SEARCH.md).
//!
//! Three layers, mirroring `cost_backends.rs`:
//!
//! 1. **Disabled axis is the pre-quantization search, bit for bit.**
//!    With `SearchConfig::quant` at its default (all spaces `None`) the
//!    co-search must reproduce the committed golden fixtures — designs,
//!    metric values and serial evaluation counts — and an explicit
//!    all-`{data_bits}` singleton config must match the default to the
//!    bit, across every metric, both cost backends, prune on/off and
//!    thread counts 1/3/4.  This suite never blesses fixtures; only
//!    `golden_cosearch` does.
//! 2. **Quant searches keep the determinism contract**: a multi-width
//!    search produces bit-identical designs (including the chosen
//!    widths) for any thread count and with pruning on or off.
//! 3. **Property tests** (`util::proptest`): format bits strictly
//!    monotone in the payload width with precision-independent metadata;
//!    a search over a width set dominates every fixed-width search of
//!    that set exactly (per-combination truncation in `format_pairs` +
//!    per-choice refinement make this a theorem, not a heuristic); the
//!    searched width is always a member of the configured set; and
//!    snapshot render∘load is a fixed point for `[quant]` configs.

use snipsnap::arch::presets;
use snipsnap::config::{load_run_config_any, snapshot};
use snipsnap::cost::{backend_from_env, ContentionParams, CostModel, Metric};
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::dataflow::ProblemDims;
use snipsnap::engine::{search_formats, EngineConfig};
use snipsnap::format::quant::{BitwidthSpace, QuantConfig};
use snipsnap::search::{
    cosearch_op, cosearch_workload, SearchConfig, SearchTelemetry, WorkloadResult,
};
use snipsnap::sparsity::analyzer::analytical_cost_quant;
use snipsnap::sparsity::{SparsityPattern, SparsitySpec};
use snipsnap::util::proptest::{run, Gen};
use snipsnap::workload::llm::{build_llm, LlmShape, LlmSparsity, Phase};
use snipsnap::workload::moe::{build_moe, MoeShape};
use snipsnap::workload::{llm, MatMulOp, Workload};
use std::fmt::Write as _;
use std::path::PathBuf;

// ---------------------------------------------------------------------
// Golden families — in lockstep with rust/tests/golden_cosearch.rs and
// rust/tests/cost_backends.rs (same workloads, same mapper budget, same
// render) so all three suites pin the same fixtures.

const SP: LlmSparsity =
    LlmSparsity { act_proj: 0.55, act_fc1: 0.50, act_fc2: 0.20, attn: 0.30, weight: 0.40 };

fn mha_small() -> Workload {
    build_llm("mha-small", LlmShape::mha(64, 128, 1, 4), SP, Phase::new(16, 4))
}

fn families() -> Vec<(&'static str, Workload)> {
    vec![
        ("mha", mha_small()),
        (
            "gqa",
            build_llm(
                "gqa-small",
                LlmShape { hidden: 64, intermediate: 128, layers: 1, heads: 4, kv_heads: 2 },
                SP,
                Phase::new(16, 4),
            ),
        ),
        (
            "moe",
            build_moe(
                "moe-small",
                MoeShape { base: LlmShape::mha(64, 128, 1, 4), experts: 4, top_k: 2 },
                SP,
                Phase::new(16, 4),
            ),
        ),
        (
            "batched_decode",
            build_llm(
                "batched-small",
                LlmShape::mha(64, 128, 1, 4),
                SP,
                Phase::new(0, 8).with_batch(4).with_kv_density(0.5),
            ),
        ),
        ("nm", llm::weight_nm_variant(mha_small(), 2, 4)),
    ]
}

fn render_designs(r: &WorkloadResult) -> String {
    let mut s = String::new();
    for d in &r.designs {
        writeln!(
            s,
            "{} | I={} | W={} | map={} | value={:.6e}",
            d.op_name, d.input_format, d.weight_format, d.mapping, d.metric_value
        )
        .unwrap();
    }
    s
}

fn render_fixture(serial: &WorkloadResult) -> String {
    let mut s = render_designs(serial);
    writeln!(s, "evaluations={}", serial.evaluations).unwrap();
    s
}

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden")
        .join(format!("{name}.txt"))
}

fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| v == "1").unwrap_or(false)
}

fn golden_cfg() -> SearchConfig {
    SearchConfig {
        mapper: MapperConfig { max_candidates: 600, ..Default::default() },
        ..Default::default()
    }
}

fn small_cfg() -> SearchConfig {
    SearchConfig {
        mapper: MapperConfig { max_candidates: 300, ..Default::default() },
        ..Default::default()
    }
}

/// Every operand class pinned at `bits` — the explicit spelling of the
/// disabled axis when `bits` is the accelerator word width.
fn all_fixed(bits: u32) -> QuantConfig {
    QuantConfig {
        w_bits: Some(BitwidthSpace::fixed(bits)),
        a_bits: Some(BitwidthSpace::fixed(bits)),
        kv_bits: Some(BitwidthSpace::fixed(bits)),
    }
}

fn assert_identical(a: &WorkloadResult, b: &WorkloadResult, what: &str) {
    assert_eq!(render_fixture(a), render_fixture(b), "{what}");
    assert_eq!(a.designs.len(), b.designs.len(), "{what}");
    for (da, db) in a.designs.iter().zip(&b.designs) {
        assert_eq!(
            da.metric_value.to_bits(),
            db.metric_value.to_bits(),
            "{what}/{}: score not bit-identical",
            da.op_name
        );
        assert_eq!(
            (da.input_bits, da.weight_bits),
            (db.input_bits, db.weight_bits),
            "{what}/{}: chosen widths diverged",
            da.op_name
        );
    }
    assert_eq!(a.evaluations, b.evaluations, "{what}: evaluation count");
}

// ---------------------------------------------------------------------
// Layer 1 — the disabled axis is the pre-quantization flow.

#[test]
fn quant_disabled_reproduces_the_golden_fixtures() {
    let arch = presets::arch3();
    let native = golden_cfg().engine.data_bits;
    for (name, w) in families() {
        let disabled = cosearch_workload(&arch, &w, &golden_cfg());
        let explicit = cosearch_workload(
            &arch,
            &w,
            &SearchConfig { quant: all_fixed(native), ..golden_cfg() },
        );
        assert_identical(&disabled, &explicit, name);
        for d in &disabled.designs {
            assert_eq!(
                (d.input_bits, d.weight_bits),
                (native, native),
                "{name}/{}: disabled axis must report native widths",
                d.op_name
            );
        }

        // Blessing runs are golden_cosearch's job; here a blessing pass
        // just skips the compare.
        if env_flag("SNIPSNAP_BLESS") {
            continue;
        }
        let path = golden_path(name);
        match std::fs::read_to_string(&path) {
            Ok(want) => assert_eq!(
                render_fixture(&disabled),
                want,
                "{name}: quant-disabled search diverged from {}",
                path.display()
            ),
            Err(_) if env_flag("SNIPSNAP_REQUIRE_GOLDEN") => panic!(
                "{name}: golden fixture {} is missing and SNIPSNAP_REQUIRE_GOLDEN=1. \
                 Generate it with `SNIPSNAP_BLESS=1 cargo test --test golden_cosearch` \
                 and commit the file.",
                path.display()
            ),
            Err(_) => eprintln!(
                "SKIP golden compare for '{name}': {} missing \
                 (create with `SNIPSNAP_BLESS=1 cargo test --test golden_cosearch`)",
                path.display()
            ),
        }
    }
}

#[test]
fn quant_disabled_identity_across_metrics_and_backends() {
    let arch = presets::arch3();
    let w = mha_small();
    let native = small_cfg().engine.data_bits;
    for metric in [Metric::Energy, Metric::MemoryEnergy, Metric::Latency, Metric::Edp] {
        for cost in [CostModel::Analytical, CostModel::Contention(ContentionParams::default())]
        {
            let mk = |quant| SearchConfig { metric, cost, quant, ..small_cfg() };
            let disabled = cosearch_workload(&arch, &w, &mk(QuantConfig::default()));
            let explicit = cosearch_workload(&arch, &w, &mk(all_fixed(native)));
            assert_identical(&disabled, &explicit, &format!("{metric:?}/{cost}"));
        }
    }
}

#[test]
fn quant_disabled_identity_across_threads_and_prune() {
    let arch = presets::arch3();
    let w = mha_small();
    let native = small_cfg().engine.data_bits;
    let serial = cosearch_workload(
        &arch,
        &w,
        &SearchConfig { threads: 1, prune: false, ..small_cfg() },
    );
    for threads in [1usize, 3, 4] {
        for prune in [true, false] {
            let r = cosearch_workload(
                &arch,
                &w,
                &SearchConfig { threads, prune, quant: all_fixed(native), ..small_cfg() },
            );
            let what = format!("threads={threads} prune={prune}");
            assert_eq!(render_designs(&serial), render_designs(&r), "{what}");
            for (ds, dr) in serial.designs.iter().zip(&r.designs) {
                assert_eq!(ds.metric_value.to_bits(), dr.metric_value.to_bits(), "{what}");
                assert_eq!((dr.input_bits, dr.weight_bits), (native, native), "{what}");
            }
            if !prune {
                // Evaluation counts are thread-invariant only with the
                // pruner off (docs/SEARCH.md).
                assert_eq!(serial.evaluations, r.evaluations, "{what}");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Layer 2 — quant searches keep the determinism contract.

#[test]
fn quant_search_designs_are_thread_and_prune_invariant() {
    let arch = presets::arch3();
    let w = mha_small(); // has qk/av ops, so the KV space is exercised
    let quant = QuantConfig {
        w_bits: Some(BitwidthSpace::new(vec![4, 16]).unwrap()),
        a_bits: Some(BitwidthSpace::fixed(8)),
        kv_bits: Some(BitwidthSpace::new(vec![8, 16]).unwrap()),
    };
    let mk = |threads, prune| SearchConfig {
        threads,
        prune,
        quant: quant.clone(),
        ..small_cfg()
    };
    let serial = cosearch_workload(&arch, &w, &mk(1, false));
    for d in &serial.designs {
        assert_eq!(d.input_bits, 8, "{}: activations pinned at 8", d.op_name);
        assert!(
            [4, 8, 16].contains(&d.weight_bits),
            "{}: width {} outside every configured space",
            d.op_name,
            d.weight_bits
        );
    }
    for threads in [1usize, 3, 4] {
        for prune in [true, false] {
            let r = cosearch_workload(&arch, &w, &mk(threads, prune));
            let what = format!("threads={threads} prune={prune}");
            assert_eq!(render_designs(&serial), render_designs(&r), "{what}");
            for (ds, dr) in serial.designs.iter().zip(&r.designs) {
                assert_eq!(ds.metric_value.to_bits(), dr.metric_value.to_bits(), "{what}");
                assert_eq!(
                    (ds.input_bits, ds.weight_bits),
                    (dr.input_bits, dr.weight_bits),
                    "{what}/{}: chosen widths must be thread/prune invariant",
                    ds.op_name
                );
            }
            if !prune {
                assert_eq!(serial.evaluations, r.evaluations, "{what}");
            }
        }
    }
}

#[test]
fn env_selected_backend_drives_a_quant_search() {
    // Read-only on SNIPSNAP_COST_BACKEND (all mutation lives in
    // cost_backends.rs; env mutation is process-global).  CI runs this
    // binary once per backend; the set-dominance theorem is backend-
    // independent, so it must hold under whatever the env selected.
    let cost = backend_from_env();
    let arch = presets::arch3();
    let op = MatMulOp {
        name: "p/fc1".into(),
        dims: ProblemDims::new(64, 64, 64),
        spec: SparsitySpec::unstructured(0.4, 0.4),
        count: 1,
    };
    let widths = [4u32, 8, 16];
    let mk = |quant| SearchConfig {
        metric: Metric::Latency,
        cost,
        quant,
        mapper: MapperConfig { max_candidates: 150, ..Default::default() },
        ..Default::default()
    };
    let set = QuantConfig {
        w_bits: Some(BitwidthSpace::new(widths.to_vec()).unwrap()),
        a_bits: Some(BitwidthSpace::fixed(8)),
        ..QuantConfig::default()
    };
    let mut tel = SearchTelemetry::default();
    let searched = cosearch_op(&arch, &op, &mk(set), &mut tel).unwrap();
    assert!(searched.metric_value.is_finite() && searched.metric_value > 0.0);
    assert_eq!(searched.input_bits, 8);
    assert!(widths.contains(&searched.weight_bits));
    for b in widths {
        let fixed_q = QuantConfig {
            w_bits: Some(BitwidthSpace::fixed(b)),
            a_bits: Some(BitwidthSpace::fixed(8)),
            ..QuantConfig::default()
        };
        let fixed = cosearch_op(&arch, &op, &mk(fixed_q), &mut tel).unwrap();
        assert!(
            searched.metric_value <= fixed.metric_value,
            "{cost}: set search {} beaten by fixed {b}-bit {}",
            searched.metric_value,
            fixed.metric_value
        );
    }
}

// ---------------------------------------------------------------------
// Layer 3 — property tests.

#[test]
fn prop_format_bits_strictly_monotone_in_payload_width() {
    run("format bits monotone in payload width", 60, |g: &mut Gen| {
        let rows = g.dim(64).max(2);
        let cols = g.dim(64).max(2);
        let density = g.f64_in(0.05, 1.0);
        let pattern = SparsityPattern::Unstructured { density };
        let cfg = EngineConfig::default();
        let (top, _) = search_formats(rows, cols, &pattern, None, &cfg);
        let widths = [2u32, 4, 8, 12, 16];
        let i = g.usize_in(0, widths.len() - 2);
        let lo = widths[i];
        let hi = widths[g.usize_in(i + 1, widths.len() - 1)];
        for s in top.iter().take(3) {
            let c_lo = analytical_cost_quant(&s.format, &pattern, cfg.data_bits, lo);
            let c_hi = analytical_cost_quant(&s.format, &pattern, cfg.data_bits, hi);
            // Metadata and the dense reference are precision-independent
            // (the lower-bound soundness condition, docs/SEARCH.md) ...
            assert_eq!(c_lo.metadata_bits.to_bits(), c_hi.metadata_bits.to_bits());
            assert_eq!(c_lo.dense_bits.to_bits(), c_hi.dense_bits.to_bits());
            // ... while payload, total and ratio grow strictly with the
            // width (density >= 0.05 keeps the expected payload nonzero).
            assert!(c_lo.payload_bits < c_hi.payload_bits, "{}", s.format);
            assert!(c_lo.total_bits() < c_hi.total_bits(), "{}", s.format);
            assert!(c_lo.ratio() < c_hi.ratio(), "{}", s.format);
        }
    });
}

#[test]
fn prop_set_search_dominates_fixed_and_stays_in_set() {
    let arch = presets::arch3();
    run("quant set search dominates fixed widths", 10, |g: &mut Gen| {
        let dims = ProblemDims::new(
            g.dim(32).max(8),
            g.dim(32).max(8),
            g.dim(32).max(8),
        );
        let op = MatMulOp {
            // Alternate KV-slot and plain ops so both spaces get hit.
            name: if g.bool() { "p/qk".into() } else { "p/fc1".into() },
            dims,
            spec: SparsitySpec::unstructured(g.f64_in(0.2, 0.9), g.f64_in(0.2, 0.9)),
            count: 1,
        };
        let all = [4u32, 8, 16];
        let mut set: Vec<u32> = all.iter().copied().filter(|_| g.bool()).collect();
        if set.is_empty() {
            set.push(*g.choose(&all));
        }
        let metric = *g.choose(&[
            Metric::Energy,
            Metric::MemoryEnergy,
            Metric::Latency,
            Metric::Edp,
        ]);
        let space = BitwidthSpace::new(set.clone()).unwrap();
        let mk = |w: BitwidthSpace, kv: BitwidthSpace| SearchConfig {
            metric,
            quant: QuantConfig { w_bits: Some(w), a_bits: None, kv_bits: Some(kv) },
            mapper: MapperConfig { max_candidates: 150, ..Default::default() },
            ..Default::default()
        };
        let mut tel = SearchTelemetry::default();
        let searched = cosearch_op(&arch, &op, &mk(space.clone(), space.clone()), &mut tel)
            .expect("set search found no design");
        assert!(
            set.contains(&searched.weight_bits),
            "searched width {} outside the configured set {set:?}",
            searched.weight_bits
        );
        assert_eq!(searched.input_bits, 16, "a_bits=None stays at data_bits");
        for &b in &set {
            let fixed = cosearch_op(
                &arch,
                &op,
                &mk(BitwidthSpace::fixed(b), BitwidthSpace::fixed(b)),
                &mut tel,
            )
            .expect("fixed search found no design");
            // Exact: the fixed run's candidate list is a sub-list of the
            // set run's (per-combination truncation), and each candidate
            // maps + refines deterministically.
            assert!(
                searched.metric_value <= fixed.metric_value,
                "{metric:?}: set {set:?} gave {}, fixed {b} gave {}",
                searched.metric_value,
                fixed.metric_value
            );
        }
    });
}

#[test]
fn prop_snapshot_render_load_fixed_point_for_quant() {
    let arch = presets::arch3();
    let w = Workload {
        name: "snap".into(),
        ops: vec![MatMulOp {
            name: "g".into(),
            dims: ProblemDims::new(16, 16, 16),
            spec: SparsitySpec::unstructured(0.5, 0.5),
            count: 1,
        }],
    };
    run("quant snapshot render-load fixed point", 40, |g: &mut Gen| {
        let mut rand_space = |g: &mut Gen| -> Option<BitwidthSpace> {
            if g.bool() {
                return None;
            }
            let all = [2u32, 4, 6, 8, 12, 16];
            let mut v: Vec<u32> = all.iter().copied().filter(|_| g.bool()).collect();
            if v.is_empty() {
                v.push(*g.choose(&all));
            }
            Some(BitwidthSpace::new(v).unwrap())
        };
        let cfg = SearchConfig {
            quant: QuantConfig {
                w_bits: rand_space(g),
                a_bits: rand_space(g),
                kv_bits: rand_space(g),
            },
            ..Default::default()
        };
        let s1 = snapshot::render(&arch, &w, &cfg);
        let loaded = load_run_config_any(&s1).expect("snapshot must load");
        assert_eq!(loaded.search.quant, cfg.quant, "quant did not round-trip");
        let s2 = snapshot::render(&loaded.arch, &loaded.workload, &loaded.search);
        assert_eq!(s1, s2, "render∘load is not a fixed point");
    });
}
