//! Frontier-mode differential correctness: one `--metric frontier`
//! arena pass must reproduce, **bit for bit**, the winners of four
//! independent scalar searches (energy / memory-energy / latency /
//! EDP) — across thread counts, prune on/off and both cost backends —
//! while spending strictly fewer cost-model evaluations than the four
//! passes combined (serially, with identical prune decisions).
//!
//! Also pinned here: the best-first proto ordering is telemetry-only
//! (designs, scores and frontier winners are bit-identical with it on
//! or off), and with pruning off the retained Pareto points themselves
//! are thread-invariant.

use snipsnap::arch::presets;
use snipsnap::cost::{ContentionParams, CostModel, Metric};
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::search::{cosearch_workload, FormatMode, OpDesign, SearchConfig, WorkloadResult};
use snipsnap::workload::llm;

fn reduced_llm() -> snipsnap::workload::Workload {
    llm::opt_125m(llm::Phase::prefill_only(64))
}

fn backends() -> [CostModel; 2] {
    [CostModel::Analytical, CostModel::Contention(ContentionParams::default())]
}

fn cfg(
    metric: Metric,
    threads: usize,
    prune: bool,
    best_first: bool,
    cost: CostModel,
) -> SearchConfig {
    SearchConfig {
        mode: FormatMode::Fixed,
        metric,
        threads,
        prune,
        best_first,
        cost,
        mapper: MapperConfig { max_candidates: 600, ..Default::default() },
        ..Default::default()
    }
}

/// Design lists equal bit for bit (telemetry intentionally ignored).
fn assert_design_lists_identical(a: &[OpDesign], b: &[OpDesign], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: design count diverged");
    for (da, db) in a.iter().zip(b) {
        assert_eq!(da.op_name, db.op_name, "{what}");
        assert_eq!(da.mapping, db.mapping, "{what}: {} mappings diverged", da.op_name);
        assert_eq!(
            da.metric_value.to_bits(),
            db.metric_value.to_bits(),
            "{what}: {} values diverged ({} vs {})",
            da.op_name,
            da.metric_value,
            db.metric_value
        );
        assert_eq!(da.input_format.to_string(), db.input_format.to_string(), "{what}");
        assert_eq!(da.weight_format.to_string(), db.weight_format.to_string(), "{what}");
        assert_eq!(da.input_bits, db.input_bits, "{what}");
        assert_eq!(da.weight_bits, db.weight_bits, "{what}");
        assert_eq!(da.report, db.report, "{what}: {} reports diverged", da.op_name);
        assert_eq!(da.count, db.count, "{what}");
    }
}

/// Four independent scalar searches — the reference the frontier pass
/// must reproduce exactly.
fn solo_references(
    arch: &snipsnap::arch::Accelerator,
    w: &snipsnap::workload::Workload,
    cost: CostModel,
) -> Vec<WorkloadResult> {
    Metric::SCALARS
        .iter()
        .map(|&m| cosearch_workload(arch, w, &cfg(m, 1, true, true, cost)))
        .collect()
}

#[test]
fn frontier_winners_match_independent_scalar_searches() {
    let arch = presets::arch3();
    let w = reduced_llm();
    for cost in backends() {
        let solo = solo_references(&arch, &w, cost);
        for threads in [1usize, 3, 4] {
            for prune in [false, true] {
                let what = format!("{cost} threads={threads} prune={prune}");
                let r = cosearch_workload(
                    &arch,
                    &w,
                    &cfg(Metric::Frontier, threads, prune, true, cost),
                );
                let f = r.frontier.as_ref().unwrap_or_else(|| panic!("{what}: no frontier"));
                for (mi, s) in solo.iter().enumerate() {
                    assert_design_lists_identical(
                        &f.winners[mi],
                        &s.designs,
                        &format!("{what} metric={:?}", Metric::SCALARS[mi]),
                    );
                }
                // The result's primary designs ARE the energy winners.
                assert_design_lists_identical(&r.designs, &f.winners[0], &what);
                assert!(r.frontier_size as usize >= w.ops.len(), "{what}: frontier too small");
                assert_eq!(r.frontier_size, f.total_points(), "{what}");
                if !prune {
                    assert_eq!(r.pruned, 0, "{what}: prune=false must never prune");
                    assert_eq!(r.pruned_by_metric, [0; 4], "{what}");
                    assert_eq!(r.bound_tightenings, 0, "{what}");
                }
            }
        }
    }
}

#[test]
fn frontier_winners_match_in_format_search_mode() {
    // Same differential with the format pair loop live: the per-metric
    // first-pair-wins rule must match each solo search's pair choice.
    let arch = presets::arch3();
    let w = reduced_llm();
    let cost = CostModel::Analytical;
    let mk = |metric, threads| SearchConfig {
        mode: FormatMode::Search,
        ..cfg(metric, threads, true, true, cost)
    };
    let solo: Vec<WorkloadResult> =
        Metric::SCALARS.iter().map(|&m| cosearch_workload(&arch, &w, &mk(m, 1))).collect();
    for threads in [1usize, 3] {
        let r = cosearch_workload(&arch, &w, &mk(Metric::Frontier, threads));
        let f = r.frontier.as_ref().expect("frontier mode returns a frontier");
        for (mi, s) in solo.iter().enumerate() {
            assert_design_lists_identical(
                &f.winners[mi],
                &s.designs,
                &format!("search-mode threads={threads} metric={:?}", Metric::SCALARS[mi]),
            );
        }
    }
}

#[test]
fn best_first_ordering_is_telemetry_only() {
    let arch = presets::arch3();
    let w = reduced_llm();
    let cost = CostModel::Analytical;
    // Scalar search: designs identical with the ordering on or off, at
    // serial and sharded thread counts, prune on or off (off makes the
    // ordering inert by construction — also covered).
    for metric in [Metric::Energy, Metric::Edp] {
        for threads in [1usize, 3] {
            for prune in [false, true] {
                let off = cosearch_workload(&arch, &w, &cfg(metric, threads, prune, false, cost));
                let on = cosearch_workload(&arch, &w, &cfg(metric, threads, prune, true, cost));
                let what = format!("{metric:?} threads={threads} prune={prune}");
                assert_design_lists_identical(&off.designs, &on.designs, &what);
                if !prune {
                    // Inert: with pruning off the permutation is never
                    // built, so even the telemetry matches.
                    assert_eq!(off.evaluations, on.evaluations, "{what}");
                    assert_eq!(off.pruned, on.pruned, "{what}");
                }
            }
        }
    }
    // Frontier search: all four winner lists and the Pareto points are
    // bit-identical with the ordering on or off.
    for threads in [1usize, 3] {
        let off = cosearch_workload(&arch, &w, &cfg(Metric::Frontier, threads, true, false, cost));
        let on = cosearch_workload(&arch, &w, &cfg(Metric::Frontier, threads, true, true, cost));
        let (fo, fn_) = (off.frontier.as_ref().unwrap(), on.frontier.as_ref().unwrap());
        for mi in 0..4 {
            assert_design_lists_identical(
                &fo.winners[mi],
                &fn_.winners[mi],
                &format!("frontier threads={threads} metric={:?}", Metric::SCALARS[mi]),
            );
        }
    }
}

#[test]
fn frontier_points_are_thread_invariant_without_pruning() {
    // With pruning off every proto descends every metric, so the point
    // stream is a pure function of the arena — the retained Pareto sets
    // must match across thread counts exactly.
    let arch = presets::arch3();
    let w = reduced_llm();
    let cost = CostModel::Analytical;
    let base = cosearch_workload(&arch, &w, &cfg(Metric::Frontier, 1, false, true, cost));
    let fb = base.frontier.as_ref().unwrap();
    for threads in [3usize, 4] {
        let r = cosearch_workload(&arch, &w, &cfg(Metric::Frontier, threads, false, true, cost));
        let f = r.frontier.as_ref().unwrap();
        assert_eq!(fb.op_points.len(), f.op_points.len());
        for ((na, pa), (nb, pb)) in fb.op_points.iter().zip(&f.op_points) {
            assert_eq!(na, nb, "op order diverged at {threads} threads");
            assert_eq!(pa.len(), pb.len(), "{na}: point count diverged at {threads} threads");
            for (a, b) in pa.iter().zip(pb) {
                assert_eq!(a.id, b.id, "{na}: point ids diverged at {threads} threads");
                for mi in 0..4 {
                    assert_eq!(
                        a.values[mi].to_bits(),
                        b.values[mi].to_bits(),
                        "{na}: point values diverged at {threads} threads"
                    );
                }
            }
        }
    }
}

#[test]
fn one_frontier_pass_beats_four_scalar_passes() {
    // The headline claim: serially, with pruning on and the index-order
    // visit (best_first off, so each metric's prune set is identical to
    // its solo search's), the single frontier pass spends strictly
    // fewer cost-model evaluations than the four scalar passes summed —
    // the trial recorder shares every mapping the descents have in
    // common.
    let arch = presets::arch3();
    let w = reduced_llm();
    for cost in backends() {
        let four_pass: u64 = Metric::SCALARS
            .iter()
            .map(|&m| cosearch_workload(&arch, &w, &cfg(m, 1, true, false, cost)).evaluations)
            .sum();
        let one_pass =
            cosearch_workload(&arch, &w, &cfg(Metric::Frontier, 1, true, false, cost)).evaluations;
        assert!(
            one_pass < four_pass,
            "{cost}: one-pass frontier spent {one_pass} evaluations vs {four_pass} for four passes"
        );
    }
}
